#!/usr/bin/env python3
"""Compare two perf_hotpath bench JSON snapshots kernel by kernel.

Usage: bench_compare.py BASELINE.json CANDIDATE.json [--tolerance 0.25]

For every kernel present in both files the *speedup* column
(dispatched-vs-scalar throughput ratio) is compared; the run fails if
any kernel's candidate speedup drops more than --tolerance (default
25%) below the baseline.  Speedup ratios — not absolute GB/s — are
compared on purpose: both columns of one snapshot come from the same
host, so the ratio is stable across runner hardware generations while
raw throughput is not.

Kernels that appear only in one file are reported but never fail the
run (new kernels land, old ones retire).  The optional "serve" section
is printed for visibility only: QPS and latency quantiles are
host-absolute, so they carry no portable pass/fail threshold.

The optional "convergence" section (epochs to a duality-gap target per
engine) IS gated: epoch counts are seed-deterministic algorithm
properties, not host measurements, so the run fails if an engine that
reached the target in the baseline no longer does, or needs more than
1.5x + 2 of the baseline's epochs.  Engines present in only one file
are reported but never fail the run; snapshots written before the
section existed skip the gate entirely.

Exit status: 0 ok, 1 regression found, 2 bad input.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_compare: cannot read {path}: {e}")
    kernels = {r["kernel"]: r for r in doc.get("kernels", [])}
    if not kernels:
        sys.exit(f"bench_compare: {path} has no kernel records")
    return doc, kernels


def compare_convergence(base_doc, cand_doc):
    """Gate the epochs-to-gap-target convergence records.

    Returns (name, base, cand, ratio) failure tuples compatible with
    the kernel failure report.  An engine regresses when it no longer
    reaches the gap target the baseline reached, or needs more than
    1.5 * baseline + 2 epochs (the +2 absorbs eval-cadence
    quantization on fast-converging engines).
    """
    base = {
        (r.get("engine"), r.get("dataset")): r
        for r in base_doc.get("convergence", [])
    }
    cand = {
        (r.get("engine"), r.get("dataset")): r
        for r in cand_doc.get("convergence", [])
    }
    if not base or not cand:
        if base or cand:
            print("\nconvergence: section present in only one snapshot — gate skipped")
        return []

    failures = []
    width = max(len(f"{e} [{d}]") for e, d in set(base) | set(cand))
    print(f"\n{'engine':{width}}  base epochs  cand epochs")
    for key in sorted(set(base) | set(cand)):
        name = f"{key[0]} [{key[1]}]"
        if key not in base:
            print(f"{name:{width}}  (new engine, no baseline — skipped)")
            continue
        if key not in cand:
            print(f"{name:{width}}  (retired: absent from candidate — skipped)")
            continue
        b = base[key].get("epochs_to_target")
        c = cand[key].get("epochs_to_target")
        if b is None:
            # baseline never reached the target: nothing to hold the
            # candidate to (it can only improve)
            status = "ok" if c is not None else "(target unreached in both)"
            print(f"{name:{width}}  {'-':>11}  {c if c is not None else '-':>11}  {status}")
            continue
        if c is None:
            failures.append((name, float(b), float("inf"), float("inf")))
            print(f"{name:{width}}  {b:11d}  {'-':>11}  << REGRESSION (target no longer reached)")
            continue
        limit = 1.5 * b + 2
        mark = ""
        if c > limit:
            mark = f"  << REGRESSION (limit {limit:.0f})"
            failures.append((name, float(b), float(c), c / max(b, 1)))
        print(f"{name:{width}}  {b:11d}  {c:11d}{mark}")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="max allowed fractional speedup drop per kernel (default 0.25)",
    )
    args = ap.parse_args()
    if not 0.0 <= args.tolerance < 1.0:
        sys.exit("bench_compare: --tolerance must be in [0, 1)")

    base_doc, base = load(args.baseline)
    cand_doc, cand = load(args.candidate)

    width = max(len(k) for k in set(base) | set(cand))
    failures = []
    print(f"{'kernel':{width}}  baseline  candidate  ratio")
    for name in sorted(set(base) | set(cand)):
        if name not in base:
            print(f"{name:{width}}  (new kernel, no baseline — skipped)")
            continue
        if name not in cand:
            print(f"{name:{width}}  (retired: absent from candidate — skipped)")
            continue
        b, c = base[name].get("speedup"), cand[name].get("speedup")
        if not b or not c or b <= 0:
            print(f"{name:{width}}  (non-finite speedup — skipped)")
            continue
        ratio = c / b
        mark = ""
        if ratio < 1.0 - args.tolerance:
            mark = "  << REGRESSION"
            failures.append((name, b, c, ratio))
        print(f"{name:{width}}  {b:8.3f}  {c:9.3f}  {ratio:5.2f}x{mark}")

    conv_failures = compare_convergence(base_doc, cand_doc)
    failures.extend(conv_failures)

    for doc, label in ((base_doc, "baseline"), (cand_doc, "candidate")):
        s = doc.get("serve")
        if s:
            print(
                f"serve [{label}]: {s.get('qps', 0):.0f} req/s, "
                f"p50 {s.get('p50_ms', 0):.3f} ms, p99 {s.get('p99_ms', 0):.3f} ms, "
                f"{s.get('published', 0)} published / {s.get('rejected', 0)} rejected"
                " (informational only)"
            )
            # memory counters (ISSUE 8) — absent from pre-8 snapshots
            if "ingest_dropped" in s or "corpus_peak" in s:
                print(
                    f"serve [{label}] memory: "
                    f"{s.get('ingest_dropped', 0)} ingest dropped, "
                    f"{s.get('corpus_evicted', 0)} corpus evicted, "
                    f"corpus peak {s.get('corpus_peak', 0)}"
                    " (informational only)"
                )

    if failures:
        print(f"\nFAIL: {len(failures)} entr(y/ies) regressed vs {args.baseline}:")
        for name, b, c, ratio in failures:
            print(f"  {name}: {b:.3f} -> {c:.3f} ({ratio:.2f}x)")
        sys.exit(1)
    print(
        f"\nOK: no kernel speedup regressed more than {args.tolerance:.0%} "
        "and no engine lost convergence speed"
    )


if __name__ == "__main__":
    main()
