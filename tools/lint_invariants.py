#!/usr/bin/env python3
"""Enforce the repo's concurrency/robustness invariants over rust/src.

Usage: lint_invariants.py [--root DIR] [--self-test]

Rules (see rust/DESIGN.md §12 for the rationale behind each):

  R1  no `std::sync::atomic` reference outside the `sync` shim
      (rust/src/sync/) and the CAS-loop kernels
      (rust/src/kernels/atomic_impl.rs) — protocol atomics must route
      through `crate::sync` so the model checker can interleave them;
      data-plane sites use `crate::sync::raw`, which is fine.
  R2  no unbounded spin loop: a `while` whose condition polls `.load(`
      must spin/yield/sleep/wait, or break/return, inside its body
      (escape hatch: `// SPIN-OK: <why>` on or above the loop).
  R3  every `unsafe` is justified: a `// SAFETY:` comment (or a
      `/// # Safety` doc section) in the contiguous comment block above
      it or within the 12 preceding lines.
  R4  no raw dot/axpy multiply-accumulate loop outside rust/src/kernels/
      — scalar fallbacks belong next to the SIMD dispatch they shadow.
  R5  no `.unwrap()` / `.expect(` in library code (tests, benches and
      the `main.rs` binary are exempt) — recover or return `Result`
      (escape hatch: `// PANIC-OK: <why>` on or above the call).

Lines that are comments are never matched; `#[cfg(test)]` items are
skipped by brace matching (block comments `/* */` are not tracked —
the crate uses line comments only).

`--self-test` runs every rule against the negative fixtures in
tools/lint_fixtures/ and fails unless each fixture trips exactly the
rule its filename names (and the `clean_` fixture trips none).

Exit status: 0 clean, 1 findings (or self-test failure), 2 bad input.
"""

import argparse
import re
import sys
from pathlib import Path

ATOMIC_ALLOW = ("rust/src/sync/", "rust/src/kernels/atomic_impl.rs")
KERNEL_DIR = "rust/src/kernels/"
R5_EXEMPT = ("rust/src/main.rs",)

SPIN_MARKERS = re.compile(r"\b(spin|yield|wait|sleep|park|break|return)\b")
RAW_MAC_PATTERNS = (
    re.compile(r"\+=\s*\w+\[\w+\]\s*\*\s*\w+\[\w+\]"),
    re.compile(r"\[\w+\]\s*\+=\s*\w+\s*\*\s*\w+\[\w+\]"),
)
UNWRAP = re.compile(r"\.unwrap\(\)")
EXPECT = re.compile(r"\.expect\(")
UNSAFE = re.compile(r"\bunsafe\b")
WHILE_LOAD = re.compile(r"^\s*(?:\}\s*)?while\b.*\.load\(")


def is_comment(line):
    return line.lstrip().startswith(("//", "//!", "///"))


def is_attr(line):
    return line.lstrip().startswith("#[") or line.lstrip().startswith("#![")


def strip_trailing_comment(line):
    """Drop a trailing line comment.  Only `//` preceded by whitespace
    counts, so `https://` inside a string survives."""
    idx = line.find(" //")
    if idx >= 0:
        return line[:idx]
    if line.lstrip().startswith("//"):
        return ""
    return line


def code_of(line):
    """The matchable code portion of a raw source line."""
    if is_comment(line):
        return ""
    return strip_trailing_comment(line)


def test_region_lines(lines):
    """0-based indices of lines inside `#[cfg(test)]`-gated items."""
    skip = set()
    i = 0
    n = len(lines)
    while i < n:
        if "#[cfg(test)]" in lines[i] and not is_comment(lines[i]):
            depth = 0
            j = i
            opened = False
            while j < n:
                skip.add(j)
                code = code_of(lines[j])
                depth += code.count("{") - code.count("}")
                if code.count("{"):
                    opened = True
                if opened and depth <= 0:
                    break
                j += 1
            i = j + 1
        else:
            i += 1
    return skip


def comment_block_above(lines, i):
    """The contiguous run of comment/attribute/blank lines above line i."""
    block = []
    j = i - 1
    while j >= 0 and (is_comment(lines[j]) or is_attr(lines[j]) or not lines[j].strip()):
        block.append(lines[j])
        j -= 1
    return block


def has_hatch(lines, i, token):
    """`token` on line i (raw, so trailing comments count) or anywhere
    in the contiguous comment block above it."""
    if token in lines[i]:
        return True
    return any(token in l for l in comment_block_above(lines, i))


def while_body(lines, i):
    """Text of the while-loop body starting at line i (brace-matched);
    falls back to the next 30 lines when no opening brace is found."""
    depth = 0
    opened = False
    body = []
    j = i
    while j < len(lines):
        code = code_of(lines[j])
        if opened:
            body.append(code)
        depth += code.count("{") - code.count("}")
        if not opened and "{" in code:
            opened = True
            body.append(code[code.index("{"):])
        if opened and depth <= 0:
            return "\n".join(body)
        j += 1
        if j - i > 400:
            break
    if not opened:
        return "\n".join(code_of(l) for l in lines[i:i + 30])
    return "\n".join(body)


def check_file(path, rel, lines=None):
    """All findings for one file, as (rule, 1-based line, message)."""
    if lines is None:
        try:
            lines = path.read_text().splitlines()
        except (OSError, UnicodeDecodeError) as e:
            sys.exit(f"lint_invariants: cannot read {path}: {e}")
    findings = []
    in_tests = test_region_lines(lines)
    allow_atomics = any(rel.startswith(p) or rel == p for p in ATOMIC_ALLOW)
    in_kernels = rel.startswith(KERNEL_DIR)
    r5_exempt = rel in R5_EXEMPT

    for i, raw in enumerate(lines):
        if i in in_tests:
            continue
        code = code_of(raw)
        if not code.strip():
            continue

        if not allow_atomics and "std::sync::atomic" in code:
            findings.append((
                "R1", i + 1,
                "std::sync::atomic outside the sync shim — route protocol "
                "atomics through crate::sync (data plane: crate::sync::raw)",
            ))

        if WHILE_LOAD.search(code) and not has_hatch(lines, i, "SPIN-OK"):
            body = while_body(lines, i)
            if not SPIN_MARKERS.search(body):
                findings.append((
                    "R2", i + 1,
                    "unbounded spin loop: poll loops must spin/yield/sleep/"
                    "wait or break (sync::spin::SpinWait), or carry "
                    "// SPIN-OK: <why>",
                ))

        if UNSAFE.search(code):
            window = comment_block_above(lines, i) + lines[max(0, i - 12):i]
            justified = ("SAFETY:" in raw or "# Safety" in raw
                         or any("SAFETY:" in l or "# Safety" in l for l in window))
            if not justified:
                findings.append((
                    "R3", i + 1,
                    "unsafe without a // SAFETY: comment (or /// # Safety "
                    "doc) justifying it",
                ))

        if not in_kernels and any(p.search(code) for p in RAW_MAC_PATTERNS):
            findings.append((
                "R4", i + 1,
                "raw multiply-accumulate loop outside kernels/ — call the "
                "dispatched kernels (dot/axpy/sq_norm) instead",
            ))

        if not r5_exempt and (UNWRAP.search(code) or EXPECT.search(code)):
            if not has_hatch(lines, i, "PANIC-OK"):
                findings.append((
                    "R5", i + 1,
                    "unwrap()/expect() in library code — recover, return "
                    "Result, or justify with // PANIC-OK: <why>",
                ))

    return findings


def lint_repo(root):
    src = root / "rust" / "src"
    if not src.is_dir():
        sys.exit(f"lint_invariants: no rust/src under {root}")
    total = 0
    for path in sorted(src.rglob("*.rs")):
        rel = path.relative_to(root).as_posix()
        for rule, line, msg in check_file(path, rel):
            total += 1
            print(f"{rel}:{line}: {rule}: {msg}")
    if total:
        print(f"\nFAIL: {total} invariant violation(s)")
        return 1
    print("OK: rust/src holds all lint invariants (R1-R5)")
    return 0


def self_test(root):
    fixtures = root / "tools" / "lint_fixtures"
    files = sorted(fixtures.glob("*.rs"))
    if not files:
        sys.exit(f"lint_invariants: no fixtures under {fixtures}")
    failed = 0
    for path in files:
        # fixture files are linted as if they lived in library code
        rel = "rust/src/" + path.name
        found = {rule for rule, _, _ in check_file(path, rel)}
        name = path.stem
        expect = {name.split("_")[0].upper()} if name.startswith("r") else set()
        status = "ok"
        if found != expect:
            failed += 1
            status = f"FAIL (expected {sorted(expect)}, got {sorted(found)})"
        print(f"self-test {path.name}: fires {sorted(found)} ... {status}")
    if failed:
        print(f"\nFAIL: {failed} fixture(s) did not trip their rule")
        return 1
    print(f"\nOK: all {len(files)} fixtures behave ({len(files) - 1} negative + clean)")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repository root (default: the parent of tools/)",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="verify every rule fires on its negative fixture",
    )
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test(args.root))
    sys.exit(lint_repo(args.root))


if __name__ == "__main__":
    main()
