//! Negative fixture: R5 must fire on an unjustified unwrap/expect in
//! library code.

pub fn head(xs: &[u32]) -> u32 {
    let first = xs.first().unwrap();
    *first
}
