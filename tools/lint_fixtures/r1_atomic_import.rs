//! Negative fixture: R1 must fire on a direct std atomic import in
//! library code (protocol atomics belong behind crate::sync).

use std::sync::atomic::AtomicU64;

pub fn counter() -> AtomicU64 {
    AtomicU64::new(0)
}
