//! Negative fixture: R3 must fire on an unsafe block with no SAFETY
//! comment in reach.

pub fn first(ptr: *const f32) -> f32 {
    let a = 1.0f32;
    let b = 2.0f32;
    let c = 3.0f32;
    let d = 4.0f32;
    let e = 5.0f32;
    let f = 6.0f32;
    let g = 7.0f32;
    let h = 8.0f32;
    let i = 9.0f32;
    let j = 10.0f32;
    let k = 11.0f32;
    let l = 12.0f32;
    let pad = a + b + c + d + e + f + g + h + i + j + k + l;
    let v = unsafe { *ptr };
    v + pad
}
