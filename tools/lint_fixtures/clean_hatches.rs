//! Positive fixture: every escape hatch and exemption in one file —
//! the lint must report nothing here.

use crate::sync::spin::SpinWait;
use crate::sync::{AtomicBool, Ordering};

/// A justified spin loop (discipline marker inside the body).
pub fn bounded_drain(flag: &AtomicBool) {
    let mut sw = SpinWait::new();
    while flag.load(Ordering::Acquire) {
        sw.spin();
    }
}

pub fn hatched_drain(flag: &AtomicBool) {
    // SPIN-OK: debug-only drain, bounded by the caller's timeout.
    while flag.load(Ordering::Acquire) {}
}

pub fn justified_unsafe(ptr: *const f32) -> f32 {
    // SAFETY: the caller guarantees `ptr` points at a live f32 for the
    // duration of this call.
    unsafe { *ptr }
}

/// Reads the head element.
///
/// # Safety
///
/// `xs` must be non-empty.
pub unsafe fn doc_justified_head(xs: &[u32]) -> u32 {
    // SAFETY: non-empty per this function's contract.
    unsafe { *xs.get_unchecked(0) }
}

pub fn justified_panic(xs: &[u32]) -> u32 {
    // PANIC-OK: the caller validated `xs` is non-empty one line up.
    let first = xs.first().unwrap();
    *first
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap_freely() {
        let v = vec![1u32];
        assert_eq!(*v.first().unwrap(), 1);
        let x: Result<u32, ()> = Ok(3);
        assert_eq!(x.unwrap(), 3);
    }
}
