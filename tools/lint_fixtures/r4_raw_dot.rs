//! Negative fixture: R4 must fire on a hand-rolled multiply-accumulate
//! loop outside kernels/ (the dispatched dot/axpy kernels exist so the
//! scalar fallback lives in exactly one place).

pub fn dot(xs: &[f32], ys: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..xs.len().min(ys.len()) {
        acc += xs[i] * ys[i];
    }
    acc
}
