//! Negative fixture: R2 must fire on a busy-wait poll loop with no
//! spin/yield/sleep/wait discipline and no SPIN-OK justification.

use crate::sync::{AtomicBool, Ordering};

pub fn drain(flag: &AtomicBool) {
    while flag.load(Ordering::Acquire) {}
}
