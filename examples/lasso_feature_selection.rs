//! Feature selection with Lasso on wide, correlated data (the paper's
//! Dogs-vs-Cats scenario: CNN-extracted features, #features >> #samples).
//!
//! ```bash
//! cargo run --release --example lasso_feature_selection
//! ```
//!
//! Demonstrates the workflow the paper's intro motivates: a planted
//! sparse model must be recovered from many correlated columns, and
//! duality-gap selection concentrates the update budget on the relevant
//! features — we report support recovery and compare against random
//! selection at an equal epoch budget.

use hthc::coordinator::Selection;
use hthc::data::{DatasetBuilder, DatasetKind, Family};
use hthc::glm::Lasso;
use hthc::memory::TierSim;
use hthc::solver::{StopWhen, Trainer};

fn f1(alpha: &[f32], truth: &[f32]) -> (f64, usize) {
    let got: Vec<bool> = alpha.iter().map(|&a| a != 0.0).collect();
    let want: Vec<bool> = truth.iter().map(|&a| a != 0.0).collect();
    let tp = got.iter().zip(&want).filter(|&(&g, &w)| g && w).count();
    let fp = got.iter().zip(&want).filter(|&(&g, &w)| g && !w).count();
    let fnn = got.iter().zip(&want).filter(|&(&g, &w)| !g && w).count();
    let prec = tp as f64 / (tp + fp).max(1) as f64;
    let rec = tp as f64 / (tp + fnn).max(1) as f64;
    (2.0 * prec * rec / (prec + rec).max(1e-12), got.iter().filter(|&&g| g).count())
}

fn main() {
    let data = DatasetBuilder::generated(DatasetKind::DvscLike, Family::Regression)
        .scale(0.25)
        .seed(7)
        .build()
        .expect("generated dataset");
    println!("dataset: {}", data.describe());
    let truth = data.alpha_star().expect("regression plants a model");
    let planted = truth.iter().filter(|&&a| a != 0.0).count();
    println!("planted support: {planted} of {} features\n", data.n());

    let sim = TierSim::default();
    for sel in [Selection::DualityGap, Selection::Random] {
        let mut model = Lasso::new(12.0);
        let res = Trainer::new()
            .threads(2, 2, 1)
            .batch_frac(0.02) // small batch: selection quality matters
            .selection(sel)
            .stop_when(
                StopWhen::gap_below(0.0) // fixed epoch budget instead
                    .max_epochs(400)
                    .eval_every(25)
                    .timeout_secs(120.0),
            )
            .fit_with(&mut model, &data, &sim);
        let (f1_score, support) = f1(&res.alpha, truth);
        println!("selection = {:<12}  {}", sel.name(), res.summary());
        println!(
            "  -> support {} features, F1 vs planted = {:.3}\n",
            support, f1_score
        );
    }
    println!(
        "note: with a {:.0}% batch, gap-guided selection should reach a \
         better F1/objective at this epoch budget — the paper's Fig. 5 \
         effect in a feature-selection setting.",
        2.0
    );
}
