//! Linear SVM on sparse high-dimensional data (the paper's News20
//! scenario: text classification, power-law sparse columns).
//!
//! ```bash
//! cargo run --release --example svm_classification
//! ```
//!
//! Exercises the dual-SVM path end to end: sparse chunked working set
//! (§IV-D), box-constrained coordinate updates, accuracy-vs-time
//! reporting against the ST baseline — plus the LIBSVM loader on an
//! inline sample so real data drops in with one path change.

use hthc::data::{DatasetBuilder, DatasetKind, Family, Matrix};
use hthc::glm::SvmDual;
use hthc::memory::TierSim;
use hthc::solver::{SeqThreshold, StopWhen, Trainer};

fn main() {
    // --- real-data path: LIBSVM format through the builder --------------
    let sample = "+1 3:0.9 7:1.2\n-1 1:0.5 3:-0.3\n+1 2:1.1 9:0.4\n";
    let samples = hthc::data::libsvm::read(sample.as_bytes()).expect("parse");
    let mini = DatasetBuilder::libsvm_samples(samples)
        .family(Family::Classification)
        .build()
        .expect("orient");
    println!(
        "libsvm loader: {} samples x {} features (labels {:?}) — swap in \
         your own file with DatasetBuilder::path(path)\n",
        mini.n_cols(),
        mini.n_rows(),
        mini.labels().unwrap()
    );

    // --- synthetic news20-like workload ---------------------------------
    let data = DatasetBuilder::generated(DatasetKind::News20Like, Family::Classification)
        .scale(0.12)
        .seed(11)
        .build()
        .expect("generated dataset");
    println!("dataset: {}", data.describe());
    let n = data.n();
    let lam = 1e-4;
    let sim = TierSim::default();

    // HTHC (A+B) — the default Trainer engine
    let stop = StopWhen::gap_below(1e-7)
        .max_epochs(200)
        .eval_every(10)
        .timeout_secs(60.0);
    let mut model = SvmDual::new(lam, n);
    let res = Trainer::new()
        .threads(2, 4, 1) // sparse: one thread per vector (paper §IV-D)
        .batch_frac(0.25)
        .stop_when(stop)
        .fit_with(&mut model, &data, &sim);
    let acc = model.accuracy(data.as_ops(), &res.v);
    println!("\nHTHC (A+B): {}", res.summary());
    println!("  training accuracy {:.2}%", acc * 100.0);

    // ST baseline at the same thread budget — same facade, one builder
    // call changed
    let mut model_st = SvmDual::new(lam, n);
    let res_st = Trainer::new()
        .solver(SeqThreshold)
        .threads(2, 6, 1)
        .stop_when(stop)
        .fit_with(&mut model_st, &data, &sim);
    let acc_st = model_st.accuracy(data.as_ops(), &res_st.v);
    println!("ST        : {}", res_st.summary());
    println!("  training accuracy {:.2}%", acc_st * 100.0);

    // box-constraint sanity
    let violations = res
        .alpha
        .iter()
        .filter(|&&a| !(-1e-6..=1.0 + 1e-6).contains(&a))
        .count();
    println!("\nbox violations: {violations} (must be 0)");
    assert_eq!(violations, 0);
    if let Matrix::Sparse(sm) = data.matrix() {
        println!("matrix density: {:.4}%", sm.density() * 100.0);
    }
}
