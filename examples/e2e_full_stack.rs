//! END-TO-END DRIVER: proves all three layers compose on a real
//! workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_full_stack
//! ```
//!
//! Pipeline exercised:
//!   L1 (Pallas tiled D^T w kernel, interpret-lowered)
//!     -> L2 (jax gap graph, AOT to HLO text by `make artifacts`)
//!       -> runtime (rust PJRT executor thread)
//!         -> L3 (HTHC coordinator: task A offloads its gap sweeps to
//!            the compiled artifact while task B runs native async SCD)
//!
//! Workload: epsilon-like dense regression (Lasso) and a dense SVM,
//! trained to fixed duality-gap targets, with the same runs repeated on
//! the native task-A path — the numerics must agree (same selection
//! signal => same convergence behaviour), which is the composition
//! proof.  Results are recorded in EXPERIMENTS.md §E2E.

use hthc::coordinator::HthcConfig;
use hthc::data::{DatasetBuilder, DatasetKind, Family};
use hthc::glm::{GlmModel, Lasso, SvmDual};
use hthc::memory::TierSim;
use hthc::runtime::{GapService, XlaRuntime};
use hthc::solver::{Hthc, StopWhen, Trainer};
use hthc::util::Timer;

fn main() {
    let dir = hthc::runtime::default_artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let t0 = Timer::start();
    let rt = XlaRuntime::start(&dir).unwrap_or_else(|e| {
        eprintln!("cannot start PJRT runtime: {e}");
        std::process::exit(1);
    });
    println!(
        "[runtime] {} artifacts loaded in {}",
        rt.manifest().artifacts.len(),
        hthc::util::fmt_secs(t0.secs())
    );
    let service = GapService::new(&rt);

    // ---------------- Lasso on epsilon-like dense -----------------------
    let data = DatasetBuilder::generated(DatasetKind::EpsilonLike, Family::Regression)
        .scale(0.2)
        .seed(4242)
        .build()
        .expect("generated dataset");
    println!("\n=== Lasso, {} ===", data.describe());
    let obj0 =
        Lasso::new(0.05).objective(&vec![0.0; data.d()], data.targets(), &vec![0.0; data.n()]);
    let tol = 1e-4 * obj0;
    let cfg = HthcConfig {
        t_a: 2,
        t_b: 2,
        v_b: 1,
        batch_frac: 0.1,
        gap_tol: tol,
        max_epochs: 3000,
        eval_every: 10,
        timeout_secs: 180.0,
        ..Default::default()
    };

    let run = |label: &str, use_pjrt: bool| {
        let mut model = Lasso::new(0.05);
        let sim = TierSim::default();
        let mut trainer = Trainer::new().config(cfg.clone());
        if use_pjrt {
            trainer = trainer.solver(Hthc::with_backend(&service));
        }
        let res = trainer.fit_with(&mut model, &data, &sim);
        println!("[{label:>10}] {}", res.summary());
        assert!(res.converged, "{label} must converge to gap <= {tol:.3e}");
        res
    };
    let res_native = run("native-A", false);
    let res_pjrt = run("pjrt-A", true);

    // composition proof: both paths land at the same optimum
    let d_obj = (res_native.trace.final_objective().unwrap()
        - res_pjrt.trace.final_objective().unwrap())
    .abs();
    println!(
        "objective agreement (native vs pjrt task A): |delta| = {d_obj:.3e} (tol {tol:.3e})"
    );
    assert!(d_obj <= 2.0 * tol, "native and PJRT paths must agree");

    // ---------------- SVM on dense classification -----------------------
    let svm_data = DatasetBuilder::generated(DatasetKind::EpsilonLike, Family::Classification)
        .scale(0.2)
        .seed(77)
        .build()
        .expect("generated dataset");
    println!("\n=== SVM, {} ===", svm_data.describe());
    let n = svm_data.n();
    let mut model = SvmDual::new(1e-3, n);
    let sim = TierSim::default();
    let res = Trainer::new()
        .solver(Hthc::with_backend(&service))
        .threads(2, 2, 1)
        .batch_frac(0.2)
        .stop_when(
            StopWhen::gap_below(1e-5)
                .max_epochs(2000)
                .eval_every(10)
                .timeout_secs(180.0),
        )
        .fit_with(&mut model, &svm_data, &sim);
    let acc = model.accuracy(svm_data.as_ops(), &res.v);
    println!("[pjrt-A   ] {}", res.summary());
    println!("training accuracy: {:.2}%", acc * 100.0);
    assert!(acc > 0.9, "separable planted data must classify well");

    println!("\nE2E OK: L1 Pallas kernel -> L2 jax graph -> HLO text -> rust PJRT -> HTHC coordinator all compose.");
}
