//! Quickstart: train Lasso with HTHC on a synthetic dense dataset.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the minimal API surface: generate (or load) data, configure
//! the two-task topology, train, inspect the convergence trace.

use hthc::data::{DatasetBuilder, DatasetKind, Family};
use hthc::glm::Lasso;
use hthc::solver::{StopWhen, Trainer};

fn main() {
    // 1. A dataset: epsilon-like (dense, samples >> features), scaled
    //    down so the example runs in seconds.  The one DatasetBuilder
    //    pipeline also loads real files (DatasetBuilder::path) and
    //    handles normalization / representation / tier placement.
    let data = DatasetBuilder::generated(DatasetKind::EpsilonLike, Family::Regression)
        .scale(0.25)
        .seed(42)
        .build()
        .expect("generated dataset");
    println!("dataset: {}", data.describe());

    // 2. A model: Lasso, regularized hard enough to select features.
    //    The gap tolerance is relative to the problem scale.
    let model = Lasso::new(2.0);
    let obj0 = {
        use hthc::glm::GlmModel;
        model.objective(&vec![0.0; data.d()], data.targets(), &vec![0.0; data.n()])
    };

    // 3. The Trainer facade: pick a solver (HTHC is the default), the
    //    two-task topology (paper §IV-F: T_A gap-refresh threads,
    //    T_B x V_B update threads, %B of coordinates per epoch) and the
    //    stopping rules, then train.  The trainer-owned TierSim records
    //    the DRAM/MCDRAM traffic split.
    let mut trainer = Trainer::new()
        .model(Box::new(model))
        .threads(2, 2, 1)
        .batch_frac(0.08)
        .stop_when(
            StopWhen::gap_below(1e-5 * obj0)
                .max_epochs(2000)
                .timeout_secs(60.0),
        );

    // 4. Train (targets travel inside the Dataset).
    let result = trainer.fit(&data);

    // 5. Inspect.
    println!("converged: {}", result.converged);
    println!("{}", result.summary());
    let support = result.alpha.iter().filter(|&&a| a != 0.0).count();
    println!(
        "selected {} of {} features ({:.1}%)",
        support,
        data.n(),
        100.0 * support as f64 / data.n() as f64
    );
    println!("\nconvergence trace (objective, duality gap):");
    for p in result.trace.points.iter().take(10) {
        println!(
            "  epoch {:>4}  t={:>8}  obj={:.6e}  gap={:.3e}",
            p.epoch,
            hthc::util::fmt_secs(p.secs),
            p.objective,
            p.duality_gap
        );
    }
}
