//! Fig. 3 — Performance of task B's coordinate updates for varying
//! vector length d, parallel updates T_B in {1,4,8,16}, and threads
//! per vector V_B (paper §V-A).
//!
//! Paper shape: below d ~ 130k one thread per vector (V_B = 1) is best;
//! for longer vectors splitting wins; more parallel updates beat more
//! threads per vector at every length (sync overhead).  Measured rows
//! cover what one core can host; modeled rows carry the full range.

use hthc::coordinator::{task_b, PerfModel, SharedVector, WorkingSet};
use hthc::data::{Dataset, DatasetBuilder, DenseMatrix, Matrix};
use hthc::glm::{GlmModel, Ridge};
use hthc::memory::TierSim;
use hthc::metrics::Table;
use hthc::threadpool::WorkerPool;
use hthc::util::timer::KNL_HZ;
use hthc::util::Timer;

fn dense_cols(d: usize, n: usize, seed: u64) -> Dataset {
    let mut rng = hthc::util::Rng::new(seed);
    let data: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect();
    let matrix = Matrix::Dense(DenseMatrix::from_col_major(d, n, data));
    DatasetBuilder::in_memory(matrix, vec![0.0; d])
        .build()
        .expect("bench dataset")
}

fn main() {
    println!("Fig. 3 reproduction: task B update performance\n");
    let t_bs = [1usize, 4, 8, 16];
    let v_bs = [1usize, 2, 4, 8];
    let measured_ds = [10_000usize, 40_000, 130_000];
    let batch = 48usize;

    let mut table = Table::new(
        "Fig 3 (measured): secs/update and flops/cycle of task B",
        &["d", "T_B", "V_B", "meas us/upd", "meas f/cyc", "model us/upd"],
    );
    let pm = PerfModel::calibrate(
        &[10_000, 130_000, 1_000_000, 5_000_000],
        &[1],
        &t_bs,
        &v_bs,
    );
    let sim0 = TierSim::default();
    let model = Ridge::new(0.5);
    let kind = model.kind();

    for &d in &measured_ds {
        let dataset = dense_cols(d, batch, 3);
        let y = vec![0.25f32; d];
        for &t_b in &t_bs {
            for &v_b in &v_bs {
                if t_b * v_b > 16 {
                    continue; // thread budget on this host
                }
                let mut ws = WorkingSet::new(dataset.matrix(), batch);
                let sim = TierSim::default();
                let all: Vec<usize> = (0..batch).collect();
                ws.swap_in(dataset.matrix(), &all, &sim, dataset.placement());
                let v = SharedVector::new(d, 1024);
                let alpha = SharedVector::new(batch, usize::MAX >> 1);
                let pool = WorkerPool::with_name(t_b * v_b, "fig3-b");
                let items = task_b::WorkItem::from_batch(&all);
                let t = Timer::start();
                let reps = 3;
                for _ in 0..reps {
                    task_b::run_epoch(
                        &pool, &ws, &items, &v, &y, &alpha, kind, t_b, v_b, &sim,
                    );
                }
                let secs = t.secs();
                let updates = (batch * reps) as f64;
                let per_upd = secs / updates;
                // flops per update: dot (2d) + axpy (2d)
                let fpc = 4.0 * d as f64 / (per_upd * KNL_HZ);
                let modeled = pm.modeled_b_update(&sim0, d, t_b, v_b);
                table.row(vec![
                    d.to_string(),
                    t_b.to_string(),
                    v_b.to_string(),
                    format!("{:.1}", per_upd * 1e6),
                    format!("{:.3}", fpc),
                    format!("{:.1}", modeled * 1e6),
                ]);
            }
        }
    }
    table.print();

    let mut mt = Table::new(
        "Fig 3 (modeled, paper range): us per update",
        &["d", "V_B=1", "V_B=2", "V_B=4", "V_B=8", "best"],
    );
    for &d in &[10_000usize, 130_000, 1_000_000, 5_000_000] {
        let per: Vec<f64> = v_bs
            .iter()
            .map(|&vb| pm.modeled_b_update(&sim0, d, 4, vb))
            .collect();
        let best = v_bs[per
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        let mut row = vec![d.to_string()];
        row.extend(per.iter().map(|p| format!("{:.1}", p * 1e6)));
        row.push(format!("V_B={best}"));
        mt.row(row);
    }
    mt.print();
    println!(
        "\nexpected shape (paper): V_B=1 best below d~130k, splitting wins \
         for longer vectors; T_B parallelism preferable to V_B splitting."
    );
}
