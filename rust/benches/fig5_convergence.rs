//! Fig. 5 — Convergence (duality gap / suboptimality vs time) for
//! Lasso and SVM on all four datasets: A+B vs ST vs ST(A+B) vs OMP vs
//! OMP WILD (paper §V-B, the headline comparison).
//!
//! Paper shape to reproduce:
//!   * Lasso dense: A+B 5-10x faster than ST to equal precision;
//!   * SVM dvsc: ~3.5x; epsilon/news20: competitive;
//!   * criteo-like sparse: ST *wins* (delta=0 skipping, §V-B2);
//!   * OMP far slower than HTHC; OMP WILD fast but plateaus above the
//!     true optimum (broken primal-dual relation).
//!
//! Reading the numbers on a 1-core host (DESIGN.md §5): the *measured*
//! wall-clock serializes task A into B's timeline, which inverts the
//! paper's premise (A runs free on spare cores).  The comparison that
//! carries the paper's shape is therefore **B-work to convergence**
//! (epochs x updates/epoch — identical per-update cost across solvers)
//! and the **modeled KNL time** built from it (B-updates x t_B from the
//! §IV-F table + working-set swap bandwidth, with A concurrent and
//! therefore free).  Both are printed alongside the raw measurements.

use hthc::bench_support::*;
use hthc::coordinator::PerfModel;
use hthc::data::generator::{DatasetKind, Family};
use hthc::glm;
use hthc::memory::TierSim;
use hthc::metrics::{report::fmt_opt_secs, Table};

fn main() {
    println!("Fig. 5 reproduction: convergence comparison\n");
    let rels = [1e-2, 1e-3, 1e-4];
    let timeout = 25.0;
    let pm = PerfModel::calibrate(&[1_000, 10_000, 100_000], &[1], &[8], &[1]);
    let sim = TierSim::default();

    let cases: Vec<(DatasetKind, &str)> = vec![
        (DatasetKind::EpsilonLike, "lasso"),
        (DatasetKind::EpsilonLike, "svm"),
        (DatasetKind::DvscLike, "lasso"),
        (DatasetKind::DvscLike, "svm"),
        (DatasetKind::News20Like, "lasso"),
        (DatasetKind::News20Like, "svm"),
        (DatasetKind::CriteoLike, "lasso"),
    ];

    for (kind, model_name) in cases {
        let family = if model_name == "svm" {
            Family::Classification
        } else {
            Family::Regression
        };
        let g = bench_dataset(kind, family, 1000 + kind as u64);
        let solvers: Vec<&str> = if kind == DatasetKind::CriteoLike {
            vec!["A+B", "ST"] // paper: only these for criteo
        } else if matches!(g.matrix(), hthc::data::Matrix::Dense(_)) {
            vec!["A+B", "ST", "ST(A+B)", "OMP", "OMP WILD"]
        } else {
            vec!["A+B", "ST", "ST(A+B)"] // paper: OMP runs only for dense
        };

        let probe = bench_model(model_name, g.n());
        let o0 = obj0(probe.as_ref(), &g);
        let mut table = Table::new(
            format!(
                "Fig 5: {} / {} ({} x {})",
                model_name,
                g.meta().source.describe(),
                g.d(),
                g.n()
            ),
            &[
                "solver",
                "t(gap<1e-3) meas",
                "B-upd@1e-3",
                "KNL modeled t",
                "final subopt",
                "epochs",
            ],
        );
        // modeled per-update cost: same for every solver (identical B
        // inner loops), so modeled ratios reduce to update-count ratios
        // plus A+B's swap overhead.
        let t_b = pm.modeled_b_update(&sim, g.d(), 8, 1);
        let mut best_objs: Vec<f64> = Vec::new();
        #[allow(clippy::type_complexity)]
        let mut rows: Vec<(String, Vec<Option<f64>>, Option<u64>, Option<f64>, f64, usize)> =
            Vec::new();
        let mut st_modeled: Option<f64> = None;
        let mut ab_modeled: Option<f64> = None;
        for solver in &solvers {
            let mut model = bench_model(model_name, g.n());
            let mut cfg = bench_cfg(1e-4 * o0, timeout);
            // %B per the paper's tuned settings (Tables II/III): small
            // batches for dense Lasso (2-8%), larger for SVM — greedy
            // selection needs small batches to focus its advantage.
            cfg.batch_frac = if model_name == "lasso" { 0.02 } else { 0.2 };
            if *solver == "ST" {
                // ST's own best-found topology: all threads on updates
                cfg.t_b = 4;
                cfg.v_b = 1;
            }
            let res = run_solver(solver, model.as_mut(), &g, &cfg);
            let times = times_to(&res, o0, &rels);
            let obj = res.trace.best_objective().unwrap_or(f64::NAN);
            best_objs.push(obj);
            // work accounting at the 1e-3 threshold
            let upd_per_epoch = match *solver {
                "ST" | "ST(A+B)" | "PASSCoDe-atomic" | "PASSCoDe-wild" => g.n() as u64,
                _ => cfg.batch_size(g.n()) as u64,
            };
            let epochs_cross = res.trace.epoch_to_gap(1e-3 * o0);
            let b_upd = epochs_cross.map(|e| e as u64 * upd_per_epoch);
            let modeled = b_upd.map(|u| {
                let e = epochs_cross.unwrap() as f64;
                let overhead = match *solver {
                    "A+B" => {
                        // per-epoch working-set swap traffic, fast tier
                        // (task A itself is concurrent on spare cores: free)
                        let bytes = cfg.batch_size(g.n()) as u64 * g.d() as u64 * 4;
                        e * sim.modeled_secs(hthc::memory::Tier::Fast, bytes, 8)
                    }
                    // OMP recomputes ALL n gaps serially each epoch —
                    // unlike A+B's concurrent task A, that phase is on
                    // the critical path and must be charged.
                    "OMP" | "OMP WILD" => {
                        // n updates spread over a 24-thread parallel-for
                        e * g.n() as f64 * pm.modeled_a_update(&sim, g.d(), 24) / 24.0
                    }
                    _ => 0.0,
                };
                u as f64 * t_b + overhead
            });
            if *solver == "ST" {
                st_modeled = modeled;
            }
            if *solver == "A+B" {
                ab_modeled = modeled;
            }
            rows.push((solver.to_string(), times, b_upd, modeled, obj, res.epochs));
        }
        let opt = best_objs.iter().cloned().fold(f64::INFINITY, f64::min);
        for (name, times, b_upd, modeled, obj, epochs) in rows {
            table.row(vec![
                name,
                fmt_opt_secs(times[1]),
                b_upd.map(|u| u.to_string()).unwrap_or_else(|| "--".into()),
                fmt_opt_secs(modeled),
                format!("{:.3e}", obj - opt),
                epochs.to_string(),
            ]);
        }
        table.print();
        if let (Some(st), Some(ab)) = (st_modeled, ab_modeled) {
            println!(
                "modeled KNL speedup A+B over ST at gap<1e-3: {:.1}x  (paper: 5-10x dense lasso, ~1x dense svm, <1x sparse)",
                st / ab
            );
        }
        println!();
    }

    // guard for the OMP-WILD plateau claim: its final suboptimality must
    // exceed OMP-atomic's on at least one dense case (broken v = D alpha).
    let g = bench_dataset(DatasetKind::EpsilonLike, Family::Regression, 7);
    let o0v = obj0(&*bench_model("lasso", g.n()), &g);
    let run = |s: &str| {
        let mut m = bench_model("lasso", g.n());
        let cfg = bench_cfg(1e-5 * o0v, 15.0);
        let r = run_solver(s, m.as_mut(), &g, &cfg);
        // true suboptimality against a consistent v (recomputed)
        let v2 = g.matvec_alpha(&r.alpha);
        let mut fresh = hthc::glm::Lasso::new(0.3);
        use hthc::glm::GlmModel;
        fresh.epoch_refresh(&r.alpha);
        let obj = fresh.objective(&v2, g.targets(), &r.alpha);
        let gap = glm::total_gap(&fresh, g.as_block_ops(), &v2, g.targets(), &r.alpha);
        (obj, gap)
    };
    let (obj_atomic, gap_atomic) = run("OMP");
    let (obj_wild, gap_wild) = run("OMP WILD");
    println!(
        "OMP plateau check: atomic obj {obj_atomic:.6e} (true gap {gap_atomic:.3e}) vs \
         wild obj {obj_wild:.6e} (true gap {gap_wild:.3e})"
    );
    println!(
        "expected: wild's *true* gap stays above atomic's when races occur \
         (single-core hosts may serialize races away)."
    );
}
