//! Fig. 2 — Performance (flops/cycle) of task A's gap updates for
//! varying vector length d and thread count T_A (paper §V-A).
//!
//! The paper's shape: near-linear gains up to ~20 threads, no gain
//! 20-24, decline + fluctuation beyond (DRAM bandwidth saturation).
//! On this 1-core host wall-clock cannot show parallel scaling, so the
//! harness reports BOTH the measured single-host numbers and the
//! TierSim/PerfModel *modeled* curve (labelled), which carries the
//! saturation shape (DESIGN.md §5).

use hthc::coordinator::{task_a, GapMemory, PerfModel};
use hthc::data::{Dataset, DatasetBuilder, DenseMatrix, Matrix};
use hthc::glm::{GlmModel, Lasso};
use hthc::memory::TierSim;
use hthc::metrics::Table;
use hthc::threadpool::WorkerPool;
use hthc::util::timer::{flops_per_cycle, KNL_HZ};
use hthc::util::Timer;

fn dense_cols(d: usize, n: usize, seed: u64) -> Dataset {
    let mut rng = hthc::util::Rng::new(seed);
    let data: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect();
    let matrix = Matrix::Dense(DenseMatrix::from_col_major(d, n, data));
    DatasetBuilder::in_memory(matrix, vec![0.0; d])
        .build()
        .expect("bench dataset")
}

fn main() {
    println!("Fig. 2 reproduction: task A gap-update performance\n");
    // paper: n = 600 coordinates, d = 10k..5M. Measured part is capped
    // by host memory; modeled part covers the paper's full range.
    let n = 600usize;
    let measured_ds = [10_000usize, 20_000, 40_000, 80_000];
    let t_as = [1usize, 2, 4, 8, 12, 16, 20, 24, 34, 68];

    let mut table = Table::new(
        "Fig 2 (measured): flops/cycle of task A vs T_A",
        &["d", "T_A", "updates", "meas flops/cyc", "modeled flops/cyc"],
    );
    let pm = PerfModel::calibrate(
        &[10_000, 100_000, 1_000_000, 5_000_000],
        &t_as,
        &[1],
        &[1],
    );

    for &d in &measured_ds {
        let dataset = dense_cols(d, n, 2);
        let model = Lasso::new(0.1);
        let kind = model.kind();
        let w = vec![0.5f32; d];
        let alpha = vec![0.1f32; n];
        for &t_a in &t_as {
            if t_a > 8 && d > 40_000 {
                continue; // keep wall-clock sane on 1 core; model covers it
            }
            let pool = WorkerPool::with_name(t_a, "fig2-a");
            let gaps = GapMemory::new(n);
            let sim = TierSim::default();
            let snap = task_a::ASnapshot { w: &w, alpha: &alpha, kind, epoch: 1 };
            // fixed work: 3 full sweeps of the 600 coords
            let coords: Vec<usize> = (0..n).cycle().take(3 * n).collect();
            let t = Timer::start();
            task_a::run_fixed(
                &pool, dataset.matrix(), &snap, &gaps, &coords, &sim, dataset.placement(),
            );
            let secs = t.secs();
            let flops = (coords.len() * 2 * d) as f64;
            // modeled: aggregate flops/cycle at T_A threads
            let upd = pm.modeled_a_update(&sim, d, t_a);
            let modeled = (2.0 * d as f64 / upd) * t_a as f64 / KNL_HZ;
            table.row(vec![
                d.to_string(),
                t_a.to_string(),
                coords.len().to_string(),
                format!("{:.3}", flops_per_cycle(flops, secs)),
                format!("{:.3}", modeled),
            ]);
        }
    }
    table.print();

    // modeled-only extension to the paper's big-d range
    let mut mt = Table::new(
        "Fig 2 (modeled, paper range): aggregate flops/cycle",
        &["d", "T_A=1", "4", "8", "16", "20", "24", "34", "68"],
    );
    let sim = TierSim::default();
    for &d in &[10_000usize, 100_000, 1_000_000, 5_000_000] {
        let mut row = vec![d.to_string()];
        for &t_a in &[1usize, 4, 8, 16, 20, 24, 34, 68] {
            let upd = pm.modeled_a_update(&sim, d, t_a);
            row.push(format!("{:.2}", (2.0 * d as f64 / upd) * t_a as f64 / KNL_HZ));
        }
        mt.row(row);
    }
    mt.print();
    println!(
        "\nexpected shape (paper): rises ~linearly to ~20 threads, flat to 24, \
         declines beyond (DRAM saturation).  Check the modeled rows above."
    );
}
