//! Table IV — SVM time-to-accuracy: A+B and ST vs PASSCoDe-atomic and
//! PASSCoDe-wild (paper §V-C).
//!
//! Paper shape: HTHC ~2x faster on epsilon-like, 2.4-5x on dvsc-like;
//! PASSCoDe clearly faster on news20-like sparse (HTHC's chunk locks
//! are wasteful for sparse data — the paper's own finding).

use hthc::bench_support::*;
use hthc::baselines::PasscodeMode;
use hthc::data::generator::{DatasetKind, Family};
use hthc::glm::SvmDual;
use hthc::memory::TierSim;
use hthc::metrics::{report::fmt_opt_secs, Table};
use hthc::solver::{Passcode, Trainer};
use hthc::util::Timer;

/// Train until accuracy target, returning seconds (None on timeout).
fn time_to_accuracy(
    solver: &str,
    g: &hthc::data::Dataset,
    target: f64,
    timeout: f64,
) -> Option<f64> {
    let n = g.n();
    let lam = 1e-3f32;
    let sim = TierSim::default();
    let acc_of = |v: &[f32]| {
        let ops = g.as_ops();
        (0..n).filter(|&j| ops.dot(j, v) > 0.0).count() as f64 / n as f64
    };
    match solver {
        "PASSCoDe-atomic" | "PASSCoDe-wild" => {
            let mode = if solver.ends_with("wild") {
                PasscodeMode::Wild
            } else {
                PasscodeMode::Atomic
            };
            let mut cfg = bench_cfg(0.0, timeout);
            cfg.eval_every = 1;
            let mut model = SvmDual::new(lam, n);
            let mut hit: Option<f64> = None;
            let _ = Trainer::new()
                .solver(Passcode { mode })
                .config(cfg)
                .on_epoch(|ev| {
                    if acc_of(ev.v) >= target {
                        hit = Some(ev.wall_secs);
                        true
                    } else {
                        false
                    }
                })
                .fit_with(&mut model, g, &sim);
            hit
        }
        name => {
            // The generic solvers have no mid-run accuracy hook; probe
            // with geometrically growing (cold-start, same-seed) epoch
            // budgets and report the wall time of the first run that
            // reaches the target — an upper bound within 2x of the true
            // time-to-accuracy.
            let outer = Timer::start();
            let mut budget = 1usize;
            while outer.secs() < timeout {
                let mut cfg = bench_cfg(0.0, timeout - outer.secs());
                cfg.eval_every = usize::MAX >> 1; // skip gap evals: pure speed
                cfg.max_epochs = budget;
                let mut model = SvmDual::new(lam, n);
                let res = run_solver(name, &mut model, g, &cfg);
                if acc_of(&res.v) >= target {
                    return Some(res.wall_secs);
                }
                if res.epochs < budget {
                    break; // hit the timeout inside the run
                }
                budget *= 2;
            }
            None
        }
    }
}

fn main() {
    println!("Table IV reproduction: SVM time-to-accuracy\n");
    let cases = [
        (DatasetKind::EpsilonLike, 0.85, "85%"),
        (DatasetKind::DvscLike, 0.95, "95%"),
        (DatasetKind::News20Like, 0.99, "99%"),
    ];
    let timeout = 20.0;
    let mut table = Table::new(
        "Table IV: SVM time to accuracy",
        &["dataset", "accuracy", "A+B", "ST", "PASSCoDe-atomic", "PASSCoDe-wild"],
    );
    for (kind, target, label) in cases {
        let g = bench_dataset(kind, Family::Classification, 4000 + kind as u64);
        let mut row = vec![g.meta().source.describe(), label.to_string()];
        for solver in ["A+B", "ST", "PASSCoDe-atomic", "PASSCoDe-wild"] {
            let t = time_to_accuracy(solver, &g, target, timeout);
            row.push(fmt_opt_secs(t));
        }
        table.row(row);
    }
    table.print();
    println!(
        "\nexpected shape (paper Table IV): A+B fastest on the dense sets; \
         PASSCoDe fastest on news20-like sparse (locking overhead)."
    );
}
