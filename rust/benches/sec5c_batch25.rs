//! §V-C (GPU-system comparison) — HTHC with the batch size forced to
//! 25% (the largest that fit the GPU RAM of the reference
//! heterogeneous system, Duenner et al. [10]) versus HTHC at its best
//! batch size.
//!
//! Paper numbers: DvsC Lasso 29 s @25% -> 20 s @best; SVM 84 s -> 41 s.
//! Shape to reproduce: the forced-25% configuration is substantially
//! slower than the tuned one — the advantage HTHC's *standalone*
//! adaptivity has over an accelerator-bound split.

use hthc::bench_support::*;
use hthc::data::generator::{DatasetKind, Family};
use hthc::metrics::{report::fmt_opt_secs, Table};

fn main() {
    println!("§V-C reproduction: forced 25% batch vs tuned batch (dvsc-like)\n");
    let timeout = 25.0;
    let mut table = Table::new(
        "HTHC batch-size adaptivity (dvsc-like)",
        &["model", "%B", "t(converge)", "epochs", "refresh/epoch"],
    );
    for model_name in ["lasso", "svm"] {
        let family = if model_name == "svm" {
            Family::Classification
        } else {
            Family::Regression
        };
        let g = bench_dataset(DatasetKind::DvscLike, family, 9000);
        let probe = bench_model(model_name, g.n());
        let o0 = obj0(probe.as_ref(), &g);
        let target = 1e-3 * o0;

        // tuned: small search over batch fracs
        let mut best: Option<(f64, f64, usize, f64)> = None;
        for &frac in &[0.02f64, 0.05, 0.10, 0.25] {
            let mut cfg = bench_cfg(target, timeout);
            cfg.batch_frac = frac;
            let mut model = bench_model(model_name, g.n());
            let res = run_solver("A+B", model.as_mut(), &g, &cfg);
            if let Some(t) = res.trace.time_to_gap(target) {
                if best.map_or(true, |b| t < b.0) {
                    best = Some((t, frac, res.epochs, res.refresh_frac()));
                }
            }
            if (frac - 0.25).abs() < 1e-12 {
                table.row(vec![
                    model_name.into(),
                    "25% (forced, GPU-RAM analogue)".into(),
                    fmt_opt_secs(res.trace.time_to_gap(target)),
                    res.epochs.to_string(),
                    format!("{:.0}%", res.refresh_frac() * 100.0),
                ]);
            }
        }
        match best {
            Some((t, frac, epochs, refresh)) => table.row(vec![
                model_name.into(),
                format!("{:.0}% (best found)", frac * 100.0),
                fmt_opt_secs(Some(t)),
                epochs.to_string(),
                format!("{:.0}%", refresh * 100.0),
            ]),
            None => table.row(vec![
                model_name.into(),
                "best (none converged)".into(),
                "--".into(),
                "--".into(),
                "--".into(),
            ]),
        };
    }
    table.print();
    println!(
        "\nexpected shape (paper §V-C): tuned %B converges substantially \
         faster than the forced 25% (paper: 29->20 s Lasso, 84->41 s SVM)."
    );
}
