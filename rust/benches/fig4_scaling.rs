//! Fig. 4 — Speedup of task B with T_B parallel updates over T_B = 1
//! (best V_B per point), paper §V-A.
//!
//! Paper shape: strongly sublinear scaling (synchronization-bound; L2
//! bandwidth per tile is the bottleneck, MCDRAM stays unsaturated).
//! Modeled speedups carry the multi-core shape; measured rows document
//! the host baseline.

use hthc::coordinator::PerfModel;
use hthc::memory::TierSim;
use hthc::metrics::Table;

fn main() {
    println!("Fig. 4 reproduction: task B scaling over T_B\n");
    let t_bs = [1usize, 2, 4, 8, 16, 32, 56, 68];
    let v_bs = [1usize, 2, 4, 8];
    let pm = PerfModel::calibrate(&[10_000, 130_000, 1_000_000], &[1], &t_bs, &v_bs);
    let sim = TierSim::default();

    let mut table = Table::new(
        "Fig 4 (modeled): speedup of B over T_B=1 (best V_B each)",
        &["d", "T_B=2", "4", "8", "16", "32", "56", "68"],
    );
    for &d in &[10_000usize, 130_000, 1_000_000] {
        // epoch throughput scales with T_B (updates run concurrently);
        // per-update time may also degrade slightly with contention.
        let thr = |t_b: usize| -> f64 {
            let best = v_bs
                .iter()
                .map(|&vb| pm.modeled_b_update(&sim, d, t_b, vb))
                .fold(f64::INFINITY, f64::min);
            t_b as f64 / best
        };
        let base = thr(1);
        let mut row = vec![d.to_string()];
        for &t_b in &t_bs[1..] {
            row.push(format!("{:.2}x", thr(t_b) / base));
        }
        table.row(row);
    }
    table.print();
    println!(
        "\nexpected shape (paper): sublinear — e.g. ~10x at T_B=16 is NOT \
         reached; sync points dominate.  Our model shows contention-limited \
         growth; the raw update speed is unaffected by staleness (paper \
         §V-A), which the convergence benches (fig5) capture separately."
    );
}
