//! Table V — Lasso time-to-squared-error: A+B and ST vs a Vowpal-Wabbit
//! style SGD (paper §V-C).
//!
//! Paper shape: HTHC wins clearly on the dense sets (epsilon: 0.56 s vs
//! VW's 12.19 s; dvsc: 5.91 vs 47.29) and *loses* on news20-like sparse
//! (VW 0.02 s) — CD + chunk locks are overkill for tiny sparse columns.

use hthc::baselines::sgd::RowCache;
use hthc::bench_support::*;
use hthc::data::generator::{DatasetKind, Family};
use hthc::metrics::{report::fmt_opt_secs, Table};
use hthc::solver::{Sgd, Trainer};

fn main() {
    println!("Table V reproduction: Lasso time-to-squared-error vs SGD\n");
    let timeout = 20.0;
    let mut table = Table::new(
        "Table V: time to reach the target mean squared error",
        &["dataset", "target MSE", "A+B", "ST", "SGD (VW-style)"],
    );

    for kind in [
        DatasetKind::EpsilonLike,
        DatasetKind::DvscLike,
        DatasetKind::News20Like,
    ] {
        let g = bench_dataset(kind, Family::Regression, 5000 + kind as u64);
        let cache = RowCache::build(g.matrix());
        // target: the MSE a converged lasso reaches, padded 10% — every
        // solver can achieve it, the question is how fast.
        let target = {
            let mut model = bench_model("lasso", g.n());
            let o0 = obj0(model.as_ref(), &g);
            let cfg = bench_cfg(1e-4 * o0, timeout);
            let res = run_solver("A+B", model.as_mut(), &g, &cfg);
            let preds = cache.predictions(&res.alpha);
            hthc::serve::predict::mean_squared_error(&preds, g.targets()) * 1.1 + 1e-6
        };

        let mut row = vec![g.meta().source.describe(), format!("{target:.4}")];
        // A+B and ST: time until their iterate's MSE crosses the target,
        // probed by geometric restarts (same protocol as Table IV).
        for solver in ["A+B", "ST"] {
            let mut budget = 1usize;
            let mut hit = None;
            let outer = hthc::util::Timer::start();
            while outer.secs() < timeout {
                let mut model = bench_model("lasso", g.n());
                let mut cfg = bench_cfg(0.0, timeout - outer.secs());
                cfg.eval_every = usize::MAX >> 1;
                cfg.max_epochs = budget;
                let res = run_solver(solver, model.as_mut(), &g, &cfg);
                let preds = cache.predictions(&res.alpha);
                if hthc::serve::predict::mean_squared_error(&preds, g.targets()) <= target {
                    hit = Some(res.wall_secs);
                    break;
                }
                if res.epochs < budget {
                    break;
                }
                budget *= 2;
            }
            row.push(fmt_opt_secs(hit));
        }
        // SGD trains on rows directly, tracking MSE per epoch (the
        // engine honours eval_every, so force the per-epoch cadence the
        // time-to-MSE comparison needs).
        let mut cfg = bench_cfg(0.0, timeout);
        cfg.eval_every = 1;
        let mut model = bench_model("lasso", g.n()); // ignored by Sgd
        let res = Trainer::new()
            .solver(Sgd { lam: 1e-4, mse_target: target })
            .config(cfg)
            .fit_with(model.as_mut(), &g, &hthc::memory::TierSim::default());
        let sgd_time = res
            .trace
            .points
            .iter()
            .find(|p| p.objective <= target)
            .map(|p| p.secs);
        row.push(fmt_opt_secs(sgd_time));
        table.row(row);
    }
    table.print();
    println!(
        "\nexpected shape (paper Table V): CD solvers (A+B, ST) beat SGD on \
         dense data; SGD wins on news20-like sparse."
    );
}
