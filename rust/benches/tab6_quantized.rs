//! Table VI — 32-bit vs mixed 32/4-bit representation: time to a fixed
//! duality gap for Lasso and SVM on the dense sets (paper §V-E).
//!
//! Paper shape: quantization wins where data movement dominates (Lasso:
//! 1.6 s -> 1.0 s on epsilon; 55.5 -> 32.4 on dvsc) and loses slightly
//! where unpack ALU hurts a compute-bound loop (SVM: 5.5 -> 5.8;
//! 38.2 -> 51.6).  We report time-to-gap, the bytes moved per sweep
//! (the mechanism), and the achieved-gap parity.

use hthc::bench_support::*;
use hthc::data::generator::{DatasetKind, Family};
use hthc::data::{Dataset, DatasetBuilder, Matrix, QuantizedMatrix};
use hthc::metrics::{report::fmt_opt_secs, Table};

fn main() {
    println!("Table VI reproduction: 32-bit vs 32/4-bit\n");
    let timeout = 20.0;
    let mut table = Table::new(
        "Table VI: time to target gap, fp32 vs quantized D",
        &["dataset", "model", "target", "32-bit", "32/4-bit", "bytes/sweep 32b", "bytes/sweep 4b"],
    );

    for kind in [DatasetKind::EpsilonLike, DatasetKind::DvscLike] {
        for model_name in ["lasso", "svm"] {
            let family = if model_name == "svm" {
                Family::Classification
            } else {
                Family::Regression
            };
            let g = bench_dataset(kind, family, 6000 + kind as u64);
            // same data, 4-bit representation (through the one builder
            // pipeline, in-memory source)
            let q = match g.matrix() {
                Matrix::Dense(dm) => DatasetBuilder::in_memory(
                    Matrix::Quantized(QuantizedMatrix::from_dense(dm)),
                    g.targets().to_vec(),
                )
                .build()
                .expect("quantized dataset"),
                _ => unreachable!("dense kinds only"),
            };
            let probe = bench_model(model_name, g.n());
            let o0 = obj0(probe.as_ref(), &g);
            // quantization noise floors the gap; pick a target both
            // representations can reach (paper uses 1e-3..1e-5 per case)
            let target = 2e-3 * o0;

            let run = |ds: &Dataset| -> Option<f64> {
                let mut model = bench_model(model_name, g.n());
                let cfg = bench_cfg(target, timeout);
                let res = run_solver("A+B", model.as_mut(), ds, &cfg);
                res.trace.time_to_gap(target)
            };
            let t32 = run(&g);
            let t4 = run(&q);
            table.row(vec![
                g.meta().source.describe(),
                model_name.into(),
                format!("{target:.2e}"),
                fmt_opt_secs(t32),
                fmt_opt_secs(t4),
                hthc::util::fmt_bytes(g.meta().bytes),
                hthc::util::fmt_bytes(q.meta().bytes),
            ]);
        }
    }
    table.print();
    println!(
        "\nexpected shape (paper Table VI): comparable times (quantized wins \
         when bandwidth-bound — Lasso dense — at ~7x fewer bytes for D; may \
         lose when unpack ALU dominates, e.g. SVM).  On this host the dot is \
         compute-bound, so parity with a large byte reduction is the \
         expected outcome; on KNL the byte reduction converts to speedup."
    );
}
