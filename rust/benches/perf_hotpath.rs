//! §Perf — hot-path microbenchmarks for the optimization pass.
//!
//! Profiles every inner loop the end-to-end runs spend time in:
//! dense/sparse/quantized dots, locked axpy across lock granularities,
//! top-m selection, barrier crossings, PJRT gap-batch latency vs the
//! native loop.  Before/after numbers from this harness are recorded in
//! EXPERIMENTS.md §Perf.

use hthc::bench_support::{BenchJson, ConvergenceRecord, ServeRecord};
use hthc::coordinator::{selection, SharedVector};
use hthc::data::{ColumnOps, DenseMatrix, QuantizedMatrix, SparseMatrix};
use hthc::kernels::{self, Backend, QGROUP};
use hthc::metrics::Table;
use hthc::threadpool::SpinBarrier;
use hthc::util::timer::{bench_median, KNL_HZ};
use hthc::util::{Rng, Timer};

/// Per-kernel scalar-vs-dispatched microbenchmarks.  Records results
/// into the bench JSON (`target/bench-json/perf_hotpath.json`) so CI
/// and EXPERIMENTS.md have machine-readable throughput + speedups.
fn bench_kernel_matrix(rng: &mut Rng, json: &mut BenchJson) {
    let dispatched = kernels::backend();
    println!(
        "kernel dispatch: {} (override with RUST_PALLAS_KERNELS=scalar|simd|portable|avx2)\n",
        dispatched.name()
    );
    if !kernels::avx2_available() {
        json.note(
            "host lacks AVX2+FMA: dispatched backend is the portable auto-vectorized \
             path, so the dense-dot >= 1.5x speedup target is waived on this machine",
        );
    }
    if dispatched == Backend::Scalar {
        json.note(
            "RUST_PALLAS_KERNELS=scalar: dispatched == scalar baseline, speedups are ~1.0 \
             by construction (A/B control run)",
        );
    }

    let mut t = Table::new(
        "kernels: scalar vs dispatched throughput",
        &["kernel", "scalar GB/s", "dispatched GB/s", "speedup"],
    );
    let mut push = |json: &mut BenchJson, name: &str, bytes: f64, scalar: f64, disp: f64| {
        json.record(name, bytes, scalar, disp);
        let r = json.records().last().unwrap();
        t.row(vec![
            name.to_string(),
            format!("{:.2}", r.scalar_gbs()),
            format!("{:.2}", r.dispatched_gbs()),
            format!("{:.2}x", r.speedup()),
        ]);
    };

    // dense kernels at d = 100k (L2-resident streams)
    let d = 100_000usize;
    let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    {
        let mut acc = 0.0f32;
        let (scal, _) =
            bench_median(|| acc += kernels::dot_with(Backend::Scalar, &a, &b), 0.1, 5_000);
        let (disp, _) = bench_median(|| acc += kernels::dot(&a, &b), 0.1, 5_000);
        std::hint::black_box(acc);
        push(json, "dense_dot", (d * 8) as f64, scal, disp);
    }
    {
        let mut v = b.clone();
        let (scal, _) =
            bench_median(|| kernels::axpy_with(Backend::Scalar, 1e-7, &a, &mut v), 0.1, 5_000);
        let (disp, _) = bench_median(|| kernels::axpy(1e-7, &a, &mut v), 0.1, 5_000);
        std::hint::black_box(v[0]);
        push(json, "dense_axpy", (d * 12) as f64, scal, disp);
    }
    {
        let mut acc = 0.0f32;
        let (scal, _) =
            bench_median(|| acc += kernels::sq_norm_with(Backend::Scalar, &a), 0.1, 5_000);
        let (disp, _) = bench_median(|| acc += kernels::sq_norm(&a), 0.1, 5_000);
        std::hint::black_box(acc);
        push(json, "dense_sq_norm", (d * 4) as f64, scal, disp);
    }
    {
        let mut acc = (0.0f32, 0.0f32);
        let (scal, _) = bench_median(
            || {
                let (x, y) = kernels::dot_sq_norm_with(Backend::Scalar, &a, &b);
                acc.0 += x;
                acc.1 += y;
            },
            0.1,
            5_000,
        );
        let (disp, _) = bench_median(
            || {
                let (x, y) = kernels::dot_sq_norm(&a, &b);
                acc.0 += x;
                acc.1 += y;
            },
            0.1,
            5_000,
        );
        std::hint::black_box(acc);
        push(json, "dense_dot_sq_norm", (d * 8) as f64, scal, disp);
    }

    // sparse kernels: 2k nnz gathered over a 100k-row vector
    {
        let nnz = 2_000usize;
        let mut rows: Vec<u32> =
            rng.sample_distinct(d, nnz).into_iter().map(|r| r as u32).collect();
        rows.sort_unstable();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut acc = 0.0f32;
        let (scal, _) = bench_median(
            || acc += kernels::sparse_dot_with(Backend::Scalar, &rows, &vals, &w),
            0.1,
            20_000,
        );
        let (disp, _) = bench_median(|| acc += kernels::sparse_dot(&rows, &vals, &w), 0.1, 20_000);
        std::hint::black_box(acc);
        push(json, "sparse_dot", (nnz * 12) as f64, scal, disp);

        let mut v = w.clone();
        let (scal, _) = bench_median(
            || kernels::sparse_axpy_with(Backend::Scalar, &rows, &vals, 1e-7, &mut v),
            0.1,
            20_000,
        );
        let (disp, _) =
            bench_median(|| kernels::sparse_axpy(&rows, &vals, 1e-7, &mut v), 0.1, 20_000);
        std::hint::black_box(v[0]);
        push(json, "sparse_axpy", (nnz * 12) as f64, scal, disp);
    }

    // quantized kernels: one 64k-row column (65_536/QGROUP = 1024 scale groups)
    {
        let dq = 65_536usize;
        let data: Vec<f32> = (0..dq).map(|_| rng.normal()).collect();
        let dm = DenseMatrix::from_col_major(dq, 1, data);
        let qm = QuantizedMatrix::from_dense(&dm);
        let (packed, scales) = qm.col_packed(0);
        let w: Vec<f32> = (0..dq).map(|_| rng.normal()).collect();
        let bytes = (dq / 2 + (dq / QGROUP) * 4 + dq * 4) as f64; // packed + scales + w
        let mut acc = 0.0f32;
        let (scal, _) = bench_median(
            || acc += kernels::quant_dot_range_with(Backend::Scalar, packed, scales, &w, 0, dq),
            0.1,
            10_000,
        );
        let (disp, _) = bench_median(
            || acc += kernels::quant_dot_range(packed, scales, &w, 0, dq),
            0.1,
            10_000,
        );
        std::hint::black_box(acc);
        push(json, "quant_dot", bytes, scal, disp);

        let mut v = w.clone();
        let (scal, _) = bench_median(
            || kernels::quant_axpy_with(Backend::Scalar, packed, scales, 1e-7, &mut v),
            0.1,
            10_000,
        );
        let (disp, _) =
            bench_median(|| kernels::quant_axpy(packed, scales, 1e-7, &mut v), 0.1, 10_000);
        std::hint::black_box(v[0]);
        push(json, "quant_axpy", bytes + (dq * 4) as f64, scal, disp);
    }

    t.print();
    bench_blocked_sweep(rng, json);
}

/// Blocked multi-column sweep vs the per-column dot path (the §IV-A/IV-D
/// tentpole): u = Dᵀ_block · w over a w too large for L1, so the blocked
/// path's w-reuse across BLOCK_COLS columns shows up as throughput.
/// Recorded into the bench JSON with "scalar" = per-column dispatched
/// dots and "dispatched" = the blocked sweep, so `speedup` reads as
/// blocked-vs-per-column.
fn bench_blocked_sweep(rng: &mut Rng, json: &mut BenchJson) {
    use hthc::data::BlockOps;
    let d = 400_000usize; // 1.6 MB of w per pass: beyond typical L2
    let nc = 2 * hthc::kernels::BLOCK_COLS;
    let dm = DenseMatrix::from_col_major(d, nc, (0..d * nc).map(|_| rng.normal()).collect());
    let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let cols: Vec<usize> = (0..nc).collect();
    let mut u = vec![0.0f32; nc];

    let (per_col, _) = bench_median(
        || {
            let mut acc = 0.0f32;
            for j in 0..nc {
                acc += dm.dot(j, &w);
            }
            std::hint::black_box(acc);
        },
        0.2,
        2_000,
    );
    let (blocked, _) = bench_median(
        || {
            dm.dots_block(&cols, &w, &mut u);
            std::hint::black_box(u[0]);
        },
        0.2,
        2_000,
    );
    // bytes actually streamed by the blocked pass: the nc column blocks
    // plus one pass over w (the per-column path re-streams w nc times)
    let bytes = ((nc * d + d) * 4) as f64;
    json.note(
        "dense_dots_block: 'scalar' column is the per-column dispatched dot sweep, \
         'dispatched' is the blocked multi-column sweep — speedup = blocked vs per-column",
    );
    json.record("dense_dots_block", bytes, per_col, blocked);
    let speedup = json.records().last().unwrap().speedup();
    if speedup < 1.0 {
        if kernels::avx2_available() {
            json.note(&format!(
                "dense_dots_block blocked sweep ran {speedup:.2}x of the per-column path \
                 on this host — below the >= 1.0x target"
            ));
        } else {
            json.note(
                "host lacks AVX2+FMA: the blocked >= per-column throughput target is \
                 waived (portable auto-vectorized path; w-reuse still reduces traffic)",
            );
        }
    }
    let mut t = Table::new(
        "blocked multi-column sweep (u = D_blockᵀ w, d = 400k, 16 cols)",
        &["path", "GB/s", "speedup"],
    );
    let r = json.records().last().unwrap();
    t.row(vec!["per-column dots".into(), format!("{:.2}", r.scalar_gbs()), "1.00x".into()]);
    t.row(vec![
        "blocked dots_block".into(),
        format!("{:.2}", r.dispatched_gbs()),
        format!("{:.2}x", r.speedup()),
    ]);
    t.print();
    bench_scheduled_sweep(rng, json);
}

/// Latency benchmark axis (ISSUE 7): a short bounded serving run —
/// batched predict through the kernel layer, streaming ingest, the
/// warm-start refit cadence — recorded as the `serve` section of the
/// bench JSON (QPS, rows/s, p50/p95/p99 request latency, publish and
/// reject counters).
fn bench_serve_axis(json: &mut BenchJson) {
    use hthc::data::{DatasetBuilder, DatasetKind, Family};
    use hthc::serve::{RefitConfig, ServeConfig};
    use hthc::solver::StopWhen;

    let base = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .scale(2.0)
        .seed(7007)
        .build()
        .expect("serve bench dataset")
        .to_samples()
        .expect("serve bench samples");
    let cfg = ServeConfig {
        duration_secs: 0.8 * hthc::bench_support::bench_scale().min(2.0),
        batch: 64,
        threads: 2,
        ingest_per_round: 8,
        refit: RefitConfig {
            refit_every: 64,
            solver: "st".into(),
            budget: StopWhen::gap_below(1e-6).max_epochs(200).timeout_secs(5.0),
            ..Default::default()
        },
        ..Default::default()
    };
    match hthc::serve::sim::run(base, &cfg) {
        Ok(r) => {
            json.set_serve(ServeRecord {
                qps: r.qps,
                rows_per_sec: r.rows_per_sec,
                p50_ms: r.p50_ms,
                p95_ms: r.p95_ms,
                p99_ms: r.p99_ms,
                published: r.published,
                rejected: r.rejected,
                attempts: r.attempts,
                ingest_dropped: r.ingest_dropped,
                corpus_evicted: r.corpus_evicted,
                corpus_peak: r.corpus_peak,
            });
            let mut t = Table::new(
                "serving axis (bounded in-process run, batch = 64)",
                &["metric", "value"],
            );
            t.row(vec!["req/s".into(), format!("{:.0}", r.qps)]);
            t.row(vec!["rows/s".into(), format!("{:.0}", r.rows_per_sec)]);
            t.row(vec!["p50 / p95 / p99 ms".into(),
                format!("{:.3} / {:.3} / {:.3}", r.p50_ms, r.p95_ms, r.p99_ms)]);
            t.row(vec!["refits pub/rej".into(),
                format!("{} / {}", r.published, r.rejected)]);
            t.row(vec!["dropped / evicted / peak".into(),
                format!("{} / {} / {}", r.ingest_dropped, r.corpus_evicted, r.corpus_peak)]);
            t.print();
            if !r.healthy() {
                json.note(&format!(
                    "serve axis unhealthy: {} published, {} rows served",
                    r.published, r.rows
                ));
            }
        }
        Err(e) => json.note(&format!("serve axis skipped: {e}")),
    }
}

/// Convergence-speed benchmark axis (ISSUE 10): epochs (cluster:
/// rounds) to a fixed relative duality-gap certificate per engine, on
/// the same seeded tiny Lasso problem.  Epoch counts are seed-
/// deterministic properties of the algorithm — unlike wall seconds —
/// so `tools/bench_compare.py` gates on them across snapshots.
fn bench_convergence_axis(json: &mut BenchJson) {
    use hthc::bench_support::{bench_cfg, bench_dataset, bench_model, obj0, run_solver};
    use hthc::cluster::{run_cluster, ClusterConfig};
    use hthc::data::{DatasetKind, Family};

    let g = bench_dataset(DatasetKind::Tiny, Family::Regression, 4242);
    let target = 1e-3 * obj0(bench_model("lasso", g.n()).as_ref(), &g);
    let mut t = Table::new(
        "convergence axis (tiny lasso, gap <= 1e-3 * obj0)",
        &["engine", "epochs to target", "epochs run", "final gap"],
    );
    let mut push = |json: &mut BenchJson, engine: &str, rec: ConvergenceRecord| {
        t.row(vec![
            engine.to_string(),
            rec.epochs_to_target.map_or("-".into(), |e| e.to_string()),
            rec.epochs_run.to_string(),
            format!("{:.3e}", rec.final_gap),
        ]);
        json.add_convergence(rec);
    };

    for engine in ["ST", "A+B"] {
        let mut m = bench_model("lasso", g.n());
        let mut cfg = bench_cfg(target, 60.0);
        cfg.eval_every = 1;
        cfg.max_epochs = 500;
        let r = run_solver(engine, m.as_mut(), &g, &cfg);
        push(
            json,
            engine,
            ConvergenceRecord {
                engine: engine.to_string(),
                dataset: "tiny-lasso".into(),
                gap_target: target,
                epochs_to_target: r.trace.epoch_to_gap(target).map(|e| e as u64),
                final_gap: r.final_gap().unwrap_or(f64::NAN),
                epochs_run: r.epochs as u64,
            },
        );
    }
    for k in [2usize, 4] {
        let engine = format!("cluster-k{k}");
        let cfg = ClusterConfig {
            nodes: k,
            gap_tol: target,
            max_rounds: 500,
            ..Default::default()
        };
        match run_cluster(&g, &|| bench_model("lasso", g.n()), &cfg) {
            Ok(rep) => push(
                json,
                &engine,
                ConvergenceRecord {
                    engine: engine.clone(),
                    dataset: "tiny-lasso".into(),
                    gap_target: target,
                    epochs_to_target: rep.fit.trace.epoch_to_gap(target).map(|e| e as u64),
                    final_gap: rep.fit.final_gap().unwrap_or(f64::NAN),
                    epochs_run: rep.fit.epochs as u64,
                },
            ),
            Err(e) => json.note(&format!("convergence axis: {engine} skipped: {e}")),
        }
    }
    t.print();
}

/// Serial-vs-scheduled sweep under a fixed wall-clock budget: a
/// single-thread per-column dot sweep against the shard-pinned
/// [`TileScheduler`] driving a [`WorkerPool`] with blocked tile dots —
/// the task-A refresh loop as `run_epoch` actually runs it.  Recorded
/// with "scalar" = serial secs/refresh and "dispatched" = scheduled
/// secs/refresh, so `speedup` reads as refreshes-per-budget ratio
/// (the PR-6 acceptance gate: strictly above 1.0).
///
/// [`TileScheduler`]: hthc::sched::TileScheduler
/// [`WorkerPool`]: hthc::threadpool::WorkerPool
fn bench_scheduled_sweep(rng: &mut Rng, json: &mut BenchJson) {
    use hthc::data::BlockOps;
    use hthc::sched::TileScheduler;
    use hthc::threadpool::WorkerPool;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    const B: usize = hthc::kernels::BLOCK_COLS;
    let d = 30_000usize;
    let n = 512usize;
    let dm = DenseMatrix::from_col_major(d, n, (0..d * n).map(|_| rng.normal()).collect());
    let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let budget_secs = 0.15f64;

    // serial reference: one thread, per-column dispatched dots (w is
    // re-streamed for every column — exactly what the scheduler's
    // blocked tiles avoid)
    let serial = {
        let mut count = 0u64;
        let mut acc = 0.0f32;
        let timer = Timer::start();
        'outer: loop {
            for j in 0..n {
                acc += dm.dot(j, &w);
                count += 1;
                if count % 128 == 0 && timer.secs() > budget_secs {
                    break 'outer;
                }
            }
        }
        std::hint::black_box(acc);
        count
    };

    // scheduled: pool workers claim cyclic tiles from their own shard
    // and sweep each tile in one blocked pass over w
    let workers = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1)
        .clamp(2, 4);
    let pool = WorkerPool::with_name(workers, "bench-sched");
    let sched = TileScheduler::new(n, workers, B);
    let stop = AtomicBool::new(false);
    let total = AtomicU64::new(0);
    std::thread::scope(|s| {
        s.spawn(|| {
            std::thread::sleep(std::time::Duration::from_secs_f64(budget_secs));
            stop.store(true, Ordering::Relaxed);
        });
        pool.run(|tid| {
            let mut idx = [0usize; B];
            let mut u = [0.0f32; B];
            let mut local = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let Some(tile) = sched.claim_cyclic(tid) else { break };
                let m = tile.len();
                for (slot, j) in idx[..m].iter_mut().zip(tile.lo..tile.hi) {
                    *slot = j;
                }
                dm.dots_block(&idx[..m], &w, &mut u[..m]);
                std::hint::black_box(u[0]);
                local += m as u64;
            }
            total.fetch_add(local, Ordering::Relaxed);
        });
    });
    let scheduled = total.load(Ordering::Relaxed);

    json.note(&format!(
        "scheduled_sweep: 'scalar' = serial per-column sweep ({serial} refreshes in \
         {budget_secs}s), 'dispatched' = {workers}-worker TileScheduler tile sweep \
         ({scheduled} refreshes) — speedup = refreshes-per-budget ratio, must be > 1.0"
    ));
    json.record(
        "scheduled_sweep",
        (d * 4) as f64,
        budget_secs / serial.max(1) as f64,
        budget_secs / scheduled.max(1) as f64,
    );
    let mut t = Table::new(
        "serial vs scheduled sweep (fixed 0.15s budget, d = 30k, n = 512)",
        &["path", "refreshes", "eff. GB/s", "ratio"],
    );
    let r = json.records().last().unwrap();
    t.row(vec![
        "serial per-column".into(),
        serial.to_string(),
        format!("{:.2}", r.scalar_gbs()),
        "1.00x".into(),
    ]);
    t.row(vec![
        format!("scheduled x{workers}"),
        scheduled.to_string(),
        format!("{:.2}", r.dispatched_gbs()),
        format!("{:.2}x", r.speedup()),
    ]);
    t.print();
}

fn main() {
    println!("§Perf hot-path microbenchmarks\n");
    let mut rng = Rng::new(424242);

    // ---- kernel layer: scalar vs dispatched -----------------------------
    let mut json = BenchJson::new("perf_hotpath");
    bench_kernel_matrix(&mut rng, &mut json);
    let dense_speedup = json
        .records()
        .iter()
        .find(|r| r.kernel == "dense_dot")
        .map(|r| r.speedup());
    if let Some(s) = dense_speedup {
        if s < 1.5 && kernels::backend() != Backend::Scalar {
            json.note(&format!(
                "dense_dot dispatched speedup {s:.2}x is below the 1.5x target on this host"
            ));
        }
    }
    // ---- serving layer: latency axis ------------------------------------
    bench_serve_axis(&mut json);
    // ---- solver layer: convergence-speed axis ----------------------------
    bench_convergence_axis(&mut json);
    match json.save() {
        Ok(path) => println!("bench JSON -> {}\n", path.display()),
        Err(e) => println!("(bench JSON not written: {e})\n"),
    }

    // ---- dense dot -----------------------------------------------------
    let mut t = Table::new(
        "dense dot (task A/B inner product, dispatched kernel)",
        &["d", "GB/s", "flops/cycle@1.5GHz", "ns/call"],
    );
    for &d in &[1_000usize, 10_000, 100_000, 1_000_000] {
        let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut acc = 0.0f32;
        let (med, _) = bench_median(|| acc += kernels::dot(&a, &b), 0.15, 10_000);
        std::hint::black_box(acc);
        t.row(vec![
            d.to_string(),
            format!("{:.2}", (d * 8) as f64 / med / 1e9),
            format!("{:.2}", 2.0 * d as f64 / (med * KNL_HZ)),
            format!("{:.0}", med * 1e9),
        ]);
    }
    t.print();

    // ---- fused stale dot (task B's actual read path) --------------------
    let mut t = Table::new(
        "fused dot_mapped_range over SharedVector (atomic reads)",
        &["d", "GB/s", "vs plain dot", "ns/call"],
    );
    for &d in &[10_000usize, 100_000] {
        let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let plain: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let v = SharedVector::from_slice(&plain, 1024);
        let mut acc = 0.0f32;
        let (med_fused, _) = bench_median(
            || acc += v.dot_mapped_range(&col, &y, |vj, yj| vj - yj, 0, d),
            0.15,
            10_000,
        );
        let mut acc2 = 0.0f32;
        let (med_plain, _) = bench_median(|| acc2 += kernels::dot(&col, &plain), 0.1, 10_000);
        std::hint::black_box((acc, acc2));
        t.row(vec![
            d.to_string(),
            format!("{:.2}", (d * 12) as f64 / med_fused / 1e9),
            format!("{:.2}x slower", med_fused / med_plain),
            format!("{:.0}", med_fused * 1e9),
        ]);
    }
    t.print();

    // ---- locked axpy across lock granularities --------------------------
    let mut t = Table::new(
        "axpy_dense_locked (single thread): lock-chunk sweep, d = 100k",
        &["lock chunk", "GB/s", "ns/call", "locks taken"],
    );
    let d = 100_000;
    let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    for &chunk in &[64usize, 256, 1024, 4096, 16384] {
        let v = SharedVector::new(d, chunk);
        let (med, _) = bench_median(|| v.axpy_dense_locked(&col, 1e-6, 0, d), 0.15, 5_000);
        t.row(vec![
            chunk.to_string(),
            format!("{:.2}", (d * 12) as f64 / med / 1e9),
            format!("{:.0}", med * 1e9),
            d.div_ceil(chunk).to_string(),
        ]);
    }
    t.print();

    // ---- sparse + quantized dots ----------------------------------------
    let mut t = Table::new("sparse & quantized column dots", &["repr", "nnz/d", "ns/col", "GB/s"]);
    {
        let d = 100_000;
        let nnz = 2_000;
        let idx = rng.sample_distinct(d, nnz);
        let cols = vec![idx.iter().map(|&r| (r as u32, rng.normal())).collect::<Vec<_>>()];
        let sm = SparseMatrix::from_columns(d, cols);
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut acc = 0.0f32;
        let (med, _) = bench_median(|| acc += sm.dot(0, &w), 0.1, 20_000);
        t.row(vec![
            "sparse CSC".into(),
            format!("{nnz}/{d}"),
            format!("{:.0}", med * 1e9),
            format!("{:.2}", (nnz * 8) as f64 / med / 1e9),
        ]);

        let dq = 65_536;
        let data: Vec<f32> = (0..dq).map(|_| rng.normal()).collect();
        let dm = DenseMatrix::from_col_major(dq, 1, data);
        let qm = QuantizedMatrix::from_dense(&dm);
        let wq: Vec<f32> = (0..dq).map(|_| rng.normal()).collect();
        let mut acc2 = 0.0f32;
        let (medq, _) = bench_median(|| acc2 += qm.dot(0, &wq), 0.1, 20_000);
        let mut acc3 = 0.0f32;
        let (medd, _) = bench_median(|| acc3 += dm.dot(0, &wq), 0.1, 20_000);
        std::hint::black_box((acc, acc2, acc3));
        t.row(vec![
            "quantized 4-bit".into(),
            format!("{dq}/{dq}"),
            format!("{:.0}", medq * 1e9),
            format!("{:.2} ({}x fewer bytes, {:.2}x time vs fp32)",
                qm.col_bytes(0) as f64 / medq / 1e9,
                (dm.col_bytes(0) / qm.col_bytes(0)),
                medq / medd),
        ]);
    }
    t.print();

    // ---- selection ------------------------------------------------------
    let mut t = Table::new("top-m selection (epoch boundary)", &["n", "m", "us/call"]);
    for &(n, m) in &[(100_000usize, 1_000usize), (1_000_000, 10_000)] {
        let z: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut out = 0usize;
        let (med, _) = bench_median(|| out += selection::top_m(&z, m).len(), 0.2, 200);
        std::hint::black_box(out);
        t.row(vec![n.to_string(), m.to_string(), format!("{:.0}", med * 1e6)]);
    }
    t.print();

    // ---- barriers ---------------------------------------------------------
    {
        let mut t = Table::new("barrier crossings (V_B sync cost)", &["kind", "threads", "ns/crossing"]);
        for &threads in &[2usize, 4] {
            let b = SpinBarrier::new(threads);
            let rounds = 5_000;
            let timer = Timer::start();
            std::thread::scope(|s| {
                for _ in 0..threads {
                    s.spawn(|| {
                        for _ in 0..rounds {
                            b.wait();
                        }
                    });
                }
            });
            t.row(vec![
                "spin".into(),
                threads.to_string(),
                format!("{:.0}", timer.secs() / rounds as f64 * 1e9),
            ]);
        }
        t.print();
    }

    // ---- PJRT gap batch vs native ----------------------------------------
    let dir = hthc::runtime::default_artifacts_dir();
    let rt = if dir.join("manifest.txt").exists() {
        hthc::runtime::XlaRuntime::start(&dir)
            .map_err(|e| println!("(PJRT unavailable: {e}; skipping microbench)"))
            .ok()
    } else {
        println!("(artifacts not built; skipping PJRT microbench)");
        None
    };
    if let Some(rt) = rt {
        use hthc::coordinator::hthc::GapBackend;
        use hthc::glm::GlmModel;
        let service = hthc::runtime::GapService::new(&rt);
        let g = hthc::data::DatasetBuilder::generated(
            hthc::data::DatasetKind::EpsilonLike,
            hthc::data::Family::Regression,
        )
        .scale(0.2)
        .seed(31)
        .build()
        .expect("bench dataset");
        let (d, n) = (g.d(), g.n());
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let alpha: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        let kind = hthc::glm::Lasso::new(0.05).kind();
        let coords: Vec<usize> = (0..service.block_len().min(n)).collect();
        // warm once (compile)
        let _ = service.batch_gaps(g.matrix(), &coords, &w, &alpha, kind);
        let (med_pjrt, _) = bench_median(
            || {
                std::hint::black_box(
                    service.batch_gaps(g.matrix(), &coords, &w, &alpha, kind),
                );
            },
            0.3,
            200,
        );
        let ops = g.as_ops();
        let (med_native, _) = bench_median(
            || {
                let mut s = 0.0f32;
                for &j in &coords {
                    s += kind.gap(ops.dot(j, &w), alpha[j]);
                }
                std::hint::black_box(s);
            },
            0.2,
            2_000,
        );
        let mut t = Table::new(
            "task A gap batch: native loop vs PJRT artifact (CPU)",
            &["path", "us/block(256 coords)", "ratio"],
        );
        t.row(vec!["native".into(), format!("{:.0}", med_native * 1e6), "1.0x".into()]);
        t.row(vec![
            "pjrt (interpret-mode pallas on CPU)".into(),
            format!("{:.0}", med_pjrt * 1e6),
            format!("{:.1}x", med_pjrt / med_native),
        ]);
        t.print();
        println!(
            "note: the PJRT path pays per-call literal packing + CPU \
             interpret overhead; on a TPU backend the same artifact is the \
             fast path.  Structural (VMEM/roofline) analysis in DESIGN.md."
        );
    }
}
