//! §Perf — hot-path microbenchmarks for the optimization pass.
//!
//! Profiles every inner loop the end-to-end runs spend time in:
//! dense/sparse/quantized dots, locked axpy across lock granularities,
//! top-m selection, barrier crossings, PJRT gap-batch latency vs the
//! native loop.  Before/after numbers from this harness are recorded in
//! EXPERIMENTS.md §Perf.

use hthc::coordinator::{selection, SharedVector};
use hthc::data::dense::dot_f32;
use hthc::data::{ColumnOps, DenseMatrix, QuantizedMatrix, SparseMatrix};
use hthc::metrics::Table;
use hthc::threadpool::SpinBarrier;
use hthc::util::timer::{bench_median, KNL_HZ};
use hthc::util::{Rng, Timer};

fn main() {
    println!("§Perf hot-path microbenchmarks\n");
    let mut rng = Rng::new(424242);

    // ---- dense dot -----------------------------------------------------
    let mut t = Table::new(
        "dense dot_f32 (task A/B inner product)",
        &["d", "GB/s", "flops/cycle@1.5GHz", "ns/call"],
    );
    for &d in &[1_000usize, 10_000, 100_000, 1_000_000] {
        let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut acc = 0.0f32;
        let (med, _) = bench_median(|| acc += dot_f32(&a, &b), 0.15, 10_000);
        std::hint::black_box(acc);
        t.row(vec![
            d.to_string(),
            format!("{:.2}", (d * 8) as f64 / med / 1e9),
            format!("{:.2}", 2.0 * d as f64 / (med * KNL_HZ)),
            format!("{:.0}", med * 1e9),
        ]);
    }
    t.print();

    // ---- fused stale dot (task B's actual read path) --------------------
    let mut t = Table::new(
        "fused dot_mapped_range over SharedVector (atomic reads)",
        &["d", "GB/s", "vs plain dot", "ns/call"],
    );
    for &d in &[10_000usize, 100_000] {
        let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let plain: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let v = SharedVector::from_slice(&plain, 1024);
        let mut acc = 0.0f32;
        let (med_fused, _) = bench_median(
            || acc += v.dot_mapped_range(&col, &y, |vj, yj| vj - yj, 0, d),
            0.15,
            10_000,
        );
        let mut acc2 = 0.0f32;
        let (med_plain, _) = bench_median(|| acc2 += dot_f32(&col, &plain), 0.1, 10_000);
        std::hint::black_box((acc, acc2));
        t.row(vec![
            d.to_string(),
            format!("{:.2}", (d * 12) as f64 / med_fused / 1e9),
            format!("{:.2}x slower", med_fused / med_plain),
            format!("{:.0}", med_fused * 1e9),
        ]);
    }
    t.print();

    // ---- locked axpy across lock granularities --------------------------
    let mut t = Table::new(
        "axpy_dense_locked (single thread): lock-chunk sweep, d = 100k",
        &["lock chunk", "GB/s", "ns/call", "locks taken"],
    );
    let d = 100_000;
    let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    for &chunk in &[64usize, 256, 1024, 4096, 16384] {
        let v = SharedVector::new(d, chunk);
        let (med, _) = bench_median(|| v.axpy_dense_locked(&col, 1e-6, 0, d), 0.15, 5_000);
        t.row(vec![
            chunk.to_string(),
            format!("{:.2}", (d * 12) as f64 / med / 1e9),
            format!("{:.0}", med * 1e9),
            d.div_ceil(chunk).to_string(),
        ]);
    }
    t.print();

    // ---- sparse + quantized dots ----------------------------------------
    let mut t = Table::new("sparse & quantized column dots", &["repr", "nnz/d", "ns/col", "GB/s"]);
    {
        let d = 100_000;
        let nnz = 2_000;
        let idx = rng.sample_distinct(d, nnz);
        let cols = vec![idx.iter().map(|&r| (r as u32, rng.normal())).collect::<Vec<_>>()];
        let sm = SparseMatrix::from_columns(d, cols);
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut acc = 0.0f32;
        let (med, _) = bench_median(|| acc += sm.dot(0, &w), 0.1, 20_000);
        t.row(vec![
            "sparse CSC".into(),
            format!("{nnz}/{d}"),
            format!("{:.0}", med * 1e9),
            format!("{:.2}", (nnz * 8) as f64 / med / 1e9),
        ]);

        let dq = 65_536;
        let data: Vec<f32> = (0..dq).map(|_| rng.normal()).collect();
        let dm = DenseMatrix::from_col_major(dq, 1, data);
        let qm = QuantizedMatrix::from_dense(&dm);
        let wq: Vec<f32> = (0..dq).map(|_| rng.normal()).collect();
        let mut acc2 = 0.0f32;
        let (medq, _) = bench_median(|| acc2 += qm.dot(0, &wq), 0.1, 20_000);
        let mut acc3 = 0.0f32;
        let (medd, _) = bench_median(|| acc3 += dm.dot(0, &wq), 0.1, 20_000);
        std::hint::black_box((acc, acc2, acc3));
        t.row(vec![
            "quantized 4-bit".into(),
            format!("{dq}/{dq}"),
            format!("{:.0}", medq * 1e9),
            format!("{:.2} ({}x fewer bytes, {:.2}x time vs fp32)",
                qm.col_bytes(0) as f64 / medq / 1e9,
                (dm.col_bytes(0) / qm.col_bytes(0)),
                medq / medd),
        ]);
    }
    t.print();

    // ---- selection ------------------------------------------------------
    let mut t = Table::new("top-m selection (epoch boundary)", &["n", "m", "us/call"]);
    for &(n, m) in &[(100_000usize, 1_000usize), (1_000_000, 10_000)] {
        let z: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut out = 0usize;
        let (med, _) = bench_median(|| out += selection::top_m(&z, m).len(), 0.2, 200);
        std::hint::black_box(out);
        t.row(vec![n.to_string(), m.to_string(), format!("{:.0}", med * 1e6)]);
    }
    t.print();

    // ---- barriers ---------------------------------------------------------
    {
        let mut t = Table::new("barrier crossings (V_B sync cost)", &["kind", "threads", "ns/crossing"]);
        for &threads in &[2usize, 4] {
            let b = SpinBarrier::new(threads);
            let rounds = 5_000;
            let timer = Timer::start();
            std::thread::scope(|s| {
                for _ in 0..threads {
                    s.spawn(|| {
                        for _ in 0..rounds {
                            b.wait();
                        }
                    });
                }
            });
            t.row(vec![
                "spin".into(),
                threads.to_string(),
                format!("{:.0}", timer.secs() / rounds as f64 * 1e9),
            ]);
        }
        t.print();
    }

    // ---- PJRT gap batch vs native ----------------------------------------
    let dir = hthc::runtime::default_artifacts_dir();
    let rt = if dir.join("manifest.txt").exists() {
        hthc::runtime::XlaRuntime::start(&dir)
            .map_err(|e| println!("(PJRT unavailable: {e}; skipping microbench)"))
            .ok()
    } else {
        println!("(artifacts not built; skipping PJRT microbench)");
        None
    };
    if let Some(rt) = rt {
        use hthc::coordinator::hthc::GapBackend;
        use hthc::glm::GlmModel;
        let service = hthc::runtime::GapService::new(&rt);
        let g = hthc::data::generator::generate(
            hthc::data::generator::DatasetKind::EpsilonLike,
            hthc::data::generator::Family::Regression,
            0.2,
            31,
        );
        let (d, n) = (g.d(), g.n());
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let alpha: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        let kind = hthc::glm::Lasso::new(0.05).kind();
        let coords: Vec<usize> = (0..service.block_len().min(n)).collect();
        // warm once (compile)
        let _ = service.batch_gaps(&g.matrix, &coords, &w, &alpha, kind);
        let (med_pjrt, _) = bench_median(
            || {
                std::hint::black_box(
                    service.batch_gaps(&g.matrix, &coords, &w, &alpha, kind),
                );
            },
            0.3,
            200,
        );
        let ops = g.matrix.as_ops();
        let (med_native, _) = bench_median(
            || {
                let mut s = 0.0f32;
                for &j in &coords {
                    s += kind.gap(ops.dot(j, &w), alpha[j]);
                }
                std::hint::black_box(s);
            },
            0.2,
            2_000,
        );
        let mut t = Table::new(
            "task A gap batch: native loop vs PJRT artifact (CPU)",
            &["path", "us/block(256 coords)", "ratio"],
        );
        t.row(vec!["native".into(), format!("{:.0}", med_native * 1e6), "1.0x".into()]);
        t.row(vec![
            "pjrt (interpret-mode pallas on CPU)".into(),
            format!("{:.0}", med_pjrt * 1e6),
            format!("{:.1}x", med_pjrt / med_native),
        ]);
        t.print();
        println!(
            "note: the PJRT path pays per-call literal packing + CPU \
             interpret overhead; on a TPU backend the same artifact is the \
             fast path.  Structural (VMEM/roofline) analysis in DESIGN.md."
        );
    }
}
