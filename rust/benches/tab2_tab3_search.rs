//! Tables II & III — best parameters found (%B, T_A, T_B, V_B) per
//! dataset and model via search (paper §V-B: exhaustive; here a coarse
//! grid sized for the host, plus the §IV-F model's recommendation for
//! comparison).

use hthc::bench_support::*;
use hthc::coordinator::PerfModel;
use hthc::data::generator::{DatasetKind, Family};
use hthc::memory::TierSim;
use hthc::metrics::Table;

fn main() {
    println!("Tables II/III reproduction: best-parameter search\n");
    let fracs = [0.02f64, 0.08, 0.25];
    let t_as = [1usize, 2];
    let t_bs = [1usize, 2, 4];
    let v_bs = [1usize, 2];
    let timeout = 12.0;

    for model_name in ["lasso", "svm"] {
        let mut table = Table::new(
            format!(
                "Table {} analogue: best settings for {}",
                if model_name == "lasso" { "II" } else { "III" },
                model_name
            ),
            &["dataset", "%B", "T_A", "T_B", "V_B", "T_total", "t(converge)", "epochs"],
        );
        for kind in [
            DatasetKind::EpsilonLike,
            DatasetKind::DvscLike,
            DatasetKind::News20Like,
        ] {
            let family = if model_name == "svm" {
                Family::Classification
            } else {
                Family::Regression
            };
            let g = bench_dataset(kind, family, 2000 + kind as u64);
            let probe = bench_model(model_name, g.n());
            let o0 = obj0(probe.as_ref(), &g);
            let target = 1e-3 * o0;

            let mut best: Option<(f64, f64, usize, usize, usize, usize)> = None;
            for &frac in &fracs {
                for &ta in &t_as {
                    for &tb in &t_bs {
                        for &vb in &v_bs {
                            if vb > 1 && !matches!(g.matrix(), hthc::data::Matrix::Dense(_)) {
                                continue; // paper: V_B = 1 for sparse
                            }
                            let mut cfg = bench_cfg(target, timeout);
                            cfg.batch_frac = frac;
                            cfg.t_a = ta;
                            cfg.t_b = tb;
                            cfg.v_b = vb;
                            let mut model = bench_model(model_name, g.n());
                            let res =
                                run_solver("A+B", model.as_mut(), &g, &cfg);
                            if let Some(t) = res.trace.time_to_gap(target) {
                                if best.map_or(true, |b| t < b.0) {
                                    best = Some((t, frac, ta, tb, vb, res.epochs));
                                }
                            }
                        }
                    }
                }
            }
            match best {
                Some((t, frac, ta, tb, vb, epochs)) => {
                    table.row(vec![
                        g.meta().source.describe(),
                        format!("{:.0}%", frac * 100.0),
                        ta.to_string(),
                        tb.to_string(),
                        vb.to_string(),
                        (ta + tb * vb).to_string(),
                        hthc::util::fmt_secs(t),
                        epochs.to_string(),
                    ]);
                }
                None => {
                    table.row(vec![
                        g.meta().source.describe(),
                        "--".into(),
                        "--".into(),
                        "--".into(),
                        "--".into(),
                        "--".into(),
                        "timeout".into(),
                        "--".into(),
                    ]);
                }
            }
        }
        table.print();
        println!();
    }

    // §IV-F model recommendation for the paper's own machine shape
    println!("§IV-F model recommendation (KNL-parameterized, 72 threads):");
    let pm = PerfModel::calibrate(
        &[10_000, 100_000, 1_000_000],
        &[1, 2, 4, 8, 12, 16, 24],
        &[1, 2, 4, 8, 14, 16, 56, 64],
        &[1, 2, 4, 6, 10],
    );
    let sim = TierSim::default();
    let _ = &sim;
    for (label, n, d) in [
        ("epsilon (Lasso orientation)", 2_000usize, 400_000usize),
        ("dvsc    (Lasso orientation)", 200_704, 40_002),
    ] {
        match pm.recommend(n, d, 0.15, &[0.02, 0.04, 0.08, 0.25], 72) {
            Some(r) => println!(
                "  {label}: m={} ({:.0}%), T_A={}, T_B={}, V_B={} -> epoch {} (refresh {:.0}%)",
                r.m,
                100.0 * r.m as f64 / n as f64,
                r.t_a,
                r.t_b,
                r.v_b,
                hthc::util::fmt_secs(r.epoch_secs),
                r.refresh_frac * 100.0
            ),
            None => println!("  {label}: infeasible"),
        }
    }
    println!(
        "\nexpected shape (paper Tables II/III): small %B best for dense \
         Lasso (2-8%), larger for SVM on sparse; V_B > 1 only for the \
         long-column dense sets (epsilon SVM row uses V_B=10 on KNL)."
    );
}
