//! Fig. 6 — Parameter combinations (T_B, V_B, %B, T_A) whose
//! convergence time lands within 110% of the best found (paper §V-D).
//!
//! Paper shape: a broad plateau of near-best settings (robustness), with
//! %B mattering most and V_B > 1 only appearing for the long-column
//! dense data.

use hthc::bench_support::*;
use hthc::data::generator::{DatasetKind, Family};
use hthc::metrics::Table;

fn main() {
    println!("Fig. 6 reproduction: near-best parameter combinations\n");
    let timeout = 10.0;
    for (kind, model_name) in [
        (DatasetKind::EpsilonLike, "lasso"),
        (DatasetKind::EpsilonLike, "svm"),
    ] {
        let family = if model_name == "svm" {
            Family::Classification
        } else {
            Family::Regression
        };
        let g = bench_dataset(kind, family, 7000);
        let probe = bench_model(model_name, g.n());
        let o0 = obj0(probe.as_ref(), &g);
        let target = 1e-3 * o0;

        let mut results: Vec<(f64, f64, usize, usize, usize)> = Vec::new();
        for &frac in &[0.02f64, 0.08, 0.25] {
            for &ta in &[1usize, 2] {
                for &tb in &[1usize, 2, 4] {
                    for &vb in &[1usize, 2] {
                        let mut cfg = bench_cfg(target, timeout);
                        cfg.batch_frac = frac;
                        cfg.t_a = ta;
                        cfg.t_b = tb;
                        cfg.v_b = vb;
                        let mut model = bench_model(model_name, g.n());
                        let res =
                            run_solver("A+B", model.as_mut(), &g, &cfg);
                        if let Some(t) = res.trace.time_to_gap(target) {
                            results.push((t, frac, ta, tb, vb));
                        }
                    }
                }
            }
        }
        results.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let best = results.first().map(|r| r.0).unwrap_or(f64::NAN);
        let mut table = Table::new(
            format!(
                "Fig 6: settings within 110% of best ({}) — {} / {}",
                hthc::util::fmt_secs(best),
                model_name,
                g.meta().source.describe()
            ),
            &["t(converge)", "%B", "T_A", "T_B", "V_B", "within"],
        );
        for (t, frac, ta, tb, vb) in &results {
            let ratio = t / best;
            if ratio <= 1.1 {
                table.row(vec![
                    hthc::util::fmt_secs(*t),
                    format!("{:.0}%", frac * 100.0),
                    ta.to_string(),
                    tb.to_string(),
                    vb.to_string(),
                    format!("{:.0}%", ratio * 100.0),
                ]);
            }
        }
        table.print();
        println!(
            "({} of {} searched settings are near-best)\n",
            results.iter().filter(|r| r.0 / best <= 1.1).count(),
            results.len()
        );
    }
    println!(
        "expected shape (paper Fig. 6): multiple near-best combinations — \
         the scheme is robust to the exact thread split; %B dominates."
    );
}
