//! Fig. 7 — Sensitivity to the number of task-A updates per epoch
//! (paper §V-D): run HTHC with A performing a *fixed* number of gap
//! refreshes per epoch and measure convergence.
//!
//! Paper shape: ~10% of n updates per epoch already achieves the best
//! wall-clock; fewer updates need more epochs but the epochs are
//! cheaper, so there is a sweet spot well below 100%.

use hthc::bench_support::*;
use hthc::coordinator::{task_a, task_b, GapMemory, Selection, SharedVector, WorkingSet};
use hthc::data::generator::{DatasetKind, Family};
use hthc::glm::{self};
use hthc::memory::TierSim;
use hthc::metrics::{report::fmt_opt_secs, Table};
use hthc::threadpool::WorkerPool;
use hthc::util::{Rng, Timer};

/// HTHC epoch loop with a fixed A-update budget per epoch (the paper's
/// Fig. 7 protocol; T_A = 10 there, scaled-down topology here).
fn run_fixed_a(
    g: &hthc::data::Dataset,
    model_name: &str,
    a_frac: f64,
    target_gap: f64,
    timeout: f64,
) -> (Option<f64>, usize) {
    let mut model = bench_model(model_name, g.n());
    let (d, n) = (g.d(), g.n());
    let m_batch = (n / 12).max(1);
    let pool_a = WorkerPool::with_name(2, "fig7-a");
    let pool_b = WorkerPool::with_name(2, "fig7-b");
    let v = SharedVector::new(d, 1024);
    let alpha = SharedVector::new(n, usize::MAX >> 1);
    let gaps = GapMemory::new(n);
    let mut ws = WorkingSet::new(g.matrix(), m_batch);
    let sim = TierSim::default();
    let mut rng = Rng::new(99);
    let timer = Timer::start();
    let a_budget = ((n as f64 * a_frac) as usize).max(1);

    for epoch in 1..=100_000u32 {
        let alpha_snap = alpha.snapshot();
        model.epoch_refresh(&alpha_snap);
        let kind = model.kind();
        let v_snap = v.snapshot();
        let mut w = vec![0.0f32; d];
        for r in 0..d {
            w[r] = kind.w_of(v_snap[r], g.targets()[r]);
        }
        let sel = if epoch == 1 { Selection::Random } else { Selection::DualityGap };
        let batch = sel.select(&gaps.values(), m_batch, &mut rng);
        ws.swap_in(g.matrix(), &batch, &sim, g.placement());

        // A: exactly a_budget random refreshes, then B (sequentialized —
        // the budget, not the overlap, is what Fig. 7 varies)
        let coords: Vec<usize> = (0..a_budget).map(|_| rng.below(n)).collect();
        let snap = task_a::ASnapshot { w: &w, alpha: &alpha_snap, kind, epoch };
        task_a::run_fixed(&pool_a, g.matrix(), &snap, &gaps, &coords, &sim, g.placement());

        let items = task_b::WorkItem::from_batch(&batch);
        task_b::run_epoch(&pool_b, &ws, &items, &v, g.targets(), &alpha, kind, 2, 1, &sim);
        for &j in &batch {
            gaps.mark_processed(j, 0.0, epoch);
        }

        if epoch % 5 == 0 {
            let a_now = alpha.snapshot();
            let v_now = g.matvec_alpha(&a_now);
            v.store_all(&v_now);
            let gap = glm::total_gap(
                model.as_ref(), g.as_block_ops(), &v_now, g.targets(), &a_now,
            );
            if gap <= target_gap {
                return (Some(timer.secs()), epoch as usize);
            }
        }
        if timer.secs() > timeout {
            return (None, epoch as usize);
        }
    }
    (None, 100_000)
}

fn main() {
    println!("Fig. 7 reproduction: sensitivity to A updates per epoch\n");
    let timeout = 15.0;
    for (kind, model_name) in [
        (DatasetKind::EpsilonLike, "lasso"),
        (DatasetKind::DvscLike, "svm"),
    ] {
        let family = if model_name == "svm" {
            Family::Classification
        } else {
            Family::Regression
        };
        let g = bench_dataset(kind, family, 8000);
        let probe = bench_model(model_name, g.n());
        let o0 = obj0(probe.as_ref(), &g);
        let target = 1e-3 * o0;
        let mut table = Table::new(
            format!("Fig 7: {} / {}", model_name, g.meta().source.describe()),
            &["A updates/epoch", "% of n", "t(converge)", "epochs"],
        );
        for frac in [0.01f64, 0.05, 0.10, 0.25, 0.50, 1.00] {
            let (t, epochs) = run_fixed_a(&g, model_name, frac, target, timeout);
            table.row(vec![
                ((g.n() as f64 * frac) as usize).to_string(),
                format!("{:.0}%", frac * 100.0),
                fmt_opt_secs(t),
                epochs.to_string(),
            ]);
        }
        table.print();
        println!();
    }
    println!(
        "expected shape (paper Fig. 7): ~10% A-updates/epoch already gives \
         the best time; more updates cost epoch time without helping, fewer \
         need more epochs."
    );
}
