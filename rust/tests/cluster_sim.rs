//! Cluster simulation suite (ISSUE 10): parity with the sequential
//! oracle at one node, duality-gap certificates at k in {2, 4},
//! failover under leader kills, healed partitions, lossy links — all
//! deterministic under fixed seeds because the whole cluster runs on
//! one thread over virtual time.
//!
//! Tolerances, documented once: at k=1 the cluster is fp-identical to
//! the sequential reference by construction (same kernels, same update
//! order, re-anchored `v` every eval round), so alpha is compared
//! bit-for-bit and the gap to 1e-12 relative.  At k>1 the *iterates*
//! legitimately differ from any single-node engine (CoCoA rounds are a
//! different algorithm path), so parity means: both sides reach the
//! same duality-gap certificate threshold, and the reported gap
//! survives independent recomputation from the reported iterate to
//! 1e-9 relative (the recomputation repeats the leader's exact eval:
//! re-anchor, refresh, `total_gap`).

use hthc::cluster::{run_cluster, ClusterConfig, ClusterReport, FaultPlan};
use hthc::coordinator::HthcConfig;
use hthc::data::{Dataset, DatasetKind, Family};
use hthc::glm::{self, GlmModel, Lasso};
use hthc::memory::TierSim;
use hthc::solver::{keys, Trainer};

const LAM: f32 = 0.3;
const TOL: f64 = 1e-3;

fn tiny() -> Dataset {
    Dataset::generated(DatasetKind::Tiny, Family::Regression, 1.0, 4242)
}

fn lasso() -> Box<dyn GlmModel> {
    Box::new(Lasso::new(LAM))
}

fn cluster_cfg(nodes: usize) -> ClusterConfig {
    ClusterConfig { nodes, gap_tol: TOL, max_rounds: 1000, ..Default::default() }
}

/// The certificate recomputed from scratch out of the reported iterate
/// — independent of everything the leader tracked during the run.
fn recomputed_gap(g: &Dataset, alpha: &[f32]) -> f64 {
    let mut model = Lasso::new(LAM);
    model.epoch_refresh(alpha);
    let v = g.matvec_alpha(alpha);
    glm::total_gap(&model, g.as_block_ops(), &v, g.targets(), alpha)
}

/// A report's certificate must hold up under independent recomputation.
fn assert_certified(g: &Dataset, rep: &ClusterReport) {
    assert!(rep.fit.converged, "not converged: {}", rep.summary());
    let reported = rep.fit.final_gap().expect("converged run has a trace");
    assert!(reported <= TOL, "reported gap {reported} above tol");
    let fresh = recomputed_gap(g, &rep.fit.alpha);
    assert!(
        (fresh - reported).abs() <= 1e-9 * reported.abs().max(1.0),
        "certificate does not survive recomputation: reported {reported}, fresh {fresh}"
    );
}

// ---------------------------------------------------------------------------
// parity with the sequential oracle
// ---------------------------------------------------------------------------

#[test]
fn k1_cluster_is_the_sequential_oracle() {
    let g = tiny();
    let cfg = cluster_cfg(1);
    let rep = run_cluster(&g, &lasso, &cfg).unwrap();
    assert!(rep.fit.converged, "{}", rep.summary());
    assert_eq!(rep.failovers, 0);

    // Reference: exactly what the one shard-owning node runs per round
    // — one sequential CD epoch, then the eval re-anchor + certificate.
    let mut model = Lasso::new(LAM);
    let mut alpha = vec![0.0f32; g.n()];
    let mut v = vec![0.0f32; g.d()];
    let mut rounds = 0u64;
    let mut gap = f64::INFINITY;
    while rounds < cfg.max_rounds {
        glm::solve_reference(&mut model, g.as_ops(), g.targets(), &mut alpha, &mut v, 1);
        rounds += 1;
        v = g.matvec_alpha(&alpha);
        model.epoch_refresh(&alpha);
        gap = glm::total_gap(&model, g.as_block_ops(), &v, g.targets(), &alpha);
        if gap <= cfg.gap_tol {
            break;
        }
    }
    assert!(gap <= cfg.gap_tol, "reference did not converge in {rounds} rounds");
    assert_eq!(rep.fit.epochs as u64, rounds, "same number of rounds");
    assert_eq!(rep.fit.alpha, alpha, "k=1 must be the sequential oracle bit-for-bit");
    let reported = rep.fit.final_gap().unwrap();
    assert!(
        (reported - gap).abs() <= 1e-12 * gap.abs().max(1.0),
        "gap mismatch: cluster {reported}, reference {gap}"
    );
}

#[test]
fn k2_and_k4_reach_the_same_certificate_as_single_node() {
    let g = tiny();
    // single-node baseline through the standard trainer facade
    let mut model = Lasso::new(LAM);
    let cfg = HthcConfig {
        gap_tol: TOL,
        max_epochs: 1000,
        eval_every: 1,
        timeout_secs: 120.0,
        ..Default::default()
    };
    let single = Trainer::new().config(cfg).fit_with(&mut model, &g, &TierSim::default());
    assert!(single.converged, "single-node baseline must converge");
    assert!(single.final_gap().unwrap() <= TOL);

    for k in [2usize, 4] {
        let rep = run_cluster(&g, &lasso, &cluster_cfg(k)).unwrap();
        assert_certified(&g, &rep);
        assert_eq!(rep.failovers, 0, "clean run, no takeovers");
        assert_eq!(rep.final_leader, 0, "bootstrap leader survives");
        assert_eq!(rep.fit.extras.u64(keys::CLUSTER_NODES), Some(k as u64));
        assert_eq!(rep.fit.extras.u64(keys::CLUSTER_ROUNDS), Some(rep.fit.epochs as u64));
    }
}

// ---------------------------------------------------------------------------
// failover
// ---------------------------------------------------------------------------

#[test]
fn leader_killed_mid_training_fails_over_and_completes() {
    let g = tiny();
    let cfg = ClusterConfig { fault: FaultPlan::default().kill(20, 0), ..cluster_cfg(4) };
    let rep = run_cluster(&g, &lasso, &cfg).unwrap();
    assert_certified(&g, &rep);
    assert_ne!(rep.final_leader, 0, "killed bootstrap leader cannot report");
    assert!(rep.failovers >= 1, "somebody must have taken over: {}", rep.summary());
    assert!(rep.elections >= 1);
    assert_eq!(rep.fit.extras.u64(keys::CLUSTER_FAILOVERS), Some(rep.failovers));

    // deterministic: the same seed replays the same failover tick-for-tick
    let again = run_cluster(&g, &lasso, &cfg).unwrap();
    assert_eq!(rep.ticks, again.ticks);
    assert_eq!(rep.final_leader, again.final_leader);
    assert_eq!(rep.fit.alpha, again.fit.alpha);
    assert_eq!(rep.fit.final_gap(), again.fit.final_gap());
}

#[test]
fn killed_worker_shards_are_reassigned() {
    let g = tiny();
    let cfg = ClusterConfig { fault: FaultPlan::default().kill(30, 2), ..cluster_cfg(3) };
    let rep = run_cluster(&g, &lasso, &cfg).unwrap();
    assert_certified(&g, &rep);
    // a dead worker is the leader's problem, not an election's
    assert_eq!(rep.final_leader, 0, "leader survives a worker death");
    assert_eq!(rep.failovers, 0);
}

#[test]
fn healed_partition_converges() {
    let g = tiny();
    // the bootstrap leader spends [5, 150) alone on an island: the
    // majority elects a replacement, the heal resolves split-brain in
    // the replacement's favor (higher term), training completes.
    let cfg = ClusterConfig {
        fault: FaultPlan::default().partition(5, 150, vec![0]),
        ..cluster_cfg(4)
    };
    let rep = run_cluster(&g, &lasso, &cfg).unwrap();
    assert_certified(&g, &rep);
    assert!(rep.elections >= 1, "isolation must trigger an election");
}

// ---------------------------------------------------------------------------
// lossy wire
// ---------------------------------------------------------------------------

#[test]
fn lossy_network_still_converges_deterministically() {
    let g = tiny();
    let cfg = ClusterConfig { fault: FaultPlan::lossy(0.15, 0.10, 3), ..cluster_cfg(3) };
    let rep = run_cluster(&g, &lasso, &cfg).unwrap();
    assert_certified(&g, &rep);
    // the faults must actually have bitten for this to mean anything
    assert!(rep.stats.dropped > 0, "drop_prob 0.15 never fired? {}", rep.summary());
    assert!(rep.stats.retransmits > 0, "drops must force retransmissions");
    assert!(rep.stats.dedup_dropped > 0, "dup_prob 0.10 never deduped?");

    let again = run_cluster(&g, &lasso, &cfg).unwrap();
    assert_eq!(rep.ticks, again.ticks, "seeded faults replay exactly");
    assert_eq!(rep.stats.dropped, again.stats.dropped);
    assert_eq!(rep.fit.alpha, again.fit.alpha);

    // a different seed draws different faults but the same certificate
    let other = run_cluster(&g, &lasso, &ClusterConfig { seed: 7, ..cfg }).unwrap();
    assert_certified(&g, &other);
}
