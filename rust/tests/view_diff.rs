//! Differential tests for `DatasetView`: a view must agree with its
//! parent matrix on the selected columns — *bitwise* for `dot`,
//! `dots_block`, `sq_norm` and `axpy`, because the view forwards the
//! very same kernel calls the parent would issue (no re-summation, no
//! re-chunking differences).  Checked across all three representations
//! (dense / sparse / quantized, each built through the
//! `DatasetBuilder::represent` stage) and across every available kernel
//! backend.
//!
//! Backend flipping uses `kernels::set_backend`, which is process
//! global — all dispatched comparisons live in the single
//! `view_forwarding_is_bitwise_everywhere` test so concurrent tests in
//! this binary never observe a mid-flight backend switch.

use hthc::data::{
    BlockOps, ColumnOps, Dataset, DatasetBuilder, DatasetKind, Family, Represent,
};
use hthc::kernels::{self, Backend, BLOCK_COLS};
use hthc::util::Rng;
use std::sync::Mutex;

/// `set_backend` is process-global; every test whose bitwise assertion
/// spans two dispatched calls serializes here so a concurrent backend
/// flip cannot land between them.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

/// The three representations over the same generated source.
fn representations(seed: u64) -> Vec<(&'static str, Dataset)> {
    let build = |r: Represent| {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .scale(2.0) // 128 x 64: spans several BLOCK_COLS tiles
            .seed(seed)
            .represent(r)
            .build()
            .unwrap()
    };
    vec![
        ("dense", build(Represent::Dense)),
        ("sparse", build(Represent::Sparse)),
        ("quantized", build(Represent::Quantized)),
    ]
}

/// Column selections that exercise both `ColSel` arms and the
/// translation tiling: ranges, shuffled subsets, duplicates, reversed.
fn selections(n: usize) -> Vec<(&'static str, Vec<usize>)> {
    let mut rng = Rng::new(12001);
    let mut shuffled: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut shuffled);
    vec![
        ("full", (0..n).collect()),
        ("range-tail", (n / 3..n).collect()),
        ("single", vec![n / 2]),
        ("strided", (0..n).step_by(3).collect()),
        ("shuffled", shuffled),
        ("reversed", (0..n).rev().collect()),
        ("duplicates", vec![1; BLOCK_COLS + 2]),
    ]
}

/// `dot`, `dots_block`, `sq_norm`, `axpy` of the view vs the parent on
/// the same columns — bitwise.
fn assert_view_matches_parent(label: &str, ds: &Dataset, cols: &[usize], w: &[f32]) {
    let view = ds.col_subset(cols.to_vec());
    let parent = ds.as_block_ops();
    assert_eq!(view.n_cols(), cols.len());
    assert_eq!(view.n_rows(), ds.n_rows());

    // per-column dot / sq_norm / axpy
    for (k, &j) in cols.iter().enumerate() {
        let vd = view.dot(k, w);
        let pd = parent.dot(j, w);
        assert_eq!(vd.to_bits(), pd.to_bits(), "{label}: dot col {j}");
        assert_eq!(
            view.sq_norm(k).to_bits(),
            parent.sq_norm(j).to_bits(),
            "{label}: sq_norm col {j}"
        );
        assert_eq!(view.nnz(k), parent.nnz(j), "{label}: nnz col {j}");
        assert_eq!(view.col_bytes(k), parent.col_bytes(j), "{label}: col_bytes {j}");

        let mut va = w.to_vec();
        let mut pa = w.to_vec();
        view.axpy(k, 0.75, &mut va);
        parent.axpy(j, 0.75, &mut pa);
        for (r, (x, y)) in va.iter().zip(&pa).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: axpy col {j} row {r}");
        }

        // dot_range windows translate too
        let d = ds.n_rows();
        let (lo, hi) = (0, d / 2 / 64 * 64); // group-aligned for quantized
        if hi > lo {
            assert_eq!(
                view.dot_range(k, w, lo, hi).to_bits(),
                parent.dot_range(j, w, lo, hi).to_bits(),
                "{label}: dot_range col {j}"
            );
        }
    }

    // blocked bulk dots: view tiling must reproduce the parent's exact
    // chunking over the same translated list
    let mut out_view = vec![0.0f32; cols.len()];
    let mut out_parent = vec![0.0f32; cols.len()];
    view.dots_block(&(0..cols.len()).collect::<Vec<_>>(), w, &mut out_view);
    parent.dots_block(cols, w, &mut out_parent);
    for (k, (a, b)) in out_view.iter().zip(&out_parent).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: dots_block slot {k}");
    }
}

#[test]
fn view_forwarding_is_bitwise_everywhere() {
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ambient: Backend = kernels::backend();
    for back in kernels::available_backends() {
        kernels::set_backend(back);
        for (repr, ds) in representations(12002) {
            let mut rng = Rng::new(12003);
            let w: Vec<f32> = (0..ds.n_rows()).map(|_| rng.normal()).collect();
            for (sel_label, cols) in selections(ds.n_cols()) {
                let label = format!("{repr}/{sel_label}[{}]", back.name());
                assert_view_matches_parent(&label, &ds, &cols, &w);
            }
        }
    }
    // restore the ambient dispatch for the rest of the process
    kernels::set_backend(ambient);
}

#[test]
fn split_views_partition_and_score() {
    let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(12004)
        .build()
        .unwrap();
    let (train, val) = ds.split(0.8, 99);
    assert!(!train.is_empty() && !val.is_empty());
    assert_eq!(train.len() + val.len(), ds.n_cols());
    // no overlap
    let mut seen = vec![false; ds.n_cols()];
    for k in 0..train.len() {
        seen[train.parent_col(k)] = true;
    }
    for k in 0..val.len() {
        assert!(!seen[val.parent_col(k)], "overlapping split");
    }
    // a consumer taking &dyn BlockOps runs unchanged on the view:
    // total_gap over the validation columns with zero duals
    let model = hthc::glm::Lasso::new(0.3);
    let v = vec![0.0f32; ds.n_rows()];
    let zeros = vec![0.0f32; val.len()];
    let gap = hthc::glm::total_gap(&model, &val, &v, ds.targets(), &zeros);
    assert!(gap.is_finite());
}

#[test]
fn materialized_split_trains_and_matches_view_columns() {
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(12005)
        .build()
        .unwrap();
    let (train_view, _) = ds.split(0.75, 5);
    let train = train_view.materialize();
    assert_eq!(train.n_cols(), train_view.len());
    // materialized columns are bitwise the view's columns
    let mut rng = Rng::new(12006);
    let w: Vec<f32> = (0..ds.n_rows()).map(|_| rng.normal()).collect();
    for k in 0..train.n_cols() {
        assert_eq!(
            train.as_ops().dot(k, &w).to_bits(),
            train_view.dot(k, &w).to_bits(),
            "col {k}"
        );
    }
    // and the materialized subset is a real trainable Dataset
    let mut model = hthc::glm::Lasso::new(0.3);
    let sim = hthc::memory::TierSim::default();
    let res = hthc::solver::Trainer::new()
        .threads(1, 1, 1)
        .stop_when(hthc::solver::StopWhen::gap_below(0.0).max_epochs(5).eval_every(1))
        .fit_with(&mut model, &train, &sim);
    assert_eq!(res.alpha.len(), train.n_cols());
}

#[test]
fn shards_cover_every_column_exactly_once() {
    let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(12007)
        .build()
        .unwrap();
    for k in [1usize, 3, 7, ds.n_cols(), ds.n_cols() + 5] {
        let shards = ds.view().shards(k);
        assert_eq!(shards.len(), k);
        let mut count = vec![0usize; ds.n_cols()];
        for s in &shards {
            for i in 0..s.len() {
                count[s.parent_col(i)] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1), "k={k}: {count:?}");
    }
}
