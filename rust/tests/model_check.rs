//! Deterministic model checking of the crate's concurrency protocols.
//!
//! The whole file compiles only under `--cfg pallas_model_check`, which
//! swaps `hthc::sync` onto the instrumented scheduler in
//! `hthc::sync::model` (see `rust/DESIGN.md` §12):
//!
//! ```text
//! RUSTFLAGS="--cfg pallas_model_check" cargo test --test model_check
//! ```
//!
//! Each test wraps a small scenario in `model::check`, which reruns it
//! under every schedule a bounded DFS can reach, plus a seeded random
//! phase when the space exceeds the budget, and returns the failing
//! interleaving trace when an invariant breaks.  CI runs the default
//! budgets (deterministic, well under a minute).  For a deeper local
//! soak, `PALLAS_MC_EXHAUSTIVE` multiplies every budget ~200x:
//!
//! ```text
//! PALLAS_MC_EXHAUSTIVE=1 RUSTFLAGS="--cfg pallas_model_check" \
//!     cargo test --release --test model_check -- --test-threads=1
//! ```
#![cfg(pallas_model_check)]

use hthc::cluster::{DedupFilter, Envelope, Mailbox, Message, Packet};
use hthc::coordinator::GapMemory;
use hthc::data::Family;
use hthc::glm::ModelKind;
use hthc::sched::TileScheduler;
use hthc::serve::{ModelSnapshot, ModelStore};
use hthc::sync::model::{check, spawn, Config, Failure, Report};
use hthc::sync::Ordering::{Relaxed, SeqCst};
use hthc::sync::{AtomicU32, AtomicUsize, Condvar, Mutex};
use hthc::threadpool::{CounterBarrier, SpinBarrier};
use std::panic::catch_unwind;
use std::sync::Arc;
use std::time::Instant;

/// Exploration budget: the given DFS/random split by default, both
/// multiplied ~200x when `PALLAS_MC_EXHAUSTIVE` is set (local soak
/// mode; CI sticks to the deterministic defaults).
fn budget(dfs: usize, random: usize) -> Config {
    let exhaustive = std::env::var_os("PALLAS_MC_EXHAUSTIVE").is_some();
    Config {
        max_executions: if exhaustive { dfs * 200 } else { dfs },
        random_executions: if exhaustive { random * 200 } else { random },
        ..Config::default()
    }
}

/// Unwrap a check result, printing the full interleaving trace of a
/// failure instead of the opaque `Err(..)` Debug form.
fn must_pass(res: Result<Report, Box<Failure>>) -> Report {
    match res {
        Ok(r) => r,
        Err(f) => panic!("{f}"),
    }
}

/// Tests that *simulate* panics (a panicking job, an injected bug) mark
/// their payloads with `[mc]`; this hook keeps those expected panics —
/// and the scheduler's internal non-string abort payloads — out of the
/// test output while real failures stay loud.
fn quiet_expected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                // Non-string payloads here are the model scheduler's
                // abort token unwinding threads after a failure was
                // already recorded.
                String::new()
            };
            if !(msg.is_empty() || msg.contains("[mc]")) {
                default(info);
            }
        }));
    });
}

fn snap(tag: f32) -> ModelSnapshot {
    ModelSnapshot {
        version: 0,
        kind: ModelKind::Lasso { lam: 0.1, lip_b: 1.0 },
        family: Family::Regression,
        weights: vec![tag; 4],
        bias: tag,
        alpha: vec![tag; 4],
        col_scales: None,
        gap: tag as f64,
        trained_cols: 4,
        absorbed: 0,
        published_at: Instant::now(),
    }
}

/// Invariant the gap-memory writers maintain: the value is a function
/// of the stamp, so any observed pair that violates it is torn.
fn fval(epoch: u32) -> f32 {
    epoch as f32 * 3.5 + 1.0
}

/// ModelStore: two readers loading concurrently with a writer that
/// republishes twice must never pin a torn or reclaimed snapshot, and
/// per-reader versions must stay monotone.
#[test]
fn model_store_readers_never_observe_torn_snapshots() {
    let res = check(&budget(1200, 600), || {
        let store = Arc::new(ModelStore::new(snap(1.0)));
        let writer = {
            let store = Arc::clone(&store);
            spawn(move || {
                store.publish(snap(2.0));
                store.publish(snap(3.0));
            })
        };
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let store = Arc::clone(&store);
                spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..2 {
                        let s = store.load();
                        assert!(s.version >= last, "versions went backwards");
                        last = s.version;
                        assert!(
                            s.weights.iter().all(|&w| w == s.bias),
                            "torn snapshot: weights do not match the bias tag"
                        );
                        assert!(s.gap == s.bias as f64, "torn snapshot: gap/bias mismatch");
                    }
                })
            })
            .collect();
        writer.join();
        for r in readers {
            r.join();
        }
        assert_eq!(store.version(), 3);
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

/// GapMemory: with the packed single-word layout, no reader may ever
/// observe a stamp paired with another epoch's value, and the update
/// counter must count every write exactly once.
#[test]
fn gap_memory_value_and_stamp_never_tear() {
    let res = check(&budget(2000, 1000), || {
        let g = Arc::new(GapMemory::new(4));
        let writers: Vec<_> = (0..2usize)
            .map(|t| {
                let g = Arc::clone(&g);
                spawn(move || {
                    for r in 0..3u32 {
                        let epoch = t as u32 * 3 + r + 1;
                        g.update((t + r as usize) % 4, fval(epoch), epoch);
                    }
                })
            })
            .collect();
        let readers: Vec<_> = (0..2usize)
            .map(|t| {
                let g = Arc::clone(&g);
                spawn(move || {
                    for i in 0..2usize {
                        let (gap, stamp) = g.read_entry((t + i) % 4);
                        if stamp == 0 {
                            assert!(gap.is_infinite(), "untouched entry must stay +inf");
                        } else {
                            assert!(gap == fval(stamp), "torn pair: stamp {stamp} gap {gap}");
                        }
                    }
                })
            })
            .collect();
        for h in writers {
            h.join();
        }
        for h in readers {
            h.join();
        }
        let (updates, _frac) = g.refresh_stats(1);
        assert_eq!(updates, 6, "every update counted exactly once");
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

/// TileScheduler drain mode: two workers racing `claim` (including
/// steals once a worker's own shard drains) must hand out every column
/// exactly once.
#[test]
fn tile_scheduler_drain_claims_every_tile_exactly_once() {
    let len = 6usize;
    let res = check(&budget(2000, 1000), move || {
        let sched = Arc::new(TileScheduler::new(len, 2, 2));
        let workers: Vec<_> = (0..2usize)
            .map(|w| {
                let sched = Arc::clone(&sched);
                spawn(move || {
                    let mut mine = Vec::new();
                    while let Some(t) = sched.claim(w) {
                        mine.push(t);
                    }
                    mine
                })
            })
            .collect();
        let mut seen = vec![0u32; len];
        for h in workers {
            for t in h.join() {
                for c in t.lo..t.hi {
                    seen[c] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "drain not exactly-once: {seen:?}");
        assert_eq!(sched.remaining(), 0);
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

fn data_env(src: usize, seq: u64) -> Envelope {
    Envelope { src, dst: 2, packet: Packet::Data { seq, msg: Message::Alive { term: seq } } }
}

/// Cluster mailbox + dedup handoff (`cluster::net`): the mailbox is the
/// seam a real socket transport would replace, so its push/drain locking
/// is explored here with two concurrent senders — one of which replays a
/// sequence number, exactly what the lossy wire's duplicates and the
/// reliable link's retransmissions produce — racing a draining receiver.
/// No envelope may be lost, the `DedupFilter` must pass each `(src,
/// seq)` to the application exactly once, and per-source arrival order
/// must survive the concurrent drains.
#[test]
fn cluster_mailbox_reliable_link_delivers_exactly_once() {
    let res = check(&budget(1200, 600), || {
        let mbox = Arc::new(Mailbox::new());
        let senders: Vec<_> = (0..2usize)
            .map(|src| {
                let mbox = Arc::clone(&mbox);
                spawn(move || {
                    for seq in 0..2u64 {
                        mbox.push(data_env(src, seq));
                    }
                    if src == 1 {
                        // wire-level duplicate of an already-sent packet
                        mbox.push(data_env(src, 0));
                    }
                })
            })
            .collect();
        let receiver = {
            let mbox = Arc::clone(&mbox);
            // bounded drains racing the pushes; leftovers are swept
            // below once every sender joined
            spawn(move || {
                let mut got = Vec::new();
                for _ in 0..4 {
                    got.extend(mbox.drain());
                }
                got
            })
        };
        for s in senders {
            s.join();
        }
        let mut got = receiver.join();
        got.extend(mbox.drain());
        assert!(mbox.is_empty(), "everything pushed must be drained");
        assert_eq!(got.len(), 5, "no envelope may be lost: {}", got.len());

        // receiver-side dedup, as ReliableLink::poll applies it
        let mut dedup = DedupFilter::new(2);
        let mut accepted = Vec::new();
        let mut replays = 0usize;
        for env in &got {
            let Packet::Data { seq, .. } = &env.packet else {
                panic!("only data packets were sent");
            };
            if dedup.accept(env.src, *seq) {
                accepted.push((env.src, *seq));
            } else {
                replays += 1;
            }
        }
        assert_eq!(replays, 1, "exactly the one replayed packet is filtered");
        for src in 0..2usize {
            let seqs: Vec<u64> =
                accepted.iter().filter(|(s, _)| *s == src).map(|&(_, q)| q).collect();
            assert_eq!(seqs, vec![0, 1], "src {src}: per-source order lost: {accepted:?}");
        }
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

/// Mirror of `WorkerPool`'s generation-stamped job handoff, built from
/// the same shim primitives (`sync::Mutex` + `sync::Condvar`).  The
/// real pool spawns its OS workers in `new()`, outside the model's
/// reach; what the model explores here is the protocol itself —
/// publish-under-lock, the generation stamp, the DoneGuard drain and
/// the panic capture path, shaped exactly like `threadpool/pool.rs`.
struct PoolMirror {
    state: Mutex<MirrorState>,
    start: Condvar,
    done: Condvar,
}

struct MirrorState {
    job: u64,
    generation: u64,
    remaining: usize,
    shutdown: bool,
    panics: usize,
}

/// Decrements `remaining` on every exit path, like the pool's guard.
struct DoneGuard<'a>(&'a PoolMirror);

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap_or_else(|e| e.into_inner());
        st.remaining -= 1;
        if st.remaining == 0 {
            self.0.done.notify_all();
        }
    }
}

fn mirror_worker(shared: Arc<PoolMirror>, id: usize) -> Vec<u64> {
    let mut seen_gen = 0u64;
    let mut seen = Vec::new();
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.shutdown {
                    return seen;
                }
                if st.generation != seen_gen {
                    seen_gen = st.generation;
                    break st.job;
                }
                st = shared.start.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        seen.push(job);
        let _done = DoneGuard(shared.as_ref());
        let result = catch_unwind(|| {
            if id == 1 && job == 2 {
                panic!("[mc] simulated job panic");
            }
        });
        if result.is_err() {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.panics += 1;
        }
    }
}

/// WorkerPool handoff: the publisher must never lose a job (a worker
/// missing a generation) or double-publish (a worker running one job
/// twice), and a panicking job must neither hang the publisher's drain
/// nor kill its worker.
#[test]
fn worker_pool_handoff_never_loses_or_double_runs_a_job() {
    quiet_expected_panics();
    let res = check(&budget(1200, 600), || {
        let shared = Arc::new(PoolMirror {
            state: Mutex::new(MirrorState {
                job: 0,
                generation: 0,
                remaining: 0,
                shutdown: false,
                panics: 0,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let workers: Vec<_> = (0..2usize)
            .map(|id| {
                let shared = Arc::clone(&shared);
                spawn(move || mirror_worker(shared, id))
            })
            .collect();
        for job in 1..=2u64 {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.job = job;
            st.generation = st.generation.wrapping_add(1);
            st.remaining = 2;
            shared.start.notify_all();
            while st.remaining > 0 {
                st = shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
            shared.start.notify_all();
        }
        for h in workers {
            assert_eq!(h.join(), vec![1, 2], "worker lost or re-ran a job");
        }
        let st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
        assert_eq!(st.panics, 1, "the simulated job panic is captured exactly once");
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

/// CounterBarrier: generations keep advancing — no deadlock in any
/// interleaving — even when one participant's per-round work panics
/// (caught, as WorkerPool jobs are) before it reaches the barrier.
#[test]
fn counter_barrier_generations_survive_a_panicking_participant() {
    quiet_expected_panics();
    let res = check(&budget(1200, 600), || {
        let bar = Arc::new(CounterBarrier::new(2));
        let parts: Vec<_> = (0..2usize)
            .map(|id| {
                let bar = Arc::clone(&bar);
                spawn(move || {
                    let mut leads = 0usize;
                    for round in 0..3u32 {
                        let _ = catch_unwind(|| {
                            if id == 1 && round == 1 {
                                panic!("[mc] simulated participant panic");
                            }
                        });
                        if bar.wait() {
                            leads += 1;
                        }
                    }
                    leads
                })
            })
            .collect();
        let total: usize = parts.into_iter().map(|h| h.join()).sum();
        assert_eq!(total, 3, "exactly one leader per round");
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

/// SpinBarrier: no thread escapes into round `r + 1` before every
/// participant finished round `r`, under every schedule.
#[test]
fn spin_barrier_rounds_stay_in_lockstep() {
    let res = check(&budget(2000, 1000), || {
        let bar = Arc::new(SpinBarrier::new(2));
        let phase = Arc::new(AtomicUsize::new(0));
        let parts: Vec<_> = (0..2usize)
            .map(|_| {
                let bar = Arc::clone(&bar);
                let phase = Arc::clone(&phase);
                spawn(move || {
                    for round in 0..2usize {
                        assert_eq!(
                            phase.load(SeqCst) / 2,
                            round,
                            "a thread escaped the barrier early"
                        );
                        phase.fetch_add(1, SeqCst);
                        bar.wait();
                    }
                })
            })
            .collect();
        for h in parts {
            h.join();
        }
        assert_eq!(phase.load(SeqCst), 4);
    });
    let report = must_pass(res);
    assert!(
        report.executions > 1000,
        "expected >1000 interleavings, explored {}",
        report.executions
    );
}

/// The bug the packed-word GapMemory fixed (and the reason this harness
/// exists): value and stamp as two independent atomics.
struct TornPair {
    value: AtomicU32,
    stamp: AtomicU32,
}

/// Injected ordering bug: publishing the pair as two separate stores
/// must be caught by the explorer, with a failure a human can act on —
/// the message names the torn pair and the trace lists the schedule.
#[test]
fn injected_split_publication_bug_yields_a_readable_trace() {
    quiet_expected_panics();
    let failure = check(&budget(2000, 1000), || {
        let p = Arc::new(TornPair {
            value: AtomicU32::new(fval(0).to_bits()),
            stamp: AtomicU32::new(0),
        });
        let writer = {
            let p = Arc::clone(&p);
            spawn(move || {
                // BUG under test: two stores instead of one packed word.
                p.value.store(fval(1).to_bits(), Relaxed);
                p.stamp.store(1, Relaxed);
            })
        };
        let reader = {
            let p = Arc::clone(&p);
            spawn(move || {
                let gap = f32::from_bits(p.value.load(Relaxed));
                let stamp = p.stamp.load(Relaxed);
                if stamp != 0 {
                    assert!(gap == fval(stamp), "[mc] torn pair: stamp {stamp} gap {gap}");
                }
            })
        };
        writer.join();
        reader.join();
    })
    .expect_err("split publication must produce a torn pair");
    assert!(failure.message.contains("torn pair"), "got: {}", failure.message);
    let shown = failure.to_string();
    assert!(shown.contains("interleaving trace"), "got: {shown}");
    assert!(
        failure.trace.iter().any(|line| line.contains(".store")),
        "trace must record the stores that led to the tear: {:?}",
        failure.trace
    );
}

/// Explorer self-test: a two-thread, one-op-each scenario is small
/// enough that the DFS must exhaust its whole schedule space.
#[test]
fn tiny_scenario_is_explored_to_completion() {
    let res = check(&Config::default(), || {
        let c = Arc::new(AtomicUsize::new(0));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let c = Arc::clone(&c);
                spawn(move || c.fetch_add(1, SeqCst))
            })
            .collect();
        let sum: usize = hs.into_iter().map(|h| h.join()).sum();
        assert_eq!(sum, 1, "fetch_add must return 0 and 1 in some order");
        assert_eq!(c.load(SeqCst), 2);
    });
    let report = must_pass(res);
    assert!(report.complete, "tiny scenario must exhaust its schedule space");
    assert!(report.executions >= 2, "at least two distinct schedules exist");
}
