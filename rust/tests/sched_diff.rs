//! Differential tests for the shard-pinned tile scheduler
//! (`sched::TileScheduler`): a scheduled multi-worker tile sweep must
//! produce exactly the same work as the serial reference that walks the
//! scheduler's tile decomposition in order — the same set of columns
//! touched exactly once, and *bitwise*-equal `dots_block` values per
//! tile, because stealing and claim order only permute whole tiles and
//! each tile's blocked pass is deterministic for a fixed backend.
//!
//! Runs over all three matrix representations; the CI kernel matrix
//! additionally runs this file under every `RUST_PALLAS_KERNELS`
//! setting, so the bitwise claim is checked per backend.

use hthc::coordinator::task_a::{self, ASnapshot};
use hthc::coordinator::GapMemory;
use hthc::data::{DenseMatrix, Matrix, QuantizedMatrix, SparseMatrix};
use hthc::glm::{GlmModel, Lasso};
use hthc::kernels::{BLOCK_COLS, QGROUP};
use hthc::memory::{Tier, TierSim};
use hthc::sched::TileScheduler;
use hthc::threadpool::WorkerPool;
use hthc::util::Rng;
use std::sync::atomic::{AtomicU32, Ordering};

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

/// One matrix per representation over the same shape: rows straddle the
/// kernel cache band (4096) and stay `QGROUP`-aligned for the quantized
/// path; the column count is deliberately not a multiple of
/// `BLOCK_COLS` or of any worker count used below, so shards and tiles
/// are ragged.
fn matrices(rng: &mut Rng) -> Vec<(&'static str, Matrix)> {
    let d = 4096 + 2 * QGROUP;
    let n = 6 * BLOCK_COLS + 5;
    let dm = DenseMatrix::from_col_major(d, n, randvec(rng, d * n));
    let qm = QuantizedMatrix::from_dense(&dm);
    let mut cols: Vec<Vec<(u32, f32)>> = Vec::new();
    for j in 0..n {
        // mix of empty, short and long columns
        let nnz = [0usize, 1, 9, 250, 3000][j % 5];
        let mut col: Vec<(u32, f32)> = rng
            .sample_distinct(d, nnz)
            .into_iter()
            .map(|r| (r as u32, rng.normal()))
            .collect();
        col.sort_unstable_by_key(|&(r, _)| r);
        cols.push(col);
    }
    let sm = SparseMatrix::from_columns(d, cols);
    vec![
        ("dense", Matrix::Dense(dm)),
        ("quantized", Matrix::Quantized(qm)),
        ("sparse", Matrix::Sparse(sm)),
    ]
}

/// The scheduler's exact tile decomposition, shard-major in claim
/// order: `[lo + k*tile, min(lo + (k+1)*tile, hi))` per shard.  Both
/// `claim` and `claim_cyclic` hand out precisely these tiles.
fn tiles_of(sched: &TileScheduler) -> Vec<(usize, usize)> {
    let tile = sched.tile_cols();
    let mut out = Vec::new();
    for s in 0..sched.n_shards() {
        let (lo, hi) = sched.shard_bounds(s);
        let mut a = lo;
        while a < hi {
            let b = (a + tile).min(hi);
            out.push((a, b));
            a = b;
        }
    }
    out
}

#[test]
fn scheduled_tile_sweep_is_bitwise_equal_to_the_serial_reference() {
    let mut rng = Rng::new(71001);
    for (label, m) in matrices(&mut rng) {
        let ops = m.as_block_ops();
        let n = m.n_cols();
        let w = randvec(&mut rng, m.n_rows());
        for &workers in &[1usize, 3] {
            let sched = TileScheduler::new(n, workers, BLOCK_COLS);
            // serial reference: walk the same tiles in deterministic order
            let mut reference = vec![0u32; n];
            for &(lo, hi) in &tiles_of(&sched) {
                let idx: Vec<usize> = (lo..hi).collect();
                let mut u = vec![0.0f32; idx.len()];
                ops.dots_block(&idx, &w, &mut u);
                for (&j, &uj) in idx.iter().zip(&u) {
                    reference[j] = uj.to_bits();
                }
            }
            // scheduled: a pool drains the claims (stealing included)
            let slots: Vec<AtomicU32> =
                (0..n).map(|_| AtomicU32::new(f32::NAN.to_bits())).collect();
            let touched: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            let pool = WorkerPool::with_name(workers, "sched-diff");
            pool.run(|tid| {
                let tile = sched.tile_cols();
                let mut idx = vec![0usize; tile];
                let mut u = vec![0.0f32; tile];
                while let Some(t) = sched.claim(tid) {
                    let len = t.len();
                    for (slot, j) in idx[..len].iter_mut().zip(t.lo..t.hi) {
                        *slot = j;
                    }
                    ops.dots_block(&idx[..len], &w, &mut u[..len]);
                    for (&j, &uj) in idx[..len].iter().zip(&u[..len]) {
                        slots[j].store(uj.to_bits(), Ordering::Relaxed);
                        touched[j].fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
            for j in 0..n {
                assert_eq!(
                    touched[j].load(Ordering::Relaxed),
                    1,
                    "{label} workers={workers}: column {j} must be claimed exactly once"
                );
                assert_eq!(
                    slots[j].load(Ordering::Relaxed),
                    reference[j],
                    "{label} workers={workers}: column {j} must match bitwise"
                );
            }
        }
    }
}

#[test]
fn run_fixed_refresh_set_and_gap_values_match_the_serial_reference() {
    let mut rng = Rng::new(71002);
    for (label, m) in matrices(&mut rng) {
        let ops = m.as_block_ops();
        let n = m.n_cols();
        let w = randvec(&mut rng, m.n_rows());
        let alpha = randvec(&mut rng, n);
        let kind = Lasso::new(0.1).kind();
        // a distinct shuffled subset: with duplicates "exactly the given
        // set" would be ambiguous (last tile to refresh a repeat wins)
        let mut coords: Vec<usize> = (0..n).step_by(2).collect();
        rng.shuffle(&mut coords);
        let pool = WorkerPool::with_name(3, "sched-diff");
        let sim = TierSim::default();
        let gaps = GapMemory::new(n);
        let snap = ASnapshot { w: &w, alpha: &alpha, kind, epoch: 1 };
        task_a::run_fixed(&pool, &m, &snap, &gaps, &coords, &sim, Tier::Slow);

        // serial reference replicating run_fixed's internal decomposition:
        // tiles are index ranges into `coords`
        let sched = TileScheduler::new(coords.len(), pool.len().max(1), BLOCK_COLS);
        let mut want = vec![f32::INFINITY; n];
        let mut refreshed = vec![false; n];
        for &(lo, hi) in &tiles_of(&sched) {
            let blk = &coords[lo..hi];
            let mut u = vec![0.0f32; blk.len()];
            ops.dots_block(blk, &w, &mut u);
            for (&j, &uj) in blk.iter().zip(&u) {
                want[j] = kind.gap(uj, alpha[j]);
                refreshed[j] = true;
            }
        }
        for j in 0..n {
            let got = gaps.read(j);
            if refreshed[j] {
                assert_eq!(
                    got.to_bits(),
                    want[j].to_bits(),
                    "{label}: column {j} gap must match the reference bitwise"
                );
            } else {
                assert!(
                    !got.is_finite(),
                    "{label}: column {j} was not in the sweep but got refreshed"
                );
            }
        }
        let (updates, frac) = gaps.refresh_stats(1);
        assert_eq!(updates, coords.len() as u64, "{label}: one refresh per coordinate");
        assert!((frac - coords.len() as f64 / n as f64).abs() < 1e-9, "{label}");
    }
}

#[test]
fn cyclic_claims_rotate_through_the_exact_tile_decomposition() {
    // claim_cyclic never drains, but one full rotation of a shard must
    // cover each of that shard's tiles exactly once — this is what
    // makes run_epoch's stop-flag loop a full sweep given enough time.
    let n = 6 * BLOCK_COLS + 5;
    for &workers in &[1usize, 2, 4] {
        let sched = TileScheduler::new(n, workers, BLOCK_COLS);
        for s in 0..sched.n_shards() {
            let (lo, hi) = sched.shard_bounds(s);
            let tile = sched.tile_cols();
            let mut expect = Vec::new();
            let mut a = lo;
            while a < hi {
                let b = (a + tile).min(hi);
                expect.push((a, b));
                a = b;
            }
            let mut seen = Vec::new();
            for _ in 0..expect.len() {
                let t = sched.claim_cyclic(s).expect("cyclic never drains");
                assert_eq!(t.shard, s, "cyclic claims stay shard-pinned");
                seen.push((t.lo, t.hi));
            }
            seen.sort_unstable();
            assert_eq!(seen, expect, "shard {s}: one rotation covers each tile once");
            // and the next lap stays inside the same tile set
            for _ in 0..expect.len() {
                let t = sched.claim_cyclic(s).expect("cyclic never drains");
                assert!(expect.contains(&(t.lo, t.hi)), "lap 2 repeats the tile set");
            }
        }
    }
}
