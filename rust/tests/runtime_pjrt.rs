//! End-to-end tests over the real PJRT artifacts: the L1 Pallas kernels
//! and L2 jax graphs, lowered to HLO text by `make artifacts`, executed
//! from rust, and cross-checked against the native `glm` math.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built — run `make artifacts` first for full coverage.

use hthc::coordinator::hthc::GapBackend;
use hthc::data::{ColumnOps, Dataset, DatasetKind, Family, Matrix};
use hthc::glm::{GlmModel, Lasso, Ridge, SvmDual};
use hthc::memory::TierSim;
use hthc::runtime::{ArgData, GapService, XlaRuntime};

fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
    Dataset::generated(kind, family, scale, seed)
}

fn runtime() -> Option<XlaRuntime> {
    let dir = hthc::runtime::default_artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match XlaRuntime::start(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // built without the `pjrt` feature (offline crate set)
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn gap_artifact_matches_native_math_all_models() {
    let Some(rt) = runtime() else { return };
    let (d, n) = (1024usize, 256usize);
    let mut rng = hthc::util::Rng::new(2024);
    let dmat: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect(); // row-major
    let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let alpha: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

    let models: Vec<(&str, Box<dyn GlmModel>)> = vec![
        ("lasso", Box::new(Lasso::new(0.1).with_lip_b(2.0))),
        ("ridge", Box::new(Ridge::new(0.7))),
        ("svm", Box::new(SvmDual::new(0.01, n))),
    ];
    for (name, model) in models {
        let kind = model.kind();
        let (lam, nn, lip_b) = match kind {
            hthc::glm::ModelKind::Lasso { lam, lip_b } => (lam, 0.0, lip_b),
            hthc::glm::ModelKind::Ridge { lam } => (lam, 0.0, 0.0),
            hthc::glm::ModelKind::Svm { .. } => (0.01, n as f32, 0.0),
            _ => unreachable!(),
        };
        let out = rt
            .run(
                &format!("gaps_{name}_1024x256"),
                vec![
                    ArgData::F32 { data: dmat.clone(), dims: vec![d, n] },
                    ArgData::F32 { data: w.clone(), dims: vec![d] },
                    ArgData::F32 { data: alpha.clone(), dims: vec![n] },
                    ArgData::ScalarF32(lam),
                    ArgData::ScalarF32(nn),
                    ArgData::ScalarF32(lip_b),
                ],
            )
            .expect("execute");
        let z = &out[0];
        assert_eq!(z.len(), n);
        // native reference: u_j = sum_r D[r,j] w[r]
        for j in (0..n).step_by(17) {
            let u: f32 = (0..d).map(|r| dmat[r * n + j] * w[r]).sum();
            let want = kind.gap(u, alpha[j]);
            let got = z[j];
            assert!(
                (got - want).abs() <= 2e-3 * want.abs().max(1.0),
                "{name} z[{j}]: pjrt {got} vs native {want}"
            );
        }
    }
}

#[test]
fn cd_epoch_artifact_matches_native_sequential_cd() {
    let Some(rt) = runtime() else { return };
    let (d, m) = (1024usize, 64usize);
    let mut rng = hthc::util::Rng::new(2025);
    let dmat: Vec<f32> = (0..d * m).map(|_| rng.normal()).collect(); // row-major
    let y: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let alpha0: Vec<f32> = vec![0.0; m];
    let v0: Vec<f32> = vec![0.0; d];
    let lam = 0.3f32;

    let out = rt
        .run(
            "cd_epoch_lasso_1024x64",
            vec![
                ArgData::F32 { data: dmat.clone(), dims: vec![d, m] },
                ArgData::F32 { data: v0.clone(), dims: vec![d] },
                ArgData::F32 { data: alpha0.clone(), dims: vec![m] },
                ArgData::F32 { data: y.clone(), dims: vec![d] },
                ArgData::ScalarF32(lam),
                ArgData::ScalarF32(m as f32),
            ],
        )
        .expect("execute");
    let (v_pjrt, a_pjrt) = (&out[0], &out[1]);

    // native replay (exact sequential CD, the task-B T_B=1 oracle)
    let kind = Lasso::new(lam).kind();
    let mut v = v0;
    let mut a = alpha0;
    for j in 0..m {
        let u: f32 = (0..d)
            .map(|r| dmat[r * m + j] * kind.w_of(v[r], y[r]))
            .sum();
        let sq: f32 = (0..d).map(|r| dmat[r * m + j].powi(2)).sum();
        let delta = kind.delta(u, a[j], sq);
        if delta != 0.0 {
            a[j] += delta;
            for r in 0..d {
                v[r] += delta * dmat[r * m + j];
            }
        }
    }
    for j in 0..m {
        assert!(
            (a_pjrt[j] - a[j]).abs() < 5e-3 * a[j].abs().max(1.0),
            "alpha[{j}]: {} vs {}",
            a_pjrt[j],
            a[j]
        );
    }
    let vmax = v.iter().fold(0.0f32, |mx, x| mx.max(x.abs())).max(1.0);
    for r in (0..d).step_by(13) {
        assert!(
            (v_pjrt[r] - v[r]).abs() < 5e-3 * vmax,
            "v[{r}]: {} vs {}",
            v_pjrt[r],
            v[r]
        );
    }
}

#[test]
fn q4_artifact_runs_and_is_close_to_fp32() {
    let Some(rt) = runtime() else { return };
    let (d, n) = (1024usize, 256usize);
    let qg = 64; // QGROUP on both sides
    let mut rng = hthc::util::Rng::new(2026);
    // build packed codes directly: code c in [-8,7], nibble-packed
    let mut packed = vec![0u8; d / 2 * n];
    let mut scales = vec![0f32; d / qg * n];
    let mut dense = vec![0f32; d * n]; // row-major dequantized truth
    for j in 0..n {
        for g in 0..d / qg {
            let scale = 0.05 + rng.f32() * 0.2;
            scales[g * n + j] = scale;
            for k in 0..qg {
                let r = g * qg + k;
                let code = (rng.below(16) as i32) - 8;
                dense[r * n + j] = code as f32 * scale;
                let b = (code + 8) as u8;
                // packed layout (d/2, n) row-major: byte (r/2, j)
                let idx = (r / 2) * n + j;
                if r % 2 == 0 {
                    packed[idx] |= b;
                } else {
                    packed[idx] |= b << 4;
                }
            }
        }
    }
    let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let alpha = vec![0.25f32; n];
    let (lam, lip_b) = (0.1f32, 1.5f32);
    let out = rt
        .run(
            "gaps_q4_lasso_1024x256",
            vec![
                ArgData::U8 { data: packed, dims: vec![d / 2, n] },
                ArgData::F32 { data: scales, dims: vec![d / qg, n] },
                ArgData::F32 { data: w.clone(), dims: vec![d] },
                ArgData::F32 { data: alpha.clone(), dims: vec![n] },
                ArgData::ScalarF32(lam),
                ArgData::ScalarF32(n as f32),
                ArgData::ScalarF32(lip_b),
            ],
        )
        .expect("execute q4");
    let z = &out[0];
    let kind = Lasso::new(lam).with_lip_b(lip_b).kind();
    for j in (0..n).step_by(31) {
        let u: f32 = (0..d).map(|r| dense[r * n + j] * w[r]).sum();
        let want = kind.gap(u, alpha[j]);
        assert!(
            (z[j] - want).abs() <= 5e-3 * want.abs().max(1.0),
            "z[{j}]: {} vs {}",
            z[j],
            want
        );
    }
}

#[test]
fn gap_service_backend_matches_native_task_a() {
    let Some(rt) = runtime() else { return };
    let service = GapService::new(&rt);
    let g = generate(DatasetKind::EpsilonLike, Family::Regression, 0.15, 77);
    let (d, n) = (g.d(), g.n());
    assert!(d <= 1024, "pick scale so the small artifact fits: d={d}");
    let mut rng = hthc::util::Rng::new(7);
    let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let alpha: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let model = Lasso::new(0.05).with_lip_b(1.3);
    let kind = model.kind();
    let coords: Vec<usize> = (0..service.block_len().min(n)).map(|k| (k * 3) % n).collect();
    let z = service
        .batch_gaps(g.matrix(), &coords, &w, &alpha, kind)
        .expect("dense lasso must offload");
    let ops = g.as_ops();
    for (i, &j) in coords.iter().enumerate() {
        let want = kind.gap(ops.dot(j, &w), alpha[j]);
        assert!(
            (z[i] - want).abs() <= 2e-3 * want.abs().max(1.0),
            "coord {j}: {} vs {}",
            z[i],
            want
        );
    }
}

#[test]
fn gap_service_sparse_ell_offload_matches_native() {
    let Some(rt) = runtime() else { return };
    let service = GapService::new(&rt);
    // news20-like at a scale where d <= 2048 and col nnz <= 128
    let g = generate(DatasetKind::News20Like, Family::Regression, 0.06, 79);
    let Matrix::Sparse(sm) = g.matrix() else { panic!("sparse expected") };
    assert!(sm.n_rows() <= 2048, "d = {}", sm.n_rows());
    let d = sm.n_rows();
    let mut rng = hthc::util::Rng::new(17);
    let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let alpha: Vec<f32> = (0..g.n()).map(|_| rng.normal() * 0.1).collect();
    let kind = Lasso::new(0.02).with_lip_b(1.1).kind();
    // pick coords whose nnz fits the k_max = 128 budget
    let coords: Vec<usize> = (0..g.n()).filter(|&j| sm.nnz(j) <= 128).take(200).collect();
    assert!(!coords.is_empty());
    let z = service
        .batch_gaps(g.matrix(), &coords, &w, &alpha, kind)
        .expect("ELL offload must engage");
    for (i, &j) in coords.iter().enumerate() {
        let want = kind.gap(sm.dot(j, &w), alpha[j]);
        assert!(
            (z[i] - want).abs() <= 2e-3 * want.abs().max(1.0),
            "coord {j}: {} vs {}",
            z[i],
            want
        );
    }
    // a block containing an over-budget column must fall back (None)
    if let Some(big) = (0..g.n()).find(|&j| sm.nnz(j) > 128) {
        let mut coords2 = coords.clone();
        coords2[0] = big;
        assert!(service.batch_gaps(g.matrix(), &coords2, &w, &alpha, kind).is_none());
    }
}

#[test]
fn hthc_training_with_pjrt_backend_converges() {
    let Some(rt) = runtime() else { return };
    let service = GapService::new(&rt);
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 88);
    let mut model = Lasso::new(0.5);
    let obj0 = model.objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; g.n()]);
    let sim = TierSim::default();
    let res = hthc::solver::Trainer::new()
        .solver(hthc::solver::Hthc::with_backend(&service))
        .config(hthc::coordinator::HthcConfig {
            t_a: 1,
            t_b: 2,
            v_b: 1,
            batch_frac: 0.25,
            gap_tol: 1e-3 * obj0.abs().max(1.0),
            max_epochs: 4000,
            eval_every: 5,
            timeout_secs: 60.0,
            use_pjrt_gaps: true,
            ..Default::default()
        })
        .fit_with(&mut model, &g, &sim);
    assert!(res.converged, "{}", res.summary());
    assert!(res.a_updates() > 0, "backend path must be exercised");
    // v consistency preserved end-to-end
    let v2 = match g.matrix() {
        Matrix::Dense(m) => m.matvec_alpha(&res.alpha),
        _ => unreachable!(),
    };
    for (a, b) in res.v.iter().zip(&v2) {
        assert!((a - b).abs() < 1e-2 * b.abs().max(1.0));
    }
}

#[test]
fn runtime_rejects_bad_shapes_cleanly() {
    let Some(rt) = runtime() else { return };
    // wrong arg count
    assert!(rt.run("gaps_lasso_1024x256", vec![]).is_err());
    // wrong dims
    let bad = rt.run(
        "gaps_lasso_1024x256",
        vec![
            ArgData::F32 { data: vec![0.0; 10], dims: vec![10] },
            ArgData::F32 { data: vec![0.0; 1024], dims: vec![1024] },
            ArgData::F32 { data: vec![0.0; 256], dims: vec![256] },
            ArgData::ScalarF32(0.1),
            ArgData::ScalarF32(0.0),
            ArgData::ScalarF32(1.0),
        ],
    );
    assert!(bad.is_err());
    // unknown artifact
    assert!(rt.run("nonexistent", vec![]).is_err());
}
