//! LIBSVM parser edge cases: what the hardened parser must tolerate
//! (comments, blank lines, stray whitespace/CRLF, out-of-order feature
//! indices) and what it must reject with a line number (malformed
//! pairs, 0-based or duplicate indices, non-numeric fields) — both
//! through the raw `libsvm::read` parser and through the
//! `DatasetBuilder::path` pipeline that real callers use.

use hthc::data::{libsvm, DatasetBuilder, Family};

fn err_of(input: &str) -> String {
    format!("{}", libsvm::read(input.as_bytes()).unwrap_err())
}

// ---------------------------------------------------------------------------
// tolerated inputs
// ---------------------------------------------------------------------------

#[test]
fn comments_blank_lines_and_whitespace_are_tolerated() {
    let input = "\
# full-line comment
+1 1:0.5 3:1.5   # trailing comment

   \t
-1 2:2.0\t4:0.25\x20\x20
";
    let s = libsvm::read(input.as_bytes()).unwrap();
    assert_eq!(s.len(), 2);
    assert_eq!(s[0].features, vec![(0, 0.5), (2, 1.5)]);
    assert_eq!(s[1].features, vec![(1, 2.0), (3, 0.25)]);
}

#[test]
fn crlf_line_endings_are_tolerated() {
    let s = libsvm::read("+1 1:1.0\r\n-1 2:2.0\r\n".as_bytes()).unwrap();
    assert_eq!(s.len(), 2);
    assert_eq!(s[1].features, vec![(1, 2.0)]);
}

#[test]
fn out_of_order_indices_are_sorted_on_ingest() {
    let s = libsvm::read("+1 9:9.0 2:2.0 5:5.0".as_bytes()).unwrap();
    assert_eq!(s[0].features, vec![(1, 2.0), (4, 5.0), (8, 9.0)]);
}

#[test]
fn signed_and_scientific_values_parse() {
    let s = libsvm::read("-1.5 1:-3e-2 2:+4.0".as_bytes()).unwrap();
    assert_eq!(s[0].label, -1.5);
    assert_eq!(s[0].features, vec![(0, -0.03), (1, 4.0)]);
}

// ---------------------------------------------------------------------------
// rejected inputs, with line numbers
// ---------------------------------------------------------------------------

#[test]
fn duplicate_feature_indices_error_with_line_number() {
    // duplicates adjacent and after reordering both trip the check
    let e = err_of("+1 1:1.0\n-1 3:1.0 3:2.0");
    assert!(e.contains("line 2"), "{e}");
    assert!(e.contains("duplicate feature index 3"), "{e}");

    let e = err_of("+1 7:1.0 2:0.5 7:2.0");
    assert!(e.contains("line 1") && e.contains("duplicate"), "{e}");
}

#[test]
fn zero_based_index_errors_with_line_number() {
    let e = err_of("+1 1:1.0\n\n+1 0:1.0");
    assert!(e.contains("line 3"), "{e}");
    assert!(e.contains("1-based"), "{e}");
}

#[test]
fn malformed_pairs_error_with_line_number() {
    for (input, line) in [
        ("+1 abc", "line 1"),
        ("+1 1:1.0\n-1 2:", "line 2"),
        ("+1 1:1.0\n-1 :5", "line 2"),
        ("+1 1:1.0\n+1 2:2.0\n-1 x:1", "line 3"),
        ("nolabel", "line 1"),
    ] {
        let e = err_of(input);
        assert!(e.contains(line), "{input:?}: {e}");
    }
}

// comment lines must not advance the error line numbering incorrectly
#[test]
fn line_numbers_count_physical_lines() {
    let e = err_of("# header\n# more\n+1 0:1");
    assert!(e.contains("line 3"), "{e}");
}

// ---------------------------------------------------------------------------
// through the builder pipeline (the path real callers use)
// ---------------------------------------------------------------------------

fn write_temp(name: &str, content: &str) -> std::path::PathBuf {
    let path = std::env::temp_dir().join(format!("hthc-libsvm-{}-{name}", std::process::id()));
    std::fs::write(&path, content).unwrap();
    path
}

#[test]
fn builder_loads_messy_but_valid_libsvm() {
    let path = write_temp(
        "ok.txt",
        "# tiny classification set\n+1 3:0.9 1:1.2\n\n-1 2:0.5 # neg\n+1 2:1.1\r\n",
    );
    let ds = DatasetBuilder::path(&path)
        .family(Family::Classification)
        .build()
        .unwrap();
    std::fs::remove_file(&path).ok();
    // classification orientation: coordinates = samples
    assert_eq!(ds.n_cols(), 3);
    assert_eq!(ds.n_rows(), 3); // max feature index
    assert_eq!(ds.labels().unwrap(), &[1.0, -1.0, 1.0]);
}

#[test]
fn builder_surfaces_parse_errors_with_file_and_line() {
    let path = write_temp("bad.txt", "+1 1:1.0\n+1 4:4.0 4:5.0\n");
    let err = DatasetBuilder::path(&path).build().unwrap_err();
    let msg = format!("{err}");
    std::fs::remove_file(&path).ok();
    assert!(msg.contains("line 2"), "{msg}");
    assert!(msg.contains("duplicate"), "{msg}");
}

#[test]
fn builder_regression_orientation_from_file() {
    let path = write_temp("reg.txt", "0.5 1:1.0 2:2.0\n-0.25 2:1.0\n");
    let ds = DatasetBuilder::path(&path).build().unwrap();
    std::fs::remove_file(&path).ok();
    // regression orientation: rows = samples, columns = features
    assert_eq!(ds.n_rows(), 2);
    assert_eq!(ds.n_cols(), 2);
    assert_eq!(ds.targets(), &[0.5, -0.25]);
}
