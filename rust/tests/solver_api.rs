//! Tests for the unified `solver` API: CLI parity (`hthc train` flags
//! and builder calls must assemble the same `Trainer`), a smoke matrix
//! running every `Solver` impl through one shared harness, and the
//! Trainer-level features (warm starts, epoch callbacks) that the
//! redesign made engine-agnostic.

use hthc::baselines::PasscodeMode;
use hthc::coordinator::Selection;
use hthc::data::{Dataset, DatasetKind, Family};
use hthc::glm::Lasso;
use hthc::memory::TierSim;
use hthc::solver::{
    by_name, cli, Hthc, Omp, Passcode, SeqThreshold, Sgd, Solver, StopWhen, Trainer,
};
use hthc::util::Args;

fn args(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(|t| t.to_string()))
}

/// Every dataset in this suite goes through the one builder pipeline.
fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
    Dataset::generated(kind, family, scale, seed)
}

// ---------------------------------------------------------------------------
// CLI parity
// ---------------------------------------------------------------------------

/// The flags accepted by `hthc train` must build exactly the Trainer the
/// builder calls produce — one source of truth for the configuration.
#[test]
fn cli_flags_match_builder_calls() {
    let cli_trainer = cli::trainer_from_args(&args(
        "--solver st --t-a 3 --t-b 2 --v-b 2 --batch 0.1 --selection random \
         --tol 1e-4 --epochs 77 --timeout 9 --eval-every 3 --seed 7",
    ))
    .unwrap();
    let built = Trainer::new()
        .solver(SeqThreshold)
        .threads(3, 2, 2)
        .batch_frac(0.1)
        .selection(Selection::Random)
        .seed(7)
        .stop_when(
            StopWhen::gap_below(1e-4)
                .max_epochs(77)
                .timeout_secs(9.0)
                .eval_every(3),
        );
    assert_eq!(cli_trainer.cfg(), built.cfg());
    assert_eq!(cli_trainer.solver_ref().name(), built.solver_ref().name());
}

#[test]
fn cli_defaults_match_builder_defaults() {
    let cli_trainer = cli::trainer_from_args(&args("")).unwrap();
    let built = Trainer::new();
    assert_eq!(cli_trainer.cfg(), built.cfg());
    assert_eq!(cli_trainer.solver_ref().name(), built.solver_ref().name());
}

#[test]
fn cli_solver_flag_selects_every_engine() {
    for (flag, want) in [
        ("hthc", "hthc"),
        ("st", "st"),
        ("omp", "omp"),
        ("omp-wild", "omp-wild"),
        ("passcode", "passcode-atomic"),
        ("passcode-wild", "passcode-wild"),
        ("sgd", "sgd"),
    ] {
        let t = cli::trainer_from_args(&args(&format!("--solver {flag}"))).unwrap();
        assert_eq!(t.solver_ref().name(), want, "--solver {flag}");
    }
}

// ---------------------------------------------------------------------------
// Solver matrix smoke: every engine through one shared harness
// ---------------------------------------------------------------------------

/// Every `Solver` impl runs on the tiny problem through the same
/// harness and returns a well-formed `FitReport`.
#[test]
fn solver_matrix_smoke() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 4001);
    let engines: Vec<Box<dyn Solver>> = vec![
        Box::new(Hthc::new()),
        Box::new(SeqThreshold),
        Box::new(Omp { wild: false }),
        Box::new(Omp { wild: true }),
        Box::new(Passcode { mode: PasscodeMode::Atomic }),
        Box::new(Passcode { mode: PasscodeMode::Wild }),
        Box::new(Sgd::default()),
    ];
    for engine in engines {
        let name = engine.name();
        let sim = TierSim::default();
        let mut model = Lasso::new(0.3);
        let res = Trainer::new()
            .solver_boxed(engine)
            .threads(1, 2, 1)
            .batch_frac(0.5)
            .stop_when(
                StopWhen::gap_below(0.0)
                    .max_epochs(3)
                    .timeout_secs(20.0)
                    .eval_every(1),
            )
            .fit_with(&mut model, &g, &sim);
        assert_eq!(res.solver, name, "report is tagged with the engine");
        assert!(res.epochs >= 1, "{name}: must run");
        assert!(!res.trace.points.is_empty(), "{name}: must trace");
        assert_eq!(res.alpha.len(), g.n(), "{name}: iterate length");
        assert_eq!(res.v.len(), g.d(), "{name}: v length");
        assert!(res.alpha.iter().all(|a| a.is_finite()), "{name}: finite");
        // the report's summary renders without panicking
        let _ = res.summary();
    }
}

/// `by_name` and the struct construction paths agree.
#[test]
fn by_name_matches_structs() {
    for name in ["hthc", "st", "omp", "omp-wild", "passcode-atomic", "passcode-wild", "sgd"] {
        assert_eq!(by_name(name).unwrap().name(), name);
    }
}

// ---------------------------------------------------------------------------
// Trainer-level features, engine-agnostic
// ---------------------------------------------------------------------------

/// Warm-starting from a converged iterate must make the next run's
/// first evaluation at least as good as a cold run's.
#[test]
fn warm_start_resumes_from_prior_iterate() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 4002);
    let sim = TierSim::default();
    let stop = StopWhen::gap_below(0.0).max_epochs(40).eval_every(1).timeout_secs(20.0);

    let mut model = Lasso::new(0.3);
    let first = Trainer::new()
        .threads(1, 1, 1)
        .stop_when(stop)
        .fit_with(&mut model, &g, &sim);
    let first_final = first.trace.final_objective().unwrap();
    let first_initial = first.trace.points.first().unwrap().objective;
    assert!(first_final < first_initial);

    let mut model2 = Lasso::new(0.3);
    let resumed = Trainer::new()
        .threads(1, 1, 1)
        .stop_when(StopWhen::gap_below(0.0).max_epochs(2).eval_every(1).timeout_secs(20.0))
        .warm_start(first.alpha.clone())
        .fit_with(&mut model2, &g, &sim);
    let resumed_first = resumed.trace.points.first().unwrap().objective;
    assert!(
        resumed_first <= first_final * 1.01 + 1e-9,
        "warm start must begin near the previous optimum: {resumed_first} vs {first_final}"
    );
}

/// Warm start works on the baselines too (they previously always
/// cold-started).
#[test]
fn warm_start_on_st_baseline() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 4003);
    let sim = TierSim::default();
    let mut model = Lasso::new(0.3);
    let run = |warm: Option<Vec<f32>>, model: &mut Lasso| {
        let mut t = Trainer::new()
            .solver(SeqThreshold)
            .threads(1, 1, 1)
            .stop_when(StopWhen::gap_below(0.0).max_epochs(25).eval_every(1).timeout_secs(20.0));
        if let Some(a) = warm {
            t = t.warm_start(a);
        }
        t.fit_with(model, &g, &sim)
    };
    let first = run(None, &mut model);
    let mut model2 = Lasso::new(0.3);
    let resumed = run(Some(first.alpha.clone()), &mut model2);
    let cold_initial = first.trace.points.first().unwrap().objective;
    let warm_initial = resumed.trace.points.first().unwrap().objective;
    assert!(
        warm_initial < cold_initial,
        "warm ST start must beat the cold start: {warm_initial} vs {cold_initial}"
    );
}

/// The per-epoch callback fires on every engine and can stop the run.
#[test]
fn on_epoch_callback_stops_any_engine() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 4004);
    let engines: Vec<Box<dyn Solver>> = vec![
        Box::new(Hthc::new()),
        Box::new(SeqThreshold),
        Box::new(Omp { wild: false }),
        Box::new(Passcode { mode: PasscodeMode::Atomic }),
        Box::new(Sgd::default()),
    ];
    for engine in engines {
        let name = engine.name();
        let sim = TierSim::default();
        let mut model = Lasso::new(0.3);
        let mut seen = 0usize;
        let res = Trainer::new()
            .solver_boxed(engine)
            .threads(1, 2, 1)
            .stop_when(
                StopWhen::gap_below(0.0).max_epochs(500).eval_every(1).timeout_secs(30.0),
            )
            .on_epoch(|ev| {
                assert_eq!(ev.solver, name);
                assert!(ev.epoch >= 1);
                seen += 1;
                seen >= 2 // stop after the second evaluation
            })
            .fit_with(&mut model, &g, &sim);
        assert!(res.converged, "{name}: callback stop marks convergence");
        assert!(res.epochs <= 4, "{name}: stopped early ({} epochs)", res.epochs);
    }
}

/// Shared stopping rules: the epoch cap binds every engine.
#[test]
fn epoch_cap_binds_every_engine() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 4005);
    for name in ["hthc", "st", "omp", "passcode-atomic", "sgd"] {
        let sim = TierSim::default();
        let mut model = Lasso::new(0.3);
        let res = Trainer::new()
            .solver_boxed(by_name(name).unwrap())
            .threads(1, 1, 1)
            .batch_frac(0.5)
            .stop_when(StopWhen::gap_below(0.0).max_epochs(2).eval_every(1).timeout_secs(20.0))
            .fit_with(&mut model, &g, &sim);
        assert_eq!(res.epochs, 2, "{name}");
        assert!(!res.converged, "{name}: gap_tol 0 must not converge");
    }
}
