//! Differential tests for the blocked multi-column sweep backend
//! (`kernels::dots_block` family + the `data::BlockOps` trait): the
//! blocked path must agree with the per-column dot path on every
//! backend, across adversarial shapes — empty column blocks, B = 1,
//! block counts that are not a multiple of the register tile, row
//! counts that straddle the cache-band boundary, duplicate and
//! reversed column lists, and degenerate (empty / zero) columns.
//!
//! Tolerances follow `kernel_diff.rs`: blocked traversal only changes
//! the summation order, so blocked and per-column results differ by at
//! most the usual `C·n·eps·Σ|term|` forward-error bound (the scalar
//! backend is defined to be bitwise identical to the per-column path
//! and is asserted as such).

use hthc::data::{BlockOps, ColumnOps, DenseMatrix, QuantizedMatrix, SparseMatrix};
use hthc::kernels::{self, Backend, BLOCK_COLS, QGROUP};
use hthc::util::Rng;

/// `C·n·eps·Σ|term|` summation bound (+ tiny absolute floor for n=0).
fn sum_bound(n: usize, sum_abs: f64) -> f64 {
    8.0 * (n.max(1) as f64) * (f32::EPSILON as f64) * sum_abs + 1e-30
}

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Row counts around the kernel's cache-band boundary (4096) and the
/// usual lane-width adversaries.
const ROWS: &[usize] = &[1, 7, 33, 1000, 4096, 4100];

/// Column-block sizes: empty, B=1, sub-tile, exact tile, tile+1, and a
/// non-multiple-of-BLOCK_COLS tail.
const NCOLS: &[usize] = &[0, 1, 3, BLOCK_COLS, BLOCK_COLS + 1, 2 * BLOCK_COLS + 3];

// ---------------------------------------------------------------------------
// Kernel level: explicit backends
// ---------------------------------------------------------------------------

#[test]
fn dense_blocked_matches_per_column_on_all_backends() {
    let mut rng = Rng::new(11001);
    for &d in ROWS {
        for &nc in NCOLS {
            let cols: Vec<Vec<f32>> = (0..nc).map(|_| randvec(&mut rng, d)).collect();
            let w = randvec(&mut rng, d);
            let slices: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
            for back in kernels::available_backends() {
                let mut out = vec![0.0f32; nc];
                kernels::dots_block_with(back, &slices, &w, &mut out);
                for (k, col) in cols.iter().enumerate() {
                    let per_col = kernels::dot_with(back, col, &w);
                    if back == Backend::Scalar {
                        // scalar blocked is *defined* as the per-column
                        // reference — bitwise, not just close
                        assert_eq!(
                            out[k].to_bits(),
                            per_col.to_bits(),
                            "scalar blocked must be bitwise per-column (d={d} k={k})"
                        );
                    }
                    let want: f64 = col.iter().zip(&w).map(|(&x, &y)| x as f64 * y as f64).sum();
                    let sum_abs: f64 =
                        col.iter().zip(&w).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
                    assert!(
                        (out[k] as f64 - want).abs() <= sum_bound(d, sum_abs),
                        "d={d} nc={nc} k={k} [{}]: {} vs {want}",
                        back.name(),
                        out[k]
                    );
                }
            }
        }
    }
}

#[test]
fn sparse_blocked_matches_per_column_on_all_backends() {
    let mut rng = Rng::new(11002);
    let d = 5000; // > one row band
    let w = randvec(&mut rng, d);
    let sparse_col = |rng: &mut Rng, nnz: usize| -> (Vec<u32>, Vec<f32>) {
        let mut rows: Vec<u32> =
            rng.sample_distinct(d, nnz).into_iter().map(|r| r as u32).collect();
        rows.sort_unstable();
        let vals = randvec(rng, nnz);
        (rows, vals)
    };
    // degenerate and banded-adversarial columns: empty, single entry at
    // each extreme, all entries inside one band, entries spanning bands,
    // zero values on live indices
    let cols: Vec<(Vec<u32>, Vec<f32>)> = vec![
        (vec![], vec![]),
        (vec![0], vec![2.0]),
        (vec![d as u32 - 1], vec![-3.0]),
        (vec![17, 40, 99], vec![0.0, 0.0, 0.0]),
        ((0..64u32).collect(), randvec(&mut rng, 64)),
        sparse_col(&mut rng, 7),
        sparse_col(&mut rng, 333),
        sparse_col(&mut rng, 2500),
        sparse_col(&mut rng, 1),
    ];
    let slices: Vec<(&[u32], &[f32])> =
        cols.iter().map(|(r, v)| (r.as_slice(), v.as_slice())).collect();
    for back in kernels::available_backends() {
        let mut out = vec![0.0f32; slices.len()];
        kernels::sparse_dots_block_with(back, &slices, &w, &mut out);
        for (k, (rows, vals)) in cols.iter().enumerate() {
            let per_col = kernels::sparse_dot_with(back, rows, vals, &w);
            if back == Backend::Scalar {
                assert_eq!(out[k].to_bits(), per_col.to_bits(), "scalar blocked k={k}");
            }
            let want: f64 = rows
                .iter()
                .zip(vals)
                .map(|(&r, &x)| x as f64 * w[r as usize] as f64)
                .sum();
            let sum_abs: f64 = rows
                .iter()
                .zip(vals)
                .map(|(&r, &x)| (x as f64 * w[r as usize] as f64).abs())
                .sum();
            assert!(
                (out[k] as f64 - want).abs() <= sum_bound(rows.len(), sum_abs),
                "k={k} nnz={} [{}]: {} vs {want}",
                rows.len(),
                back.name(),
                out[k]
            );
        }
    }
}

#[test]
fn quant_blocked_matches_per_column_on_all_backends() {
    let mut rng = Rng::new(11003);
    // spans the band boundary (4096 rows = 64 groups) plus a tail band
    for &groups in &[1usize, 3, 64, 65] {
        let d = groups * QGROUP;
        let nc = BLOCK_COLS + 1;
        let dm = DenseMatrix::from_col_major(d, nc, randvec(&mut rng, d * nc));
        let qm = QuantizedMatrix::from_dense(&dm);
        let w = randvec(&mut rng, d);
        let slices: Vec<(&[u8], &[f32])> = (0..nc).map(|j| qm.col_packed(j)).collect();
        for back in kernels::available_backends() {
            let mut out = vec![0.0f32; nc];
            kernels::quant_dots_block_with(back, &slices, &w, &mut out);
            for k in 0..nc {
                let (packed, scales) = qm.col_packed(k);
                let per_col = kernels::quant_dot_range_with(back, packed, scales, &w, 0, d);
                if back == Backend::Scalar {
                    assert_eq!(out[k].to_bits(), per_col.to_bits(), "scalar blocked k={k}");
                }
                let deq = qm.col_dense(k);
                let want: f64 = deq.iter().zip(&w).map(|(&x, &y)| x as f64 * y as f64).sum();
                let sum_abs: f64 =
                    deq.iter().zip(&w).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
                assert!(
                    (out[k] as f64 - want).abs() <= 2.0 * sum_bound(d, sum_abs),
                    "groups={groups} k={k} [{}]: {} vs {want}",
                    back.name(),
                    out[k]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// BlockOps level: the dispatched trait path every bulk consumer uses
// ---------------------------------------------------------------------------

/// Column lists the consumers actually produce: contiguous blocks,
/// shuffled claims, duplicates (task A's random blocks), reversed, and
/// the empty/B=1/tail shapes.
fn adversarial_col_lists(n: usize) -> Vec<Vec<usize>> {
    let mut lists = vec![
        vec![],
        vec![n / 2],
        (0..n).collect::<Vec<_>>(),
        (0..n).rev().collect::<Vec<_>>(),
        (0..n.min(BLOCK_COLS + 3)).collect::<Vec<_>>(),
    ];
    lists.push(vec![0; BLOCK_COLS.min(n)]); // duplicates
    lists.push((0..n).step_by(3).collect::<Vec<_>>()); // strided tail
    lists
}

/// `col_of(j)` materializes column j densely (dequantized/densified) so
/// the reference and the `Σ|term|` bound are computed in f64 regardless
/// of representation.
fn assert_blockops_matches_per_column(
    ops: &dyn BlockOps,
    w: &[f32],
    col_of: &dyn Fn(usize) -> Vec<f32>,
    label: &str,
) {
    let n = ops.n_cols();
    let d = ops.n_rows();
    for cols in adversarial_col_lists(n) {
        let mut out = vec![0.0f32; cols.len()];
        ops.dots_block(&cols, w, &mut out);
        for (k, &j) in cols.iter().enumerate() {
            let dense = col_of(j);
            let want: f64 = dense.iter().zip(w).map(|(&x, &y)| x as f64 * y as f64).sum();
            let sum_abs: f64 =
                dense.iter().zip(w).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let per_col = ops.dot(j, w) as f64;
            let tol = 2.0 * sum_bound(d, sum_abs);
            assert!(
                (out[k] as f64 - want).abs() <= tol,
                "{label}: col {j} (slot {k}): blocked {} vs reference {want}",
                out[k]
            );
            assert!(
                (out[k] as f64 - per_col).abs() <= 2.0 * tol,
                "{label}: col {j} (slot {k}): blocked {} vs per-column {per_col}",
                out[k]
            );
        }
    }
}

#[test]
fn blockops_dense_sparse_quantized_agree_with_per_column_dot() {
    let mut rng = Rng::new(11004);
    let d = 4160; // spans the row band
    let n = 2 * BLOCK_COLS + 3;

    let dm = DenseMatrix::from_col_major(d, n, randvec(&mut rng, d * n));
    let w = randvec(&mut rng, d);
    assert_blockops_matches_per_column(&dm, &w, &|j| dm.col(j).to_vec(), "dense");

    let qm = QuantizedMatrix::from_dense(&dm);
    assert_blockops_matches_per_column(&qm, &w, &|j| qm.col_dense(j), "quantized");

    let mut cols: Vec<Vec<(u32, f32)>> = Vec::new();
    for j in 0..n {
        // mix of empty, short and long columns
        let nnz = [0usize, 1, 5, 200, 2000][j % 5];
        let mut col: Vec<(u32, f32)> = rng
            .sample_distinct(d, nnz)
            .into_iter()
            .map(|r| (r as u32, rng.normal()))
            .collect();
        col.sort_unstable_by_key(|&(r, _)| r);
        cols.push(col);
    }
    let sm = SparseMatrix::from_columns(d, cols);
    assert_blockops_matches_per_column(&sm, &w, &|j| sm.col_dense(j), "sparse");
}

/// The trait's default body is the documented per-column fallback: a
/// representation that does not override `dots_block` must get results
/// identical to its own `dot`.
#[test]
fn blockops_default_impl_is_the_per_column_fallback() {
    struct Plain(DenseMatrix);
    impl ColumnOps for Plain {
        fn n_rows(&self) -> usize {
            self.0.n_rows()
        }
        fn n_cols(&self) -> usize {
            self.0.n_cols()
        }
        fn dot(&self, col: usize, w: &[f32]) -> f32 {
            self.0.dot(col, w)
        }
        fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
            self.0.dot_range(col, w, lo, hi)
        }
        fn axpy(&self, col: usize, delta: f32, v: &mut [f32]) {
            self.0.axpy(col, delta, v)
        }
        fn sq_norm(&self, col: usize) -> f32 {
            self.0.sq_norm(col)
        }
        fn nnz(&self, col: usize) -> usize {
            self.0.nnz(col)
        }
        fn col_bytes(&self, col: usize) -> u64 {
            self.0.col_bytes(col)
        }
    }
    impl BlockOps for Plain {} // default dots_block

    let mut rng = Rng::new(11005);
    let (d, n) = (257, BLOCK_COLS + 2);
    let p = Plain(DenseMatrix::from_col_major(d, n, randvec(&mut rng, d * n)));
    let w = randvec(&mut rng, d);
    let cols: Vec<usize> = (0..n).rev().collect();
    let mut out = vec![0.0f32; n];
    p.dots_block(&cols, &w, &mut out);
    for (k, &j) in cols.iter().enumerate() {
        assert_eq!(out[k].to_bits(), p.dot(j, &w).to_bits(), "fallback col {j}");
    }
}
