//! Concurrent bounded-ingest stress (ISSUE 8).
//!
//! Hammers a capped [`IngestBuffer`] with producer threads racing a
//! drainer, and a [`RetainedCorpus`] under each retention policy, and
//! checks the memory invariants hold at every observation point:
//!
//! * the buffer never holds more than its cap, no matter how far the
//!   producers outrun the drainer;
//! * conservation: `pushed == drained + dropped + buffered` — no sample
//!   is lost untracked and none is double-counted;
//! * the reservoir retains *exactly* `cap` samples once saturated, and
//!   `retained + evicted == offered` for every policy.
//!
//! Run with `--nocapture` under each `RUST_PALLAS_KERNELS` backend in
//! the CI kernel matrix — the serving counters must be
//! backend-independent.

use hthc::data::Sample;
use hthc::serve::{IngestBuffer, RetainedCorpus, RetentionPolicy};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

fn tagged(producer: usize, k: usize) -> Sample {
    Sample {
        label: (producer * 1_000_000 + k) as f32,
        features: vec![(0, 1.0)],
    }
}

/// Producers race a drainer on a capped buffer; the cap holds at every
/// observation and the conservation law balances exactly at the end.
#[test]
fn concurrent_capped_buffer_conserves_and_never_overflows() {
    const CAP: usize = 64;
    const PRODUCERS: usize = 4;
    const BATCHES: usize = 200;
    const BATCH: usize = 9; // deliberately not a divisor of CAP

    let buf = Arc::new(IngestBuffer::bounded(CAP));
    let drained = AtomicU64::new(0);
    let done = AtomicBool::new(false);

    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let buf = Arc::clone(&buf);
            s.spawn(move || {
                for b in 0..BATCHES {
                    let batch: Vec<Sample> =
                        (0..BATCH).map(|k| tagged(p, b * BATCH + k)).collect();
                    buf.push_many(batch);
                    // the cap must hold at every interleaving, not just
                    // at quiescence
                    assert!(buf.len() <= CAP, "buffer {} exceeded cap {CAP}", buf.len());
                }
            });
        }
        // drainer: what a Refitter's cadence loop does, minus the fit
        let buf2 = Arc::clone(&buf);
        let drained = &drained;
        let done = &done;
        s.spawn(move || {
            while !done.load(Relaxed) {
                drained.fetch_add(buf2.drain().len() as u64, Relaxed);
                assert!(buf2.len() <= CAP);
                std::thread::yield_now();
            }
        });
        // wait for every producer push, then stop the drainer
        while buf.total() < (PRODUCERS * BATCHES * BATCH) as u64 {
            std::thread::yield_now();
        }
        done.store(true, Relaxed);
    });

    let pushed = buf.total();
    assert_eq!(pushed, (PRODUCERS * BATCHES * BATCH) as u64);
    let buffered = buf.len() as u64;
    assert!(buffered <= CAP as u64);
    assert_eq!(
        pushed,
        drained.load(Relaxed) + buf.dropped() + buffered,
        "conservation: pushed == drained + dropped + buffered \
         (drained {}, dropped {}, buffered {buffered})",
        drained.load(Relaxed),
        buf.dropped(),
    );
    // 4 producers x 1800 pushes against a 64-slot buffer must actually
    // exercise backpressure, or this test proves nothing
    assert!(buf.dropped() > 0, "stress run never hit the cap");
}

/// An unbounded buffer under the same race obeys the degenerate law
/// (dropped == 0) — the default path stays loss-free.
#[test]
fn concurrent_unbounded_buffer_drops_nothing() {
    const PRODUCERS: usize = 4;
    const PUSHES: usize = 500;
    let buf = Arc::new(IngestBuffer::new());
    let drained = AtomicU64::new(0);
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let buf = Arc::clone(&buf);
            s.spawn(move || {
                for k in 0..PUSHES {
                    buf.push(tagged(p, k));
                }
            });
        }
        let buf2 = Arc::clone(&buf);
        let drained = &drained;
        s.spawn(move || {
            for _ in 0..50 {
                drained.fetch_add(buf2.drain().len() as u64, Relaxed);
                std::thread::yield_now();
            }
        });
    });
    assert_eq!(buf.dropped(), 0);
    assert_eq!(
        buf.total(),
        drained.load(Relaxed) + buf.len() as u64,
        "unbounded conservation"
    );
    assert_eq!(buf.total(), (PRODUCERS * PUSHES) as u64);
}

/// Every policy preserves `retained + evicted == offered`, and capped
/// policies never retain past their cap (reservoir: exactly cap once
/// saturated).
#[test]
fn retention_policies_balance_offered_against_evicted() {
    const CAP: usize = 33;
    const OFFERS: usize = 1000;
    for policy in [
        RetentionPolicy::KeepAll,
        RetentionPolicy::Reservoir { cap: CAP },
        RetentionPolicy::SlidingWindow { cap: CAP },
    ] {
        let mut corpus = RetainedCorpus::new(Vec::new(), policy, 7);
        for k in 0..OFFERS {
            // mixed single offers and batches, like refit drains
            if k % 7 == 0 {
                corpus.offer_many(vec![tagged(0, k), tagged(1, k)]);
            } else {
                corpus.offer(tagged(0, k));
            }
            if let Some(cap) = policy.cap() {
                assert!(
                    corpus.len() <= cap,
                    "{policy:?} retained {} past cap {cap}",
                    corpus.len()
                );
                assert!(corpus.peak() <= cap);
            }
            assert_eq!(
                corpus.len() as u64 + corpus.evicted(),
                corpus.seen(),
                "{policy:?} leaked samples at offer {k}"
            );
        }
        match policy {
            RetentionPolicy::KeepAll => {
                assert_eq!(corpus.evicted(), 0);
                assert_eq!(corpus.len() as u64, corpus.seen());
            }
            RetentionPolicy::Reservoir { cap } | RetentionPolicy::SlidingWindow { cap } => {
                assert_eq!(corpus.len(), cap, "{policy:?} not saturated at exactly cap");
                assert!(corpus.has_evicted());
            }
        }
    }
}

/// The drain → offer pipeline (exactly what `Refitter::refit_once`
/// runs) keeps both ends bounded when producers race it.
#[test]
fn drain_into_corpus_stays_bounded_under_race() {
    const BUF_CAP: usize = 48;
    const CORPUS_CAP: usize = 100;
    let buf = Arc::new(IngestBuffer::bounded(BUF_CAP));
    let mut corpus = RetainedCorpus::new(
        (0..CORPUS_CAP).map(|k| tagged(9, k)).collect(),
        RetentionPolicy::Reservoir { cap: CORPUS_CAP },
        11,
    );
    assert_eq!(corpus.len(), CORPUS_CAP, "base fills the reservoir exactly");

    let mut absorbed = 0u64;
    std::thread::scope(|s| {
        for p in 0..3 {
            let buf = Arc::clone(&buf);
            s.spawn(move || {
                for k in 0..400 {
                    buf.push(tagged(p, k));
                }
            });
        }
        for _ in 0..200 {
            let fresh = buf.drain();
            absorbed += fresh.len() as u64;
            corpus.offer_many(fresh);
            assert!(corpus.len() <= CORPUS_CAP);
            assert!(buf.len() <= BUF_CAP);
            std::thread::yield_now();
        }
    });
    // final drain after producers stop
    let fresh = buf.drain();
    absorbed += fresh.len() as u64;
    corpus.offer_many(fresh);

    assert_eq!(buf.total(), 3 * 400);
    assert_eq!(buf.total(), absorbed + buf.dropped(), "drain-side conservation");
    assert_eq!(corpus.seen(), CORPUS_CAP as u64 + absorbed);
    assert_eq!(corpus.len(), CORPUS_CAP, "reservoir holds exactly cap");
    assert_eq!(corpus.peak(), CORPUS_CAP);
}
