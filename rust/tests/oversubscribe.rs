//! Thread-oversubscription tests: the scheduler and the full HTHC loop
//! must make progress (no deadlock, no starvation, no lost tiles) when
//! the configured thread counts exceed the host's cores.  CI runs this
//! file on purpose with `t_a`/`t_b` above the runner's core count; the
//! worker counts below are derived from the *detected* core count so
//! the 4x factor oversubscribes on any machine.

use hthc::coordinator::{host_threads, HthcConfig};
use hthc::data::{DatasetBuilder, DatasetKind, Family};
use hthc::glm::Lasso;
use hthc::kernels::BLOCK_COLS;
use hthc::memory::TierSim;
use hthc::sched::TileScheduler;
use hthc::solver::Trainer;
use hthc::threadpool::WorkerPool;
use std::sync::atomic::{AtomicU32, Ordering};

#[test]
fn drain_is_exactly_once_with_4x_host_core_workers() {
    let cores = host_threads().unwrap_or(2);
    let workers = (4 * cores).max(8);
    let n = workers * 3 * BLOCK_COLS + 5; // ragged tail, ~3 tiles/shard
    let sched = TileScheduler::new(n, workers, BLOCK_COLS);
    let touched: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let pool = WorkerPool::with_name(workers, "oversub");
    pool.run(|tid| {
        while let Some(t) = sched.claim(tid) {
            for j in t.lo..t.hi {
                touched[j].fetch_add(1, Ordering::Relaxed);
            }
        }
    });
    for j in 0..n {
        assert_eq!(touched[j].load(Ordering::Relaxed), 1, "column {j} claimed exactly once");
    }
    assert_eq!(sched.remaining(), 0, "drain must exhaust every shard");
}

#[test]
fn hthc_fit_completes_oversubscribed() {
    // t_a + t_b * v_b = 19 threads: far above any CI runner we use.
    // validate() warns (never rejects) and the fit must still finish —
    // the tile scheduler and task B's group barrier may not deadlock
    // when the OS timeslices the oversubscribed pools arbitrarily.
    let cfg = HthcConfig {
        t_a: 9,
        t_b: 5,
        v_b: 2,
        max_epochs: 6,
        eval_every: 3,
        gap_tol: 0.0, // never converges: runs all 6 epochs
        timeout_secs: 60.0,
        ..Default::default()
    };
    assert!(
        cfg.oversubscription_warning(4).is_some(),
        "19 threads on a 4-core budget must warn"
    );
    cfg.validate();
    let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(7301)
        .build()
        .unwrap();
    let mut model = Lasso::new(0.4);
    let sim = TierSim::default();
    let res = Trainer::new().config(cfg).fit_with(&mut model, &g, &sim);
    assert!(res.epochs >= 1, "oversubscribed fit must make progress: {}", res.summary());
    assert!(
        res.b_updates() > 0,
        "task B must process coordinates under oversubscription"
    );
}

#[test]
fn clamped_config_fits_the_reported_budget() {
    let cfg = HthcConfig { t_a: 9, t_b: 5, v_b: 2, ..Default::default() };
    for budget in [1usize, 2, 4, 8, 16] {
        let c = cfg.clamped_to(budget);
        assert!(c.t_a >= 1 && c.t_b >= 1 && c.v_b >= 1);
        // either the clamp fits the budget or it bottomed out at the
        // (1, 1, 1) floor (budget 1 cannot be met: the floor needs 2)
        assert!(
            c.total_threads() <= budget || (c.t_a, c.t_b, c.v_b) == (1, 1, 1),
            "clamp to {budget} left {} threads",
            c.total_threads()
        );
    }
}
