//! Failure injection: malformed inputs, degenerate data, adversarial
//! configurations.  Everything must error cleanly or train robustly —
//! never panic from library internals, never emit NaN iterates.

use hthc::coordinator::HthcConfig;
use hthc::data::{
    libsvm, Dataset, DatasetBuilder, DatasetKind, DenseMatrix, Family, Matrix, SparseMatrix,
};
use hthc::glm::{GlmModel, Lasso, Ridge};
use hthc::memory::TierSim;
use hthc::solver::{FitReport, Trainer};
use hthc::util::Rng;

/// Every dataset here goes through the builder pipeline
/// (`Dataset::from_parts` is the in-memory spelling of it).
fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
    Dataset::generated(kind, family, scale, seed)
}

/// HTHC via the unified facade (the adversarial suite targets the
/// default engine).
fn fit_hthc(cfg: HthcConfig, model: &mut dyn GlmModel, ds: &Dataset) -> FitReport {
    let sim = TierSim::default();
    Trainer::new().config(cfg).fit_with(model, ds, &sim)
}

// ---------------------------------------------------------------------------
// libsvm parser fuzz
// ---------------------------------------------------------------------------

#[test]
fn libsvm_fuzz_never_panics() {
    let mut rng = Rng::new(7001);
    let tokens = [
        "+1", "-1", "0", "1:1.0", "2:-3.5", "abc", "1:", ":5", "1:1:1", "#x",
        "999999999999:1", "3:nan", "3:inf", "-1e30", "\t", "1:0x10",
    ];
    for _ in 0..500 {
        let lines = (0..rng.below(6))
            .map(|_| {
                (0..rng.below(8))
                    .map(|_| tokens[rng.below(tokens.len())])
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect::<Vec<_>>()
            .join("\n");
        // must return Ok or Err — never panic
        let _ = libsvm::read(lines.as_bytes());
    }
}

#[test]
fn libsvm_nan_inf_values_are_rejected_with_the_line() {
    // rust f32 happily parses "nan"/"inf", but one such entry poisons
    // every downstream norm and dot — the strict parser names the line.
    let err = libsvm::read("+1 1:0.5\n+1 1:inf 2:nan".as_bytes()).unwrap_err();
    assert!(format!("{err}").contains("line 2"), "{err}");
    let err = libsvm::read("nan 1:0.5".as_bytes()).unwrap_err();
    assert!(format!("{err}").contains("line 1"), "{err}");
    // the escape hatch still parses them as plain floats
    let s = libsvm::read_with("+1 1:inf 2:nan".as_bytes(), false).unwrap();
    assert!(s[0].features[0].1.is_infinite());
    assert!(s[0].features[1].1.is_nan());
    // and the builder pipeline has the same gate + hatch for parsed
    // samples (coordinate-attributed, since line numbers are gone)
    let bad = vec![libsvm::Sample { label: 1.0, features: vec![(0, f32::NAN)] }];
    let err = DatasetBuilder::libsvm_samples(bad.clone()).build().unwrap_err();
    assert!(format!("{err}").contains("non-finite"), "{err}");
    assert!(DatasetBuilder::libsvm_samples(bad).validate(false).build().is_ok());
}

// ---------------------------------------------------------------------------
// degenerate matrices
// ---------------------------------------------------------------------------

fn quick_cfg() -> HthcConfig {
    HthcConfig {
        t_a: 1,
        t_b: 2,
        v_b: 1,
        batch_frac: 0.5,
        gap_tol: 0.0,
        max_epochs: 30,
        eval_every: 10,
        timeout_secs: 20.0,
        ..Default::default()
    }
}

#[test]
fn constant_columns_and_duplicate_columns() {
    let d = 64;
    let mut rng = Rng::new(7002);
    let base: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let mut data = Vec::new();
    data.extend(std::iter::repeat(1.0f32).take(d)); // constant col
    data.extend(base.iter()); // col A
    data.extend(base.iter()); // exact duplicate of col A
    data.extend(base.iter().map(|x| -x)); // negated duplicate
    let ds = Dataset::from_parts(
        Matrix::Dense(DenseMatrix::from_col_major(d, 4, data)),
        (0..d).map(|_| rng.normal()).collect(),
    );
    let mut model = Lasso::new(0.05);
    let res = fit_hthc(quick_cfg(), &mut model, &ds);
    assert!(res.alpha.iter().all(|a| a.is_finite()));
    assert!(res.trace.final_objective().unwrap().is_finite());
}

#[test]
fn single_coordinate_problem() {
    let d = 32;
    let mut rng = Rng::new(7003);
    let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let ds = Dataset::from_parts(
        Matrix::Dense(DenseMatrix::from_col_major(d, 1, col.clone())),
        col.iter().map(|&x| 2.0 * x).collect(),
    );
    let mut model = Ridge::new(1e-4);
    let mut cfg = quick_cfg();
    cfg.batch_frac = 1.0;
    cfg.max_epochs = 50;
    let res = fit_hthc(cfg, &mut model, &ds);
    assert!((res.alpha[0] - 2.0).abs() < 0.05, "alpha {}", res.alpha[0]);
}

#[test]
fn empty_sparse_columns_everywhere() {
    let ds = Dataset::from_parts(
        Matrix::Sparse(SparseMatrix::from_columns(16, vec![vec![]; 8])),
        vec![1.0f32; 16],
    );
    let mut model = Lasso::new(0.1);
    let res = fit_hthc(quick_cfg(), &mut model, &ds);
    assert!(res.alpha.iter().all(|&a| a == 0.0), "nothing can move");
}

#[test]
fn extreme_regularization_is_stable() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 7004);
    for lam in [1e-12f32, 1e12] {
        let mut model = Lasso::new(lam);
        let res = fit_hthc(quick_cfg(), &mut model, &g);
        assert!(res.alpha.iter().all(|a| a.is_finite()), "lam={lam}");
        if lam > 1.0 {
            assert!(res.alpha.iter().all(|&a| a == 0.0), "huge lam kills all");
        }
    }
}

#[test]
fn huge_target_magnitudes_stay_finite() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 7005);
    let scaled = DatasetBuilder::in_memory(
        match g.matrix() {
            Matrix::Dense(dm) => Matrix::Dense(dm.clone()),
            _ => unreachable!("tiny is dense"),
        },
        g.targets().iter().map(|&t| t * 1e10).collect(),
    )
    .build()
    .unwrap();
    let mut model = Ridge::new(1.0);
    let res = fit_hthc(quick_cfg(), &mut model, &scaled);
    assert!(res.alpha.iter().all(|a| a.is_finite()));
    assert!(res.v.iter().all(|v| v.is_finite()));
}

// ---------------------------------------------------------------------------
// adversarial configurations
// ---------------------------------------------------------------------------

#[test]
fn more_threads_than_coordinates() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 7006);
    let mut cfg = quick_cfg();
    cfg.t_b = 8;
    cfg.v_b = 2;
    cfg.batch_frac = 0.02; // batch of ~1 coordinate, 16 B-threads
    let mut model = Lasso::new(0.1);
    let res = fit_hthc(cfg, &mut model, &g);
    assert!(res.epochs > 0);
}

#[test]
fn v_b_larger_than_rows() {
    let d = 8;
    let mut rng = Rng::new(7007);
    let data: Vec<f32> = (0..d * 4).map(|_| rng.normal()).collect();
    let ds = Dataset::from_parts(
        Matrix::Dense(DenseMatrix::from_col_major(d, 4, data)),
        (0..d).map(|_| rng.normal()).collect(),
    );
    let mut cfg = quick_cfg();
    cfg.t_b = 1;
    cfg.v_b = 16; // lanes get empty row ranges — must not deadlock
    cfg.batch_frac = 1.0;
    let mut model = Ridge::new(0.5);
    let res = fit_hthc(cfg, &mut model, &ds);
    assert!(res.trace.final_objective().unwrap().is_finite());
}

#[test]
fn lock_chunk_of_one_is_correct_if_slow() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 7008);
    let mut cfg = quick_cfg();
    cfg.lock_chunk = 1; // pathological: one mutex per element
    cfg.max_epochs = 10;
    let mut model = Lasso::new(0.2);
    let res = fit_hthc(cfg, &mut model, &g);
    // v = D alpha must still hold exactly
    let v2 = g.matvec_alpha(&res.alpha);
    for (a, b) in res.v.iter().zip(&v2) {
        assert!((a - b).abs() < 1e-2 * b.abs().max(1.0));
    }
}

#[test]
fn dataset_loading_rejects_garbage_gracefully() {
    // the builder's path source sniffs the format and must surface a
    // clean error for binary-magic garbage, truncation, and non-UTF8 /
    // non-LIBSVM text alike
    let dir = std::env::temp_dir();
    for (i, garbage) in [&b"HTHC"[..], &b"HTHC1\xFF"[..], &b"XXXXX\x01\x00"[..]]
        .iter()
        .enumerate()
    {
        let path = dir.join(format!("hthc-garbage-{}-{i}.bin", std::process::id()));
        std::fs::write(&path, garbage).unwrap();
        let res = DatasetBuilder::path(&path).build();
        std::fs::remove_file(&path).ok();
        assert!(res.is_err(), "garbage case {i} must error");
        assert!(hthc::data::io::load_model(*garbage).is_err());
    }
    // a missing file errors with context rather than panicking
    assert!(DatasetBuilder::path(dir.join("hthc-definitely-missing.bin"))
        .build()
        .is_err());
}
