//! Differential property tests for the `kernels` layer: every SIMD
//! backend must agree with the scalar reference within a summation
//! error bound, across adversarial shapes — empty slices, length 1,
//! non-multiple-of-lane lengths, unaligned `dot_range` sub-ranges,
//! degenerate sparse columns, and quantized group boundaries.
//!
//! Bound rationale (see rust/DESIGN.md §Kernels): any summation order
//! of `n` f32 terms has forward error at most `(n-1) eps Σ|term_i|`
//! (FMA only tightens it), so two orders differ by at most twice that.
//! The assertions use `C·n·eps·Σ|term|` with a small safety factor C
//! — a tight, shape-aware ULP-style bound rather than a loose fixed
//! tolerance.
//!
//! Runs under any `RUST_PALLAS_KERNELS` setting: explicit `_with`
//! entry points pin each backend, so scalar-vs-SIMD agreement is
//! checked regardless of what the dispatcher would pick (CI runs the
//! whole suite under both `scalar` and `simd` anyway).

use hthc::coordinator::SharedVector;
use hthc::data::{DenseMatrix, QuantizedMatrix};
use hthc::kernels::{self, Backend, QGROUP};
use hthc::util::Rng;

/// Adversarial lengths: empty, 1, around every lane width (4/8/16/32),
/// and the issue's non-multiples 7, 33, 1023.
const LENGTHS: &[usize] = &[
    0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 255, 256, 257, 1023,
    1024, 1025,
];

/// `C·n·eps·Σ|term|` summation bound (+ tiny absolute floor for n=0).
fn sum_bound(n: usize, sum_abs: f64) -> f64 {
    8.0 * (n.max(1) as f64) * (f32::EPSILON as f64) * sum_abs + 1e-30
}

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

// ---------------------------------------------------------------------------
// Dense kernels
// ---------------------------------------------------------------------------

#[test]
fn dot_backends_agree_across_lengths() {
    let mut rng = Rng::new(9001);
    for &n in LENGTHS {
        let a = randvec(&mut rng, n);
        let b = randvec(&mut rng, n);
        let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let sum_abs: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        let tol = sum_bound(n, sum_abs);
        let scalar = kernels::dot_with(Backend::Scalar, &a, &b) as f64;
        assert!((scalar - want).abs() <= tol, "scalar n={n}: {scalar} vs {want}");
        for back in kernels::available_backends() {
            let got = kernels::dot_with(back, &a, &b) as f64;
            assert!(
                (got - scalar).abs() <= 2.0 * tol,
                "n={n} [{}]: {got} vs scalar {scalar} (tol {tol:e})",
                back.name()
            );
        }
    }
}

#[test]
fn dot_range_unaligned_subranges_agree() {
    let mut rng = Rng::new(9002);
    let n = 1023;
    let a = randvec(&mut rng, n);
    let b = randvec(&mut rng, n);
    // deliberately lane-misaligned windows
    for &(lo, hi) in &[
        (0usize, 0usize),
        (0, 1),
        (1, 2),
        (1, n),
        (3, 7),
        (5, 38),
        (17, 1000),
        (511, 513),
        (1000, 1023),
    ] {
        let want: f64 = a[lo..hi]
            .iter()
            .zip(&b[lo..hi])
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        let sum_abs: f64 = a[lo..hi]
            .iter()
            .zip(&b[lo..hi])
            .map(|(&x, &y)| (x as f64 * y as f64).abs())
            .sum();
        let tol = sum_bound(hi - lo, sum_abs);
        for back in kernels::available_backends() {
            let got = kernels::dot_range_with(back, &a, &b, lo, hi) as f64;
            assert!(
                (got - want).abs() <= tol,
                "[{lo}, {hi}) [{}]: {got} vs {want}",
                back.name()
            );
        }
    }
}

#[test]
fn axpy_backends_agree_elementwise() {
    let mut rng = Rng::new(9003);
    for &n in LENGTHS {
        let x = randvec(&mut rng, n);
        let v0 = randvec(&mut rng, n);
        let delta = rng.normal();
        let mut scalar = v0.clone();
        kernels::axpy_with(Backend::Scalar, delta, &x, &mut scalar);
        for back in kernels::available_backends() {
            let mut got = v0.clone();
            kernels::axpy_with(back, delta, &x, &mut got);
            for (i, (&g, &s)) in got.iter().zip(&scalar).enumerate() {
                // per-element: FMA vs mul+add differ by ~0.5 ulp of the
                // *product*, which under cancellation (v0 ~ -delta*x)
                // dwarfs any bound on the result — include the term
                let term = (delta * x[i]).abs();
                let tol = 4.0 * f32::EPSILON * (g.abs() + s.abs() + term) + 1e-30;
                assert!(
                    (g - s).abs() <= tol,
                    "n={n} i={i} [{}]: {g} vs {s}",
                    back.name()
                );
            }
        }
    }
}

#[test]
fn sq_norm_backends_agree() {
    let mut rng = Rng::new(9004);
    for &n in LENGTHS {
        let x = randvec(&mut rng, n);
        let want: f64 = x.iter().map(|&v| v as f64 * v as f64).sum();
        let tol = sum_bound(n, want); // all terms nonnegative
        for back in kernels::available_backends() {
            let got = kernels::sq_norm_with(back, &x) as f64;
            assert!((got - want).abs() <= tol, "n={n} [{}]: {got} vs {want}", back.name());
        }
    }
}

#[test]
fn fused_dot_sq_norm_matches_separate_kernels() {
    let mut rng = Rng::new(9005);
    for &n in LENGTHS {
        let a = randvec(&mut rng, n);
        let b = randvec(&mut rng, n);
        let dot_abs: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        let nrm: f64 = a.iter().map(|&v| v as f64 * v as f64).sum();
        let dot_ref: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        for back in kernels::available_backends() {
            let (d, q) = kernels::dot_sq_norm_with(back, &a, &b);
            assert!(
                (d as f64 - dot_ref).abs() <= sum_bound(n, dot_abs),
                "fused dot n={n} [{}]",
                back.name()
            );
            assert!(
                (q as f64 - nrm).abs() <= sum_bound(n, nrm),
                "fused sq_norm n={n} [{}]",
                back.name()
            );
        }
    }
}

#[test]
fn f64_reductions_backends_agree() {
    // sq_err_f64 / sq_norm_f64 accumulate in f64, so the backend gap is
    // at f64 epsilon scale — bound with the f64 analogue of sum_bound
    let mut rng = Rng::new(9014);
    for &n in LENGTHS {
        let a = randvec(&mut rng, n);
        let b = randvec(&mut rng, n);
        let err_ref: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| {
                let r = (x - y) as f64;
                r * r
            })
            .sum();
        let nrm_ref: f64 = a.iter().map(|&x| x as f64 * x as f64).sum();
        let ftol = |sum: f64| 8.0 * (n.max(1) as f64) * f64::EPSILON * sum + 1e-300;
        for back in kernels::available_backends() {
            let e = kernels::sq_err_f64_with(back, &a, &b);
            let q = kernels::sq_norm_f64_with(back, &a);
            assert!((e - err_ref).abs() <= ftol(err_ref), "sq_err n={n} [{}]", back.name());
            assert!((q - nrm_ref).abs() <= ftol(nrm_ref), "sq_norm n={n} [{}]", back.name());
        }
    }
}

#[test]
fn map2_backends_are_bitwise_identical() {
    // the map applies f elementwise on every backend — only the loop
    // structure differs, so outputs must match exactly
    let mut rng = Rng::new(9015);
    for &n in LENGTHS {
        let a = randvec(&mut rng, n);
        let b = randvec(&mut rng, n);
        let f = |x: f32, y: f32| (x - y).clamp(-1.5, 1.5) * 0.25;
        let mut scalar = vec![0.0f32; n];
        kernels::map2_into_with(Backend::Scalar, &mut scalar, &a, &b, f);
        for back in kernels::available_backends() {
            let mut got = vec![0.0f32; n];
            kernels::map2_into_with(back, &mut got, &a, &b, f);
            for (i, (&g, &s)) in got.iter().zip(&scalar).enumerate() {
                assert!(g.to_bits() == s.to_bits(), "n={n} i={i} [{}]", back.name());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sparse kernels
// ---------------------------------------------------------------------------

/// Random sorted sparse column with `nnz` entries over `d` rows.
fn sparse_col(rng: &mut Rng, d: usize, nnz: usize) -> (Vec<u32>, Vec<f32>) {
    let mut rows: Vec<u32> = rng.sample_distinct(d, nnz).into_iter().map(|r| r as u32).collect();
    rows.sort_unstable();
    let vals = randvec(rng, nnz);
    (rows, vals)
}

#[test]
fn sparse_dot_backends_agree_adversarial_columns() {
    let mut rng = Rng::new(9006);
    let d = 4096;
    let w = randvec(&mut rng, d);
    // empty, single-nonzero, tiny, lane-odd, dense-ish
    let cases: Vec<(Vec<u32>, Vec<f32>)> = vec![
        (vec![], vec![]),
        (vec![17], vec![3.5]),
        (vec![d as u32 - 1], vec![-2.0]),
        sparse_col(&mut rng, d, 3),
        sparse_col(&mut rng, d, 7),
        sparse_col(&mut rng, d, 33),
        sparse_col(&mut rng, d, 1023),
        // all-zero values on live indices
        (vec![0, 5, 9], vec![0.0, 0.0, 0.0]),
    ];
    for (ci, (rows, vals)) in cases.iter().enumerate() {
        let want: f64 = rows
            .iter()
            .zip(vals)
            .map(|(&r, &x)| x as f64 * w[r as usize] as f64)
            .sum();
        let sum_abs: f64 = rows
            .iter()
            .zip(vals)
            .map(|(&r, &x)| (x as f64 * w[r as usize] as f64).abs())
            .sum();
        let tol = sum_bound(rows.len(), sum_abs);
        for back in kernels::available_backends() {
            let got = kernels::sparse_dot_with(back, rows, vals, &w) as f64;
            assert!(
                (got - want).abs() <= tol,
                "case {ci} nnz={} [{}]: {got} vs {want}",
                rows.len(),
                back.name()
            );
        }
    }
}

#[test]
fn sparse_axpy_backends_agree() {
    let mut rng = Rng::new(9007);
    let d = 2048;
    for &nnz in &[0usize, 1, 7, 33, 500] {
        let (rows, vals) = sparse_col(&mut rng, d, nnz);
        let v0 = randvec(&mut rng, d);
        let delta = rng.normal();
        let mut scalar = v0.clone();
        kernels::sparse_axpy_with(Backend::Scalar, &rows, &vals, delta, &mut scalar);
        // per-element scattered term magnitude (0 where no entry landed),
        // for the same cancellation-proof tolerance as the dense test
        let mut term = vec![0.0f32; d];
        for (&r, &x) in rows.iter().zip(&vals) {
            term[r as usize] = (delta * x).abs();
        }
        for back in kernels::available_backends() {
            let mut got = v0.clone();
            kernels::sparse_axpy_with(back, &rows, &vals, delta, &mut got);
            for (i, (&g, &s)) in got.iter().zip(&scalar).enumerate() {
                let tol = 4.0 * f32::EPSILON * (g.abs() + s.abs() + term[i]) + 1e-30;
                assert!((g - s).abs() <= tol, "nnz={nnz} i={i} [{}]", back.name());
            }
        }
    }
}

#[test]
fn pair_dot_backends_agree() {
    // SGD's interleaved (index, value) row format
    let mut rng = Rng::new(9013);
    let d = 512;
    let w = randvec(&mut rng, d);
    for &nnz in &[0usize, 1, 2, 3, 7, 33, 255] {
        let (rows, vals) = sparse_col(&mut rng, d, nnz);
        let row: Vec<(u32, f32)> = rows.iter().copied().zip(vals.iter().copied()).collect();
        let want: f64 = row.iter().map(|&(j, x)| x as f64 * w[j as usize] as f64).sum();
        let sum_abs: f64 = row
            .iter()
            .map(|&(j, x)| (x as f64 * w[j as usize] as f64).abs())
            .sum();
        let tol = sum_bound(nnz, sum_abs);
        for back in kernels::available_backends() {
            let got = kernels::pair_dot_with(back, &row, &w) as f64;
            assert!(
                (got - want).abs() <= tol,
                "nnz={nnz} [{}]: {got} vs {want}",
                back.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized kernels
// ---------------------------------------------------------------------------

fn quantized(rng: &mut Rng, d: usize) -> QuantizedMatrix {
    let data = randvec(rng, d);
    QuantizedMatrix::from_dense(&DenseMatrix::from_col_major(d, 1, data))
}

#[test]
fn quant_dot_backends_agree_at_group_boundaries() {
    let mut rng = Rng::new(9008);
    let d = 4 * QGROUP; // 256
    let q = quantized(&mut rng, d);
    let (packed, scales) = q.col_packed(0);
    let w = randvec(&mut rng, d);
    let deq = q.col_dense(0);
    // lo must be group-aligned; hi may cut a group anywhere
    for &(lo, hi) in &[
        (0usize, 0usize),
        (0, 1),
        (0, QGROUP - 1),
        (0, QGROUP),
        (0, QGROUP + 5),
        (0, 100),
        (QGROUP, QGROUP),
        (QGROUP, QGROUP + 1),
        (QGROUP, 2 * QGROUP + 17),
        (2 * QGROUP, d),
        (3 * QGROUP, d - 3),
        (0, d),
    ] {
        let want: f64 = (lo..hi).map(|r| deq[r] as f64 * w[r] as f64).sum();
        let sum_abs: f64 = (lo..hi).map(|r| (deq[r] as f64 * w[r] as f64).abs()).sum();
        let tol = sum_bound(hi - lo, sum_abs) * 2.0; // + per-group scale rounding
        for back in kernels::available_backends() {
            let got = kernels::quant_dot_range_with(back, packed, scales, &w, lo, hi) as f64;
            assert!(
                (got - want).abs() <= tol,
                "[{lo}, {hi}) [{}]: {got} vs {want} (tol {tol:e})",
                back.name()
            );
        }
    }
}

#[test]
fn quant_axpy_backends_agree_elementwise() {
    let mut rng = Rng::new(9009);
    for &groups in &[1usize, 2, 5] {
        let d = groups * QGROUP;
        let q = quantized(&mut rng, d);
        let (packed, scales) = q.col_packed(0);
        let v0 = randvec(&mut rng, d);
        let delta = rng.normal();
        let mut scalar = v0.clone();
        kernels::quant_axpy_with(Backend::Scalar, packed, scales, delta, &mut scalar);
        // against the dequantized reference
        let deq = q.col_dense(0);
        for (i, &s) in scalar.iter().enumerate() {
            let want = v0[i] + delta * deq[i];
            // the term's own rounding can exceed a bound on the (possibly
            // cancelled) result, so include its magnitude in the tolerance
            let tol = 8.0 * f32::EPSILON * (s.abs() + want.abs() + (delta * deq[i]).abs()) + 1e-30;
            assert!((s - want).abs() <= tol, "scalar vs dequantized i={i}: {s} vs {want}");
        }
        for back in kernels::available_backends() {
            let mut got = v0.clone();
            kernels::quant_axpy_with(back, packed, scales, delta, &mut got);
            for (i, (&g, &s)) in got.iter().zip(&scalar).enumerate() {
                let tol = 4.0 * f32::EPSILON * (g.abs() + s.abs()) + 1e-30;
                assert!((g - s).abs() <= tol, "d={d} i={i} [{}]", back.name());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared-vector (atomic) kernels — validated against an f64 reference
// through the public SharedVector API on the *dispatched* backend (the
// CI kernel matrix runs this under both scalar and simd settings).
// ---------------------------------------------------------------------------

#[test]
fn shared_vector_mapped_dot_matches_f64_reference() {
    let mut rng = Rng::new(9010);
    for &n in &[0usize, 1, 7, 33, 1023] {
        let vv = randvec(&mut rng, n);
        let x = randvec(&mut rng, n);
        let y = randvec(&mut rng, n);
        let v = SharedVector::from_slice(&vv, 64);
        let w_of = |vj: f32, yj: f32| vj - yj;
        let want: f64 = (0..n)
            .map(|r| x[r] as f64 * (vv[r] - y[r]) as f64)
            .sum();
        let sum_abs: f64 = (0..n)
            .map(|r| (x[r] as f64 * (vv[r] - y[r]) as f64).abs())
            .sum();
        let got = v.dot_mapped_range(&x, &y, w_of, 0, n) as f64;
        assert!(
            (got - want).abs() <= 2.0 * sum_bound(n, sum_abs),
            "n={n} [{}]: {got} vs {want}",
            kernels::backend().name()
        );
        // unaligned window
        if n > 5 {
            let (lo, hi) = (1, n - 2);
            let wwant: f64 = (lo..hi).map(|r| x[r] as f64 * (vv[r] - y[r]) as f64).sum();
            let wgot = v.dot_mapped_range(&x, &y, w_of, lo, hi) as f64;
            assert!((wgot - wwant).abs() <= 2.0 * sum_bound(n, sum_abs), "window n={n}");
        }
        // scaled fast path
        let scale = 0.37f32;
        let swant: f64 = (0..n).map(|r| x[r] as f64 * vv[r] as f64).sum::<f64>() * scale as f64;
        let sgot = v.dot_scaled_range(&x, scale, 0, n) as f64;
        assert!((sgot - swant).abs() <= 2.0 * sum_bound(n, sum_abs) + 1e-12, "scaled n={n}");
    }
}

#[test]
fn shared_vector_locked_axpy_matches_f64_reference() {
    let mut rng = Rng::new(9011);
    let n = 1023;
    let vv = randvec(&mut rng, n);
    let x = randvec(&mut rng, n);
    let delta = rng.normal();
    // dense, across lock-chunk sizes that do and don't divide n
    for &chunk in &[1usize, 7, 64, 1024, 4096] {
        let v = SharedVector::from_slice(&vv, chunk);
        v.axpy_dense_locked(&x, delta, 0, n);
        for r in 0..n {
            let want = vv[r] + delta * x[r];
            let got = v.read(r);
            let tol = 4.0 * f32::EPSILON * (want.abs() + got.abs()) + 1e-30;
            assert!((got - want).abs() <= tol, "chunk={chunk} r={r}");
        }
    }
    // sparse scatter spanning several chunks
    let (rows, vals) = sparse_col(&mut rng, n, 100);
    let v = SharedVector::from_slice(&vv, 64);
    v.axpy_sparse_locked(&rows, &vals, delta);
    let mut want = vv.clone();
    for (&r, &xv) in rows.iter().zip(&vals) {
        want[r as usize] += delta * xv;
    }
    for r in 0..n {
        let got = v.read(r);
        let tol = 4.0 * f32::EPSILON * (want[r].abs() + got.abs()) + 1e-30;
        assert!((got - want[r]).abs() <= tol, "sparse r={r}");
    }
}

// ---------------------------------------------------------------------------
// Dispatch plumbing
// ---------------------------------------------------------------------------

#[test]
fn dispatched_entry_points_match_explicit_backend() {
    let mut rng = Rng::new(9012);
    let a = randvec(&mut rng, 257);
    let b = randvec(&mut rng, 257);
    let back = kernels::backend();
    assert_eq!(kernels::dot(&a, &b), kernels::dot_with(back, &a, &b));
    assert_eq!(kernels::sq_norm(&a), kernels::sq_norm_with(back, &a));
    assert_eq!(kernels::dot_sq_norm(&a, &b), kernels::dot_sq_norm_with(back, &a, &b));
    let (rows, vals) = sparse_col(&mut rng, 257, 33);
    assert_eq!(
        kernels::sparse_dot(&rows, &vals, &a),
        kernels::sparse_dot_with(back, &rows, &vals, &a)
    );
}

#[test]
fn env_spec_parsing_is_total_over_documented_values() {
    for spec in ["scalar", "simd", "portable", "avx2"] {
        assert!(kernels::Backend::parse(spec).is_some(), "{spec}");
    }
    assert!(kernels::Backend::parse("mmx").is_none());
}
