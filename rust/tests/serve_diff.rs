//! Differential tests for the serving layer (ISSUE 7 satellite):
//!
//! * batched `PredictEngine` answers are **bitwise** equal to direct
//!   per-tile kernel calls, across all three representations × every
//!   available kernel backend × serial and pooled execution;
//! * concurrent readers never observe a torn [`ModelSnapshot`] while a
//!   writer republishes (the slot-ring protocol in `serve::store`);
//! * a refit whose certificate regresses is rejected and the old
//!   version keeps serving (graceful degradation).
//!
//! Backend flipping uses `kernels::set_backend`, which is process
//! global — the backend-iterating test serializes on `KERNEL_LOCK` and
//! restores the ambient dispatch, same discipline as `view_diff.rs`.

use hthc::data::{
    Dataset, DatasetBuilder, DatasetKind, Family, Represent, Sample,
};
use hthc::glm::ModelKind;
use hthc::kernels::{self, Backend, BLOCK_COLS};
use hthc::serve::{
    IngestBuffer, ModelSnapshot, ModelStore, PredictEngine, RefitConfig, RefitOutcome,
    Refitter, ServeStats,
};
use hthc::solver::StopWhen;
use hthc::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

static KERNEL_LOCK: Mutex<()> = Mutex::new(());

fn snapshot_with(weights: Vec<f32>, bias: f32) -> ModelSnapshot {
    let n = weights.len();
    ModelSnapshot {
        version: 0,
        kind: ModelKind::Lasso { lam: 0.1, lip_b: 1.0 },
        family: Family::Regression,
        weights,
        bias,
        alpha: vec![0.0; n],
        col_scales: None,
        gap: 0.0,
        trained_cols: n,
        absorbed: 0,
        published_at: Instant::now(),
    }
}

/// The three representations over the same generated source (spans
/// several BLOCK_COLS tiles plus a ragged tail).
fn representations(seed: u64) -> Vec<(&'static str, Dataset)> {
    let build = |r: Represent| {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .scale(2.0)
            .seed(seed)
            .represent(r)
            .build()
            .unwrap()
    };
    vec![
        ("dense", build(Represent::Dense)),
        ("sparse", build(Represent::Sparse)),
        ("quantized", build(Represent::Quantized)),
    ]
}

/// Direct kernel evaluation: the exact per-tile `dots_block` calls the
/// engine's contract promises, plus the same post-hoc bias add.
fn direct_scores(ds: &Dataset, w: &[f32], bias: f32) -> Vec<f32> {
    let ops = ds.as_block_ops();
    let n = ds.n_cols();
    let mut out = vec![0.0f32; n];
    let mut idx = [0usize; BLOCK_COLS];
    for (tile, chunk) in out.chunks_mut(BLOCK_COLS).enumerate() {
        let base = tile * BLOCK_COLS;
        for (t, j) in idx.iter_mut().zip(base..base + chunk.len()) {
            *t = j;
        }
        ops.dots_block(&idx[..chunk.len()], w, chunk);
    }
    if bias != 0.0 {
        for o in out.iter_mut() {
            *o += bias;
        }
    }
    out
}

#[test]
fn batch_predict_is_bitwise_direct_kernels_everywhere() {
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ambient: Backend = kernels::backend();
    for back in kernels::available_backends() {
        kernels::set_backend(back);
        for (repr, ds) in representations(21001) {
            let d = ds.n_rows();
            let mut rng = Rng::new(21002);
            let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let bias = 0.25f32;
            let want = direct_scores(&ds, &w, bias);
            for threads in [1usize, 3] {
                let engine =
                    PredictEngine::new(Arc::new(ModelStore::new(snapshot_with(
                        w.clone(),
                        bias,
                    ))))
                    .with_threads(threads);
                let got = engine.predict_batch(ds.as_block_ops());
                assert_eq!(got.len(), want.len());
                for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{repr}[{}] threads={threads} col {j}",
                        back.name()
                    );
                }
            }
        }
    }
    kernels::set_backend(ambient);
}

/// Readers racing a republishing writer must always see an internally
/// consistent snapshot: every field carries the same version tag.
#[test]
fn readers_never_observe_a_torn_snapshot() {
    const DIM: usize = 16;
    const PUBLISHES: u64 = 300;
    let tagged = |tag: u64| {
        let mut s = snapshot_with(vec![tag as f32; DIM], 0.0);
        s.alpha = vec![tag as f32; DIM];
        s.gap = tag as f64;
        s
    };
    let store = Arc::new(ModelStore::new(tagged(1)));
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..3 {
            s.spawn(|| {
                let mut last_version = 0u64;
                while !stop.load(Relaxed) {
                    let snap = store.load();
                    let tag = snap.weights[0];
                    assert!(
                        snap.weights.iter().all(|&x| x == tag),
                        "torn weights: {:?}",
                        &snap.weights[..4]
                    );
                    assert!(snap.alpha.iter().all(|&x| x == tag), "torn alpha");
                    assert_eq!(snap.gap, tag as f64, "gap from a different publish");
                    assert!(
                        snap.version >= last_version,
                        "version went backwards: {} -> {}",
                        last_version,
                        snap.version
                    );
                    last_version = snap.version;
                }
            });
        }
        for tag in 2..=PUBLISHES {
            store.publish(tagged(tag));
        }
        stop.store(true, Relaxed);
    });
    assert_eq!(store.version(), PUBLISHES);
}

/// A refit whose certificate regresses past tolerance is rejected: the
/// old version keeps serving and the rejection is counted.
#[test]
fn regressed_refit_is_rejected_and_old_version_serves() {
    let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(21003)
        .normalize(true)
        .center_targets(true)
        .build()
        .unwrap();
    let mut model = hthc::glm::Lasso::new(0.01);
    let mut trainer = hthc::solver::Trainer::new()
        .solver(hthc::solver::SeqThreshold)
        .stop_when(StopWhen::gap_below(1e-7).max_epochs(200));
    let report = trainer.fit_with(&mut model, &ds, &Default::default());
    let mut snap = ModelSnapshot::from_fit(&model, &ds, &report, 0.0, 0);
    // pretend the live certificate is perfect: with regress_tol 0 and an
    // unreachable convergence tolerance, any real refit must regress
    snap.gap = 0.0;
    let store = ModelStore::new(snap);
    let base = ds.to_samples().unwrap();
    let before = store.load();

    let mut refitter = Refitter::new(
        base.clone(),
        "lasso",
        0.01,
        true,
        true,
        RefitConfig {
            refit_every: 1,
            solver: "st".into(),
            regress_tol: 0.0,
            budget: StopWhen::gap_below(1e-300).max_epochs(2),
            ..Default::default()
        },
    );
    let buf = IngestBuffer::new();
    let stats = ServeStats::new();
    buf.push(Sample { label: base[0].label, features: base[0].features.clone() });
    match refitter.refit_once(&store, &buf, &stats) {
        RefitOutcome::Rejected { gap, serving } => {
            assert!(gap.is_finite() && gap > 0.0);
            assert_eq!(serving, 1);
        }
        other => panic!("expected rejection, got {other:?}"),
    }
    assert_eq!(store.version(), 1, "old version keeps serving");
    assert_eq!(stats.rejected(), 1);
    assert_eq!(stats.published(), 0);
    // and the serving snapshot is untouched — same weights, same gap
    let after = store.load();
    assert_eq!(after.version, before.version);
    assert_eq!(after.weights, before.weights);
    assert_eq!(after.gap, before.gap);
}

/// End-to-end: a short bounded run publishes at least one warm-start
/// refit and serves rows (the same gate `hthc serve --assert-healthy`
/// and the CI serve-smoke job apply).
#[test]
fn bounded_serve_run_is_healthy() {
    let base = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(21004)
        .build()
        .unwrap()
        .to_samples()
        .unwrap();
    let cfg = hthc::serve::ServeConfig {
        duration_secs: 0.3,
        batch: 16,
        threads: 2,
        ingest_per_round: 8,
        refit: RefitConfig {
            refit_every: 16,
            solver: "st".into(),
            budget: StopWhen::gap_below(1e-6).max_epochs(100).timeout_secs(5.0),
            ..Default::default()
        },
        ..Default::default()
    };
    let report = hthc::serve::sim::run(base, &cfg).unwrap();
    assert!(report.healthy(), "{report:?}");
    assert!(report.final_version >= 2, "{report:?}");
    assert!(report.qps > 0.0);
}
