//! Convergence golden tests: every engine, on a fixed-seed synthetic
//! Lasso and SVM problem, must reach a *recorded* duality-gap
//! threshold within a *recorded* epoch budget — and must produce the
//! same result under `RUST_PALLAS_KERNELS=scalar` and the default
//! dispatch (bitwise where the run is deterministic and the backends
//! coincide; an explicit f32 tolerance where exactness is impossible
//! because summation orders differ between backends).
//!
//! SGD deviation, asserted explicitly below: SGD carries no duality
//! gap (its certificate column is NaN), so its golden threshold is a
//! recorded training-MSE target on the Lasso problem instead, and it
//! has no SVM row (it is a primal squared-loss learner).
//!
//! Backend flipping uses `kernels::set_backend`, which is process
//! global — every test that flips or depends on a stable backend
//! serializes on `KERNEL_LOCK`.

use hthc::coordinator::HthcConfig;
use hthc::data::{Dataset, DatasetKind, Family};
use hthc::glm::{GlmModel, Lasso, SvmDual};
use hthc::kernels::{self, Backend};
use hthc::memory::TierSim;
use hthc::solver::{by_name, FitReport, Trainer};
use std::sync::Mutex;

static KERNEL_LOCK: Mutex<()> = Mutex::new(());

/// Restore the previously active backend on drop (panic-safe).
struct BackendGuard(Backend);

impl BackendGuard {
    fn set(b: Backend) -> Self {
        let prev = kernels::backend();
        kernels::set_backend(b);
        BackendGuard(prev)
    }
}

impl Drop for BackendGuard {
    fn drop(&mut self) {
        kernels::set_backend(self.0);
    }
}

// ---------------------------------------------------------------------------
// The golden table
// ---------------------------------------------------------------------------

/// Fixed-seed problems: one Lasso, one SVM (recorded — do not drift).
const LASSO_SEED: u64 = 7701;
const SVM_SEED: u64 = 7702;
const LASSO_LAM: f32 = 0.3;
const SVM_LAM: f32 = 1e-3;

/// Recorded per-engine epoch budgets on the Tiny problems.  The gap
/// threshold is `1e-3 * max(1, |F(0)|)` for every CD engine (for the
/// SVM dual `F(0) = 0`, so the threshold is absolute 1e-3).
const GAP_REL: f64 = 1e-3;
const BUDGET_LASSO: &[(&str, usize)] =
    &[("hthc", 2000), ("st", 400), ("omp", 800), ("passcode-atomic", 400)];
const BUDGET_SVM: &[(&str, usize)] =
    &[("hthc", 2000), ("st", 400), ("omp", 800), ("passcode-atomic", 400)];
/// SGD golden: recorded *relative* MSE target (fraction of the
/// predict-zero MSE — the noise floor sits near 1% of it on the Tiny
/// generator) and epoch budget on the Lasso problem.
const SGD_MSE_REL: f64 = 0.25;
const SGD_BUDGET: usize = 400;

/// The builder pipeline must not perturb the recorded generator output
/// (asserted in `data::builder` unit tests), so the goldens stand.
fn generate(kind: DatasetKind, family: Family, seed: u64) -> Dataset {
    Dataset::generated(kind, family, 1.0, seed)
}

fn lasso_problem() -> (Dataset, Lasso) {
    let g = generate(DatasetKind::Tiny, Family::Regression, LASSO_SEED);
    (g, Lasso::new(LASSO_LAM))
}

fn svm_problem() -> (Dataset, SvmDual) {
    let g = generate(DatasetKind::Tiny, Family::Classification, SVM_SEED);
    let n = g.n();
    (g, SvmDual::new(SVM_LAM, n))
}

fn gap_tol(model: &dyn GlmModel, g: &Dataset) -> f64 {
    let obj0 = model.objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; g.n()]);
    GAP_REL * obj0.abs().max(1.0)
}

/// Deterministic single-worker topology: every engine processes
/// coordinates in a seeded order on one update thread, so repeated
/// runs on one backend are bit-identical (HTHC is the exception — its
/// task A races wall-clock against task B by design, so only its
/// threshold behaviour is golden, not its iterate).
fn golden_cfg(gap_tol: f64, max_epochs: usize) -> HthcConfig {
    HthcConfig {
        t_a: 1,
        t_b: 1,
        v_b: 1,
        batch_frac: 0.5,
        gap_tol,
        max_epochs,
        timeout_secs: 60.0,
        eval_every: 1,
        seed: 4242,
        ..Default::default()
    }
}

fn run(engine: &str, cfg: HthcConfig, model: &mut dyn GlmModel, g: &Dataset) -> FitReport {
    let sim = TierSim::default();
    Trainer::new()
        .solver_boxed(by_name(engine).unwrap())
        .config(cfg)
        .fit_with(model, g, &sim)
}

// ---------------------------------------------------------------------------
// Threshold-within-budget goldens
// ---------------------------------------------------------------------------

#[test]
fn golden_lasso_every_engine_reaches_recorded_gap_in_budget() {
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for &(engine, budget) in BUDGET_LASSO {
        let (g, mut model) = lasso_problem();
        let tol = gap_tol(&model, &g);
        let res = run(engine, golden_cfg(tol, budget), &mut model, &g);
        assert!(
            res.converged,
            "{engine}: gap {:.3e} !<= {tol:.3e} within {budget} epochs ({})",
            res.final_gap().unwrap_or(f64::NAN),
            res.summary()
        );
        assert!(res.epochs <= budget, "{engine}: {} > {budget}", res.epochs);
    }
}

#[test]
fn golden_svm_every_engine_reaches_recorded_gap_in_budget() {
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for &(engine, budget) in BUDGET_SVM {
        let (g, mut model) = svm_problem();
        let tol = gap_tol(&model, &g);
        let res = run(engine, golden_cfg(tol, budget), &mut model, &g);
        assert!(
            res.converged,
            "{engine}: gap {:.3e} !<= {tol:.3e} within {budget} epochs ({})",
            res.final_gap().unwrap_or(f64::NAN),
            res.summary()
        );
    }
}

#[test]
fn golden_sgd_reaches_recorded_mse_in_budget() {
    // SGD has no duality gap — asserted explicitly: its gap column is
    // NaN and its golden is an MSE target (see module docs).
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (g, _) = lasso_problem();
    let sim = TierSim::default();
    let mut model = Lasso::new(LASSO_LAM);
    let mse0 = kernels::sq_err_f64(g.targets(), &vec![0.0; g.d()]) / g.d() as f64;
    let target = SGD_MSE_REL * mse0;
    let res = Trainer::new()
        .solver(hthc::solver::Sgd { lam: 1e-4, mse_target: target })
        .config(golden_cfg(0.0, SGD_BUDGET))
        .fit_with(&mut model, &g, &sim);
    assert!(
        res.converged,
        "sgd: MSE {:?} !<= {target:.4} within {SGD_BUDGET} epochs",
        res.final_objective()
    );
    assert!(res.final_gap().unwrap().is_nan(), "sgd must report NaN gap (no certificate)");
}

// ---------------------------------------------------------------------------
// Scalar vs dispatched-backend agreement
// ---------------------------------------------------------------------------

/// Compare two FitReports field by field.  `bitwise` demands exact
/// equality (same backend + deterministic engine); otherwise an
/// explicit f32 tolerance absorbs summation-order differences, which
/// compound over epochs — exactness across backends is impossible and
/// that is asserted knowingly here.
fn assert_reports_agree(engine: &str, a: &FitReport, b: &FitReport, bitwise: bool) {
    assert_eq!(a.solver, b.solver, "{engine}: solver tag");
    assert_eq!(a.converged, b.converged, "{engine}: converged flag");
    assert_eq!(a.alpha.len(), b.alpha.len(), "{engine}: iterate length");
    if bitwise {
        assert_eq!(a.epochs, b.epochs, "{engine}: epoch count (bitwise run)");
        for (i, (&x, &y)) in a.alpha.iter().zip(&b.alpha).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{engine}: alpha[{i}] {x} != {y} — same backend must be bit-identical"
            );
        }
        for (i, (&x, &y)) in a.v.iter().zip(&b.v).enumerate() {
            assert!(x.to_bits() == y.to_bits(), "{engine}: v[{i}] (bitwise run)");
        }
    } else {
        // f32 tolerance, asserted explicitly (see fn docs)
        for (i, (&x, &y)) in a.alpha.iter().zip(&b.alpha).enumerate() {
            assert!(
                (x - y).abs() <= 5e-2 * x.abs().max(y.abs()).max(1.0),
                "{engine}: alpha[{i}] {x} vs {y} beyond cross-backend tolerance"
            );
        }
    }
}

/// The deterministic engines (single worker, seeded order): ST, OMP,
/// PASSCoDe, SGD.  HTHC is excluded — task A's refresh count races
/// wall-clock, so its iterate is not run-reproducible even on one
/// backend; its goldens are the threshold tests above.
const DETERMINISTIC_ENGINES: &[&str] = &["st", "omp", "passcode-atomic", "sgd"];

#[test]
fn scalar_vs_dispatched_reports_agree() {
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ambient = kernels::backend();
    for &engine in DETERMINISTIC_ENGINES {
        let (g, _) = lasso_problem();
        let budget = 50; // short fixed run: compares iterates, not convergence
        // gap_tol -1.0: unreachable (gaps are >= -fp-noise), so both runs
        // always execute exactly `budget` epochs and `converged` cannot
        // flip on a gap that rounds to 0.0 under one backend only
        let fit_once = || {
            let mut model = Lasso::new(LASSO_LAM);
            run(engine, golden_cfg(-1.0, budget), &mut model, &g)
        };

        let (scalar_a, scalar_b) = {
            let _g = BackendGuard::set(Backend::Scalar);
            (fit_once(), fit_once())
        };
        // determinism on one backend: bit-identical
        assert_reports_agree(engine, &scalar_a, &scalar_b, true);

        let dispatched = {
            let _g = BackendGuard::set(ambient);
            fit_once()
        };
        // scalar vs dispatched: bitwise when the dispatcher already
        // resolves to scalar (the CI scalar matrix job), tolerance
        // otherwise — exact cross-backend equality is impossible
        let bitwise = ambient == Backend::Scalar;
        assert_reports_agree(engine, &scalar_a, &dispatched, bitwise);
        assert_eq!(scalar_a.epochs, dispatched.epochs, "{engine}: fixed epoch budget");
    }
}

#[test]
fn scalar_vs_dispatched_both_reach_the_golden_threshold() {
    // HTHC's cross-backend golden: not iterate equality (see above),
    // but the recorded threshold must hold under both kernel settings.
    let _l = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ambient = kernels::backend();
    for backend in [Backend::Scalar, ambient] {
        let _g = BackendGuard::set(backend);
        let (g, mut model) = lasso_problem();
        let tol = gap_tol(&model, &g);
        let res = run("hthc", golden_cfg(tol, 2000), &mut model, &g);
        assert!(
            res.converged,
            "hthc[{}]: {}",
            backend.name(),
            res.summary()
        );
    }
}
