//! Cross-module integration tests: full solver runs across every
//! (model x representation x solver) combination the paper evaluates,
//! plus the coordination invariants that only show up end-to-end.

use hthc::baselines::PasscodeMode;
use hthc::coordinator::{HthcConfig, Selection};
use hthc::data::{Dataset, DatasetBuilder, DatasetKind, Family, Matrix, Represent};
use hthc::glm::{self, ElasticNet, GlmModel, Lasso, LogisticL1, Ridge, SvmDual};
use hthc::memory::{Tier, TierSim};
use hthc::solver::{FitReport, Hthc, Omp, Passcode, SeqThreshold, Solver, Trainer};

/// Every dataset in this suite goes through the one builder pipeline.
fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
    Dataset::generated(kind, family, scale, seed)
}

fn rel_tol(model: &dyn GlmModel, d: usize, n: usize, y: &[f32], rel: f64) -> f64 {
    let obj0 = model.objective(&vec![0.0; d], y, &vec![0.0; n]);
    rel * obj0.abs().max(1.0)
}

fn quick_cfg(gap_tol: f64) -> HthcConfig {
    HthcConfig {
        t_a: 2,
        t_b: 2,
        v_b: 1,
        batch_frac: 0.25,
        gap_tol,
        max_epochs: 3000,
        timeout_secs: 45.0,
        eval_every: 5,
        ..Default::default()
    }
}

/// Run any engine through the unified facade (the only entry point the
/// integration suite uses).
fn fit(
    solver: impl Solver + 'static,
    cfg: HthcConfig,
    model: &mut dyn GlmModel,
    data: &Dataset,
    sim: &TierSim,
) -> FitReport {
    Trainer::new().solver(solver).config(cfg).fit_with(model, data, sim)
}

/// Every model trains on its natural dataset through the full HTHC
/// stack and reaches a small relative duality gap.
#[test]
fn all_models_train_via_hthc() {
    let cases: Vec<(Box<dyn GlmModel>, Family)> = {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 201);
        let n = g.n();
        vec![
            (Box::new(Lasso::new(0.4)) as Box<dyn GlmModel>, Family::Regression),
            (Box::new(Ridge::new(0.5)), Family::Regression),
            (Box::new(ElasticNet::new(0.4, 0.5)), Family::Regression),
            (Box::new(SvmDual::new(1e-3, n)), Family::Classification),
            (Box::new(LogisticL1::new(0.01)), Family::Classification),
        ]
    };
    for (mut model, family) in cases {
        let g = generate(DatasetKind::Tiny, family, 1.0, 201);
        let tol = rel_tol(model.as_ref(), g.d(), g.n(), g.targets(), 1e-3);
        let sim = TierSim::default();
        let res = fit(Hthc::new(), quick_cfg(tol), model.as_mut(), &g, &sim);
        let name = model.name();
        assert!(res.converged, "{name}: {}", res.summary());
        // the headline invariant: locked updates never lose writes
        let v2 = g.matvec_alpha(&res.alpha);
        for (idx, (a, b)) in res.v.iter().zip(&v2).enumerate() {
            assert!(
                (a - b).abs() < 1e-2 * b.abs().max(1.0),
                "{name}: v[{idx}] inconsistent"
            );
        }
    }
}

/// Dense, sparse and quantized representations all train lasso — the
/// builder's `represent` stage producing each one.
#[test]
fn all_representations_train() {
    // dense
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 202);
    // quantized pipeline over the same generated source
    let gq = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
        .seed(202)
        .represent(Represent::Quantized)
        .build()
        .unwrap();
    // sparse dataset
    let gs = generate(DatasetKind::News20Like, Family::Regression, 0.03, 202);

    for (label, ds) in [("dense", &g), ("quantized", &gq), ("sparse", &gs)] {
        let mut model = Lasso::new(0.3);
        let tol = rel_tol(&model, ds.n_rows(), ds.n_cols(), ds.targets(), 5e-3);
        let sim = TierSim::default();
        let res = fit(Hthc::new(), quick_cfg(tol), &mut model, ds, &sim);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(
            last < first,
            "{label}: objective must decrease ({first} -> {last})"
        );
        if label != "quantized" {
            // quantization noise floors the achievable gap; dense and
            // sparse must actually converge
            assert!(res.converged, "{label}: {}", res.summary());
        }
    }
}

/// All solvers minimize the same objective on the same data — final
/// objectives must agree (the baselines are *performance* comparators,
/// not different algorithms).
#[test]
fn solvers_agree_on_the_optimum() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 203);
    let sim = TierSim::default();
    let tol = rel_tol(&Lasso::new(0.4), g.d(), g.n(), g.targets(), 1e-3);
    let mut objs: Vec<(String, f64)> = Vec::new();

    // every engine through the one facade — same model, same data
    let engines: Vec<Box<dyn Solver>> = vec![
        Box::new(Hthc::new()),
        Box::new(SeqThreshold),
        Box::new(Omp { wild: false }),
        Box::new(Passcode { mode: PasscodeMode::Atomic }),
    ];
    for engine in engines {
        let name = engine.name();
        let mut m = Lasso::new(0.4);
        let r = Trainer::new()
            .solver_boxed(engine)
            .config(quick_cfg(tol))
            .fit_with(&mut m, &g, &sim);
        objs.push((name.into(), r.trace.final_objective().unwrap()));
    }

    let best = objs.iter().map(|&(_, o)| o).fold(f64::INFINITY, f64::min);
    for (name, obj) in &objs {
        assert!(
            (obj - best) <= 2.0 * tol + 1e-2 * best.abs(),
            "{name} landed at {obj}, best {best}"
        );
    }
}

/// OMP-WILD's lost updates break v = D alpha — the paper's Fig. 5
/// plateau argument — while OMP-atomic preserves it.
#[test]
fn wild_breaks_primal_dual_consistency_atomic_does_not() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 2.0, 204);
    let sim = TierSim::default();
    let mut cfg = quick_cfg(0.0);
    cfg.max_epochs = 30;
    cfg.t_b = 4; // more concurrency -> more lost updates for wild
    let drift = |wild: bool| {
        let mut m = Lasso::new(0.2);
        let r = fit(Omp { wild }, cfg.clone(), &mut m, &g, &sim);
        let v2 = g.matvec_alpha(&r.alpha);
        r.v
            .iter()
            .zip(&v2)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
    };
    let atomic_drift = drift(false);
    assert!(
        atomic_drift < 1e-1,
        "atomic drift should be fp-noise only: {atomic_drift}"
    );
    // wild drift is usually large; on a 1-core host races may be rare,
    // so only assert the *ordering*, not a magnitude.
    let wild_drift = drift(true);
    assert!(
        wild_drift >= atomic_drift * 0.9,
        "wild ({wild_drift}) should not be cleaner than atomic ({atomic_drift})"
    );
}

/// The §IV-A1 resource-separation claim: task A charges the slow tier,
/// task B the fast tier, and the working-set swap both.
#[test]
fn tier_traffic_separation() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 205);
    let sim = TierSim::default();
    let mut cfg = quick_cfg(0.0);
    cfg.max_epochs = 10;
    let mut model = Lasso::new(0.4);
    let _ = fit(Hthc::new(), cfg, &mut model, &g, &sim);
    let slow = sim.stats(Tier::Slow);
    let fast = sim.stats(Tier::Fast);
    assert!(slow.read_bytes > 0, "A must stream the full matrix from DRAM");
    assert!(fast.read_bytes > 0, "B must stream its working set from MCDRAM");
    assert!(fast.write_bytes > 0, "swaps must write into MCDRAM");
}

/// Importance-sampling selection also converges (the paper: "any
/// adaptive selection scheme could be adopted").
#[test]
fn importance_selection_converges() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 206);
    let mut model = Lasso::new(0.4);
    let tol = rel_tol(&model, g.d(), g.n(), g.targets(), 1e-3);
    let mut cfg = quick_cfg(tol);
    cfg.selection = Selection::Importance;
    let sim = TierSim::default();
    let res = fit(Hthc::new(), cfg, &mut model, &g, &sim);
    assert!(res.converged, "{}", res.summary());
}

/// Failure injection: a dataset with all-zero columns must not panic,
/// NaN, or stall the batch queue (delta = 0 path).
#[test]
fn zero_columns_are_handled() {
    let d = 64;
    let n = 32;
    let mut data = vec![0.0f32; d * n];
    let mut rng = hthc::util::Rng::new(207);
    // half the columns are zero, half random
    for j in 0..n / 2 {
        for r in 0..d {
            data[j * d + r] = rng.normal();
        }
    }
    let m = hthc::data::DenseMatrix::from_col_major(d, n, data);
    let y: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let ds = Dataset::from_parts(Matrix::Dense(m), y);
    let mut model = Lasso::new(0.1);
    let mut cfg = quick_cfg(0.0);
    cfg.max_epochs = 50;
    let sim = TierSim::default();
    let res = fit(Hthc::new(), cfg, &mut model, &ds, &sim);
    assert!(res.alpha.iter().all(|a| a.is_finite()));
    assert!(res.v.iter().all(|v| v.is_finite()));
    // zero columns never move
    for j in n / 2..n {
        assert_eq!(res.alpha[j], 0.0);
    }
}

/// The duality gap reported on the trace is a true certificate: it
/// bounds suboptimality from above (checked against a long reference
/// solve).
#[test]
fn gap_upper_bounds_suboptimality() {
    let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 208);
    let sim = TierSim::default();
    // long reference solve for a near-exact optimum
    let mut ref_model = Lasso::new(0.4);
    let (mut alpha, mut v) = (vec![0.0f32; g.n()], vec![0.0f32; g.d()]);
    let ops = g.as_ops();
    let opt = glm::solve_reference(&mut ref_model, ops, g.targets(), &mut alpha, &mut v, 800);

    let mut model = Lasso::new(0.4);
    let mut cfg = quick_cfg(0.0);
    cfg.max_epochs = 120;
    cfg.eval_every = 10;
    let res = fit(Hthc::new(), cfg, &mut model, &g, &sim);
    for p in &res.trace.points {
        let subopt = p.objective - opt;
        assert!(
            p.duality_gap >= subopt - 1e-3 * opt.abs().max(1.0),
            "gap {} must bound subopt {} (epoch {})",
            p.duality_gap,
            subopt,
            p.epoch
        );
    }
}
