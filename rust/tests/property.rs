//! Property-based tests (hand-rolled generators; proptest is not in the
//! offline crate set).  Each property runs across many random cases
//! with shrinking-free but seed-reported failures.

use hthc::coordinator::{selection, SharedVector};
use hthc::data::sparse::SparseMatrix;
use hthc::data::{ColumnOps, DenseMatrix, QuantizedMatrix};
use hthc::glm::{ElasticNet, GlmModel, Lasso, LogisticL1, ModelKind, Ridge, SvmDual};
use hthc::kernels;
use hthc::util::Rng;

const CASES: usize = 60;

fn models(n: usize) -> Vec<Box<dyn GlmModel>> {
    vec![
        Box::new(Lasso::new(0.2).with_lip_b(2.0)),
        Box::new(Ridge::new(0.6)),
        Box::new(ElasticNet::new(0.3, 0.5)),
        Box::new(SvmDual::new(0.05, n)),
        Box::new(LogisticL1::new(0.1)),
    ]
}

/// kernels::dot == f64 reference within fp32 accumulation error, for
/// every available backend and any length.
#[test]
fn prop_dot_matches_f64_reference() {
    let mut rng = Rng::new(301);
    for case in 0..CASES {
        let len = 1 + rng.below(5000);
        let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let tol = 1e-5 * (len as f64).sqrt() * 10.0;
        for back in kernels::available_backends() {
            let got = kernels::dot_with(back, &a, &b) as f64;
            assert!(
                (got - want).abs() <= tol * want.abs().max(1.0),
                "case {case} len {len} [{}]: {got} vs {want}",
                back.name()
            );
        }
    }
}

/// axpy then axpy with -delta restores the vector (within fp noise),
/// for every available backend.
#[test]
fn prop_axpy_invertible() {
    let mut rng = Rng::new(302);
    for _ in 0..CASES {
        let len = 1 + rng.below(2000);
        let x: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let v0: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let delta = rng.normal();
        for back in kernels::available_backends() {
            let mut v = v0.clone();
            kernels::axpy_with(back, delta, &x, &mut v);
            kernels::axpy_with(back, -delta, &x, &mut v);
            for (a, b) in v.iter().zip(&v0) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "[{}]", back.name());
            }
        }
    }
}

/// Sparse dot == densified dot for random sparsity patterns.
#[test]
fn prop_sparse_dot_matches_dense() {
    let mut rng = Rng::new(303);
    for _ in 0..CASES {
        let d = 16 + rng.below(500);
        let nnz = rng.below(d.min(100));
        let idx = rng.sample_distinct(d, nnz);
        let col: Vec<(u32, f32)> = idx.into_iter().map(|r| (r as u32, rng.normal())).collect();
        let m = SparseMatrix::from_columns(d, vec![col]);
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let dense = m.col_dense(0);
        let want: f32 = dense.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert!((m.dot(0, &w) - want).abs() < 1e-3 * want.abs().max(1.0));
        // row-window split composes
        let mid = d / 2;
        let split = m.dot_range(0, &w, 0, mid) + m.dot_range(0, &w, mid, d);
        assert!((split - want).abs() < 1e-3 * want.abs().max(1.0));
    }
}

/// Quantization roundtrip error bound holds for adversarial scales.
#[test]
fn prop_quantization_error_bound() {
    let mut rng = Rng::new(304);
    for _ in 0..CASES {
        let d = 64 * (1 + rng.below(8));
        let scale = 10f32.powf(rng.normal() * 2.0); // wild magnitudes
        let data: Vec<f32> = (0..d).map(|_| rng.normal() * scale).collect();
        let m = DenseMatrix::from_col_major(d, 1, data.clone());
        let q = QuantizedMatrix::from_dense(&m);
        let deq = q.col_dense(0);
        for (r, (&x, &xq)) in data.iter().zip(&deq).enumerate() {
            let bound = q.group_err_bound(0, r / 64) + 1e-9;
            assert!((x - xq).abs() <= bound, "row {r}: {x} vs {xq} (bound {bound})");
        }
    }
}

/// For every model: the closed-form update minimizes the 1-D restriction
/// — no nearby point along the coordinate does better (local optimality
/// probe on the true objective).
#[test]
fn prop_update_is_one_dimensional_minimizer() {
    let mut rng = Rng::new(305);
    for _ in 0..CASES / 2 {
        let d = 24;
        let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let sq: f32 = col.iter().map(|x| x * x).sum();
        let y: Vec<f32> = (0..d)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        for model in models(40) {
            // logistic's prox step is a majorizer step, not the exact
            // 1-D minimizer — skip the exactness probe for it.
            if model.name() == "logistic-l1" {
                continue;
            }
            let a0 = if model.box_constrained() { rng.f32() } else { rng.normal() };
            let kind = model.kind();
            let u: f32 = col
                .iter()
                .zip(v.iter().zip(&y))
                .map(|(&x, (&vj, &yj))| x * kind.w_of(vj, yj))
                .sum();
            let delta = kind.delta(u, a0, sq);
            // objective restricted to the coordinate, via full eval
            let eval = |t: f32| -> f64 {
                let vt: Vec<f32> = v.iter().zip(&col).map(|(&vj, &x)| vj + (t - a0) * x).collect();
                let mut alpha = vec![0.0f32; 8];
                alpha[3] = t;
                // objective uses only alpha[3]'s g_i term plus f(v):
                // build a 1-coordinate problem view
                model.objective(&vt, &y, &alpha[3..4])
            };
            let best = eval(a0 + delta);
            for probe in [-0.01f32, 0.01, -0.1, 0.1] {
                let t = a0 + delta + probe;
                let t = if model.box_constrained() { t.clamp(0.0, 1.0) } else { t };
                assert!(
                    eval(t) >= best - 1e-4 * best.abs().max(1.0),
                    "{}: t={t} beats closed form",
                    model.name()
                );
            }
        }
    }
}

/// SharedVector locked axpy: concurrent mixed sparse/dense updates sum
/// exactly (no lost updates) regardless of chunk size.
#[test]
fn prop_locked_updates_never_lost() {
    let mut rng = Rng::new(306);
    for _ in 0..8 {
        let d = 64 + rng.below(512);
        let chunk = 1 + rng.below(128);
        let v = SharedVector::new(d, chunk);
        let dense_x: Vec<f32> = vec![1.0; d];
        let idx: Vec<u32> = (0..d as u32).step_by(3).collect();
        let vals: Vec<f32> = idx.iter().map(|_| 2.0).collect();
        let reps = 50;
        std::thread::scope(|s| {
            for t in 0..4 {
                let v = &v;
                let dense_x = &dense_x;
                let idx = &idx;
                let vals = &vals;
                s.spawn(move || {
                    for _ in 0..reps {
                        if t % 2 == 0 {
                            v.axpy_dense_locked(dense_x, 1.0, 0, dense_x.len());
                        } else {
                            v.axpy_sparse_locked(idx, vals, 1.0);
                        }
                    }
                });
            }
        });
        for r in 0..d {
            let sparse_part = if r % 3 == 0 { 2.0 * 2.0 * reps as f32 } else { 0.0 };
            let want = 2.0 * reps as f32 + sparse_part;
            assert_eq!(v.read(r), want, "row {r} chunk {chunk}");
        }
    }
}

/// top_m always returns exactly the m largest entries (checked against
/// a full sort), for any distribution including duplicates.
#[test]
fn prop_top_m_matches_sort() {
    let mut rng = Rng::new(307);
    for _ in 0..CASES {
        let n = 1 + rng.below(2000);
        let m = rng.below(n + 1);
        let z: Vec<f32> = (0..n).map(|_| (rng.below(50) as f32) / 10.0).collect();
        let got = selection::top_m(&z, m);
        assert_eq!(got.len(), m);
        let mut sorted: Vec<usize> = (0..n).collect();
        sorted.sort_by(|&a, &b| z[b].partial_cmp(&z[a]).unwrap());
        let thresh = if m == 0 { f32::INFINITY } else { z[sorted[m - 1]] };
        // every selected value >= threshold value
        for &i in &got {
            assert!(z[i] >= thresh - 1e-9);
        }
        // total of selected == total of top-m by sort (handles ties)
        let sum_got: f64 = got.iter().map(|&i| z[i] as f64).sum();
        let sum_want: f64 = sorted[..m].iter().map(|&i| z[i] as f64).sum();
        assert!((sum_got - sum_want).abs() < 1e-6);
    }
}

/// ModelKind::gap is scale-consistent: gap >= 0 on feasible iterates for
/// random hyperparameters (the certificate never goes negative).
#[test]
fn prop_gap_nonnegative_random_hyperparams() {
    let mut rng = Rng::new(308);
    for _ in 0..CASES {
        let lam = 10f32.powf(rng.normal());
        let n = 10 + rng.below(1000);
        let kinds = [
            ModelKind::Lasso { lam, lip_b: 5.0 },
            ModelKind::Ridge { lam },
            ModelKind::ElasticNet { l1: lam * 0.5, l2: lam * 0.5 },
            ModelKind::Svm {
                inv_scale: 1.0 / (lam * (n as f32) * (n as f32)),
                inv_n: 1.0 / n as f32,
            },
        ];
        for kind in kinds {
            for _ in 0..20 {
                let u = rng.normal() * 3.0;
                let a = match kind {
                    ModelKind::Svm { .. } => rng.f32(),
                    _ => rng.normal().clamp(-5.0, 5.0),
                };
                let g = kind.gap(u, a);
                assert!(g >= -1e-3, "{kind:?}: gap({u}, {a}) = {g}");
            }
        }
    }
}
