//! Explicit AVX2+FMA dense kernels (`std::arch::x86_64`).
//!
//! Four 8-lane FMA accumulators per loop — 32 elements in flight —
//! mirroring the paper's AVX-512 multiple-accumulator strategy one
//! register width down.  Unaligned loads throughout (`loadu`): column
//! slices and `dot_range` sub-ranges carry no alignment guarantee.
//!
//! Every function here is `unsafe`: callers must have verified
//! AVX2+FMA support at runtime (`kernels::avx2_available()`), which
//! the dispatch layer does before ever selecting [`Backend::Avx2`].
//!
//! [`Backend::Avx2`]: super::Backend::Avx2

use std::arch::x86_64::*;

/// Horizontal sum of one 8-lane register.
///
/// # Safety
/// Requires AVX (subsumed by the callers' AVX2+FMA contract).
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let hi = _mm256_extractf128_ps::<1>(v);
    let lo = _mm256_castps256_ps128(v);
    let quad = _mm_add_ps(lo, hi);
    let dual = _mm_add_ps(quad, _mm_movehl_ps(quad, quad));
    let single = _mm_add_ss(dual, _mm_shuffle_ps::<0b01>(dual, dual));
    _mm_cvtss_f32(single)
}

/// `<a, b>`.
///
/// # Safety
/// Host must support AVX2 and FMA; `a.len() == b.len()`.
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        acc2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 16)),
            _mm256_loadu_ps(pb.add(i + 16)),
            acc2,
        );
        acc3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 24)),
            _mm256_loadu_ps(pb.add(i + 24)),
            acc3,
        );
        i += 32;
    }
    while i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        i += 8;
    }
    let mut s = hsum(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// `v += delta * x`.
///
/// # Safety
/// Host must support AVX2 and FMA; `x.len() == v.len()`.
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub(super) unsafe fn axpy(delta: f32, x: &[f32], v: &mut [f32]) {
    let n = v.len();
    let d = _mm256_set1_ps(delta);
    let px = x.as_ptr();
    let pv = v.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(px.add(i));
        let vv = _mm256_loadu_ps(pv.add(i));
        _mm256_storeu_ps(pv.add(i), _mm256_fmadd_ps(d, xv, vv));
        i += 8;
    }
    while i < n {
        v[i] += delta * x[i];
        i += 1;
    }
}

/// `||x||^2`.
///
/// # Safety
/// Host must support AVX2 and FMA.
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub(super) unsafe fn sq_norm(x: &[f32]) -> f32 {
    let n = x.len();
    let px = x.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let a = _mm256_loadu_ps(px.add(i));
        let b = _mm256_loadu_ps(px.add(i + 8));
        acc0 = _mm256_fmadd_ps(a, a, acc0);
        acc1 = _mm256_fmadd_ps(b, b, acc1);
        i += 16;
    }
    while i + 8 <= n {
        let a = _mm256_loadu_ps(px.add(i));
        acc0 = _mm256_fmadd_ps(a, a, acc0);
        i += 8;
    }
    let mut s = hsum(_mm256_add_ps(acc0, acc1));
    while i < n {
        s += x[i] * x[i];
        i += 1;
    }
    s
}

/// Two dots sharing each `w` load: `(<a, w>, <b, w>)` with two 8-lane
/// FMA accumulators per column — the register tile of the blocked
/// multi-column sweep (each loaded `w` vector feeds two columns).
///
/// # Safety
/// Host must support AVX2 and FMA; `a.len() == b.len() == w.len()`.
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn dot2(a: &[f32], b: &[f32], w: &[f32]) -> (f32, f32) {
    let n = w.len();
    let (pa, pb, pw) = (a.as_ptr(), b.as_ptr(), w.as_ptr());
    let mut aacc0 = _mm256_setzero_ps();
    let mut aacc1 = _mm256_setzero_ps();
    let mut bacc0 = _mm256_setzero_ps();
    let mut bacc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let w0 = _mm256_loadu_ps(pw.add(i));
        let w1 = _mm256_loadu_ps(pw.add(i + 8));
        aacc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), w0, aacc0);
        bacc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pb.add(i)), w0, bacc0);
        aacc1 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i + 8)), w1, aacc1);
        bacc1 = _mm256_fmadd_ps(_mm256_loadu_ps(pb.add(i + 8)), w1, bacc1);
        i += 16;
    }
    while i + 8 <= n {
        let w0 = _mm256_loadu_ps(pw.add(i));
        aacc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), w0, aacc0);
        bacc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pb.add(i)), w0, bacc0);
        i += 8;
    }
    let mut sa = hsum(_mm256_add_ps(aacc0, aacc1));
    let mut sb = hsum(_mm256_add_ps(bacc0, bacc1));
    while i < n {
        sa += a[i] * w[i];
        sb += b[i] * w[i];
        i += 1;
    }
    (sa, sb)
}

/// Dense blocked dots `out[k] = <cols[k], w>`: column tiles of
/// [`super::BLOCK_COLS`] over `ROW_BLOCK`-sized bands of `w`, column
/// pairs sharing every `w` load via [`dot2`].
///
/// # Safety
/// Host must support AVX2 and FMA; every `cols[k].len() == w.len()` and
/// `cols.len() == out.len()`.
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub(super) unsafe fn dots_block(cols: &[&[f32]], w: &[f32], out: &mut [f32]) {
    use super::block::ROW_BLOCK;
    use super::BLOCK_COLS;

    debug_assert_eq!(cols.len(), out.len());
    let d = w.len();
    for (tile, otile) in cols.chunks(BLOCK_COLS).zip(out.chunks_mut(BLOCK_COLS)) {
        let mut acc = [0.0f32; BLOCK_COLS];
        let mut lo = 0usize;
        while lo < d {
            let hi = (lo + ROW_BLOCK).min(d);
            let wb = &w[lo..hi];
            let mut k = 0usize;
            while k + 1 < tile.len() {
                let (s0, s1) = dot2(&tile[k][lo..hi], &tile[k + 1][lo..hi], wb);
                acc[k] += s0;
                acc[k + 1] += s1;
                k += 2;
            }
            if k < tile.len() {
                acc[k] += dot(&tile[k][lo..hi], wb);
            }
            lo = hi;
        }
        otile.copy_from_slice(&acc[..tile.len()]);
    }
}

/// Fused `(<a, b>, ||a||^2)` — one pass over `a`.
///
/// # Safety
/// Host must support AVX2 and FMA; `a.len() == b.len()`.
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
pub(super) unsafe fn dot_sq_norm(a: &[f32], b: &[f32]) -> (f32, f32) {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut dacc0 = _mm256_setzero_ps();
    let mut dacc1 = _mm256_setzero_ps();
    let mut qacc0 = _mm256_setzero_ps();
    let mut qacc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let a0 = _mm256_loadu_ps(pa.add(i));
        let a1 = _mm256_loadu_ps(pa.add(i + 8));
        let b0 = _mm256_loadu_ps(pb.add(i));
        let b1 = _mm256_loadu_ps(pb.add(i + 8));
        dacc0 = _mm256_fmadd_ps(a0, b0, dacc0);
        dacc1 = _mm256_fmadd_ps(a1, b1, dacc1);
        qacc0 = _mm256_fmadd_ps(a0, a0, qacc0);
        qacc1 = _mm256_fmadd_ps(a1, a1, qacc1);
        i += 16;
    }
    let mut d = hsum(_mm256_add_ps(dacc0, dacc1));
    let mut q = hsum(_mm256_add_ps(qacc0, qacc1));
    while i < n {
        d += a[i] * b[i];
        q += a[i] * a[i];
        i += 1;
    }
    (d, q)
}
