//! Kernels over the shared vector's atomic storage.
//!
//! `SharedVector` keeps `v` as f32 bits in `AtomicU32` so racy reads
//! are defined (§IV-C; on x86 a relaxed load is an ordinary `mov`).
//! These are the lock-free inner bodies of its hot paths: the caller
//! (`coordinator::shared_vec`) owns the chunk-lock discipline and
//! hands these the ranges/segments a lock covers.
//!
//! §Perf iteration log (EXPERIMENTS.md §Perf): a 256-element staging
//! buffer (copy v out of the atomics, then a vectorizable FMA loop)
//! measured *slower* (10.9 vs 7.8 us at d=10k) — the per-element
//! `w_of` map blocks SIMD either way, so staging only added traffic.
//! Four independent accumulators on direct relaxed loads remain the
//! best variant tried; that is the non-scalar backend here.

use std::sync::atomic::{AtomicU32, Ordering};

#[inline(always)]
fn read(v: &[AtomicU32], i: usize) -> f32 {
    f32::from_bits(v[i].load(Ordering::Relaxed))
}

pub(super) fn dot_mapped_scalar<F: Fn(f32, f32) -> f32>(
    v: &[AtomicU32],
    x: &[f32],
    y: &[f32],
    w_of: F,
    lo: usize,
    hi: usize,
) -> f32 {
    let mut s = 0.0f32;
    for r in lo..hi {
        s += x[r] * w_of(read(v, r), y[r]);
    }
    s
}

pub(super) fn dot_mapped_unrolled<F: Fn(f32, f32) -> f32>(
    v: &[AtomicU32],
    x: &[f32],
    y: &[f32],
    w_of: F,
    lo: usize,
    hi: usize,
) -> f32 {
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut r = lo;
    while r + 3 < hi {
        s0 += x[r] * w_of(read(v, r), y[r]);
        s1 += x[r + 1] * w_of(read(v, r + 1), y[r + 1]);
        s2 += x[r + 2] * w_of(read(v, r + 2), y[r + 2]);
        s3 += x[r + 3] * w_of(read(v, r + 3), y[r + 3]);
        r += 4;
    }
    while r < hi {
        s0 += x[r] * w_of(read(v, r), y[r]);
        r += 1;
    }
    (s0 + s1) + (s2 + s3)
}

pub(super) fn dot_scaled_scalar(v: &[AtomicU32], x: &[f32], lo: usize, hi: usize) -> f32 {
    let mut s = 0.0f32;
    for r in lo..hi {
        s += x[r] * read(v, r);
    }
    s
}

pub(super) fn dot_scaled_unrolled(v: &[AtomicU32], x: &[f32], lo: usize, hi: usize) -> f32 {
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut r = lo;
    while r + 3 < hi {
        s0 += x[r] * read(v, r);
        s1 += x[r + 1] * read(v, r + 1);
        s2 += x[r + 2] * read(v, r + 2);
        s3 += x[r + 3] * read(v, r + 3);
        r += 4;
    }
    while r < hi {
        s0 += x[r] * read(v, r);
        r += 1;
    }
    (s0 + s1) + (s2 + s3)
}

pub(super) fn sparse_dot_mapped<F: Fn(f32, f32) -> f32>(
    v: &[AtomicU32],
    rows: &[u32],
    vals: &[f32],
    y: &[f32],
    w_of: F,
) -> f32 {
    let mut s = 0.0f32;
    for (&r, &x) in rows.iter().zip(vals) {
        let r = r as usize;
        s += x * w_of(read(v, r), y[r]);
    }
    s
}

/// Unlocked `v[r] += delta * x[r]` for `r in [lo, hi)` (caller holds
/// the covering lock; each access is individually relaxed-atomic).
pub(super) fn axpy(v: &[AtomicU32], x: &[f32], delta: f32, lo: usize, hi: usize) {
    for r in lo..hi {
        let old = read(v, r);
        v[r].store((old + delta * x[r]).to_bits(), Ordering::Relaxed);
    }
}

/// Unlocked scatter `v[rows[k]] += delta * vals[k]` (caller holds the
/// covering lock).
pub(super) fn sparse_axpy(v: &[AtomicU32], rows: &[u32], vals: &[f32], delta: f32) {
    for (&r, &x) in rows.iter().zip(vals) {
        let r = r as usize;
        let old = read(v, r);
        v[r].store((old + delta * x).to_bits(), Ordering::Relaxed);
    }
}
