//! Portable SIMD-friendly kernels: fixed-width chunks with multiple
//! independent accumulators (instruction-level parallelism), written
//! so LLVM auto-vectorizes the unrolled lanes on any target — the
//! paper's multiple-AVX-512-accumulator strategy (§IV-A3) without
//! target-specific intrinsics.  Tails fall back to the plain loop.

/// Dot with 4 independent accumulators over 16-element chunks.
#[inline]
pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let chunks = a.len() / 16;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 16;
        let (xa, xb) = (&a[i..i + 16], &b[i..i + 16]);
        s0 += xa[0] * xb[0] + xa[1] * xb[1] + xa[2] * xb[2] + xa[3] * xb[3];
        s1 += xa[4] * xb[4] + xa[5] * xb[5] + xa[6] * xb[6] + xa[7] * xb[7];
        s2 += xa[8] * xb[8] + xa[9] * xb[9] + xa[10] * xb[10] + xa[11] * xb[11];
        s3 += xa[12] * xb[12] + xa[13] * xb[13] + xa[14] * xb[14] + xa[15] * xb[15];
    }
    let mut tail = 0.0f32;
    for i in chunks * 16..a.len() {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Elementwise FMA with no loop-carried dependence — the plain zip
/// already auto-vectorizes (each lane is independent).
#[inline]
pub(super) fn axpy(delta: f32, x: &[f32], v: &mut [f32]) {
    for (vi, xi) in v.iter_mut().zip(x) {
        *vi += delta * *xi;
    }
}

/// `||x||^2` with 4 accumulators over 16-element chunks.
#[inline]
pub(super) fn sq_norm(x: &[f32]) -> f32 {
    let chunks = x.len() / 16;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 16;
        let w = &x[i..i + 16];
        s0 += w[0] * w[0] + w[1] * w[1] + w[2] * w[2] + w[3] * w[3];
        s1 += w[4] * w[4] + w[5] * w[5] + w[6] * w[6] + w[7] * w[7];
        s2 += w[8] * w[8] + w[9] * w[9] + w[10] * w[10] + w[11] * w[11];
        s3 += w[12] * w[12] + w[13] * w[13] + w[14] * w[14] + w[15] * w[15];
    }
    let mut tail = 0.0f32;
    for v in &x[chunks * 16..] {
        tail += v * v;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Fused `(<a, b>, ||a||^2)`: 2+2 accumulators over 8-element chunks
/// (two reductions share one pass over `a`).
#[inline]
pub(super) fn dot_sq_norm(a: &[f32], b: &[f32]) -> (f32, f32) {
    let chunks = a.len() / 8;
    let (mut d0, mut d1) = (0.0f32, 0.0f32);
    let (mut q0, mut q1) = (0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        let (xa, xb) = (&a[i..i + 8], &b[i..i + 8]);
        d0 += xa[0] * xb[0] + xa[1] * xb[1] + xa[2] * xb[2] + xa[3] * xb[3];
        d1 += xa[4] * xb[4] + xa[5] * xb[5] + xa[6] * xb[6] + xa[7] * xb[7];
        q0 += xa[0] * xa[0] + xa[1] * xa[1] + xa[2] * xa[2] + xa[3] * xa[3];
        q1 += xa[4] * xa[4] + xa[5] * xa[5] + xa[6] * xa[6] + xa[7] * xa[7];
    }
    let (mut dt, mut qt) = (0.0f32, 0.0f32);
    for i in chunks * 8..a.len() {
        dt += a[i] * b[i];
        qt += a[i] * a[i];
    }
    (d0 + d1 + dt, q0 + q1 + qt)
}

/// Gathered dot with 4 accumulators over 4-entry chunks (the gathers
/// stay scalar loads; the independent accumulators still buy ILP —
/// §IV-D's "minimal chunk size of 32 enables multiple accumulators").
#[inline]
pub(super) fn sparse_dot(rows: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    let n = rows.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += vals[i] * w[rows[i] as usize];
        s1 += vals[i + 1] * w[rows[i + 1] as usize];
        s2 += vals[i + 2] * w[rows[i + 2] as usize];
        s3 += vals[i + 3] * w[rows[i + 3] as usize];
    }
    let mut tail = 0.0f32;
    for i in chunks * 4..n {
        tail += vals[i] * w[rows[i] as usize];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Scatter axpy: rows may repeat between columns but are distinct
/// within one, so there is no carried dependence to unroll around;
/// hardware scatter (AVX-512) is a ROADMAP item.
#[inline]
pub(super) fn sparse_axpy(rows: &[u32], vals: &[f32], delta: f32, v: &mut [f32]) {
    for (&r, &x) in rows.iter().zip(vals) {
        v[r as usize] += delta * x;
    }
}

/// Gathered dot over interleaved `(index, value)` pairs, 2-wide
/// unrolled (SGD's VW-style row cache; indices and values interleave,
/// so the wider dense unroll does not apply).
#[inline]
pub(super) fn pair_dot(row: &[(u32, f32)], w: &[f32]) -> f32 {
    let n = row.len();
    let chunks = n / 2;
    let (mut s0, mut s1) = (0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 2;
        s0 += row[i].1 * w[row[i].0 as usize];
        s1 += row[i + 1].1 * w[row[i + 1].0 as usize];
    }
    if n % 2 == 1 {
        s0 += row[n - 1].1 * w[row[n - 1].0 as usize];
    }
    s0 + s1
}

/// f64-accumulated `sum (a_i - b_i)^2` with 2 accumulators (objective
/// evaluations keep f64 so traces do not floor at fp32 noise).
#[inline]
pub(super) fn sq_err_f64(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1) = (0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        let r0 = (a[i] - b[i]) as f64;
        let r1 = (a[i + 1] - b[i + 1]) as f64;
        let r2 = (a[i + 2] - b[i + 2]) as f64;
        let r3 = (a[i + 3] - b[i + 3]) as f64;
        s0 += r0 * r0 + r1 * r1;
        s1 += r2 * r2 + r3 * r3;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        let r = (a[i] - b[i]) as f64;
        tail += r * r;
    }
    s0 + s1 + tail
}

/// f64-accumulated `||a||^2` with 2 accumulators.
#[inline]
pub(super) fn sq_norm_f64(a: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1) = (0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        let r0 = a[i] as f64;
        let r1 = a[i + 1] as f64;
        let r2 = a[i + 2] as f64;
        let r3 = a[i + 3] as f64;
        s0 += r0 * r0 + r1 * r1;
        s1 += r2 * r2 + r3 * r3;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        let r = a[i] as f64;
        tail += r * r;
    }
    s0 + s1 + tail
}

/// Elementwise map, 4-wide unrolled (the closure blocks vectorization;
/// unrolling still hides call/branch latency on trivial maps).
#[inline]
pub(super) fn map2_into<F: Fn(f32, f32) -> f32>(out: &mut [f32], a: &[f32], b: &[f32], f: F) {
    let n = out.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        out[i] = f(a[i], b[i]);
        out[i + 1] = f(a[i + 1], b[i + 1]);
        out[i + 2] = f(a[i + 2], b[i + 2]);
        out[i + 3] = f(a[i + 3], b[i + 3]);
    }
    for i in chunks * 4..n {
        out[i] = f(a[i], b[i]);
    }
}
