//! The unified kernel layer: every hot inner loop in the crate, behind
//! one runtime-dispatched seam (paper §IV-A3: "architecture-cognizant"
//! vectorized inner loops are where the order-of-magnitude Lasso
//! speedup comes from).  This includes the blocked multi-column sweep
//! family ([`dots_block`] and friends): bulk `u = Dᵀ_block · w` dots
//! that reuse each cache line of `w` across [`BLOCK_COLS`] columns —
//! see `rust/DESIGN.md` §8.
//!
//! Three backends implement the same kernel set:
//!
//! * [`Backend::Scalar`] — straight-line reference loops
//!   ([`scalar`]); the ground truth the differential harness
//!   (`rust/tests/kernel_diff.rs`) checks the others against.
//! * [`Backend::Portable`] — chunked/unrolled Rust with multiple
//!   independent accumulators ([`portable`]); LLVM auto-vectorizes it
//!   on any target (the paper's multiple-AVX-512-accumulator strategy,
//!   expressed portably).
//! * [`Backend::Avx2`] — explicit `std::arch` AVX2+FMA intrinsics for
//!   the dense kernels (x86-64 only, runtime-detected).  Sparse,
//!   quantized and mapped kernels fall back to the portable code —
//!   gather-based sparse SIMD and AVX-512 are ROADMAP items.
//!
//! The backend is chosen once per process: the `RUST_PALLAS_KERNELS`
//! environment variable (`scalar` | `simd` | `portable` | `avx2`) or
//! the `hthc --kernels` CLI flag override the default, which is the
//! best SIMD path the host supports.  [`set_backend`] re-points the
//! dispatch at runtime — that is an A/B-testing hook for benches and
//! the differential tests, not something engine code should call.
//!
//! Numerical contract: all backends compute the same quantity with
//! possibly different summation trees.  Any summation order of `n`
//! terms differs from any other by at most `2 (n-1) eps Σ|term_i|`
//! (standard forward-error bound), which is the bound the differential
//! tests assert — see `rust/DESIGN.md` §Kernels for the rationale.

mod atomic_impl;
mod block;
mod portable;
mod quant;
mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;

pub use quant::QGROUP;

// Data plane (`sync::raw` = std in every build): the atomic-slice
// kernels are HOGWILD bit cells whose races are by-design, and the
// BACKEND byte is a one-shot detection cache — neither is a protocol
// the model checker should interleave.
use crate::sync::raw::{AtomicU32, AtomicU8, Ordering};

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// Which kernel implementation the dispatched entry points run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Reference scalar loops.
    Scalar,
    /// Unrolled multi-accumulator Rust (auto-vectorized).
    Portable,
    /// `std::arch` AVX2+FMA dense kernels (x86-64 with runtime support).
    Avx2,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Portable => "portable",
            Backend::Avx2 => "avx2",
        }
    }

    /// Parse a `RUST_PALLAS_KERNELS` / `--kernels` spec.  `simd` maps
    /// to the best SIMD backend the host supports; requesting `avx2`
    /// on a host without AVX2+FMA resolves to `portable` (the closest
    /// supported backend) rather than failing.
    pub fn parse(spec: &str) -> Option<Backend> {
        match spec {
            "scalar" => Some(Backend::Scalar),
            "portable" => Some(Backend::Portable),
            "simd" => Some(best_simd()),
            "avx2" => Some(if avx2_available() { Backend::Avx2 } else { Backend::Portable }),
            _ => None,
        }
    }

    fn from_u8(raw: u8) -> Backend {
        match raw {
            0 => Backend::Scalar,
            1 => Backend::Portable,
            _ => Backend::Avx2,
        }
    }
}

/// Whether the host can run the AVX2+FMA dense kernels.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The best SIMD backend available on this host.
pub fn best_simd() -> Backend {
    if avx2_available() {
        Backend::Avx2
    } else {
        Backend::Portable
    }
}

/// Every backend this host can execute (scalar and portable always;
/// AVX2 when detected) — the axis the differential tests sweep.
pub fn available_backends() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar, Backend::Portable];
    if avx2_available() {
        v.push(Backend::Avx2);
    }
    v
}

const BACKEND_UNSET: u8 = u8::MAX;
static BACKEND: AtomicU8 = AtomicU8::new(BACKEND_UNSET);

/// The active backend, resolving `RUST_PALLAS_KERNELS` on first use.
/// Unknown spec values fall back to the default (best SIMD) after a
/// one-line warning rather than aborting a long training run.
#[inline]
pub fn backend() -> Backend {
    let raw = BACKEND.load(Ordering::Relaxed);
    if raw != BACKEND_UNSET {
        return Backend::from_u8(raw);
    }
    let chosen = match std::env::var("RUST_PALLAS_KERNELS") {
        Ok(spec) if !spec.is_empty() => Backend::parse(&spec).unwrap_or_else(|| {
            eprintln!(
                "warning: RUST_PALLAS_KERNELS={spec:?} not recognized \
                 (want scalar|simd|portable|avx2); using {}",
                best_simd().name()
            );
            best_simd()
        }),
        _ => best_simd(),
    };
    BACKEND.store(chosen as u8, Ordering::Relaxed);
    chosen
}

/// Re-point the dispatch (benches / differential tests only; see the
/// module docs).  Takes effect for every subsequent dispatched call in
/// the process.  Requesting [`Backend::Avx2`] on a host without
/// AVX2+FMA degrades to [`Backend::Portable`] — this is a safe fn, so
/// it must never be able to route safe callers into intrinsics the
/// CPU lacks (the AVX2 trampolines' safety contract).
pub fn set_backend(b: Backend) {
    let b = if b == Backend::Avx2 && !avx2_available() {
        Backend::Portable
    } else {
        b
    };
    BACKEND.store(b as u8, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Dense kernels
// ---------------------------------------------------------------------------

/// `<a, b>` with an explicit backend (benches, differential tests).
#[inline]
pub fn dot_with(b: Backend, x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    match b {
        Backend::Scalar => scalar::dot(x, y),
        Backend::Portable => portable::dot(x, y),
        Backend::Avx2 => dot_avx2(x, y),
    }
}

/// `<a, b>` (Eq. (3)/(4)'s `<w, d_i>` inner product).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    dot_with(backend(), x, y)
}

/// Partial dot over `[lo, hi)` — V_B-way vector splitting.  The
/// sub-range is in general unaligned to any SIMD lane width; every
/// backend handles that (differential tests exercise it).
#[inline]
pub fn dot_range_with(b: Backend, x: &[f32], y: &[f32], lo: usize, hi: usize) -> f32 {
    dot_with(b, &x[lo..hi], &y[lo..hi])
}

/// Partial dot over `[lo, hi)` on the dispatched backend.
#[inline]
pub fn dot_range(x: &[f32], y: &[f32], lo: usize, hi: usize) -> f32 {
    dot_with(backend(), &x[lo..hi], &y[lo..hi])
}

/// `v += delta * x` with an explicit backend.
#[inline]
pub fn axpy_with(b: Backend, delta: f32, x: &[f32], v: &mut [f32]) {
    debug_assert_eq!(x.len(), v.len());
    match b {
        Backend::Scalar => scalar::axpy(delta, x, v),
        Backend::Portable => portable::axpy(delta, x, v),
        Backend::Avx2 => axpy_avx2(delta, x, v),
    }
}

/// `v += delta * x` (the shared-vector maintenance step).
#[inline]
pub fn axpy(delta: f32, x: &[f32], v: &mut [f32]) {
    axpy_with(backend(), delta, x, v)
}

/// `||x||^2` with an explicit backend.
#[inline]
pub fn sq_norm_with(b: Backend, x: &[f32]) -> f32 {
    match b {
        Backend::Scalar => scalar::sq_norm(x),
        Backend::Portable => portable::sq_norm(x),
        Backend::Avx2 => sq_norm_avx2(x),
    }
}

/// `||x||^2` (column norms for the closed-form coordinate update).
#[inline]
pub fn sq_norm(x: &[f32]) -> f32 {
    sq_norm_with(backend(), x)
}

/// Fused `(<x, y>, ||x||^2)` in one pass over `x` — one memory stream
/// instead of two when a column's dot and norm are both needed (e.g.
/// normalizing while scoring, or CD without precomputed norms).
#[inline]
pub fn dot_sq_norm_with(b: Backend, x: &[f32], y: &[f32]) -> (f32, f32) {
    debug_assert_eq!(x.len(), y.len());
    match b {
        Backend::Scalar => scalar::dot_sq_norm(x, y),
        Backend::Portable => portable::dot_sq_norm(x, y),
        Backend::Avx2 => dot_sq_norm_avx2(x, y),
    }
}

/// Fused `(<x, y>, ||x||^2)` on the dispatched backend.
#[inline]
pub fn dot_sq_norm(x: &[f32], y: &[f32]) -> (f32, f32) {
    dot_sq_norm_with(backend(), x, y)
}

// AVX2 trampolines: the cfg lives here so the match arms above stay
// identical on every target (non-x86 hosts degrade to portable).
#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    // SAFETY: Backend::Avx2 is only ever selected after
    // `avx2_available()` confirmed AVX2+FMA at runtime.
    unsafe { avx2::dot(x, y) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    portable::dot(x, y)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn axpy_avx2(delta: f32, x: &[f32], v: &mut [f32]) {
    // SAFETY: as for `dot_avx2`.
    unsafe { avx2::axpy(delta, x, v) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn axpy_avx2(delta: f32, x: &[f32], v: &mut [f32]) {
    portable::axpy(delta, x, v)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn sq_norm_avx2(x: &[f32]) -> f32 {
    // SAFETY: as for `dot_avx2`.
    unsafe { avx2::sq_norm(x) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn sq_norm_avx2(x: &[f32]) -> f32 {
    portable::sq_norm(x)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_sq_norm_avx2(x: &[f32], y: &[f32]) -> (f32, f32) {
    // SAFETY: as for `dot_avx2`.
    unsafe { avx2::dot_sq_norm(x, y) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot_sq_norm_avx2(x: &[f32], y: &[f32]) -> (f32, f32) {
    portable::dot_sq_norm(x, y)
}

// ---------------------------------------------------------------------------
// Blocked multi-column sweeps (bulk `u = D_blockᵀ w`, paper §IV-A/IV-D)
// ---------------------------------------------------------------------------

/// Columns per claim/register tile for the blocked sweeps: bulk
/// consumers (task A, the baselines' full-epoch refreshes, objective
/// evaluation) claim work in blocks of this many columns, and the
/// blocked kernels tile their accumulators at the same width.
pub const BLOCK_COLS: usize = 8;

/// Blocked dense dots `out[k] = <cols[k], w>` with an explicit backend.
/// The SIMD backends traverse rows in cache blocks and columns in
/// register-tiled pairs so each `w` load feeds many columns; the scalar
/// backend is the per-column reference (bitwise-identical to calling
/// [`dot_with`] per column).
#[inline]
pub fn dots_block_with(b: Backend, cols: &[&[f32]], w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(cols.len(), out.len());
    debug_assert!(cols.iter().all(|c| c.len() == w.len()));
    match b {
        Backend::Scalar => {
            for (o, col) in out.iter_mut().zip(cols) {
                *o = scalar::dot(col, w);
            }
        }
        Backend::Portable => block::dots_dense(cols, w, out),
        Backend::Avx2 => dots_block_avx2(cols, w, out),
    }
}

/// Blocked dense dots on the dispatched backend.
#[inline]
pub fn dots_block(cols: &[&[f32]], w: &[f32], out: &mut [f32]) {
    dots_block_with(backend(), cols, w, out)
}

/// Blocked sparse dots over row-sorted columns, with an explicit
/// backend: `out[k] = sum_e vals_k[e] * w[rows_k[e]]`.  The SIMD
/// backends walk all columns' entries in one banded pass over the row
/// space (per-column cursors); scalar is the per-column reference.
#[inline]
pub fn sparse_dots_block_with(b: Backend, cols: &[(&[u32], &[f32])], w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(cols.len(), out.len());
    match b {
        Backend::Scalar => {
            for (o, &(rows, vals)) in out.iter_mut().zip(cols) {
                *o = scalar::sparse_dot(rows, vals, w);
            }
        }
        Backend::Portable | Backend::Avx2 => block::sparse_dots_banded(cols, w, out),
    }
}

/// Blocked sparse dots on the dispatched backend.
#[inline]
pub fn sparse_dots_block(cols: &[(&[u32], &[f32])], w: &[f32], out: &mut [f32]) {
    sparse_dots_block_with(backend(), cols, w, out)
}

/// Blocked quantized dots over packed 4-bit columns, with an explicit
/// backend: `out[k]` is column k's unpack-dot against `w` (rows
/// `0..w.len()`).  The SIMD backends reuse each group-aligned `w` band
/// across all columns; scalar is the per-column reference.
#[inline]
pub fn quant_dots_block_with(b: Backend, cols: &[(&[u8], &[f32])], w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(cols.len(), out.len());
    debug_assert!(cols.iter().all(|&(p, _)| w.len() <= p.len() * 2));
    match b {
        Backend::Scalar => {
            for (o, &(packed, scales)) in out.iter_mut().zip(cols) {
                *o = quant::dot_range_scalar(packed, scales, w, 0, w.len());
            }
        }
        Backend::Portable | Backend::Avx2 => block::quant_dots_banded(cols, w, out),
    }
}

/// Blocked quantized dots on the dispatched backend.
#[inline]
pub fn quant_dots_block(cols: &[(&[u8], &[f32])], w: &[f32], out: &mut [f32]) {
    quant_dots_block_with(backend(), cols, w, out)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn dots_block_avx2(cols: &[&[f32]], w: &[f32], out: &mut [f32]) {
    // SAFETY: as for `dot_avx2`.
    unsafe { avx2::dots_block(cols, w, out) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dots_block_avx2(cols: &[&[f32]], w: &[f32], out: &mut [f32]) {
    block::dots_dense(cols, w, out)
}

// ---------------------------------------------------------------------------
// Sparse kernels (index-gather over parallel (rows, vals) slices)
// ---------------------------------------------------------------------------

/// Sparse gather dot `sum_k vals[k] * w[rows[k]]` with an explicit
/// backend.  AVX2 has no dense-kernel advantage here (a hardware
/// gather pass is a ROADMAP item), so `Avx2` runs the portable code.
#[inline]
pub fn sparse_dot_with(b: Backend, rows: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(rows.len(), vals.len());
    match b {
        Backend::Scalar => scalar::sparse_dot(rows, vals, w),
        Backend::Portable | Backend::Avx2 => portable::sparse_dot(rows, vals, w),
    }
}

/// Sparse gather dot on the dispatched backend.
#[inline]
pub fn sparse_dot(rows: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    sparse_dot_with(backend(), rows, vals, w)
}

/// Sparse scatter axpy `v[rows[k]] += delta * vals[k]` with an explicit
/// backend (scatter has no portable SIMD form; kept here so the whole
/// hot-loop inventory lives behind one seam).
#[inline]
pub fn sparse_axpy_with(b: Backend, rows: &[u32], vals: &[f32], delta: f32, v: &mut [f32]) {
    debug_assert_eq!(rows.len(), vals.len());
    match b {
        Backend::Scalar => scalar::sparse_axpy(rows, vals, delta, v),
        Backend::Portable | Backend::Avx2 => portable::sparse_axpy(rows, vals, delta, v),
    }
}

/// Sparse scatter axpy on the dispatched backend.
#[inline]
pub fn sparse_axpy(rows: &[u32], vals: &[f32], delta: f32, v: &mut [f32]) {
    sparse_axpy_with(backend(), rows, vals, delta, v)
}

// ---------------------------------------------------------------------------
// 4-bit quantized kernels (two codes per byte, one scale per QGROUP)
// ---------------------------------------------------------------------------

/// Quantized unpack-dot over rows `[lo, hi)` with an explicit backend:
/// `sum_g scale[g] * sum_{r in g} code(packed, r) * w[r]`.  `lo` must
/// be [`QGROUP`]-aligned; `hi` may be arbitrary (partial final group).
#[inline]
pub fn quant_dot_range_with(
    b: Backend,
    packed: &[u8],
    scales: &[f32],
    w: &[f32],
    lo: usize,
    hi: usize,
) -> f32 {
    debug_assert!(lo % QGROUP == 0, "lo must be group-aligned");
    debug_assert!(hi <= packed.len() * 2 && hi <= w.len());
    match b {
        Backend::Scalar => quant::dot_range_scalar(packed, scales, w, lo, hi),
        Backend::Portable | Backend::Avx2 => quant::dot_range_lut(packed, scales, w, lo, hi),
    }
}

/// Quantized unpack-dot on the dispatched backend.
#[inline]
pub fn quant_dot_range(packed: &[u8], scales: &[f32], w: &[f32], lo: usize, hi: usize) -> f32 {
    quant_dot_range_with(backend(), packed, scales, w, lo, hi)
}

/// Quantized unpack-axpy `v[r] += delta * scale[g(r)] * code(packed, r)`
/// over the whole column, with an explicit backend.  `v.len()` must be
/// a multiple of [`QGROUP`] with `scales.len() * QGROUP == v.len()`.
#[inline]
pub fn quant_axpy_with(b: Backend, packed: &[u8], scales: &[f32], delta: f32, v: &mut [f32]) {
    debug_assert_eq!(scales.len() * QGROUP, v.len());
    debug_assert_eq!(packed.len() * 2, v.len());
    match b {
        Backend::Scalar => quant::axpy_scalar(packed, scales, delta, v),
        Backend::Portable | Backend::Avx2 => quant::axpy_lut(packed, scales, delta, v),
    }
}

/// Quantized unpack-axpy on the dispatched backend.
#[inline]
pub fn quant_axpy(packed: &[u8], scales: &[f32], delta: f32, v: &mut [f32]) {
    quant_axpy_with(backend(), packed, scales, delta, v)
}

/// Decode one 4-bit code (row `r` parity picks the nibble) — the shared
/// scalar decode used by reference paths and column densification.
#[inline(always)]
pub fn quant_code(byte: u8, even: bool) -> i32 {
    quant::code_of(byte, even)
}

// ---------------------------------------------------------------------------
// Interleaved-pair kernels (row-major (index, value) pair slices — the
// SGD baseline's VW-style row cache)
// ---------------------------------------------------------------------------

/// Gathered dot over interleaved `(index, value)` pairs, with an
/// explicit backend: `sum_k vals_k * w[idx_k]`.
#[inline]
pub fn pair_dot_with(b: Backend, row: &[(u32, f32)], w: &[f32]) -> f32 {
    match b {
        Backend::Scalar => scalar::pair_dot(row, w),
        Backend::Portable | Backend::Avx2 => portable::pair_dot(row, w),
    }
}

/// Gathered pair dot on the dispatched backend.
#[inline]
pub fn pair_dot(row: &[(u32, f32)], w: &[f32]) -> f32 {
    pair_dot_with(backend(), row, w)
}

/// `sum_k vals_k^2` over interleaved pairs (row-norm step scaling).
#[inline]
pub fn pair_sq_norm(row: &[(u32, f32)]) -> f32 {
    let mut s = 0.0f32;
    for &(_, x) in row {
        s += x * x;
    }
    s
}

// ---------------------------------------------------------------------------
// Scaled scatter drivers (per-element-synchronized baselines)
// ---------------------------------------------------------------------------
//
// OMP / PASSCoDe update `v` one element at a time (atomic or racy-wild
// add) — that per-element synchronization IS the baseline being
// compared against, so the kernel only owns the iteration and scaling;
// the caller supplies the per-element sink.

/// Drive `sink(r, delta * x[r])` over a dense column.
#[inline]
pub fn scaled_scatter<F: FnMut(usize, f32)>(x: &[f32], delta: f32, mut sink: F) {
    for (r, &xi) in x.iter().enumerate() {
        sink(r, delta * xi);
    }
}

/// Drive `sink(rows[k], delta * vals[k])` over a sparse column.
#[inline]
pub fn scaled_scatter_sparse<F: FnMut(usize, f32)>(
    rows: &[u32],
    vals: &[f32],
    delta: f32,
    mut sink: F,
) {
    for (&r, &x) in rows.iter().zip(vals) {
        sink(r as usize, delta * x);
    }
}

// ---------------------------------------------------------------------------
// f64-accumulated residual reductions (objective / trace evaluations)
// ---------------------------------------------------------------------------

/// `sum_i (a_i - b_i)^2` accumulated in f64, with an explicit backend.
#[inline]
pub fn sq_err_f64_with(back: Backend, a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match back {
        Backend::Scalar => scalar::sq_err_f64(a, b),
        Backend::Portable | Backend::Avx2 => portable::sq_err_f64(a, b),
    }
}

/// `sum_i (a_i - b_i)^2` accumulated in f64 — the squared-loss residual
/// shared by the Lasso/ridge/elastic-net objectives.  f64 so the
/// convergence traces do not floor at fp32 accumulation noise.
#[inline]
pub fn sq_err_f64(a: &[f32], b: &[f32]) -> f64 {
    sq_err_f64_with(backend(), a, b)
}

/// f64-accumulated `||a||^2` with an explicit backend.
#[inline]
pub fn sq_norm_f64_with(back: Backend, a: &[f32]) -> f64 {
    match back {
        Backend::Scalar => scalar::sq_norm_f64(a),
        Backend::Portable | Backend::Avx2 => portable::sq_norm_f64(a),
    }
}

/// `||a||^2` accumulated in f64 (the SVM-family objective term).
#[inline]
pub fn sq_norm_f64(a: &[f32]) -> f64 {
    sq_norm_f64_with(backend(), a)
}

// ---------------------------------------------------------------------------
// Elementwise residual map
// ---------------------------------------------------------------------------

/// Elementwise map with an explicit backend (see [`map2_into`]).
#[inline]
pub fn map2_into_with<F: Fn(f32, f32) -> f32>(
    back: Backend,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    f: F,
) {
    debug_assert!(a.len() >= out.len() && b.len() >= out.len());
    match back {
        Backend::Scalar => scalar::map2_into(out, a, b, f),
        Backend::Portable | Backend::Avx2 => portable::map2_into(out, a, b, f),
    }
}

/// `out[i] = f(a[i], b[i])` — the `v -> w` residual/dual map
/// (`glm::w_from_v` and the per-epoch `w` snapshots).  The map closure
/// blocks real SIMD, so the backends differ only in unrolling; kept in
/// the kernel layer so every elementwise hot loop shares one home.
#[inline]
pub fn map2_into<F: Fn(f32, f32) -> f32>(out: &mut [f32], a: &[f32], b: &[f32], f: F) {
    map2_into_with(backend(), out, a, b, f)
}

// ---------------------------------------------------------------------------
// Atomic-slice kernels (SharedVector's hot paths)
// ---------------------------------------------------------------------------
//
// The shared vector stores f32 bits in `AtomicU32` so racy reads are
// defined; these kernels stream those atomics with relaxed ordering.
// The caller owns all locking discipline (chunk locks around the axpy
// variants) — these are the lock-free inner bodies only.

/// Fused stale dot `sum_r x[r] * w_of(v[r], y[r])` over `[lo, hi)`
/// against live atomic `v` (task B's read path).
#[inline]
pub fn dot_mapped_atomic<F: Fn(f32, f32) -> f32>(
    v: &[AtomicU32],
    x: &[f32],
    y: &[f32],
    w_of: F,
    lo: usize,
    hi: usize,
) -> f32 {
    match backend() {
        Backend::Scalar => atomic_impl::dot_mapped_scalar(v, x, y, w_of, lo, hi),
        Backend::Portable | Backend::Avx2 => {
            atomic_impl::dot_mapped_unrolled(v, x, y, w_of, lo, hi)
        }
    }
}

/// Scaled plain dot `scale * sum_r x[r] * v[r]` over `[lo, hi)` — the
/// y-free fast path for models with `w = scale * v` (SVM family).
#[inline]
pub fn dot_scaled_atomic(v: &[AtomicU32], x: &[f32], scale: f32, lo: usize, hi: usize) -> f32 {
    match backend() {
        Backend::Scalar => atomic_impl::dot_scaled_scalar(v, x, lo, hi) * scale,
        Backend::Portable | Backend::Avx2 => atomic_impl::dot_scaled_unrolled(v, x, lo, hi) * scale,
    }
}

/// Sparse variant of [`dot_mapped_atomic`] over gathered entries.
#[inline]
pub fn sparse_dot_mapped_atomic<F: Fn(f32, f32) -> f32>(
    v: &[AtomicU32],
    rows: &[u32],
    vals: &[f32],
    y: &[f32],
    w_of: F,
) -> f32 {
    // gathered entries + a closure: no profitable unrolling split —
    // one shared implementation for all backends.
    atomic_impl::sparse_dot_mapped(v, rows, vals, y, w_of)
}

/// Unlocked dense axpy body `v[r] += delta * x[r]` for `r in [lo, hi)`
/// (relaxed load/store; the caller holds the covering chunk lock).
#[inline]
pub fn axpy_atomic(v: &[AtomicU32], x: &[f32], delta: f32, lo: usize, hi: usize) {
    atomic_impl::axpy(v, x, delta, lo, hi)
}

/// Unlocked sparse scatter body `v[rows[k]] += delta * vals[k]`
/// (relaxed; caller holds the covering chunk lock).
#[inline]
pub fn sparse_axpy_atomic(v: &[AtomicU32], rows: &[u32], vals: &[f32], delta: f32) {
    atomic_impl::sparse_axpy(v, rows, vals, delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that read-or-flip the process-global
    /// backend (cargo runs unit tests on parallel threads).
    static BACKEND_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn parse_specs() {
        assert_eq!(Backend::parse("scalar"), Some(Backend::Scalar));
        assert_eq!(Backend::parse("portable"), Some(Backend::Portable));
        assert_eq!(Backend::parse("simd"), Some(best_simd()));
        // avx2 resolves to something runnable on every host
        let avx2 = Backend::parse("avx2").unwrap();
        assert!(avx2 == Backend::Avx2 || avx2 == Backend::Portable);
        assert_eq!(Backend::parse("neon"), None);
        assert_eq!(Backend::parse(""), None);
    }

    #[test]
    fn available_backends_start_with_scalar_and_portable() {
        let all = available_backends();
        assert!(all.len() >= 2);
        assert_eq!(all[0], Backend::Scalar);
        assert_eq!(all[1], Backend::Portable);
        assert_eq!(all.contains(&Backend::Avx2), avx2_available());
    }

    #[test]
    fn backend_names_roundtrip_through_parse() {
        for b in [Backend::Scalar, Backend::Portable] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
    }

    #[test]
    fn every_backend_agrees_on_a_tiny_dot() {
        let a = [1.0f32, -2.0, 3.0, 0.5, 4.0];
        let b = [2.0f32, 1.0, -1.0, 8.0, 0.25];
        let want = 2.0f32 - 2.0 - 3.0 + 4.0 + 1.0;
        for back in available_backends() {
            let got = dot_with(back, &a, &b);
            assert!((got - want).abs() < 1e-5, "{}: {got}", back.name());
        }
    }

    #[test]
    fn dispatched_matches_selected_backend() {
        let _l = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let a: Vec<f32> = (0..100).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..100).map(|i| (i % 5) as f32 - 2.0).collect();
        assert_eq!(dot(&a, &b), dot_with(backend(), &a, &b));
    }

    #[test]
    fn set_backend_never_selects_unsupported_avx2() {
        // safe fn contract: must not be able to route safe callers
        // into intrinsics the CPU lacks
        let _l = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = backend();
        set_backend(Backend::Avx2);
        let eff = backend();
        set_backend(prev); // restore before asserting (other tests)
        if avx2_available() {
            assert_eq!(eff, Backend::Avx2);
        } else {
            assert_eq!(eff, Backend::Portable);
        }
    }
}
