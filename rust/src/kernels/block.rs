//! Blocked multi-column sweep kernels (paper §IV-A/IV-D).
//!
//! Task A's whole budget goes into bulk `u_j = <w, d_j>` sweeps, and
//! the paper's KNL implementation wins by traversing *many columns per
//! pass over `w`*: each cache line of `w` is loaded once and reused
//! across a block of B columns instead of being streamed again for
//! every single-column dot.  The kernels here implement that scheme:
//!
//! * columns are processed in register tiles of [`super::BLOCK_COLS`]
//!   with one accumulator per column (column *pairs* share each `w`
//!   load, so the reuse is explicit in registers, not just in cache);
//! * rows are traversed in [`ROW_BLOCK`]-sized cache blocks, so the
//!   active window of `w` stays L1/L2-resident while the B column
//!   blocks stream past it;
//! * the sparse and quantized variants walk all B columns' entries in
//!   one banded pass over the row space, with per-column cursors
//!   (sparse) or group-aligned row windows (quantized).
//!
//! The scalar backend intentionally bypasses all of this: it computes
//! each column with the plain per-column reference dot, which makes it
//! bitwise-identical to the single-column path and the ground truth the
//! blocked differential tests (`rust/tests/block_diff.rs`) compare
//! against.

use super::{portable, quant, BLOCK_COLS};

/// Rows per cache block: 4096 f32 = 16 KiB of `w` per band, half a
/// typical 32 KiB L1d so the band and one column tile coexist.  Must be
/// a multiple of [`super::QGROUP`] (the quantized variant reuses the
/// same banding and `quant_dot_range` requires group-aligned `lo`) —
/// enforced at compile time below, since an unaligned band start would
/// silently double-count the rows shared with the previous band's
/// group.
pub(super) const ROW_BLOCK: usize = 4096;

const _: () = assert!(ROW_BLOCK % quant::QGROUP == 0, "bands must stay scale-group aligned");

/// Dense blocked dots: `out[k] = <cols[k], w>`, portable backend.
/// Accepts any number of columns; tiles them by [`BLOCK_COLS`]
/// internally so the accumulators stay in registers.
pub(super) fn dots_dense(cols: &[&[f32]], w: &[f32], out: &mut [f32]) {
    let d = w.len();
    for (tile, otile) in cols.chunks(BLOCK_COLS).zip(out.chunks_mut(BLOCK_COLS)) {
        let mut acc = [0.0f32; BLOCK_COLS];
        let mut lo = 0usize;
        while lo < d {
            let hi = (lo + ROW_BLOCK).min(d);
            let wb = &w[lo..hi];
            let mut k = 0usize;
            while k + 1 < tile.len() {
                let (s0, s1) = dot2(&tile[k][lo..hi], &tile[k + 1][lo..hi], wb);
                acc[k] += s0;
                acc[k + 1] += s1;
                k += 2;
            }
            if k < tile.len() {
                acc[k] += portable::dot(&tile[k][lo..hi], wb);
            }
            lo = hi;
        }
        otile.copy_from_slice(&acc[..tile.len()]);
    }
}

/// Two dots sharing one pass over `w`: `(<a, w>, <b, w>)` with two
/// independent accumulators per column over 8-element chunks — the
/// register-tile primitive the dense blocked sweep is built from.
fn dot2(a: &[f32], b: &[f32], w: &[f32]) -> (f32, f32) {
    let n = w.len();
    let chunks = n / 8;
    let (mut a0, mut a1) = (0.0f32, 0.0f32);
    let (mut b0, mut b1) = (0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        let (xa, xb, xw) = (&a[i..i + 8], &b[i..i + 8], &w[i..i + 8]);
        a0 += xa[0] * xw[0] + xa[1] * xw[1] + xa[2] * xw[2] + xa[3] * xw[3];
        a1 += xa[4] * xw[4] + xa[5] * xw[5] + xa[6] * xw[6] + xa[7] * xw[7];
        b0 += xb[0] * xw[0] + xb[1] * xw[1] + xb[2] * xw[2] + xb[3] * xw[3];
        b1 += xb[4] * xw[4] + xb[5] * xw[5] + xb[6] * xw[6] + xb[7] * xw[7];
    }
    let (mut at, mut bt) = (0.0f32, 0.0f32);
    for i in chunks * 8..n {
        at += a[i] * w[i];
        bt += b[i] * w[i];
    }
    (a0 + a1 + at, b0 + b1 + bt)
}

/// Sparse blocked dots over row-sorted CSC columns: a banded pass over
/// the row space with a cursor per column, so the `w` rows a band
/// touches stay cache-hot across all B columns (entries outside the
/// band are never scanned — the cursor advances by binary search).
/// Bands with no entries in *any* tile column are skipped outright by
/// jumping to the band of the smallest unconsumed row, so the loop
/// count is bounded by the tile's populated bands, not `d / ROW_BLOCK`
/// — tall, very sparse matrices would otherwise pay thousands of empty
/// band iterations per tile and lose to the per-column path.
pub(super) fn sparse_dots_banded(cols: &[(&[u32], &[f32])], w: &[f32], out: &mut [f32]) {
    let d = w.len();
    for (tile, otile) in cols.chunks(BLOCK_COLS).zip(out.chunks_mut(BLOCK_COLS)) {
        let mut cur = [0usize; BLOCK_COLS];
        let mut acc = [0.0f32; BLOCK_COLS];
        while let Some(next) = tile
            .iter()
            .zip(&cur)
            .filter_map(|(&(rows, _), &c)| rows.get(c).map(|&r| r as usize))
            .min()
        {
            if next >= d {
                break; // malformed out-of-range rows: never consumable
            }
            let lo = next - next % ROW_BLOCK;
            let hi = (lo + ROW_BLOCK).min(d);
            for (k, &(rows, vals)) in tile.iter().enumerate() {
                let s = cur[k];
                let e = s + rows[s..].partition_point(|&r| (r as usize) < hi);
                if e > s {
                    acc[k] += portable::sparse_dot(&rows[s..e], &vals[s..e], w);
                }
                cur[k] = e;
            }
        }
        otile.copy_from_slice(&acc[..tile.len()]);
    }
}

/// Quantized blocked dots over packed 4-bit columns: group-aligned row
/// bands (ROW_BLOCK is a QGROUP multiple), each band's `w` window
/// reused across all B columns' unpack-dots.
pub(super) fn quant_dots_banded(cols: &[(&[u8], &[f32])], w: &[f32], out: &mut [f32]) {
    let d = w.len();
    for (tile, otile) in cols.chunks(BLOCK_COLS).zip(out.chunks_mut(BLOCK_COLS)) {
        let mut acc = [0.0f32; BLOCK_COLS];
        let mut lo = 0usize;
        while lo < d {
            let hi = (lo + ROW_BLOCK).min(d);
            for (k, &(packed, scales)) in tile.iter().enumerate() {
                acc[k] += quant::dot_range_lut(packed, scales, w, lo, hi);
            }
            lo = hi;
        }
        otile.copy_from_slice(&acc[..tile.len()]);
    }
}
