//! 4-bit quantized unpack kernels (paper §IV-E, Clover-style).
//!
//! Codes live two-per-byte (low nibble = even row), biased by +8 into
//! `[0, 15]`; one f32 scale per [`QGROUP`]-element group.  The kernels
//! accumulate each group at code precision and apply the scale once
//! per group (hoisted), trading unpack ALU for 4x less data movement.
//!
//! The scalar reference decodes nibbles arithmetically; the SIMD-path
//! implementation replaces the two shift/mask/convert chains per byte
//! with one L1-resident 2 KiB lookup table (§Perf: measured faster
//! than the arithmetic unpack — the table stays hot).

/// Elements per scale group — must match `ref.QGROUP` on the python
/// side (`python/compile/kernels/ref.py`).
pub const QGROUP: usize = 64;

/// byte -> (low-nibble value, high-nibble value), debiased to [-8, 7].
/// Built at compile time: float arithmetic is allowed in `static`
/// initializers (unlike in `const fn` on older toolchains), so this
/// needs no lazy-init dependency.
static NIBBLE_LUT: [[f32; 2]; 256] = {
    let mut lut = [[0.0f32; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        lut[b][0] = (b & 0xF) as f32 - 8.0;
        lut[b][1] = (b >> 4) as f32 - 8.0;
        b += 1;
    }
    lut
};

/// Decode one 4-bit code: row parity picks the nibble.
#[inline(always)]
pub(super) fn code_of(byte: u8, even: bool) -> i32 {
    let nib = if even { byte & 0xF } else { byte >> 4 };
    nib as i32 - 8
}

/// Scalar reference unpack-dot over rows `[lo, hi)`, `lo` group-aligned.
pub(super) fn dot_range_scalar(
    packed: &[u8],
    scales: &[f32],
    w: &[f32],
    lo: usize,
    hi: usize,
) -> f32 {
    let mut total = 0.0f32;
    let g_lo = lo / QGROUP;
    let g_hi = hi.div_ceil(QGROUP);
    for g in g_lo..g_hi {
        let base = g * QGROUP;
        let end = (base + QGROUP).min(hi);
        let mut s = 0.0f32;
        for r in base..end {
            s += code_of(packed[r / 2], r % 2 == 0) as f32 * w[r];
        }
        total += s * scales[g];
    }
    total
}

/// LUT-based unpack-dot with 4 accumulators (two bytes -> four codes
/// per step), same group/scale structure as the scalar reference.
pub(super) fn dot_range_lut(
    packed: &[u8],
    scales: &[f32],
    w: &[f32],
    lo: usize,
    hi: usize,
) -> f32 {
    let lut = &NIBBLE_LUT;
    let mut total = 0.0f32;
    let g_lo = lo / QGROUP;
    let g_hi = hi.div_ceil(QGROUP);
    for g in g_lo..g_hi {
        let base = g * QGROUP;
        let end = (base + QGROUP).min(hi);
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut r = base;
        while r + 3 < end {
            let b0 = lut[packed[r / 2] as usize];
            let b1 = lut[packed[r / 2 + 1] as usize];
            s0 += b0[0] * w[r];
            s1 += b0[1] * w[r + 1];
            s2 += b1[0] * w[r + 2];
            s3 += b1[1] * w[r + 3];
            r += 4;
        }
        while r < end {
            s0 += code_of(packed[r / 2], r % 2 == 0) as f32 * w[r];
            r += 1;
        }
        total += ((s0 + s1) + (s2 + s3)) * scales[g];
    }
    total
}

/// Scalar reference unpack-axpy over the whole column.
pub(super) fn axpy_scalar(packed: &[u8], scales: &[f32], delta: f32, v: &mut [f32]) {
    for (g, &scale) in scales.iter().enumerate() {
        let ds = delta * scale;
        let base = g * QGROUP;
        for r in base..base + QGROUP {
            v[r] += code_of(packed[r / 2], r % 2 == 0) as f32 * ds;
        }
    }
}

/// LUT-based unpack-axpy: one table load yields both nibbles of a byte.
pub(super) fn axpy_lut(packed: &[u8], scales: &[f32], delta: f32, v: &mut [f32]) {
    let lut = &NIBBLE_LUT;
    for (g, &scale) in scales.iter().enumerate() {
        let ds = delta * scale;
        let base = g * QGROUP;
        let mut r = base;
        while r + 1 < base + QGROUP {
            let pair = lut[packed[r / 2] as usize];
            v[r] += pair[0] * ds;
            v[r + 1] += pair[1] * ds;
            r += 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_code_of() {
        for b in 0..=255u8 {
            assert_eq!(NIBBLE_LUT[b as usize][0], code_of(b, true) as f32);
            assert_eq!(NIBBLE_LUT[b as usize][1], code_of(b, false) as f32);
        }
    }

    #[test]
    fn code_range_is_centered() {
        assert_eq!(code_of(0x00, true), -8);
        assert_eq!(code_of(0x0F, true), 7);
        assert_eq!(code_of(0xF0, false), 7);
        assert_eq!(code_of(0x80, true), -8);
    }
}
