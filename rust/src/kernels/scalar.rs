//! Reference scalar kernels: the simplest correct loops, in the
//! left-to-right summation order.  These are the ground truth the
//! differential harness (`rust/tests/kernel_diff.rs`) measures the
//! SIMD backends against, and the `RUST_PALLAS_KERNELS=scalar` A/B
//! baseline — keep them boring.

#[inline]
pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

#[inline]
pub(super) fn axpy(delta: f32, x: &[f32], v: &mut [f32]) {
    for (vi, xi) in v.iter_mut().zip(x) {
        *vi += delta * *xi;
    }
}

#[inline]
pub(super) fn sq_norm(x: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for v in x {
        s += v * v;
    }
    s
}

#[inline]
pub(super) fn dot_sq_norm(a: &[f32], b: &[f32]) -> (f32, f32) {
    let (mut d, mut q) = (0.0f32, 0.0f32);
    for (x, y) in a.iter().zip(b) {
        d += x * y;
        q += x * x;
    }
    (d, q)
}

#[inline]
pub(super) fn sparse_dot(rows: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&r, &x) in rows.iter().zip(vals) {
        s += x * w[r as usize];
    }
    s
}

#[inline]
pub(super) fn sparse_axpy(rows: &[u32], vals: &[f32], delta: f32, v: &mut [f32]) {
    for (&r, &x) in rows.iter().zip(vals) {
        v[r as usize] += delta * x;
    }
}

#[inline]
pub(super) fn map2_into<F: Fn(f32, f32) -> f32>(out: &mut [f32], a: &[f32], b: &[f32], f: F) {
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = f(x, y);
    }
}

#[inline]
pub(super) fn pair_dot(row: &[(u32, f32)], w: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for &(j, x) in row {
        s += x * w[j as usize];
    }
    s
}

#[inline]
pub(super) fn sq_err_f64(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let r = (x - y) as f64;
        s += r * r;
    }
    s
}

#[inline]
pub(super) fn sq_norm_f64(a: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &x in a {
        let r = x as f64;
        s += r * r;
    }
    s
}
