//! Minimal error plumbing (anyhow stand-in; no external deps offline).
//!
//! Provides the three things the crate actually uses from `anyhow`:
//! a boxed message-plus-source [`Error`], the [`Context`] extension on
//! `Result`/`Option`, and the [`err!`](crate::err)/[`bail!`](crate::bail)
//! macros.  `Display` prints `message: source` so wrapped I/O and parse
//! errors stay legible in CLI output.

use std::fmt;

/// A message with an optional boxed source error.
#[derive(Debug)]
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into(), source: None }
    }

    pub fn wrap(
        m: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        Error { msg: m.into(), source: Some(Box::new(source)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(s) = &self.source {
            write!(f, ": {s}")?;
        }
        Ok(())
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::wrap("I/O error", e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// `.context(msg)` / `.with_context(|| msg)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::wrap(c.to_string(), e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::wrap(f().to_string(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Return early with a formatted [`Error`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_chains_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = Error::wrap("open file", io);
        let s = format!("{e}");
        assert!(s.starts_with("open file: "), "{s}");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u8> = None;
        assert!(none.context("missing").is_err());
        let bad: std::result::Result<u8, std::num::ParseIntError> = "x".parse();
        let e = bad.with_context(|| format!("parse {}", "x")).unwrap_err();
        assert!(format!("{e}").contains("parse x"));
    }

    #[test]
    fn bail_macro_returns_err() {
        fn f(x: u8) -> Result<u8> {
            if x == 0 {
                bail!("zero not allowed ({x})");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert_eq!(f(3).unwrap(), 3);
    }
}
