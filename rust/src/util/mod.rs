//! Dependency-free utilities: PRNG, CLI parsing, timers, error plumbing.

pub mod cli;
pub mod error;
pub mod prng;
pub mod timer;

pub use cli::Args;
pub use error::{Context, Error};
pub use prng::Rng;
pub use timer::Timer;

/// Align `x` up to a multiple of `to` (used for tile padding).
#[inline]
pub fn align_up(x: usize, to: usize) -> usize {
    debug_assert!(to > 0);
    x.div_ceil(to) * to
}

/// Pretty-print a byte count.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Pretty-print seconds with adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basic() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 8), 16);
        assert_eq!(align_up(1023, 1024), 1024);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
