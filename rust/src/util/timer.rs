//! Wall-clock timing plus a cycle model.
//!
//! The paper reports flops/cycle on a 1.5 GHz KNL.  We time in seconds
//! and convert through a configurable clock so benches can print the
//! paper's units; `CYCLES_PER_SEC` defaults to the KNL base frequency so
//! "flops/cycle" figures are directly comparable in *shape* (see
//! DESIGN.md §5 on measured vs modeled numbers).

use std::time::Instant;

/// KNL base frequency used for flops/cycle conversions.
pub const KNL_HZ: f64 = 1.5e9;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    #[inline]
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// flops/cycle at the KNL reference clock, given work and elapsed time.
pub fn flops_per_cycle(flops: f64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    flops / (secs * KNL_HZ)
}

/// Run `f` repeatedly until `min_secs` of total time or `max_reps`
/// repetitions, returning (median_secs, reps).  Dependency-free
/// criterion stand-in used by the bench harnesses.
pub fn bench_median<F: FnMut()>(mut f: F, min_secs: f64, max_reps: usize) -> (f64, usize) {
    let mut times = Vec::new();
    let total = Timer::start();
    loop {
        let t = Timer::start();
        f();
        times.push(t.secs());
        if times.len() >= max_reps || (total.secs() >= min_secs && times.len() >= 3) {
            break;
        }
    }
    times.sort_by(|a, b| a.total_cmp(b));
    (times[times.len() / 2], times.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn flops_per_cycle_math() {
        // 1.5e9 flops in 1s at 1.5GHz = 1 flop/cycle
        assert!((flops_per_cycle(KNL_HZ, 1.0) - 1.0).abs() < 1e-12);
        assert_eq!(flops_per_cycle(1.0, 0.0), 0.0);
    }

    #[test]
    fn bench_median_runs_at_least_three() {
        let mut n = 0;
        let (med, reps) = bench_median(|| n += 1, 0.0, 100);
        assert!(reps >= 3);
        assert!(med >= 0.0);
        assert!(n >= 3);
    }
}
