//! Minimal CLI argument parser (no clap offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments.  Typed getters with defaults keep call sites
//! terse; unknown-flag detection catches typos in bench scripts.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were actually read by the program (for typo detection).
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            // PANIC-OK: peek() just returned Some.
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    fn raw(&self, key: &str) -> Option<&str> {
        let v = self.flags.get(key).map(|s| s.as_str());
        if v.is_some() {
            self.consumed.borrow_mut().insert(key.to_string());
        }
        v
    }

    pub fn has(&self, key: &str) -> bool {
        self.raw(key).is_some()
    }

    pub fn get(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.typed_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.typed_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.typed_or(key, default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.typed_or(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.raw(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(other) => panic!("--{key}: expected bool, got {other:?}"),
        }
    }

    fn typed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.raw(key) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={s}: {e}")),
        }
    }

    /// List of `--flags` that were provided but never read — call after
    /// all getters to catch typos.
    pub fn unknown(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.flags
            .keys()
            .filter(|k| !consumed.contains(*k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse("--threads 8 --lam=0.5");
        assert_eq!(a.usize_or("threads", 0), 8);
        assert!((a.f64_or("lam", 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("--verbose --quant=false train");
        assert!(a.bool_or("verbose", false));
        assert!(!a.bool_or("quant", true));
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("--wild --threads 4");
        assert!(a.bool_or("wild", false));
        assert_eq!(a.usize_or("threads", 0), 4);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.usize_or("missing", 42), 42);
        assert_eq!(a.str_or("name", "x"), "x");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("--known 1 --typo 2");
        let _ = a.usize_or("known", 0);
        assert_eq!(a.unknown(), vec!["typo".to_string()]);
    }

    #[test]
    #[should_panic]
    fn bad_type_panics() {
        let a = parse("--threads abc");
        let _ = a.usize_or("threads", 0);
    }
}
