//! Small, fast, reproducible PRNG (xoshiro256++ core with splitmix64
//! seeding).  No external `rand` crate is available offline; the paper's
//! code similarly rolls its own sampling for task A's random coordinate
//! picks, where the PRNG sits on the hot path.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic seeding: every distinct seed gives a distinct,
    /// well-mixed stream (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-thread RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift reduction).
    #[inline(always)]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline(always)]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline(always)]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity — generation is not the bottleneck anywhere we use it).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher-Yates on an
    /// index pool when k is large, rejection when tiny).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 8 < n {
            // sparse: rejection sampling with a small hash set
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        } else {
            let mut pool: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                pool.swap(i, j);
            }
            pool.truncate(k);
            pool
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_both_paths() {
        let mut r = Rng::new(5);
        for (n, k) in [(1000, 10), (100, 90), (50, 50), (10, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut base = Rng::new(9);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
