//! L2-loss (squared-hinge) SVM dual — the other LIBLINEAR workhorse
//! (Hsieh et al.); model-zoo extension within the paper's GLM frame.
//!
//! `min_alpha 1/(2 lam n^2) ||D alpha||^2 + sum_i [ -alpha_i/n +
//! (mu/2) alpha_i^2 + I{alpha_i >= 0} ]` with columns `d_i = y_i x_i`.
//! The quadratic dual term (`mu = 1/(2 C n^2)`-style smoothing of the
//! hinge) removes the upper box bound and makes `g_i` strongly convex,
//! so the coordinate gap is exact:
//! `g_i*(-u) = max(0, 1/n - u)^2 / (2 mu)`.

use super::{GlmModel, ModelKind};

#[derive(Clone, Debug)]
pub struct SvmL2Dual {
    pub lam: f32,
    pub n: usize,
    /// Dual smoothing coefficient (from the squared-hinge C).
    pub mu: f32,
    inv_scale: f32,
    inv_n: f32,
}

impl SvmL2Dual {
    pub fn new(lam: f32, n: usize, mu: f32) -> Self {
        assert!(lam > 0.0 && n > 0 && mu > 0.0);
        SvmL2Dual {
            lam,
            n,
            mu,
            inv_scale: 1.0 / (lam * (n as f32) * (n as f32)),
            inv_n: 1.0 / n as f32,
        }
    }
}

// Training accuracy (the same margin test as the L1-hinge dual) lives in
// `crate::serve::predict::accuracy` — the consolidated predict seam.

impl GlmModel for SvmL2Dual {
    fn name(&self) -> &'static str {
        "svm-l2"
    }

    fn kind(&self) -> ModelKind {
        ModelKind::SvmL2 { inv_scale: self.inv_scale, inv_n: self.inv_n, mu: self.mu }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, _y_j: f32) -> f32 {
        v_j * self.inv_scale
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        let g = -alpha_i * self.inv_n + 0.5 * self.mu * alpha_i * alpha_i;
        let c = (self.inv_n - u).max(0.0);
        alpha_i * u + g + c * c / (2.0 * self.mu)
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        let hess = sq_norm * self.inv_scale + self.mu;
        let grad = u - self.inv_n + self.mu * alpha_i;
        (alpha_i - grad / hess).max(0.0) - alpha_i
    }

    fn objective(&self, v: &[f32], _y: &[f32], alpha: &[f32]) -> f64 {
        let fv = crate::kernels::sq_norm_f64(v) * 0.5 * self.inv_scale as f64;
        let g: f64 = alpha
            .iter()
            .map(|&a| {
                (-a * self.inv_n + 0.5 * self.mu * a * a) as f64
            })
            .sum();
        fv + g
    }

    fn box_constrained(&self) -> bool {
        true // one-sided: alpha >= 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::data::Matrix;
    use crate::glm::{solve_reference, total_gap};
    use crate::util::Rng;

    #[test]
    fn gap_zero_at_coordinate_optimum() {
        let m = SvmL2Dual::new(0.1, 10, 0.05);
        // stationarity at alpha > 0: u = 1/n - mu*alpha
        let a = 0.8f32;
        let u = m.inv_n - m.mu * a;
        assert!(m.gap(u, a).abs() < 1e-6);
        // at alpha = 0 with u >= 1/n: gap 0
        assert_eq!(m.gap(0.2, 0.0), 0.0);
    }

    #[test]
    fn updates_stay_nonnegative() {
        let m = SvmL2Dual::new(0.01, 50, 0.1);
        let mut rng = Rng::new(81);
        for _ in 0..300 {
            let a = rng.f32() * 2.0;
            let u = rng.normal() * 5.0;
            let sq = rng.f32() * 3.0 + 0.01;
            assert!(a + m.delta(u, a, sq) >= -1e-7);
        }
    }

    #[test]
    fn update_is_exact_coordinate_minimizer() {
        let m = SvmL2Dual::new(0.05, 30, 0.2);
        let mut rng = Rng::new(82);
        for _ in 0..100 {
            let sq = rng.f32() * 2.0 + 0.1;
            let a = rng.f32();
            let u = rng.normal();
            let d1 = m.delta(u, a, sq);
            // re-evaluating at the new point must give ~0 (u moves by
            // delta * sq * inv_scale)
            let u2 = u + d1 * sq * m.inv_scale;
            let d2 = m.delta(u2, a + d1, sq);
            assert!(d2.abs() < 1e-4 * d1.abs().max(1.0));
        }
    }

    #[test]
    fn trains_separable_data_to_high_accuracy_and_small_gap() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 83);
        let n = g.n();
        let mut model = SvmL2Dual::new(1e-3, n, 0.5 / n as f32);
        // concrete &DenseMatrix: coerces to &dyn ColumnOps for
        // solve_reference/accuracy and &dyn BlockOps for total_gap
        let ops = match &g.matrix {
            Matrix::Dense(m) => m,
            _ => unreachable!(),
        };
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; g.d()];
        solve_reference(&mut model, ops, &g.targets, &mut alpha, &mut v, 80);
        assert!(crate::serve::predict::accuracy(ops, &v) > 0.95);
        let gap = total_gap(&model, ops, &v, &g.targets, &alpha);
        let obj0 = model.objective(&vec![0.0; g.d()], &g.targets, &vec![0.0; n]).abs();
        assert!(gap < 1e-3 * obj0.max(1.0), "gap {gap}");
    }
}
