//! Ridge regression: `f(v) = 1/2 ||v - y||^2`, `g_i(a) = lam/2 a^2`.
//!
//! The smooth conjugate `g_i*(u) = u^2 / (2 lam)` makes the coordinate
//! gap *exact* — no Lipschitzing needed:
//! `gap_i = (u + lam a)^2 / (2 lam)`.

use super::GlmModel;

#[derive(Clone, Debug)]
pub struct Ridge {
    pub lam: f32,
}

impl Ridge {
    pub fn new(lam: f32) -> Self {
        assert!(lam > 0.0);
        Ridge { lam }
    }
}

impl GlmModel for Ridge {
    fn name(&self) -> &'static str {
        "ridge"
    }

    fn kind(&self) -> super::ModelKind {
        super::ModelKind::Ridge { lam: self.lam }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, y_j: f32) -> f32 {
        v_j - y_j
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        let t = u + self.lam * alpha_i;
        t * t / (2.0 * self.lam)
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        -(u + self.lam * alpha_i) / (sq_norm + self.lam)
    }

    fn objective(&self, v: &[f32], y: &[f32], alpha: &[f32]) -> f64 {
        let fv = 0.5 * crate::kernels::sq_err_f64(v, y);
        let g: f64 = alpha
            .iter()
            .map(|&a| 0.5 * (self.lam * a * a) as f64)
            .sum();
        fv + g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::test_support::*;
    use crate::glm::{solve_reference, total_gap};

    #[test]
    fn update_is_stationary() {
        assert_stationary(&Ridge::new(0.4), 41);
    }

    #[test]
    fn gap_nonneg() {
        assert_gap_nonneg(&Ridge::new(0.4), 42);
    }

    #[test]
    fn gap_zero_iff_coordinate_optimal() {
        let m = Ridge::new(0.5);
        // optimal when u = -lam * a
        assert_eq!(m.gap(-0.25, 0.5), 0.0);
        assert!(m.gap(0.25, 0.5) > 0.0);
    }

    #[test]
    fn closed_form_matches_solve() {
        // Ridge has a unique dense optimum; CD must reach tiny total gap.
        let (mat, y, _, n) = tiny_problem(43);
        let mut model = Ridge::new(0.7);
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; y.len()];
        solve_reference(&mut model, &mat, &y, &mut alpha, &mut v, 120);
        let gap = total_gap(&model, &mat, &v, &y, &alpha);
        assert!(gap < 1e-6, "gap {gap}");
        // v stays consistent with alpha
        let v2 = mat.matvec_alpha(&alpha);
        for (a, b) in v.iter().zip(&v2) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
