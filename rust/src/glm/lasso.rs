//! Lasso: `f(v) = 1/2 ||v - y||^2`, `g_i(a) = lam * |a|`.
//!
//! The L1 conjugate is unbounded, so coordinate-wise duality gaps use
//! the Lipschitzing trick of Dünner et al. (paper ref [23], footnote 2):
//! restrict `|a| <= B`, giving `g_i*(u) = B * max(0, |u| - lam)`.  `B`
//! is refreshed each epoch from the current iterate.

use super::{soft_threshold, GlmModel};

#[derive(Clone, Debug)]
pub struct Lasso {
    pub lam: f32,
    /// Lipschitzing bound B (iterate-dependent, epoch-refreshed).
    pub lip_b: f32,
}

impl Lasso {
    pub fn new(lam: f32) -> Self {
        assert!(lam > 0.0);
        Lasso { lam, lip_b: 1.0 }
    }

    pub fn with_lip_b(mut self, b: f32) -> Self {
        self.lip_b = b;
        self
    }
}

impl GlmModel for Lasso {
    fn name(&self) -> &'static str {
        "lasso"
    }

    fn kind(&self) -> super::ModelKind {
        super::ModelKind::Lasso { lam: self.lam, lip_b: self.lip_b }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, y_j: f32) -> f32 {
        v_j - y_j
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        alpha_i * u + self.lam * alpha_i.abs() + self.lip_b * (u.abs() - self.lam).max(0.0)
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        let raw = alpha_i - u / sq_norm;
        soft_threshold(raw, self.lam / sq_norm) - alpha_i
    }

    fn objective(&self, v: &[f32], y: &[f32], alpha: &[f32]) -> f64 {
        let fv = 0.5 * crate::kernels::sq_err_f64(v, y);
        let g: f64 = alpha.iter().map(|&a| (self.lam * a.abs()) as f64).sum();
        fv + g
    }

    fn epoch_refresh(&mut self, alpha: &[f32]) {
        // B must dominate |alpha_i| at the optimum; twice the current
        // max (floored) is the standard safe choice.
        let amax = alpha.iter().fold(0.0f32, |m, &a| m.max(a.abs()));
        self.lip_b = (2.0 * amax).max(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::test_support::*;
    use crate::glm::{solve_reference, total_gap};

    #[test]
    fn update_is_stationary() {
        assert_stationary(&Lasso::new(0.3), 11);
    }

    #[test]
    fn gap_nonneg() {
        assert_gap_nonneg(&Lasso::new(0.3).with_lip_b(2.0), 12);
    }

    #[test]
    fn gap_zero_inside_subdifferential() {
        // alpha = 0 and |u| <= lam: coordinate is optimal, gap exactly 0.
        let m = Lasso::new(0.1).with_lip_b(5.0);
        for u in [-0.09f32, -0.02, 0.0, 0.05, 0.1] {
            assert_eq!(m.gap(u, 0.0), 0.0, "u={u}");
        }
        assert!(m.gap(0.2, 0.0) > 0.0);
    }

    #[test]
    fn large_lambda_zeroes_solution() {
        let (mat, y, _, n) = tiny_problem(21);
        let mut model = Lasso::new(1e4);
        let mut alpha = vec![0.2f32; n];
        let mut v = mat.matvec_alpha(&alpha);
        solve_reference(&mut model, &mat, &y, &mut alpha, &mut v, 30);
        assert!(alpha.iter().all(|&a| a == 0.0), "lam=1e4 must kill all coords");
    }

    #[test]
    fn converges_to_small_gap_and_sparse_model() {
        let (mat, y, _, n) = tiny_problem(22);
        let mut model = Lasso::new(0.5);
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; y.len()];
        let obj0 = model.objective(&v, &y, &alpha);
        let obj = solve_reference(&mut model, &mat, &y, &mut alpha, &mut v, 200);
        assert!(obj < obj0);
        let gap = total_gap(&model, &mat, &v, &y, &alpha);
        assert!(gap < 1e-4 * obj0.abs().max(1.0), "gap {gap}");
        let support = alpha.iter().filter(|&&a| a != 0.0).count();
        assert!(support < n, "L1 must induce sparsity: {support}/{n}");
    }

    #[test]
    fn epoch_refresh_tracks_iterate() {
        let mut m = Lasso::new(0.1);
        m.epoch_refresh(&[0.0, -3.0, 1.0]);
        assert_eq!(m.lip_b, 6.0);
        m.epoch_refresh(&[0.0, 0.0]);
        assert_eq!(m.lip_b, 1.0); // floor
    }
}
