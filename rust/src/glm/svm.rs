//! Dual hinge-loss SVM.
//!
//! `min_alpha  1/(2 lam n^2) ||D alpha||^2 - (1/n) sum_i alpha_i`
//! subject to `alpha_i in [0, 1]`, where columns `d_i = y_i x_i` are
//! samples pre-scaled by their labels.  This is the formulation the
//! paper inherits from PASSCoDe/CoCoA: `g_i(a) = -a/n + I_[0,1](a)`,
//! whose conjugate under Eq. (2)'s sign convention gives
//! `g_i*(-u) = max_{a in [0,1]} (-u a + a/n) = max(0, 1/n - u)`.

use super::GlmModel;

#[derive(Clone, Debug)]
pub struct SvmDual {
    pub lam: f32,
    /// Number of coordinates (samples) — enters the 1/(lam n^2) scaling.
    pub n: usize,
    inv_scale: f32, // 1 / (lam * n^2)
    inv_n: f32,
}

impl SvmDual {
    pub fn new(lam: f32, n: usize) -> Self {
        assert!(lam > 0.0 && n > 0);
        SvmDual {
            lam,
            n,
            inv_scale: 1.0 / (lam * (n as f32) * (n as f32)),
            inv_n: 1.0 / n as f32,
        }
    }
}

// Training accuracy from `v = D alpha` lives in `crate::serve::predict`
// (`accuracy(data, v)`): sample i is classified correctly iff
// `<v, d_i> > 0`, which is model-independent given the label-scaled
// column convention — the method that used to sit here was one of the
// ad-hoc predict paths consolidated onto that seam.

impl GlmModel for SvmDual {
    fn name(&self) -> &'static str {
        "svm"
    }

    fn kind(&self) -> super::ModelKind {
        super::ModelKind::Svm { inv_scale: self.inv_scale, inv_n: self.inv_n }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, _y_j: f32) -> f32 {
        v_j * self.inv_scale
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        alpha_i * u - alpha_i * self.inv_n + (self.inv_n - u).max(0.0)
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        // Newton step on the coordinate (the dual problem is quadratic
        // along each coordinate), clipped to the box.
        let hess = sq_norm * self.inv_scale;
        let new = (alpha_i - (u - self.inv_n) / hess).clamp(0.0, 1.0);
        new - alpha_i
    }

    fn objective(&self, v: &[f32], _y: &[f32], alpha: &[f32]) -> f64 {
        let fv = crate::kernels::sq_norm_f64(v) * 0.5 * self.inv_scale as f64;
        let g: f64 = -alpha.iter().map(|&a| a as f64).sum::<f64>() * self.inv_n as f64;
        fv + g
    }

    fn box_constrained(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::data::Matrix;
    use crate::glm::test_support::assert_stationary;
    use crate::glm::{solve_reference, total_gap};

    #[test]
    fn update_is_stationary() {
        assert_stationary(&SvmDual::new(0.05, 64), 31);
    }

    #[test]
    fn gap_nonneg_in_box() {
        let m = SvmDual::new(0.1, 100);
        let mut rng = crate::util::Rng::new(32);
        for _ in 0..500 {
            let u = rng.normal();
            let a = rng.f32();
            assert!(m.gap(u, a) >= -1e-5);
        }
    }

    #[test]
    fn gap_zero_at_coordinate_optimum() {
        let m = SvmDual::new(0.1, 10);
        // alpha = 0 needs u >= 1/n; alpha = 1 needs u <= 1/n.
        assert_eq!(m.gap(0.15, 0.0), 0.0);
        assert!((m.gap(0.05, 1.0) - 0.0).abs() < 1e-7);
        assert!(m.gap(0.05, 0.0) > 0.0);
        assert!(m.gap(0.15, 1.0) > 0.0);
    }

    #[test]
    fn updates_respect_box() {
        let m = SvmDual::new(0.01, 50);
        let mut rng = crate::util::Rng::new(33);
        for _ in 0..200 {
            let a = rng.f32();
            let u = rng.normal() * 10.0;
            let sq = rng.f32() * 3.0 + 0.1;
            let next = a + m.delta(u, a, sq);
            assert!((-1e-6..=1.0 + 1e-6).contains(&next));
        }
    }

    #[test]
    fn trains_to_high_accuracy_on_separable_data() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 34);
        let (d, n) = (g.d(), g.n());
        let mut model = SvmDual::new(1e-3, n);
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; d];
        // concrete &DenseMatrix: coerces to &dyn ColumnOps for
        // solve_reference/accuracy and &dyn BlockOps for total_gap
        let ops = match &g.matrix {
            Matrix::Dense(m) => m,
            _ => unreachable!(),
        };
        solve_reference(&mut model, ops, &g.targets, &mut alpha, &mut v, 60);
        let acc = crate::serve::predict::accuracy(ops, &v);
        assert!(acc > 0.95, "accuracy {acc}");
        let gap = total_gap(&model, ops, &v, &g.targets, &alpha);
        assert!(gap >= -1e-6);
    }
}
