//! Huber-loss regression with L1 regularization (model-zoo extension;
//! the paper's framework covers any smooth `f` + separable `g`).
//!
//! `f(v) = sum_j huber_delta(v_j - y_j)` with
//! `huber(r) = r^2/2` for `|r| <= delta`, `delta(|r| - delta/2)` beyond —
//! robust to target outliers, which matters for the noisy synthetic
//! regression workloads.  `w_j = clip(v_j - y_j, ±delta)`;
//! `f'' <= 1` so the prox step uses `L_i = ||d_i||^2`.

use super::{soft_threshold, GlmModel, ModelKind};

#[derive(Clone, Debug)]
pub struct HuberL1 {
    pub lam: f32,
    pub delta: f32,
    pub lip_b: f32,
}

impl HuberL1 {
    pub fn new(lam: f32, delta: f32) -> Self {
        assert!(lam > 0.0 && delta > 0.0);
        HuberL1 { lam, delta, lip_b: 1.0 }
    }
}

impl GlmModel for HuberL1 {
    fn name(&self) -> &'static str {
        "huber-l1"
    }

    fn kind(&self) -> ModelKind {
        ModelKind::Huber { lam: self.lam, delta: self.delta, lip_b: self.lip_b }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, y_j: f32) -> f32 {
        (v_j - y_j).clamp(-self.delta, self.delta)
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        // L1 gap with the Lipschitzing trick, as for lasso.
        alpha_i * u + self.lam * alpha_i.abs() + self.lip_b * (u.abs() - self.lam).max(0.0)
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        // prox-gradient step, L_i = ||d_i||^2 (huber'' <= 1)
        soft_threshold(alpha_i - u / sq_norm, self.lam / sq_norm) - alpha_i
    }

    fn objective(&self, v: &[f32], y: &[f32], alpha: &[f32]) -> f64 {
        let delta = self.delta as f64;
        let fv: f64 = v
            .iter()
            .zip(y)
            .map(|(&vj, &yj)| {
                let r = (vj - yj) as f64;
                if r.abs() <= delta {
                    0.5 * r * r
                } else {
                    delta * (r.abs() - 0.5 * delta)
                }
            })
            .sum();
        let g: f64 = alpha.iter().map(|&a| (self.lam * a.abs()) as f64).sum();
        fv + g
    }

    fn epoch_refresh(&mut self, alpha: &[f32]) {
        let amax = alpha.iter().fold(0.0f32, |m, &a| m.max(a.abs()));
        self.lip_b = (2.0 * amax).max(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::solve_reference;
    use crate::glm::test_support::tiny_problem;
    use crate::util::Rng;

    #[test]
    fn w_saturates_at_delta() {
        let m = HuberL1::new(0.1, 0.5);
        assert_eq!(m.w_of(10.0, 0.0), 0.5);
        assert_eq!(m.w_of(-10.0, 0.0), -0.5);
        assert_eq!(m.w_of(0.2, 0.0), 0.2);
    }

    #[test]
    fn objective_quadratic_inside_linear_outside() {
        let m = HuberL1::new(1e-9, 1.0);
        let inside = m.objective(&[0.5], &[0.0], &[0.0]);
        assert!((inside - 0.125).abs() < 1e-9);
        let outside = m.objective(&[3.0], &[0.0], &[0.0]);
        assert!((outside - (3.0 - 0.5)).abs() < 1e-9); // delta(|r|-delta/2)=2.5
    }

    #[test]
    fn robust_to_outliers_vs_lasso() {
        // corrupt a few targets: huber's fit on clean rows degrades less
        let (mat, mut y, d, n) = tiny_problem(71);
        let clean = y.clone();
        let mut rng = Rng::new(72);
        for _ in 0..3 {
            let j = rng.below(d);
            y[j] += 50.0 * rng.normal().signum();
        }
        let fit = |huber: bool| -> f64 {
            let mut alpha = vec![0.0f32; n];
            let mut v = vec![0.0f32; d];
            if huber {
                let mut m = HuberL1::new(0.05, 1.0);
                solve_reference(&mut m, &mat, &y, &mut alpha, &mut v, 150);
            } else {
                let mut m = crate::glm::Lasso::new(0.05);
                solve_reference(&mut m, &mat, &y, &mut alpha, &mut v, 150);
            }
            // error against the *clean* targets
            v.iter()
                .zip(&clean)
                .map(|(&vj, &cj)| ((vj - cj) as f64).powi(2))
                .sum::<f64>()
                / d as f64
        };
        let huber_err = fit(true);
        let lasso_err = fit(false);
        assert!(
            huber_err < lasso_err,
            "huber {huber_err} should beat lasso {lasso_err} under outliers"
        );
    }

    #[test]
    fn trains_to_decreasing_objective() {
        let (mat, y, d, n) = tiny_problem(73);
        let mut m = HuberL1::new(0.1, 1.0);
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; d];
        let o0 = m.objective(&v, &y, &alpha);
        let o1 = solve_reference(&mut m, &mat, &y, &mut alpha, &mut v, 100);
        assert!(o1 < o0);
    }
}
