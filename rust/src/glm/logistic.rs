//! L1-regularized logistic regression (rust-side model-zoo extension;
//! the paper's framework covers it as a GLM with smooth `f`).
//!
//! `f(v) = sum_j log(1 + exp(-y_j v_j))` with row labels `y_j in {±1}`,
//! `g_i(a) = lam |a|`.  `w_j = -y_j * sigmoid(-y_j v_j)`.
//!
//! No closed-form coordinate minimizer exists; the update is the
//! standard prox-gradient step with the coordinate-wise Lipschitz bound
//! `L_i = ||d_i||^2 / 4` (since `f'' <= 1/4`), which the paper's scheme
//! admits ("otherwise allows a simple gradient-step restricted to the
//! coordinate").

use super::{soft_threshold, GlmModel};

#[derive(Clone, Debug)]
pub struct LogisticL1 {
    pub lam: f32,
    pub lip_b: f32,
}

impl LogisticL1 {
    pub fn new(lam: f32) -> Self {
        assert!(lam > 0.0);
        LogisticL1 { lam, lip_b: 1.0 }
    }
}

#[inline(always)]
fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

impl GlmModel for LogisticL1 {
    fn name(&self) -> &'static str {
        "logistic-l1"
    }

    fn kind(&self) -> super::ModelKind {
        super::ModelKind::Logistic { lam: self.lam, lip_b: self.lip_b }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, y_j: f32) -> f32 {
        -y_j * sigmoid(-y_j * v_j)
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        // Same L1 gap structure as lasso (Lipschitzing trick).
        alpha_i * u + self.lam * alpha_i.abs() + self.lip_b * (u.abs() - self.lam).max(0.0)
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        let lip = sq_norm * 0.25;
        soft_threshold(alpha_i - u / lip, self.lam / lip) - alpha_i
    }

    fn objective(&self, v: &[f32], y: &[f32], alpha: &[f32]) -> f64 {
        let fv: f64 = v
            .iter()
            .zip(y)
            .map(|(&vj, &yj)| {
                let m = (-yj * vj) as f64;
                // stable log(1+exp(m))
                if m > 0.0 {
                    m + (1.0 + (-m).exp()).ln()
                } else {
                    (1.0 + m.exp()).ln()
                }
            })
            .sum();
        let g: f64 = alpha.iter().map(|&a| (self.lam * a.abs()) as f64).sum();
        fv + g
    }

    fn epoch_refresh(&mut self, alpha: &[f32]) {
        let amax = alpha.iter().fold(0.0f32, |m, &a| m.max(a.abs()));
        self.lip_b = (2.0 * amax).max(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DenseMatrix;
    use crate::glm::solve_reference;
    use crate::util::Rng;

    #[test]
    fn sigmoid_stable_and_correct() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(40.0) > 0.999_999);
        assert!(sigmoid(-40.0) < 1e-6);
        assert!(sigmoid(-1000.0) >= 0.0); // no NaN/underflow panic
    }

    #[test]
    fn w_is_bounded_gradient() {
        let m = LogisticL1::new(0.1);
        let mut rng = Rng::new(51);
        for _ in 0..200 {
            let w = m.w_of(rng.normal() * 5.0, if rng.f32() < 0.5 { 1.0 } else { -1.0 });
            assert!(w.abs() <= 1.0, "logistic gradient bounded by 1: {w}");
        }
    }

    #[test]
    fn prox_step_decreases_objective() {
        let mut rng = Rng::new(52);
        let (d, n) = (64, 16);
        let data: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect();
        let mat = DenseMatrix::from_col_major(d, n, data);
        let y: Vec<f32> = (0..d)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let mut model = LogisticL1::new(0.05);
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; d];
        let o0 = model.objective(&v, &y, &alpha);
        let o1 = solve_reference(&mut model, &mat, &y, &mut alpha, &mut v, 5);
        let o2 = {
            let mut m2 = model.clone();
            solve_reference(&mut m2, &mat, &y, &mut alpha, &mut v, 30)
        };
        assert!(o1 < o0, "{o1} < {o0}");
        assert!(o2 <= o1 + 1e-9);
    }

    #[test]
    fn l1_induces_sparsity() {
        let mut rng = Rng::new(53);
        let (d, n) = (64, 32);
        let data: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect();
        let mat = DenseMatrix::from_col_major(d, n, data);
        let y: Vec<f32> = (0..d)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let mut model = LogisticL1::new(2.0); // strong regularization
        let mut alpha = vec![0.0f32; n];
        let mut v = vec![0.0f32; d];
        solve_reference(&mut model, &mat, &y, &mut alpha, &mut v, 50);
        let nnz = alpha.iter().filter(|&&a| a != 0.0).count();
        assert!(nnz < n / 2, "strong L1 must sparsify: {nnz}/{n}");
    }
}
