//! Elastic net: `f(v) = 1/2 ||v - y||^2`,
//! `g_i(a) = lam * (rho |a| + (1 - rho)/2 a^2)`, `rho in (0, 1)`.
//!
//! The strongly-convex L2 part makes the conjugate finite — the gap is
//! exact with no Lipschitzing:
//! `g_i*(z) = max(0, |z| - lam rho)^2 / (2 lam (1 - rho))`.

use super::{soft_threshold, GlmModel};

#[derive(Clone, Debug)]
pub struct ElasticNet {
    pub lam: f32,
    pub rho: f32,
}

impl ElasticNet {
    pub fn new(lam: f32, rho: f32) -> Self {
        assert!(lam > 0.0);
        assert!(
            (0.0..1.0).contains(&rho),
            "rho must be in [0,1); rho=1 is plain lasso — use Lasso"
        );
        ElasticNet { lam, rho }
    }
}

impl GlmModel for ElasticNet {
    fn name(&self) -> &'static str {
        "elastic-net"
    }

    fn kind(&self) -> super::ModelKind {
        super::ModelKind::ElasticNet {
            l1: self.lam * self.rho,
            l2: self.lam * (1.0 - self.rho),
        }
    }

    #[inline(always)]
    fn w_of(&self, v_j: f32, y_j: f32) -> f32 {
        v_j - y_j
    }

    #[inline(always)]
    fn gap(&self, u: f32, alpha_i: f32) -> f32 {
        let l1 = self.lam * self.rho;
        let l2 = self.lam * (1.0 - self.rho);
        let g = l1 * alpha_i.abs() + 0.5 * l2 * alpha_i * alpha_i;
        let conj_arg = (u.abs() - l1).max(0.0);
        let g_conj = conj_arg * conj_arg / (2.0 * l2);
        alpha_i * u + g + g_conj
    }

    #[inline(always)]
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32 {
        if sq_norm <= 0.0 {
            return 0.0;
        }
        let l1 = self.lam * self.rho;
        let l2 = self.lam * (1.0 - self.rho);
        // minimize 1/2||v + t d - y||^2 + l1|a+t| + l2/2 (a+t)^2 over t:
        // closed form soft-threshold on the combined quadratic.
        let new = soft_threshold(alpha_i * sq_norm - u, l1) / (sq_norm + l2);
        new - alpha_i
    }

    fn objective(&self, v: &[f32], y: &[f32], alpha: &[f32]) -> f64 {
        let fv = 0.5 * crate::kernels::sq_err_f64(v, y);
        let l1 = (self.lam * self.rho) as f64;
        let l2 = (self.lam * (1.0 - self.rho)) as f64;
        let g: f64 = alpha
            .iter()
            .map(|&a| l1 * a.abs() as f64 + 0.5 * l2 * (a as f64) * (a as f64))
            .sum();
        fv + g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::test_support::*;
    use crate::glm::{solve_reference, total_gap};

    #[test]
    fn update_is_stationary() {
        assert_stationary(&ElasticNet::new(0.3, 0.5), 61);
    }

    #[test]
    fn gap_nonneg() {
        assert_gap_nonneg(&ElasticNet::new(0.3, 0.5), 62);
    }

    #[test]
    fn gap_zero_at_coordinate_optimum() {
        let m = ElasticNet::new(0.4, 0.5);
        // optimum of a*u + g(a) + g*(-u) in u for fixed a>0: u = -(l1 + l2 a)
        let (l1, l2) = (0.2f32, 0.2f32);
        let a = 0.7f32;
        let u = -(l1 + l2 * a);
        assert!(m.gap(u, a).abs() < 1e-6);
    }

    #[test]
    fn interpolates_lasso_and_ridge() {
        // rho -> 1 behaves like lasso (sparsity); rho -> 0 like ridge.
        let (mat, y, _, n) = tiny_problem(63);
        let run = |rho: f32| {
            let mut model = ElasticNet::new(1.0, rho);
            let mut alpha = vec![0.0f32; n];
            let mut v = vec![0.0f32; y.len()];
            solve_reference(&mut model, &mat, &y, &mut alpha, &mut v, 150);
            let gap = total_gap(&model, &mat, &v, &y, &alpha);
            assert!(gap < 1e-5, "rho={rho} gap {gap}");
            alpha.iter().filter(|&&a| a != 0.0).count()
        };
        let sparse_support = run(0.99);
        let dense_support = run(0.01);
        assert!(
            sparse_support <= dense_support,
            "L1-heavy ({sparse_support}) should be at most as dense as L2-heavy ({dense_support})"
        );
    }
}
