//! Generalized linear models (paper §II-A):
//!
//! ```text
//! min_{alpha in R^n}  F(alpha) := f(D alpha) + sum_i g_i(alpha_i)
//! ```
//!
//! with `w := grad f(D alpha)` and the coordinate-wise duality gap
//! (paper Eq. (2)):
//!
//! ```text
//! gap_i(alpha_i; w) = alpha_i <w, d_i> + g_i(alpha_i) + g_i*(-<w, d_i>)
//! ```
//!
//! Every model implements [`GlmModel`]: the two scalar functions the
//! paper calls `h` (gap, Eq. (3)) and `h-hat` (closed-form coordinate
//! update, Eq. (4)), plus the `v -> w` primal-dual map and the objective
//! used for suboptimality traces.  Tasks A and B only ever call these
//! scalar hooks — all models share the same hot path.
//!
//! The numerics here must match `python/compile/kernels/ref.py`
//! (cross-checked by `rust/tests/runtime_pjrt.rs` through the PJRT
//! artifacts).

pub mod elastic_net;
pub mod huber;
pub mod lasso;
pub mod logistic;
pub mod ridge;
pub mod svm;
pub mod svm_l2;

pub use elastic_net::ElasticNet;
pub use huber::HuberL1;
pub use lasso::Lasso;
pub use logistic::LogisticL1;
pub use ridge::Ridge;
pub use svm::SvmDual;
pub use svm_l2::SvmL2Dual;

use crate::data::{BlockOps, ColumnOps};

/// Copyable scalar-op bundle for the hot paths.
///
/// Tasks A/B run millions of `w_of`/`gap`/`delta` evaluations per
/// second; a virtual call per element would dominate.  [`ModelKind`]
/// carries the same scalar math as the trait object in a `Copy` enum —
/// the inner-loop `match` is branch-predicted away, and the loops stay
/// inlinable.  `GlmModel::kind()` snapshots the current hyperparameters
/// (taken fresh each epoch, so `epoch_refresh` updates propagate).
#[derive(Clone, Copy, Debug)]
pub enum ModelKind {
    Lasso { lam: f32, lip_b: f32 },
    Svm { inv_scale: f32, inv_n: f32 },
    Ridge { lam: f32 },
    Logistic { lam: f32, lip_b: f32 },
    ElasticNet { l1: f32, l2: f32 },
    Huber { lam: f32, delta: f32, lip_b: f32 },
    SvmL2 { inv_scale: f32, inv_n: f32, mu: f32 },
}

impl ModelKind {
    /// If `w_of(v, y) == scale * v` (y unused), the fused dot reduces to
    /// a plain scaled dot with one fewer memory stream and no per-element
    /// branch — task B's fast path for the SVM family (§Perf).
    #[inline(always)]
    pub fn linear_in_v(self) -> Option<f32> {
        match self {
            ModelKind::Svm { inv_scale, .. } | ModelKind::SvmL2 { inv_scale, .. } => {
                Some(inv_scale)
            }
            _ => None,
        }
    }

    #[inline(always)]
    pub fn w_of(self, v_j: f32, y_j: f32) -> f32 {
        match self {
            ModelKind::Lasso { .. } | ModelKind::Ridge { .. } | ModelKind::ElasticNet { .. } => {
                v_j - y_j
            }
            ModelKind::Svm { inv_scale, .. } | ModelKind::SvmL2 { inv_scale, .. } => {
                v_j * inv_scale
            }
            ModelKind::Huber { delta, .. } => (v_j - y_j).clamp(-delta, delta),
            ModelKind::Logistic { .. } => {
                let m = -y_j * v_j;
                let s = if m >= 0.0 {
                    1.0 / (1.0 + (-m).exp())
                } else {
                    let e = m.exp();
                    e / (1.0 + e)
                };
                -y_j * s
            }
        }
    }

    #[inline(always)]
    pub fn gap(self, u: f32, a: f32) -> f32 {
        match self {
            ModelKind::Lasso { lam, lip_b }
            | ModelKind::Logistic { lam, lip_b }
            | ModelKind::Huber { lam, lip_b, .. } => {
                a * u + lam * a.abs() + lip_b * (u.abs() - lam).max(0.0)
            }
            ModelKind::SvmL2 { inv_n, mu, .. } => {
                let g = -a * inv_n + 0.5 * mu * a * a;
                let c = (inv_n - u).max(0.0);
                a * u + g + c * c / (2.0 * mu)
            }
            ModelKind::Svm { inv_n, .. } => a * u - a * inv_n + (inv_n - u).max(0.0),
            ModelKind::Ridge { lam } => {
                let t = u + lam * a;
                t * t / (2.0 * lam)
            }
            ModelKind::ElasticNet { l1, l2 } => {
                let g = l1 * a.abs() + 0.5 * l2 * a * a;
                let c = (u.abs() - l1).max(0.0);
                a * u + g + c * c / (2.0 * l2)
            }
        }
    }

    #[inline(always)]
    pub fn delta(self, u: f32, a: f32, sq: f32) -> f32 {
        if sq <= 0.0 {
            return 0.0;
        }
        match self {
            ModelKind::Lasso { lam, .. } | ModelKind::Huber { lam, .. } => {
                // huber'' <= 1 so L_i = ||d_i||^2 serves both
                soft_threshold(a - u / sq, lam / sq) - a
            }
            ModelKind::SvmL2 { inv_scale, inv_n, mu } => {
                let hess = sq * inv_scale + mu;
                (a - (u - inv_n + mu * a) / hess).max(0.0) - a
            }
            ModelKind::Svm { inv_scale, inv_n } => {
                let hess = sq * inv_scale;
                (a - (u - inv_n) / hess).clamp(0.0, 1.0) - a
            }
            ModelKind::Ridge { lam } => -(u + lam * a) / (sq + lam),
            ModelKind::Logistic { lam, .. } => {
                let lip = sq * 0.25;
                soft_threshold(a - u / lip, lam / lip) - a
            }
            ModelKind::ElasticNet { l1, l2 } => {
                soft_threshold(a * sq - u, l1) / (sq + l2) - a
            }
        }
    }
}

/// A GLM instance (hyperparameters baked in).
pub trait GlmModel: Sync + Send {
    fn name(&self) -> &'static str;

    /// Snapshot the scalar ops for the hot loops (see [`ModelKind`]).
    fn kind(&self) -> ModelKind;

    /// Dual-mapped vector element: `w_j = (grad f)(v)_j`, which for all
    /// supported models is an elementwise function of `v_j` and `y_j`.
    fn w_of(&self, v_j: f32, y_j: f32) -> f32;

    /// Coordinate-wise duality gap from `u = <w, d_i>` (paper Eq. 3).
    fn gap(&self, u: f32, alpha_i: f32) -> f32;

    /// Closed-form coordinate update delta (paper Eq. 4):
    /// `alpha_i+ = alpha_i + delta`.
    fn delta(&self, u: f32, alpha_i: f32, sq_norm: f32) -> f32;

    /// Objective `F(alpha) = f(v) + sum_i g_i(alpha_i)` (f64 for traces).
    fn objective(&self, v: &[f32], y: &[f32], alpha: &[f32]) -> f64;

    /// Whether coordinates live in a box (SVM dual: [0, 1]).
    fn box_constrained(&self) -> bool {
        false
    }

    /// Refresh iterate-dependent constants at an epoch boundary (e.g.
    /// the Lipschitzing bound B for L1 gaps).  Default: no-op.
    fn epoch_refresh(&mut self, _alpha: &[f32]) {}
}

/// Name-based model construction — the single CLI/serving dispatch
/// (previously duplicated in `main.rs`): `n` is the coordinate count
/// (needed by the SVM duals' `1/(lam n)` scaling).  Fixed secondary
/// hyperparameters match the CLI's historical choices (`svm-l2` mu
/// `0.5/n`, elastic `l2 = 0.5`, huber `delta = 1.0`).
pub fn model_by_name(name: &str, lam: f32, n: usize) -> Option<Box<dyn GlmModel>> {
    Some(match name {
        "lasso" => Box::new(Lasso::new(lam)),
        "svm" => Box::new(SvmDual::new(lam, n)),
        "svm-l2" => Box::new(SvmL2Dual::new(lam, n, 0.5 / n as f32)),
        "ridge" => Box::new(Ridge::new(lam)),
        "logistic" => Box::new(LogisticL1::new(lam)),
        "elastic" => Box::new(ElasticNet::new(lam, 0.5)),
        "huber" => Box::new(HuberL1::new(lam, 1.0)),
        _ => return None,
    })
}

/// Which matrix orientation a model name trains in (classification
/// models consume label-scaled sample columns, paper §II-A).
pub fn family_for(model_name: &str) -> crate::data::Family {
    if matches!(model_name, "svm" | "svm-l2" | "logistic") {
        crate::data::Family::Classification
    } else {
        crate::data::Family::Regression
    }
}

/// Materialize `w` from `v` — the residual/dual map, evaluated through
/// the kernel layer's elementwise map (dense helper used by tasks and
/// tests).
pub fn w_from_v(model: &dyn GlmModel, v: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), y.len());
    crate::kernels::map2_into(out, v, y, |vj, yj| model.w_of(vj, yj));
}

/// Total duality gap `sum_i gap_i` over all columns (exact, sequential —
/// used for convergence thresholds and traces, not the hot path).  The
/// full-matrix `u = Dᵀ w` sweep runs through the blocked multi-column
/// backend ([`BlockOps::dots_block`]): one O(nd) pass that reuses each
/// cache line of `w` across [`crate::kernels::BLOCK_COLS`] columns.
pub fn total_gap(
    model: &dyn GlmModel,
    data: &dyn BlockOps,
    v: &[f32],
    y: &[f32],
    alpha: &[f32],
) -> f64 {
    const B: usize = crate::kernels::BLOCK_COLS;
    let mut w = vec![0.0f32; v.len()];
    w_from_v(model, v, y, &mut w);
    let n = data.n_cols();
    let mut total = 0.0f64;
    let mut idx = [0usize; B];
    let mut u = [0.0f32; B];
    for start in (0..n).step_by(B) {
        let end = (start + B).min(n);
        let m = end - start;
        for (t, j) in idx.iter_mut().zip(start..end) {
            *t = j;
        }
        data.dots_block(&idx[..m], &w, &mut u[..m]);
        for (j, &uj) in (start..end).zip(&u) {
            total += model.gap(uj, alpha[j]) as f64;
        }
    }
    total
}

/// Exact sequential coordinate descent (the T_B = 1 oracle).  Returns
/// the final objective.  Used by tests and to compute reference optima
/// for suboptimality traces.
///
/// `w = grad f(v)` is re-anchored from `v` once per epoch (which also
/// bounds fp32 drift) and maintained *incrementally* through the epoch:
/// for the models whose dual map is affine in `v` (`w = v - y` for the
/// squared-loss family, `w = v / scale` for the SVMs) the same `axpy`
/// that updates `v` updates `w` exactly.  For the nonlinear maps
/// (Huber's clamp, logistic's sigmoid) an incremental slope does not
/// exist, so `w` is re-mapped from `v` — but only after a coordinate
/// actually moved, not unconditionally per coordinate as before (with
/// L1 models most deltas are zero, so the old O(d)-per-coordinate
/// re-map was nearly always wasted work).
pub fn solve_reference(
    model: &mut dyn GlmModel,
    data: &dyn ColumnOps,
    y: &[f32],
    alpha: &mut [f32],
    v: &mut [f32],
    epochs: usize,
) -> f64 {
    let n = data.n_cols();
    let d = data.n_rows();
    let mut w = vec![0.0f32; d];
    for _ in 0..epochs {
        model.epoch_refresh(alpha);
        // dw/dv where the map is affine in v; None -> re-map on change
        let w_slope = match model.kind() {
            ModelKind::Lasso { .. } | ModelKind::Ridge { .. } | ModelKind::ElasticNet { .. } => {
                Some(1.0f32)
            }
            ModelKind::Svm { inv_scale, .. } | ModelKind::SvmL2 { inv_scale, .. } => {
                Some(inv_scale)
            }
            ModelKind::Huber { .. } | ModelKind::Logistic { .. } => None,
        };
        w_from_v(model, v, y, &mut w); // per-epoch re-anchor
        let mut w_stale = false;
        for j in 0..n {
            if w_stale {
                w_from_v(model, v, y, &mut w);
                w_stale = false;
            }
            let u = data.dot(j, &w);
            let delta = model.delta(u, alpha[j], data.sq_norm(j));
            if delta != 0.0 {
                alpha[j] += delta;
                data.axpy(j, delta, v);
                match w_slope {
                    Some(s) => data.axpy(j, delta * s, &mut w),
                    None => w_stale = true,
                }
            }
        }
    }
    model.objective(v, y, alpha)
}

/// Scalar soft-threshold `sign(x) * max(|x| - k, 0)`.
#[inline(always)]
pub fn soft_threshold(x: f32, k: f32) -> f32 {
    if x > k {
        x - k
    } else if x < -k {
        x + k
    } else {
        0.0
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::DenseMatrix;
    use crate::util::Rng;

    /// Small dense regression problem with known optimum via long solve.
    pub fn tiny_problem(seed: u64) -> (DenseMatrix, Vec<f32>, usize, usize) {
        let (d, n) = (48, 24);
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect();
        let m = DenseMatrix::from_col_major(d, n, data);
        let mut astar = vec![0.0f32; n];
        for j in 0..4 {
            astar[j * 5] = rng.normal();
        }
        let mut y = m.matvec_alpha(&astar);
        for t in y.iter_mut() {
            *t += 0.05 * rng.normal();
        }
        (m, y, d, n)
    }

    /// Assert a model's closed-form delta is a per-coordinate fixed point.
    pub fn assert_stationary(model: &dyn GlmModel, seed: u64) {
        let mut rng = Rng::new(seed);
        for _ in 0..50 {
            let d = 32;
            let col: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let sq: f32 = col.iter().map(|x| x * x).sum();
            let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let a0 = if model.box_constrained() {
                rng.f32()
            } else {
                rng.normal()
            };
            let u = |vv: &[f32]| -> f32 {
                let mut w = vec![0.0f32; d];
                w_from_v(model, vv, &y, &mut w);
                col.iter().zip(&w).map(|(a, b)| a * b).sum()
            };
            let delta = model.delta(u(&v), a0, sq);
            let v2: Vec<f32> = v.iter().zip(&col).map(|(&x, &c)| x + delta * c).collect();
            let delta2 = model.delta(u(&v2), a0 + delta, sq);
            assert!(
                delta2.abs() <= 1e-3 * delta.abs().max(1.0),
                "{}: delta {delta} then {delta2}",
                model.name()
            );
        }
    }

    /// Assert gaps are nonnegative wherever the iterate is feasible.
    /// For L1 models the Lipschitzing bound must dominate the iterate
    /// (|alpha| <= B) — that is the trick's contract (paper ref [23]) —
    /// so draws are clamped to [-1, 1] and callers pass lip_b >= 1.
    pub fn assert_gap_nonneg(model: &dyn GlmModel, seed: u64) {
        let mut rng = Rng::new(seed);
        for _ in 0..200 {
            let u = rng.normal() * 3.0;
            let a = if model.box_constrained() {
                rng.f32()
            } else {
                rng.normal().clamp(-1.0, 1.0)
            };
            let g = model.gap(u, a);
            assert!(g >= -1e-4, "{}: gap({u}, {a}) = {g}", model.name());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn model_kind_matches_trait_for_every_model() {
        // ModelKind is a *copy* of each model's scalar math; any drift
        // between the enum and the trait impls is a correctness bug in
        // the hot path.
        let models: Vec<Box<dyn GlmModel>> = vec![
            Box::new(Lasso::new(0.3).with_lip_b(2.0)),
            Box::new(SvmDual::new(0.05, 64)),
            Box::new(Ridge::new(0.7)),
            Box::new(LogisticL1::new(0.2)),
            Box::new(ElasticNet::new(0.5, 0.4)),
            Box::new(HuberL1::new(0.2, 1.0)),
            Box::new(SvmL2Dual::new(0.05, 64, 0.1)),
        ];
        let mut rng = Rng::new(99);
        for m in &models {
            let k = m.kind();
            for _ in 0..300 {
                let u = rng.normal() * 2.0;
                let a = if m.box_constrained() { rng.f32() } else { rng.normal() };
                let sq = rng.f32() * 3.0;
                let (v_j, y_j) = (rng.normal(), if rng.f32() < 0.5 { 1.0 } else { -1.0 });
                assert!(
                    (m.w_of(v_j, y_j) - k.w_of(v_j, y_j)).abs() < 1e-6,
                    "{} w_of", m.name()
                );
                assert!((m.gap(u, a) - k.gap(u, a)).abs() < 1e-5, "{} gap", m.name());
                assert!(
                    (m.delta(u, a, sq) - k.delta(u, a, sq)).abs() < 1e-5,
                    "{} delta", m.name()
                );
            }
        }
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }
}
