//! Dense column-major matrix — the representation the paper's headline
//! results (order-of-magnitude Lasso speedup) are about.
//!
//! The dot/axpy kernels mirror the paper's AVX-512 strategy (§IV-A3):
//! multiple independent accumulators for instruction-level parallelism,
//! written so LLVM auto-vectorizes the unrolled lanes.  On KNL the paper
//! reaches ~7.2 flops/cycle for the full coordinate update; here the
//! same structure hits the host's practical roofline (measured in
//! `benches/perf_hotpath.rs`).

use super::ColumnOps;

/// Column-major dense f32 matrix (`d` rows — samples; `n` cols — the
/// coordinates/features the CD algorithm iterates over).
#[derive(Clone)]
pub struct DenseMatrix {
    d: usize,
    n: usize,
    /// Column-major storage, `d * n` elements, column `j` at `j*d..(j+1)*d`.
    data: Vec<f32>,
    /// Precomputed `||d_i||^2`.
    sq_norms: Vec<f32>,
}

/// Dot product with 4 independent accumulators (ILP; auto-vectorizes).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 16;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 16;
        let (xa, xb) = (&a[i..i + 16], &b[i..i + 16]);
        s0 += xa[0] * xb[0] + xa[1] * xb[1] + xa[2] * xb[2] + xa[3] * xb[3];
        s1 += xa[4] * xb[4] + xa[5] * xb[5] + xa[6] * xb[6] + xa[7] * xb[7];
        s2 += xa[8] * xb[8] + xa[9] * xb[9] + xa[10] * xb[10] + xa[11] * xb[11];
        s3 += xa[12] * xb[12] + xa[13] * xb[13] + xa[14] * xb[14] + xa[15] * xb[15];
    }
    let mut tail = 0.0f32;
    for i in chunks * 16..a.len() {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// `v += delta * x` (unrolled axpy; auto-vectorizes).
#[inline]
pub fn axpy_f32(delta: f32, x: &[f32], v: &mut [f32]) {
    debug_assert_eq!(x.len(), v.len());
    for (vi, xi) in v.iter_mut().zip(x.iter()) {
        *vi += delta * *xi;
    }
}

impl DenseMatrix {
    /// Build from column-major data.
    pub fn from_col_major(d: usize, n: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), d * n, "column-major size mismatch");
        let sq_norms = (0..n)
            .map(|j| {
                let c = &data[j * d..(j + 1) * d];
                dot_f32(c, c)
            })
            .collect();
        DenseMatrix { d, n, data, sq_norms }
    }

    /// Column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.d..(j + 1) * self.d]
    }

    /// `v = D * alpha` from scratch (consistency checks, initialization).
    pub fn matvec_alpha(&self, alpha: &[f32]) -> Vec<f32> {
        assert_eq!(alpha.len(), self.n);
        let mut v = vec![0.0f32; self.d];
        for (j, &a) in alpha.iter().enumerate() {
            if a != 0.0 {
                axpy_f32(a, self.col(j), &mut v);
            }
        }
        v
    }

    /// Raw storage (runtime layer feeds padded tiles to PJRT).
    pub fn raw(&self) -> &[f32] {
        &self.data
    }
}

impl ColumnOps for DenseMatrix {
    fn n_rows(&self) -> usize {
        self.d
    }

    fn n_cols(&self) -> usize {
        self.n
    }

    #[inline]
    fn dot(&self, col: usize, w: &[f32]) -> f32 {
        dot_f32(self.col(col), &w[..self.d])
    }

    #[inline]
    fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        dot_f32(&self.col(col)[lo..hi], &w[lo..hi])
    }

    #[inline]
    fn axpy(&self, col: usize, delta: f32, v: &mut [f32]) {
        axpy_f32(delta, self.col(col), &mut v[..self.d]);
    }

    #[inline]
    fn sq_norm(&self, col: usize) -> f32 {
        self.sq_norms[col]
    }

    fn nnz(&self, _col: usize) -> usize {
        self.d
    }

    fn col_bytes(&self, _col: usize) -> u64 {
        (self.d * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // 3 rows x 2 cols: col0 = [1,2,3], col1 = [0,-1,4]
        DenseMatrix::from_col_major(3, 2, vec![1.0, 2.0, 3.0, 0.0, -1.0, 4.0])
    }

    #[test]
    fn col_access() {
        let m = small();
        assert_eq!(m.col(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.col(1), &[0.0, -1.0, 4.0]);
    }

    #[test]
    fn dot_matches_naive() {
        let m = small();
        let w = vec![1.0, 1.0, 1.0];
        assert_eq!(m.dot(0, &w), 6.0);
        assert_eq!(m.dot(1, &w), 3.0);
    }

    #[test]
    fn dot_f32_long_vectors_accurate() {
        // length not a multiple of 16 exercises the tail path
        let n = 1037;
        let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32 - 2.0).collect();
        let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as f64).sum();
        let got = dot_f32(&a, &b) as f64;
        assert!((got - naive).abs() < 1e-3 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_range_partial_sums_compose() {
        let m = small();
        let w = vec![2.0, -1.0, 0.5];
        let full = m.dot(0, &w);
        let split = m.dot_range(0, &w, 0, 2) + m.dot_range(0, &w, 2, 3);
        assert!((full - split).abs() < 1e-6);
    }

    #[test]
    fn sq_norms_precomputed() {
        let m = small();
        assert_eq!(m.sq_norm(0), 14.0);
        assert_eq!(m.sq_norm(1), 17.0);
    }

    #[test]
    fn axpy_updates_v() {
        let m = small();
        let mut v = vec![1.0, 1.0, 1.0];
        m.axpy(0, 2.0, &mut v);
        assert_eq!(v, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn matvec_alpha_consistent() {
        let m = small();
        let v = m.matvec_alpha(&[2.0, -1.0]);
        assert_eq!(v, vec![2.0, 5.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        DenseMatrix::from_col_major(3, 2, vec![0.0; 5]);
    }
}
