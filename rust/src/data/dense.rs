//! Dense column-major matrix — the representation the paper's headline
//! results (order-of-magnitude Lasso speedup) are about.
//!
//! All inner loops live in [`crate::kernels`] (runtime-dispatched
//! scalar/SIMD, paper §IV-A3); this module only owns the layout and
//! the precomputed column norms.

use super::{BlockOps, ColumnOps};
use crate::kernels;

/// Column-major dense f32 matrix (`d` rows — samples; `n` cols — the
/// coordinates/features the CD algorithm iterates over).
#[derive(Clone)]
pub struct DenseMatrix {
    d: usize,
    n: usize,
    /// Column-major storage, `d * n` elements, column `j` at `j*d..(j+1)*d`.
    data: Vec<f32>,
    /// Precomputed `||d_i||^2`.
    sq_norms: Vec<f32>,
}

impl DenseMatrix {
    /// Build from column-major data.
    pub fn from_col_major(d: usize, n: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), d * n, "column-major size mismatch");
        let sq_norms = (0..n)
            .map(|j| kernels::sq_norm(&data[j * d..(j + 1) * d]))
            .collect();
        DenseMatrix { d, n, data, sq_norms }
    }

    /// Column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.d..(j + 1) * self.d]
    }

    /// `v = D * alpha` from scratch (consistency checks, initialization).
    pub fn matvec_alpha(&self, alpha: &[f32]) -> Vec<f32> {
        assert_eq!(alpha.len(), self.n);
        let mut v = vec![0.0f32; self.d];
        for (j, &a) in alpha.iter().enumerate() {
            if a != 0.0 {
                kernels::axpy(a, self.col(j), &mut v);
            }
        }
        v
    }

    /// Raw storage (runtime layer feeds padded tiles to PJRT).
    pub fn raw(&self) -> &[f32] {
        &self.data
    }
}

impl ColumnOps for DenseMatrix {
    fn n_rows(&self) -> usize {
        self.d
    }

    fn n_cols(&self) -> usize {
        self.n
    }

    #[inline]
    fn dot(&self, col: usize, w: &[f32]) -> f32 {
        kernels::dot(self.col(col), &w[..self.d])
    }

    #[inline]
    fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        kernels::dot_range(self.col(col), &w[..self.d], lo, hi)
    }

    #[inline]
    fn axpy(&self, col: usize, delta: f32, v: &mut [f32]) {
        kernels::axpy(delta, self.col(col), &mut v[..self.d]);
    }

    #[inline]
    fn sq_norm(&self, col: usize) -> f32 {
        self.sq_norms[col]
    }

    fn nnz(&self, _col: usize) -> usize {
        self.d
    }

    fn col_bytes(&self, _col: usize) -> u64 {
        (self.d * 4) as u64
    }
}

impl BlockOps for DenseMatrix {
    fn dots_block(&self, cols: &[usize], w: &[f32], out: &mut [f32]) {
        const B: usize = kernels::BLOCK_COLS;
        debug_assert_eq!(cols.len(), out.len());
        let w = &w[..self.d];
        // Stack-tile the column list so the kernel sees at most B
        // slices per call — no per-call allocation on the task-A hot
        // path.
        for (cidx, o) in cols.chunks(B).zip(out.chunks_mut(B)) {
            let mut slices: [&[f32]; B] = [&[]; B];
            for (s, &j) in slices.iter_mut().zip(cidx) {
                *s = self.col(j);
            }
            kernels::dots_block(&slices[..cidx.len()], w, o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // 3 rows x 2 cols: col0 = [1,2,3], col1 = [0,-1,4]
        DenseMatrix::from_col_major(3, 2, vec![1.0, 2.0, 3.0, 0.0, -1.0, 4.0])
    }

    #[test]
    fn col_access() {
        let m = small();
        assert_eq!(m.col(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.col(1), &[0.0, -1.0, 4.0]);
    }

    #[test]
    fn dot_matches_naive() {
        let m = small();
        let w = vec![1.0, 1.0, 1.0];
        assert_eq!(m.dot(0, &w), 6.0);
        assert_eq!(m.dot(1, &w), 3.0);
    }

    #[test]
    fn dot_long_vectors_accurate() {
        // length not a multiple of any SIMD width exercises tail paths
        let n = 1037;
        let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..n).map(|i| (i % 5) as f32 - 2.0).collect();
        let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as f64).sum();
        let m = DenseMatrix::from_col_major(n, 1, a);
        let got = m.dot(0, &b) as f64;
        assert!((got - naive).abs() < 1e-3 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_range_partial_sums_compose() {
        let m = small();
        let w = vec![2.0, -1.0, 0.5];
        let full = m.dot(0, &w);
        let split = m.dot_range(0, &w, 0, 2) + m.dot_range(0, &w, 2, 3);
        assert!((full - split).abs() < 1e-6);
    }

    #[test]
    fn sq_norms_precomputed() {
        let m = small();
        assert_eq!(m.sq_norm(0), 14.0);
        assert_eq!(m.sq_norm(1), 17.0);
    }

    #[test]
    fn axpy_updates_v() {
        let m = small();
        let mut v = vec![1.0, 1.0, 1.0];
        m.axpy(0, 2.0, &mut v);
        assert_eq!(v, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn matvec_alpha_consistent() {
        let m = small();
        let v = m.matvec_alpha(&[2.0, -1.0]);
        assert_eq!(v, vec![2.0, 5.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        DenseMatrix::from_col_major(3, 2, vec![0.0; 5]);
    }
}
