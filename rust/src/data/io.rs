//! Binary dataset / model persistence (hand-rolled; no serde offline).
//!
//! Format `HTHC1` (little-endian):
//!
//! ```text
//! magic[5] = "HTHC1"
//! kind: u8           1 = dense dataset, 2 = sparse dataset, 3 = model
//! -- dense:   d u64, n u64, targets f32[d], data f32[d*n] (col-major)
//! -- sparse:  d u64, n u64, targets f32[d],
//!             per column: nnz u64, rows u32[nnz], vals f32[nnz]
//! -- model:   name_len u64, name bytes, lam f32, n u64, alpha f32[n]
//! ```
//!
//! The dataset half of the format is crate-internal plumbing: writing
//! goes through [`Dataset::save`](crate::data::Dataset::save), reading
//! through `DatasetBuilder::path` (which sniffs the magic) — the old
//! public `save_dataset_file`/`load_dataset_file` load path is gone.
//! Model export/import stays public for the `evaluate` flow.

use crate::data::{ColumnOps, DenseMatrix, Matrix, SparseMatrix};
use crate::util::error::Context;
use crate::{bail, Result};
use std::io::{Read, Write};

pub(crate) const MAGIC: &[u8; 5] = b"HTHC1";

fn w_u64<W: Write>(w: &mut W, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn r_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_f32s<R: Read>(r: &mut R, len: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; len * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn r_u32s<R: Read>(r: &mut R, len: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; len * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a dataset (dense or sparse) with its targets.
pub(crate) fn save_dataset<W: Write>(mut w: W, m: &Matrix, targets: &[f32]) -> Result<()> {
    w.write_all(MAGIC)?;
    match m {
        Matrix::Dense(dm) => {
            w.write_all(&[1u8])?;
            w_u64(&mut w, dm.n_rows() as u64)?;
            w_u64(&mut w, dm.n_cols() as u64)?;
            w_f32s(&mut w, targets)?;
            w_f32s(&mut w, dm.raw())?;
        }
        Matrix::Sparse(sm) => {
            w.write_all(&[2u8])?;
            w_u64(&mut w, sm.n_rows() as u64)?;
            w_u64(&mut w, sm.n_cols() as u64)?;
            w_f32s(&mut w, targets)?;
            for j in 0..sm.n_cols() {
                let (rows, vals) = sm.col(j);
                w_u64(&mut w, rows.len() as u64)?;
                for &r in rows {
                    w.write_all(&r.to_le_bytes())?;
                }
                w_f32s(&mut w, vals)?;
            }
        }
        Matrix::Quantized(_) => bail!("save the fp32 source, not the quantized view"),
    }
    Ok(())
}

/// Load a dataset saved by [`save_dataset`].
pub(crate) fn load_dataset<R: Read>(mut r: R) -> Result<(Matrix, Vec<f32>)> {
    let mut magic = [0u8; 5];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an HTHC1 file");
    }
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    let d = r_u64(&mut r)? as usize;
    let n = r_u64(&mut r)? as usize;
    let targets = r_f32s(&mut r, d)?;
    match kind[0] {
        1 => {
            let data = r_f32s(&mut r, d * n)?;
            Ok((Matrix::Dense(DenseMatrix::from_col_major(d, n, data)), targets))
        }
        2 => {
            let mut cols = Vec::with_capacity(n);
            for _ in 0..n {
                let nnz = r_u64(&mut r)? as usize;
                let rows = r_u32s(&mut r, nnz)?;
                let vals = r_f32s(&mut r, nnz)?;
                cols.push(rows.into_iter().zip(vals).collect());
            }
            Ok((Matrix::Sparse(SparseMatrix::from_columns(d, cols)), targets))
        }
        k => bail!("unknown dataset kind {k}"),
    }
}

/// A trained model export: name + lambda + alpha.
#[derive(Debug, Clone, PartialEq)]
pub struct SavedModel {
    pub name: String,
    pub lam: f32,
    pub alpha: Vec<f32>,
}

pub fn save_model<W: Write>(mut w: W, m: &SavedModel) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&[3u8])?;
    w_u64(&mut w, m.name.len() as u64)?;
    w.write_all(m.name.as_bytes())?;
    w.write_all(&m.lam.to_le_bytes())?;
    w_u64(&mut w, m.alpha.len() as u64)?;
    w_f32s(&mut w, &m.alpha)?;
    Ok(())
}

pub fn load_model<R: Read>(mut r: R) -> Result<SavedModel> {
    let mut magic = [0u8; 5];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an HTHC1 file");
    }
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    if kind[0] != 3 {
        bail!("not a model file (kind {})", kind[0]);
    }
    let name_len = r_u64(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let mut lam = [0u8; 4];
    r.read_exact(&mut lam)?;
    let n = r_u64(&mut r)? as usize;
    let alpha = r_f32s(&mut r, n)?;
    Ok(SavedModel {
        name: String::from_utf8(name).context("model name utf8")?,
        lam: f32::from_le_bytes(lam),
        alpha,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ColumnOps, DatasetBuilder, DatasetKind, Family};

    #[test]
    fn dense_roundtrip() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(501)
            .build()
            .unwrap();
        let mut buf = Vec::new();
        save_dataset(&mut buf, g.matrix(), g.targets()).unwrap();
        let (m2, t2) = load_dataset(buf.as_slice()).unwrap();
        assert_eq!(t2, g.targets());
        if let (Matrix::Dense(a), Matrix::Dense(b)) = (g.matrix(), &m2) {
            assert_eq!(a.raw(), b.raw());
        } else {
            panic!("expected dense");
        }
    }

    #[test]
    fn sparse_roundtrip() {
        let g = DatasetBuilder::generated(DatasetKind::News20Like, Family::Regression)
            .scale(0.03)
            .seed(502)
            .build()
            .unwrap();
        let mut buf = Vec::new();
        save_dataset(&mut buf, g.matrix(), g.targets()).unwrap();
        let (m2, t2) = load_dataset(buf.as_slice()).unwrap();
        assert_eq!(t2, g.targets());
        if let (Matrix::Sparse(a), Matrix::Sparse(b)) = (g.matrix(), &m2) {
            assert_eq!(a.n_rows(), b.n_rows());
            for j in 0..a.n_cols() {
                assert_eq!(a.col(j), b.col(j), "col {j}");
            }
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn model_roundtrip() {
        let m = SavedModel { name: "lasso".into(), lam: 0.125, alpha: vec![0.0, -1.5, 3.25] };
        let mut buf = Vec::new();
        save_model(&mut buf, &m).unwrap();
        let m2 = load_model(buf.as_slice()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn corrupt_magic_rejected() {
        assert!(load_dataset(&b"BOGUS\x01"[..]).is_err());
        assert!(load_model(&b"HTHC1\x01"[..]).is_err()); // dataset kind, not model
    }

    #[test]
    fn truncated_file_errors_not_panics() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(503)
            .build()
            .unwrap();
        let mut buf = Vec::new();
        save_dataset(&mut buf, g.matrix(), g.targets()).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(load_dataset(buf.as_slice()).is_err());
    }

    #[test]
    fn quantized_save_refused() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(504)
            .represent(crate::data::Represent::Quantized)
            .build()
            .unwrap();
        assert!(save_dataset(Vec::new(), g.matrix(), g.targets()).is_err());
    }
}
