//! [`Dataset`]: matrix + targets + provenance in one owned value.
//!
//! The pre-redesign data layer passed `(Matrix, Vec<f32>)` pairs around
//! and smeared ingestion/normalization/quantization/placement across
//! `data::io`, `data::libsvm`, `data::preprocess` and `main.rs`.  A
//! [`Dataset`] is the one owned value the rest of the crate consumes:
//! `solver::Problem` borrows it whole (targets are no longer a separate
//! field), the `TierSim` charges traffic against its recorded
//! [`placement`](Dataset::placement), and zero-copy column
//! [`views`](Dataset::view) serve splits, shards and restricted sweeps.
//!
//! Construction goes through [`DatasetBuilder`](super::DatasetBuilder)
//! — see `rust/DESIGN.md` §9 for the pipeline stages.

use super::generator::{DatasetKind, Family};
use super::view::DatasetView;
use super::{io, BlockOps, ColumnOps, Matrix};
use crate::memory::Tier;
use crate::util::Rng;
use crate::Result;
use std::path::{Path, PathBuf};

/// Where a dataset came from (recorded by the builder).
#[derive(Clone, Debug, PartialEq)]
pub enum SourceInfo {
    /// Synthetic Table-I analogue from [`super::generator::generate`].
    Generated { kind: DatasetKind, scale: f64, seed: u64 },
    /// LIBSVM text file.
    Libsvm { path: PathBuf },
    /// `HTHC1` binary file (written by [`Dataset::save`]).
    Binary { path: PathBuf },
    /// Parsed LIBSVM samples handed to the builder directly.
    Samples,
    /// An in-memory matrix handed to the builder directly.
    InMemory,
}

impl SourceInfo {
    pub fn describe(&self) -> String {
        match self {
            SourceInfo::Generated { kind, scale, seed } => {
                format!("{} (scale {scale}, seed {seed})", kind.name())
            }
            SourceInfo::Libsvm { path } => format!("libsvm {}", path.display()),
            SourceInfo::Binary { path } => format!("binary {}", path.display()),
            SourceInfo::Samples => "libsvm samples".into(),
            SourceInfo::InMemory => "in-memory".into(),
        }
    }
}

/// Provenance and derived statistics carried alongside the matrix.
#[derive(Clone, Debug)]
pub struct DatasetMeta {
    pub source: SourceInfo,
    /// Which orientation the matrix is in (coordinates = features for
    /// regression, coordinates = samples for classification).
    pub family: Family,
    /// Per-column scales applied by the builder's unit-norm stage —
    /// `alpha` learned on the normalized data maps back to the original
    /// column scale via `alpha_i * col_scales[i]`.
    pub col_scales: Option<Vec<f32>>,
    /// Mean subtracted from the targets by the centering stage.
    pub target_mean: Option<f32>,
    /// Per-coordinate labels (classification orientation only).
    pub labels: Option<Vec<f32>>,
    /// Planted sparse model (generated regression data only).
    pub alpha_star: Option<Vec<f32>>,
    /// Memory tier the matrix is placed in (what the engines charge
    /// bulk matrix reads against).
    pub placement: Tier,
    /// Stored entries in the current representation.
    pub nnz: u64,
    /// Bytes streamed by one full pass in the current representation.
    pub bytes: u64,
}

/// One training dataset: matrix + targets + [`DatasetMeta`].
///
/// Targets always have length `n_rows` (zeros in the classification
/// orientation, where the per-coordinate labels live in the metadata).
pub struct Dataset {
    matrix: Matrix,
    targets: Vec<f32>,
    meta: DatasetMeta,
}

impl Dataset {
    /// Assemble from parts (the builder's final step).
    pub(crate) fn assemble(matrix: Matrix, targets: Vec<f32>, meta: DatasetMeta) -> Self {
        assert_eq!(
            targets.len(),
            matrix.n_rows(),
            "targets length must equal matrix rows"
        );
        Dataset { matrix, targets, meta }
    }

    /// In-memory dataset with default metadata — the terse spelling of
    /// `DatasetBuilder::in_memory(matrix, targets).build()` for tests
    /// and harnesses that assemble raw matrices by hand.
    ///
    /// Panics on any builder rejection (length mismatch, empty matrix),
    /// quoting the builder's actual error.
    pub fn from_parts(matrix: Matrix, targets: Vec<f32>) -> Self {
        super::DatasetBuilder::in_memory(matrix, targets)
            .build()
            .unwrap_or_else(|e| panic!("Dataset::from_parts: {e}"))
    }

    /// Generated dataset with default pipeline stages — the terse
    /// spelling of `DatasetBuilder::generated(kind, family).scale(..)
    /// .seed(..).build()` shared by the test suites (generation cannot
    /// fail, so the `Result` is absorbed here).
    pub fn generated(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Self {
        super::DatasetBuilder::generated(kind, family)
            .scale(scale)
            .seed(seed)
            .build()
            .unwrap_or_else(|e| panic!("Dataset::generated: {e}"))
    }

    pub fn matrix(&self) -> &Matrix {
        &self.matrix
    }

    pub fn targets(&self) -> &[f32] {
        &self.targets
    }

    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    pub fn family(&self) -> Family {
        self.meta.family
    }

    /// The memory tier the matrix lives in (engines key their
    /// [`TierSim`](crate::memory::TierSim) charges off this).
    pub fn placement(&self) -> Tier {
        self.meta.placement
    }

    /// Per-coordinate labels (classification orientation).
    pub fn labels(&self) -> Option<&[f32]> {
        self.meta.labels.as_deref()
    }

    /// Planted model of generated regression data.
    pub fn alpha_star(&self) -> Option<&[f32]> {
        self.meta.alpha_star.as_deref()
    }

    pub fn n_rows(&self) -> usize {
        self.matrix.n_rows()
    }

    pub fn n_cols(&self) -> usize {
        self.matrix.n_cols()
    }

    /// `d` in the paper's notation (rows).
    pub fn d(&self) -> usize {
        self.matrix.n_rows()
    }

    /// `n` in the paper's notation (columns = model coordinates).
    pub fn n(&self) -> usize {
        self.matrix.n_cols()
    }

    pub fn repr_name(&self) -> &'static str {
        self.matrix.repr_name()
    }

    /// Column access (delegates to the matrix).
    pub fn as_ops(&self) -> &dyn ColumnOps {
        self.matrix.as_ops()
    }

    /// Bulk column access (delegates to the matrix).
    pub fn as_block_ops(&self) -> &dyn BlockOps {
        self.matrix.as_block_ops()
    }

    /// `v = D * alpha` from scratch (delegates to the matrix).
    pub fn matvec_alpha(&self, alpha: &[f32]) -> Vec<f32> {
        self.matrix.matvec_alpha(alpha)
    }

    /// One-line human description (shape, representation, size, tier).
    pub fn describe(&self) -> String {
        let family = match self.meta.family {
            Family::Regression => "regression",
            Family::Classification => "classification",
        };
        let tier = match self.meta.placement {
            Tier::Slow => "DRAM",
            Tier::Fast => "MCDRAM",
        };
        let mut s = format!(
            "{} [{}] {} x {} ({}, {}, {})",
            self.meta.source.describe(),
            family,
            self.d(),
            self.n(),
            self.repr_name(),
            crate::util::fmt_bytes(self.meta.bytes),
            tier,
        );
        if self.meta.col_scales.is_some() {
            s.push_str(" [unit-normed]");
        }
        if let Some(m) = self.meta.target_mean {
            s.push_str(&format!(" [targets centered, mean {m:.4}]"));
        }
        s
    }

    // -- views ---------------------------------------------------------

    /// Zero-copy view over every column.
    pub fn view(&self) -> DatasetView<'_> {
        DatasetView::range(self, 0, self.n_cols())
    }

    /// Zero-copy view over the column range `[lo, hi)`.
    ///
    /// Panics if `lo > hi` or `hi > n_cols`.
    pub fn col_range(&self, lo: usize, hi: usize) -> DatasetView<'_> {
        DatasetView::range(self, lo, hi)
    }

    /// Zero-copy view over an explicit column subset.
    ///
    /// Panics if any index is out of bounds.
    pub fn col_subset(&self, cols: Vec<usize>) -> DatasetView<'_> {
        DatasetView::subset(self, cols)
    }

    /// Deterministic train/validation split over *columns* (model
    /// coordinates): for the classification orientation columns are
    /// samples, so this is a sample split; for regression it holds out
    /// coordinates (screening-style validation).  Both sides are
    /// non-empty and sorted for access locality.
    ///
    /// Panics unless `0 < train_frac < 1` and `n_cols >= 2`.
    pub fn split(&self, train_frac: f64, seed: u64) -> (DatasetView<'_>, DatasetView<'_>) {
        let n = self.n_cols();
        assert!(n >= 2, "split needs at least two columns");
        assert!(
            train_frac > 0.0 && train_frac < 1.0,
            "train fraction must be in (0, 1), got {train_frac}"
        );
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut idx);
        let n_train = (((n as f64) * train_frac).round() as usize).clamp(1, n - 1);
        let mut train = idx[..n_train].to_vec();
        let mut val = idx[n_train..].to_vec();
        train.sort_unstable();
        val.sort_unstable();
        (DatasetView::subset(self, train), DatasetView::subset(self, val))
    }

    /// Export as LIBSVM samples in **raw input space**, inverting the
    /// recorded preprocessing (normalization scales divided back out,
    /// target mean added back).  This is the serving layer's rebuild
    /// currency: streamed raw examples and the current training set
    /// meet in one sample list that a fresh
    /// [`DatasetBuilder`](super::DatasetBuilder) run re-normalizes
    /// consistently.
    ///
    /// Regression orientation emits one sample per row; classification
    /// emits one per column with the label sign divided out of the
    /// stored `d_j = y_j x_j` entries (and fails without labels).
    /// Quantized data cannot be exported exactly and is rejected.
    pub fn to_samples(&self) -> Result<Vec<super::libsvm::Sample>> {
        use super::libsvm::Sample;
        let scales = self.meta.col_scales.as_deref();
        let scale_of = |j: usize| scales.map_or(1.0, |s| s[j]);
        match self.meta.family {
            Family::Regression => {
                let mean = self.meta.target_mean.unwrap_or(0.0);
                let mut feats: Vec<Vec<(u32, f32)>> = vec![Vec::new(); self.n_rows()];
                // column-outer iteration in ascending j keeps every
                // per-row feature list sorted by index for free
                match &self.matrix {
                    Matrix::Dense(dm) => {
                        for j in 0..self.n_cols() {
                            let s = scale_of(j);
                            for (r, &x) in dm.col(j).iter().enumerate() {
                                if x != 0.0 {
                                    feats[r].push((j as u32, x / s));
                                }
                            }
                        }
                    }
                    Matrix::Sparse(sm) => {
                        for j in 0..self.n_cols() {
                            let s = scale_of(j);
                            let (rows, vals) = sm.col(j);
                            for (&r, &x) in rows.iter().zip(vals) {
                                feats[r as usize].push((j as u32, x / s));
                            }
                        }
                    }
                    Matrix::Quantized(_) => crate::bail!(
                        "quantized data cannot be exported as exact samples — \
                         keep the fp32 source for ingest-append rebuilds"
                    ),
                }
                Ok(feats
                    .into_iter()
                    .zip(&self.targets)
                    .map(|(features, &t)| Sample { label: t + mean, features })
                    .collect())
            }
            Family::Classification => {
                let Some(labels) = self.meta.labels.as_deref() else {
                    crate::bail!(
                        "classification dataset has no labels — cannot invert \
                         the label-scaled columns into samples"
                    );
                };
                let mut out = Vec::with_capacity(self.n_cols());
                for j in 0..self.n_cols() {
                    let y = labels[j];
                    // stored d_j = y_j x_j * s_j with y in {-1, +1}, so
                    // dividing by y is multiplying by it
                    let inv = y / scale_of(j);
                    let features: Vec<(u32, f32)> = match &self.matrix {
                        Matrix::Dense(dm) => dm
                            .col(j)
                            .iter()
                            .enumerate()
                            .filter(|&(_, &x)| x != 0.0)
                            .map(|(r, &x)| (r as u32, x * inv))
                            .collect(),
                        Matrix::Sparse(sm) => {
                            let (rows, vals) = sm.col(j);
                            rows.iter().zip(vals).map(|(&r, &x)| (r, x * inv)).collect()
                        }
                        Matrix::Quantized(_) => crate::bail!(
                            "quantized data cannot be exported as exact samples — \
                             keep the fp32 source for ingest-append rebuilds"
                        ),
                    };
                    out.push(Sample { label: y, features });
                }
                Ok(out)
            }
        }
    }

    // -- persistence ---------------------------------------------------

    /// Save in the `HTHC1` binary format (load back through
    /// `DatasetBuilder::path`).  Refuses quantized data — save the fp32
    /// source and re-quantize on load instead.
    ///
    /// Only the matrix and targets are persisted: the `HTHC1` format
    /// predates [`DatasetMeta`], so provenance (family, labels,
    /// normalization scales, target mean) is **not** round-tripped —
    /// the loader rebuilds metadata from its own pipeline flags, and a
    /// reloaded classification dataset has `labels() == None`.  A
    /// meta-preserving record is a ROADMAP follow-up.
    pub fn save(&self, path: &Path) -> Result<()> {
        use crate::util::error::Context;
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        io::save_dataset(std::io::BufWriter::new(f), &self.matrix, &self.targets)
    }
}

/// Stored entries across all columns in the current representation.
pub(crate) fn stored_nnz(m: &Matrix) -> u64 {
    let ops = m.as_ops();
    (0..m.n_cols()).map(|j| ops.nnz(j) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::super::DatasetBuilder;
    use super::*;

    fn ds(seed: u64) -> Dataset {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn accessors_are_consistent() {
        let g = ds(9001);
        assert_eq!(g.targets().len(), g.n_rows());
        assert_eq!(g.d(), g.n_rows());
        assert_eq!(g.n(), g.n_cols());
        assert_eq!(g.meta().bytes, g.matrix().total_bytes());
        assert_eq!(g.meta().nnz, stored_nnz(g.matrix()));
        assert_eq!(g.placement(), Tier::Slow);
        assert!(g.describe().contains("tiny"));
    }

    #[test]
    fn split_partitions_columns() {
        let g = ds(9002);
        let (train, val) = g.split(0.75, 7);
        assert_eq!(train.len() + val.len(), g.n_cols());
        let mut all: Vec<usize> = (0..train.len())
            .map(|k| train.parent_col(k))
            .chain((0..val.len()).map(|k| val.parent_col(k)))
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..g.n_cols()).collect::<Vec<_>>());
        // deterministic per seed
        let (train2, _) = g.split(0.75, 7);
        assert_eq!(
            (0..train.len()).map(|k| train.parent_col(k)).collect::<Vec<_>>(),
            (0..train2.len()).map(|k| train2.parent_col(k)).collect::<Vec<_>>()
        );
        // different seed shuffles differently
        let (train3, _) = g.split(0.75, 8);
        let a: Vec<usize> = (0..train.len()).map(|k| train.parent_col(k)).collect();
        let b: Vec<usize> = (0..train3.len()).map(|k| train3.parent_col(k)).collect();
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic]
    fn split_rejects_bad_fraction() {
        let g = ds(9003);
        let _ = g.split(1.5, 1);
    }

    #[test]
    fn to_samples_inverts_preprocessing_regression() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(9005)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        let raw = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(9005)
            .build()
            .unwrap();
        let samples = g.to_samples().unwrap();
        assert_eq!(samples.len(), g.n_rows());
        let Matrix::Dense(dm) = raw.matrix() else { panic!("expected dense") };
        for (r, s) in samples.iter().enumerate() {
            assert!((s.label - raw.targets()[r]).abs() < 1e-4);
            for &(j, x) in &s.features {
                let want = dm.col(j as usize)[r];
                assert!((x - want).abs() < 1e-4, "row {r} feat {j}: {x} vs {want}");
            }
            // sorted indices (the LIBSVM invariant)
            assert!(s.features.windows(2).all(|w| w[0].0 < w[1].0));
        }
        // rebuilding from the exported samples reproduces the dataset
        let back = DatasetBuilder::libsvm_samples(samples)
            .family(Family::Regression)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        assert_eq!(back.n_rows(), g.n_rows());
        for j in 0..g.n_cols() {
            assert!((back.as_ops().sq_norm(j) - g.as_ops().sq_norm(j)).abs() < 1e-4);
        }
    }

    #[test]
    fn to_samples_divides_labels_out_classification() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Classification)
            .seed(9006)
            .normalize(true)
            .build()
            .unwrap();
        let samples = g.to_samples().unwrap();
        assert_eq!(samples.len(), g.n_cols(), "one sample per column");
        let labels = g.labels().unwrap();
        let scales = g.meta().col_scales.as_ref().unwrap();
        let Matrix::Dense(dm) = g.matrix() else { panic!("expected dense") };
        for (j, s) in samples.iter().enumerate() {
            assert_eq!(s.label, labels[j]);
            for &(r, x) in &s.features {
                let stored = dm.col(j)[r as usize];
                let want = stored * labels[j] / scales[j];
                assert!((x - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn save_roundtrips_through_builder() {
        let g = ds(9004);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hthc-ds-roundtrip-{}.bin", std::process::id()));
        g.save(&path).unwrap();
        let back = DatasetBuilder::path(&path).build().unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.n_rows(), g.n_rows());
        assert_eq!(back.n_cols(), g.n_cols());
        assert_eq!(back.targets(), g.targets());
        assert!(matches!(back.meta().source, SourceInfo::Binary { .. }));
    }
}
