//! LIBSVM text-format parsing.
//!
//! The paper's real datasets ship in this format (`label idx:val ...`,
//! 1-based indices).  The parser is deliberately tolerant of what
//! real-world files contain — `#` comments (whole-line or trailing),
//! blank lines, stray whitespace (including CRLF line endings), and
//! out-of-order feature indices (sorted on ingest) — and rejects, with
//! line numbers, what cannot be saved: malformed pairs, 0-based
//! indices, and duplicate feature indices within a sample.
//!
//! Datasets are built from parsed samples by `DatasetBuilder` (the
//! orientation conversions below are crate-internal pipeline stages);
//! the parser itself stays public for tooling and tests.

use crate::data::sparse::SparseMatrix;
use crate::util::error::Context;
use crate::{bail, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// One parsed sample: label + sorted (0-based feature, value) pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub label: f32,
    pub features: Vec<(u32, f32)>,
}

/// Parse a LIBSVM file.
pub fn read_file(path: &Path) -> Result<Vec<Sample>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read(BufReader::new(f))
}

/// Parse LIBSVM lines from any reader.
///
/// Tolerated: `#` comments, blank lines, leading/trailing whitespace
/// (and CRLF endings), out-of-order feature indices (sorted on
/// ingest).  Rejected with a line number: malformed pairs, non-numeric
/// labels/indices/values, non-finite labels/values (`nan`/`inf` parse
/// as floats but poison every norm and dot downstream), 0-based
/// indices, and duplicate feature indices within one sample.
///
/// Use [`read_with`] to opt out of the finiteness check when a
/// downstream stage cleans the data itself.
pub fn read<R: BufRead>(r: R) -> Result<Vec<Sample>> {
    read_with(r, true)
}

/// [`read`] with the non-finite rejection made explicit:
/// `reject_nonfinite = false` lets `nan`/`inf` labels and values
/// through (they are valid f32 spellings) for callers that scrub or
/// tolerate them — the `DatasetBuilder`'s `validate(false)` escape
/// hatch routes here.
pub fn read_with<R: BufRead>(r: R, reject_nonfinite: bool) -> Result<Vec<Sample>> {
    let mut out = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let label: f32 = toks
            .next()
            // PANIC-OK: the line was checked non-empty above, so
            // split_whitespace yields at least one token.
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        if reject_nonfinite && !label.is_finite() {
            bail!("line {}: non-finite label {label}", lineno + 1);
        }
        let mut features = Vec::new();
        for t in toks {
            let (i, v) = t
                .split_once(':')
                .with_context(|| format!("line {}: bad pair {t:?}", lineno + 1))?;
            let i: u32 = i
                .parse()
                .with_context(|| format!("line {}: bad index {i:?}", lineno + 1))?;
            if i == 0 {
                bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
            }
            let v: f32 = v
                .parse()
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            if reject_nonfinite && !v.is_finite() {
                bail!("line {}: non-finite value for feature {i}: {v}", lineno + 1);
            }
            features.push((i - 1, v));
        }
        // out-of-order indices are tolerated (sorted); duplicates are a
        // hard error — "last one wins" silently corrupts norms and dots
        features.sort_unstable_by_key(|&(i, _)| i);
        if let Some(w) = features.windows(2).find(|w| w[0].0 == w[1].0) {
            bail!(
                "line {}: duplicate feature index {}",
                lineno + 1,
                w[0].0 + 1
            );
        }
        out.push(Sample { label, features });
    }
    Ok(out)
}

/// Write samples in LIBSVM format.
pub fn write<W: Write>(mut w: W, samples: &[Sample]) -> Result<()> {
    for s in samples {
        write!(w, "{}", s.label)?;
        for &(i, v) in &s.features {
            write!(w, " {}:{}", i + 1, v)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Number of features = 1 + max index.
pub fn n_features(samples: &[Sample]) -> usize {
    samples
        .iter()
        .flat_map(|s| s.features.iter().map(|&(i, _)| i as usize + 1))
        .max()
        .unwrap_or(0)
}

/// Regression orientation: coordinates = features.
/// Returns (D of shape samples x features, targets = labels).
/// Crate-internal: datasets are oriented by the `DatasetBuilder`.
pub(crate) fn to_regression(samples: &[Sample]) -> (SparseMatrix, Vec<f32>) {
    let d = samples.len();
    let n = n_features(samples);
    let mut cols: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
    for (row, s) in samples.iter().enumerate() {
        for &(feat, v) in &s.features {
            cols[feat as usize].push((row as u32, v));
        }
    }
    let targets = samples.iter().map(|s| s.label).collect();
    (SparseMatrix::from_columns(d, cols), targets)
}

/// Dual-SVM orientation: coordinates = samples, columns y_i * x_i.
/// Returns (D of shape features x samples, labels per column).
/// Crate-internal: datasets are oriented by the `DatasetBuilder`.
pub(crate) fn to_classification(samples: &[Sample]) -> (SparseMatrix, Vec<f32>) {
    let d = n_features(samples);
    let labels: Vec<f32> = samples
        .iter()
        .map(|s| if s.label > 0.0 { 1.0 } else { -1.0 })
        .collect();
    let cols = samples
        .iter()
        .zip(&labels)
        .map(|(s, &y)| s.features.iter().map(|&(i, v)| (i, y * v)).collect())
        .collect();
    (SparseMatrix::from_columns(d, cols), labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ColumnOps;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.5
-1 2:2.0 # trailing comment

+1 1:-1.0 2:0.25 3:4.0
";

    #[test]
    fn parse_basic() {
        let s = read(SAMPLE.as_bytes()).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].label, 1.0);
        assert_eq!(s[0].features, vec![(0, 0.5), (2, 1.5)]);
        assert_eq!(s[1].features, vec![(1, 2.0)]);
        assert_eq!(n_features(&s), 3);
    }

    #[test]
    fn zero_index_rejected() {
        assert!(read("+1 0:1.0".as_bytes()).is_err());
    }

    #[test]
    fn bad_pair_rejected() {
        assert!(read("+1 abc".as_bytes()).is_err());
        assert!(read("+1 2:xyz".as_bytes()).is_err());
    }

    #[test]
    fn nonfinite_rejected_with_line_number() {
        let err = read("+1 1:0.5\n+1 2:nan".as_bytes()).unwrap_err();
        assert!(format!("{err}").contains("line 2"), "{err}");
        let err = read("inf 1:0.5".as_bytes()).unwrap_err();
        assert!(format!("{err}").contains("line 1"), "{err}");
        assert!(read("+1 1:-inf".as_bytes()).is_err());
    }

    #[test]
    fn read_with_escape_hatch_admits_nonfinite() {
        let s = read_with("nan 1:inf".as_bytes(), false).unwrap();
        assert!(s[0].label.is_nan());
        assert_eq!(s[0].features[0].1, f32::INFINITY);
    }

    #[test]
    fn roundtrip() {
        let s = read(SAMPLE.as_bytes()).unwrap();
        let mut buf = Vec::new();
        write(&mut buf, &s).unwrap();
        let s2 = read(buf.as_slice()).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn regression_orientation() {
        let s = read(SAMPLE.as_bytes()).unwrap();
        let (m, targets) = to_regression(&s);
        assert_eq!(m.n_rows(), 3); // samples
        assert_eq!(m.n_cols(), 3); // features
        assert_eq!(targets, vec![1.0, -1.0, 1.0]);
        // feature 0 appears in samples 0 and 2
        let (rows, vals) = m.col(0);
        assert_eq!(rows, &[0, 2]);
        assert_eq!(vals, &[0.5, -1.0]);
    }

    #[test]
    fn classification_orientation_scales_by_label() {
        let s = read(SAMPLE.as_bytes()).unwrap();
        let (m, labels) = to_classification(&s);
        assert_eq!(m.n_rows(), 3); // features
        assert_eq!(m.n_cols(), 3); // samples
        assert_eq!(labels, vec![1.0, -1.0, 1.0]);
        // sample 1 has label -1, feature 1 value 2.0 -> stored -2.0
        let (rows, vals) = m.col(1);
        assert_eq!(rows, &[1]);
        assert_eq!(vals, &[-2.0]);
    }
}
