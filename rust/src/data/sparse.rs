//! Sparse representation (paper §IV-D).
//!
//! The full data matrix is CSC-like: each column stores only its
//! non-zero `(index, value)` pairs.  Task B keeps *its own copy* of the
//! selected columns in the fast tier, split into fixed-length chunks
//! managed by a free stack, so columns of very different length can be
//! swapped in and out of preallocated space each epoch without
//! reallocating — that is the paper's chunk/stack/linked-list design,
//! implemented here with chunk indices instead of raw pointers.

use super::{BlockOps, ColumnOps};
use crate::kernels;

/// Minimum chunk length: "the minimal chunk size of 32 enables the use
/// of multiple AVX-512 accumulators" (§IV-D).
pub const MIN_CHUNK: usize = 32;

/// CSC sparse matrix: per-column (row-index, value) pairs.
pub struct SparseMatrix {
    d: usize,
    n: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<f32>,
    sq_norms: Vec<f32>,
}

impl SparseMatrix {
    /// Build from per-column (row, value) lists.  Rows may be unsorted.
    pub fn from_columns(d: usize, cols: Vec<Vec<(u32, f32)>>) -> Self {
        let n = cols.len();
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        let mut sq_norms = Vec::with_capacity(n);
        col_ptr.push(0);
        for mut col in cols {
            col.sort_unstable_by_key(|&(r, _)| r);
            let start = values.len();
            for (r, v) in col {
                assert!((r as usize) < d, "row {r} out of bounds (d={d})");
                row_idx.push(r);
                values.push(v);
            }
            col_ptr.push(row_idx.len());
            sq_norms.push(kernels::sq_norm(&values[start..]));
        }
        SparseMatrix { d, n, col_ptr, row_idx, values, sq_norms }
    }

    /// Entries of column `j` as parallel slices.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[a..b], &self.values[a..b])
    }

    /// `v = D * alpha` from scratch.
    pub fn matvec_alpha(&self, alpha: &[f32]) -> Vec<f32> {
        let mut v = vec![0.0f32; self.d];
        for (j, &a) in alpha.iter().enumerate() {
            if a != 0.0 {
                let (rows, vals) = self.col(j);
                kernels::sparse_axpy(rows, vals, a, &mut v);
            }
        }
        v
    }

    /// Overall density (nnz / (d*n)).
    pub fn density(&self) -> f64 {
        self.values.len() as f64 / (self.d as f64 * self.n as f64)
    }

    /// Densify one column (testing / PJRT padding).
    pub fn col_dense(&self, j: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        let (rows, vals) = self.col(j);
        for (&r, &x) in rows.iter().zip(vals) {
            out[r as usize] = x;
        }
        out
    }
}

impl ColumnOps for SparseMatrix {
    fn n_rows(&self) -> usize {
        self.d
    }

    fn n_cols(&self) -> usize {
        self.n
    }

    #[inline]
    fn dot(&self, col: usize, w: &[f32]) -> f32 {
        let (rows, vals) = self.col(col);
        kernels::sparse_dot(rows, vals, w)
    }

    #[inline]
    fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        // Range over *row indices*: entries are row-sorted, binary-search
        // the window.  (The paper notes V_B = 1 is best for sparse data —
        // most sparse columns are too short to split profitably.)
        let (rows, vals) = self.col(col);
        let a = rows.partition_point(|&r| (r as usize) < lo);
        let b = rows.partition_point(|&r| (r as usize) < hi);
        kernels::sparse_dot(&rows[a..b], &vals[a..b], w)
    }

    #[inline]
    fn axpy(&self, col: usize, delta: f32, v: &mut [f32]) {
        let (rows, vals) = self.col(col);
        kernels::sparse_axpy(rows, vals, delta, v);
    }

    #[inline]
    fn sq_norm(&self, col: usize) -> f32 {
        self.sq_norms[col]
    }

    fn nnz(&self, col: usize) -> usize {
        self.col_ptr[col + 1] - self.col_ptr[col]
    }

    fn col_bytes(&self, col: usize) -> u64 {
        (self.nnz(col) * 8) as u64 // (u32 index + f32 value)
    }
}

impl BlockOps for SparseMatrix {
    fn dots_block(&self, cols: &[usize], w: &[f32], out: &mut [f32]) {
        const B: usize = kernels::BLOCK_COLS;
        debug_assert_eq!(cols.len(), out.len());
        let w = &w[..self.d];
        for (cidx, o) in cols.chunks(B).zip(out.chunks_mut(B)) {
            let mut slices: [(&[u32], &[f32]); B] = [(&[], &[]); B];
            for (s, &j) in slices.iter_mut().zip(cidx) {
                *s = self.col(j);
            }
            kernels::sparse_dots_block(&slices[..cidx.len()], w, o);
        }
    }
}

// ---------------------------------------------------------------------------
// Chunked working-set storage (task B's fast-tier copy)
// ---------------------------------------------------------------------------

/// One fixed-length chunk of (index, value) pairs.
struct Chunk {
    rows: Box<[u32]>,
    vals: Box<[f32]>,
    /// Valid prefix length (last chunk of a column may be partial).
    len: usize,
    /// Next chunk of the same column, or usize::MAX.
    next: usize,
}

const NONE: usize = usize::MAX;

/// Preallocated pool of chunks with a free stack + per-column chain
/// heads: the paper's §IV-D structure.  `swap_in` pops chunks from the
/// stack to hold a new column; `swap_out` pushes them back.  Total pool
/// size is fixed up-front from the `m` densest columns, as in the paper.
pub struct ChunkPool {
    chunk_len: usize,
    chunks: Vec<Chunk>,
    free: Vec<usize>,
    /// Chain head per working-set slot.
    heads: Vec<usize>,
    /// nnz per slot (for iteration).
    lens: Vec<usize>,
    sq_norms: Vec<f32>,
}

impl ChunkPool {
    /// Pool sized for `slots` columns of up to `max_nnz` entries each.
    pub fn new(slots: usize, max_nnz: usize, chunk_len: usize) -> Self {
        assert!(chunk_len >= MIN_CHUNK && chunk_len % MIN_CHUNK == 0);
        let per_col = max_nnz.div_ceil(chunk_len);
        let total = slots * per_col;
        let mut chunks = Vec::with_capacity(total);
        for _ in 0..total {
            chunks.push(Chunk {
                rows: vec![0u32; chunk_len].into_boxed_slice(),
                vals: vec![0f32; chunk_len].into_boxed_slice(),
                len: 0,
                next: NONE,
            });
        }
        ChunkPool {
            chunk_len,
            chunks,
            free: (0..total).rev().collect(),
            heads: vec![NONE; slots],
            lens: vec![0; slots],
            sq_norms: vec![0.0; slots],
        }
    }

    pub fn chunk_len(&self) -> usize {
        self.chunk_len
    }

    pub fn free_chunks(&self) -> usize {
        self.free.len()
    }

    pub fn slots(&self) -> usize {
        self.heads.len()
    }

    /// Copy a column into `slot`, linking chunks popped from the stack.
    /// Returns false (slot untouched) if the pool is exhausted.
    pub fn swap_in(&mut self, slot: usize, rows: &[u32], vals: &[f32]) -> bool {
        assert_eq!(rows.len(), vals.len());
        self.swap_out(slot);
        let needed = rows.len().div_ceil(self.chunk_len);
        if needed > self.free.len() {
            return false;
        }
        let mut head = NONE;
        let mut tail = NONE;
        let mut sq = 0.0f32;
        for start in (0..rows.len()).step_by(self.chunk_len) {
            let end = (start + self.chunk_len).min(rows.len());
            // PANIC-OK: `needed <= free.len()` was checked above.
            let id = self.free.pop().expect("checked above");
            let c = &mut self.chunks[id];
            let k = end - start;
            c.rows[..k].copy_from_slice(&rows[start..end]);
            c.vals[..k].copy_from_slice(&vals[start..end]);
            c.len = k;
            c.next = NONE;
            sq += kernels::sq_norm(&vals[start..end]);
            if head == NONE {
                head = id;
            } else {
                self.chunks[tail].next = id;
            }
            tail = id;
        }
        self.heads[slot] = head;
        self.lens[slot] = rows.len();
        self.sq_norms[slot] = sq;
        true
    }

    /// Return `slot`'s chunks to the free stack.
    pub fn swap_out(&mut self, slot: usize) {
        let mut id = self.heads[slot];
        while id != NONE {
            let next = self.chunks[id].next;
            self.chunks[id].len = 0;
            self.chunks[id].next = NONE;
            self.free.push(id);
            id = next;
        }
        self.heads[slot] = NONE;
        self.lens[slot] = 0;
        self.sq_norms[slot] = 0.0;
    }

    /// Iterate `slot`'s (rows, vals) chunk by chunk.
    pub fn for_each_chunk<F: FnMut(&[u32], &[f32])>(&self, slot: usize, mut f: F) {
        let mut id = self.heads[slot];
        while id != NONE {
            let c = &self.chunks[id];
            f(&c.rows[..c.len], &c.vals[..c.len]);
            id = c.next;
        }
    }

    /// `<w, column-at-slot>` across chunks.
    pub fn dot(&self, slot: usize, w: &[f32]) -> f32 {
        let mut s = 0.0f32;
        self.for_each_chunk(slot, |rows, vals| s += kernels::sparse_dot(rows, vals, w));
        s
    }

    pub fn nnz(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn sq_norm(&self, slot: usize) -> f32 {
        self.sq_norms[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat() -> SparseMatrix {
        // d=5; col0: rows {0:1, 4:2}; col1: rows {2:-3}; col2: empty
        SparseMatrix::from_columns(
            5,
            vec![vec![(4, 2.0), (0, 1.0)], vec![(2, -3.0)], vec![]],
        )
    }

    #[test]
    fn construction_sorts_rows() {
        let m = mat();
        let (rows, vals) = m.col(0);
        assert_eq!(rows, &[0, 4]);
        assert_eq!(vals, &[1.0, 2.0]);
        assert_eq!(m.nnz(2), 0);
    }

    #[test]
    fn dot_and_sq_norm() {
        let m = mat();
        let w = vec![1.0, 1.0, 1.0, 1.0, 0.5];
        assert_eq!(m.dot(0, &w), 2.0);
        assert_eq!(m.dot(1, &w), -3.0);
        assert_eq!(m.dot(2, &w), 0.0);
        assert_eq!(m.sq_norm(0), 5.0);
        assert_eq!(m.sq_norm(1), 9.0);
    }

    #[test]
    fn dot_range_by_row_window() {
        let m = mat();
        let w = vec![1.0; 5];
        assert_eq!(m.dot_range(0, &w, 0, 1), 1.0); // row 0 only
        assert_eq!(m.dot_range(0, &w, 1, 5), 2.0); // row 4 only
        let whole = m.dot_range(0, &w, 0, 5);
        assert_eq!(whole, m.dot(0, &w));
    }

    #[test]
    fn axpy_scatter() {
        let m = mat();
        let mut v = vec![0.0; 5];
        m.axpy(0, 2.0, &mut v);
        assert_eq!(v, vec![2.0, 0.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn matvec_alpha_consistent() {
        let m = mat();
        let v = m.matvec_alpha(&[1.0, 2.0, 3.0]);
        assert_eq!(v, vec![1.0, 0.0, -6.0, 0.0, 2.0]);
    }

    #[test]
    fn density() {
        assert!((mat().density() - 3.0 / 15.0).abs() < 1e-12);
    }

    // --- chunk pool ---

    #[test]
    fn pool_swap_in_out_roundtrip() {
        let mut p = ChunkPool::new(2, 100, 32);
        let rows: Vec<u32> = (0..70).collect();
        let vals: Vec<f32> = (0..70).map(|i| i as f32).collect();
        assert!(p.swap_in(0, &rows, &vals));
        assert_eq!(p.nnz(0), 70);
        // 70 entries over 32-chunks = 3 chunks used
        assert_eq!(p.free_chunks(), 2 * 4 - 3);
        let mut got_rows = Vec::new();
        p.for_each_chunk(0, |r, v| {
            assert_eq!(r.len(), v.len());
            got_rows.extend_from_slice(r);
        });
        assert_eq!(got_rows, rows);
        p.swap_out(0);
        assert_eq!(p.free_chunks(), 8);
        assert_eq!(p.nnz(0), 0);
    }

    #[test]
    fn pool_dot_matches_sparse() {
        let m = mat();
        let mut p = ChunkPool::new(1, 64, 32);
        let (rows, vals) = m.col(0);
        p.swap_in(0, rows, vals);
        let w = vec![1.0, 1.0, 1.0, 1.0, 0.5];
        assert_eq!(p.dot(0, &w), m.dot(0, &w));
        assert_eq!(p.sq_norm(0), m.sq_norm(0));
    }

    #[test]
    fn pool_exhaustion_is_clean() {
        let mut p = ChunkPool::new(1, 32, 32); // exactly 1 chunk
        let rows: Vec<u32> = (0..64).collect();
        let vals = vec![1.0f32; 64];
        assert!(!p.swap_in(0, &rows, &vals)); // needs 2 chunks
        assert_eq!(p.free_chunks(), 1); // nothing leaked
        assert!(p.swap_in(0, &rows[..32], &vals[..32]));
    }

    #[test]
    fn pool_swap_replaces_previous() {
        let mut p = ChunkPool::new(1, 96, 32);
        p.swap_in(0, &[1, 2, 3], &[1.0, 2.0, 3.0]);
        p.swap_in(0, &[7], &[9.0]);
        assert_eq!(p.nnz(0), 1);
        let mut seen = Vec::new();
        p.for_each_chunk(0, |r, _| seen.extend_from_slice(r));
        assert_eq!(seen, vec![7]);
        assert_eq!(p.free_chunks(), 3 - 1);
    }
}
