//! Data representations and workload generation.
//!
//! HTHC supports three matrix representations (paper §IV-D/E):
//! dense column-major f32, chunked compressed-sparse-column, and 4-bit
//! quantized (Clover-style).  All expose the one access pattern the
//! algorithm needs — *iterate a column and dot it against a dense
//! vector* — via the [`ColumnOps`] trait, so tasks A/B and every
//! baseline are generic over representation.  Bulk consumers use the
//! [`BlockOps`] extension instead: many columns dotted per pass over
//! `w` through the blocked kernel backend (`rust/DESIGN.md` §8).
//!
//! The [`Dataset`] layer on top (`rust/DESIGN.md` §9) bundles a matrix
//! with its targets and provenance: construction goes through the
//! [`DatasetBuilder`] pipeline (source → format sniff → preprocess →
//! represent → place), and [`DatasetView`] exposes zero-copy column
//! ranges/subsets for splits, per-core shards and restricted sweeps.

pub mod builder;
pub mod dataset;
pub mod dense;
pub mod generator;
pub mod io;
pub mod libsvm;
pub mod quantized;
pub mod sparse;
pub mod view;

pub use builder::{DatasetBuilder, Represent, DENSE_DENSITY_THRESHOLD};
pub use dataset::{Dataset, DatasetMeta, SourceInfo};
pub use dense::DenseMatrix;
pub use generator::{DatasetKind, Family, GeneratedDataset};
pub use libsvm::Sample;
pub use quantized::QuantizedMatrix;
pub use sparse::{ChunkPool, SparseMatrix};
pub use view::DatasetView;

/// Column access used by the gap/update hot paths.
///
/// `dot` is Eq. (3)/(4)'s `<w, d_i>`; `axpy` is the shared-vector
/// maintenance `v += delta * d_i` (the caller handles locking);
/// `sq_norm` is `||d_i||^2`.
pub trait ColumnOps: Sync {
    fn n_rows(&self) -> usize;
    fn n_cols(&self) -> usize;
    /// `<w, d_i>`.
    fn dot(&self, col: usize, w: &[f32]) -> f32;
    /// Partial dot over rows `[lo, hi)` — V_B-way vector splitting.
    fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32;
    /// `v += delta * d_i` on a raw slice (caller synchronizes).
    fn axpy(&self, col: usize, delta: f32, v: &mut [f32]);
    /// `||d_i||^2`.
    fn sq_norm(&self, col: usize) -> f32;
    /// Number of stored (non-zero) entries in the column.
    fn nnz(&self, col: usize) -> usize;
    /// Bytes touched when streaming this column (for TierSim charging).
    fn col_bytes(&self, col: usize) -> u64;
}

/// Bulk column access for the blocked multi-column sweeps (paper
/// §IV-A/IV-D): compute `out[k] = <w, d_cols[k]>` for a whole block of
/// columns in one cache-blocked pass, so every cache line of `w` is
/// reused across the block instead of re-streamed per column.
///
/// The default implementation is the per-column fallback — any
/// [`ColumnOps`] type gets correct (unblocked) behaviour for free; the
/// three crate representations override it with the
/// `crate::kernels::*dots_block*` kernel family.  Bulk consumers (task
/// A's sweeps, the ST/OMP full-epoch refreshes, `glm::total_gap`)
/// claim column blocks of [`crate::kernels::BLOCK_COLS`] and call this
/// instead of per-column [`ColumnOps::dot`].
pub trait BlockOps: ColumnOps {
    /// `out[k] = <w, d_cols[k]>` for every k (`cols.len() == out.len()`).
    fn dots_block(&self, cols: &[usize], w: &[f32], out: &mut [f32]) {
        for (o, &j) in out.iter_mut().zip(cols) {
            *o = self.dot(j, w);
        }
    }
}

/// Dense, sparse or quantized — run-time polymorphism for the CLI layer.
pub enum Matrix {
    Dense(DenseMatrix),
    Sparse(SparseMatrix),
    Quantized(QuantizedMatrix),
}

impl Matrix {
    pub fn as_ops(&self) -> &dyn ColumnOps {
        match self {
            Matrix::Dense(m) => m,
            Matrix::Sparse(m) => m,
            Matrix::Quantized(m) => m,
        }
    }

    /// Column access including the blocked bulk-dot sweeps (every
    /// [`ColumnOps`] method is reachable through the supertrait).
    pub fn as_block_ops(&self) -> &dyn BlockOps {
        match self {
            Matrix::Dense(m) => m,
            Matrix::Sparse(m) => m,
            Matrix::Quantized(m) => m,
        }
    }

    pub fn n_rows(&self) -> usize {
        self.as_ops().n_rows()
    }

    pub fn n_cols(&self) -> usize {
        self.as_ops().n_cols()
    }

    pub fn total_bytes(&self) -> u64 {
        (0..self.n_cols()).map(|j| self.as_ops().col_bytes(j)).sum()
    }

    /// `v = D * alpha` from scratch — used to periodically re-anchor the
    /// incrementally-maintained shared vector (fp32 drift after many
    /// `v += delta d_i` updates otherwise floors the achievable gap).
    pub fn matvec_alpha(&self, alpha: &[f32]) -> Vec<f32> {
        match self {
            Matrix::Dense(m) => m.matvec_alpha(alpha),
            Matrix::Sparse(m) => m.matvec_alpha(alpha),
            Matrix::Quantized(m) => {
                let mut v = vec![0.0f32; m.n_rows()];
                for (j, &a) in alpha.iter().enumerate() {
                    if a != 0.0 {
                        m.axpy(j, a, &mut v);
                    }
                }
                v
            }
        }
    }

    pub fn repr_name(&self) -> &'static str {
        match self {
            Matrix::Dense(_) => "dense",
            Matrix::Sparse(_) => "sparse",
            Matrix::Quantized(_) => "quantized-4bit",
        }
    }
}
