//! Preprocessing: column normalization, target centering, row splits.
//!
//! The paper's datasets arrive preprocessed (epsilon is unit-normed;
//! dvsc features are CNN activations scaled as in its source).  CD's
//! per-coordinate step sizes are `1/||d_i||^2`, so normalizing columns
//! equalizes progress per update and is standard practice; these
//! helpers make that a first-class part of the pipeline.

use crate::data::{ColumnOps, DenseMatrix, Matrix, SparseMatrix};
use crate::util::Rng;

/// Scale every column to unit L2 norm.  Returns (normalized matrix,
/// per-column scales applied) — `alpha` learned on the normalized data
/// maps back via `alpha_i / scale_i`.
pub fn unit_norm_columns(m: &Matrix) -> (Matrix, Vec<f32>) {
    match m {
        Matrix::Dense(dm) => {
            let (d, n) = (dm.n_rows(), dm.n_cols());
            let mut data = Vec::with_capacity(d * n);
            let mut scales = Vec::with_capacity(n);
            for j in 0..n {
                let col = dm.col(j);
                let norm = dm.sq_norm(j).sqrt();
                let s = if norm > 0.0 { 1.0 / norm } else { 1.0 };
                scales.push(s);
                data.extend(col.iter().map(|&x| x * s));
            }
            (Matrix::Dense(DenseMatrix::from_col_major(d, n, data)), scales)
        }
        Matrix::Sparse(sm) => {
            let n = sm.n_cols();
            let mut cols = Vec::with_capacity(n);
            let mut scales = Vec::with_capacity(n);
            for j in 0..n {
                let (rows, vals) = sm.col(j);
                let norm = sm.sq_norm(j).sqrt();
                let s = if norm > 0.0 { 1.0 / norm } else { 1.0 };
                scales.push(s);
                cols.push(
                    rows.iter()
                        .zip(vals)
                        .map(|(&r, &v)| (r, v * s))
                        .collect(),
                );
            }
            (Matrix::Sparse(SparseMatrix::from_columns(sm.n_rows(), cols)), scales)
        }
        Matrix::Quantized(_) => panic!("normalize before quantizing"),
    }
}

/// Subtract the mean from regression targets; returns (centered, mean).
/// Centering absorbs the intercept so no bias column is needed.
pub fn center_targets(y: &[f32]) -> (Vec<f32>, f32) {
    let mean = y.iter().map(|&t| t as f64).sum::<f64>() / y.len().max(1) as f64;
    let mean = mean as f32;
    (y.iter().map(|&t| t - mean).collect(), mean)
}

/// Split row indices into train/test (regression orientation: rows are
/// samples).  Deterministic per seed.
pub fn train_test_split(d: usize, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut idx: Vec<usize> = (0..d).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let n_test = ((d as f64) * test_frac).round() as usize;
    let test = idx[..n_test].to_vec();
    let train = idx[n_test..].to_vec();
    (train, test)
}

/// Restrict a dense regression problem to a subset of rows.
pub fn take_rows_dense(m: &DenseMatrix, y: &[f32], rows: &[usize]) -> (DenseMatrix, Vec<f32>) {
    let n = m.n_cols();
    let dd = rows.len();
    let mut data = Vec::with_capacity(dd * n);
    for j in 0..n {
        let col = m.col(j);
        data.extend(rows.iter().map(|&r| col[r]));
    }
    let ty = rows.iter().map(|&r| y[r]).collect();
    (DenseMatrix::from_col_major(dd, n, data), ty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::data::ColumnOps;

    #[test]
    fn unit_norm_dense() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 601);
        let (m2, scales) = unit_norm_columns(&g.matrix);
        assert_eq!(scales.len(), g.n());
        for j in 0..m2.n_cols() {
            let sq = m2.as_ops().sq_norm(j);
            assert!((sq - 1.0).abs() < 1e-4, "col {j}: {sq}");
        }
    }

    #[test]
    fn unit_norm_sparse_preserves_pattern() {
        let g = generate(DatasetKind::News20Like, Family::Regression, 0.03, 602);
        let (m2, _) = unit_norm_columns(&g.matrix);
        if let (Matrix::Sparse(a), Matrix::Sparse(b)) = (&g.matrix, &m2) {
            for j in 0..a.n_cols() {
                assert_eq!(a.col(j).0, b.col(j).0, "pattern must not change");
                if a.nnz(j) > 0 {
                    assert!((b.sq_norm(j) - 1.0).abs() < 1e-4);
                }
            }
        } else {
            panic!();
        }
    }

    #[test]
    fn zero_column_scale_is_identity() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(4, 2, vec![
            1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0,
        ]));
        let (m2, scales) = unit_norm_columns(&m);
        assert_eq!(scales[1], 1.0);
        assert_eq!(m2.as_ops().sq_norm(1), 0.0);
    }

    #[test]
    fn center_targets_zero_mean() {
        let (c, mean) = center_targets(&[1.0, 2.0, 3.0, 6.0]);
        assert_eq!(mean, 3.0);
        let s: f32 = c.iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn split_is_partition() {
        let (train, test) = train_test_split(100, 0.2, 9);
        assert_eq!(test.len(), 20);
        assert_eq!(train.len(), 80);
        let mut all: Vec<usize> = train.iter().chain(&test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn take_rows_consistent() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 603);
        if let Matrix::Dense(dm) = &g.matrix {
            let rows = vec![3, 10, 20];
            let (sub, ty) = take_rows_dense(dm, &g.targets, &rows);
            assert_eq!(sub.n_rows(), 3);
            assert_eq!(sub.n_cols(), dm.n_cols());
            assert_eq!(ty[1], g.targets[10]);
            assert_eq!(sub.col(5)[2], dm.col(5)[20]);
        }
    }
}
