//! [`DatasetBuilder`]: the one pipeline every dataset goes through.
//!
//! ```text
//! source (generated | path | samples | in-memory)
//!   -> format auto-detect (HTHC1 binary magic, else LIBSVM text)
//!   -> orient (Family: coordinates = features | samples)
//!   -> preprocess (unit-norm columns, center targets — recorded in meta)
//!   -> represent (Dense | Sparse | Quantized | Auto by density threshold)
//!   -> place (memory tier; build_in reserves arena capacity)
//! ```
//!
//! Replaces the seed's ad-hoc load paths (`io::load_dataset_file`,
//! `libsvm::to_regression`/`to_classification` call sites,
//! `preprocess::unit_norm_columns`/`center_targets` plumbing in
//! `main.rs` and the bench harnesses) — deleted, not deprecated.
//!
//! # Example
//!
//! ```
//! use hthc::data::{DatasetBuilder, DatasetKind, Family, Represent};
//!
//! let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
//!     .seed(7)
//!     .normalize(true)
//!     .center_targets(true)
//!     .represent(Represent::Auto)
//!     .build()
//!     .unwrap();
//! assert_eq!(ds.targets().len(), ds.n_rows());
//! assert_eq!(ds.repr_name(), "dense"); // tiny is dense at any threshold
//! ```

use super::dataset::{stored_nnz, Dataset, DatasetMeta, SourceInfo};
use super::generator::{self, DatasetKind, Family};
use super::{io, libsvm, DenseMatrix, Matrix, QuantizedMatrix, SparseMatrix};
use crate::data::ColumnOps;
use crate::kernels::QGROUP;
use crate::memory::{Arena, Tier};
use crate::util::error::Context;
use crate::{bail, Result};
use std::io::BufRead;
use std::path::PathBuf;

/// Default density threshold for [`Represent::Auto`]: at or above this
/// fraction of stored entries a column-major dense layout streams fewer
/// bytes per pass than (index, value) pairs — 8 bytes per nnz vs 4 per
/// element puts break-even at 0.5; the margin below that pays for the
/// dense layout's better vectorization (paper §IV-D).
pub const DENSE_DENSITY_THRESHOLD: f64 = 0.25;

/// Which matrix representation the pipeline's `represent` stage emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Represent {
    /// Whatever the source produced (generated sparse kinds stay
    /// sparse, LIBSVM loads stay sparse, in-memory matrices are kept).
    Keep,
    /// Column-major dense f32 (densifies sparse sources).
    Dense,
    /// Chunked CSC (sparsifies dense sources).
    Sparse,
    /// 4-bit quantized (paper §IV-E).  Requires a dense source with
    /// `d` divisible by the quantization group — quantizing a sparse
    /// source is rejected rather than silently materializing a `d*n`
    /// dense copy (chain `represent(Dense)` through a rebuild if the
    /// densification cost is really intended).
    Quantized,
    /// Dense when the stored-entry density is at least the threshold
    /// (see [`DENSE_DENSITY_THRESHOLD`]), sparse otherwise.
    Auto,
}

impl Represent {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "keep" => Represent::Keep,
            "dense" => Represent::Dense,
            "sparse" => Represent::Sparse,
            "quantized" | "q4" => Represent::Quantized,
            "auto" => Represent::Auto,
            _ => return None,
        })
    }
}

enum Source {
    Generated(DatasetKind),
    Path(PathBuf),
    Samples(Vec<libsvm::Sample>),
    /// Shared ownership of an already-parsed corpus: the pipeline only
    /// borrows the samples, so a caller that rebuilds repeatedly from a
    /// long-lived corpus (the streaming-refit loop) pays no per-build
    /// copy of its history.
    SharedSamples(std::sync::Arc<Vec<libsvm::Sample>>),
    InMemory { matrix: Matrix, targets: Vec<f32> },
}

/// Fluent pipeline producing a [`Dataset`] — see the module docs.
pub struct DatasetBuilder {
    source: Source,
    appended: Vec<libsvm::Sample>,
    family: Family,
    scale: f64,
    seed: u64,
    normalize: bool,
    center: bool,
    represent: Represent,
    density_threshold: f64,
    placement: Tier,
    validate: bool,
}

impl DatasetBuilder {
    fn new(source: Source, family: Family) -> Self {
        DatasetBuilder {
            source,
            appended: Vec::new(),
            family,
            scale: 1.0,
            seed: 42,
            normalize: false,
            center: false,
            represent: Represent::Keep,
            density_threshold: DENSE_DENSITY_THRESHOLD,
            placement: Tier::Slow,
            validate: true,
        }
    }

    /// Synthetic Table-I analogue (see [`generator::generate`]).
    pub fn generated(kind: DatasetKind, family: Family) -> Self {
        Self::new(Source::Generated(kind), family)
    }

    /// Load from a file, sniffing the format at build time: the `HTHC1`
    /// magic selects the binary format, anything else parses as LIBSVM
    /// text (oriented by [`family`](Self::family)).
    pub fn path(p: impl Into<PathBuf>) -> Self {
        Self::new(Source::Path(p.into()), Family::Regression)
    }

    /// Already-parsed LIBSVM samples (oriented by
    /// [`family`](Self::family) at build time).
    pub fn libsvm_samples(samples: Vec<libsvm::Sample>) -> Self {
        Self::new(Source::Samples(samples), Family::Regression)
    }

    /// Like [`libsvm_samples`](Self::libsvm_samples) but *borrowing* a
    /// shared corpus: the pipeline reads through the `Arc` and never
    /// clones the sample vector, so repeated rebuilds from a growing
    /// retained corpus (the serve-layer refit loop) cost O(matrix)
    /// instead of O(history) extra allocation per build.  The `Arc` is
    /// dropped when `build` returns — callers keep sole ownership
    /// between builds and can mutate via [`std::sync::Arc::make_mut`]
    /// without a copy.
    pub fn libsvm_shared(samples: std::sync::Arc<Vec<libsvm::Sample>>) -> Self {
        Self::new(Source::SharedSamples(samples), Family::Regression)
    }

    /// An existing matrix + targets (tests, harnesses, adversarial
    /// constructions).  Build fails if the lengths disagree.
    pub fn in_memory(matrix: Matrix, targets: Vec<f32>) -> Self {
        Self::new(Source::InMemory { matrix, targets }, Family::Regression)
    }

    /// Append raw samples to a [`libsvm_samples`](Self::libsvm_samples)
    /// source before the pipeline runs — the streaming-ingest rebuild
    /// path: the base training set and the newly-ingested examples are
    /// oriented, normalized and centered together so preprocessing stays
    /// consistent across refits.  `build` rejects this on any other
    /// source kind (appending *raw* samples to an already-preprocessed
    /// matrix would mix spaces).
    pub fn append_samples(mut self, samples: Vec<libsvm::Sample>) -> Self {
        self.appended.extend(samples);
        self
    }

    /// Orientation for LIBSVM sources and the generator (ignored by
    /// binary/in-memory sources, which carry their own shape).
    pub fn family(mut self, family: Family) -> Self {
        self.family = family;
        self
    }

    /// Generator shape multiplier (generated sources only).
    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    /// Generator PRNG seed (generated sources only).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Scale every column to unit L2 norm (recorded in
    /// `meta.col_scales`).  CD step sizes are `1/||d_i||^2`, so this
    /// equalizes per-update progress — standard practice, and how the
    /// paper's dense sets arrive.
    pub fn normalize(mut self, yes: bool) -> Self {
        self.normalize = yes;
        self
    }

    /// Subtract the target mean (regression orientation only; absorbs
    /// the intercept so no bias column is needed).  Recorded in
    /// `meta.target_mean`.
    pub fn center_targets(mut self, yes: bool) -> Self {
        self.center = yes;
        self
    }

    /// Output representation (default [`Represent::Keep`]).
    pub fn represent(mut self, r: Represent) -> Self {
        self.represent = r;
        self
    }

    /// Density threshold for [`Represent::Auto`].
    pub fn density_threshold(mut self, t: f64) -> Self {
        self.density_threshold = t;
        self
    }

    /// Reject non-finite features and targets at build time (default
    /// `true`).  A single `nan`/`inf` entry poisons every norm, dot
    /// and duality gap downstream into a silent non-converging run, so
    /// the pipeline refuses it up front — with the offending line
    /// number for LIBSVM text sources, the coordinate otherwise.
    /// `validate(false)` is the escape hatch for callers that clean
    /// the data themselves.
    pub fn validate(mut self, yes: bool) -> Self {
        self.validate = yes;
        self
    }

    /// Record the memory tier the matrix lives in (default
    /// [`Tier::Slow`] — the full dataset belongs in DRAM; task B copies
    /// its working set into the fast tier separately).  Capacity is not
    /// checked; use [`build_in`](Self::build_in) for that.
    pub fn place(mut self, tier: Tier) -> Self {
        self.placement = tier;
        self
    }

    /// Run the pipeline.
    pub fn build(self) -> Result<Dataset> {
        let DatasetBuilder {
            source,
            appended,
            family,
            scale,
            seed,
            normalize,
            center,
            represent,
            density_threshold,
            placement,
            validate,
        } = self;

        let source = if appended.is_empty() {
            source
        } else {
            match source {
                Source::Samples(mut base) => {
                    base.extend(appended);
                    Source::Samples(base)
                }
                // appending would force a copy of the shared corpus —
                // the whole point of the shared source is to avoid one;
                // callers extend the corpus before sharing it instead
                _ => bail!(
                    "append_samples requires a libsvm_samples source — raw \
                     samples cannot join an already-preprocessed matrix"
                ),
            }
        };

        // -- 1. load + orient ------------------------------------------
        let (mut matrix, mut targets, mut meta) =
            load_source(source, family, scale, seed, validate)?;
        if matrix.n_cols() == 0 || matrix.n_rows() == 0 {
            bail!("{}: empty dataset", meta.source.describe());
        }
        if targets.len() != matrix.n_rows() {
            bail!(
                "{}: targets length {} != matrix rows {}",
                meta.source.describe(),
                targets.len(),
                matrix.n_rows()
            );
        }
        if validate {
            reject_nonfinite(&matrix, &targets, &meta)?;
        }

        // -- 2. preprocess ---------------------------------------------
        if normalize {
            if matches!(matrix, Matrix::Quantized(_)) {
                bail!("normalize before quantizing: the 4-bit codes cannot be rescaled");
            }
            let (m, scales) = unit_norm_columns(&matrix);
            matrix = m;
            meta.col_scales = Some(scales);
        }
        if center {
            if family == Family::Classification {
                bail!("target centering applies to the regression orientation only");
            }
            let (c, mean) = center_targets(&targets);
            targets = c;
            meta.target_mean = Some(mean);
        }

        // -- 3. represent ----------------------------------------------
        let matrix = apply_representation(matrix, represent, density_threshold)?;

        // -- 4. place + finalize ---------------------------------------
        meta.placement = placement;
        meta.nnz = stored_nnz(&matrix);
        meta.bytes = matrix.total_bytes();
        Ok(Dataset::assemble(matrix, targets, meta))
    }

    /// Run the pipeline and reserve the dataset's bytes in `arena`
    /// (placement is taken from the arena's tier).  Fails when the
    /// dataset does not fit the remaining capacity — the same rejection
    /// a real `memkind` allocation would produce on MCDRAM.
    pub fn build_in(mut self, arena: &mut Arena) -> Result<Dataset> {
        self.placement = arena.tier();
        let ds = self.build()?;
        let bytes = ds.meta().bytes;
        if !arena.reserve_bytes(bytes) {
            bail!(
                "dataset ({}) does not fit the {:?} arena ({} of {} used)",
                crate::util::fmt_bytes(bytes),
                arena.tier(),
                crate::util::fmt_bytes(arena.used_bytes()),
                crate::util::fmt_bytes(arena.capacity_bytes()),
            );
        }
        Ok(ds)
    }

}

// ---------------------------------------------------------------------------
// Pipeline stages
// ---------------------------------------------------------------------------

fn blank_meta(source: SourceInfo, family: Family) -> DatasetMeta {
    DatasetMeta {
        source,
        family,
        col_scales: None,
        target_mean: None,
        labels: None,
        alpha_star: None,
        placement: Tier::Slow,
        nnz: 0,
        bytes: 0,
    }
}

fn load_source(
    source: Source,
    family: Family,
    scale: f64,
    seed: u64,
    validate: bool,
) -> Result<(Matrix, Vec<f32>, DatasetMeta)> {
    match source {
        Source::Generated(kind) => {
            let g = generator::generate(kind, family, scale, seed);
            let mut meta = blank_meta(SourceInfo::Generated { kind, scale, seed }, family);
            meta.labels = g.labels;
            meta.alpha_star = g.alpha_star;
            Ok((g.matrix, g.targets, meta))
        }
        Source::Path(path) => {
            let f = std::fs::File::open(&path)
                .with_context(|| format!("open {}", path.display()))?;
            let mut r = std::io::BufReader::new(f);
            let is_binary = r.fill_buf()?.starts_with(io::MAGIC);
            if is_binary {
                let (matrix, targets) =
                    io::load_dataset(r).with_context(|| format!("load {}", path.display()))?;
                let meta = blank_meta(SourceInfo::Binary { path }, family);
                Ok((matrix, targets, meta))
            } else {
                // parse-time rejection carries the offending line
                // number; the post-orient scan is the backstop for the
                // other source kinds
                let samples = libsvm::read_with(r, validate)
                    .with_context(|| format!("parse {}", path.display()))?;
                let (matrix, targets, mut meta) = orient(&samples, family)?;
                meta.source = SourceInfo::Libsvm { path };
                Ok((matrix, targets, meta))
            }
        }
        Source::Samples(samples) => orient(&samples, family),
        Source::SharedSamples(samples) => orient(&samples, family),
        Source::InMemory { matrix, targets } => {
            Ok((matrix, targets, blank_meta(SourceInfo::InMemory, family)))
        }
    }
}

/// Build-time finiteness gate (`validate(true)`, the default): one
/// `nan`/`inf` feature or target survives every kernel (dots, norms,
/// axpys all propagate it) and surfaces only as a run that never
/// converges, so the pipeline names the first offending coordinate and
/// refuses.  LIBSVM text sources are additionally checked at parse
/// time, where the line number is still known.
fn reject_nonfinite(matrix: &Matrix, targets: &[f32], meta: &DatasetMeta) -> Result<()> {
    let src = meta.source.describe();
    if let Some(i) = targets.iter().position(|t| !t.is_finite()) {
        bail!("{src}: non-finite target at row {i}: {}", targets[i]);
    }
    match matrix {
        Matrix::Dense(dm) => {
            let d = dm.n_rows();
            if let Some(i) = dm.raw().iter().position(|x| !x.is_finite()) {
                bail!(
                    "{src}: non-finite feature at column {}, row {}: {}",
                    i / d,
                    i % d,
                    dm.raw()[i]
                );
            }
        }
        Matrix::Sparse(sm) => {
            for j in 0..sm.n_cols() {
                let (rows, vals) = sm.col(j);
                if let Some(k) = vals.iter().position(|x| !x.is_finite()) {
                    bail!(
                        "{src}: non-finite feature at column {j}, row {}: {}",
                        rows[k],
                        vals[k]
                    );
                }
            }
        }
        Matrix::Quantized(qm) => {
            // the 4-bit codes are finite by construction; a non-finite
            // source value lands in the per-group scale
            for j in 0..qm.n_cols() {
                let (_, scales) = qm.col_packed(j);
                if let Some(g) = scales.iter().position(|s| !s.is_finite()) {
                    bail!(
                        "{src}: non-finite quantization scale at column {j}, group {g}"
                    );
                }
            }
        }
    }
    Ok(())
}

/// LIBSVM samples into the family's matrix orientation (paper §II-A).
/// Borrows the samples: shared-corpus sources orient without copying.
fn orient(
    samples: &[libsvm::Sample],
    family: Family,
) -> Result<(Matrix, Vec<f32>, DatasetMeta)> {
    if samples.is_empty() {
        bail!("libsvm source: no samples");
    }
    let mut meta = blank_meta(SourceInfo::Samples, family);
    match family {
        Family::Regression => {
            let (m, targets) = libsvm::to_regression(samples);
            Ok((Matrix::Sparse(m), targets, meta))
        }
        Family::Classification => {
            let (m, labels) = libsvm::to_classification(samples);
            let d = m.n_rows();
            meta.labels = Some(labels);
            Ok((Matrix::Sparse(m), vec![0.0; d], meta))
        }
    }
}

fn apply_representation(
    matrix: Matrix,
    represent: Represent,
    density_threshold: f64,
) -> Result<Matrix> {
    let want = match represent {
        // a quantized source is already in its final form — Auto's
        // dense/sparse density policy does not apply to it
        Represent::Auto if matches!(matrix, Matrix::Quantized(_)) => Represent::Keep,
        Represent::Auto => {
            if fp32_density(&matrix) >= density_threshold {
                Represent::Dense
            } else {
                Represent::Sparse
            }
        }
        other => other,
    };
    // fail on row misalignment BEFORE any densification: quantizing a
    // sparse source materializes a d*n dense copy, which must not be
    // paid (it can be enormous) just to discover the shape is invalid
    if want == Represent::Quantized && matrix.n_rows() % QGROUP != 0 {
        bail!(
            "4-bit quantization needs rows divisible by the group size \
             {QGROUP} (got {})",
            matrix.n_rows()
        );
    }
    Ok(match (want, matrix) {
        (Represent::Keep, m) => m,
        (Represent::Dense, Matrix::Dense(m)) => Matrix::Dense(m),
        (Represent::Dense, Matrix::Sparse(m)) => Matrix::Dense(densify(&m)),
        (Represent::Sparse, Matrix::Sparse(m)) => Matrix::Sparse(m),
        (Represent::Sparse, Matrix::Dense(m)) => Matrix::Sparse(sparsify(&m)),
        (Represent::Quantized, Matrix::Quantized(m)) => Matrix::Quantized(m),
        // rows are QGROUP-aligned here (checked above); from_dense
        // asserts the same invariant as its own last line of defense
        (Represent::Quantized, Matrix::Dense(m)) => {
            Matrix::Quantized(QuantizedMatrix::from_dense(&m))
        }
        (Represent::Quantized, Matrix::Sparse(m)) => {
            // never densify implicitly: a paper-scale sparse set would
            // materialize a d*n f32 copy (news20: ~100 GB) just to be
            // quantized — an explicit dense rebuild must opt into that
            bail!(
                "4-bit quantization requires a dense source ({} x {} sparse \
                 given) — build with represent(Dense) first if densifying \
                 is really intended",
                m.n_rows(),
                m.n_cols()
            );
        }
        (_, Matrix::Quantized(_)) => {
            bail!(
                "quantized data cannot be restored to fp32 exactly — \
                 rebuild from the fp32 source instead"
            );
        }
        (Represent::Auto, _) => unreachable!("Auto resolved above"),
    })
}

// ---------------------------------------------------------------------------
// Stage helpers (the former data::preprocess free functions, now private
// pipeline stages)
// ---------------------------------------------------------------------------

/// Scale every column to unit L2 norm; returns the per-column scales
/// applied (1.0 for all-zero columns).
fn unit_norm_columns(m: &Matrix) -> (Matrix, Vec<f32>) {
    match m {
        Matrix::Dense(dm) => {
            let (d, n) = (dm.n_rows(), dm.n_cols());
            let mut data = Vec::with_capacity(d * n);
            let mut scales = Vec::with_capacity(n);
            for j in 0..n {
                let col = dm.col(j);
                let norm = dm.sq_norm(j).sqrt();
                let s = if norm > 0.0 { 1.0 / norm } else { 1.0 };
                scales.push(s);
                data.extend(col.iter().map(|&x| x * s));
            }
            (Matrix::Dense(DenseMatrix::from_col_major(d, n, data)), scales)
        }
        Matrix::Sparse(sm) => {
            let n = sm.n_cols();
            let mut cols = Vec::with_capacity(n);
            let mut scales = Vec::with_capacity(n);
            for j in 0..n {
                let (rows, vals) = sm.col(j);
                let norm = sm.sq_norm(j).sqrt();
                let s = if norm > 0.0 { 1.0 / norm } else { 1.0 };
                scales.push(s);
                cols.push(rows.iter().zip(vals).map(|(&r, &v)| (r, v * s)).collect());
            }
            (Matrix::Sparse(SparseMatrix::from_columns(sm.n_rows(), cols)), scales)
        }
        Matrix::Quantized(_) => unreachable!("builder rejects normalize-after-quantize"),
    }
}

/// Subtract the mean from regression targets; returns (centered, mean).
fn center_targets(y: &[f32]) -> (Vec<f32>, f32) {
    let mean = y.iter().map(|&t| t as f64).sum::<f64>() / y.len().max(1) as f64;
    let mean = mean as f32;
    (y.iter().map(|&t| t - mean).collect(), mean)
}

/// Fraction of stored entries that are non-zero (dense counts actual
/// zeros so an all-dense-but-sparse in-memory matrix still auto-routes
/// to the sparse representation).
fn fp32_density(m: &Matrix) -> f64 {
    match m {
        Matrix::Dense(dm) => {
            let total = dm.n_rows() * dm.n_cols();
            if total == 0 {
                return 1.0;
            }
            let nz = dm.raw().iter().filter(|&&x| x != 0.0).count();
            nz as f64 / total as f64
        }
        Matrix::Sparse(sm) => sm.density(),
        Matrix::Quantized(_) => 1.0,
    }
}

fn densify(sm: &SparseMatrix) -> DenseMatrix {
    let (d, n) = (sm.n_rows(), sm.n_cols());
    let mut data = vec![0.0f32; d * n];
    for j in 0..n {
        let (rows, vals) = sm.col(j);
        let col = &mut data[j * d..(j + 1) * d];
        for (&r, &x) in rows.iter().zip(vals) {
            col[r as usize] = x;
        }
    }
    DenseMatrix::from_col_major(d, n, data)
}

fn sparsify(dm: &DenseMatrix) -> SparseMatrix {
    let (d, n) = (dm.n_rows(), dm.n_cols());
    let cols = (0..n)
        .map(|j| {
            dm.col(j)
                .iter()
                .enumerate()
                .filter(|&(_, &x)| x != 0.0)
                .map(|(r, &x)| (r as u32, x))
                .collect()
        })
        .collect();
    SparseMatrix::from_columns(d, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(seed: u64) -> DatasetBuilder {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression).seed(seed)
    }

    #[test]
    fn generated_matches_raw_generator() {
        // goldens depend on this: the builder must not perturb the
        // generator's output
        let ds = tiny(4242).build().unwrap();
        let g = generator::generate(DatasetKind::Tiny, Family::Regression, 1.0, 4242);
        assert_eq!(ds.targets(), &g.targets[..]);
        match (ds.matrix(), &g.matrix) {
            (Matrix::Dense(a), Matrix::Dense(b)) => assert_eq!(a.raw(), b.raw()),
            _ => panic!("expected dense"),
        }
        assert_eq!(ds.alpha_star().unwrap(), &g.alpha_star.unwrap()[..]);
    }

    #[test]
    fn normalize_records_scales_and_unit_norms() {
        let ds = tiny(601).normalize(true).build().unwrap();
        let scales = ds.meta().col_scales.as_ref().unwrap();
        assert_eq!(scales.len(), ds.n_cols());
        for j in 0..ds.n_cols() {
            let sq = ds.as_ops().sq_norm(j);
            assert!((sq - 1.0).abs() < 1e-4, "col {j}: {sq}");
        }
    }

    #[test]
    fn normalize_sparse_preserves_pattern() {
        let ds = DatasetBuilder::generated(DatasetKind::News20Like, Family::Regression)
            .scale(0.03)
            .seed(602)
            .build()
            .unwrap();
        let normed = DatasetBuilder::generated(DatasetKind::News20Like, Family::Regression)
            .scale(0.03)
            .seed(602)
            .normalize(true)
            .build()
            .unwrap();
        let (Matrix::Sparse(a), Matrix::Sparse(b)) = (ds.matrix(), normed.matrix()) else {
            panic!("expected sparse");
        };
        for j in 0..a.n_cols() {
            assert_eq!(a.col(j).0, b.col(j).0, "pattern must not change");
            if a.nnz(j) > 0 {
                assert!((b.sq_norm(j) - 1.0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn center_targets_zero_mean() {
        let ds = tiny(603).center_targets(true).build().unwrap();
        let mean = ds.meta().target_mean.unwrap();
        let s: f64 = ds.targets().iter().map(|&t| t as f64).sum();
        assert!(s.abs() / ds.n_rows() as f64 < 1e-4, "centered mean {s}");
        assert!(mean.is_finite());
    }

    #[test]
    fn center_rejected_for_classification() {
        let err = DatasetBuilder::generated(DatasetKind::Tiny, Family::Classification)
            .center_targets(true)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("regression"), "{err}");
    }

    #[test]
    fn represent_roundtrip_preserves_values() {
        let dense = tiny(604).build().unwrap();
        let sparse = tiny(604).represent(Represent::Sparse).build().unwrap();
        assert_eq!(sparse.repr_name(), "sparse");
        // dense -> sparse conversion preserves every value exactly
        let Matrix::Sparse(sm) = sparse.matrix() else { panic!() };
        let Matrix::Dense(dm) = dense.matrix() else { panic!() };
        for j in 0..dense.n_cols() {
            assert_eq!(sm.col_dense(j), dm.col(j), "col {j}");
        }
    }

    #[test]
    fn auto_picks_by_density() {
        let news = DatasetBuilder::generated(DatasetKind::News20Like, Family::Regression)
            .scale(0.05)
            .represent(Represent::Auto)
            .build()
            .unwrap();
        assert_eq!(news.repr_name(), "sparse", "low density stays sparse");
        let eps = tiny(605).represent(Represent::Auto).build().unwrap();
        assert_eq!(eps.repr_name(), "dense", "dense data stays dense");
        // threshold 1.01 forces even dense gaussian data to sparse
        let forced = tiny(605)
            .represent(Represent::Auto)
            .density_threshold(1.01)
            .build()
            .unwrap();
        assert_eq!(forced.repr_name(), "sparse");
    }

    #[test]
    fn quantize_via_builder() {
        let q = tiny(606).represent(Represent::Quantized).build().unwrap();
        assert_eq!(q.repr_name(), "quantized-4bit");
        assert!(q.meta().bytes < tiny(606).build().unwrap().meta().bytes / 3);
    }

    #[test]
    fn quantize_rejects_unaligned_rows() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(3, 1, vec![1.0, 2.0, 3.0]));
        let err = DatasetBuilder::in_memory(m, vec![0.0; 3])
            .represent(Represent::Quantized)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("divisible"), "{err}");
    }

    #[test]
    fn quantize_rejects_sparse_source_without_densifying() {
        // group-aligned rows, so the rejection is the dense-source rule,
        // not the divisibility check — and it must fire before any
        // (potentially enormous) densification is attempted
        let s = Matrix::Sparse(SparseMatrix::from_columns(64, vec![vec![(0, 1.0)]; 2]));
        let err = DatasetBuilder::in_memory(s, vec![0.0; 64])
            .represent(Represent::Quantized)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("dense source"), "{err}");
    }

    #[test]
    fn auto_keeps_quantized_input() {
        let dense = tiny(609).build().unwrap();
        let Matrix::Dense(dm) = dense.matrix() else { panic!() };
        let qm = QuantizedMatrix::from_dense(dm);
        let ds = DatasetBuilder::in_memory(Matrix::Quantized(qm), vec![0.0; dense.n_rows()])
            .represent(Represent::Auto)
            .build()
            .unwrap();
        assert_eq!(ds.repr_name(), "quantized-4bit");
    }

    #[test]
    fn zero_column_scale_is_identity() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(
            4,
            2,
            vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0],
        ));
        let ds = DatasetBuilder::in_memory(m, vec![0.0; 4])
            .normalize(true)
            .build()
            .unwrap();
        assert_eq!(ds.meta().col_scales.as_ref().unwrap()[1], 1.0);
        assert_eq!(ds.as_ops().sq_norm(1), 0.0, "zero column stays zero");
        assert!((ds.as_ops().sq_norm(0) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn in_memory_length_mismatch_is_an_error() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(4, 1, vec![1.0; 4]));
        assert!(DatasetBuilder::in_memory(m, vec![0.0; 3]).build().is_err());
    }

    #[test]
    fn build_in_reserves_and_rejects() {
        let small = tiny(607).build().unwrap();
        let need = small.meta().bytes;
        let mut arena = Arena::with_capacity(Tier::Fast, need + 16);
        let placed = tiny(607).build_in(&mut arena).unwrap();
        assert_eq!(placed.placement(), Tier::Fast);
        assert_eq!(arena.used_bytes(), need);
        // a second copy no longer fits
        assert!(tiny(607).build_in(&mut arena).is_err());
    }

    #[test]
    fn classification_orientation_has_labels() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Classification)
            .seed(608)
            .build()
            .unwrap();
        let labels = ds.labels().unwrap();
        assert_eq!(labels.len(), ds.n_cols());
        assert!(ds.targets().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn append_samples_extends_a_samples_source() {
        let base = vec![
            libsvm::Sample { label: 1.0, features: vec![(0, 1.0), (2, 2.0)] },
            libsvm::Sample { label: -1.0, features: vec![(1, 3.0)] },
        ];
        let extra = vec![libsvm::Sample { label: 2.0, features: vec![(2, -1.0)] }];
        let ds = DatasetBuilder::libsvm_samples(base)
            .append_samples(extra)
            .family(Family::Regression)
            .build()
            .unwrap();
        // regression orientation: rows = samples
        assert_eq!(ds.n_rows(), 3);
        assert_eq!(ds.targets(), &[1.0, -1.0, 2.0]);
    }

    #[test]
    fn shared_samples_build_matches_owned_and_releases_the_arc() {
        let base = vec![
            libsvm::Sample { label: 1.0, features: vec![(0, 1.0), (2, 2.0)] },
            libsvm::Sample { label: -1.0, features: vec![(1, 3.0)] },
            libsvm::Sample { label: 0.5, features: vec![(0, -0.5), (1, 0.25)] },
        ];
        let shared = std::sync::Arc::new(base.clone());
        let owned = DatasetBuilder::libsvm_samples(base)
            .family(Family::Regression)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        let via_arc = DatasetBuilder::libsvm_shared(std::sync::Arc::clone(&shared))
            .family(Family::Regression)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        assert_eq!(owned.targets(), via_arc.targets());
        assert_eq!(owned.meta().col_scales, via_arc.meta().col_scales);
        let ones = vec![1.0f32; owned.n_rows()];
        for j in 0..owned.n_cols() {
            assert_eq!(owned.as_ops().dot(j, &ones), via_arc.as_ops().dot(j, &ones));
        }
        // the pipeline dropped its clone: sole ownership is back, so
        // Arc::make_mut between rebuilds never copies the corpus
        assert_eq!(std::sync::Arc::strong_count(&shared), 1);
    }

    #[test]
    fn append_samples_rejected_on_non_sample_sources() {
        let err = tiny(610)
            .append_samples(vec![libsvm::Sample { label: 0.0, features: vec![] }])
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("libsvm_samples"), "{err}");
    }

    #[test]
    fn nonfinite_features_rejected_at_build() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(
            2,
            2,
            vec![1.0, 2.0, f32::NAN, 4.0],
        ));
        let err = DatasetBuilder::in_memory(m, vec![0.0; 2]).build().unwrap_err();
        assert!(format!("{err}").contains("column 1, row 0"), "{err}");
        let s = Matrix::Sparse(SparseMatrix::from_columns(3, vec![vec![(1, f32::INFINITY)]]));
        let err = DatasetBuilder::in_memory(s, vec![0.0; 3]).build().unwrap_err();
        assert!(format!("{err}").contains("column 0, row 1"), "{err}");
    }

    #[test]
    fn nonfinite_targets_rejected_at_build() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(2, 1, vec![1.0, 2.0]));
        let err = DatasetBuilder::in_memory(m, vec![0.0, f32::NAN])
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("target at row 1"), "{err}");
    }

    #[test]
    fn validate_false_is_the_escape_hatch() {
        let m = Matrix::Dense(DenseMatrix::from_col_major(
            2,
            2,
            vec![1.0, 2.0, f32::NAN, 4.0],
        ));
        let ds = DatasetBuilder::in_memory(m, vec![0.0; 2])
            .validate(false)
            .build()
            .unwrap();
        assert!(ds.as_ops().dot(1, &[1.0, 1.0]).is_nan());
    }

    #[test]
    fn represent_parse_covers_spellings() {
        assert_eq!(Represent::parse("auto"), Some(Represent::Auto));
        assert_eq!(Represent::parse("q4"), Some(Represent::Quantized));
        assert_eq!(Represent::parse("bogus"), None);
    }
}
