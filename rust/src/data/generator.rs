//! Synthetic workload generators with the shape signatures of Table I.
//!
//! The paper's datasets (Epsilon, Dogs-vs-Cats, News20, Criteo) are not
//! redistributable here; each generator reproduces the *axes the
//! experiments exercise* — density, aspect ratio, scale — per the
//! substitution rule in DESIGN.md §2.  Default sizes are scaled to this
//! host; every bench prints the actual shapes it ran (its "Table I").
//!
//! Orientation note (paper §II-A): D ∈ R^{d×n} has one *column per
//! model coordinate*.  For Lasso, coordinates are features (d = #samples);
//! for dual SVM, coordinates are samples (d = #features, columns
//! pre-scaled by their labels y_i ∈ {±1}).

use super::{dense::DenseMatrix, sparse::SparseMatrix, Matrix};
use crate::util::Rng;

/// Which Table-I dataset shape to mimic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Epsilon: dense, samples >> features (400k x 2k, 3.2 GB).
    EpsilonLike,
    /// Dogs-vs-Cats: dense, features >> samples (40k x 200k, 32 GB).
    DvscLike,
    /// News20: sparse, very high-dimensional, power-law columns.
    News20Like,
    /// Criteo: sparse, huge sample count, near-binary features.
    CriteoLike,
    /// Tiny deterministic set for unit tests.
    Tiny,
}

impl DatasetKind {
    pub fn name(self) -> &'static str {
        match self {
            DatasetKind::EpsilonLike => "epsilon-like",
            DatasetKind::DvscLike => "dvsc-like",
            DatasetKind::News20Like => "news20-like",
            DatasetKind::CriteoLike => "criteo-like",
            DatasetKind::Tiny => "tiny",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "epsilon" | "epsilon-like" => DatasetKind::EpsilonLike,
            "dvsc" | "dvsc-like" => DatasetKind::DvscLike,
            "news20" | "news20-like" => DatasetKind::News20Like,
            "criteo" | "criteo-like" => DatasetKind::CriteoLike,
            "tiny" => DatasetKind::Tiny,
            _ => return None,
        })
    }

    /// (samples, features, sparse) at scale 1.0.
    pub fn base_shape(self) -> (usize, usize, bool) {
        match self {
            DatasetKind::EpsilonLike => (4096, 512, false),
            DatasetKind::DvscLike => (1024, 4096, false),
            DatasetKind::News20Like => (2048, 16384, true),
            DatasetKind::CriteoLike => (4096, 32768, true),
            DatasetKind::Tiny => (64, 32, false),
        }
    }
}

/// Which learning family the matrix is oriented for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Coordinates = features; targets per row (Lasso / ridge).
    Regression,
    /// Coordinates = samples; columns pre-scaled by labels (SVM).
    Classification,
}

/// A generated problem instance.
pub struct GeneratedDataset {
    pub kind: DatasetKind,
    pub family: Family,
    pub matrix: Matrix,
    /// Regression targets (length d) — zeros for classification.
    pub targets: Vec<f32>,
    /// Per-coordinate labels (length n) for classification accuracy.
    pub labels: Option<Vec<f32>>,
    /// Planted sparse model (regression only).
    pub alpha_star: Option<Vec<f32>>,
}

impl GeneratedDataset {
    pub fn d(&self) -> usize {
        self.matrix.n_rows()
    }

    pub fn n(&self) -> usize {
        self.matrix.n_cols()
    }

    pub fn describe(&self) -> String {
        format!(
            "{} [{}] {} x {} ({}, {})",
            self.kind.name(),
            match self.family {
                Family::Regression => "regression",
                Family::Classification => "classification",
            },
            self.d(),
            self.n(),
            self.matrix.repr_name(),
            crate::util::fmt_bytes(self.matrix.total_bytes()),
        )
    }
}

/// Generate a dataset.  `scale` multiplies the base shape (rounded up to
/// 64 so PJRT tiles stay aligned); `seed` gives reproducibility.
pub fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> GeneratedDataset {
    let (samples, features, sparse) = kind.base_shape();
    let sc = |x: usize| ((x as f64 * scale).ceil() as usize).max(64).div_ceil(64) * 64;
    let (samples, features) = (sc(samples), sc(features));
    let mut rng = Rng::new(seed ^ 0x5EED_BA5E);
    match family {
        Family::Regression => {
            let (d, n) = (samples, features);
            if sparse {
                let m = gen_sparse(d, n, kind, &mut rng);
                regression_from(Matrix::Sparse(m), kind, family, &mut rng)
            } else {
                let m = gen_dense(d, n, kind, &mut rng);
                regression_from(Matrix::Dense(m), kind, family, &mut rng)
            }
        }
        Family::Classification => {
            // D is (features x samples); plant a hyperplane u, draw
            // x_i = noise + margin * y_i * u, store columns y_i * x_i.
            let (d, n) = (features, samples);
            let u: Vec<f32> = (0..d).map(|_| rng.normal() / (d as f32).sqrt()).collect();
            let mut labels = Vec::with_capacity(n);
            if sparse {
                let mut cols = Vec::with_capacity(n);
                for _ in 0..n {
                    let y = if rng.f32() < 0.5 { -1.0f32 } else { 1.0 };
                    labels.push(y);
                    let nnz = col_nnz(kind, d, &mut rng);
                    let idx = rng.sample_distinct(d, nnz);
                    let col: Vec<(u32, f32)> = idx
                        .into_iter()
                        .map(|r| {
                            let base = feature_value(kind, &mut rng);
                            let xv = base + 1.5 * y * u[r] * (d as f32).sqrt();
                            (r as u32, y * xv)
                        })
                        .collect();
                    cols.push(col);
                }
                GeneratedDataset {
                    kind,
                    family,
                    matrix: Matrix::Sparse(SparseMatrix::from_columns(d, cols)),
                    targets: vec![0.0; d],
                    labels: Some(labels),
                    alpha_star: None,
                }
            } else {
                let mut data = vec![0.0f32; d * n];
                for j in 0..n {
                    let y = if rng.f32() < 0.5 { -1.0f32 } else { 1.0 };
                    labels.push(y);
                    let col = &mut data[j * d..(j + 1) * d];
                    for (r, cv) in col.iter_mut().enumerate() {
                        let xv = rng.normal() + 1.5 * y * u[r];
                        *cv = y * xv;
                    }
                }
                GeneratedDataset {
                    kind,
                    family,
                    matrix: Matrix::Dense(DenseMatrix::from_col_major(d, n, data)),
                    targets: vec![0.0; d],
                    labels: Some(labels),
                    alpha_star: None,
                }
            }
        }
    }
}

fn gen_dense(d: usize, n: usize, kind: DatasetKind, rng: &mut Rng) -> DenseMatrix {
    let mut data = vec![0.0f32; d * n];
    match kind {
        DatasetKind::DvscLike => {
            // CNN-feature-like: correlated columns in blocks (extracted
            // features share filters), heavier tails than white noise.
            let block = 64;
            let mut factor: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            for j in 0..n {
                if j % block == 0 {
                    for f in factor.iter_mut() {
                        *f = rng.normal();
                    }
                }
                let col = &mut data[j * d..(j + 1) * d];
                for (r, c) in col.iter_mut().enumerate() {
                    *c = 0.6 * factor[r] + rng.normal();
                }
            }
        }
        _ => {
            for x in data.iter_mut() {
                *x = rng.normal();
            }
        }
    }
    DenseMatrix::from_col_major(d, n, data)
}

fn col_nnz(kind: DatasetKind, d: usize, rng: &mut Rng) -> usize {
    match kind {
        // Power-law column lengths (text data): many rare terms, few
        // ubiquitous ones.  Pareto with alpha ~ 1.1, capped at d/4.
        DatasetKind::News20Like => {
            let u = rng.f64().max(1e-9);
            ((3.0 * u.powf(-1.0 / 1.1)) as usize).clamp(1, d / 4)
        }
        // Hashed categorical: narrow distribution around a small mean.
        DatasetKind::CriteoLike => (8 + rng.below(24)).min(d),
        _ => (d / 10).max(1),
    }
}

fn feature_value(kind: DatasetKind, rng: &mut Rng) -> f32 {
    match kind {
        // tf-idf-ish positive weights
        DatasetKind::News20Like => (1.0 + rng.f32() * 3.0) / 4.0,
        // mostly-binary indicators with occasional counts
        DatasetKind::CriteoLike => {
            if rng.f32() < 0.9 {
                1.0
            } else {
                1.0 + rng.below(8) as f32
            }
        }
        _ => rng.normal(),
    }
}

fn gen_sparse(d: usize, n: usize, kind: DatasetKind, rng: &mut Rng) -> SparseMatrix {
    let mut cols = Vec::with_capacity(n);
    for _ in 0..n {
        let nnz = col_nnz(kind, d, rng);
        let idx = rng.sample_distinct(d, nnz);
        cols.push(
            idx.into_iter()
                .map(|r| (r as u32, feature_value(kind, rng)))
                .collect(),
        );
    }
    SparseMatrix::from_columns(d, cols)
}

fn regression_from(
    matrix: Matrix,
    kind: DatasetKind,
    family: Family,
    rng: &mut Rng,
) -> GeneratedDataset {
    let (d, n) = (matrix.n_rows(), matrix.n_cols());
    // Planted model with ~12% support (the paper tunes lambda to a 12%
    // support for Lasso on the dense sets).
    let support = (n / 8).max(1);
    let mut alpha_star = vec![0.0f32; n];
    for j in rng.sample_distinct(n, support) {
        alpha_star[j] = rng.normal() * 2.0;
    }
    let clean = match &matrix {
        Matrix::Dense(m) => m.matvec_alpha(&alpha_star),
        Matrix::Sparse(m) => m.matvec_alpha(&alpha_star),
        Matrix::Quantized(_) => unreachable!("generator emits fp32"),
    };
    let noise_scale = 0.1
        * (crate::kernels::sq_norm_f64(&clean) / d as f64)
            .sqrt()
            .max(1e-6) as f32;
    let targets: Vec<f32> = clean
        .iter()
        .map(|&c| c + noise_scale * rng.normal())
        .collect();
    GeneratedDataset {
        kind,
        family,
        matrix,
        targets,
        labels: None,
        alpha_star: Some(alpha_star),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_scale_and_align() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 1);
        assert_eq!(g.d() % 64, 0);
        assert_eq!(g.n() % 64, 0);
        let g2 = generate(DatasetKind::Tiny, Family::Regression, 2.0, 1);
        assert!(g2.d() >= g.d());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(DatasetKind::Tiny, Family::Regression, 1.0, 7);
        let b = generate(DatasetKind::Tiny, Family::Regression, 1.0, 7);
        assert_eq!(a.targets, b.targets);
        let c = generate(DatasetKind::Tiny, Family::Regression, 1.0, 8);
        assert_ne!(a.targets, c.targets);
    }

    #[test]
    fn regression_targets_follow_planted_model() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 2);
        let astar = g.alpha_star.as_ref().unwrap();
        let clean = match &g.matrix {
            Matrix::Dense(m) => m.matvec_alpha(astar),
            _ => unreachable!(),
        };
        // noise is 10%: correlation between targets and clean must be high
        let dot: f64 = clean.iter().zip(&g.targets).map(|(&a, &b)| (a * b) as f64).sum();
        let na: f64 = clean.iter().map(|&a| (a * a) as f64).sum();
        let nb: f64 = g.targets.iter().map(|&b| (b * b) as f64).sum();
        assert!(dot / (na.sqrt() * nb.sqrt()) > 0.95);
    }

    #[test]
    fn classification_is_separable_enough() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 3);
        let labels = g.labels.as_ref().unwrap();
        assert_eq!(labels.len(), g.n());
        assert!(labels.iter().all(|&y| y == 1.0 || y == -1.0));
        // Columns are y_i x_i with a planted margin: summing all columns
        // recovers a direction positively correlated with every column.
        let ops = g.matrix.as_ops();
        let mut v = vec![0.0f32; g.d()];
        for j in 0..g.n() {
            ops.axpy(j, 1.0 / g.n() as f32, &mut v);
        }
        let pos = (0..g.n()).filter(|&j| ops.dot(j, &v) > 0.0).count();
        assert!(pos as f64 / g.n() as f64 > 0.9, "separability {pos}/{}", g.n());
    }

    #[test]
    fn sparse_kinds_are_sparse() {
        let g = generate(DatasetKind::News20Like, Family::Regression, 0.1, 4);
        match &g.matrix {
            Matrix::Sparse(m) => {
                assert!(m.density() < 0.05, "density {}", m.density());
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn criteo_values_near_binary() {
        let g = generate(DatasetKind::CriteoLike, Family::Regression, 0.05, 5);
        if let Matrix::Sparse(m) = &g.matrix {
            let mut ones = 0usize;
            let mut total = 0usize;
            for j in 0..g.n() {
                let (_, vals) = m.col(j);
                ones += vals.iter().filter(|&&v| v == 1.0).count();
                total += vals.len();
            }
            assert!(ones as f64 / total as f64 > 0.8);
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            DatasetKind::EpsilonLike,
            DatasetKind::DvscLike,
            DatasetKind::News20Like,
            DatasetKind::CriteoLike,
            DatasetKind::Tiny,
        ] {
            assert_eq!(DatasetKind::parse(k.name()), Some(k));
        }
        assert_eq!(DatasetKind::parse("nope"), None);
    }
}
