//! 4-bit quantized representation (paper §IV-E, Clover-style).
//!
//! The data matrix D is stored as 4-bit codes (two per byte) with one
//! f32 scale per `QGROUP`-element group per column; `v` and `alpha`
//! remain f32 ("low precision results in excessive error accumulation").
//! The benefit is 4x less data movement for D at the cost of unpack
//! arithmetic — Table VI measures exactly that trade.
//!
//! Layout matches `python/compile/kernels/ref.py` (`pack4`/`quantize4`):
//! round-to-nearest codes in [-8, 7] biased by +8, low nibble = even row.
//!
//! The unpack-dot / unpack-axpy inner loops live in [`crate::kernels`]
//! (runtime-dispatched scalar vs LUT paths); this module owns layout,
//! quantization and the error-bound bookkeeping.

use super::{dense::DenseMatrix, BlockOps, ColumnOps};
use crate::kernels;

/// Elements per scale group — re-exported from the kernel layer, which
/// owns the group structure; must match `ref.QGROUP` on the python side.
pub use crate::kernels::QGROUP;

/// 4-bit quantized column-major matrix.
pub struct QuantizedMatrix {
    d: usize,
    n: usize,
    /// ceil(d/2) bytes per column, column-major.
    packed: Vec<u8>,
    /// d/QGROUP scales per column, column-major.
    scales: Vec<f32>,
    sq_norms: Vec<f32>,
    bytes_per_col: usize,
    groups_per_col: usize,
}

impl QuantizedMatrix {
    /// Quantize a dense matrix (round-to-nearest, per-group absmax/7).
    pub fn from_dense(m: &DenseMatrix) -> Self {
        let d = m.n_rows();
        let n = m.n_cols();
        assert!(d % QGROUP == 0, "d must be a multiple of QGROUP={QGROUP}");
        let bytes_per_col = d / 2;
        let groups_per_col = d / QGROUP;
        let mut packed = vec![0u8; bytes_per_col * n];
        let mut scales = vec![0f32; groups_per_col * n];
        let mut sq_norms = vec![0f32; n];
        for j in 0..n {
            let col = m.col(j);
            let pcol = &mut packed[j * bytes_per_col..(j + 1) * bytes_per_col];
            let scol = &mut scales[j * groups_per_col..(j + 1) * groups_per_col];
            let mut sq = 0.0f32;
            for g in 0..groups_per_col {
                let grp = &col[g * QGROUP..(g + 1) * QGROUP];
                let absmax = grp.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let scale = if absmax > 0.0 { absmax / 7.0 } else { 1.0 };
                scol[g] = scale;
                let mut deq = [0.0f32; QGROUP];
                for (k, &x) in grp.iter().enumerate() {
                    let code = (x / scale).round().clamp(-8.0, 7.0) as i32;
                    deq[k] = code as f32 * scale;
                    let row = g * QGROUP + k;
                    let b = (code + 8) as u8;
                    if row % 2 == 0 {
                        pcol[row / 2] |= b;
                    } else {
                        pcol[row / 2] |= b << 4;
                    }
                }
                sq += kernels::sq_norm(&deq);
            }
            sq_norms[j] = sq;
        }
        QuantizedMatrix { d, n, packed, scales, sq_norms, bytes_per_col, groups_per_col }
    }

    #[inline]
    fn pcol(&self, j: usize) -> &[u8] {
        &self.packed[j * self.bytes_per_col..(j + 1) * self.bytes_per_col]
    }

    #[inline]
    fn scol(&self, j: usize) -> &[f32] {
        &self.scales[j * self.groups_per_col..(j + 1) * self.groups_per_col]
    }

    /// Dequantize one column to f32 (tests, PJRT padding).
    pub fn col_dense(&self, j: usize) -> Vec<f32> {
        let pcol = self.pcol(j);
        let scol = self.scol(j);
        (0..self.d)
            .map(|r| {
                let scale = scol[r / QGROUP];
                kernels::quant_code(pcol[r / 2], r % 2 == 0) as f32 * scale
            })
            .collect()
    }

    /// Raw packed bytes of column `j` (for the PJRT q4 artifact).
    pub fn col_packed(&self, j: usize) -> (&[u8], &[f32]) {
        (self.pcol(j), self.scol(j))
    }

    /// Worst-case absolute dequantization error for group `g` of col `j`.
    pub fn group_err_bound(&self, j: usize, g: usize) -> f32 {
        self.scol(j)[g] / 2.0
    }

    /// Copy a column subset into a new matrix — packed bytes and scales
    /// are moved verbatim, so there is **no** requantization error
    /// (re-deriving group scales from dequantized values would shift
    /// codes).  Backs `DatasetView::materialize`.
    pub(crate) fn select_columns(&self, cols: &[usize]) -> QuantizedMatrix {
        let mut packed = Vec::with_capacity(self.bytes_per_col * cols.len());
        let mut scales = Vec::with_capacity(self.groups_per_col * cols.len());
        let mut sq_norms = Vec::with_capacity(cols.len());
        for &j in cols {
            packed.extend_from_slice(self.pcol(j));
            scales.extend_from_slice(self.scol(j));
            sq_norms.push(self.sq_norms[j]);
        }
        QuantizedMatrix {
            d: self.d,
            n: cols.len(),
            packed,
            scales,
            sq_norms,
            bytes_per_col: self.bytes_per_col,
            groups_per_col: self.groups_per_col,
        }
    }
}

impl ColumnOps for QuantizedMatrix {
    fn n_rows(&self) -> usize {
        self.d
    }

    fn n_cols(&self) -> usize {
        self.n
    }

    /// Unpack-dequantize-FMA in one pass, group by group (scale hoisted):
    /// the Clover pattern — trade unpack ALU for 4x less memory traffic.
    #[inline]
    fn dot(&self, col: usize, w: &[f32]) -> f32 {
        self.dot_range(col, w, 0, self.d)
    }

    #[inline]
    fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        debug_assert!(lo % QGROUP == 0, "range must be group-aligned");
        kernels::quant_dot_range(self.pcol(col), self.scol(col), w, lo, hi)
    }

    #[inline]
    fn axpy(&self, col: usize, delta: f32, v: &mut [f32]) {
        kernels::quant_axpy(self.pcol(col), self.scol(col), delta, &mut v[..self.d]);
    }

    #[inline]
    fn sq_norm(&self, col: usize) -> f32 {
        self.sq_norms[col]
    }

    fn nnz(&self, _col: usize) -> usize {
        self.d
    }

    /// The whole point: a column streams d/2 bytes + group scales
    /// instead of 4d bytes.
    fn col_bytes(&self, _col: usize) -> u64 {
        (self.bytes_per_col + self.groups_per_col * 4) as u64
    }
}

impl BlockOps for QuantizedMatrix {
    fn dots_block(&self, cols: &[usize], w: &[f32], out: &mut [f32]) {
        const B: usize = kernels::BLOCK_COLS;
        debug_assert_eq!(cols.len(), out.len());
        let w = &w[..self.d];
        for (cidx, o) in cols.chunks(B).zip(out.chunks_mut(B)) {
            let mut slices: [(&[u8], &[f32]); B] = [(&[], &[]); B];
            for (s, &j) in slices.iter_mut().zip(cidx) {
                *s = (self.pcol(j), self.scol(j));
            }
            kernels::quant_dots_block(&slices[..cidx.len()], w, o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_dense(d: usize, n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..d * n).map(|_| rng.normal()).collect();
        DenseMatrix::from_col_major(d, n, data)
    }

    #[test]
    fn roundtrip_error_bounded() {
        let m = random_dense(256, 8, 1);
        let q = QuantizedMatrix::from_dense(&m);
        for j in 0..8 {
            let deq = q.col_dense(j);
            for (r, (&x, &xq)) in m.col(j).iter().zip(&deq).enumerate() {
                let bound = q.group_err_bound(j, r / QGROUP) + 1e-6;
                assert!(
                    (x - xq).abs() <= bound,
                    "col {j} row {r}: {x} vs {xq} bound {bound}"
                );
            }
        }
    }

    #[test]
    fn dot_matches_dequantized_dot() {
        let m = random_dense(512, 4, 2);
        let q = QuantizedMatrix::from_dense(&m);
        let mut rng = Rng::new(3);
        let w: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
        for j in 0..4 {
            let deq = q.col_dense(j);
            let want: f32 = deq.iter().zip(&w).map(|(a, b)| a * b).sum();
            let got = q.dot(j, &w);
            assert!((got - want).abs() < 1e-3, "col {j}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_range_composes() {
        let m = random_dense(256, 2, 4);
        let q = QuantizedMatrix::from_dense(&m);
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        let full = q.dot(0, &w);
        let split = q.dot_range(0, &w, 0, 128) + q.dot_range(0, &w, 128, 256);
        assert!((full - split).abs() < 1e-4);
    }

    #[test]
    fn axpy_matches_dequantized() {
        let m = random_dense(128, 2, 6);
        let q = QuantizedMatrix::from_dense(&m);
        let mut v1 = vec![0.5f32; 128];
        let mut v2 = v1.clone();
        q.axpy(1, 0.7, &mut v1);
        let deq = q.col_dense(1);
        for (vi, xi) in v2.iter_mut().zip(&deq) {
            *vi += 0.7 * xi;
        }
        for (a, b) in v1.iter().zip(&v2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn sq_norm_is_dequantized_norm() {
        let m = random_dense(128, 3, 7);
        let q = QuantizedMatrix::from_dense(&m);
        for j in 0..3 {
            let deq = q.col_dense(j);
            let want: f32 = deq.iter().map(|x| x * x).sum();
            assert!((q.sq_norm(j) - want).abs() < 1e-3);
        }
    }

    #[test]
    fn bytes_are_4x_smaller_plus_scales() {
        let m = random_dense(1024, 1, 8);
        let q = QuantizedMatrix::from_dense(&m);
        assert_eq!(q.col_bytes(0), (1024 / 2 + (1024 / QGROUP) * 4) as u64);
        let dense_bytes = 1024 * 4;
        assert!((q.col_bytes(0) as usize) < dense_bytes / 3);
    }

    #[test]
    fn zero_matrix_quantizes_to_zero() {
        let m = DenseMatrix::from_col_major(128, 1, vec![0.0; 128]);
        let q = QuantizedMatrix::from_dense(&m);
        assert!(q.col_dense(0).iter().all(|&x| x == 0.0));
        assert_eq!(q.sq_norm(0), 0.0);
    }
}
