//! [`DatasetView`]: zero-copy column-range / column-subset views.
//!
//! A view borrows a [`Dataset`] and exposes a subset of its columns
//! through the same [`ColumnOps`] + [`BlockOps`] traits the full matrix
//! implements, with index translation and no data movement.  One
//! abstraction serves three consumers (paper §IV-A/IV-D):
//!
//! * train/validation splits ([`Dataset::split`]) — for the
//!   classification orientation columns are samples, so a column split
//!   is a sample split;
//! * per-core column shards ([`DatasetView::shards`]) — the ROADMAP's
//!   threaded tile scheduler pins one shard per core;
//! * working-set-restricted sweeps — any consumer taking
//!   `&dyn BlockOps` (e.g. `glm::total_gap`) runs unchanged on a view.
//!
//! Forwarding preserves bitwise results: a view's `dot`/`dots_block`
//! issue exactly the kernel calls the parent would for the selected
//! columns (`rust/tests/view_diff.rs` asserts this on every backend).

use super::dataset::{stored_nnz, Dataset, DatasetMeta, SourceInfo};
use super::{BlockOps, ColumnOps, Matrix, SparseMatrix};
use crate::kernels;

/// Which columns of the parent a view exposes.
enum ColSel {
    /// Contiguous `[lo, hi)` — splits and shards of resident data.
    Range(usize, usize),
    /// Explicit (sorted or not) subset — random splits, working sets.
    Subset(Vec<usize>),
}

/// A zero-copy view over a column range or subset of a [`Dataset`].
pub struct DatasetView<'a> {
    parent: &'a Dataset,
    sel: ColSel,
}

impl<'a> DatasetView<'a> {
    pub(crate) fn range(parent: &'a Dataset, lo: usize, hi: usize) -> Self {
        assert!(
            lo <= hi && hi <= parent.n_cols(),
            "column range [{lo}, {hi}) out of bounds (n_cols {})",
            parent.n_cols()
        );
        DatasetView { parent, sel: ColSel::Range(lo, hi) }
    }

    pub(crate) fn subset(parent: &'a Dataset, cols: Vec<usize>) -> Self {
        let n = parent.n_cols();
        for &j in &cols {
            assert!(j < n, "column {j} out of bounds (n_cols {n})");
        }
        DatasetView { parent, sel: ColSel::Subset(cols) }
    }

    /// Number of columns the view exposes.
    pub fn len(&self) -> usize {
        match &self.sel {
            ColSel::Range(lo, hi) => *hi - *lo,
            ColSel::Subset(cols) => cols.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The dataset this view borrows.
    pub fn parent(&self) -> &'a Dataset {
        self.parent
    }

    /// Parent column index of view column `k`.
    ///
    /// Panics when `k >= len()` — a real assert, not a debug one, so a
    /// release-build over-iteration cannot silently read a neighbouring
    /// parent column (the subset arm already panics via indexing).
    #[inline]
    pub fn parent_col(&self, k: usize) -> usize {
        match &self.sel {
            ColSel::Range(lo, hi) => {
                assert!(*lo + k < *hi, "view column {k} out of bounds (len {})", *hi - *lo);
                *lo + k
            }
            ColSel::Subset(cols) => cols[k],
        }
    }

    /// Parent column indices, in view order.
    pub fn parent_cols(&self) -> Vec<usize> {
        match &self.sel {
            ColSel::Range(lo, hi) => (*lo..*hi).collect(),
            ColSel::Subset(cols) => cols.clone(),
        }
    }

    /// The parent's targets (rows are shared by every view).
    pub fn targets(&self) -> &'a [f32] {
        self.parent.targets()
    }

    /// Per-coordinate labels restricted to the view's columns
    /// (classification orientation).
    pub fn labels(&self) -> Option<Vec<f32>> {
        let labels = self.parent.labels()?;
        Some((0..self.len()).map(|k| labels[self.parent_col(k)]).collect())
    }

    /// Split into `k` near-equal column shards (one per core).  Shards
    /// of a range view stay ranges (no allocation per shard); trailing
    /// shards may be empty when `k > len`.
    pub fn shards(&self, k: usize) -> Vec<DatasetView<'a>> {
        assert!(k >= 1, "at least one shard");
        let len = self.len();
        let base = len / k;
        let rem = len % k;
        let mut out = Vec::with_capacity(k);
        let mut start = 0usize;
        for i in 0..k {
            let take = base + usize::from(i < rem);
            let end = start + take;
            out.push(match &self.sel {
                ColSel::Range(lo, _) => DatasetView {
                    parent: self.parent,
                    sel: ColSel::Range(*lo + start, *lo + end),
                },
                ColSel::Subset(cols) => DatasetView {
                    parent: self.parent,
                    sel: ColSel::Subset(cols[start..end].to_vec()),
                },
            });
            start = end;
        }
        out
    }

    /// Copy the selected columns into an owned [`Dataset`] in the
    /// parent's representation (the engines' working-set machinery
    /// needs owned column storage; evaluation paths should keep using
    /// the zero-copy view).  Quantized columns are copied packed — no
    /// requantization error.  Metadata (labels, scales, planted model)
    /// is restricted to the selected columns.
    pub fn materialize(&self) -> Dataset {
        let cols = self.parent_cols();
        let d = self.parent.n_rows();
        let matrix = match self.parent.matrix() {
            Matrix::Dense(dm) => {
                let mut data = Vec::with_capacity(d * cols.len());
                for &j in &cols {
                    data.extend_from_slice(dm.col(j));
                }
                Matrix::Dense(super::DenseMatrix::from_col_major(d, cols.len(), data))
            }
            Matrix::Sparse(sm) => {
                let sub = cols
                    .iter()
                    .map(|&j| {
                        let (rows, vals) = sm.col(j);
                        rows.iter().copied().zip(vals.iter().copied()).collect()
                    })
                    .collect();
                Matrix::Sparse(SparseMatrix::from_columns(d, sub))
            }
            Matrix::Quantized(qm) => Matrix::Quantized(qm.select_columns(&cols)),
        };
        let pm = self.parent.meta();
        let take = |v: &Vec<f32>| -> Vec<f32> { cols.iter().map(|&j| v[j]).collect() };
        let meta = DatasetMeta {
            source: SourceInfo::InMemory,
            family: pm.family,
            col_scales: pm.col_scales.as_ref().map(take),
            target_mean: pm.target_mean,
            labels: pm.labels.as_ref().map(take),
            alpha_star: pm.alpha_star.as_ref().map(take),
            placement: pm.placement,
            nnz: stored_nnz(&matrix),
            bytes: matrix.total_bytes(),
        };
        Dataset::assemble(matrix, self.parent.targets().to_vec(), meta)
    }
}

impl ColumnOps for DatasetView<'_> {
    fn n_rows(&self) -> usize {
        self.parent.n_rows()
    }

    fn n_cols(&self) -> usize {
        self.len()
    }

    #[inline]
    fn dot(&self, col: usize, w: &[f32]) -> f32 {
        self.parent.as_ops().dot(self.parent_col(col), w)
    }

    #[inline]
    fn dot_range(&self, col: usize, w: &[f32], lo: usize, hi: usize) -> f32 {
        self.parent.as_ops().dot_range(self.parent_col(col), w, lo, hi)
    }

    #[inline]
    fn axpy(&self, col: usize, delta: f32, v: &mut [f32]) {
        self.parent.as_ops().axpy(self.parent_col(col), delta, v);
    }

    #[inline]
    fn sq_norm(&self, col: usize) -> f32 {
        self.parent.as_ops().sq_norm(self.parent_col(col))
    }

    fn nnz(&self, col: usize) -> usize {
        self.parent.as_ops().nnz(self.parent_col(col))
    }

    fn col_bytes(&self, col: usize) -> u64 {
        self.parent.as_ops().col_bytes(self.parent_col(col))
    }
}

impl BlockOps for DatasetView<'_> {
    fn dots_block(&self, cols: &[usize], w: &[f32], out: &mut [f32]) {
        const B: usize = kernels::BLOCK_COLS;
        debug_assert_eq!(cols.len(), out.len());
        let ops = self.parent.as_block_ops();
        // Translate in BLOCK_COLS-sized stack tiles and forward: the
        // parent receives exactly the per-chunk column lists it would
        // cut for itself, so view results are bitwise the parent's.
        for (cidx, o) in cols.chunks(B).zip(out.chunks_mut(B)) {
            let mut mapped = [0usize; B];
            for (m, &k) in mapped.iter_mut().zip(cidx) {
                *m = self.parent_col(k);
            }
            ops.dots_block(&mapped[..cidx.len()], w, o);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{DatasetBuilder, DatasetKind, Family};
    use super::*;

    fn ds(seed: u64) -> Dataset {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn range_and_subset_translate_indices() {
        let g = ds(9101);
        let r = g.col_range(4, 9);
        assert_eq!(r.len(), 5);
        assert_eq!(r.parent_col(0), 4);
        assert_eq!(r.parent_col(4), 8);
        let s = g.col_subset(vec![7, 1, 30]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.parent_cols(), vec![7, 1, 30]);
        assert_eq!(s.n_rows(), g.n_rows());
        assert_eq!(s.n_cols(), 3);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_subset_panics() {
        let g = ds(9102);
        let _ = g.col_subset(vec![g.n_cols()]);
    }

    #[test]
    fn shards_partition_in_order() {
        let g = ds(9103);
        let v = g.view();
        let shards = v.shards(5);
        assert_eq!(shards.len(), 5);
        let mut all = Vec::new();
        for s in &shards {
            all.extend(s.parent_cols());
        }
        assert_eq!(all, (0..g.n_cols()).collect::<Vec<_>>());
        // near-equal: sizes differ by at most one
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn more_shards_than_columns_gives_empty_tails() {
        let g = ds(9104);
        let v = g.col_range(0, 3);
        let shards = v.shards(5);
        assert_eq!(shards.iter().filter(|s| !s.is_empty()).count(), 3);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 3);
    }

    #[test]
    fn materialize_copies_selected_columns() {
        let g = ds(9105);
        let cols = vec![2, 17, 5];
        let sub = g.col_subset(cols.clone()).materialize();
        assert_eq!(sub.n_cols(), 3);
        assert_eq!(sub.n_rows(), g.n_rows());
        assert_eq!(sub.targets(), g.targets());
        let (Matrix::Dense(a), Matrix::Dense(b)) = (sub.matrix(), g.matrix()) else {
            panic!("expected dense");
        };
        for (k, &j) in cols.iter().enumerate() {
            assert_eq!(a.col(k), b.col(j), "col {j}");
        }
        // planted model restricted to the same columns
        let astar = g.alpha_star().unwrap();
        let sub_astar = sub.alpha_star().unwrap();
        for (k, &j) in cols.iter().enumerate() {
            assert_eq!(sub_astar[k], astar[j]);
        }
    }

    #[test]
    fn labels_subset_follows_view() {
        let g = DatasetBuilder::generated(DatasetKind::Tiny, Family::Classification)
            .seed(9106)
            .build()
            .unwrap();
        let v = g.col_subset(vec![3, 0, 9]);
        let want: Vec<f32> =
            [3usize, 0, 9].iter().map(|&j| g.labels().unwrap()[j]).collect();
        assert_eq!(v.labels().unwrap(), want);
    }
}
