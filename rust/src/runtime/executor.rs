//! The PJRT executor thread.
//!
//! The `xla` crate's objects wrap raw C pointers; everything PJRT lives
//! on one dedicated thread that owns the `PjRtClient` and a cache of
//! compiled executables (compile-on-first-use per artifact).  Callers
//! interact through [`XlaRuntime`]: plain-data requests in, plain f32
//! vectors out — cheap to send across the channel and keeps the unsafe
//! surface in one place.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): see
//! `python/compile/aot.py` for why serialized protos are rejected by
//! this XLA version.
//!
//! The `xla` crate is not part of the offline dependency set, so the
//! whole backend is gated behind the `pjrt` cargo feature.  Without it
//! the public API is unchanged but [`XlaRuntime::start`] reports a
//! clean "not compiled in" error — callers (tests, benches, the CLI)
//! already treat a failed start as "skip the PJRT path".

use super::manifest::{ArtifactSpec, DType, Manifest};
use crate::util::error::Context;
use crate::{bail, err, Result};
use std::path::Path;
use std::sync::mpsc;

/// One argument's data, shaped.
#[derive(Clone, Debug)]
pub enum ArgData {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    U8 { data: Vec<u8>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
    ScalarF32(f32),
}

impl ArgData {
    fn matches(&self, spec: &super::manifest::ArgSpec) -> bool {
        match self {
            ArgData::F32 { dims, .. } => spec.dtype == DType::F32 && *dims == spec.dims,
            ArgData::U8 { dims, .. } => spec.dtype == DType::U8 && *dims == spec.dims,
            ArgData::I32 { dims, .. } => spec.dtype == DType::I32 && *dims == spec.dims,
            ArgData::ScalarF32(_) => spec.dtype == DType::F32 && spec.dims.is_empty(),
        }
    }

    fn len(&self) -> usize {
        match self {
            ArgData::F32 { data, .. } => data.len(),
            ArgData::U8 { data, .. } => data.len(),
            ArgData::I32 { data, .. } => data.len(),
            ArgData::ScalarF32(_) => 1,
        }
    }
}

enum Req {
    Run {
        name: String,
        args: Vec<ArgData>,
        resp: mpsc::SyncSender<Result<Vec<Vec<f32>>>>,
    },
    Shutdown,
}

/// Handle to the executor thread.
pub struct XlaRuntime {
    tx: mpsc::Sender<Req>,
    handle: Option<std::thread::JoinHandle<()>>,
    manifest: Manifest,
}

impl XlaRuntime {
    /// Start the executor over an artifacts directory.
    pub fn start(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let specs = manifest.artifacts.clone();
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(specs, rx, ready_tx))
            .context("spawn pjrt executor")?;
        ready_rx
            .recv()
            .map_err(|_| err!("executor thread died during init"))??;
        Ok(XlaRuntime { tx, handle: Some(handle), manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `name`; returns the tuple elements as f32 vecs.
    /// Validates shapes against the manifest before crossing the channel.
    pub fn run(&self, name: &str, args: Vec<ArgData>) -> Result<Vec<Vec<f32>>> {
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| err!("no artifact named {name:?}"))?;
        if spec.args.len() != args.len() {
            bail!(
                "{name}: expected {} args, got {}",
                spec.args.len(),
                args.len()
            );
        }
        for (i, (a, s)) in args.iter().zip(&spec.args).enumerate() {
            if !a.matches(s) {
                bail!("{name}: arg {i} shape/dtype mismatch (want {s:?}, got len {})", a.len());
            }
        }
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Req::Run { name: name.to_string(), args, resp: resp_tx })
            .map_err(|_| err!("executor thread gone"))?;
        resp_rx.recv().map_err(|_| err!("executor dropped reply"))?
    }
}

impl Drop for XlaRuntime {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Stub executor: built without the `pjrt` feature there is no XLA
/// client, so init reports failure and [`XlaRuntime::start`] errors out.
#[cfg(not(feature = "pjrt"))]
fn executor_loop(
    _specs: Vec<ArtifactSpec>,
    _rx: mpsc::Receiver<Req>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    let _ = ready.send(Err(err!(
        "PJRT backend not compiled in: rebuild with `--features pjrt` \
         (requires the `xla` crate; see rust/DESIGN.md §Runtime)"
    )));
}

#[cfg(feature = "pjrt")]
fn executor_loop(
    specs: Vec<ArtifactSpec>,
    rx: mpsc::Receiver<Req>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    use std::collections::HashMap;

    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(err!("PjRtClient::cpu: {e:?}")));
            return;
        }
    };
    let by_name: HashMap<String, ArtifactSpec> =
        specs.into_iter().map(|s| (s.name.clone(), s)).collect();
    let mut compiled: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Run { name, args, resp } => {
                let result = run_one(&client, &by_name, &mut compiled, &name, args);
                let _ = resp.send(result);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn run_one(
    client: &xla::PjRtClient,
    by_name: &std::collections::HashMap<String, ArtifactSpec>,
    compiled: &mut std::collections::HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    args: Vec<ArgData>,
) -> Result<Vec<Vec<f32>>> {
    if !compiled.contains_key(name) {
        let spec = by_name.get(name).ok_or_else(|| err!("unknown artifact {name}"))?;
        let path = spec
            .path
            .to_str()
            .ok_or_else(|| err!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| err!("parse {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| err!("compile {name}: {e:?}"))?;
        compiled.insert(name.to_string(), exe);
    }
    // PANIC-OK: the entry was inserted just above when absent.
    let exe = compiled.get(name).unwrap();

    let literals: Vec<xla::Literal> = args
        .into_iter()
        .map(|a| -> Result<xla::Literal> {
            Ok(match a {
                ArgData::ScalarF32(x) => xla::Literal::scalar(x),
                ArgData::F32 { data, dims } => {
                    let lit = xla::Literal::vec1(&data);
                    if dims.len() <= 1 {
                        lit
                    } else {
                        let di: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                        lit.reshape(&di).map_err(|e| err!("reshape: {e:?}"))?
                    }
                }
                ArgData::U8 { data, dims } => {
                    // u8 lacks a NativeType impl in this crate version;
                    // build the literal from untyped bytes + shape.
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        &dims,
                        &data,
                    )
                    .map_err(|e| err!("u8 literal: {e:?}"))?
                }
                ArgData::I32 { data, dims } => {
                    let lit = xla::Literal::vec1(&data);
                    if dims.len() <= 1 {
                        lit
                    } else {
                        let di: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                        lit.reshape(&di).map_err(|e| err!("reshape: {e:?}"))?
                    }
                }
            })
        })
        .collect::<Result<_>>()?;

    let out = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| err!("execute {name}: {e:?}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| err!("fetch result: {e:?}"))?;
    // aot.py lowers with return_tuple=True: the result is always a tuple.
    let elems = lit.to_tuple().map_err(|e| err!("untuple: {e:?}"))?;
    elems
        .into_iter()
        .map(|e| e.to_vec::<f32>().map_err(|er| err!("to_vec: {er:?}")))
        .collect()
}
