//! `artifacts/manifest.txt` parsing.
//!
//! One artifact per line, tab-separated:
//! `name <tab> relative-path <tab> sig` where `sig` is a comma list of
//! `dtype:dims` entries (`float32:1024x256`, `float32:scalar`), exactly
//! as written by `python/compile/aot.py::sig_of`.

use crate::util::error::Context;
use crate::{bail, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U8,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => DType::F32,
            "uint8" => DType::U8,
            "int32" => DType::I32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }
}

/// One argument's shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArgSpec {
    pub dtype: DType,
    /// Empty for scalars.
    pub dims: Vec<usize>,
}

/// One compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub args: Vec<ArgSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("read {}/manifest.txt", dir.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split('\t');
            let (name, rel, sig) = match (parts.next(), parts.next(), parts.next()) {
                (Some(a), Some(b), Some(c)) => (a, b, c),
                _ => bail!("manifest line {}: expected 3 tab-separated fields", lineno + 1),
            };
            let mut args = Vec::new();
            for entry in sig.split(',') {
                let (dt, dims) = entry
                    .split_once(':')
                    .with_context(|| format!("manifest line {}: bad sig entry {entry:?}", lineno + 1))?;
                let dims = if dims == "scalar" {
                    vec![]
                } else {
                    dims.split('x')
                        .map(|d| d.parse::<usize>().context("bad dim"))
                        .collect::<Result<Vec<_>>>()?
                };
                args.push(ArgSpec { dtype: DType::parse(dt)?, dims });
            }
            artifacts.push(ArtifactSpec { name: name.to_string(), path: dir.join(rel), args });
        }
        Ok(Manifest { artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All `gaps_{model}_{d}x{n}` artifacts for a model, as (d, n, spec),
    /// sorted by ascending d.
    pub fn gap_artifacts(&self, model: &str) -> Vec<(usize, usize, &ArtifactSpec)> {
        let prefix = format!("gaps_{model}_");
        let mut out: Vec<(usize, usize, &ArtifactSpec)> = self
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(&prefix))
            .filter_map(|a| {
                let shape = a.name.strip_prefix(&prefix)?;
                let (d, n) = shape.split_once('x')?;
                Some((d.parse().ok()?, n.parse().ok()?, a))
            })
            .collect();
        out.sort_by_key(|&(d, _, _)| d);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "gaps_lasso_1024x256\tgaps_lasso_1024x256.hlo.txt\tfloat32:1024x256,float32:1024,float32:256,float32:scalar,float32:scalar,float32:scalar\n\
gaps_q4_lasso_1024x256\tgaps_q4_lasso_1024x256.hlo.txt\tuint8:512x256,float32:16x256,float32:1024,float32:256,float32:scalar,float32:scalar,float32:scalar\n\
gaps_lasso_4096x512\tgaps_lasso_4096x512.hlo.txt\tfloat32:4096x512,float32:4096,float32:512,float32:scalar,float32:scalar,float32:scalar\n";

    #[test]
    fn parses_names_paths_sigs() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.find("gaps_lasso_1024x256").unwrap();
        assert_eq!(a.path, Path::new("/tmp/a/gaps_lasso_1024x256.hlo.txt"));
        assert_eq!(a.args.len(), 6);
        assert_eq!(a.args[0], ArgSpec { dtype: DType::F32, dims: vec![1024, 256] });
        assert_eq!(a.args[3], ArgSpec { dtype: DType::F32, dims: vec![] });
        let q = m.find("gaps_q4_lasso_1024x256").unwrap();
        assert_eq!(q.args[0].dtype, DType::U8);
    }

    #[test]
    fn gap_artifacts_sorted_by_d() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let gaps = m.gap_artifacts("lasso");
        assert_eq!(gaps.len(), 2);
        assert_eq!((gaps[0].0, gaps[0].1), (1024, 256));
        assert_eq!((gaps[1].0, gaps[1].1), (4096, 512));
        // the q4 family is addressable under its own model key, and the
        // fp32 "lasso" prefix above did NOT match the q4 artifact
        assert_eq!(m.gap_artifacts("q4_lasso").len(), 1);
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Manifest::parse("only-one-field", Path::new(".")).is_err());
        assert!(Manifest::parse("a\tb\tbaddtype:2", Path::new(".")).is_err());
        assert!(Manifest::parse("a\tb\tfloat32:2xNaN", Path::new(".")).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration smoke: only runs when `make artifacts` has run
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 13);
            for a in &m.artifacts {
                assert!(a.path.exists(), "{} missing", a.path.display());
            }
        }
    }
}
