//! [`GapService`]: the coordinator-facing adapter for offloading task
//! A's bulk gap computation to the compiled JAX/Pallas artifacts.
//!
//! Per request: pick the smallest `gaps_{model}_{d}x{n}` artifact whose
//! row capacity holds the problem, pack the requested coordinate block
//! into a zero-padded row-major tile, attach the `w`/`alpha` snapshots
//! and the runtime scalars `(lam, n, lip_b)` recovered from
//! [`ModelKind`], execute, and return the first `coords.len()` gaps.
//!
//! Zero-padding is sound: padded rows contribute nothing to `D^T w`, and
//! padded columns evaluate `gap(0, 0) = 0`, which is discarded anyway.

use super::executor::{ArgData, XlaRuntime};
use crate::coordinator::hthc::GapBackend;
use crate::data::{ColumnOps, Matrix};
use crate::glm::ModelKind;

pub struct GapService<'r> {
    rt: &'r XlaRuntime,
}

impl<'r> GapService<'r> {
    pub fn new(rt: &'r XlaRuntime) -> Self {
        GapService { rt }
    }

    /// (model name, lam, n_total, lip_b) from the scalar-op snapshot;
    /// None for models with no compiled artifact.
    fn scalars(kind: ModelKind) -> Option<(&'static str, f32, f32, f32)> {
        match kind {
            ModelKind::Lasso { lam, lip_b } => Some(("lasso", lam, 0.0, lip_b)),
            ModelKind::Ridge { lam } => Some(("ridge", lam, 0.0, 0.0)),
            ModelKind::Svm { inv_scale, inv_n } => {
                let n = 1.0 / inv_n;
                let lam = 1.0 / (inv_scale * n * n);
                Some(("svm", lam, n, 0.0))
            }
            // logistic / elastic-net: rust-side extensions, no artifact
            _ => None,
        }
    }
}

impl GapBackend for GapService<'_> {
    fn block_len(&self) -> usize {
        256 // the n-tile of the smallest artifacts
    }

    fn batch_gaps(
        &self,
        data: &Matrix,
        coords: &[usize],
        w: &[f32],
        alpha: &[f32],
        kind: ModelKind,
    ) -> Option<Vec<f32>> {
        let dm = match data {
            Matrix::Dense(dm) => dm,
            Matrix::Sparse(sm) => {
                return self.batch_gaps_sparse(sm, coords, w, alpha, kind)
            }
            Matrix::Quantized(_) => return None, // native fallback
        };
        let (model, lam, nn, lip_b) = Self::scalars(kind)?;
        let d = dm.n_rows();
        // smallest artifact that holds d rows and coords columns
        let (da, na, spec) = self
            .rt
            .manifest()
            .gap_artifacts(model)
            .into_iter()
            .find(|&(da, na, _)| da >= d && na >= coords.len())?;
        let name = spec.name.clone();

        // pack row-major (da x na), zero-padded
        let mut tile = vec![0.0f32; da * na];
        for (c, &j) in coords.iter().enumerate() {
            let col = dm.col(j);
            for (r, &x) in col.iter().enumerate() {
                tile[r * na + c] = x;
            }
        }
        let mut w_pad = vec![0.0f32; da];
        w_pad[..d].copy_from_slice(&w[..d]);
        let mut a_pad = vec![0.0f32; na];
        for (c, &j) in coords.iter().enumerate() {
            a_pad[c] = alpha[j];
        }

        let out = self
            .rt
            .run(
                &name,
                vec![
                    ArgData::F32 { data: tile, dims: vec![da, na] },
                    ArgData::F32 { data: w_pad, dims: vec![da] },
                    ArgData::F32 { data: a_pad, dims: vec![na] },
                    ArgData::ScalarF32(lam),
                    ArgData::ScalarF32(nn),
                    ArgData::ScalarF32(lip_b),
                ],
            )
            .ok()?;
        let z = out.into_iter().next()?;
        Some(z[..coords.len()].to_vec())
    }
}

impl GapService<'_> {
    /// Sparse blocks go through the ELL-padded artifact
    /// (`gaps_ell_{model}_{k_max}x{n}`, see kernels/sparse_ell.py) when
    /// every requested column fits the padded-nnz budget; otherwise the
    /// caller falls back to the native loop.
    fn batch_gaps_sparse(
        &self,
        sm: &crate::data::SparseMatrix,
        coords: &[usize],
        w: &[f32],
        alpha: &[f32],
        kind: ModelKind,
    ) -> Option<Vec<f32>> {
        let (model, lam, nn, lip_b) = Self::scalars(kind)?;
        let d = sm.n_rows();
        // fixed artifact geometry (catalogue in python/compile/model.py)
        let (kmax, ncols, dvec) = (128usize, 256usize, 2048usize);
        if d > dvec || coords.len() > ncols {
            return None;
        }
        if coords.iter().any(|&j| sm.nnz(j) > kmax) {
            return None; // truncation would be silent wrongness
        }
        let name = format!("gaps_ell_{model}_{kmax}x{ncols}");
        self.rt.manifest().find(&name)?;

        let mut idx = vec![0i32; kmax * ncols];
        let mut val = vec![0f32; kmax * ncols];
        for (c, &j) in coords.iter().enumerate() {
            let (rows, vals) = sm.col(j);
            for (k, (&r, &x)) in rows.iter().zip(vals).enumerate() {
                idx[k * ncols + c] = r as i32; // row-major (kmax, ncols)
                val[k * ncols + c] = x;
            }
        }
        let mut w_pad = vec![0f32; dvec];
        w_pad[..d].copy_from_slice(&w[..d]);
        let mut a_pad = vec![0f32; ncols];
        for (c, &j) in coords.iter().enumerate() {
            a_pad[c] = alpha[j];
        }
        let out = self
            .rt
            .run(
                &name,
                vec![
                    ArgData::I32 { data: idx, dims: vec![kmax, ncols] },
                    ArgData::F32 { data: val, dims: vec![kmax, ncols] },
                    ArgData::F32 { data: w_pad, dims: vec![dvec] },
                    ArgData::F32 { data: a_pad, dims: vec![ncols] },
                    ArgData::ScalarF32(lam),
                    ArgData::ScalarF32(nn),
                    ArgData::ScalarF32(lip_b),
                ],
            )
            .ok()?;
        let z = out.into_iter().next()?;
        Some(z[..coords.len()].to_vec())
    }
}

// Tests live in rust/tests/runtime_pjrt.rs (they need built artifacts).
