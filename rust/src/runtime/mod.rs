//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the rust hot path (python never runs at serve time).
//!
//! `make artifacts` lowers the L2 graphs (which embed the L1 Pallas
//! kernels) to **HLO text** in `artifacts/*.hlo.txt` plus a
//! `manifest.txt` index.  [`XlaRuntime`] owns a `PjRtClient` on a
//! dedicated executor thread (the PJRT wrappers hold raw pointers and
//! are kept off other threads entirely); callers submit typed requests
//! over a channel and block on a reply — the same pattern a serving
//! coordinator uses for an accelerator-bound executor.
//!
//! [`GapService`] adapts the runtime to the coordinator's
//! [`GapBackend`](crate::coordinator::hthc::GapBackend) hook: task A's
//! bulk gap sweeps (`z = h(D^T w, alpha)`) run through the compiled
//! artifact with tile padding.

pub mod executor;
pub mod gap_service;
pub mod manifest;

pub use executor::{ArgData, XlaRuntime};
pub use gap_service::GapService;
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // honour an override for tests / deployments
    if let Ok(p) = std::env::var("HTHC_ARTIFACTS") {
        return p.into();
    }
    "artifacts".into()
}
