//! Flag-to-[`Trainer`] mapping shared by the `hthc` binary and the
//! CLI-parity tests: the single source of truth for how `hthc train`
//! flags become a training configuration.

use super::{by_name, Sgd, Solver, Trainer, DEFAULT_LAM};
use crate::cluster::{ClusterConfig, FaultPlan};
use crate::coordinator::{HthcConfig, Selection};
use crate::util::Args;

/// Build an [`HthcConfig`] from `hthc train`-style flags (defaults match
/// the `hthc help` text).
pub fn config_from_args(args: &Args) -> HthcConfig {
    HthcConfig {
        t_a: args.usize_or("t-a", 4),
        t_b: args.usize_or("t-b", 2),
        v_b: args.usize_or("v-b", 1),
        batch_frac: args.f64_or("batch", 0.08),
        selection: Selection::parse(&args.str_or("selection", "gap"))
            .unwrap_or(Selection::DualityGap),
        gap_tol: args.f64_or("tol", 1e-5),
        max_epochs: args.usize_or("epochs", 200),
        timeout_secs: args.f64_or("timeout", 120.0),
        eval_every: args.usize_or("eval-every", 1),
        seed: args.u64_or("seed", 42),
        use_pjrt_gaps: args.bool_or("pjrt", false),
        // PANIC-OK: CLI flag validation — a malformed value should
        // abort with the flag name.
        adaptive_r_tilde: args.get("adaptive-r").map(|s| s.parse().expect("--adaptive-r")),
        autotune: args.bool_or("autotune", false),
        ..Default::default()
    }
}

/// Parse an `hthc cluster` fault script: `--kill NODE@TICK[,..]` and
/// `--partition FROM:TO:ID[+ID..][,..]` on top of the probabilistic
/// `--drop/--dup/--delay` wire faults.
fn fault_plan_from_args(args: &Args) -> crate::Result<FaultPlan> {
    let mut plan = FaultPlan::lossy(
        args.f64_or("drop", 0.0),
        args.f64_or("dup", 0.0),
        args.u64_or("delay", 0),
    );
    if !(0.0..1.0).contains(&plan.drop_prob) || !(0.0..1.0).contains(&plan.dup_prob) {
        crate::bail!("cluster: --drop/--dup must be probabilities in [0, 1)");
    }
    if let Some(spec) = args.get("kill") {
        for part in spec.split(',') {
            let Some((node, tick)) = part.split_once('@') else {
                crate::bail!("cluster: --kill wants NODE@TICK, got {part:?}");
            };
            let node: usize = node
                .trim()
                .parse()
                .map_err(|_| crate::err!("cluster: bad --kill node {node:?}"))?;
            let tick: u64 = tick
                .trim()
                .parse()
                .map_err(|_| crate::err!("cluster: bad --kill tick {tick:?}"))?;
            plan = plan.kill(tick, node);
        }
    }
    if let Some(spec) = args.get("partition") {
        for part in spec.split(',') {
            let fields: Vec<&str> = part.split(':').collect();
            let [from, to, ids] = fields[..] else {
                crate::bail!("cluster: --partition wants FROM:TO:ID[+ID..], got {part:?}");
            };
            let from: u64 = from
                .trim()
                .parse()
                .map_err(|_| crate::err!("cluster: bad --partition start {from:?}"))?;
            let to: u64 = to
                .trim()
                .parse()
                .map_err(|_| crate::err!("cluster: bad --partition end {to:?}"))?;
            let island = ids
                .split('+')
                .map(|id| {
                    id.trim()
                        .parse::<usize>()
                        .map_err(|_| crate::err!("cluster: bad --partition node {id:?}"))
                })
                .collect::<crate::Result<Vec<usize>>>()?;
            plan = plan.partition(from, to, island);
        }
    }
    Ok(plan)
}

/// Build a [`ClusterConfig`] from `hthc cluster`-style flags.  Shares
/// the `--tol/--epochs/--eval-every/--seed` spellings with `hthc
/// train` (rounds play the role of epochs); the fault script comes
/// from [`fault_plan_from_args`].
pub fn cluster_config_from_args(args: &Args) -> crate::Result<ClusterConfig> {
    Ok(ClusterConfig {
        nodes: args.usize_or("nodes", 4),
        local_passes: args.usize_or("local-passes", 1),
        gap_tol: args.f64_or("tol", 1e-5),
        max_rounds: args.u64_or("epochs", 200),
        eval_every: args.u64_or("eval-every", 1).max(1),
        seed: args.u64_or("seed", 42),
        max_ticks: args.u64_or("max-ticks", 100_000),
        initial_leader: args.usize_or("leader", 0),
        fault: fault_plan_from_args(args)?,
        ..Default::default()
    })
}

/// Build the full [`Trainer`] (engine + configuration) from the flags.
/// Errors on an unknown `--solver` — the process-exit policy stays in
/// the binary.
pub fn trainer_from_args(args: &Args) -> crate::Result<Trainer<'static>> {
    let name = args.str_or("solver", "hthc");
    // case-insensitive so that --solver SGD also honours --lam/--mse-target
    let solver: Box<dyn Solver> = if name.eq_ignore_ascii_case("sgd") {
        // SGD reads its own regularizer and target from the flags.
        Box::new(Sgd {
            lam: args.f32_or("lam", DEFAULT_LAM),
            mse_target: args.f64_or("mse-target", 0.0),
        })
    } else {
        by_name(&name).ok_or_else(|| crate::err!("unknown solver {name:?}"))?
    };
    Ok(Trainer::new()
        .solver_boxed(solver)
        .config(config_from_args(args)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn defaults_match_help_text() {
        let cfg = config_from_args(&parse(""));
        assert_eq!((cfg.t_a, cfg.t_b, cfg.v_b), (4, 2, 1));
        assert_eq!(cfg.batch_frac, 0.08);
        assert_eq!(cfg.selection, Selection::DualityGap);
        assert_eq!(cfg.gap_tol, 1e-5);
        assert_eq!(cfg.max_epochs, 200);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.adaptive_r_tilde, None);
        assert!(!cfg.autotune);
    }

    #[test]
    fn autotune_flag_enables_auto_mode() {
        let cfg = config_from_args(&parse("--autotune"));
        assert!(cfg.autotune);
        assert!(cfg.autotune_warmup >= 1);
    }

    #[test]
    fn sgd_solver_reads_lam_flag() {
        let t = trainer_from_args(&parse("--solver sgd --lam 0.25")).unwrap();
        assert_eq!(t.solver_ref().name(), "sgd");
        // case-insensitive spelling routes through the same branch
        let t2 = trainer_from_args(&parse("--solver SGD --lam 0.25")).unwrap();
        assert_eq!(t2.solver_ref().name(), "sgd");
    }

    #[test]
    fn unknown_solver_is_an_error_not_an_exit() {
        assert!(trainer_from_args(&parse("--solver bogus")).is_err());
    }

    #[test]
    fn cluster_defaults_match_help_text() {
        let cfg = cluster_config_from_args(&parse("")).unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.local_passes, 1);
        assert_eq!(cfg.gap_tol, 1e-5);
        assert_eq!(cfg.max_rounds, 200);
        assert_eq!(cfg.initial_leader, 0);
        assert_eq!(cfg.fault.drop_prob, 0.0);
        assert!(cfg.fault.kills.is_empty());
        assert!(cfg.fault.partitions.is_empty());
    }

    #[test]
    fn cluster_fault_script_parses() {
        let cfg = cluster_config_from_args(&parse(
            "--nodes 3 --drop 0.1 --dup 0.05 --delay 4 \
             --kill 0@20,2@50 --partition 5:150:0+1",
        ))
        .unwrap();
        assert_eq!(cfg.nodes, 3);
        assert_eq!(cfg.fault.drop_prob, 0.1);
        assert_eq!(cfg.fault.delay_max, 4);
        assert_eq!(cfg.fault.kills, vec![(20, 0), (50, 2)]);
        assert_eq!(cfg.fault.partitions.len(), 1);
        assert_eq!(cfg.fault.partitions[0].from, 5);
        assert_eq!(cfg.fault.partitions[0].to, 150);
        assert_eq!(cfg.fault.partitions[0].island, vec![0, 1]);
    }

    #[test]
    fn cluster_bad_fault_scripts_are_errors() {
        assert!(cluster_config_from_args(&parse("--kill 0-20")).is_err());
        assert!(cluster_config_from_args(&parse("--kill x@20")).is_err());
        assert!(cluster_config_from_args(&parse("--partition 5:150")).is_err());
        assert!(cluster_config_from_args(&parse("--partition a:b:0")).is_err());
        assert!(cluster_config_from_args(&parse("--drop 1.5")).is_err());
    }
}
