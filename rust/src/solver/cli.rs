//! Flag-to-[`Trainer`] mapping shared by the `hthc` binary and the
//! CLI-parity tests: the single source of truth for how `hthc train`
//! flags become a training configuration.

use super::{by_name, Sgd, Solver, Trainer, DEFAULT_LAM};
use crate::coordinator::{HthcConfig, Selection};
use crate::util::Args;

/// Build an [`HthcConfig`] from `hthc train`-style flags (defaults match
/// the `hthc help` text).
pub fn config_from_args(args: &Args) -> HthcConfig {
    HthcConfig {
        t_a: args.usize_or("t-a", 4),
        t_b: args.usize_or("t-b", 2),
        v_b: args.usize_or("v-b", 1),
        batch_frac: args.f64_or("batch", 0.08),
        selection: Selection::parse(&args.str_or("selection", "gap"))
            .unwrap_or(Selection::DualityGap),
        gap_tol: args.f64_or("tol", 1e-5),
        max_epochs: args.usize_or("epochs", 200),
        timeout_secs: args.f64_or("timeout", 120.0),
        eval_every: args.usize_or("eval-every", 1),
        seed: args.u64_or("seed", 42),
        use_pjrt_gaps: args.bool_or("pjrt", false),
        // PANIC-OK: CLI flag validation — a malformed value should
        // abort with the flag name.
        adaptive_r_tilde: args.get("adaptive-r").map(|s| s.parse().expect("--adaptive-r")),
        autotune: args.bool_or("autotune", false),
        ..Default::default()
    }
}

/// Build the full [`Trainer`] (engine + configuration) from the flags.
/// Errors on an unknown `--solver` — the process-exit policy stays in
/// the binary.
pub fn trainer_from_args(args: &Args) -> crate::Result<Trainer<'static>> {
    let name = args.str_or("solver", "hthc");
    // case-insensitive so that --solver SGD also honours --lam/--mse-target
    let solver: Box<dyn Solver> = if name.eq_ignore_ascii_case("sgd") {
        // SGD reads its own regularizer and target from the flags.
        Box::new(Sgd {
            lam: args.f32_or("lam", DEFAULT_LAM),
            mse_target: args.f64_or("mse-target", 0.0),
        })
    } else {
        by_name(&name).ok_or_else(|| crate::err!("unknown solver {name:?}"))?
    };
    Ok(Trainer::new()
        .solver_boxed(solver)
        .config(config_from_args(args)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn defaults_match_help_text() {
        let cfg = config_from_args(&parse(""));
        assert_eq!((cfg.t_a, cfg.t_b, cfg.v_b), (4, 2, 1));
        assert_eq!(cfg.batch_frac, 0.08);
        assert_eq!(cfg.selection, Selection::DualityGap);
        assert_eq!(cfg.gap_tol, 1e-5);
        assert_eq!(cfg.max_epochs, 200);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.adaptive_r_tilde, None);
        assert!(!cfg.autotune);
    }

    #[test]
    fn autotune_flag_enables_auto_mode() {
        let cfg = config_from_args(&parse("--autotune"));
        assert!(cfg.autotune);
        assert!(cfg.autotune_warmup >= 1);
    }

    #[test]
    fn sgd_solver_reads_lam_flag() {
        let t = trainer_from_args(&parse("--solver sgd --lam 0.25")).unwrap();
        assert_eq!(t.solver_ref().name(), "sgd");
        // case-insensitive spelling routes through the same branch
        let t2 = trainer_from_args(&parse("--solver SGD --lam 0.25")).unwrap();
        assert_eq!(t2.solver_ref().name(), "sgd");
    }

    #[test]
    fn unknown_solver_is_an_error_not_an_exit() {
        assert!(trainer_from_args(&parse("--solver bogus")).is_err());
    }
}
