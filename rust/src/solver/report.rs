//! [`FitReport`]: the unified outcome of any training run.
//!
//! Replaces the old `TrainResult`-vs-ad-hoc-tuple split: the fields all
//! solvers share are first-class, and solver-specific statistics (task
//! A/B update counts, gap-memory refresh fraction, SGD's final MSE, ...)
//! live in a typed [`Extras`] map keyed by the constants in [`keys`].

use crate::metrics::{ConvergenceTrace, PhaseTimes, StalenessHistogram};
use std::collections::BTreeMap;

/// Well-known [`Extras`] keys.  Engines only ever write these constants
/// so downstream tables can rely on the names.
pub mod keys {
    /// Task-A gap refreshes over the whole run (u64).
    pub const A_UPDATES: &str = "a_updates";
    /// Coordinate updates applied by the update task (u64).
    pub const B_UPDATES: &str = "b_updates";
    /// Updates whose closed-form delta was exactly zero (u64).
    pub const B_ZERO_DELTAS: &str = "b_zero_deltas";
    /// Mean fraction of the gap memory refreshed per epoch (f64).
    pub const REFRESH_FRAC: &str = "refresh_frac";
    /// SGD: final training mean squared error (f64).
    pub const FINAL_MSE: &str = "final_mse";
    /// `hthc train --split`: duality-gap certificate summed over the
    /// held-out columns with zero dual variables — the decomposable
    /// held-out objective (hinge loss of held-out samples for the SVM
    /// orientation, screening violation for L1 regression) (f64).
    pub const HELDOUT_GAP: &str = "heldout_gap";
    /// `hthc train --split`, classification: held-out accuracy (f64).
    pub const HELDOUT_ACCURACY: &str = "heldout_accuracy";
    /// `hthc train --split`: number of held-out columns (u64).
    pub const HELDOUT_COLS: &str = "heldout_cols";
    /// `hthc train --heldout-every N`: how many in-run held-out
    /// certificate evaluations the epoch observer performed (u64).
    pub const HELDOUT_EVALS: &str = "heldout_evals";
    /// Autotune: task-A threads in effect at the end of the run (u64).
    pub const AUTOTUNE_T_A: &str = "autotune_t_a";
    /// Autotune: task-B parallel updates in effect at run end (u64).
    pub const AUTOTUNE_T_B: &str = "autotune_t_b";
    /// Autotune: task-B vector lanes in effect at run end (u64).
    pub const AUTOTUNE_V_B: &str = "autotune_v_b";
    /// Autotune: batch size `m` in effect at run end (u64).
    pub const AUTOTUNE_M: &str = "autotune_m";
    /// Autotune: task-A scheduler tile granularity at run end (u64).
    pub const AUTOTUNE_TILE_COLS: &str = "autotune_tile_cols";
    /// Cluster: node count `K` of the simulated run (u64).
    pub const CLUSTER_NODES: &str = "cluster_nodes";
    /// Cluster: rounds completed under the final leader's term (u64).
    pub const CLUSTER_ROUNDS: &str = "cluster_rounds";
    /// Cluster: virtual ticks the run took (u64).
    pub const CLUSTER_TICKS: &str = "cluster_ticks";
    /// Cluster: election attempts across all nodes (u64).
    pub const CLUSTER_ELECTIONS: &str = "cluster_elections";
    /// Cluster: leadership takeovers after bootstrap (u64).
    pub const CLUSTER_FAILOVERS: &str = "cluster_failovers";
    /// Cluster: id of the leader that produced the report (u64).
    pub const CLUSTER_FINAL_LEADER: &str = "cluster_final_leader";
    /// Cluster: unicasts submitted to the wire (u64).
    pub const CLUSTER_MSGS_SENT: &str = "cluster_msgs_sent";
    /// Cluster: messages lost to faults, partitions or death (u64).
    pub const CLUSTER_MSGS_DROPPED: &str = "cluster_msgs_dropped";
    /// Cluster: messages the fault plan duplicated (u64).
    pub const CLUSTER_MSGS_DUPLICATED: &str = "cluster_msgs_duplicated";
    /// Cluster: reliable-link retransmissions (u64).
    pub const CLUSTER_RETRANSMITS: &str = "cluster_retransmits";
    /// Cluster: duplicate deliveries suppressed at receivers (u64).
    pub const CLUSTER_DEDUP_DROPPED: &str = "cluster_dedup_dropped";
}

/// One solver-specific statistic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Stat {
    U64(u64),
    F64(f64),
}

/// Typed string-keyed statistics map.
#[derive(Clone, Debug, Default)]
pub struct Extras(BTreeMap<&'static str, Stat>);

impl Extras {
    pub fn set_u64(&mut self, key: &'static str, v: u64) {
        self.0.insert(key, Stat::U64(v));
    }

    pub fn set_f64(&mut self, key: &'static str, v: f64) {
        self.0.insert(key, Stat::F64(v));
    }

    pub fn get(&self, key: &str) -> Option<Stat> {
        self.0.get(key).copied()
    }

    pub fn u64(&self, key: &str) -> Option<u64> {
        match self.get(key)? {
            Stat::U64(v) => Some(v),
            Stat::F64(_) => None,
        }
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            Stat::F64(v) => Some(v),
            Stat::U64(v) => Some(v as f64),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, Stat)> + '_ {
        self.0.iter().map(|(&k, &v)| (k, v))
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// A portable training iterate: everything a later fit needs to resume
/// from where an earlier one stopped.  Exported by
/// [`FitReport::iterate`], consumed by
/// [`Trainer::warm_start_from`](super::Trainer::warm_start_from) — the
/// warm-start currency between the solver layer and long-lived
/// consumers like the serving layer's refit loop.
///
/// Only `alpha` is authoritative: the shared vector `v = D alpha` is
/// re-derived exactly from the data at fit start, so an `Iterate` stays
/// valid across dataset rebuilds that preserve column identities.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Iterate {
    /// Dual iterate (SGD: primal weights) in normalized training space.
    pub alpha: Vec<f32>,
    /// Duality-gap certificate of the run that produced the iterate,
    /// when one was computed.
    pub gap: Option<f64>,
}

/// Outcome of a [`Solver::fit`](super::Solver::fit) run.
pub struct FitReport {
    /// Engine name (matches the trace label).
    pub solver: &'static str,
    /// Final dual iterate (SGD: primal weights `beta`).
    pub alpha: Vec<f32>,
    /// Final shared vector `v = D alpha` (SGD: predictions).
    pub v: Vec<f32>,
    /// Convergence measurements over the run.
    pub trace: ConvergenceTrace,
    pub epochs: usize,
    /// True when stopped by `gap_tol` or by the epoch callback.
    pub converged: bool,
    pub wall_secs: f64,
    /// Where epoch time went (engines that do not instrument phases
    /// leave this default).
    pub phase_times: PhaseTimes,
    /// Gap-memory staleness at the end of the run (HTHC only).
    pub staleness: StalenessHistogram,
    /// Solver-specific statistics (see [`keys`]).
    pub extras: Extras,
}

impl FitReport {
    pub fn final_objective(&self) -> Option<f64> {
        self.trace.final_objective()
    }

    pub fn final_gap(&self) -> Option<f64> {
        self.trace.final_gap()
    }

    /// Export the final iterate for a later warm start.
    pub fn iterate(&self) -> Iterate {
        Iterate {
            alpha: self.alpha.clone(),
            gap: self.final_gap().filter(|g| g.is_finite()),
        }
    }

    /// Task-A refreshes (0 for engines without a gap task).
    pub fn a_updates(&self) -> u64 {
        self.extras.u64(keys::A_UPDATES).unwrap_or(0)
    }

    pub fn b_updates(&self) -> u64 {
        self.extras.u64(keys::B_UPDATES).unwrap_or(0)
    }

    pub fn b_zero_deltas(&self) -> u64 {
        self.extras.u64(keys::B_ZERO_DELTAS).unwrap_or(0)
    }

    /// Mean gap-memory refresh fraction per epoch (engines that touch
    /// every coordinate per epoch report 1.0).
    pub fn refresh_frac(&self) -> f64 {
        self.extras.f64(keys::REFRESH_FRAC).unwrap_or(0.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "[{}] epochs={} wall={} gap={:.3e} obj={:.6e} refreshed/epoch={:.1}% A-updates={} B-updates={} (zero-deltas {})",
            self.solver,
            self.epochs,
            crate::util::fmt_secs(self.wall_secs),
            self.final_gap().unwrap_or(f64::NAN),
            self.final_objective().unwrap_or(f64::NAN),
            100.0 * self.refresh_frac(),
            self.a_updates(),
            self.b_updates(),
            self.b_zero_deltas(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> FitReport {
        let mut extras = Extras::default();
        extras.set_u64(keys::A_UPDATES, 10);
        extras.set_u64(keys::B_UPDATES, 20);
        extras.set_u64(keys::B_ZERO_DELTAS, 3);
        extras.set_f64(keys::REFRESH_FRAC, 0.5);
        let mut trace = ConvergenceTrace::new("test");
        trace.push(1.0, 4, 2.5, 0.125);
        FitReport {
            solver: "test",
            alpha: vec![1.0],
            v: vec![2.0],
            trace,
            epochs: 4,
            converged: true,
            wall_secs: 1.0,
            phase_times: Default::default(),
            staleness: Default::default(),
            extras,
        }
    }

    #[test]
    fn extras_typed_access() {
        let r = report();
        assert_eq!(r.a_updates(), 10);
        assert_eq!(r.b_updates(), 20);
        assert_eq!(r.b_zero_deltas(), 3);
        assert_eq!(r.refresh_frac(), 0.5);
        assert_eq!(r.extras.u64(keys::REFRESH_FRAC), None, "wrong type is None");
        assert_eq!(r.extras.f64(keys::A_UPDATES), Some(10.0), "u64 widens to f64");
        assert_eq!(r.extras.get("nonexistent"), None);
    }

    #[test]
    fn missing_extras_default_to_zero() {
        let mut r = report();
        r.extras = Extras::default();
        assert_eq!(r.a_updates(), 0);
        assert_eq!(r.refresh_frac(), 0.0);
    }

    #[test]
    fn summary_mentions_solver_and_counts() {
        let s = report().summary();
        assert!(s.contains("[test]"), "{s}");
        assert!(s.contains("A-updates=10"), "{s}");
    }
}
