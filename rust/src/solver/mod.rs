//! The engine-agnostic training API.
//!
//! The paper's contribution is a *comparison* — HTHC against ST,
//! OMP/OMP-WILD, PASSCoDe and SGD on the same problems — so the crate
//! exposes one interface over all of them:
//!
//! * [`Problem`] bundles a borrowed [`Dataset`] (matrix + targets +
//!   tier placement in one value) + model + [`TierSim`]
//!   (+ warm start + epoch observer + [`HthcConfig`]);
//! * [`Solver`] is the engine trait (`fit(&mut Problem) -> FitReport`),
//!   implemented by [`Hthc`], [`SeqThreshold`] (ST), [`Omp`],
//!   [`Passcode`] and [`Sgd`];
//! * [`FitReport`] is the unified outcome (iterate, trace, stop reason,
//!   phase times, typed solver-specific [`Extras`]);
//! * [`Trainer`] is the builder facade gluing it together, with the
//!   shared stopping rules in [`StopWhen`] and name-based dispatch in
//!   [`by_name`] / [`cli`].
//!
//! This is the only way to run an engine: the pre-redesign per-engine
//! entry points (`HthcSolver::train`, `train_st`, `train_omp`,
//! `train_passcode`, `train_sgd`) were kept as deprecated shims for
//! one release and have now been removed.
//!
//! [`Dataset`]: crate::data::Dataset
//! [`TierSim`]: crate::memory::TierSim
//! [`HthcConfig`]: crate::coordinator::HthcConfig

pub mod cli;
pub mod engines;
pub mod problem;
pub mod report;
pub mod trainer;

pub use engines::{by_name, Hthc, Omp, Passcode, SeqThreshold, Sgd, DEFAULT_LAM};
pub(crate) use problem::notify_epoch;
pub use problem::{EpochEvent, OnEpoch, Problem};
pub use report::{keys, Extras, FitReport, Iterate, Stat};
pub use trainer::{StopWhen, Trainer};

/// A training engine: consumes a [`Problem`], produces a [`FitReport`].
///
/// Engines honour the shared contract: `cfg`'s stopping rules
/// (`gap_tol`, `max_epochs`, `timeout_secs`, `eval_every`), the seed,
/// the warm start, and the per-epoch observer.  Solver-specific knobs
/// live on the implementing struct (e.g. `Omp { wild }`).
pub trait Solver {
    /// Stable engine name (doubles as the trace label).
    fn name(&self) -> &'static str;

    /// Run the engine to completion on `problem`.
    fn fit(&self, problem: &mut Problem<'_>) -> FitReport;
}
