//! [`Problem`]: everything one training run needs, in one place.
//!
//! The pre-redesign API passed (model, matrix, targets, sim)
//! positionally with a different shape per engine; `Problem` bundles a
//! borrowed [`Dataset`] (matrix + targets + placement metadata as one
//! value — targets are no longer a separate field) with the model, the
//! tier simulator, the run configuration, an optional warm start, and
//! an optional per-epoch observer, so every [`Solver`](super::Solver)
//! sees the same inputs.  Engines key their bulk-read `TierSim` charges
//! off [`Dataset::placement`].

use crate::coordinator::HthcConfig;
use crate::data::Dataset;
use crate::glm::GlmModel;
use crate::memory::TierSim;

/// Snapshot handed to the per-epoch callback at every convergence
/// evaluation (`cfg.eval_every` epochs).  `v`/`alpha` are the freshly
/// evaluated iterate; returning `true` from the callback stops the run
/// and marks the report converged (caller-defined stopping criterion,
/// e.g. time-to-accuracy probes).
pub struct EpochEvent<'e> {
    /// Engine name (matches the trace label).
    pub solver: &'static str,
    pub epoch: usize,
    pub wall_secs: f64,
    pub objective: f64,
    /// Duality gap (NaN for solvers without a certificate, e.g. SGD).
    pub gap: f64,
    /// Shared vector `v = D alpha` (SGD: predictions `X beta`).
    pub v: &'e [f32],
    /// Dual iterate (SGD: primal weights `beta`).
    pub alpha: &'e [f32],
}

/// Per-epoch observer: `true` = stop now (converged by caller's rule).
pub type OnEpoch<'a> = &'a mut dyn FnMut(&EpochEvent<'_>) -> bool;

/// Dispatch an epoch event to an optional observer — the one dispatch
/// path shared by every engine loop (engines `take()` the observer out
/// of the [`Problem`] before their borrow-heavy loops start).
pub(crate) fn notify_epoch(on_epoch: &mut Option<OnEpoch<'_>>, ev: &EpochEvent<'_>) -> bool {
    match on_epoch.as_mut() {
        Some(cb) => (**cb)(ev),
        None => false,
    }
}

/// One training problem: dataset + model + tier simulator +
/// configuration (+ optional warm start and epoch observer).
pub struct Problem<'a> {
    /// The data — matrix, targets and placement in one value.
    pub data: &'a Dataset,
    pub model: &'a mut dyn GlmModel,
    pub sim: &'a TierSim,
    /// Shared run configuration (thread topology, batch, stopping rules,
    /// seed).  Engines read the fields that apply to them — the same
    /// contract `HthcConfig` always had for the baselines.
    pub cfg: HthcConfig,
    /// Warm-start iterate (length n).  `v` is re-derived exactly as
    /// `D alpha` so the primal-dual invariant holds from epoch one.
    pub warm_alpha: Option<Vec<f32>>,
    /// Per-epoch observer (see [`EpochEvent`]).
    pub on_epoch: Option<OnEpoch<'a>>,
}

impl<'a> Problem<'a> {
    pub fn new(
        model: &'a mut dyn GlmModel,
        data: &'a Dataset,
        sim: &'a TierSim,
        cfg: HthcConfig,
    ) -> Self {
        // every engine gets the documented panic-early messages, not
        // just HTHC (whose pool construction used to be the only check)
        cfg.validate();
        Problem { data, model, sim, cfg, warm_alpha: None, on_epoch: None }
    }

    /// Start from a previous iterate instead of zeros.
    pub fn warm_start(mut self, alpha: Vec<f32>) -> Self {
        self.warm_alpha = Some(alpha);
        self
    }

    /// Observe (and optionally stop) the run at every evaluation epoch.
    pub fn on_epoch(mut self, cb: OnEpoch<'a>) -> Self {
        self.on_epoch = Some(cb);
        self
    }

    /// Consume the warm start into an initial `(alpha, v)` pair; zeros
    /// when no warm start was requested.
    pub(crate) fn initial_state(&mut self) -> (Vec<f32>, Vec<f32>) {
        let (d, n) = (self.data.n_rows(), self.data.n_cols());
        match self.warm_alpha.take() {
            Some(alpha) => {
                assert_eq!(alpha.len(), n, "warm-start alpha length must equal n_cols");
                let v = self.data.matvec_alpha(&alpha);
                (alpha, v)
            }
            None => (vec![0.0; n], vec![0.0; d]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetBuilder, DatasetKind, Family};
    use crate::glm::Lasso;

    fn tiny(seed: u64) -> Dataset {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn initial_state_zero_without_warm_start() {
        let g = tiny(3100);
        let mut model = Lasso::new(0.1);
        let sim = TierSim::default();
        let mut p = Problem::new(&mut model, &g, &sim, HthcConfig::default());
        let (a, v) = p.initial_state();
        assert_eq!(a.len(), g.n());
        assert_eq!(v.len(), g.d());
        assert!(a.iter().all(|&x| x == 0.0) && v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn warm_start_rederives_v_exactly() {
        let g = tiny(3101);
        let mut model = Lasso::new(0.1);
        let sim = TierSim::default();
        let alpha: Vec<f32> = (0..g.n()).map(|j| (j % 3) as f32 * 0.5).collect();
        let mut p = Problem::new(&mut model, &g, &sim, HthcConfig::default())
            .warm_start(alpha.clone());
        let (a, v) = p.initial_state();
        assert_eq!(a, alpha);
        assert_eq!(v, g.matvec_alpha(&alpha));
        // consumed: a second call is a cold start
        assert!(p.initial_state().0.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn mismatched_warm_start_rejected() {
        let g = tiny(3102);
        let mut model = Lasso::new(0.1);
        let sim = TierSim::default();
        let mut p = Problem::new(&mut model, &g, &sim, HthcConfig::default())
            .warm_start(vec![0.0; g.n() - 1]);
        let _ = p.initial_state();
    }
}
