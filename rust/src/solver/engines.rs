//! The engine adapters: one zero-cost struct per solver family, each
//! implementing [`Solver`](super::Solver) by delegating to the engine
//! loop that lives next to its algorithm (coordinator for HTHC, the
//! `baselines` modules for the comparators).
//!
//! Adding a solver = implement `Solver` + add a [`by_name`] arm; nothing
//! else in the crate needs to know.

use super::{FitReport, Problem, Solver};
use crate::baselines::{omp, passcode, sgd, st, OmpMode, PasscodeMode};
use crate::coordinator::hthc::{GapBackend, HthcSolver};

/// The paper's scheme: heterogeneous tasks A+B (§III).  Optionally
/// carries a PJRT [`GapBackend`] for task A's bulk gap sweeps.
#[derive(Default)]
pub struct Hthc<'b> {
    backend: Option<&'b dyn GapBackend>,
}

impl<'b> Hthc<'b> {
    pub fn new() -> Self {
        Hthc { backend: None }
    }

    /// Route task A's gap computation through a PJRT backend.
    pub fn with_backend(backend: &'b dyn GapBackend) -> Self {
        Hthc { backend: Some(backend) }
    }
}

impl Solver for Hthc<'_> {
    fn name(&self) -> &'static str {
        "hthc"
    }

    fn fit(&self, problem: &mut Problem<'_>) -> FitReport {
        // mut: autotuning may re-size the solver's pools mid-run
        let mut solver = HthcSolver::new(problem.cfg.clone());
        solver.fit_problem(problem, self.backend)
    }
}

/// The paper's ST baseline: single-task parallel async SCD over every
/// coordinate each epoch (§V-B1).
#[derive(Clone, Copy, Debug, Default)]
pub struct SeqThreshold;

impl Solver for SeqThreshold {
    fn name(&self) -> &'static str {
        "st"
    }

    fn fit(&self, problem: &mut Problem<'_>) -> FitReport {
        st::fit(problem)
    }
}

/// The "straightforward OpenMP port" comparator; `wild` drops the
/// per-element atomics (OMP WILD).
#[derive(Clone, Copy, Debug, Default)]
pub struct Omp {
    pub wild: bool,
}

impl Solver for Omp {
    fn name(&self) -> &'static str {
        if self.wild {
            "omp-wild"
        } else {
            "omp"
        }
    }

    fn fit(&self, problem: &mut Problem<'_>) -> FitReport {
        let mode = if self.wild { OmpMode::Wild } else { OmpMode::Atomic };
        omp::fit(problem, mode)
    }
}

/// PASSCoDe-atomic / -wild (Hsieh et al., Table IV).
#[derive(Clone, Copy, Debug)]
pub struct Passcode {
    pub mode: PasscodeMode,
}

impl Default for Passcode {
    fn default() -> Self {
        Passcode { mode: PasscodeMode::Atomic }
    }
}

impl Solver for Passcode {
    fn name(&self) -> &'static str {
        match self.mode {
            PasscodeMode::Atomic => "passcode-atomic",
            PasscodeMode::Wild => "passcode-wild",
        }
    }

    fn fit(&self, problem: &mut Problem<'_>) -> FitReport {
        passcode::fit(problem, self.mode)
    }
}

/// The one `--lam` default, shared by the CLI parser, `main`'s model
/// factory and [`Sgd::default`] so the three cannot drift apart.
pub const DEFAULT_LAM: f32 = 1e-3;

/// VW-style primal SGD (Table V).  Ignores the problem's GLM model: it
/// optimizes the primal Lasso objective with its own `lam`, and the
/// report's `alpha` holds the primal weights `beta` (`v` the predictions).
#[derive(Clone, Copy, Debug)]
pub struct Sgd {
    pub lam: f32,
    /// Stop (converged) once the training MSE falls to this.
    pub mse_target: f64,
}

impl Default for Sgd {
    fn default() -> Self {
        // comparisons that care about the objective must set `lam`
        // explicitly (SGD is model-free)
        Sgd { lam: DEFAULT_LAM, mse_target: 0.0 }
    }
}

impl Solver for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn fit(&self, problem: &mut Problem<'_>) -> FitReport {
        sgd::fit(problem, self.lam, self.mse_target)
    }
}

/// Solver dispatch by name — accepts both the CLI spellings
/// (`hthc`, `st`, `omp-wild`, `passcode`, ...) and the paper's table
/// labels (`A+B`, `ST`, `OMP WILD`, `PASSCoDe-atomic`, ...).
///
/// `"sgd"` returns [`Sgd::default`] (lam 1e-3, no MSE target).  SGD
/// optimizes its own primal objective and ignores the problem's GLM
/// model, so objective comparisons against the CD engines must
/// construct `Sgd { lam, mse_target }` explicitly instead.
pub fn by_name(name: &str) -> Option<Box<dyn Solver>> {
    Some(match name {
        "hthc" | "A+B" => Box::new(Hthc::new()),
        "st" | "ST" | "ST(A+B)" => Box::new(SeqThreshold),
        "omp" | "OMP" => Box::new(Omp { wild: false }),
        "omp-wild" | "OMP WILD" => Box::new(Omp { wild: true }),
        "passcode" | "passcode-atomic" | "PASSCoDe-atomic" => {
            Box::new(Passcode { mode: PasscodeMode::Atomic })
        }
        "passcode-wild" | "PASSCoDe-wild" => Box::new(Passcode { mode: PasscodeMode::Wild }),
        "sgd" | "SGD" => Box::new(Sgd::default()),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_cli_and_paper_spellings() {
        for (name, want) in [
            ("hthc", "hthc"),
            ("A+B", "hthc"),
            ("st", "st"),
            ("ST", "st"),
            ("ST(A+B)", "st"),
            ("omp", "omp"),
            ("OMP WILD", "omp-wild"),
            ("passcode", "passcode-atomic"),
            ("PASSCoDe-wild", "passcode-wild"),
            ("sgd", "sgd"),
        ] {
            assert_eq!(by_name(name).unwrap().name(), want, "{name}");
        }
        assert!(by_name("bogus").is_none());
    }
}
