//! [`Trainer`]: the builder facade over every [`Solver`].
//!
//! ```no_run
//! use hthc::data::{DatasetBuilder, DatasetKind, Family};
//! use hthc::glm::Lasso;
//! use hthc::solver::{SeqThreshold, StopWhen, Trainer};
//!
//! let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
//!     .seed(42)
//!     .build()
//!     .unwrap();
//! let report = Trainer::new()
//!     .solver(SeqThreshold)
//!     .model(Box::new(Lasso::new(0.3)))
//!     .threads(2, 2, 1)
//!     .stop_when(StopWhen::gap_below(1e-4).max_epochs(500))
//!     .fit(&ds);
//! println!("{}", report.summary());
//! ```
//!
//! The shared stopping rules (gap tolerance, epoch cap, wall-clock
//! timeout), deterministic seeding, warm starts and per-epoch callbacks
//! apply to every engine — before the redesign only HTHC (stopping) and
//! PASSCoDe (callback) had them.

use super::{EpochEvent, FitReport, Hthc, Problem, Solver};
use crate::coordinator::{HthcConfig, Selection};
use crate::data::Dataset;
use crate::glm::GlmModel;
use crate::memory::TierSim;

/// The shared stopping rules, separable from the solver knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StopWhen {
    /// Stop (converged) when the total duality gap falls below this.
    pub gap_tol: f64,
    /// Hard epoch cap.
    pub max_epochs: usize,
    /// Hard wall-clock cap (seconds).
    pub timeout_secs: f64,
    /// Epochs between exact convergence evaluations.
    pub eval_every: usize,
}

impl Default for StopWhen {
    fn default() -> Self {
        let cfg = HthcConfig::default();
        StopWhen {
            gap_tol: cfg.gap_tol,
            max_epochs: cfg.max_epochs,
            timeout_secs: cfg.timeout_secs,
            eval_every: cfg.eval_every,
        }
    }
}

impl StopWhen {
    /// Converge on a duality-gap threshold (other limits at defaults).
    pub fn gap_below(tol: f64) -> Self {
        StopWhen { gap_tol: tol, ..Default::default() }
    }

    pub fn max_epochs(mut self, n: usize) -> Self {
        self.max_epochs = n;
        self
    }

    pub fn timeout_secs(mut self, s: f64) -> Self {
        self.timeout_secs = s;
        self
    }

    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k;
        self
    }
}

/// Builder facade: pick a solver, a model, the topology and stopping
/// rules, then [`fit`](Trainer::fit).
///
/// The lifetime `'b` covers borrowed engine state (a PJRT backend in
/// [`Hthc::with_backend`]) and the epoch callback; plain usage infers it.
pub struct Trainer<'b> {
    solver: Box<dyn Solver + 'b>,
    model: Option<Box<dyn GlmModel>>,
    cfg: HthcConfig,
    warm_alpha: Option<Vec<f32>>,
    on_epoch: Option<Box<dyn FnMut(&EpochEvent<'_>) -> bool + 'b>>,
    sim: TierSim,
}

impl Default for Trainer<'_> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'b> Trainer<'b> {
    /// A trainer with the HTHC engine and default configuration.
    pub fn new() -> Self {
        Trainer {
            solver: Box::new(Hthc::new()),
            model: None,
            cfg: HthcConfig::default(),
            warm_alpha: None,
            on_epoch: None,
            sim: TierSim::default(),
        }
    }

    /// Select the engine (default: [`Hthc`]).
    pub fn solver(mut self, s: impl Solver + 'b) -> Self {
        self.solver = Box::new(s);
        self
    }

    /// Select an already-boxed engine (e.g. from [`super::by_name`]).
    pub fn solver_boxed(mut self, s: Box<dyn Solver + 'b>) -> Self {
        self.solver = s;
        self
    }

    /// Own the model to train; retrieve it after [`fit`](Trainer::fit)
    /// with [`model_ref`](Trainer::model_ref), or keep ownership outside
    /// and use [`fit_with`](Trainer::fit_with).
    pub fn model(mut self, m: Box<dyn GlmModel>) -> Self {
        self.model = Some(m);
        self
    }

    /// Replace the whole configuration (harness path; the granular
    /// setters below cover interactive use).
    pub fn config(mut self, cfg: HthcConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Thread topology `(T_A, T_B, V_B)` (paper §IV-F).
    pub fn threads(mut self, t_a: usize, t_b: usize, v_b: usize) -> Self {
        self.cfg.t_a = t_a;
        self.cfg.t_b = t_b;
        self.cfg.v_b = v_b;
        self
    }

    /// `%B`: fraction of coordinates updated per epoch.
    pub fn batch_frac(mut self, frac: f64) -> Self {
        self.cfg.batch_frac = frac;
        self
    }

    pub fn selection(mut self, s: Selection) -> Self {
        self.cfg.selection = s;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn lock_chunk(mut self, chunk: usize) -> Self {
        self.cfg.lock_chunk = chunk;
        self
    }

    /// Online §IV-F batch controller target (HTHC only).
    pub fn adaptive_refresh(mut self, r_tilde: Option<f64>) -> Self {
        self.cfg.adaptive_r_tilde = r_tilde;
        self
    }

    /// Auto mode (HTHC only): after a few observed epochs, re-solve the
    /// §IV-F split from *measured* tier traffic and timings and apply
    /// the recommendation (threads, batch size, scheduler tile).  The
    /// chosen split is reported under the `autotune_*` extras keys.
    pub fn autotune(mut self, on: bool) -> Self {
        self.cfg.autotune = on;
        self
    }

    /// The shared stopping rules.
    pub fn stop_when(mut self, stop: StopWhen) -> Self {
        self.cfg.gap_tol = stop.gap_tol;
        self.cfg.max_epochs = stop.max_epochs;
        self.cfg.timeout_secs = stop.timeout_secs;
        self.cfg.eval_every = stop.eval_every;
        self
    }

    /// Warm-start the **next fit only** from a previous iterate; it is
    /// consumed by that fit, so on a reused trainer subsequent fits
    /// cold-start unless `warm_start` is called again (solver, config
    /// and callback persist across fits — the warm start deliberately
    /// does not, since replaying a stale iterate is rarely intended).
    pub fn warm_start(mut self, alpha: Vec<f32>) -> Self {
        self.warm_alpha = Some(alpha);
        self
    }

    /// [`warm_start`](Trainer::warm_start) from an exported
    /// [`Iterate`](super::Iterate) (e.g. [`FitReport::iterate`]), resized
    /// to `n_cols` with zeros for coordinates the iterate has not seen —
    /// the refit path for a dataset that grew by appended columns.
    pub fn warm_start_from(self, it: &super::Iterate, n_cols: usize) -> Self {
        let mut alpha = it.alpha.clone();
        alpha.resize(n_cols, 0.0);
        self.warm_start(alpha)
    }

    /// Observe every evaluation epoch; return `true` to stop the run
    /// (the report is then marked converged).
    pub fn on_epoch(mut self, cb: impl FnMut(&EpochEvent<'_>) -> bool + 'b) -> Self {
        self.on_epoch = Some(Box::new(cb));
        self
    }

    /// The assembled configuration (CLI parity tests, introspection).
    pub fn cfg(&self) -> &HthcConfig {
        &self.cfg
    }

    /// The selected engine.
    pub fn solver_ref(&self) -> &(dyn Solver + 'b) {
        &*self.solver
    }

    /// The trainer-owned tier simulator (traffic accounting for fits
    /// run through [`fit`](Trainer::fit)).
    pub fn tier_sim(&self) -> &TierSim {
        &self.sim
    }

    /// The owned model, if one was set (post-fit inspection).
    pub fn model_ref(&self) -> Option<&dyn GlmModel> {
        self.model.as_deref()
    }

    /// Train the owned model on `data` (targets travel inside the
    /// [`Dataset`]).
    ///
    /// Panics if no model was set — harnesses that keep model ownership
    /// outside the trainer use [`fit_with`](Trainer::fit_with).
    pub fn fit(&mut self, data: &Dataset) -> FitReport {
        let mut model = self
            .model
            .take()
            // PANIC-OK: documented contract — `fit` panics without a
            // model (see doc comment above).
            .expect("Trainer::fit: no model set — call .model(...) or use fit_with");
        let report = {
            let mut problem =
                Problem::new(model.as_mut(), data, &self.sim, self.cfg.clone());
            if let Some(alpha) = self.warm_alpha.take() {
                problem = problem.warm_start(alpha);
            }
            if let Some(cb) = self.on_epoch.as_deref_mut() {
                problem = problem.on_epoch(cb);
            }
            self.solver.fit(&mut problem)
        };
        self.model = Some(model);
        report
    }

    /// Train a borrowed model against an external tier simulator — the
    /// harness-facing twin of [`fit`](Trainer::fit).
    pub fn fit_with(
        &mut self,
        model: &mut dyn GlmModel,
        data: &Dataset,
        sim: &TierSim,
    ) -> FitReport {
        let mut problem = Problem::new(model, data, sim, self.cfg.clone());
        if let Some(alpha) = self.warm_alpha.take() {
            problem = problem.warm_start(alpha);
        }
        if let Some(cb) = self.on_epoch.as_deref_mut() {
            problem = problem.on_epoch(cb);
        }
        self.solver.fit(&mut problem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_when_maps_onto_config() {
        let t = Trainer::new().stop_when(
            StopWhen::gap_below(1e-3)
                .max_epochs(7)
                .timeout_secs(2.5)
                .eval_every(4),
        );
        assert_eq!(t.cfg().gap_tol, 1e-3);
        assert_eq!(t.cfg().max_epochs, 7);
        assert_eq!(t.cfg().timeout_secs, 2.5);
        assert_eq!(t.cfg().eval_every, 4);
    }

    #[test]
    fn builder_setters_compose() {
        let t = Trainer::new()
            .threads(3, 4, 2)
            .batch_frac(0.5)
            .selection(Selection::Random)
            .seed(9)
            .lock_chunk(64)
            .adaptive_refresh(Some(0.2))
            .autotune(true);
        let c = t.cfg();
        assert_eq!((c.t_a, c.t_b, c.v_b), (3, 4, 2));
        assert_eq!(c.batch_frac, 0.5);
        assert_eq!(c.selection, Selection::Random);
        assert_eq!(c.seed, 9);
        assert_eq!(c.lock_chunk, 64);
        assert_eq!(c.adaptive_r_tilde, Some(0.2));
        assert!(c.autotune);
    }

    #[test]
    fn default_engine_is_hthc() {
        assert_eq!(Trainer::new().solver_ref().name(), "hthc");
    }
}
