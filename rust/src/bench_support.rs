//! Shared plumbing for the paper-reproduction bench harnesses
//! (`rust/benches/*`): dataset construction, solver dispatch, and
//! time-to-threshold extraction.  Not part of the training API.

use crate::coordinator::HthcConfig;
use crate::data::{Dataset, DatasetBuilder, DatasetKind, Family};
use crate::glm::{GlmModel, Lasso, SvmDual};
use crate::memory::TierSim;
use crate::solver::{by_name, FitReport, Trainer};

/// Environment-tunable dataset scale so `cargo bench` stays minutes,
/// not hours, on small hosts (`HTHC_BENCH_SCALE`, default 1.0 applies
/// the per-bench baseline scales).
pub fn bench_scale() -> f64 {
    std::env::var("HTHC_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// The four Table-I analogues at bench scale (built through the one
/// [`DatasetBuilder`] pipeline, like every other dataset in the crate).
pub fn bench_dataset(kind: DatasetKind, family: Family, seed: u64) -> Dataset {
    let base = match kind {
        DatasetKind::EpsilonLike => 0.35,
        DatasetKind::DvscLike => 0.3,
        DatasetKind::News20Like => 0.08,
        DatasetKind::CriteoLike => 0.05,
        DatasetKind::Tiny => 1.0,
    };
    DatasetBuilder::generated(kind, family)
        .scale(base * bench_scale())
        .seed(seed)
        .build()
        // PANIC-OK: bench harness setup; a bad generator config should
        // abort the bench run loudly.
        .expect("bench dataset")
}

/// Model factory per paper experiment (lambdas follow Table II/III's
/// magnitudes, adjusted for the scaled data).
pub fn bench_model(model: &str, n: usize) -> Box<dyn GlmModel> {
    match model {
        "lasso" => Box::new(Lasso::new(0.3)),
        "svm" => Box::new(SvmDual::new(1e-3, n)),
        other => panic!("bench_model: {other}"),
    }
}

/// Relative initial objective for threshold scaling.
pub fn obj0(model: &dyn GlmModel, ds: &Dataset) -> f64 {
    model
        .objective(&vec![0.0; ds.n_rows()], ds.targets(), &vec![0.0; ds.n_cols()])
        .abs()
        .max(1.0)
}

/// Solver dispatch by the paper's names (and the CLI spellings) — a
/// thin veneer over [`crate::solver::by_name`]: all dispatch lives in
/// the solver layer.
pub fn run_solver(
    name: &str,
    model: &mut dyn GlmModel,
    data: &Dataset,
    cfg: &HthcConfig,
) -> FitReport {
    let sim = TierSim::default();
    let solver = by_name(name).unwrap_or_else(|| panic!("run_solver: {name}"));
    Trainer::new()
        .solver_boxed(solver)
        .config(cfg.clone())
        .fit_with(model, data, &sim)
}

/// Default bench config (thread topology mirrors the paper's tables at
/// host scale).
pub fn bench_cfg(gap_tol: f64, timeout: f64) -> HthcConfig {
    HthcConfig {
        t_a: 2,
        t_b: 2,
        v_b: 1,
        batch_frac: 0.08,
        gap_tol,
        max_epochs: 100_000,
        timeout_secs: timeout,
        eval_every: 5,
        ..Default::default()
    }
}

/// Render "time to gap <= thr" for a set of thresholds.
pub fn times_to(res: &FitReport, obj0: f64, rels: &[f64]) -> Vec<Option<f64>> {
    rels.iter().map(|r| res.trace.time_to_gap(r * obj0)).collect()
}

// ---------------------------------------------------------------------------
// Bench JSON (dependency-free writer)
// ---------------------------------------------------------------------------

/// One kernel's scalar-vs-dispatched measurement.
pub struct KernelRecord {
    pub kernel: String,
    /// Bytes a single call streams (for GB/s conversion).
    pub bytes_per_call: f64,
    pub scalar_secs: f64,
    pub dispatched_secs: f64,
}

impl KernelRecord {
    pub fn scalar_gbs(&self) -> f64 {
        self.bytes_per_call / self.scalar_secs.max(1e-12) / 1e9
    }

    pub fn dispatched_gbs(&self) -> f64 {
        self.bytes_per_call / self.dispatched_secs.max(1e-12) / 1e9
    }

    /// Throughput ratio dispatched / scalar.
    pub fn speedup(&self) -> f64 {
        self.scalar_secs / self.dispatched_secs.max(1e-12)
    }
}

/// One bounded serving-simulation measurement: the latency benchmark
/// axis that rides alongside the kernel throughput records (ISSUE 7).
/// Latencies are milliseconds, straight from the serve layer's
/// fixed-bucket histogram.
pub struct ServeRecord {
    pub qps: f64,
    pub rows_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub published: u64,
    pub rejected: u64,
    pub attempts: u64,
    /// Examples the bounded ingest buffer dropped under backpressure
    /// (ISSUE 8 memory counters — 0 in an uncapped run).
    pub ingest_dropped: u64,
    /// Samples the retention policy forgot from the training corpus.
    pub corpus_evicted: u64,
    /// High-water mark of the retained corpus size.
    pub corpus_peak: u64,
}

/// One engine's epochs-to-certificate measurement: the convergence-
/// speed benchmark axis (ISSUE 10).  Epoch counts — not wall seconds —
/// are compared across snapshots on purpose: they are a property of
/// the algorithm and the seed, not of the runner hardware, so
/// `tools/bench_compare.py` can gate on them portably.
pub struct ConvergenceRecord {
    pub engine: String,
    pub dataset: String,
    /// Absolute duality-gap target the epochs count down to.
    pub gap_target: f64,
    /// First evaluated epoch (cluster: round) at gap <= target, if
    /// reached.
    pub epochs_to_target: Option<u64>,
    pub final_gap: f64,
    pub epochs_run: u64,
}

/// Machine-readable bench output: per-kernel scalar-vs-dispatched
/// throughput plus free-form notes (e.g. "host lacks AVX2").  Written
/// as JSON with a hand-rolled renderer — the crate is dependency-free.
pub struct BenchJson {
    bench: String,
    backend: String,
    records: Vec<KernelRecord>,
    serve: Option<ServeRecord>,
    convergence: Vec<ConvergenceRecord>,
    notes: Vec<String>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

/// Scientific spelling for quantities spanning many decades (duality
/// gaps) — still a valid JSON number.
fn json_num_e(x: f64) -> String {
    if x.is_finite() {
        format!("{x:e}")
    } else {
        "null".to_string()
    }
}

impl BenchJson {
    pub fn new(bench: &str) -> Self {
        BenchJson {
            bench: bench.to_string(),
            backend: crate::kernels::backend().name().to_string(),
            records: Vec::new(),
            serve: None,
            convergence: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Attach the serving-simulation measurement (at most one per
    /// bench; a second call replaces the first).
    pub fn set_serve(&mut self, serve: ServeRecord) {
        self.serve = Some(serve);
    }

    pub fn serve(&self) -> Option<&ServeRecord> {
        self.serve.as_ref()
    }

    /// Record one engine's epochs-to-certificate measurement.
    pub fn add_convergence(&mut self, rec: ConvergenceRecord) {
        self.convergence.push(rec);
    }

    pub fn convergence(&self) -> &[ConvergenceRecord] {
        &self.convergence
    }

    /// Record one kernel's scalar-vs-dispatched timing.
    pub fn record(
        &mut self,
        kernel: &str,
        bytes_per_call: f64,
        scalar_secs: f64,
        dispatched_secs: f64,
    ) {
        self.records.push(KernelRecord {
            kernel: kernel.to_string(),
            bytes_per_call,
            scalar_secs,
            dispatched_secs,
        });
    }

    /// Attach a free-form note (e.g. why a speedup target is waived).
    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    pub fn records(&self) -> &[KernelRecord] {
        &self.records
    }

    /// Render the JSON document.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        out.push_str(&format!(
            "  \"dispatched_backend\": \"{}\",\n",
            json_escape(&self.backend)
        ));
        out.push_str("  \"kernels\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"bytes_per_call\": {}, \
                 \"scalar_gbs\": {}, \"dispatched_gbs\": {}, \"speedup\": {}}}{}\n",
                json_escape(&r.kernel),
                json_num(r.bytes_per_call),
                json_num(r.scalar_gbs()),
                json_num(r.dispatched_gbs()),
                json_num(r.speedup()),
                if i + 1 < self.records.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n");
        if let Some(s) = &self.serve {
            out.push_str(&format!(
                "  \"serve\": {{\"qps\": {}, \"rows_per_sec\": {}, \
                 \"p50_ms\": {}, \"p95_ms\": {}, \"p99_ms\": {}, \
                 \"published\": {}, \"rejected\": {}, \"attempts\": {}, \
                 \"ingest_dropped\": {}, \"corpus_evicted\": {}, \
                 \"corpus_peak\": {}}},\n",
                json_num(s.qps),
                json_num(s.rows_per_sec),
                json_num(s.p50_ms),
                json_num(s.p95_ms),
                json_num(s.p99_ms),
                s.published,
                s.rejected,
                s.attempts,
                s.ingest_dropped,
                s.corpus_evicted,
                s.corpus_peak,
            ));
        }
        if !self.convergence.is_empty() {
            out.push_str("  \"convergence\": [\n");
            for (i, r) in self.convergence.iter().enumerate() {
                let epochs = match r.epochs_to_target {
                    Some(e) => e.to_string(),
                    None => "null".to_string(),
                };
                out.push_str(&format!(
                    "    {{\"engine\": \"{}\", \"dataset\": \"{}\", \
                     \"gap_target\": {}, \"epochs_to_target\": {}, \
                     \"final_gap\": {}, \"epochs_run\": {}}}{}\n",
                    json_escape(&r.engine),
                    json_escape(&r.dataset),
                    json_num_e(r.gap_target),
                    epochs,
                    json_num_e(r.final_gap),
                    r.epochs_run,
                    if i + 1 < self.convergence.len() { "," } else { "" },
                ));
            }
            out.push_str("  ],\n");
        }
        out.push_str("  \"notes\": [");
        for (i, n) in self.notes.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\"", json_escape(n)));
        }
        out.push_str("]\n}\n");
        out
    }

    /// Write to `$HTHC_BENCH_JSON_DIR` (default `target/bench-json/`)
    /// as `<bench>.json`; returns the path.
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("HTHC_BENCH_JSON_DIR")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|_| std::path::PathBuf::from("target/bench-json"));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.json", self.bench));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_default_is_one() {
        // (cannot set env var safely in parallel tests; just check parse)
        assert!(bench_scale() > 0.0);
    }

    #[test]
    fn bench_json_renders_valid_structure() {
        let mut j = BenchJson::new("unit");
        j.record("dense_dot", 800.0, 2e-6, 1e-6);
        j.record("sparse \"dot\"", 96.0, 1e-6, 1e-6);
        j.note("line1\nline2");
        let s = j.render();
        assert!(s.contains("\"bench\": \"unit\""));
        assert!(s.contains("\"dispatched_backend\""));
        assert!(s.contains("\"speedup\": 2.000000"), "{s}");
        assert!(s.contains("sparse \\\"dot\\\""), "escaped: {s}");
        assert!(s.contains("line1\\nline2"));
        assert!(!s.contains("\"serve\""), "no serve section unless set");
        // crude balance check on the hand-rolled renderer
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());

        j.set_serve(ServeRecord {
            qps: 1000.0,
            rows_per_sec: 64_000.0,
            p50_ms: 0.05,
            p95_ms: 0.20,
            p99_ms: 0.90,
            published: 3,
            rejected: 1,
            attempts: 4,
            ingest_dropped: 7,
            corpus_evicted: 12,
            corpus_peak: 96,
        });
        let s = j.render();
        assert!(s.contains("\"serve\": {\"qps\": 1000.000000"), "{s}");
        assert!(s.contains("\"published\": 3, \"rejected\": 1, \"attempts\": 4"), "{s}");
        assert!(
            s.contains("\"ingest_dropped\": 7, \"corpus_evicted\": 12, \"corpus_peak\": 96"),
            "{s}"
        );
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());

        j.add_convergence(ConvergenceRecord {
            engine: "st".into(),
            dataset: "tiny-lasso".into(),
            gap_target: 1.5e-3,
            epochs_to_target: Some(12),
            final_gap: 4.2e-4,
            epochs_run: 12,
        });
        j.add_convergence(ConvergenceRecord {
            engine: "cluster-k4".into(),
            dataset: "tiny-lasso".into(),
            gap_target: 1.5e-3,
            epochs_to_target: None,
            final_gap: f64::NAN,
            epochs_run: 500,
        });
        let s = j.render();
        assert!(s.contains("\"convergence\": ["), "{s}");
        assert!(s.contains("\"epochs_to_target\": 12"), "{s}");
        assert!(s.contains("\"epochs_to_target\": null"), "{s}");
        assert!(s.contains("\"gap_target\": 1.5e-3"), "{s}");
        assert!(s.contains("\"final_gap\": null"), "{s}");
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn kernel_record_throughput_math() {
        let r = KernelRecord {
            kernel: "k".into(),
            bytes_per_call: 1e9,
            scalar_secs: 1.0,
            dispatched_secs: 0.5,
        };
        assert!((r.scalar_gbs() - 1.0).abs() < 1e-9);
        assert!((r.dispatched_gbs() - 2.0).abs() < 1e-9);
        assert!((r.speedup() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dispatch_covers_all_solvers() {
        let g = bench_dataset(DatasetKind::Tiny, Family::Regression, 9);
        for s in ["A+B", "ST", "OMP", "OMP WILD", "PASSCoDe-atomic", "PASSCoDe-wild", "sgd"] {
            let mut m = bench_model("lasso", g.n());
            let mut cfg = bench_cfg(0.0, 5.0);
            cfg.max_epochs = 2;
            let r = run_solver(s, m.as_mut(), &g, &cfg);
            assert!(r.epochs >= 1, "{s}");
        }
    }
}
