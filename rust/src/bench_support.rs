//! Shared plumbing for the paper-reproduction bench harnesses
//! (`rust/benches/*`): dataset construction, solver dispatch, and
//! time-to-threshold extraction.  Not part of the training API.

use crate::coordinator::HthcConfig;
use crate::data::generator::{generate, DatasetKind, Family, GeneratedDataset};
use crate::data::Matrix;
use crate::glm::{GlmModel, Lasso, SvmDual};
use crate::memory::TierSim;
use crate::solver::{by_name, FitReport, Trainer};

/// Environment-tunable dataset scale so `cargo bench` stays minutes,
/// not hours, on small hosts (`HTHC_BENCH_SCALE`, default 1.0 applies
/// the per-bench baseline scales).
pub fn bench_scale() -> f64 {
    std::env::var("HTHC_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// The four Table-I analogues at bench scale.
pub fn bench_dataset(kind: DatasetKind, family: Family, seed: u64) -> GeneratedDataset {
    let base = match kind {
        DatasetKind::EpsilonLike => 0.35,
        DatasetKind::DvscLike => 0.3,
        DatasetKind::News20Like => 0.08,
        DatasetKind::CriteoLike => 0.05,
        DatasetKind::Tiny => 1.0,
    };
    generate(kind, family, base * bench_scale(), seed)
}

/// Model factory per paper experiment (lambdas follow Table II/III's
/// magnitudes, adjusted for the scaled data).
pub fn bench_model(model: &str, n: usize) -> Box<dyn GlmModel> {
    match model {
        "lasso" => Box::new(Lasso::new(0.3)),
        "svm" => Box::new(SvmDual::new(1e-3, n)),
        other => panic!("bench_model: {other}"),
    }
}

/// Relative initial objective for threshold scaling.
pub fn obj0(model: &dyn GlmModel, m: &Matrix, y: &[f32]) -> f64 {
    model
        .objective(&vec![0.0; m.n_rows()], y, &vec![0.0; m.n_cols()])
        .abs()
        .max(1.0)
}

/// Solver dispatch by the paper's names (and the CLI spellings) — a
/// thin veneer over [`crate::solver::by_name`]: all dispatch lives in
/// the solver layer.
pub fn run_solver(
    name: &str,
    model: &mut dyn GlmModel,
    data: &Matrix,
    y: &[f32],
    cfg: &HthcConfig,
) -> FitReport {
    let sim = TierSim::default();
    let solver = by_name(name).unwrap_or_else(|| panic!("run_solver: {name}"));
    Trainer::new()
        .solver_boxed(solver)
        .config(cfg.clone())
        .fit_with(model, data, y, &sim)
}

/// Default bench config (thread topology mirrors the paper's tables at
/// host scale).
pub fn bench_cfg(gap_tol: f64, timeout: f64) -> HthcConfig {
    HthcConfig {
        t_a: 2,
        t_b: 2,
        v_b: 1,
        batch_frac: 0.08,
        gap_tol,
        max_epochs: 100_000,
        timeout_secs: timeout,
        eval_every: 5,
        ..Default::default()
    }
}

/// Render "time to gap <= thr" for a set of thresholds.
pub fn times_to(res: &FitReport, obj0: f64, rels: &[f64]) -> Vec<Option<f64>> {
    rels.iter().map(|r| res.trace.time_to_gap(r * obj0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_default_is_one() {
        // (cannot set env var safely in parallel tests; just check parse)
        assert!(bench_scale() > 0.0);
    }

    #[test]
    fn dispatch_covers_all_solvers() {
        let g = bench_dataset(DatasetKind::Tiny, Family::Regression, 9);
        for s in ["A+B", "ST", "OMP", "OMP WILD", "PASSCoDe-atomic", "PASSCoDe-wild", "sgd"] {
            let mut m = bench_model("lasso", g.n());
            let mut cfg = bench_cfg(0.0, 5.0);
            cfg.max_epochs = 2;
            let r = run_solver(s, m.as_mut(), &g.matrix, &g.targets, &cfg);
            assert!(r.epochs >= 1, "{s}");
        }
    }
}
