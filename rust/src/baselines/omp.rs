//! OMP / OMP-WILD: the "straightforward implementation" comparator
//! (paper §V-B1, items 3-4).
//!
//! What a first-pass OpenMP port of HTHC looks like: the same two-task
//! algorithm expressed as flat `parallel for` loops — no thread pinning,
//! no persistent pools (threads are logically created per parallel
//! region: we model that by spawning scoped threads each region, which
//! is exactly the churn the paper's pool avoids), no chunk locks.
//! `v` updates use per-element atomics (`#pragma omp atomic`) in OMP
//! mode, or plain racy writes in WILD mode — which is faster but breaks
//! the primal-dual invariant `v = D alpha`, so WILD converges to a
//! *neighborhood* of the optimum and its computed "gap" is unreliable
//! (the paper's suboptimality plateaus, Fig. 5).

use crate::coordinator::SharedVector;
use crate::data::Matrix;
use crate::glm;
use crate::metrics::ConvergenceTrace;
use crate::solver::{keys, notify_epoch, EpochEvent, Extras, FitReport, Problem};
use crate::sync::{AtomicUsize, Ordering};
use crate::util::{Rng, Timer};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OmpMode {
    /// `#pragma omp atomic` on every v element update.
    Atomic,
    /// No synchronization at all (lost updates allowed).
    Wild,
}

/// The OMP engine loop over a [`Problem`] (entered via
/// [`crate::solver::Omp`]).  Uses the HTHC thread counts (`t_a` for the
/// gap loop, `t_b * v_b` flat threads for updates) so the comparison is
/// like-for-like in resources (§V-B1: "with the thread counts T_A, T_B
/// and V_B").
pub(crate) fn fit(p: &mut Problem<'_>, mode: OmpMode) -> FitReport {
    let cfg = p.cfg.clone();
    let data = p.data.matrix();
    let y = p.data.targets();
    let home = p.data.placement();
    let sim = p.sim;
    let mut on_epoch = p.on_epoch.take();
    let (alpha0, v0) = p.initial_state();
    let model = &mut *p.model;
    let (d, n) = (data.n_rows(), data.n_cols());
    let ops = data.as_block_ops();
    let v = SharedVector::from_slice(&v0, cfg.lock_chunk);
    let alpha = SharedVector::from_slice(&alpha0, usize::MAX >> 1);
    let m_batch = cfg.batch_size(n);
    let mut z = vec![f32::INFINITY; n];
    let mut rng = Rng::new(cfg.seed);
    let name = match mode {
        OmpMode::Atomic => "omp",
        OmpMode::Wild => "omp-wild",
    };
    let mut trace = ConvergenceTrace::new(name);
    let timer = Timer::start();
    let update_threads = cfg.t_b * cfg.v_b;
    let mut total_b = 0u64;
    let mut total_a = 0u64;
    let mut converged = false;
    let mut epochs = 0usize;

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        let alpha_snap = alpha.snapshot();
        model.epoch_refresh(&alpha_snap);
        let kind = model.kind();

        // --- "task B": parallel for over the selected batch -----------
        let batch = if epoch == 1 {
            rng.sample_distinct(n, m_batch)
        } else {
            crate::coordinator::selection::top_m(&z, m_batch)
        };
        let next = AtomicUsize::new(0);
        // OpenMP spawns its team per region; we mirror that churn with
        // scoped threads (the overhead the paper's pools avoid).
        std::thread::scope(|s| {
            for _ in 0..update_threads {
                s.spawn(|| loop {
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= batch.len() {
                        break;
                    }
                    let j = batch[k];
                    // naive: reload v for the whole dot, no working set
                    let u = match data {
                        Matrix::Dense(m) => {
                            let col = m.col(j);
                            v.dot_mapped_range(col, y, |vj, yj| kind.w_of(vj, yj), 0, d)
                        }
                        Matrix::Sparse(m) => {
                            let (rows, vals) = m.col(j);
                            v.dot_mapped_sparse(rows, vals, y, |vj, yj| kind.w_of(vj, yj))
                        }
                        Matrix::Quantized(m) => {
                            let col = m.col_dense(j);
                            v.dot_mapped_range(&col, y, |vj, yj| kind.w_of(vj, yj), 0, d)
                        }
                    };
                    let a = alpha.read(j);
                    let delta = kind.delta(u, a, ops.sq_norm(j));
                    if delta != 0.0 {
                        alpha.write(j, a + delta);
                        // per-element updates — atomic or wild
                        let sink = |r: usize, upd: f32| apply(&v, r, upd, mode);
                        match data {
                            Matrix::Dense(m) => {
                                crate::kernels::scaled_scatter(m.col(j), delta, sink);
                            }
                            Matrix::Sparse(m) => {
                                let (rows, vals) = m.col(j);
                                crate::kernels::scaled_scatter_sparse(rows, vals, delta, sink);
                            }
                            Matrix::Quantized(m) => {
                                crate::kernels::scaled_scatter(&m.col_dense(j), delta, sink);
                            }
                        }
                    }
                    sim.read(home, ops.col_bytes(j) * 2);
                });
            }
        });
        total_b += batch.len() as u64;

        // --- "task A": parallel for refreshing all gap values ---------
        // (the naive port recomputes the full z each epoch, serially
        // with respect to B — no concurrent heterogeneous tasks).  Each
        // worker drains its own shard of the tile scheduler (stealing
        // from the heaviest remainder) and computes each tile's dots in
        // one blocked pass over w (the §IV-A/IV-D sweep backend).
        let v_snap = v.snapshot();
        let mut w = vec![0.0f32; d];
        crate::kernels::map2_into(&mut w, &v_snap, y, |vj, yj| kind.w_of(vj, yj));
        let a_now = alpha.snapshot();
        let sched =
            crate::sched::TileScheduler::new(n, cfg.t_a.max(1), crate::kernels::BLOCK_COLS);
        // data plane (sync::raw): f32 bit cells, disjoint per-tile writes
        let z_cell: Vec<crate::sync::raw::AtomicU32> =
            (0..n).map(|_| crate::sync::raw::AtomicU32::new(0)).collect();
        std::thread::scope(|s| {
            for tid in 0..cfg.t_a.max(1) {
                let (sched, z_cell, w) = (&sched, &z_cell, &w);
                let a_now = &a_now;
                s.spawn(move || {
                    const B: usize = crate::kernels::BLOCK_COLS;
                    let mut idx = [0usize; B];
                    let mut u = [0.0f32; B];
                    let mut charges = crate::memory::ReadBatcher::new(sim, home);
                    while let Some(t) = sched.claim(tid) {
                        let m = t.len();
                        for (slot, j) in idx.iter_mut().zip(t.lo..t.hi) {
                            *slot = j;
                        }
                        ops.dots_block(&idx[..m], w, &mut u[..m]);
                        for (j, &uj) in (t.lo..t.hi).zip(&u) {
                            z_cell[j].store(kind.gap(uj, a_now[j]).to_bits(), Ordering::Relaxed);
                            charges.add(ops.col_bytes(j));
                        }
                    }
                });
            }
        });
        for (zj, cell) in z.iter_mut().zip(&z_cell) {
            *zj = f32::from_bits(cell.load(Ordering::Relaxed));
        }
        total_a += n as u64;

        if epoch % cfg.eval_every == 0 || epoch == cfg.max_epochs {
            let v_now = v.snapshot();
            let obj = model.objective(&v_now, y, &a_now);
            // NOTE: for WILD, v != D alpha, so this "gap" is the paper's
            // observation that OMP-WILD's gap readings are not true
            // certificates (they can undershoot the real suboptimality).
            let gap = glm::total_gap(model, ops, &v_now, y, &a_now);
            trace.push(timer.secs(), epoch, obj, gap);
            let stop_requested = notify_epoch(
                &mut on_epoch,
                &EpochEvent {
                    solver: name,
                    epoch,
                    wall_secs: timer.secs(),
                    objective: obj,
                    gap,
                    v: &v_now,
                    alpha: &a_now,
                },
            );
            if stop_requested {
                converged = true;
                break;
            }
            if gap <= cfg.gap_tol && mode == OmpMode::Atomic {
                converged = true;
                break;
            }
            if gap <= cfg.gap_tol && mode == OmpMode::Wild {
                // stop on the (unreliable) certificate as well, but do
                // not claim convergence unless v is actually consistent
                converged = false;
                break;
            }
        }
        if timer.secs() > cfg.timeout_secs {
            break;
        }
    }

    let mut extras = Extras::default();
    extras.set_f64(keys::REFRESH_FRAC, 1.0);
    extras.set_u64(keys::A_UPDATES, total_a);
    extras.set_u64(keys::B_UPDATES, total_b);
    extras.set_u64(keys::B_ZERO_DELTAS, 0);
    FitReport {
        solver: name,
        alpha: alpha.snapshot(),
        v: v.snapshot(),
        trace,
        epochs,
        converged,
        wall_secs: timer.secs(),
        phase_times: Default::default(),
        staleness: Default::default(),
        extras,
    }
}

#[inline]
fn apply(v: &SharedVector, r: usize, x: f32, mode: OmpMode) {
    match mode {
        OmpMode::Atomic => v.add_atomic(r, x),
        OmpMode::Wild => v.add_wild(r, x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::HthcConfig;
    use crate::data::{Dataset, DatasetKind, Family};
    use crate::glm::Lasso;
    use crate::memory::TierSim;
    use crate::solver::{Omp, Trainer};

    fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
        Dataset::generated(kind, family, scale, seed)
    }

    fn cfg(gap_tol: f64) -> HthcConfig {
        HthcConfig {
            t_a: 2,
            t_b: 2,
            v_b: 1,
            // the naive OMP port converges slowly with small batches
            // (that is the paper's point); give it a generous batch and
            // epoch budget so the *correctness* assertion is isolated
            // from the *performance* comparison (bench fig5 does that).
            batch_frac: 0.5,
            gap_tol,
            max_epochs: 500,
            timeout_secs: 30.0,
            eval_every: 2,
            ..Default::default()
        }
    }

    fn fit_omp(cfg: HthcConfig, model: &mut Lasso, g: &Dataset, wild: bool) -> FitReport {
        let sim = TierSim::default();
        Trainer::new()
            .solver(Omp { wild })
            .config(cfg)
            .fit_with(model, g, &sim)
    }

    #[test]
    fn omp_atomic_converges_and_v_consistent() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 131);
        let mut model = Lasso::new(0.5);
        let obj0 = model.objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; g.n()]);
        let tol = 1e-4 * obj0.abs().max(1.0);
        let res = fit_omp(cfg(tol), &mut model, &g, false);
        assert!(res.converged, "{}", res.summary());
        let v2 = match g.matrix() {
            Matrix::Dense(m) => m.matvec_alpha(&res.alpha),
            _ => unreachable!(),
        };
        for (a, b) in res.v.iter().zip(&v2) {
            assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "atomic keeps v = D alpha");
        }
    }

    #[test]
    fn omp_wild_objective_still_decreases() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 132);
        let mut model = Lasso::new(0.5);
        let res = fit_omp(cfg(1e-5), &mut model, &g, true);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(last < first, "wild still optimizes approximately");
        // wild never *claims* convergence
        assert!(!res.converged);
    }
}
