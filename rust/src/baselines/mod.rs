//! Reference implementations the paper benchmarks against (§V-B/C):
//!
//! * [`st`] — **ST**: homogeneous single-task parallel async SCD over
//!   *all* coordinates each epoch (same low-level machinery as task B,
//!   no duality-gap selection).
//! * [`omp`] — **OMP** / **OMP WILD**: the "straightforward looped C
//!   code with OpenMP directives" comparator — a flat parallel-for with
//!   per-element atomic (or racy-wild) updates of `v`, no working set,
//!   no thread roles, no chunk locks.
//! * [`passcode`] — **PASSCoDe-atomic / -wild** (Hsieh et al. [16]):
//!   asynchronous dual SCD keeping `v` in memory, per-element atomics or
//!   lock-free writes.
//! * [`sgd`] — a Vowpal-Wabbit-style SGD comparator for the Lasso runs
//!   of Table V (VW does not implement CD; the paper uses its SGD).

//! All four run through the unified [`crate::solver`] API
//! ([`crate::solver::SeqThreshold`], [`crate::solver::Omp`],
//! [`crate::solver::Passcode`], [`crate::solver::Sgd`]).  The old
//! `train_*` free-function shims served their one deprecation release
//! and are gone.

pub mod omp;
pub mod passcode;
pub mod sgd;
pub mod st;

pub use omp::OmpMode;
pub use passcode::PasscodeMode;
