//! PASSCoDe (Hsieh et al., ICML'15 — paper ref [16]): parallel
//! asynchronous stochastic dual coordinate descent, the state-of-the-art
//! comparator of Table IV.
//!
//! PASSCoDe keeps the shared vector `v` in memory and updates it either
//! with per-element atomic adds (**PASSCoDe-atomic**, maintains
//! `v = D alpha`) or entirely lock-free (**PASSCoDe-wild**, faster but
//! converges to a perturbed solution).  No coordinate selection, no
//! working set, no heterogeneous tasks: all threads hammer random
//! coordinates of the full problem — each coordinate once per epoch
//! (random permutation split across threads), as in the original.
//!
//! Table IV benches SVM (PASSCoDe "does not support Lasso"); the
//! implementation is model-generic anyway, keyed off [`crate::glm`].

use crate::coordinator::SharedVector;
use crate::data::Matrix;
use crate::glm;
use crate::metrics::ConvergenceTrace;
use crate::solver::{keys, notify_epoch, EpochEvent, Extras, FitReport, Problem};
use crate::sync::{AtomicUsize, Ordering};
use crate::util::{Rng, Timer};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PasscodeMode {
    Atomic,
    Wild,
}

/// The PASSCoDe engine loop over a [`Problem`] (entered via
/// [`crate::solver::Passcode`]).  Uses `cfg.t_b` threads (T_B in
/// Table IV); stops on `gap_tol` / `max_epochs` / `timeout_secs` or the
/// problem's epoch observer (the Table IV time-to-accuracy probe).
pub(crate) fn fit(p: &mut Problem<'_>, mode: PasscodeMode) -> FitReport {
    let cfg = p.cfg.clone();
    let data = p.data.matrix();
    let y = p.data.targets();
    let home = p.data.placement();
    let sim = p.sim;
    let mut on_epoch = p.on_epoch.take();
    let (alpha0, v0) = p.initial_state();
    let model = &mut *p.model;
    let (d, n) = (data.n_rows(), data.n_cols());
    let ops = data.as_block_ops();
    let v = SharedVector::from_slice(&v0, cfg.lock_chunk);
    let alpha = SharedVector::from_slice(&alpha0, usize::MAX >> 1);
    let threads = cfg.t_b.max(1);
    let mut rng = Rng::new(cfg.seed);
    let mut order: Vec<usize> = (0..n).collect();
    let name = match mode {
        PasscodeMode::Atomic => "passcode-atomic",
        PasscodeMode::Wild => "passcode-wild",
    };
    let mut trace = ConvergenceTrace::new(name);
    let timer = Timer::start();
    let mut total = 0u64;
    let mut zeros = 0u64;
    let mut converged = false;
    let mut epochs = 0usize;

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        let alpha_snap = alpha.snapshot();
        model.epoch_refresh(&alpha_snap);
        let kind = model.kind();
        rng.shuffle(&mut order);
        let next = AtomicUsize::new(0);
        let zero_ctr = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= n {
                        break;
                    }
                    let j = order[k];
                    let u = match data {
                        Matrix::Dense(m) => {
                            v.dot_mapped_range(m.col(j), y, |vj, yj| kind.w_of(vj, yj), 0, d)
                        }
                        Matrix::Sparse(m) => {
                            let (rows, vals) = m.col(j);
                            v.dot_mapped_sparse(rows, vals, y, |vj, yj| kind.w_of(vj, yj))
                        }
                        Matrix::Quantized(m) => {
                            let col = m.col_dense(j);
                            v.dot_mapped_range(&col, y, |vj, yj| kind.w_of(vj, yj), 0, d)
                        }
                    };
                    let a = alpha.read(j);
                    let delta = kind.delta(u, a, ops.sq_norm(j));
                    if delta == 0.0 {
                        zero_ctr.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    alpha.write(j, a + delta);
                    let sink = |r: usize, upd: f32| apply(&v, r, upd, mode);
                    match data {
                        Matrix::Dense(m) => {
                            crate::kernels::scaled_scatter(m.col(j), delta, sink);
                        }
                        Matrix::Sparse(m) => {
                            let (rows, vals) = m.col(j);
                            crate::kernels::scaled_scatter_sparse(rows, vals, delta, sink);
                        }
                        Matrix::Quantized(m) => {
                            crate::kernels::scaled_scatter(&m.col_dense(j), delta, sink);
                        }
                    }
                    sim.read(home, ops.col_bytes(j) * 2);
                });
            }
        });
        total += n as u64;
        zeros += zero_ctr.load(Ordering::Relaxed) as u64;

        if epoch % cfg.eval_every == 0 || epoch == cfg.max_epochs {
            let a_now = alpha.snapshot();
            let v_now = v.snapshot();
            let obj = model.objective(&v_now, y, &a_now);
            let gap = glm::total_gap(model, ops, &v_now, y, &a_now);
            trace.push(timer.secs(), epoch, obj, gap);
            let stop_requested = notify_epoch(
                &mut on_epoch,
                &EpochEvent {
                    solver: name,
                    epoch,
                    wall_secs: timer.secs(),
                    objective: obj,
                    gap,
                    v: &v_now,
                    alpha: &a_now,
                },
            );
            if stop_requested {
                converged = true;
                break;
            }
            if gap <= cfg.gap_tol && mode == PasscodeMode::Atomic {
                converged = true;
                break;
            }
        }
        if timer.secs() > cfg.timeout_secs {
            break;
        }
    }

    let mut extras = Extras::default();
    extras.set_f64(keys::REFRESH_FRAC, 1.0);
    extras.set_u64(keys::A_UPDATES, 0);
    extras.set_u64(keys::B_UPDATES, total - zeros);
    extras.set_u64(keys::B_ZERO_DELTAS, zeros);
    FitReport {
        solver: name,
        alpha: alpha.snapshot(),
        v: v.snapshot(),
        trace,
        epochs,
        converged,
        wall_secs: timer.secs(),
        phase_times: Default::default(),
        staleness: Default::default(),
        extras,
    }
}

#[inline]
fn apply(v: &SharedVector, r: usize, x: f32, mode: PasscodeMode) {
    match mode {
        PasscodeMode::Atomic => v.add_atomic(r, x),
        PasscodeMode::Wild => v.add_wild(r, x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::HthcConfig;
    use crate::data::{Dataset, DatasetKind, Family};
    use crate::glm::SvmDual;
    use crate::memory::TierSim;
    use crate::solver::{Passcode, Trainer};

    fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
        Dataset::generated(kind, family, scale, seed)
    }

    fn cfg() -> HthcConfig {
        HthcConfig {
            t_b: 2,
            gap_tol: 1e-6,
            max_epochs: 100,
            timeout_secs: 30.0,
            eval_every: 2,
            ..Default::default()
        }
    }

    #[test]
    fn passcode_atomic_reaches_accuracy() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 141);
        let mut model = SvmDual::new(1e-3, g.n());
        let sim = TierSim::default();
        let target = 0.95;
        // the Table IV time-to-accuracy probe: the engine-agnostic
        // Trainer::on_epoch observer stops the run
        let res = Trainer::new()
            .solver(Passcode { mode: PasscodeMode::Atomic })
            .config(cfg())
            .on_epoch(|ev| {
                let ops = g.as_ops();
                let correct = (0..g.n()).filter(|&j| ops.dot(j, ev.v) > 0.0).count();
                correct as f64 / g.n() as f64 >= target
            })
            .fit_with(&mut model, &g, &sim);
        assert!(res.converged, "{}", res.summary());
    }

    #[test]
    fn passcode_wild_still_optimizes() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 142);
        let mut model = SvmDual::new(1e-3, g.n());
        let sim = TierSim::default();
        let res = Trainer::new()
            .solver(Passcode { mode: PasscodeMode::Wild })
            .config(cfg())
            .fit_with(&mut model, &g, &sim);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(last < first);
    }

    #[test]
    fn alpha_stays_in_box() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 143);
        let mut model = SvmDual::new(1e-2, g.n());
        let sim = TierSim::default();
        let mut c = cfg();
        c.max_epochs = 10;
        let res = Trainer::new()
            .solver(Passcode { mode: PasscodeMode::Atomic })
            .config(c)
            .fit_with(&mut model, &g, &sim);
        assert!(res.alpha.iter().all(|&a| (-1e-6..=1.0 + 1e-6).contains(&a)));
    }
}
