//! SGD baseline for Table V (Vowpal Wabbit stand-in).
//!
//! "Since VW does not implement coordinate descent, we opt for
//! stochastic gradient descent" (§V-C).  This is primal SGD on
//! `1/2 ||X beta - t||^2 + lam ||beta||_1` over *rows* (samples) of the
//! regression problem — the row-access pattern VW uses, which is why the
//! column-oriented CSC matrix must first be transposed into sample rows
//! (also mirrors VW's "previously cached data" preprocessing step).
//!
//! Learning rate follows VW's default-ish `eta / (1 + eta lam t)^p`
//! power decay; L1 is applied via truncated gradient (Langford et al.),
//! the scheme VW uses for `--l1`.

use crate::data::Matrix;
use crate::glm::soft_threshold;
use crate::metrics::ConvergenceTrace;
use crate::solver::{keys, notify_epoch, EpochEvent, Extras, FitReport, Problem};
use crate::util::{Rng, Timer};

/// Row view of a column-oriented matrix: samples as (indices, values).
pub struct RowCache {
    pub rows: Vec<Vec<(u32, f32)>>,
    pub n_features: usize,
}

impl RowCache {
    pub fn build(data: &Matrix) -> Self {
        let (d, n) = (data.n_rows(), data.n_cols());
        let mut rows: Vec<Vec<(u32, f32)>> = vec![Vec::new(); d];
        match data {
            Matrix::Dense(m) => {
                for j in 0..n {
                    for (r, &x) in m.col(j).iter().enumerate() {
                        if x != 0.0 {
                            rows[r].push((j as u32, x));
                        }
                    }
                }
            }
            Matrix::Sparse(m) => {
                for j in 0..n {
                    let (ridx, vals) = m.col(j);
                    for (&r, &x) in ridx.iter().zip(vals) {
                        rows[r as usize].push((j as u32, x));
                    }
                }
            }
            Matrix::Quantized(m) => {
                for j in 0..n {
                    for (r, &x) in m.col_dense(j).iter().enumerate() {
                        if x != 0.0 {
                            rows[r].push((j as u32, x));
                        }
                    }
                }
            }
        }
        RowCache { rows, n_features: n }
    }

    /// Row-wise predictions `X beta` (VW's progressive-validation pass).
    /// The MSE itself goes through
    /// [`crate::serve::predict::mean_squared_error`] — the consolidated
    /// predict seam — rather than a private duplicate.
    pub fn predictions(&self, beta: &[f32]) -> Vec<f32> {
        self.rows
            .iter()
            .map(|row| crate::kernels::pair_dot(row, beta))
            .collect()
    }
}

/// The SGD engine loop over a [`Problem`] (entered via
/// [`crate::solver::Sgd`]).  Ignores the problem's GLM model; the
/// report's `alpha` holds the primal weights `beta` and `v` the final
/// predictions `X beta`.  `cfg.t_b` is accepted for API symmetry but
/// SGD here is sequential — VW's single-node learner is too (its
/// parallelism is across nodes, and the paper uses few nodes / one node
/// for the dense sets).
pub(crate) fn fit(p: &mut Problem<'_>, lam: f32, mse_target: f64) -> FitReport {
    let cfg = p.cfg.clone();
    let data = p.data.matrix();
    let targets = p.data.targets();
    let mut on_epoch = p.on_epoch.take();
    // warm start: alpha doubles as beta for the primal solver.  Taken
    // directly (not via initial_state) — SGD has no shared vector to
    // seed, so deriving v = D alpha here would be a wasted matvec.
    let n = data.n_cols();
    let mut beta = match p.warm_alpha.take() {
        Some(a) => {
            assert_eq!(a.len(), n, "warm-start alpha length must equal n_cols");
            a
        }
        None => vec![0.0f32; n],
    };
    let cache = RowCache::build(data);
    debug_assert_eq!(beta.len(), cache.n_features);
    let mut rng = Rng::new(cfg.seed);
    let mut order: Vec<usize> = (0..cache.rows.len()).collect();
    let mut trace = ConvergenceTrace::new("sgd");
    let timer = Timer::start();
    let eta0 = 0.5f32;
    let mut t = 0u64;
    let mut epochs = 0usize;
    let mut converged = false;
    let mut last_mse = f64::NAN;

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        rng.shuffle(&mut order);
        for &r in &order {
            t += 1;
            let row = &cache.rows[r];
            let pred = crate::kernels::pair_dot(row, &beta);
            let err = pred - targets[r];
            let eta = eta0 / (1.0 + eta0 * 0.01 * t as f32).sqrt();
            // row norm-normalized step (VW normalizes by feature scale)
            let row_sq = crate::kernels::pair_sq_norm(row).max(1e-6);
            let step = eta * err / row_sq;
            for &(j, x) in row {
                let bj = &mut beta[j as usize];
                *bj -= step * x;
                // truncated-gradient L1 (VW --l1)
                *bj = soft_threshold(*bj, eta * lam);
            }
        }
        // evaluation cadence follows cfg.eval_every like every other
        // engine (MSE, trace, observer and the mse_target stop all
        // happen at evaluation epochs only)
        if epoch % cfg.eval_every == 0 || epoch == cfg.max_epochs {
            // one row-wise prediction pass serves both the MSE (through
            // the consolidated serve::predict seam) and the event's v
            let preds = cache.predictions(&beta);
            let mse = crate::serve::predict::mean_squared_error(&preds, targets);
            trace.push(timer.secs(), epoch, mse, f64::NAN);
            last_mse = mse;
            let stop_requested = notify_epoch(
                &mut on_epoch,
                &EpochEvent {
                    solver: "sgd",
                    epoch,
                    wall_secs: timer.secs(),
                    objective: mse,
                    gap: f64::NAN,
                    v: &preds,
                    alpha: &beta,
                },
            );
            if stop_requested || mse <= mse_target {
                converged = true;
                break;
            }
        }
        if timer.secs() > cfg.timeout_secs {
            break;
        }
    }

    let mut extras = Extras::default();
    extras.set_f64(keys::FINAL_MSE, last_mse);
    let v = data.matvec_alpha(&beta);
    FitReport {
        solver: "sgd",
        alpha: beta,
        v,
        trace,
        epochs,
        converged,
        wall_secs: timer.secs(),
        phase_times: Default::default(),
        staleness: Default::default(),
        extras,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::HthcConfig;
    use crate::data::{Dataset, DatasetKind, Family};
    use crate::memory::TierSim;
    use crate::solver::{Sgd, Trainer};

    fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
        Dataset::generated(kind, family, scale, seed)
    }

    /// Run the SGD engine through the Trainer facade; the problem's GLM
    /// model is ignored by SGD (lam comes from the Sgd struct).
    fn fit_sgd(g: &Dataset, lam: f32, mse_target: f64, max_epochs: usize) -> FitReport {
        let sim = TierSim::default();
        let mut model = crate::glm::Lasso::new(lam);
        Trainer::new()
            .solver(Sgd { lam, mse_target })
            .config(HthcConfig { max_epochs, timeout_secs: 20.0, ..Default::default() })
            .fit_with(&mut model, g, &sim)
    }

    #[test]
    fn row_cache_matches_matrix() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 151);
        let cache = RowCache::build(g.matrix());
        assert_eq!(cache.rows.len(), g.d());
        assert_eq!(cache.n_features, g.n());
        // reconstruct one column from rows
        if let Matrix::Dense(m) = g.matrix() {
            let j = 3usize;
            for (r, &x) in m.col(j).iter().enumerate() {
                let got = cache.rows[r]
                    .iter()
                    .find(|&&(jj, _)| jj as usize == j)
                    .map(|&(_, v)| v)
                    .unwrap_or(0.0);
                assert_eq!(got, x);
            }
        }
    }

    #[test]
    fn sgd_reduces_mse() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 152);
        let res = fit_sgd(&g, 1e-4, 0.0, 60);
        let first = res.trace.points.first().unwrap().objective;
        let last = res.trace.final_objective().unwrap();
        assert!(last < first * 0.5, "MSE {first} -> {last}");
        assert_eq!(res.alpha.len(), g.n(), "alpha carries the primal beta");
    }

    #[test]
    fn mse_target_stops_early() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 153);
        let res = fit_sgd(&g, 1e-4, 1e9, 1000);
        assert_eq!(res.trace.points.len(), 1, "target met after first epoch");
        assert!(res.converged);
    }
}
