//! ST: the single-task homogeneous baseline (paper §V-B1).
//!
//! "A parallel, but homogeneous single task implementation, which
//! allocates the data matrix D to DRAM and the remaining data to
//! MCDRAM.  It performs randomized asynchronous SCD [with] the same
//! low-level optimizations as task B but without duality-gap-based
//! coordinate selection: in each epoch we update v, alpha for all
//! coordinates of D."
//!
//! Notably ST *skips* the `v += delta d_i` write when `delta == 0` —
//! the effect that lets ST win on criteo-like sparse data (§V-B2).

use crate::coordinator::{task_b, SharedVector, WorkingSet};
use crate::glm;
use crate::metrics::ConvergenceTrace;
use crate::solver::{keys, notify_epoch, EpochEvent, Extras, FitReport, Problem};
use crate::threadpool::WorkerPool;
use crate::util::{Rng, Timer};

/// The ST engine loop over a [`Problem`] (entered via
/// [`crate::solver::SeqThreshold`]).  Uses `cfg.t_b`, `cfg.v_b`,
/// `cfg.gap_tol`, `cfg.max_epochs`, `cfg.timeout_secs`, `cfg.lock_chunk`;
/// `t_a`, `batch_frac` and `selection` are ignored (there is no task A).
pub(crate) fn fit(p: &mut Problem<'_>) -> FitReport {
    let cfg = p.cfg.clone();
    let data = p.data.matrix();
    let y = p.data.targets();
    let home = p.data.placement();
    let sim = p.sim;
    let mut on_epoch = p.on_epoch.take();
    let (alpha0, v0) = p.initial_state();
    let model = &mut *p.model;
    let n = data.n_cols();
    let v = SharedVector::from_slice(&v0, cfg.lock_chunk);
    let alpha = SharedVector::from_slice(&alpha0, usize::MAX >> 1);
    let pool = WorkerPool::with_name(cfg.t_b * cfg.v_b, "st");
    let mut rng = Rng::new(cfg.seed);
    let mut trace = ConvergenceTrace::new("st");
    let timer = Timer::start();

    // ST processes all of D every epoch; its "working set" is the whole
    // matrix referenced in place.  For the dense/sparse representations
    // we still go through WorkingSet so the inner loops are identical to
    // task B's — the full index set is swapped in once (the paper's ST
    // keeps D in DRAM; v/alpha in MCDRAM, which TierSim reflects by the
    // per-update charges inside task_b::run_epoch).  Group claiming
    // inside run_epoch goes through the shard-pinned TileScheduler, so
    // ST's full sweep inherits the same stealing as HTHC's batches.
    let all: Vec<usize> = (0..n).collect();
    let mut ws = WorkingSet::new(data, n);
    ws.swap_in(data, &all, sim, home);

    let mut order: Vec<usize> = (0..n).collect();
    let mut total_b = 0u64;
    let mut total_zero = 0u64;
    let mut converged = false;
    let mut epochs = 0usize;

    for epoch in 1..=cfg.max_epochs {
        epochs = epoch;
        let alpha_snap = alpha.snapshot();
        model.epoch_refresh(&alpha_snap);
        let kind = model.kind();
        rng.shuffle(&mut order);
        // slot == coordinate for the resident full matrix; only the
        // processing order is shuffled.
        let items = task_b::WorkItem::from_resident_order(&order);
        let stats = task_b::run_epoch(
            &pool, &ws, &items, &v, y, &alpha, kind, cfg.t_b, cfg.v_b, sim,
        );
        total_b += stats.updates;
        total_zero += stats.zero_deltas;

        if epoch % cfg.eval_every == 0 || epoch == cfg.max_epochs {
            let a_now = alpha.snapshot();
            // re-anchor v (see HthcSolver: fp32 drift floors the gap)
            let v_now = data.matvec_alpha(&a_now);
            v.store_all(&v_now);
            let obj = model.objective(&v_now, y, &a_now);
            let gap = glm::total_gap(model, data.as_block_ops(), &v_now, y, &a_now);
            trace.push(timer.secs(), epoch, obj, gap);
            let stop_requested = notify_epoch(
                &mut on_epoch,
                &EpochEvent {
                    solver: "st",
                    epoch,
                    wall_secs: timer.secs(),
                    objective: obj,
                    gap,
                    v: &v_now,
                    alpha: &a_now,
                },
            );
            if stop_requested || gap <= cfg.gap_tol {
                converged = true;
                break;
            }
        }
        if timer.secs() > cfg.timeout_secs {
            break;
        }
    }

    let mut extras = Extras::default();
    extras.set_f64(keys::REFRESH_FRAC, 1.0); // every coordinate, every epoch
    extras.set_u64(keys::A_UPDATES, 0);
    extras.set_u64(keys::B_UPDATES, total_b);
    extras.set_u64(keys::B_ZERO_DELTAS, total_zero);
    FitReport {
        solver: "st",
        alpha: alpha.snapshot(),
        v: v.snapshot(),
        trace,
        epochs,
        converged,
        wall_secs: timer.secs(),
        phase_times: Default::default(),
        staleness: Default::default(),
        extras,
    }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::HthcConfig;
    use crate::data::{Dataset, DatasetKind, Family};
    use crate::glm::{GlmModel, Lasso, SvmDual};
    use crate::memory::TierSim;
    use crate::solver::{FitReport, SeqThreshold, Trainer};

    fn generate(kind: DatasetKind, family: Family, scale: f64, seed: u64) -> Dataset {
        Dataset::generated(kind, family, scale, seed)
    }

    fn cfg(gap_tol: f64) -> HthcConfig {
        HthcConfig {
            t_b: 2,
            v_b: 1,
            gap_tol,
            max_epochs: 200,
            timeout_secs: 30.0,
            eval_every: 3,
            ..Default::default()
        }
    }

    /// Run the ST engine through the Trainer facade.
    fn fit_st(cfg: HthcConfig, model: &mut dyn GlmModel, g: &Dataset) -> FitReport {
        let sim = TierSim::default();
        Trainer::new()
            .solver(SeqThreshold)
            .config(cfg)
            .fit_with(model, g, &sim)
    }

    /// Relative tolerance (see coordinator::hthc tests).
    fn rel_tol(model: &dyn crate::glm::GlmModel, g: &Dataset, rel: f64) -> f64 {
        let obj0 = model.objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; g.n()]);
        rel * obj0.abs().max(1.0)
    }

    #[test]
    fn st_converges_lasso_dense() {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 121);
        let mut model = Lasso::new(0.5);
        let tol = rel_tol(&model, &g, 1e-4);
        let res = fit_st(cfg(tol), &mut model, &g);
        assert!(res.converged, "{}", res.summary());
        // every coordinate processed every epoch
        assert_eq!(
            res.b_updates() + res.b_zero_deltas(),
            (res.epochs * g.n()) as u64
        );
    }

    #[test]
    fn st_converges_svm() {
        let g = generate(DatasetKind::Tiny, Family::Classification, 1.0, 122);
        let mut model = SvmDual::new(1e-3, g.n());
        let res = fit_st(cfg(1e-4), &mut model, &g);
        assert!(res.trace.final_gap().unwrap() < 1e-3, "{}", res.summary());
    }

    #[test]
    fn st_zero_delta_skipping_on_sparse_lasso() {
        // with strong L1 most coordinates stay at zero -> many skipped
        // axpys: the criteo effect (§V-B2).
        let g = generate(DatasetKind::News20Like, Family::Regression, 0.03, 123);
        let mut model = Lasso::new(5.0);
        let mut c = cfg(0.0);
        c.max_epochs = 5;
        let res = fit_st(c, &mut model, &g);
        assert!(
            res.b_zero_deltas() > res.b_updates(),
            "strong L1 should skip most: {} zero vs {} real",
            res.b_zero_deltas(),
            res.b_updates()
        );
    }
}
