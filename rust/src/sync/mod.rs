//! Concurrency shim: the one place the crate touches `std::sync`.
//!
//! Every protocol atomic, mutex and spin loop in the crate goes through
//! this module instead of `std` directly (enforced by
//! `tools/lint_invariants.py`).  In a normal build the module is a pure
//! re-export — zero cost, byte-identical codegen.  Under
//! `--cfg pallas_model_check` the same names resolve to instrumented
//! versions from [`model`], driven by a deterministic scheduler that
//! explores thread interleavings exhaustively (bounded DFS) or by
//! seeded random sampling, and reports an operation trace when an
//! invariant breaks.  See `rust/DESIGN.md` §12.
//!
//! # Usage rules
//!
//! * **Protocol state** — atomics and locks whose *ordering* encodes a
//!   hand-shake (stamps, pin counts, cursors, generations, publish
//!   words) — imports from `crate::sync::{...}` so the model checker
//!   can interleave every access.
//! * **Data-plane state** — bulk storage where atomics only provide
//!   word-atomicity for HOGWILD arithmetic (`SharedVector` bits, the
//!   kernel backend byte, baseline scratch cells) — imports from
//!   [`raw`], which is always the `std` type.  This keeps the
//!   `&[AtomicU32]` kernel signatures identical in both builds and
//!   keeps the model's state space focused on control words.
//! * **Spin/yield** — every busy-wait uses [`spin::SpinWait`] (or the
//!   free functions) so the model can deprioritize spinners instead of
//!   exploring unbounded spin interleavings, and so release builds
//!   share one bounded spin-then-yield discipline.

#[cfg(pallas_model_check)]
pub mod model;

#[cfg(not(pallas_model_check))]
pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
#[cfg(not(pallas_model_check))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(pallas_model_check)]
pub use model::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard};

pub use std::sync::atomic::Ordering;

/// Always-`std` atomics for data-plane storage (see module docs): the
/// shared model vector's `f32` bit cells, the kernel dispatch byte and
/// baseline scratch arrays.  These stay uninstrumented even under the
/// model checker — their races are benign-by-design HOGWILD arithmetic
/// (word-atomic, last-writer-wins), not protocol hand-shakes, and the
/// atomic-slice kernels keep one signature across both builds.
pub mod raw {
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
}

/// Spin-wait discipline: bounded spin-then-yield, model-check aware.
pub mod spin {
    /// One polite busy-wait pause (a PAUSE-class hint).  Under the
    /// model checker this is a scheduler yield point that marks the
    /// thread as spinning, so exploration deprioritizes it until
    /// another thread makes progress.
    #[inline]
    pub fn spin_loop() {
        #[cfg(pallas_model_check)]
        super::model::spin_yield();
        #[cfg(not(pallas_model_check))]
        std::hint::spin_loop();
    }

    /// Give up the rest of the timeslice (`std::thread::yield_now`).
    /// Same model-check semantics as [`spin_loop`].
    #[inline]
    pub fn yield_now() {
        #[cfg(pallas_model_check)]
        super::model::spin_yield();
        #[cfg(not(pallas_model_check))]
        std::thread::yield_now();
    }

    /// How many [`spin_loop`] pauses a [`SpinWait`] issues before it
    /// starts yielding the timeslice.  The spin window covers waits a
    /// few instructions wide (a racing publish, a barrier straggler on
    /// its way in); past it the waiter must yield so a preempted peer
    /// can run — a pure spin deadlocks on one core.
    pub const SPIN_BUDGET: u32 = 64;

    /// Bounded spin-then-yield helper: `spin()` pauses for the first
    /// [`SPIN_BUDGET`] calls, then yields the timeslice on every call
    /// after that.  One `SpinWait` per wait loop; `reset()` re-arms the
    /// budget when the same loop waits for logically distinct events.
    #[derive(Default)]
    pub struct SpinWait {
        spins: u32,
    }

    impl SpinWait {
        pub const fn new() -> Self {
            SpinWait { spins: 0 }
        }

        /// One wait step: PAUSE while under budget, yield past it.
        #[inline]
        pub fn spin(&mut self) {
            if self.spins < SPIN_BUDGET {
                self.spins += 1;
                spin_loop();
            } else {
                yield_now();
            }
        }

        /// Re-arm the spin budget.
        #[inline]
        pub fn reset(&mut self) {
            self.spins = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::spin::SpinWait;
    use super::{AtomicU64, Ordering};

    #[test]
    fn shim_atomics_behave_like_std() {
        let a = AtomicU64::new(3);
        assert_eq!(a.fetch_add(4, Ordering::SeqCst), 3);
        assert_eq!(a.load(Ordering::SeqCst), 7);
        a.store(1, Ordering::Release);
        assert_eq!(a.swap(9, Ordering::AcqRel), 1);
    }

    #[test]
    fn spin_wait_crosses_its_budget() {
        let mut sw = SpinWait::new();
        for _ in 0..(super::spin::SPIN_BUDGET + 8) {
            sw.spin();
        }
        sw.reset();
        sw.spin();
    }
}
