//! Deterministic interleaving explorer (a miniature loom) behind
//! `--cfg pallas_model_check`.
//!
//! # How it works
//!
//! A *check* runs one scenario body many times.  Each run (an
//! *execution*) creates threads via [`spawn`]; the scheduler holds a
//! single run token, so exactly one thread executes at a time and every
//! instrumented operation — atomic load/store/RMW, mutex lock/unlock,
//! condvar wait/notify, spin yield — is a *scheduling point* where the
//! token may move.  The choice of which thread runs next is what the
//! explorer enumerates:
//!
//! * **DFS** (`max_executions` bound): replay the previous execution's
//!   choice prefix, increment the deepest choice that still has an
//!   untried alternative, run to completion.  When the prefix space is
//!   exhausted the check is *complete* — every schedule of the scenario
//!   (at sequential-consistency granularity) was seen.
//! * **Random** (`random_executions`, seeded LCG): uniform choice at
//!   every scheduling point; reproducible from the seed.
//!
//! Spin loops would make the schedule tree infinite, so [`spin_yield`]
//! marks the caller *spinning*: a spinning thread is only scheduled
//! when no non-spinning thread is runnable, and every state-changing
//! operation re-arms all spinners.  A window where every live thread is
//! spinning and nothing changes is reported as a livelock, as is
//! exceeding the per-execution operation budget.  Blocked-thread cycles
//! are reported as deadlocks.  Any panic in the scenario (a failed
//! assertion, a torn read) aborts the execution and surfaces as
//! [`Failure`] carrying the operation trace that led there.
//!
//! # Limitations
//!
//! Exploration is at sequential-consistency granularity: orderings are
//! recorded in the trace but weaker-than-SeqCst effects (store
//! buffering, reordering) are not simulated.  Torn *protocol* states —
//! the bugs this crate has actually had — are visible at this
//! granularity; weak-memory bugs are delegated to the TSan/Miri CI
//! jobs.  Uninstrumented shared state (e.g. `Arc` refcounts,
//! `sync::raw` atomics) does not create scheduling points.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar as StdCondvar, LockResult, Mutex as StdMutex, OnceLock, PoisonError};

// ---------------------------------------------------------------------------
// Public check API
// ---------------------------------------------------------------------------

/// Exploration budget and strategy for one [`check`].
#[derive(Clone, Debug)]
pub struct Config {
    /// DFS execution bound (0 skips the DFS phase).  If DFS finishes
    /// the whole space under this bound the report says `complete` and
    /// the random phase is skipped.
    pub max_executions: usize,
    /// Random-schedule executions appended after an incomplete DFS.
    pub random_executions: usize,
    /// Seed for the random phase (execution `i` uses `seed + i`).
    pub seed: u64,
    /// Per-execution scheduling-point budget; exceeding it fails the
    /// check as a livelock with the trailing trace.
    pub max_ops: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { max_executions: 2000, random_executions: 1000, seed: 0x5eed, max_ops: 50_000 }
    }
}

/// What a successful [`check`] explored.
#[derive(Clone, Debug)]
pub struct Report {
    /// Executions run across both phases.
    pub executions: usize,
    /// DFS exhausted the schedule space (every interleaving was seen).
    pub complete: bool,
}

/// A failing interleaving: what broke and the schedule that got there.
#[derive(Debug)]
pub struct Failure {
    /// Panic message, deadlock or livelock description.
    pub message: String,
    /// 1-based execution index that failed (reproducible: DFS is
    /// deterministic and random execution `i` reseeds from the config).
    pub execution: usize,
    /// Most recent scheduling-point events, oldest first; entries are
    /// `T<thread> <object>.<op>(<args>) [-> <result>]`.
    pub trace: Vec<String>,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "model check failed on execution {}: {}", self.execution, self.message)?;
        writeln!(f, "interleaving trace ({} events):", self.trace.len())?;
        for line in &self.trace {
            writeln!(f, "  {line}")?;
        }
        Ok(())
    }
}

/// Explore interleavings of `body` under `cfg`.  The body runs once per
/// execution on the calling thread (model thread `T0`), spawning peers
/// with [`spawn`]; it must create all shared state fresh inside the
/// closure so every execution starts identical.  Returns the first
/// failing interleaving, or a report of how much was explored.
///
/// Checks are serialized process-wide (one exploration at a time), so
/// `cargo test` concurrency cannot interleave two schedulers.
pub fn check<F>(cfg: &Config, body: F) -> Result<Report, Box<Failure>>
where
    F: Fn() + Send + Sync,
{
    static RUN_LOCK: StdMutex<()> = StdMutex::new(());
    let _serial = RUN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let sched = sched();

    let mut executions = 0usize;
    let mut complete = false;
    let mut replay: Vec<u32> = Vec::new();

    for _ in 0..cfg.max_executions {
        executions += 1;
        let taken = run_one(sched, cfg, executions, Mode::Dfs, &replay, &body)?;
        match next_prefix(&taken) {
            Some(p) => replay = p,
            None => {
                complete = true;
                break;
            }
        }
    }

    if !complete {
        for i in 0..cfg.random_executions {
            executions += 1;
            let seed = cfg.seed.wrapping_add(i as u64);
            run_one(sched, cfg, executions, Mode::Random { seed }, &[], &body)?;
        }
    }

    Ok(Report { executions, complete })
}

/// Smallest DFS prefix lexicographically after `taken`, or `None` when
/// every alternative at every depth has been tried.
fn next_prefix(taken: &[(u32, u32)]) -> Option<Vec<u32>> {
    for depth in (0..taken.len()).rev() {
        let (chosen, options) = taken[depth];
        if chosen + 1 < options {
            let mut p: Vec<u32> = taken[..depth].iter().map(|&(c, _)| c).collect();
            p.push(chosen + 1);
            return Some(p);
        }
    }
    None
}

fn run_one<F>(
    sched: &'static Sched,
    cfg: &Config,
    execution: usize,
    mode: Mode,
    replay: &[u32],
    body: &F,
) -> Result<Vec<(u32, u32)>, Box<Failure>>
where
    F: Fn() + Send + Sync,
{
    sched.reset(cfg, mode, replay);
    CUR_TID.with(|t| t.set(Some(0)));
    let res = catch_unwind(AssertUnwindSafe(|| {
        body();
        sched.drain_controller();
    }));
    CUR_TID.with(|t| t.set(None));
    if let Err(payload) = res {
        if !payload.is::<ModelAbort>() {
            sched.fail_external(payload_message(&payload));
        }
    }
    // Every spawned OS thread exits promptly once a failure is set (all
    // scheduling points abort); join them so executions never overlap.
    for h in sched.take_handles() {
        let _ = h.join();
    }
    sched.outcome(execution)
}

fn payload_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// Panic payload used to unwind model threads once a failure is
/// recorded; recognized (and swallowed) by the spawn wrapper and the
/// check driver.
struct ModelAbort;

thread_local! {
    static CUR_TID: Cell<Option<usize>> = const { Cell::new(None) };
}

fn cur_tid() -> Option<usize> {
    CUR_TID.with(|t| t.get())
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Block {
    Mutex(usize),
    Cond(usize),
    Join(usize),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TState {
    Runnable,
    /// In a spin loop: schedulable only when nothing else is runnable.
    Spinning,
    Blocked(Block),
    Finished,
}

#[derive(Clone, Copy, Debug)]
enum Mode {
    Dfs,
    Random { seed: u64 },
}

/// Trace entries kept after truncation (recent events matter most).
const TRACE_KEEP: usize = 256;

struct SchedState {
    threads: Vec<TState>,
    active: Option<usize>,
    mutex_held: Vec<Option<usize>>,
    next_obj: usize,
    ops: u64,
    max_ops: u64,
    /// Consecutive schedules granted from an all-spinning candidate set
    /// with no state-changing operation in between.
    stall_rounds: u32,
    mode: Mode,
    rng: u64,
    replay: Vec<u32>,
    pos: usize,
    taken: Vec<(u32, u32)>,
    trace: Vec<String>,
    dropped_events: usize,
    failure: Option<(String, Vec<String>)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl SchedState {
    fn record(&mut self, tid: usize, msg: String) {
        if self.failure.is_some() {
            return;
        }
        if self.trace.len() >= 2 * TRACE_KEEP {
            self.dropped_events += self.trace.len() - TRACE_KEEP;
            self.trace.drain(..self.trace.len() - TRACE_KEEP);
        }
        self.trace.push(format!("T{tid} {msg}"));
    }

    fn fail(&mut self, message: String) {
        if self.failure.is_some() {
            return;
        }
        let mut trace = Vec::with_capacity(self.trace.len() + 1);
        if self.dropped_events > 0 {
            trace.push(format!("... {} earlier events dropped ...", self.dropped_events));
        }
        trace.append(&mut self.trace);
        self.failure = Some((message, trace));
    }

    /// A state-changing operation executed: spinners may observe new
    /// state, so they all become schedulable again.
    fn progress(&mut self) {
        self.stall_rounds = 0;
        for t in &mut self.threads {
            if *t == TState::Spinning {
                *t = TState::Runnable;
            }
        }
    }

    /// Pick the next thread to hold the token, or `None` when no live
    /// thread can run (deadlock — unless everything is finished).
    fn choose(&mut self) -> Option<usize> {
        let mut cands: Vec<usize> = (0..self.threads.len())
            .filter(|&i| self.threads[i] == TState::Runnable)
            .collect();
        let all_spinning = cands.is_empty();
        if all_spinning {
            cands = (0..self.threads.len())
                .filter(|&i| self.threads[i] == TState::Spinning)
                .collect();
            self.stall_rounds += 1;
            let limit = 4 * self.threads.len() as u32 + 16;
            if self.stall_rounds > limit && !cands.is_empty() {
                self.fail(format!(
                    "livelock: every live thread spun {} consecutive rounds with no progress",
                    self.stall_rounds
                ));
                return None;
            }
        }
        if cands.is_empty() {
            return None;
        }
        let n = cands.len() as u32;
        let idx = if n == 1 {
            0
        } else {
            match self.mode {
                Mode::Dfs => {
                    let i = if self.pos < self.replay.len() { self.replay[self.pos] } else { 0 };
                    self.taken.push((i, n));
                    self.pos += 1;
                    i.min(n - 1)
                }
                Mode::Random { .. } => {
                    self.rng = self
                        .rng
                        .wrapping_mul(6364136223846793005)
                        .wrapping_add(1442695040888963407);
                    ((self.rng >> 33) % n as u64) as u32
                }
            }
        };
        Some(cands[idx as usize])
    }
}

struct Sched {
    m: StdMutex<SchedState>,
    cv: StdCondvar,
}

fn sched() -> &'static Sched {
    static SCHED: OnceLock<Sched> = OnceLock::new();
    SCHED.get_or_init(|| Sched {
        m: StdMutex::new(SchedState {
            threads: Vec::new(),
            active: None,
            mutex_held: Vec::new(),
            next_obj: 0,
            ops: 0,
            max_ops: 0,
            stall_rounds: 0,
            mode: Mode::Dfs,
            rng: 0,
            replay: Vec::new(),
            pos: 0,
            taken: Vec::new(),
            trace: Vec::new(),
            dropped_events: 0,
            failure: None,
            handles: Vec::new(),
        }),
        cv: StdCondvar::new(),
    })
}

type Guarded<'a> = std::sync::MutexGuard<'a, SchedState>;

impl Sched {
    fn lock(&self) -> Guarded<'_> {
        self.m.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn reset(&self, cfg: &Config, mode: Mode, replay: &[u32]) {
        let mut st = self.lock();
        st.threads = vec![TState::Runnable];
        st.active = Some(0);
        st.mutex_held.clear();
        st.next_obj = 0;
        st.ops = 0;
        st.max_ops = cfg.max_ops;
        st.stall_rounds = 0;
        st.mode = mode;
        st.rng = match mode {
            Mode::Random { seed } => seed | 1,
            Mode::Dfs => 0,
        };
        st.replay = replay.to_vec();
        st.pos = 0;
        st.taken.clear();
        st.trace.clear();
        st.dropped_events = 0;
        st.failure = None;
        debug_assert!(st.handles.is_empty(), "executions overlapped");
    }

    fn take_handles(&self) -> Vec<std::thread::JoinHandle<()>> {
        std::mem::take(&mut self.lock().handles)
    }

    fn outcome(&self, execution: usize) -> Result<Vec<(u32, u32)>, Box<Failure>> {
        let mut st = self.lock();
        match st.failure.take() {
            Some((message, trace)) => Err(Box::new(Failure { message, execution, trace })),
            None => Ok(std::mem::take(&mut st.taken)),
        }
    }

    /// Record `message` as the failure from outside the scheduler (a
    /// controller panic) and wake everything so it aborts.
    fn fail_external(&self, message: String) {
        let mut st = self.lock();
        st.fail(message);
        self.cv.notify_all();
    }

    fn abort(&self, st: Guarded<'_>) -> ! {
        drop(st);
        self.cv.notify_all();
        std::panic::panic_any(ModelAbort);
    }

    fn check_abort(&self, st: &Guarded<'_>) -> bool {
        st.failure.is_some()
    }

    /// Move the token to `next` (or park it when `next` is `None`) and
    /// wait until this thread is granted again.
    fn hand_off_and_wait(&self, mut st: Guarded<'_>, tid: usize, next: Option<usize>) {
        st.active = next;
        self.cv.notify_all();
        while st.active != Some(tid) {
            if self.check_abort(&st) {
                self.abort(st);
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.threads[tid] == TState::Spinning {
            st.threads[tid] = TState::Runnable;
        }
    }

    /// One scheduling point: charge the op budget, let the explorer
    /// pick who runs next, and return once this thread holds the token
    /// again.  `write` marks state-changing operations (they re-arm
    /// spinners once the operation executes).
    fn grant(&self, tid: usize, write: bool) {
        let mut st = self.lock();
        if self.check_abort(&st) {
            self.abort(st);
        }
        st.ops += 1;
        if st.ops > st.max_ops {
            let budget = st.max_ops;
            st.fail(format!("operation budget ({budget}) exceeded: livelock or runaway loop"));
            self.abort(st);
        }
        match st.choose() {
            Some(next) => self.hand_off_and_wait(st, tid, next),
            None => self.abort(st),
        }
        // Token regained: the operation executes now, before any other
        // thread can be scheduled.
        if write {
            let mut st = self.lock();
            st.progress();
        }
    }

    /// Record a completed operation in the trace.
    fn note(&self, tid: usize, msg: String) {
        let mut st = self.lock();
        st.record(tid, msg);
    }

    /// Spin-loop yield point: deprioritize this thread until progress.
    fn yield_spin(&self, tid: usize) {
        let mut st = self.lock();
        if self.check_abort(&st) {
            self.abort(st);
        }
        st.ops += 1;
        if st.ops > st.max_ops {
            st.fail(format!("operation budget ({}) exceeded while spinning", st.max_ops));
            self.abort(st);
        }
        st.threads[tid] = TState::Spinning;
        match st.choose() {
            Some(next) => self.hand_off_and_wait(st, tid, next),
            None => {
                st.fail("deadlock: every live thread is blocked or spinning".to_string());
                self.abort(st)
            }
        }
    }

    /// Block on `why` until woken, then return with the token held.
    fn block_on(&self, mut st: Guarded<'_>, tid: usize, why: Block) {
        st.threads[tid] = TState::Blocked(why);
        match st.choose() {
            Some(next) => self.hand_off_and_wait(st, tid, next),
            None => {
                let blocked: Vec<String> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| matches!(s, TState::Blocked(_)))
                    .map(|(i, s)| format!("T{i} {s:?}"))
                    .collect();
                st.fail(format!("deadlock: all live threads blocked [{}]", blocked.join(", ")));
                self.abort(st)
            }
        }
    }

    fn wake(st: &mut SchedState, pred: impl Fn(Block) -> bool) {
        for t in st.threads.iter_mut() {
            if let TState::Blocked(b) = *t {
                if pred(b) {
                    *t = TState::Runnable;
                }
            }
        }
    }

    fn fresh_obj(&self) -> usize {
        let mut st = self.lock();
        let id = st.next_obj;
        st.next_obj += 1;
        id
    }

    // -- threads ----------------------------------------------------------

    fn register_thread(&self, parent: usize) -> usize {
        let mut st = self.lock();
        let tid = st.threads.len();
        st.threads.push(TState::Runnable);
        st.record(parent, format!("spawned T{tid}"));
        tid
    }

    fn startup_wait(&self, tid: usize) {
        let mut st = self.lock();
        while st.active != Some(tid) {
            if self.check_abort(&st) {
                self.abort(st);
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn finish(&self, tid: usize, panic_msg: Option<String>) {
        let mut st = self.lock();
        if let Some(msg) = panic_msg {
            st.record(tid, format!("panicked: {msg}"));
            st.fail(format!("thread T{tid} panicked: {msg}"));
            st.threads[tid] = TState::Finished;
            drop(st);
            self.cv.notify_all();
            return;
        }
        st.record(tid, "finished".to_string());
        st.threads[tid] = TState::Finished;
        Self::wake(&mut st, |b| b == Block::Join(tid));
        st.progress();
        st.active = st.choose();
        if st.active.is_none() && st.threads.iter().any(|t| !matches!(t, TState::Finished)) {
            // Nobody left to run but live threads remain: a blocked
            // cycle nothing will ever wake (e.g. a lost notify).
            st.fail("deadlock: finishing thread leaves only blocked threads".to_string());
        }
        drop(st);
        self.cv.notify_all();
    }

    fn finish_aborted(&self, tid: usize) {
        let mut st = self.lock();
        st.threads[tid] = TState::Finished;
        drop(st);
        self.cv.notify_all();
    }

    fn join_wait(&self, tid: usize, target: usize) {
        loop {
            let st = self.lock();
            if self.check_abort(&st) {
                self.abort(st);
            }
            if st.threads[target] == TState::Finished {
                return;
            }
            self.block_on(st, tid, Block::Join(target));
        }
    }

    /// Controller tail: wait (as a polite spinner) for every spawned
    /// thread to finish, so executions never leak threads.
    fn drain_controller(&self) {
        loop {
            {
                let st = self.lock();
                if self.check_abort(&st) {
                    self.abort(st);
                }
                if st.threads[1..].iter().all(|t| *t == TState::Finished) {
                    return;
                }
            }
            self.yield_spin(0);
        }
    }

    // -- mutexes ----------------------------------------------------------

    fn acquire_mutex(&self, tid: usize, mid: usize) {
        self.grant(tid, true);
        loop {
            let mut st = self.lock();
            if self.check_abort(&st) {
                self.abort(st);
            }
            if st.mutex_held.len() <= mid {
                st.mutex_held.resize(mid + 1, None);
            }
            if st.mutex_held[mid].is_none() {
                st.mutex_held[mid] = Some(tid);
                st.record(tid, format!("m{mid}.lock"));
                return;
            }
            self.block_on(st, tid, Block::Mutex(mid));
        }
    }

    fn release_mutex(&self, tid: usize, mid: usize) {
        let mut st = self.lock();
        if st.mutex_held.len() > mid {
            st.mutex_held[mid] = None;
        }
        Self::wake(&mut st, |b| b == Block::Mutex(mid));
        st.progress();
        st.record(tid, format!("m{mid}.unlock"));
        drop(st);
        self.cv.notify_all();
    }

    // -- condvars ---------------------------------------------------------

    /// Atomically release `mid` and sleep on `cid` (the caller has
    /// already dropped the real guard); returns once notified and
    /// scheduled, with the mutex *not yet* reacquired.
    fn cv_wait(&self, tid: usize, cid: usize, mid: usize) {
        let mut st = self.lock();
        if self.check_abort(&st) {
            self.abort(st);
        }
        if st.mutex_held.len() > mid {
            st.mutex_held[mid] = None;
        }
        Self::wake(&mut st, |b| b == Block::Mutex(mid));
        st.progress();
        st.record(tid, format!("c{cid}.wait (released m{mid})"));
        self.block_on(st, tid, Block::Cond(cid));
    }

    fn cv_notify(&self, tid: usize, cid: usize, all: bool) {
        let mut st = self.lock();
        if all {
            Self::wake(&mut st, |b| b == Block::Cond(cid));
        } else {
            let waiter = Block::Cond(cid);
            if let Some(one) = st.threads.iter().position(|t| *t == TState::Blocked(waiter)) {
                st.threads[one] = TState::Runnable;
            }
        }
        st.progress();
        st.record(tid, format!("c{cid}.notify_{}", if all { "all" } else { "one" }));
        drop(st);
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Model threads
// ---------------------------------------------------------------------------

/// Handle to a thread spawned inside a model execution.
pub struct JoinHandle<T> {
    tid: usize,
    slot: Arc<StdMutex<Option<T>>>,
}

impl<T> JoinHandle<T> {
    /// Wait (as a scheduling point) for the thread to finish and return
    /// its result.  A panicked thread aborts the execution instead.
    pub fn join(self) -> T {
        let s = sched();
        // PANIC-OK: API misuse — join() is only callable from inside a
        // check body, where the TLS tid is always set.
        let tid = cur_tid().expect("model join outside a check");
        s.join_wait(tid, self.tid);
        match self.slot.lock().unwrap_or_else(|e| e.into_inner()).take() {
            Some(v) => v,
            // The target panicked: its failure is already recorded.
            None => std::panic::panic_any(ModelAbort),
        }
    }
}

/// Spawn a model thread inside a [`check`] execution.  It starts
/// suspended and runs only when the explorer schedules it.
pub fn spawn<T, F>(f: F) -> JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    // PANIC-OK: API misuse — spawn() requires an enclosing check body.
    let parent = cur_tid().expect("model::spawn outside a check body");
    let s = sched();
    let tid = s.register_thread(parent);
    let slot: Arc<StdMutex<Option<T>>> = Arc::new(StdMutex::new(None));
    let out = Arc::clone(&slot);
    let handle = std::thread::Builder::new()
        .name(format!("model-T{tid}"))
        .spawn(move || {
            CUR_TID.with(|t| t.set(Some(tid)));
            let res = catch_unwind(AssertUnwindSafe(|| {
                s.startup_wait(tid);
                f()
            }));
            match res {
                Ok(v) => {
                    *out.lock().unwrap_or_else(|e| e.into_inner()) = Some(v);
                    s.finish(tid, None);
                }
                Err(payload) => {
                    if payload.is::<ModelAbort>() {
                        s.finish_aborted(tid);
                    } else {
                        s.finish(tid, Some(payload_message(&payload)));
                    }
                }
            }
        })
        // PANIC-OK: OS thread exhaustion during a test harness run is
        // unrecoverable; fail the check loudly.
        .expect("spawn model thread");
    sched().lock().handles.push(handle);
    JoinHandle { tid, slot }
}

/// Spin-loop yield point (`sync::spin::{spin_loop, yield_now}` route
/// here under the model).  Outside a model thread it degrades to a real
/// OS yield.
pub fn spin_yield() {
    match cur_tid() {
        Some(tid) => sched().yield_spin(tid),
        None => std::thread::yield_now(),
    }
}

/// Run `f` as one instrumented operation: schedule, execute with the
/// token held, trace.  Passthrough when the calling thread is not part
/// of a model execution (ordinary tests under this cfg).
fn op<T>(write: bool, f: impl FnOnce() -> T, desc: impl FnOnce(&T) -> String) -> T {
    match cur_tid() {
        None => f(),
        Some(tid) => {
            let s = sched();
            s.grant(tid, write);
            let v = f();
            let msg = desc(&v);
            s.note(tid, msg);
            v
        }
    }
}

// ---------------------------------------------------------------------------
// Instrumented atomics
// ---------------------------------------------------------------------------

fn obj_id(slot: &OnceLock<usize>) -> usize {
    *slot.get_or_init(|| sched().fresh_obj())
}

macro_rules! model_atomic {
    ($name:ident, $std:ty, $val:ty) => {
        /// Instrumented atomic: every access is a scheduling point of
        /// the model explorer; identical API to the `std` type.
        pub struct $name {
            id: OnceLock<usize>,
            inner: $std,
        }

        impl $name {
            pub const fn new(v: $val) -> Self {
                $name { id: OnceLock::new(), inner: <$std>::new(v) }
            }

            fn tag(&self) -> usize {
                obj_id(&self.id)
            }

            pub fn load(&self, o: Ordering) -> $val {
                let t = &self.inner;
                op(false, || t.load(o), |v| format!("a{}.load({o:?}) -> {v:?}", self.tag()))
            }

            pub fn store(&self, v: $val, o: Ordering) {
                let t = &self.inner;
                op(true, || t.store(v, o), |_| format!("a{}.store({v:?}, {o:?})", self.tag()))
            }

            pub fn swap(&self, v: $val, o: Ordering) -> $val {
                let t = &self.inner;
                op(
                    true,
                    || t.swap(v, o),
                    |p| format!("a{}.swap({v:?}, {o:?}) -> {p:?}", self.tag()),
                )
            }

            pub fn compare_exchange(
                &self,
                cur: $val,
                new: $val,
                ok: Ordering,
                err: Ordering,
            ) -> Result<$val, $val> {
                let t = &self.inner;
                op(
                    true,
                    || t.compare_exchange(cur, new, ok, err),
                    |r| format!("a{}.compare_exchange({cur:?}, {new:?}) -> {r:?}", self.tag()),
                )
            }

            pub fn compare_exchange_weak(
                &self,
                cur: $val,
                new: $val,
                ok: Ordering,
                err: Ordering,
            ) -> Result<$val, $val> {
                // Never fails spuriously under the model: spurious
                // failure adds schedules without adding reachable
                // protocol states.
                self.compare_exchange(cur, new, ok, err)
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new(Default::default())
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                // Diagnostic read: not a scheduling point.
                write!(f, "{:?}", self.inner)
            }
        }
    };
}

macro_rules! model_atomic_arith {
    ($name:ident, $val:ty) => {
        impl $name {
            pub fn fetch_add(&self, v: $val, o: Ordering) -> $val {
                let t = &self.inner;
                op(
                    true,
                    || t.fetch_add(v, o),
                    |p| format!("a{}.fetch_add({v}, {o:?}) -> {p}", self.tag()),
                )
            }

            pub fn fetch_sub(&self, v: $val, o: Ordering) -> $val {
                let t = &self.inner;
                op(
                    true,
                    || t.fetch_sub(v, o),
                    |p| format!("a{}.fetch_sub({v}, {o:?}) -> {p}", self.tag()),
                )
            }

            pub fn fetch_max(&self, v: $val, o: Ordering) -> $val {
                let t = &self.inner;
                op(
                    true,
                    || t.fetch_max(v, o),
                    |p| format!("a{}.fetch_max({v}, {o:?}) -> {p}", self.tag()),
                )
            }
        }
    };
}

model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
model_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
model_atomic_arith!(AtomicU64, u64);
model_atomic_arith!(AtomicU32, u32);
model_atomic_arith!(AtomicUsize, usize);

// ---------------------------------------------------------------------------
// Instrumented Mutex / Condvar
// ---------------------------------------------------------------------------

/// Instrumented mutex: lock/unlock are scheduling points; contention
/// blocks in the model scheduler, never in the OS.  API-compatible with
/// `std::sync::Mutex` for the crate's usage (`lock` + poison recovery).
pub struct Mutex<T: ?Sized> {
    id: OnceLock<usize>,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Mutex { id: OnceLock::new(), inner: StdMutex::new(t) }
    }
}

impl<T: ?Sized> Mutex<T> {
    fn tag(&self) -> usize {
        obj_id(&self.id)
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let model = match cur_tid() {
            Some(tid) => {
                sched().acquire_mutex(tid, self.tag());
                true
            }
            None => false,
        };
        // With the model bookkeeping holding this mutex for us, the
        // inner lock is uncontended among model threads; unregistered
        // threads must not share a model-checked structure mid-check.
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), model }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                lock: self,
                inner: Some(p.into_inner()),
                model,
            })),
        }
    }
}

impl<T: ?Sized> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model::Mutex")
    }
}

/// Guard for [`Mutex`]; drops the real guard first, then releases the
/// model bookkeeping (a non-transferring operation: the token stays
/// with the unlocking thread until its next scheduling point).
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    model: bool,
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // PANIC-OK: `inner` is only None transiently inside drop/wait.
        self.inner.as_ref().expect("guard still holds the lock")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // PANIC-OK: `inner` is only None transiently inside drop/wait.
        self.inner.as_mut().expect("guard still holds the lock")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        self.inner = None;
        if self.model {
            if let Some(tid) = cur_tid() {
                sched().release_mutex(tid, self.lock.tag());
            }
        }
    }
}

/// Instrumented condvar: waits park in the model scheduler (modeling
/// lost wakeups faithfully — a notify with no waiter wakes nobody).
pub struct Condvar {
    id: OnceLock<usize>,
    inner: StdCondvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar { id: OnceLock::new(), inner: StdCondvar::new() }
    }

    fn tag(&self) -> usize {
        obj_id(&self.id)
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        match cur_tid() {
            None => {
                // PANIC-OK: a live guard always holds its std guard.
                let std_guard = guard.inner.take().expect("guard still holds the lock");
                guard.model = false;
                drop(guard);
                match self.inner.wait(std_guard) {
                    Ok(g) => Ok(MutexGuard { lock, inner: Some(g), model: false }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        lock,
                        inner: Some(p.into_inner()),
                        model: false,
                    })),
                }
            }
            Some(tid) => {
                let s = sched();
                let mid = lock.tag();
                // Drop the real guard without releasing the model
                // bookkeeping; cv_wait hands both over atomically.
                guard.inner = None;
                guard.model = false;
                drop(guard);
                s.cv_wait(tid, self.tag(), mid);
                // Notified and scheduled: contend for the mutex again.
                s.acquire_mutex(tid, mid);
                match lock.inner.lock() {
                    Ok(g) => Ok(MutexGuard { lock, inner: Some(g), model: true }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        lock,
                        inner: Some(p.into_inner()),
                        model: true,
                    })),
                }
            }
        }
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
        if let Some(tid) = cur_tid() {
            sched().cv_notify(tid, self.tag(), true);
        }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
        if let Some(tid) = cur_tid() {
            sched().cv_notify(tid, self.tag(), false);
        }
    }
}
