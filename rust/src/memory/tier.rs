//! Bandwidth accounting for the two KNL memory tiers.

use crate::sync::{AtomicU64, Ordering};

/// Which memory a structure lives in (paper: DRAM vs MCDRAM flat mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Large, slow: KNL DRAM, 6 channels, ~80 GB/s STREAM.
    Slow,
    /// Small, fast: KNL MCDRAM scratchpad, ~440 GB/s, 16 GB.
    Fast,
}

/// Default tier parameters from the paper (§II-D).
pub const SLOW_GBS: f64 = 80.0;
pub const FAST_GBS: f64 = 440.0;
pub const FAST_CAPACITY: u64 = 16 * (1 << 30);

/// Per-tier traffic counters.  Relaxed throughout: pure statistics
/// totals read at phase boundaries; no counter publishes other memory.
#[derive(Default)]
pub struct TierCounters {
    pub read_bytes: AtomicU64,
    pub write_bytes: AtomicU64,
}

/// Snapshot of one tier's accumulated traffic.
#[derive(Clone, Copy, Debug, Default)]
pub struct TierStats {
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl TierStats {
    pub fn total(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }
}

/// The simulator: traffic counters + bandwidth model.
///
/// Modeled time for a task = bytes moved on its tier / tier bandwidth,
/// optionally derated by a saturation factor when more threads stream
/// than the tier's channels sustain (this reproduces the Fig-2 roll-off
/// above ~24 task-A threads on DRAM).
pub struct TierSim {
    pub slow: TierCounters,
    pub fast: TierCounters,
    pub slow_gbs: f64,
    pub fast_gbs: f64,
}

impl Default for TierSim {
    fn default() -> Self {
        TierSim {
            slow: TierCounters::default(),
            fast: TierCounters::default(),
            slow_gbs: SLOW_GBS,
            fast_gbs: FAST_GBS,
        }
    }
}

impl TierSim {
    pub fn new(slow_gbs: f64, fast_gbs: f64) -> Self {
        TierSim { slow_gbs, fast_gbs, ..Default::default() }
    }

    fn counters(&self, tier: Tier) -> &TierCounters {
        match tier {
            Tier::Slow => &self.slow,
            Tier::Fast => &self.fast,
        }
    }

    /// Record a bulk read of `bytes` from `tier`.
    #[inline]
    pub fn read(&self, tier: Tier, bytes: u64) {
        self.counters(tier).read_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a bulk write of `bytes` to `tier`.
    #[inline]
    pub fn write(&self, tier: Tier, bytes: u64) {
        self.counters(tier).write_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn stats(&self, tier: Tier) -> TierStats {
        let c = self.counters(tier);
        TierStats {
            read_bytes: c.read_bytes.load(Ordering::Relaxed),
            write_bytes: c.write_bytes.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        for t in [Tier::Slow, Tier::Fast] {
            let c = self.counters(t);
            c.read_bytes.store(0, Ordering::Relaxed);
            c.write_bytes.store(0, Ordering::Relaxed);
        }
    }

    /// Effective bandwidth for `threads` concurrent streamers on `tier`.
    ///
    /// Bandwidth scales ~linearly until the channel count saturates, then
    /// degrades slightly due to contention on the mesh (paper Fig. 2:
    /// no gain above ~20 threads, decline + fluctuation above ~24).
    pub fn effective_gbs(&self, tier: Tier, threads: usize) -> f64 {
        let (peak, sat_threads) = match tier {
            // DRAM: ~6 channels; measured saturation at about 20 threads.
            Tier::Slow => (self.slow_gbs, 20.0),
            // MCDRAM: 8 channels; ~32 streaming cores reach peak
            // (~14 GB/s per-core streaming, consistent with KNL STREAM).
            Tier::Fast => (self.fast_gbs, 32.0),
        };
        let t = threads.max(1) as f64;
        if t <= sat_threads {
            peak * (t / sat_threads)
        } else {
            // Beyond saturation: contention costs ~0.3% per extra thread.
            peak * (1.0 - 0.003 * (t - sat_threads)).max(0.8)
        }
    }

    /// Modeled seconds to move `bytes` with `threads` streamers on `tier`.
    pub fn modeled_secs(&self, tier: Tier, bytes: u64, threads: usize) -> f64 {
        bytes as f64 / (self.effective_gbs(tier, threads) * 1e9)
    }
}

/// Charges accumulate locally until this many bytes, then flush in one
/// atomic add — keeps the counter off the sweep hot path.
pub const CHARGE_FLUSH_BYTES: u64 = 1 << 20;

/// Per-worker batching of [`TierSim::read`] charges.  Every sweep
/// consumer (task A's epoch loop, `run_fixed`, OMP's refresh) shares
/// this one helper so no path forgets the 1 MiB batching threshold; the
/// `Drop` impl flushes the tail, so early exits cannot lose traffic.
pub struct ReadBatcher<'a> {
    sim: &'a TierSim,
    tier: Tier,
    pending: u64,
}

impl<'a> ReadBatcher<'a> {
    pub fn new(sim: &'a TierSim, tier: Tier) -> Self {
        ReadBatcher { sim, tier, pending: 0 }
    }

    /// Record a read; flushes once the local tally passes
    /// [`CHARGE_FLUSH_BYTES`].
    #[inline]
    pub fn add(&mut self, bytes: u64) {
        self.pending += bytes;
        if self.pending > CHARGE_FLUSH_BYTES {
            self.flush();
        }
    }

    pub fn flush(&mut self) {
        if self.pending > 0 {
            self.sim.read(self.tier, self.pending);
            self.pending = 0;
        }
    }
}

impl Drop for ReadBatcher<'_> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let sim = TierSim::default();
        sim.read(Tier::Slow, 100);
        sim.read(Tier::Slow, 50);
        sim.write(Tier::Fast, 30);
        assert_eq!(sim.stats(Tier::Slow).read_bytes, 150);
        assert_eq!(sim.stats(Tier::Fast).write_bytes, 30);
        assert_eq!(sim.stats(Tier::Fast).read_bytes, 0);
        sim.reset();
        assert_eq!(sim.stats(Tier::Slow).total(), 0);
    }

    #[test]
    fn bandwidth_saturates_like_fig2() {
        let sim = TierSim::default();
        let b1 = sim.effective_gbs(Tier::Slow, 1);
        let b12 = sim.effective_gbs(Tier::Slow, 12);
        let b20 = sim.effective_gbs(Tier::Slow, 20);
        let b40 = sim.effective_gbs(Tier::Slow, 40);
        assert!(b12 > b1 * 8.0, "near-linear scaling early");
        assert!((b20 - SLOW_GBS).abs() < 1e-9, "peak at saturation");
        assert!(b40 < b20, "decline past saturation");
        assert!(b40 >= 0.8 * SLOW_GBS, "bounded decline");
    }

    #[test]
    fn fast_tier_is_much_faster() {
        let sim = TierSim::default();
        let slow = sim.modeled_secs(Tier::Slow, 1 << 30, 20);
        let fast = sim.modeled_secs(Tier::Fast, 1 << 30, 32);
        assert!(slow / fast > 5.0, "MCDRAM ~5.5x DRAM: {slow} vs {fast}");
    }

    #[test]
    fn read_batcher_flushes_at_threshold_and_on_drop() {
        let sim = TierSim::default();
        {
            let mut b = ReadBatcher::new(&sim, Tier::Slow);
            b.add(CHARGE_FLUSH_BYTES); // == threshold: held locally
            assert_eq!(sim.stats(Tier::Slow).read_bytes, 0, "below/at threshold: no flush");
            b.add(1); // crosses the threshold
            assert_eq!(sim.stats(Tier::Slow).read_bytes, CHARGE_FLUSH_BYTES + 1);
            b.add(7); // tail stays pending until drop
            assert_eq!(sim.stats(Tier::Slow).read_bytes, CHARGE_FLUSH_BYTES + 1);
        }
        assert_eq!(sim.stats(Tier::Slow).read_bytes, CHARGE_FLUSH_BYTES + 8, "drop flushes tail");
    }

    #[test]
    fn concurrent_charges() {
        let sim = std::sync::Arc::new(TierSim::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let sim = sim.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        sim.read(Tier::Fast, 8);
                    }
                });
            }
        });
        assert_eq!(sim.stats(Tier::Fast).read_bytes, 4 * 1000 * 8);
    }
}
