//! Tier-tagged arena allocator.
//!
//! On KNL the paper uses `memkind`/`numa` to place task B's working set
//! in MCDRAM and everything else in DRAM.  Here an [`Arena`] is a plain
//! slab tagged with its [`Tier`]; allocation tracks usage against the
//! tier capacity (MCDRAM: 16 GB) so configurations that would not fit
//! on the real machine are rejected the same way (this drives the
//! paper's "B works on a subset small enough for MCDRAM" constraint).

use super::tier::{Tier, FAST_CAPACITY};

/// A bump arena of f32 slots in one memory tier.
pub struct Arena {
    tier: Tier,
    capacity_bytes: u64,
    used_bytes: u64,
    /// Slabs handed out (kept alive by the arena).
    allocations: Vec<Box<[f32]>>,
}

impl Arena {
    pub fn new(tier: Tier) -> Self {
        let capacity_bytes = match tier {
            Tier::Fast => FAST_CAPACITY,
            Tier::Slow => u64::MAX, // DRAM: effectively unbounded here
        };
        Arena { tier, capacity_bytes, used_bytes: 0, allocations: Vec::new() }
    }

    /// Arena with an explicit capacity (tests, scaled experiments).
    pub fn with_capacity(tier: Tier, capacity_bytes: u64) -> Self {
        Arena { tier, capacity_bytes, used_bytes: 0, allocations: Vec::new() }
    }

    pub fn tier(&self) -> Tier {
        self.tier
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Whether `len` f32 elements would still fit.
    pub fn fits(&self, len: usize) -> bool {
        self.used_bytes + (len as u64) * 4 <= self.capacity_bytes
    }

    /// Allocate a zeroed f32 slab, or `None` if the tier is full.
    ///
    /// Returns a raw pointer + length; the arena owns the storage.  The
    /// coordinator wraps these in the shared-vector / working-set types,
    /// which manage cross-thread access.
    pub fn alloc(&mut self, len: usize) -> Option<&mut [f32]> {
        if !self.fits(len) {
            return None;
        }
        self.used_bytes += (len as u64) * 4;
        self.allocations.push(vec![0.0f32; len].into_boxed_slice());
        // PANIC-OK: the slab was pushed on the line above.
        let slab = self.allocations.last_mut().unwrap();
        // SAFETY: the boxed slab's storage address is stable (growing
        // `allocations` moves the Box, not the heap slab), it lives
        // until `reset`/drop, and each slab is handed out exactly once,
        // so no aliasing `&mut` can exist.
        Some(unsafe { std::slice::from_raw_parts_mut(slab.as_mut_ptr(), len) })
    }

    /// Reserve raw capacity without handing out a slab — how the
    /// `DatasetBuilder` charges a dataset's placed representation
    /// against the tier (packed/quantized layouts are not f32 slabs).
    /// Returns false (nothing reserved) when the bytes do not fit.
    pub fn reserve_bytes(&mut self, bytes: u64) -> bool {
        if self.used_bytes.saturating_add(bytes) > self.capacity_bytes {
            return false;
        }
        self.used_bytes += bytes;
        true
    }

    /// Release everything (working-set teardown between runs).
    pub fn reset(&mut self) {
        self.allocations.clear();
        self.used_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_tracks_usage() {
        let mut a = Arena::with_capacity(Tier::Fast, 1024);
        assert!(a.fits(256));
        let s = a.alloc(100).unwrap();
        assert_eq!(s.len(), 100);
        assert!(s.iter().all(|&x| x == 0.0));
        assert_eq!(a.used_bytes(), 400);
    }

    #[test]
    fn capacity_enforced() {
        let mut a = Arena::with_capacity(Tier::Fast, 1000);
        assert!(a.alloc(200).is_some()); // 800 bytes
        assert!(a.alloc(100).is_none()); // would exceed
        assert!(a.alloc(50).is_some()); // exactly fits
        assert!(!a.fits(1));
    }

    #[test]
    fn reset_frees() {
        let mut a = Arena::with_capacity(Tier::Fast, 1000);
        a.alloc(250).unwrap();
        assert!(!a.fits(1));
        a.reset();
        assert!(a.fits(250));
        assert_eq!(a.used_bytes(), 0);
    }

    #[test]
    fn reserve_bytes_tracks_and_rejects() {
        let mut a = Arena::with_capacity(Tier::Fast, 100);
        assert!(a.reserve_bytes(60));
        assert_eq!(a.used_bytes(), 60);
        assert!(!a.reserve_bytes(41), "over capacity");
        assert_eq!(a.used_bytes(), 60, "failed reserve must not charge");
        assert!(a.reserve_bytes(40), "exact fit");
        assert!(!a.reserve_bytes(u64::MAX), "saturating add, no overflow");
        a.reset();
        assert!(a.reserve_bytes(100));
    }

    #[test]
    fn default_fast_capacity_is_16gb() {
        let a = Arena::new(Tier::Fast);
        assert_eq!(a.capacity_bytes(), 16 * (1 << 30));
    }
}
