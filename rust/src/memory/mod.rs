//! Two-tier memory placement & bandwidth simulation.
//!
//! KNL flat mode gives HTHC two separately-allocatable memories:
//! DRAM (~80 GB/s, large) for task A's full dataset and MCDRAM
//! (~440 GB/s, 16 GB) for task B's working set, so that one task
//! saturating its tier cannot stall the other (paper §IV-A1).
//!
//! This host has a single uniform memory, so the *placement decisions*
//! are executed for real (separate arenas, real copies on working-set
//! swap) while the *bandwidth consequences* are modeled: every bulk
//! access charges bytes to its tier and the [`TierSim`] converts traffic
//! into modeled seconds with per-tier saturation.  Benches report both
//! wall-clock (measured) and modeled time (see DESIGN.md §5).

pub mod arena;
pub mod platform;
pub mod tier;

pub use arena::Arena;
pub use platform::Platform;
pub use tier::{ReadBatcher, Tier, TierSim, TierStats};
