//! Platform profiles (paper intro + conclusion: "the inherent
//! adaptivity of HTHC should enable porting it to other existing and
//! future standalone manycore platforms").
//!
//! A profile parameterizes the §IV-F model and the TierSim: core count,
//! clock, per-tier bandwidths and their saturation points.  `--platform`
//! on the CLI re-targets the recommendation without touching code —
//! the adaptivity claim made executable.

use super::tier::TierSim;

/// One manycore target.
#[derive(Clone, Debug, PartialEq)]
pub struct Platform {
    pub name: &'static str,
    pub cores: usize,
    pub clock_hz: f64,
    /// Large-tier (DRAM) bandwidth GB/s and streaming-thread saturation.
    pub slow_gbs: f64,
    pub slow_sat_threads: f64,
    /// Fast-tier bandwidth GB/s (None = single uniform memory: the fast
    /// tier degenerates to the slow one and HTHC loses the placement
    /// lever, as on most non-KNL parts).
    pub fast_gbs: Option<f64>,
    pub fast_capacity_gb: f64,
}

impl Platform {
    /// Intel Xeon Phi 7290 (Knights Landing) — the paper's machine.
    pub fn knl() -> Self {
        Platform {
            name: "knl",
            cores: 72,
            clock_hz: 1.5e9,
            slow_gbs: 80.0,
            slow_sat_threads: 20.0,
            fast_gbs: Some(440.0),
            fast_capacity_gb: 16.0,
        }
    }

    /// Marvell/Cavium ThunderX2 (64 cores, 8-ch DDR4) — paper intro.
    pub fn thunderx2() -> Self {
        Platform {
            name: "thunderx2",
            cores: 64,
            clock_hz: 2.2e9,
            slow_gbs: 150.0,
            slow_sat_threads: 24.0,
            fast_gbs: None,
            fast_capacity_gb: 0.0,
        }
    }

    /// Qualcomm Centriq 2400 (48 cores, 6-ch DDR4) — paper intro.
    pub fn centriq() -> Self {
        Platform {
            name: "centriq",
            cores: 48,
            clock_hz: 2.5e9,
            slow_gbs: 120.0,
            slow_sat_threads: 20.0,
            fast_gbs: None,
            fast_capacity_gb: 0.0,
        }
    }

    /// This host (for measured-vs-modeled sanity): 1 core, uniform mem.
    pub fn host() -> Self {
        Platform {
            name: "host",
            cores: 1,
            clock_hz: 3.0e9,
            slow_gbs: 37.0, // measured STREAM-ish via dot_f32 bench
            slow_sat_threads: 1.0,
            fast_gbs: None,
            fast_capacity_gb: 0.0,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "knl" => Self::knl(),
            "thunderx2" => Self::thunderx2(),
            "centriq" => Self::centriq(),
            "host" => Self::host(),
            _ => return None,
        })
    }

    /// Whether the platform has a separately-allocatable fast tier (the
    /// precondition for HTHC's memory-separation lever).
    pub fn has_fast_tier(&self) -> bool {
        self.fast_gbs.is_some()
    }

    /// Build the matching simulator.  Uniform-memory platforms get
    /// fast == slow (placement becomes a no-op, not an error).
    pub fn tier_sim(&self) -> TierSim {
        TierSim::new(self.slow_gbs, self.fast_gbs.unwrap_or(self.slow_gbs))
    }

    pub fn describe(&self) -> String {
        format!(
            "{}: {} cores @ {:.1} GHz, DRAM {:.0} GB/s{}",
            self.name,
            self.cores,
            self.clock_hz / 1e9,
            self.slow_gbs,
            match self.fast_gbs {
                Some(f) => format!(", fast tier {:.0} GB/s ({} GB)", f, self.fast_capacity_gb),
                None => ", uniform memory".into(),
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all() {
        for name in ["knl", "thunderx2", "centriq", "host"] {
            let p = Platform::parse(name).unwrap();
            assert_eq!(p.name, name);
            assert!(p.cores >= 1);
        }
        assert!(Platform::parse("gpu").is_none());
    }

    #[test]
    fn only_knl_has_fast_tier() {
        assert!(Platform::knl().has_fast_tier());
        assert!(!Platform::thunderx2().has_fast_tier());
        assert!(!Platform::centriq().has_fast_tier());
    }

    #[test]
    fn uniform_memory_sim_has_equal_tiers() {
        let sim = Platform::thunderx2().tier_sim();
        assert_eq!(sim.slow_gbs, sim.fast_gbs);
        let knl = Platform::knl().tier_sim();
        assert!(knl.fast_gbs > 5.0 * knl.slow_gbs);
    }

    #[test]
    fn describe_mentions_tier() {
        assert!(Platform::knl().describe().contains("fast tier"));
        assert!(Platform::centriq().describe().contains("uniform"));
    }
}
