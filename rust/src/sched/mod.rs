//! Shard-pinned tile scheduling for bulk column sweeps.
//!
//! Every sweep consumer (task A's gap refresh, `run_fixed`, OMP's full
//! refresh, task B's work queue) used to hand-roll an `AtomicUsize`
//! cursor over the whole coordinate range — one global queue, no
//! locality.  The [`TileScheduler`] replaces those cursors with the
//! §IV-A placement discipline: the domain is split into one shard per
//! worker using exactly the [`DatasetView::shards`] arithmetic (so a
//! scheduler shard *is* the worker's view shard), each shard is
//! decomposed into `tile_cols`-sized column tiles, and a worker claims
//! tiles from its own shard first.  Pinning keeps each worker's blocked
//! `w`-pass ([`dots_block`]) walking a contiguous column range it owns,
//! so the epoch-frozen snapshot streams stay within one shard and tier
//! traffic can be attributed per shard against the dataset's recorded
//! [`placement`].
//!
//! Two claim disciplines cover the two sweep shapes:
//!
//! * [`claim`](TileScheduler::claim) — **drain** semantics: every tile
//!   is handed out exactly once.  A worker that empties its own shard
//!   steals from the *heaviest* remaining shard (most unclaimed tiles),
//!   which keeps the tail of an imbalanced sweep spread across workers
//!   instead of serialized on the slowest shard.  Claims are single
//!   `fetch_add`s (the HOGWILD!-style lock-free discipline) — a lost
//!   steal race just rescans.
//! * [`claim_cyclic`](TileScheduler::claim_cyclic) — **wrap**
//!   semantics for run-until-stopped sweeps (task A): the worker cycles
//!   through its own shard's tiles indefinitely, so every coordinate is
//!   revisited with period `shard_len / tile_cols` and the gap memory
//!   ages uniformly.  The wrap position persists across epochs, so
//!   successive epochs continue the rotation instead of re-touching the
//!   shard head.
//!
//! [`DatasetView::shards`]: crate::data::DatasetView::shards
//! [`dots_block`]: crate::data::BlockOps::dots_block
//! [`placement`]: crate::data::Dataset::placement

use crate::sync::{AtomicU64, AtomicUsize, Ordering};

/// One claimed unit of work: the half-open column range `[lo, hi)` and
/// the shard it came from (for per-shard traffic attribution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub lo: usize,
    pub hi: usize,
    pub shard: usize,
}

impl Tile {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Per-shard claim state.  `cursor` is the drain offset (monotone,
/// may overshoot `len`); `wrap` is the cyclic tile counter.
struct Shard {
    lo: usize,
    hi: usize,
    /// Drain offset.  Relaxed: exactly-once handout rests on the
    /// fetch_add's RMW atomicity alone — each claimer gets a distinct
    /// offset; no other memory is published through this word.
    cursor: AtomicUsize,
    /// Cyclic tile counter.  Relaxed: same RMW-uniqueness argument; the
    /// modulo consumer tolerates any interleaving.
    wrap: AtomicUsize,
}

impl Shard {
    fn len(&self) -> usize {
        self.hi - self.lo
    }

    fn remaining(&self) -> usize {
        self.len().saturating_sub(self.cursor.load(Ordering::Relaxed))
    }

    /// Claim the next `tile` columns of this shard, or None if drained.
    fn try_claim(&self, idx: usize, tile: usize) -> Option<Tile> {
        let got = self.cursor.fetch_add(tile, Ordering::Relaxed);
        if got >= self.len() {
            return None;
        }
        Some(Tile {
            lo: self.lo + got,
            hi: self.lo + (got + tile).min(self.len()),
            shard: idx,
        })
    }
}

/// The shard-pinned tile scheduler (module docs).
pub struct TileScheduler {
    shards: Vec<Shard>,
    /// Shard indices with at least one column (cyclic redirect targets).
    nonempty: Vec<usize>,
    tile: usize,
    /// Foreign-shard claims.  Relaxed: diagnostics counter only.
    steals: AtomicU64,
}

impl TileScheduler {
    /// Split `[0, len)` into `workers` shards of `tile_cols`-sized
    /// tiles.  The shard boundaries use the same near-equal arithmetic
    /// as [`DatasetView::shards`] (`base = len / k`, first `len % k`
    /// shards take one extra), so worker `i`'s tile range is exactly
    /// view shard `i`.
    ///
    /// [`DatasetView::shards`]: crate::data::DatasetView::shards
    pub fn new(len: usize, workers: usize, tile_cols: usize) -> Self {
        assert!(workers >= 1, "at least one worker shard");
        assert!(tile_cols >= 1, "tile_cols must be >= 1");
        let base = len / workers;
        let rem = len % workers;
        let mut shards = Vec::with_capacity(workers);
        let mut start = 0usize;
        for i in 0..workers {
            let end = start + base + usize::from(i < rem);
            shards.push(Shard {
                lo: start,
                hi: end,
                cursor: AtomicUsize::new(0),
                wrap: AtomicUsize::new(0),
            });
            start = end;
        }
        let nonempty = (0..workers).filter(|&i| shards[i].len() > 0).collect();
        TileScheduler { shards, nonempty, tile: tile_cols, steals: AtomicU64::new(0) }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn tile_cols(&self) -> usize {
        self.tile
    }

    /// Shard `i`'s column range `[lo, hi)`.
    pub fn shard_bounds(&self, i: usize) -> (usize, usize) {
        (self.shards[i].lo, self.shards[i].hi)
    }

    /// Columns not yet claimed in drain mode.
    pub fn remaining(&self) -> usize {
        self.shards.iter().map(|s| s.remaining()).sum()
    }

    /// Tiles claimed from a foreign shard so far (drain mode).
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Re-arm for another drain pass (also rewinds the cyclic
    /// positions and the steal counter).
    pub fn reset(&self) {
        for s in &self.shards {
            s.cursor.store(0, Ordering::Relaxed);
            s.wrap.store(0, Ordering::Relaxed);
        }
        self.steals.store(0, Ordering::Relaxed);
    }

    /// Drain-mode claim for `worker`: next tile of the pinned shard,
    /// else steal from the heaviest remaining shard.  Returns None only
    /// when every shard is drained — each column is handed out exactly
    /// once per pass.
    pub fn claim(&self, worker: usize) -> Option<Tile> {
        let k = self.shards.len();
        let pin = worker % k;
        if let Some(t) = self.shards[pin].try_claim(pin, self.tile) {
            return Some(t);
        }
        loop {
            let victim = (0..k)
                .filter(|&i| i != pin)
                .max_by_key(|&i| self.shards[i].remaining())?;
            if self.shards[victim].remaining() == 0 {
                return None;
            }
            if let Some(t) = self.shards[victim].try_claim(victim, self.tile) {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
            // lost the race to the victim's last tile — rescan
        }
    }

    /// Wrap-mode claim for `worker`: cycle through the pinned shard's
    /// tiles indefinitely (workers whose own shard is empty are
    /// redirected to a nonempty one).  None only when the whole domain
    /// is empty.
    pub fn claim_cyclic(&self, worker: usize) -> Option<Tile> {
        if self.nonempty.is_empty() {
            return None;
        }
        let pin = worker % self.shards.len();
        let s = if self.shards[pin].len() > 0 {
            pin
        } else {
            self.nonempty[worker % self.nonempty.len()]
        };
        let q = &self.shards[s];
        let n_tiles = q.len().div_ceil(self.tile);
        let i = q.wrap.fetch_add(1, Ordering::Relaxed) % n_tiles;
        let lo = q.lo + i * self.tile;
        Some(Tile { lo, hi: (lo + self.tile).min(q.hi), shard: s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, DatasetKind, Family};

    #[test]
    fn shard_bounds_match_dataset_view_shards() {
        let g = Dataset::generated(DatasetKind::Tiny, Family::Regression, 1.0, 7);
        let n = g.n();
        for k in [1, 2, 3, 5, 7, n, n + 3] {
            let sched = TileScheduler::new(n, k, 8);
            let views = g.view().shards(k);
            assert_eq!(sched.n_shards(), views.len());
            for (i, v) in views.iter().enumerate() {
                let (lo, hi) = sched.shard_bounds(i);
                assert_eq!(hi - lo, v.len(), "shard {i} of {k}");
                if v.len() > 0 {
                    assert_eq!(v.parent_col(0), lo, "shard {i} start");
                    assert_eq!(v.parent_col(v.len() - 1), hi - 1, "shard {i} end");
                }
            }
        }
    }

    #[test]
    fn drain_hands_out_every_column_exactly_once() {
        for (len, workers, tile) in [(100, 4, 8), (37, 3, 16), (5, 8, 4), (64, 1, 8)] {
            let sched = TileScheduler::new(len, workers, tile);
            let mut seen = vec![0u32; len];
            let mut turn = 0usize;
            while let Some(t) = sched.claim(turn % workers) {
                turn += 1;
                assert!(t.hi <= len);
                let (slo, shi) = sched.shard_bounds(t.shard);
                assert!(t.lo >= slo && t.hi <= shi, "tile within its shard");
                for c in t.lo..t.hi {
                    seen[c] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{len}/{workers}/{tile}: {seen:?}");
            assert_eq!(sched.remaining(), 0);
        }
    }

    #[test]
    fn concurrent_drain_is_exactly_once() {
        let (len, workers) = (10_000, 8);
        let sched = TileScheduler::new(len, workers, 16);
        let hits: Vec<crate::sync::AtomicU32> =
            (0..len).map(|_| crate::sync::AtomicU32::new(0)).collect();
        std::thread::scope(|s| {
            for w in 0..workers {
                let (sched, hits) = (&sched, &hits);
                s.spawn(move || {
                    while let Some(t) = sched.claim(w) {
                        for c in t.lo..t.hi {
                            hits[c].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        for (c, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "column {c}");
        }
    }

    #[test]
    fn idle_worker_steals_from_heaviest_shard() {
        // worker 0's shard is tiny; shard 2 is the heaviest victim
        let sched = TileScheduler::new(3 + 10 + 40, 3, 1);
        // carve shards by hand: use new() math — len 53 / 3 = 17,17,... —
        // instead drain shard 0 via worker 0 only and check steals occur
        let mut claimed_own = 0;
        let mut stolen = Vec::new();
        while let Some(t) = sched.claim(0) {
            if t.shard == 0 {
                claimed_own += 1;
            } else {
                stolen.push(t.shard);
            }
        }
        assert!(claimed_own > 0);
        assert!(!stolen.is_empty(), "worker 0 must steal once shard 0 drains");
        assert_eq!(sched.steals(), stolen.len() as u64);
        // first steal hits the heaviest remaining shard (both full: the
        // max_by_key tie-break picks the later one, shard 2)
        assert_eq!(stolen[0], 2);
        assert_eq!(sched.remaining(), 0);
    }

    #[test]
    fn cyclic_claims_wrap_over_own_shard() {
        let sched = TileScheduler::new(40, 2, 8);
        let (lo, hi) = sched.shard_bounds(1);
        let n_tiles = (hi - lo).div_ceil(8);
        let mut starts = Vec::new();
        for _ in 0..2 * n_tiles {
            let t = sched.claim_cyclic(1).unwrap();
            assert_eq!(t.shard, 1, "cyclic claims stay on the pinned shard");
            assert!(t.lo >= lo && t.hi <= hi);
            starts.push(t.lo);
        }
        // two full rotations: every tile seen exactly twice
        starts.sort_unstable();
        starts.dedup();
        assert_eq!(starts.len(), n_tiles);
    }

    #[test]
    fn cyclic_redirects_workers_with_empty_shards() {
        // 3 columns over 8 workers: shards 3..8 are empty
        let sched = TileScheduler::new(3, 8, 4);
        for w in 0..8 {
            let t = sched.claim_cyclic(w).expect("domain is nonempty");
            assert!(t.len() > 0);
            assert!(sched.shard_bounds(t.shard).1 > sched.shard_bounds(t.shard).0);
        }
    }

    #[test]
    fn empty_domain_claims_none() {
        let sched = TileScheduler::new(0, 4, 8);
        assert_eq!(sched.claim(0), None);
        assert_eq!(sched.claim_cyclic(2), None);
        assert_eq!(sched.remaining(), 0);
    }

    #[test]
    fn reset_rearms_a_drained_pass() {
        let sched = TileScheduler::new(32, 2, 8);
        while sched.claim(0).is_some() {}
        assert_eq!(sched.remaining(), 0);
        sched.reset();
        assert_eq!(sched.remaining(), 32);
        assert_eq!(sched.steals(), 0);
        let t = sched.claim(0).unwrap();
        assert_eq!((t.lo, t.shard), (0, 0));
    }

    #[test]
    fn tile_boundaries_are_aligned_within_shards() {
        let sched = TileScheduler::new(1000, 4, 32);
        while let Some(t) = sched.claim(1) {
            let (slo, _) = sched.shard_bounds(t.shard);
            assert_eq!((t.lo - slo) % 32, 0, "tiles start on tile_cols boundaries");
            assert!(t.len() <= 32);
        }
    }
}
