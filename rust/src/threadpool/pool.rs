//! Persistent worker pool with generation-stamped job broadcast.
//!
//! The paper avoids "the overhead of creating and destroying threads"
//! by keeping constant pools for tasks A and B across epochs and
//! coordinating start/stop with counter barriers (§IV-B).  This pool
//! does the same: workers park on a condvar between jobs; `run(f)`
//! publishes one closure to all workers and returns when every worker
//! has finished it.  Borrowed (non-'static) closures are allowed because
//! `run` joins the job before returning — the same contract as
//! `std::thread::scope`, enforced here with a brief unsafe lifetime
//! erasure documented inline.
//!
//! Failure semantics (also mirroring `std::thread::scope`): a job
//! closure that panics on any worker does *not* hang `run()` or kill
//! the worker — the panic is caught, the completion counter is still
//! decremented (via a drop-guard, so even a panic in the bookkeeping
//! cannot leak a count), and the first captured payload is re-raised
//! from `run()` on the caller's thread once every worker has finished.
//! The pool remains fully usable afterwards.  Concurrent `run()` calls
//! from different threads are serialized by a publisher lock — the
//! job/remaining handoff is single-publisher by construction, not by a
//! `debug_assert!` that vanishes in release builds.

use crate::sync::{Condvar, Mutex};

type Job = *const (dyn Fn(usize) + Sync);

struct Shared {
    /// Single-publisher handoff state: `job`/`generation`/`remaining`
    /// move together under this one lock — the mutex (not atomic
    /// ordering) is the publication edge for everything in [`State`].
    state: Mutex<State>,
    start_cv: Condvar,
    done_cv: Condvar,
}

struct State {
    job: Option<SendJob>,
    generation: u64,
    remaining: usize,
    /// First panic payload captured from a worker during the current
    /// job; re-raised by `run()` on the caller's thread.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

/// Raw job pointer made Send; validity is guaranteed by `run`'s joining.
struct SendJob(Job);
// SAFETY: the pointee is `Sync` (so &-calls from any thread are fine)
// and outlives every dereference — `run` publishes the pointer, then
// blocks until all workers report done before the borrow ends.
unsafe impl Send for SendJob {}
impl Clone for SendJob {
    fn clone(&self) -> Self {
        SendJob(self.0)
    }
}

/// Persistent pool of `n` workers with ids `0..n`.
pub struct WorkerPool {
    shared: std::sync::Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes concurrent `run(&self)` publishers: without it two
    /// threads would race on `job`/`remaining` and corrupt the handoff.
    run_lock: Mutex<()>,
    /// The workers' thread ids — `run` refuses (with a panic naming the
    /// bug) to be called from inside a job, which would deadlock on
    /// `run_lock` in every build profile.
    worker_ids: Vec<std::thread::ThreadId>,
    n: usize,
}

impl WorkerPool {
    pub fn new(n: usize) -> Self {
        Self::with_name(n, "hthc-worker")
    }

    /// Named pool ("hthc-a" / "hthc-b" in the coordinator — the paper
    /// pins A and B to disjoint tiles; thread names record the role).
    pub fn with_name(n: usize, name: &str) -> Self {
        assert!(n > 0);
        let shared = std::sync::Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                generation: 0,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            start_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..n)
            .map(|id| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{id}"))
                    .spawn(move || worker_loop(id, &shared))
                    // PANIC-OK: a host that cannot spawn threads cannot
                    // run the solver at all; surface it at pool setup.
                    .expect("spawn worker")
            })
            .collect::<Vec<_>>();
        let worker_ids = handles.iter().map(|h| h.thread().id()).collect();
        WorkerPool { shared, handles, run_lock: Mutex::new(()), worker_ids, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Run `f(worker_id)` on every worker; blocks until all finish.
    ///
    /// If any worker's invocation of `f` panics, the panic payload is
    /// re-raised here on the caller's thread *after* every worker has
    /// finished the job (so the borrowed-closure contract still holds)
    /// and the pool stays usable for subsequent `run`s.  Concurrent
    /// callers on different threads are serialized, not corrupted.
    pub fn run<'a, F>(&self, f: F)
    where
        F: Fn(usize) + Sync + 'a,
    {
        // A job closure calling back into run() would deadlock on the
        // publisher lock below; fail loudly (in every profile) instead.
        assert!(
            !self.worker_ids.contains(&std::thread::current().id()),
            "WorkerPool::run called reentrantly from a worker job"
        );
        // One publisher at a time: the job/remaining handoff below is
        // single-publisher state (a poisoned lock just means a previous
        // run re-raised a job panic — publishing is still safe).
        let _publish = self.run_lock.lock().unwrap_or_else(|e| e.into_inner());
        let job_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the pointer is only dereferenced by workers between the
        // publish below and the `remaining == 0` wait; `f` outlives both
        // because this function does not return until the wait completes.
        let job_ptr: Job = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(job_ref) as Job
        };
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.job = Some(SendJob(job_ptr));
        st.generation = st.generation.wrapping_add(1);
        st.remaining = self.n;
        self.shared.start_cv.notify_all();
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        if let Some(payload) = st.panic.take() {
            // state is clean again (job cleared, panic consumed): the
            // pool survives; the caller observes the job's panic
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }
}

/// Decrements `remaining` (and wakes the publisher at zero) on drop, so
/// the count is released on every exit path from a job — including a
/// panic escaping the worker's bookkeeping itself.
struct DoneGuard<'a>(&'a Shared);

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap_or_else(|e| e.into_inner());
        st.remaining -= 1;
        if st.remaining == 0 {
            self.0.done_cv.notify_all();
        }
    }
}

fn worker_loop(id: usize, shared: &Shared) {
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen_gen {
                    seen_gen = st.generation;
                    // PANIC-OK: the publisher stores `job` and bumps
                    // `generation` under the same lock; a fresh
                    // generation with no job is unreachable.
                    break st.job.clone().expect("job set with generation");
                }
                st = shared.start_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // The guard decrements `remaining` on every exit path; a
        // panicking job must neither hang `run()` nor kill this worker.
        let _done = DoneGuard(shared);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: see `run` — the closure outlives this call.
            unsafe { (*job.0)(id) }
        }));
        if let Err(payload) = result {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            // keep the first payload; later ones add no information
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
            self.shared.start_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{AtomicUsize, Ordering};

    #[test]
    fn all_workers_run_each_job() {
        let pool = WorkerPool::new(4);
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        pool.run(|_| {
            count.fetch_add(10, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 44);
    }

    #[test]
    fn worker_ids_are_distinct() {
        let pool = WorkerPool::new(8);
        let seen: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.run(|id| {
            seen[id].fetch_add(1, Ordering::SeqCst);
        });
        for s in &seen {
            assert_eq!(s.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn borrows_local_state() {
        let pool = WorkerPool::new(3);
        let data = vec![1.0f32; 100]; // NOT 'static
        let sum = AtomicUsize::new(0);
        pool.run(|id| {
            let part: f32 = data[id * 10..(id + 1) * 10].iter().sum();
            sum.fetch_add(part as usize, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 30);
    }

    #[test]
    fn many_epochs_no_thread_churn() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(4);
        pool.run(|_| {});
        drop(pool); // must not hang or panic
    }

    /// Regression (issue 4): a panicking job must neither hang `run()`
    /// forever nor poison the pool — the panic propagates to the
    /// caller and the very next `run` completes normally on all
    /// workers (the `#[should_panic]`-style check is done manually so
    /// the same test can also exercise the pool afterwards).
    #[test]
    fn panicking_job_propagates_and_pool_stays_usable() {
        let pool = WorkerPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|id| {
                if id == 1 {
                    panic!("boom from worker 1");
                }
            });
        }));
        let payload = result.expect_err("job panic must re-raise from run()");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom from worker 1", "original payload preserved");

        // the dead-worker epoch poison is gone: all 4 workers run again
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn all_workers_panicking_still_terminates() {
        let pool = WorkerPool::new(3);
        for _ in 0..5 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(|_| panic!("everyone"));
            }));
            assert!(r.is_err());
        }
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    /// A job closure that calls back into `run` must fail loudly (the
    /// reentrancy panic propagates like any job panic, and the pool
    /// stays usable) rather than silently deadlock on the publisher
    /// lock.
    #[test]
    fn reentrant_run_from_a_job_panics_instead_of_deadlocking() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|id| {
                if id == 0 {
                    pool.run(|_| {});
                }
            });
        }));
        assert!(r.is_err(), "reentrant run must panic, not hang");
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    /// Regression (issue 4): concurrent `run(&self)` from two threads
    /// used to race on `job`/`remaining` with only a `debug_assert!`
    /// in the way; the publisher lock serializes them.  Every job must
    /// still execute on every worker exactly once.
    #[test]
    fn concurrent_run_from_two_threads_serializes() {
        let pool = WorkerPool::new(3);
        let count = AtomicUsize::new(0);
        let rounds = 50;
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    for _ in 0..rounds {
                        pool.run(|_| {
                            count.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(count.load(Ordering::SeqCst), 2 * rounds * 3);
    }
}
