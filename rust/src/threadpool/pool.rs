//! Persistent worker pool with generation-stamped job broadcast.
//!
//! The paper avoids "the overhead of creating and destroying threads"
//! by keeping constant pools for tasks A and B across epochs and
//! coordinating start/stop with counter barriers (§IV-B).  This pool
//! does the same: workers park on a condvar between jobs; `run(f)`
//! publishes one closure to all workers and returns when every worker
//! has finished it.  Borrowed (non-'static) closures are allowed because
//! `run` joins the job before returning — the same contract as
//! `std::thread::scope`, enforced here with a brief unsafe lifetime
//! erasure documented inline.

use std::sync::{Condvar, Mutex};

type Job = *const (dyn Fn(usize) + Sync);

struct Shared {
    state: Mutex<State>,
    start_cv: Condvar,
    done_cv: Condvar,
}

struct State {
    job: Option<SendJob>,
    generation: u64,
    remaining: usize,
    shutdown: bool,
}

/// Raw job pointer made Send; validity is guaranteed by `run`'s joining.
struct SendJob(Job);
unsafe impl Send for SendJob {}
impl Clone for SendJob {
    fn clone(&self) -> Self {
        SendJob(self.0)
    }
}

/// Persistent pool of `n` workers with ids `0..n`.
pub struct WorkerPool {
    shared: std::sync::Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n: usize,
}

impl WorkerPool {
    pub fn new(n: usize) -> Self {
        Self::with_name(n, "hthc-worker")
    }

    /// Named pool ("hthc-a" / "hthc-b" in the coordinator — the paper
    /// pins A and B to disjoint tiles; thread names record the role).
    pub fn with_name(n: usize, name: &str) -> Self {
        assert!(n > 0);
        let shared = std::sync::Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                generation: 0,
                remaining: 0,
                shutdown: false,
            }),
            start_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..n)
            .map(|id| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{id}"))
                    .spawn(move || worker_loop(id, &shared))
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { shared, handles, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Run `f(worker_id)` on every worker; blocks until all finish.
    pub fn run<'a, F>(&self, f: F)
    where
        F: Fn(usize) + Sync + 'a,
    {
        let job_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the pointer is only dereferenced by workers between the
        // publish below and the `remaining == 0` wait; `f` outlives both
        // because this function does not return until the wait completes.
        let job_ptr: Job = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(job_ref) as Job
        };
        let mut st = self.shared.state.lock().unwrap();
        debug_assert!(st.job.is_none(), "run() is not reentrant");
        st.job = Some(SendJob(job_ptr));
        st.generation = st.generation.wrapping_add(1);
        st.remaining = self.n;
        self.shared.start_cv.notify_all();
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }
}

fn worker_loop(id: usize, shared: &Shared) {
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen_gen {
                    seen_gen = st.generation;
                    break st.job.clone().expect("job set with generation");
                }
                st = shared.start_cv.wait(st).unwrap();
            }
        };
        // SAFETY: see `run` — the closure outlives this call.
        unsafe { (*job.0)(id) };
        let mut st = shared.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.start_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_workers_run_each_job() {
        let pool = WorkerPool::new(4);
        let count = AtomicUsize::new(0);
        pool.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        pool.run(|_| {
            count.fetch_add(10, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 44);
    }

    #[test]
    fn worker_ids_are_distinct() {
        let pool = WorkerPool::new(8);
        let seen: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.run(|id| {
            seen[id].fetch_add(1, Ordering::SeqCst);
        });
        for s in &seen {
            assert_eq!(s.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn borrows_local_state() {
        let pool = WorkerPool::new(3);
        let data = vec![1.0f32; 100]; // NOT 'static
        let sum = AtomicUsize::new(0);
        pool.run(|id| {
            let part: f32 = data[id * 10..(id + 1) * 10].iter().sum();
            sum.fetch_add(part as usize, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 30);
    }

    #[test]
    fn many_epochs_no_thread_churn() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(4);
        pool.run(|_| {});
        drop(pool); // must not hang or panic
    }
}
