//! Counter-based barriers (paper §IV-B, after [15]).
//!
//! pthread barriers are "relatively expensive"; the paper replaces them
//! with integer counters protected by mutexes.  [`CounterBarrier`] is
//! that scheme (sense-reversing generation counter + condvar for the
//! epoch-level waits); [`SpinBarrier`] is the lock-free variant for task
//! B's per-update synchronization, where the expected wait is far below
//! a scheduler quantum.

use crate::sync::spin::SpinWait;
use crate::sync::{AtomicUsize, Condvar, Mutex, Ordering};

/// Sense-reversing barrier on a mutex-protected counter.
pub struct CounterBarrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived, generation)
    cv: Condvar,
}

impl CounterBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        CounterBarrier { n, state: Mutex::new((0, 0)), cv: Condvar::new() }
    }

    /// Block until all `n` participants arrive.  Returns true for
    /// exactly one "leader" per round (the last arriver).  A poisoned
    /// lock (a participant panicked mid-round) is recovered: the
    /// counter state itself is never left torn by a panic, so the
    /// surviving participants keep synchronizing.
    pub fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 = st.1.wrapping_add(1);
            self.cv.notify_all();
            true
        } else {
            while st.1 == gen {
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            false
        }
    }
}

/// Spin barrier: atomic counter + generation, no syscalls.
///
/// Used around task B's shared-scalar-product phases where V_B threads
/// synchronize several times *per coordinate update* (paper §IV-B: three
/// barriers per update) — the wait is short enough that parking would
/// dominate.
pub struct SpinBarrier {
    n: usize,
    /// Arrivals this round.  AcqRel on the increment: the last arriver
    /// must observe every earlier participant's pre-barrier writes
    /// before it opens the next generation.  The reset store is
    /// Relaxed: it is ordered for waiters by the `generation` Release
    /// below (no waiter reads `arrived` before passing the generation
    /// Acquire).
    arrived: AtomicUsize,
    /// Round counter.  Release on open / Acquire on the spin-read:
    /// *this* edge publishes all pre-barrier writes to every waiter.
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        SpinBarrier { n, arrived: AtomicUsize::new(0), generation: AtomicUsize::new(0) }
    }

    /// Spin until all `n` arrive.  Returns true for the last arriver.
    pub fn wait(&self) -> bool {
        let gen = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) == self.n - 1 {
            self.arrived.store(0, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
            true
        } else {
            // Bounded spin-then-yield (sync::spin::SpinWait): short
            // waits stay on the PAUSE fast path, stragglers yield so
            // the remaining participants can actually run on an
            // oversubscribed or single-core host.
            let mut sw = SpinWait::new();
            while self.generation.load(Ordering::Acquire) == gen {
                sw.spin();
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn exercise_barrier(wait: impl Fn() -> bool + Sync, n: usize, rounds: usize) {
        let phase = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..n {
                s.spawn(|| {
                    for r in 0..rounds {
                        // Everybody must observe the same phase before the
                        // barrier releases anyone into the next round.
                        assert_eq!(phase.load(Ordering::SeqCst) / n, r);
                        phase.fetch_add(1, Ordering::SeqCst);
                        wait();
                    }
                });
            }
        });
        assert_eq!(phase.load(Ordering::SeqCst), n * rounds);
    }

    #[test]
    fn counter_barrier_synchronizes_rounds() {
        let b = CounterBarrier::new(4);
        exercise_barrier(|| b.wait(), 4, 20);
    }

    #[test]
    fn spin_barrier_synchronizes_rounds() {
        let b = SpinBarrier::new(3);
        exercise_barrier(|| b.wait(), 3, 50);
    }

    #[test]
    fn exactly_one_leader_per_round() {
        let b = Arc::new(CounterBarrier::new(5));
        let leaders = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..5 {
                s.spawn(|| {
                    for _ in 0..10 {
                        if b.wait() {
                            leaders.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        assert_eq!(leaders.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn single_participant_never_blocks() {
        let b = CounterBarrier::new(1);
        let sb = SpinBarrier::new(1);
        for _ in 0..100 {
            assert!(b.wait());
            assert!(sb.wait());
        }
    }
}
