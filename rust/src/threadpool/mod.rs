//! Explicit thread control (paper §IV-A/B).
//!
//! HTHC's core engineering claim is that *detailed thread control* —
//! persistent pools, explicit task-to-core assignment, cheap
//! counter-based barriers instead of heavyweight primitives — beats
//! straightforward OpenMP by an order of magnitude.  This module is the
//! rust equivalent of the paper's pthreads layer:
//!
//! * [`CounterBarrier`] / [`SpinBarrier`] — the "integer counters
//!   protected by mutexes" barrier replacement (after Franchetti's fast
//!   x86 barrier, paper ref [15]); the spin variant is used inside task
//!   B's per-update V_B synchronization where waits are ~ns.
//! * [`WorkerPool`] — a persistent pool with generation-stamped job
//!   broadcast, so epochs start/stop tasks without creating or
//!   destroying threads (paper §IV-B, "thread pool with a constant
//!   number of threads for A and B").

pub mod barrier;
pub mod pool;

pub use barrier::{CounterBarrier, SpinBarrier};
pub use pool::WorkerPool;
