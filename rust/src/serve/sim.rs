//! Bounded in-process serving run — the `hthc serve` engine room.
//!
//! No sockets (ROADMAP simulate-first sequencing): an in-process
//! request generator drives [`PredictEngine`] with perturbed copies of
//! real examples while a background thread runs the
//! [`Refitter`] cadence over an [`IngestBuffer`] that the request loop
//! feeds.  The run is wall-clock bounded and returns a [`ServeReport`]
//! (throughput, latency quantiles, refit counters, final certificate)
//! that the CLI renders and the serve benchmark records.

use super::{
    IngestBuffer, ModelSnapshot, ModelStore, PredictEngine, Refitter, RefitConfig,
    RetentionPolicy, ServeStats,
};
use crate::data::{DatasetBuilder, Sample, SparseMatrix};
use crate::memory::TierSim;
use crate::solver::{by_name, Trainer};
use crate::sync::{AtomicBool, Ordering::Relaxed};
use crate::util::Rng;
use crate::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Knobs for one bounded serving run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Wall-clock budget for the request loop.
    pub duration_secs: f64,
    /// Rows per predict request.
    pub batch: usize,
    /// Predict-pool workers (1 = serial).
    pub threads: usize,
    /// Examples streamed into the ingest buffer per request round.
    pub ingest_per_round: usize,
    /// Hard capacity of the ingest buffer (0 = unbounded); past it the
    /// oldest buffered example is dropped and counted.
    pub ingest_cap: usize,
    /// Refit cadence, budget, publish tolerance and corpus retention
    /// policy (`refit.retention`).
    pub refit: RefitConfig,
    /// Preprocessing flags shared by the initial fit and every refit.
    pub normalize: bool,
    pub center: bool,
    /// Model name (see [`crate::glm::model_by_name`]).
    pub model: String,
    pub lam: f32,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            duration_secs: 5.0,
            batch: 64,
            threads: 2,
            ingest_per_round: 4,
            ingest_cap: 0,
            refit: RefitConfig::default(),
            normalize: true,
            center: true,
            model: "lasso".into(),
            lam: 1e-3,
            seed: 42,
        }
    }
}

/// Outcome of a bounded serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub elapsed_secs: f64,
    pub requests: u64,
    pub rows: u64,
    pub qps: f64,
    pub rows_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub published: u64,
    pub rejected: u64,
    pub failed: u64,
    pub attempts: u64,
    pub ingested: u64,
    /// Examples the bounded ingest buffer dropped under backpressure.
    pub ingest_dropped: u64,
    /// Samples the retention policy forgot from the training corpus.
    pub corpus_evicted: u64,
    /// High-water mark of the retained corpus.
    pub corpus_peak: u64,
    /// Retained corpus size at the end of the run.
    pub corpus_size: u64,
    pub final_version: u64,
    pub final_gap: f64,
    pub staleness_secs: f64,
    pub absorbed: u64,
}

impl ServeReport {
    /// The serve-smoke gate: at least one refit published and requests
    /// actually flowed.
    pub fn healthy(&self) -> bool {
        self.published >= 1 && self.rows > 0
    }

    pub fn render(&self) -> String {
        format!(
            "serve: {:.1}s, {} requests ({} rows) — {:.0} req/s, {:.0} rows/s\n\
             latency: p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms\n\
             refits: {} published / {} rejected / {} failed ({} attempts), \
             {} examples ingested\n\
             memory: {} ingest dropped, {} corpus evicted, \
             corpus {} retained (peak {})\n\
             live model: v{} gap {:.3e}, staleness {:.1}s, {} absorbed examples",
            self.elapsed_secs,
            self.requests,
            self.rows,
            self.qps,
            self.rows_per_sec,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.published,
            self.rejected,
            self.failed,
            self.attempts,
            self.ingested,
            self.ingest_dropped,
            self.corpus_evicted,
            self.corpus_size,
            self.corpus_peak,
            self.final_version,
            self.final_gap,
            self.staleness_secs,
            self.absorbed,
        )
    }
}

/// Perturb a base sample into a plausible fresh example: features
/// jittered ~1%, label jittered likewise (regression) or kept
/// (classification labels stay in the sign alphabet).
fn perturb(base: &Sample, classification: bool, rng: &mut Rng) -> Sample {
    Sample {
        label: if classification {
            base.label
        } else {
            base.label + 0.01 * rng.normal()
        },
        features: base
            .features
            .iter()
            .map(|&(j, x)| (j, x * (1.0 + 0.01 * rng.normal())))
            .collect(),
    }
}

/// Build request batches from base samples: each batch is a sparse
/// matrix whose columns are perturbed raw input vectors (features at or
/// past `input_dim` dropped — the predict path ignores them anyway, but
/// the matrix shape must stay within the snapshot's input space).
fn request_batches(
    base: &[Sample],
    input_dim: usize,
    batch: usize,
    count: usize,
    rng: &mut Rng,
) -> Vec<SparseMatrix> {
    (0..count)
        .map(|b| {
            let cols: Vec<Vec<(u32, f32)>> = (0..batch)
                .map(|k| {
                    let s = &base[(b * batch + k) % base.len()];
                    s.features
                        .iter()
                        .filter(|&&(j, _)| (j as usize) < input_dim)
                        .map(|&(j, x)| (j, x * (1.0 + 0.01 * rng.normal())))
                        .collect()
                })
                .collect();
            SparseMatrix::from_columns(input_dim, cols)
        })
        .collect()
}

/// Run the bounded serving simulation (see module docs): initial fit →
/// serve + ingest until the deadline with the refit loop on its own
/// thread → report.  If the bounded window closed before any publish
/// (slow host, long refit), one synchronous refit runs after the loop
/// so the warm-start path is always exercised.
pub fn run(base: Vec<Sample>, cfg: &ServeConfig) -> Result<ServeReport> {
    if base.is_empty() {
        bail!("serve: no base samples");
    }
    if cfg.batch == 0 {
        bail!("serve: batch must be positive");
    }
    let family = crate::glm::family_for(&cfg.model);
    let classification = family == crate::data::Family::Classification;

    // -- initial fit ---------------------------------------------------
    let ds = DatasetBuilder::libsvm_samples(base.clone())
        .family(family)
        .normalize(cfg.normalize)
        .center_targets(cfg.center && !classification)
        .build()?;
    let Some(mut model) = crate::glm::model_by_name(&cfg.model, cfg.lam, ds.n_cols()) else {
        bail!("serve: unknown model {:?}", cfg.model);
    };
    let Some(engine) = by_name(&cfg.refit.solver) else {
        bail!("serve: unknown solver {:?}", cfg.refit.solver);
    };
    let (t_a, t_b, v_b) = cfg.refit.threads;
    let report = Trainer::new()
        .solver_boxed(engine)
        .threads(t_a, t_b, v_b)
        .stop_when(cfg.refit.budget)
        .seed(cfg.refit.seed)
        .fit_with(model.as_mut(), &ds, &TierSim::default());
    let gap = crate::glm::total_gap(
        model.as_ref(),
        ds.as_block_ops(),
        &report.v,
        ds.targets(),
        &report.alpha,
    );
    let store = Arc::new(ModelStore::new(ModelSnapshot::from_fit(
        model.as_ref(),
        &ds,
        &report,
        gap,
        0,
    )));
    drop(ds);

    // -- serving loop --------------------------------------------------
    let stats = Arc::new(ServeStats::new());
    let predict = PredictEngine::new(Arc::clone(&store))
        .with_threads(cfg.threads)
        .with_stats(Arc::clone(&stats));
    let mut rng = Rng::new(cfg.seed ^ 0x5e7e);
    let input_dim = store.load().input_dim();
    let batches = request_batches(&base, input_dim, cfg.batch, 8, &mut rng);

    let buf = IngestBuffer::bounded(cfg.ingest_cap);
    let mut refitter = Refitter::new(
        base.clone(),
        &cfg.model,
        cfg.lam,
        cfg.normalize,
        cfg.center && !classification,
        cfg.refit.clone(),
    );
    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs_f64(cfg.duration_secs);

    std::thread::scope(|s| {
        let refit_handle = s.spawn(|| {
            while !stop.load(Relaxed) {
                if refitter.should_refit(buf.len()) {
                    refitter.refit_once(&store, &buf, &stats);
                } else {
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        });
        let mut round = 0usize;
        while Instant::now() < deadline {
            predict.predict_batch(&batches[round % batches.len()]);
            if cfg.ingest_per_round > 0 {
                let fresh: Vec<Sample> = (0..cfg.ingest_per_round)
                    .map(|k| {
                        perturb(
                            &base[(round * cfg.ingest_per_round + k) % base.len()],
                            classification,
                            &mut rng,
                        )
                    })
                    .collect();
                stats.ingested.fetch_add(fresh.len() as u64, Relaxed);
                buf.push_many(fresh);
            }
            round += 1;
        }
        stop.store(true, Relaxed);
        // PANIC-OK: a refit-thread panic must fail the run loudly.
        refit_handle.join().expect("refit thread panicked");
    });

    // the smoke gate needs at least one exercised refit: if the window
    // closed before the cadence fired (or every attempt lost the race),
    // run one synchronously on whatever is buffered
    if stats.published() == 0 {
        if buf.is_empty() {
            let seeded: Vec<Sample> = base
                .iter()
                .take(4)
                .map(|s| perturb(s, classification, &mut rng))
                .collect();
            stats.ingested.fetch_add(seeded.len() as u64, Relaxed);
            buf.push_many(seeded);
        }
        refitter.refit_once(&store, &buf, &stats);
    }

    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let live = store.load();
    Ok(ServeReport {
        elapsed_secs: elapsed,
        requests: stats.requests(),
        rows: stats.rows(),
        qps: stats.requests() as f64 / elapsed,
        rows_per_sec: stats.rows() as f64 / elapsed,
        p50_ms: stats.latency.p50() * 1e3,
        p95_ms: stats.latency.p95() * 1e3,
        p99_ms: stats.latency.p99() * 1e3,
        published: stats.published(),
        rejected: stats.rejected(),
        failed: stats.failed(),
        attempts: stats.attempts(),
        ingested: stats.ingested(),
        // read the primary sources, not the stats mirrors — drops after
        // the last refit drain must still be reported
        ingest_dropped: buf.dropped(),
        corpus_evicted: refitter.corpus_evicted(),
        corpus_peak: refitter.corpus_peak() as u64,
        corpus_size: refitter.sample_count() as u64,
        final_version: live.version,
        final_gap: live.gap,
        staleness_secs: live.staleness_secs(),
        absorbed: live.absorbed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, Family};
    use crate::solver::StopWhen;

    fn base_samples(seed: u64) -> Vec<Sample> {
        DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(seed)
            .build()
            .unwrap()
            .to_samples()
            .unwrap()
    }

    #[test]
    fn bounded_run_serves_and_publishes() {
        let cfg = ServeConfig {
            duration_secs: 0.4,
            batch: 16,
            threads: 2,
            ingest_per_round: 8,
            refit: RefitConfig {
                refit_every: 16,
                solver: "st".into(),
                budget: StopWhen::gap_below(1e-6).max_epochs(100).timeout_secs(5.0),
                ..Default::default()
            },
            model: "lasso".into(),
            lam: 1e-3,
            ..Default::default()
        };
        let report = run(base_samples(81), &cfg).unwrap();
        assert!(report.rows > 0, "no rows served: {report:?}");
        assert!(report.requests > 0);
        assert!(report.healthy(), "expected >=1 publish: {report:?}");
        assert!(report.final_version >= 2, "{report:?}");
        assert!(report.qps > 0.0);
        assert!(report.p99_ms >= report.p50_ms);
        let text = report.render();
        assert!(text.contains("req/s"), "{text}");
        assert!(text.contains("published"), "{text}");
    }

    /// Bounded run: small ingest cap + reservoir corpus cap, heavy
    /// ingest.  Everything stays within its cap and the caps are
    /// visible in the report.
    #[test]
    fn capped_run_bounds_buffer_and_corpus() {
        let base = base_samples(91);
        let cap = base.len(); // reservoir the corpus at its initial size
        let cfg = ServeConfig {
            duration_secs: 0.4,
            batch: 16,
            threads: 2,
            ingest_per_round: 16, // outrun the refit cadence
            ingest_cap: 32,
            refit: RefitConfig {
                refit_every: 16,
                solver: "st".into(),
                budget: StopWhen::gap_below(1e-6).max_epochs(100).timeout_secs(5.0),
                retention: RetentionPolicy::Reservoir { cap },
                ..Default::default()
            },
            model: "lasso".into(),
            lam: 1e-3,
            ..Default::default()
        };
        let report = run(base, &cfg).unwrap();
        assert!(report.rows > 0, "{report:?}");
        assert!(report.healthy(), "capped run must still publish: {report:?}");
        assert!(
            report.corpus_peak <= cap as u64,
            "corpus peak {} exceeded cap {cap}",
            report.corpus_peak
        );
        assert!(report.corpus_size <= cap as u64, "{report:?}");
        assert!(
            report.corpus_evicted > 0,
            "heavy ingest over a full reservoir must evict: {report:?}"
        );
        let text = report.render();
        assert!(text.contains("corpus"), "{text}");
        assert!(text.contains("dropped"), "{text}");
    }

    #[test]
    fn rejects_empty_base_and_zero_batch() {
        assert!(run(vec![], &ServeConfig::default()).is_err());
        let cfg = ServeConfig { batch: 0, ..Default::default() };
        assert!(run(base_samples(82), &cfg).is_err());
    }
}
