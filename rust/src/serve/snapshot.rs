//! [`ModelSnapshot`]: one immutable, versioned serving model.
//!
//! A snapshot folds everything prediction needs into raw-input space at
//! publish time, so the per-request path touches no dataset metadata:
//!
//! * **Regression orientation** (rows = samples, coordinates =
//!   features): the trained dual iterate `alpha` lives in the
//!   *normalized* column space; the serving weights fold the recorded
//!   column scales back in (`weights_j = alpha_j * col_scales_j`) and
//!   the target-centering mean becomes the bias, so
//!   `predict(x_raw) = <weights, x_raw> + bias`.
//! * **Classification orientation** (columns = label-scaled samples
//!   `d_j = y_j x_j`): the primal weight vector is proportional to the
//!   shared vector `v = D alpha`, which already lives in raw feature
//!   space (normalization scales columns, not feature rows), so
//!   `weights = v`, bias 0, and `sign(<weights, x_raw>)` classifies.
//!
//! The snapshot also carries the warm-start seed (`alpha` in normalized
//! training space), the duality-gap certificate of the fit that
//! produced it, and staleness bookkeeping (publish instant + streamed
//! examples absorbed into its training set).

use crate::data::{Dataset, Family};
use crate::glm::{GlmModel, ModelKind};
use crate::solver::{FitReport, Iterate};
use std::time::Instant;

/// One immutable serving model version (see module docs).
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Assigned by [`super::ModelStore::publish`]; monotone from 1.
    pub version: u64,
    /// Scalar-math bundle of the model that produced this snapshot.
    pub kind: ModelKind,
    /// Orientation the model was trained in (decides the weight map).
    pub family: Family,
    /// Prediction weights in **raw input space** (see module docs).
    pub weights: Vec<f32>,
    /// Additive bias (`target_mean` of a centered regression fit).
    pub bias: f32,
    /// Dual iterate in normalized training space — the warm-start seed
    /// for the next refit.
    pub alpha: Vec<f32>,
    /// Column scales the training pipeline applied (None = unnormalized).
    pub col_scales: Option<Vec<f32>>,
    /// Duality-gap certificate of the producing fit (the publish rule's
    /// input, and the live freshness/quality metric per version).
    pub gap: f64,
    /// Columns (model coordinates) of the producing training set.
    pub trained_cols: usize,
    /// Streamed examples absorbed into the training set by refits.
    pub absorbed: u64,
    /// When this version went live.
    pub published_at: Instant,
}

impl ModelSnapshot {
    /// Build a snapshot from a finished fit on `data`.
    ///
    /// `gap` is the certificate to record (callers recompute it with
    /// [`crate::glm::total_gap`] so every engine gets a comparable
    /// certificate, including ones whose traces carry NaN gaps).
    pub fn from_fit(
        model: &dyn GlmModel,
        data: &Dataset,
        report: &FitReport,
        gap: f64,
        absorbed: u64,
    ) -> Self {
        let meta = data.meta();
        let weights = match meta.family {
            Family::Regression => match &meta.col_scales {
                Some(scales) => report
                    .alpha
                    .iter()
                    .zip(scales)
                    .map(|(&a, &s)| a * s)
                    .collect(),
                None => report.alpha.clone(),
            },
            Family::Classification => report.v.clone(),
        };
        ModelSnapshot {
            version: 0, // assigned at publish
            kind: model.kind(),
            family: meta.family,
            weights,
            bias: meta.target_mean.unwrap_or(0.0),
            alpha: report.alpha.clone(),
            col_scales: meta.col_scales.clone(),
            gap,
            trained_cols: data.n_cols(),
            absorbed,
            published_at: Instant::now(),
        }
    }

    /// Length of a raw input vector this snapshot can score.
    pub fn input_dim(&self) -> usize {
        self.weights.len()
    }

    /// Seconds since this version went live.
    pub fn staleness_secs(&self) -> f64 {
        self.published_at.elapsed().as_secs_f64()
    }

    /// Export the training iterate (the `solver`-layer warm-start
    /// currency: feed to [`crate::solver::Trainer::warm_start_from`]).
    pub fn iterate(&self) -> Iterate {
        Iterate {
            alpha: self.alpha.clone(),
            gap: Some(self.gap),
        }
    }

    /// Remap this snapshot's iterate into a rebuild's column space.
    ///
    /// `alpha` was recorded in the *old* normalization: coordinate `j`
    /// multiplies a column that was scaled by `self.col_scales[j]`, so
    /// the raw-space weight it encodes is `alpha_j * s_old_j`.  A
    /// rebuild re-normalizes with its own `new_scales`, and preserving
    /// the raw-space weight requires
    /// `alpha_new_j = alpha_j * s_old_j / s_new_j` — feeding the stale
    /// alpha through unchanged silently rescales every weight by
    /// `s_new_j / s_old_j` and can start the fit *farther* from the
    /// optimum than zero.  Columns new to the rebuild start at zero;
    /// degenerate scales (zero/non-finite ratios) also fall back to
    /// zero rather than poisoning the iterate.
    pub fn remapped_alpha(&self, new_scales: Option<&[f32]>, n_cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n_cols];
        for (j, slot) in out.iter_mut().enumerate().take(self.alpha.len()) {
            let a = self.alpha[j];
            let s_old = self
                .col_scales
                .as_ref()
                .and_then(|s| s.get(j).copied())
                .unwrap_or(1.0);
            let s_new = new_scales.and_then(|s| s.get(j).copied()).unwrap_or(1.0);
            let remapped = a * s_old / s_new;
            *slot = if remapped.is_finite() { remapped } else { 0.0 };
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetBuilder, DatasetKind};
    use crate::glm::Lasso;
    use crate::solver::{SeqThreshold, StopWhen, Trainer};

    #[test]
    fn regression_snapshot_folds_scales_and_mean() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(41)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        let mut model = Lasso::new(0.01);
        let mut trainer = Trainer::new()
            .solver(SeqThreshold)
            .stop_when(StopWhen::gap_below(1e-6).max_epochs(50));
        let report = trainer.fit_with(&mut model, &ds, &Default::default());
        let gap = crate::glm::total_gap(
            &model,
            ds.as_block_ops(),
            &report.v,
            ds.targets(),
            &report.alpha,
        );
        let snap = ModelSnapshot::from_fit(&model, &ds, &report, gap, 3);
        let scales = ds.meta().col_scales.as_ref().unwrap();
        for j in 0..ds.n_cols() {
            assert_eq!(snap.weights[j], report.alpha[j] * scales[j]);
        }
        assert_eq!(snap.bias, ds.meta().target_mean.unwrap());
        assert_eq!(snap.input_dim(), ds.n_cols());
        assert_eq!(snap.absorbed, 3);
        assert_eq!(snap.iterate().alpha, report.alpha);
    }

    #[test]
    fn remapped_alpha_preserves_raw_weights() {
        let snap = ModelSnapshot {
            version: 1,
            kind: Lasso::new(0.01).kind(),
            family: Family::Regression,
            weights: vec![0.0; 3],
            bias: 0.0,
            alpha: vec![2.0, -4.0, 8.0],
            col_scales: Some(vec![0.5, 0.25, 2.0]),
            gap: 1e-6,
            trained_cols: 3,
            absorbed: 0,
            published_at: std::time::Instant::now(),
        };
        // rebuild re-normalized differently and grew by two columns
        let new_scales = [1.0f32, 0.5, 2.0, 4.0, 8.0];
        let out = snap.remapped_alpha(Some(&new_scales), 5);
        assert_eq!(out.len(), 5);
        for j in 0..3 {
            // raw-space weight must be invariant: a_new * s_new == a_old * s_old
            assert!(
                (out[j] * new_scales[j] - snap.alpha[j] * snap.col_scales.as_ref().unwrap()[j])
                    .abs()
                    < 1e-6
            );
        }
        assert_eq!(&out[3..], &[0.0, 0.0], "new columns start cold");
        // degenerate new scale (zeroed column) must not poison the iterate
        let out = snap.remapped_alpha(Some(&[1.0, 0.0, 1.0]), 3);
        assert_eq!(out[1], 0.0);
        assert!(out.iter().all(|a| a.is_finite()));
        // unnormalized rebuild: old scales fold in, new default to 1
        let out = snap.remapped_alpha(None, 3);
        assert_eq!(out, vec![1.0, -1.0, 16.0]);
    }

    #[test]
    fn classification_snapshot_serves_v() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Classification)
            .seed(42)
            .build()
            .unwrap();
        let mut model = crate::glm::SvmDual::new(0.01, ds.n_cols());
        let mut trainer = Trainer::new()
            .solver(SeqThreshold)
            .stop_when(StopWhen::gap_below(1e-6).max_epochs(50));
        let report = trainer.fit_with(&mut model, &ds, &Default::default());
        let snap = ModelSnapshot::from_fit(&model, &ds, &report, 0.0, 0);
        assert_eq!(snap.weights, report.v, "classification serves v directly");
        assert_eq!(snap.bias, 0.0);
        assert_eq!(snap.input_dim(), ds.n_rows());
    }
}
