//! Streaming ingest and the warm-start refit loop.
//!
//! [`IngestBuffer`] accumulates raw labeled examples; a [`Refitter`]
//! drains it on a configurable cadence (example count or elapsed time),
//! rebuilds the training set through the one [`DatasetBuilder`]
//! pipeline (base samples + everything absorbed so far, re-normalized
//! together), warm-starts a [`Trainer`] fit from the live snapshot's
//! iterate, and publishes the result **only if the duality-gap
//! certificate does not regress** beyond a tolerance
//! ([`publish_decision`]).  A failed or diverged refit keeps the old
//! version serving and is counted — graceful degradation, never a
//! serving gap.
//!
//! The refit budget is an ordinary [`StopWhen`], so count-based and
//! wall-clock-bounded refits use the same stopping machinery as any
//! other fit.

use super::{ModelSnapshot, ModelStore, ServeStats};
use crate::data::{Dataset, DatasetBuilder, Family, Sample};
use crate::memory::TierSim;
use crate::solver::{by_name, StopWhen, Trainer};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe accumulator for streamed raw examples.
#[derive(Default)]
pub struct IngestBuffer {
    inner: Mutex<Vec<Sample>>,
    /// Examples ever pushed (drains do not reset this).
    total: AtomicU64,
}

impl IngestBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, s: Sample) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).push(s);
        self.total.fetch_add(1, Relaxed);
    }

    pub fn push_many(&self, batch: Vec<Sample>) {
        let n = batch.len() as u64;
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .extend(batch);
        self.total.fetch_add(n, Relaxed);
    }

    /// Examples currently buffered (waiting for the next refit).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Examples ever pushed.
    pub fn total(&self) -> u64 {
        self.total.load(Relaxed)
    }

    /// Take everything buffered.
    pub fn drain(&self) -> Vec<Sample> {
        std::mem::take(&mut *self.inner.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

/// The refit loop's knobs.
#[derive(Clone, Debug)]
pub struct RefitConfig {
    /// Refit once this many examples are buffered (0 disables the
    /// count trigger).
    pub refit_every: usize,
    /// Refit when this much time passed since the last attempt and at
    /// least one example is buffered (0 disables the time trigger).
    pub refit_secs: f64,
    /// Training budget per refit (`timeout_secs` is the serving-path
    /// latency bound on background training).
    pub budget: StopWhen,
    /// Publish tolerance: a refit whose certificate exceeds
    /// `old_gap * (1 + regress_tol)` (and is not converged outright) is
    /// rejected.
    pub regress_tol: f64,
    /// Thread topology `(T_A, T_B, V_B)` for refits.
    pub threads: (usize, usize, usize),
    /// Engine name for refits (see [`by_name`]).
    pub solver: String,
    pub seed: u64,
}

impl Default for RefitConfig {
    fn default() -> Self {
        RefitConfig {
            refit_every: 64,
            refit_secs: 0.0,
            budget: StopWhen::gap_below(1e-5).max_epochs(100).timeout_secs(10.0),
            regress_tol: 0.10,
            threads: (1, 2, 1),
            solver: "hthc".into(),
            seed: 42,
        }
    }
}

/// The publish rule, separated out so the rejection path is testable
/// without running a diverged fit:
///
/// * a non-finite certificate never publishes (diverged refit);
/// * a certificate within the convergence tolerance always publishes
///   (the refit solved its problem — the old gap, measured on *fewer*
///   examples, is not comparable beyond that);
/// * otherwise publish only if the gap did not regress past
///   `old_gap * (1 + regress_tol)`.
pub fn publish_decision(old_gap: f64, new_gap: f64, gap_tol: f64, regress_tol: f64) -> bool {
    if !new_gap.is_finite() {
        return false;
    }
    new_gap <= gap_tol || new_gap <= old_gap * (1.0 + regress_tol)
}

/// What one refit attempt did.
#[derive(Clone, Debug, PartialEq)]
pub enum RefitOutcome {
    /// New version live.
    Published { version: u64, gap: f64 },
    /// Certificate regressed (or went non-finite); old version keeps
    /// serving.
    Rejected { gap: f64, serving: u64 },
    /// Dataset rebuild or model construction failed; old version keeps
    /// serving, absorbed examples are retained for the next attempt.
    Failed { error: String },
    /// Nothing buffered — no attempt made.
    NoData,
}

/// Owns the growing raw training set and runs warm-started refits
/// against a [`ModelStore`] (see module docs).
pub struct Refitter {
    /// Raw-space training samples: the base set plus everything
    /// absorbed by previous refits.
    samples: Vec<Sample>,
    family: Family,
    normalize: bool,
    center: bool,
    model_name: String,
    lam: f32,
    cfg: RefitConfig,
    last_refit: Instant,
    absorbed_total: u64,
}

impl Refitter {
    /// `base` is the initial training set in raw space (e.g.
    /// [`Dataset::to_samples`] of what the live snapshot was trained
    /// on); `normalize`/`center` must match the pipeline flags the base
    /// model was built with, so refits preprocess consistently.
    pub fn new(
        base: Vec<Sample>,
        model_name: &str,
        lam: f32,
        normalize: bool,
        center: bool,
        cfg: RefitConfig,
    ) -> Self {
        Refitter {
            samples: base,
            family: crate::glm::family_for(model_name),
            normalize,
            center,
            model_name: model_name.to_string(),
            lam,
            cfg,
            last_refit: Instant::now(),
            absorbed_total: 0,
        }
    }

    pub fn config(&self) -> &RefitConfig {
        &self.cfg
    }

    /// Examples absorbed into the training set across all refits.
    pub fn absorbed(&self) -> u64 {
        self.absorbed_total
    }

    /// Current training-set size (base + absorbed).
    pub fn sample_count(&self) -> usize {
        self.samples.len()
    }

    /// Whether the cadence says a refit is due given `buffered` waiting
    /// examples.
    pub fn should_refit(&self, buffered: usize) -> bool {
        if buffered == 0 {
            return false;
        }
        (self.cfg.refit_every > 0 && buffered >= self.cfg.refit_every)
            || (self.cfg.refit_secs > 0.0
                && self.last_refit.elapsed().as_secs_f64() >= self.cfg.refit_secs)
    }

    fn rebuild(&self) -> crate::Result<Dataset> {
        DatasetBuilder::libsvm_samples(self.samples.clone())
            .family(self.family)
            .normalize(self.normalize)
            .center_targets(self.center)
            .build()
    }

    /// Drain the buffer, rebuild, warm-start a fit from the live
    /// snapshot, and publish or reject by certificate.  Counters land
    /// in `stats`; the old version keeps serving on every non-publish
    /// path.
    pub fn refit_once(
        &mut self,
        store: &ModelStore,
        buf: &IngestBuffer,
        stats: &ServeStats,
    ) -> RefitOutcome {
        let fresh = buf.drain();
        if fresh.is_empty() {
            return RefitOutcome::NoData;
        }
        stats.refit_attempts.fetch_add(1, Relaxed);
        self.absorbed_total += fresh.len() as u64;
        self.samples.extend(fresh);
        self.last_refit = Instant::now();

        let outcome = self.train_and_decide(store);
        match &outcome {
            RefitOutcome::Published { .. } => stats.refit_published.fetch_add(1, Relaxed),
            RefitOutcome::Rejected { .. } => stats.refit_rejected.fetch_add(1, Relaxed),
            RefitOutcome::Failed { .. } => stats.refit_failed.fetch_add(1, Relaxed),
            RefitOutcome::NoData => 0,
        };
        outcome
    }

    fn train_and_decide(&mut self, store: &ModelStore) -> RefitOutcome {
        let ds = match self.rebuild() {
            Ok(ds) => ds,
            Err(e) => return RefitOutcome::Failed { error: format!("rebuild: {e}") },
        };
        let Some(mut model) = crate::glm::model_by_name(&self.model_name, self.lam, ds.n_cols())
        else {
            return RefitOutcome::Failed {
                error: format!("unknown model {:?}", self.model_name),
            };
        };
        let Some(engine) = by_name(&self.cfg.solver) else {
            return RefitOutcome::Failed {
                error: format!("unknown solver {:?}", self.cfg.solver),
            };
        };
        let live = store.load();
        let (t_a, t_b, v_b) = self.cfg.threads;
        let mut trainer = Trainer::new()
            .solver_boxed(engine)
            .threads(t_a, t_b, v_b)
            .stop_when(self.cfg.budget)
            .seed(self.cfg.seed)
            .warm_start_from(&live.iterate(), ds.n_cols());
        let report = trainer.fit_with(model.as_mut(), &ds, &TierSim::default());
        // engine-independent certificate: some engines' own traces carry
        // NaN gaps (SGD), and publish decisions must be comparable
        let cert = crate::glm::total_gap(
            model.as_ref(),
            ds.as_block_ops(),
            &report.v,
            ds.targets(),
            &report.alpha,
        );
        if publish_decision(live.gap, cert, self.cfg.budget.gap_tol, self.cfg.regress_tol) {
            let snap =
                ModelSnapshot::from_fit(model.as_ref(), &ds, &report, cert, self.absorbed_total);
            let version = store.publish(snap);
            RefitOutcome::Published { version, gap: cert }
        } else {
            RefitOutcome::Rejected { gap: cert, serving: live.version }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::glm::Lasso;
    use crate::solver::SeqThreshold;
    use crate::util::Rng;

    #[test]
    fn buffer_push_drain_and_totals() {
        let buf = IngestBuffer::new();
        assert!(buf.is_empty());
        buf.push(Sample { label: 1.0, features: vec![(0, 1.0)] });
        buf.push_many(vec![
            Sample { label: 2.0, features: vec![] },
            Sample { label: 3.0, features: vec![] },
        ]);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.total(), 3);
        let drained = buf.drain();
        assert_eq!(drained.len(), 3);
        assert!(buf.is_empty());
        assert_eq!(buf.total(), 3, "total survives the drain");
    }

    #[test]
    fn publish_decision_rules() {
        // converged outright: publish regardless of the old gap
        assert!(publish_decision(1e-9, 5e-6, 1e-5, 0.1));
        // mild regression within tolerance: publish
        assert!(publish_decision(1.0, 1.05, 1e-5, 0.1));
        // regression past tolerance: reject
        assert!(!publish_decision(1.0, 1.2, 1e-5, 0.1));
        // improvement always publishes
        assert!(publish_decision(1.0, 0.5, 1e-5, 0.0));
        // diverged certificates never publish
        assert!(!publish_decision(1.0, f64::NAN, 1e-5, 10.0));
        assert!(!publish_decision(1.0, f64::INFINITY, 1e-5, 10.0));
    }

    #[test]
    fn should_refit_count_cadence() {
        let r = Refitter::new(
            vec![],
            "lasso",
            0.01,
            true,
            true,
            RefitConfig { refit_every: 4, refit_secs: 0.0, ..Default::default() },
        );
        assert!(!r.should_refit(0));
        assert!(!r.should_refit(3));
        assert!(r.should_refit(4));
        // both triggers disabled: never refit
        let never = Refitter::new(
            vec![],
            "lasso",
            0.01,
            true,
            true,
            RefitConfig { refit_every: 0, refit_secs: 0.0, ..Default::default() },
        );
        assert!(!never.should_refit(1000));
    }

    /// Full flow: initial fit -> serve -> ingest perturbed examples ->
    /// warm-started refit publishes version 2 with the absorbed count.
    #[test]
    fn refit_publishes_and_counts_absorbed() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(71)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        let mut model = Lasso::new(0.01);
        let mut trainer = Trainer::new()
            .solver(SeqThreshold)
            .stop_when(StopWhen::gap_below(1e-7).max_epochs(200));
        let report = trainer.fit_with(&mut model, &ds, &Default::default());
        let gap = crate::glm::total_gap(
            &model,
            ds.as_block_ops(),
            &report.v,
            ds.targets(),
            &report.alpha,
        );
        let store = ModelStore::new(ModelSnapshot::from_fit(&model, &ds, &report, gap, 0));
        let stats = ServeStats::new();
        let base = ds.to_samples().unwrap();

        let mut refitter = Refitter::new(
            base.clone(),
            "lasso",
            0.01,
            true,
            true,
            RefitConfig {
                refit_every: 2,
                solver: "st".into(),
                budget: StopWhen::gap_below(1e-7).max_epochs(200),
                ..Default::default()
            },
        );
        let buf = IngestBuffer::new();
        assert_eq!(refitter.refit_once(&store, &buf, &stats), RefitOutcome::NoData);

        // stream slightly perturbed copies of real rows
        let mut rng = Rng::new(72);
        buf.push_many(
            base.iter()
                .take(3)
                .map(|s| Sample {
                    label: s.label + 0.01 * rng.normal(),
                    features: s.features.clone(),
                })
                .collect(),
        );
        assert!(refitter.should_refit(buf.len()));
        match refitter.refit_once(&store, &buf, &stats) {
            RefitOutcome::Published { version, gap } => {
                assert_eq!(version, 2);
                assert!(gap.is_finite());
            }
            other => panic!("expected publish, got {other:?}"),
        }
        assert_eq!(store.version(), 2);
        assert_eq!(stats.published(), 1);
        let live = store.load();
        assert_eq!(live.absorbed, 3);
        assert_eq!(refitter.sample_count(), base.len() + 3);
        assert_eq!(stats.attempts(), 1);
        assert!(buf.is_empty());
    }

    #[test]
    fn failed_rebuild_keeps_old_version() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(73)
            .build()
            .unwrap();
        let mut model = Lasso::new(0.01);
        let mut trainer =
            Trainer::new().solver(SeqThreshold).stop_when(StopWhen::gap_below(1e-6));
        let report = trainer.fit_with(&mut model, &ds, &Default::default());
        let store = ModelStore::new(ModelSnapshot::from_fit(&model, &ds, &report, 0.1, 0));
        let stats = ServeStats::new();
        // unknown model name forces the failure path after absorption
        let mut refitter = Refitter::new(
            ds.to_samples().unwrap(),
            "definitely-not-a-model",
            0.01,
            false,
            false,
            RefitConfig::default(),
        );
        let buf = IngestBuffer::new();
        buf.push(Sample { label: 0.5, features: vec![(0, 1.0)] });
        match refitter.refit_once(&store, &buf, &stats) {
            RefitOutcome::Failed { error } => assert!(error.contains("unknown model")),
            other => panic!("expected failure, got {other:?}"),
        }
        assert_eq!(store.version(), 1, "old version keeps serving");
        assert_eq!(stats.failed(), 1);
        assert_eq!(stats.attempts(), 1);
    }
}
