//! Streaming ingest and the warm-start refit loop — every byte on this
//! path is bounded and observable.
//!
//! [`IngestBuffer`] accumulates raw labeled examples behind a hard
//! capacity with an explicit backpressure rule (drop-oldest, counted —
//! never an unbounded queue); a [`Refitter`] drains it on a
//! configurable cadence (example count or elapsed time), absorbs the
//! fresh examples into a [`RetainedCorpus`] governed by a
//! [`RetentionPolicy`] (keep-all, uniform reservoir sample, or sliding
//! window — so the retained training set never grows past a configured
//! cap), rebuilds the training set through the one [`DatasetBuilder`]
//! pipeline *without copying the corpus* (shared `Arc` source,
//! re-normalized together), warm-starts a [`Trainer`] fit from the live
//! snapshot's iterate **remapped into the rebuild's column space**
//! ([`ModelSnapshot::remapped_alpha`]), and publishes the result **only
//! if the duality-gap certificate does not regress** beyond a tolerance
//! ([`publish_decision`]).  A failed or diverged refit keeps the old
//! version serving and is counted — graceful degradation, never a
//! serving gap.
//!
//! Forgetting is safe *because* of the certificate gate: a refit on a
//! reservoir- or window-thinned corpus still computes a fresh
//! `total_gap` on the rebuilt problem, and only goes live if that
//! certificate passes `publish_decision` against the serving gap.
//!
//! The refit budget is an ordinary [`StopWhen`], so count-based and
//! wall-clock-bounded refits use the same stopping machinery as any
//! other fit.

use super::{ModelSnapshot, ModelStore, ServeStats};
use crate::data::{Dataset, DatasetBuilder, Family, Sample};
use crate::memory::TierSim;
use crate::solver::{by_name, StopWhen, Trainer};
use crate::util::Rng;
use crate::sync::{AtomicU64, Mutex, Ordering::Relaxed};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// What the retained training corpus forgets once it hits its cap.
///
/// * [`KeepAll`](RetentionPolicy::KeepAll) — the PR-7 behavior: nothing
///   is ever forgotten and memory grows with history (the default, so
///   existing runs are behavior-identical).
/// * [`Reservoir`](RetentionPolicy::Reservoir) — Vitter's Algorithm R:
///   once `cap` samples are retained, each further offer replaces a
///   uniformly random resident with probability `cap / seen`, so the
///   corpus is always a uniform sample of *everything ever offered*
///   (unbiased history; order not preserved).
/// * [`SlidingWindow`](RetentionPolicy::SlidingWindow) — forget
///   oldest-first: the corpus is always the most recent `cap` offers
///   (biased toward the present; order preserved).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetentionPolicy {
    KeepAll,
    Reservoir { cap: usize },
    SlidingWindow { cap: usize },
}

impl RetentionPolicy {
    /// CLI spelling → policy (`--retention` + `--corpus-cap`).  Capped
    /// policies reject a zero cap rather than silently retaining
    /// nothing.
    pub fn parse(name: &str, cap: usize) -> Option<Self> {
        match name {
            "keep" | "keep-all" | "keepall" => Some(RetentionPolicy::KeepAll),
            "reservoir" if cap > 0 => Some(RetentionPolicy::Reservoir { cap }),
            "window" | "sliding-window" if cap > 0 => {
                Some(RetentionPolicy::SlidingWindow { cap })
            }
            _ => None,
        }
    }

    /// The retention cap, if this policy has one.
    pub fn cap(&self) -> Option<usize> {
        match *self {
            RetentionPolicy::KeepAll => None,
            RetentionPolicy::Reservoir { cap } | RetentionPolicy::SlidingWindow { cap } => {
                Some(cap)
            }
        }
    }
}

/// The retained raw-space training corpus: base samples plus everything
/// absorbed by refits, bounded by a [`RetentionPolicy`].
///
/// The samples live behind an `Arc` so a rebuild
/// ([`DatasetBuilder::libsvm_shared`]) borrows them without an
/// O(history) copy; between rebuilds the corpus is the sole owner, so
/// mutation through [`Arc::make_mut`] is copy-free.
pub struct RetainedCorpus {
    samples: Arc<Vec<Sample>>,
    policy: RetentionPolicy,
    /// Samples ever offered (base included) — the reservoir's `t`.
    seen: u64,
    /// Samples the policy removed (or refused entry) — every offer past
    /// the cap evicts exactly one.
    evicted: u64,
    /// High-water mark of the retained count.
    peak: usize,
    rng: Rng,
}

impl RetainedCorpus {
    /// A corpus seeded with `base` (the policy applies to the base too:
    /// a base larger than the cap is thinned immediately).
    pub fn new(base: Vec<Sample>, policy: RetentionPolicy, seed: u64) -> Self {
        let mut corpus = RetainedCorpus {
            samples: Arc::new(Vec::new()),
            policy,
            seen: 0,
            evicted: 0,
            peak: 0,
            rng: Rng::new(seed ^ 0x5e7a_17ed),
        };
        corpus.offer_many(base);
        corpus
    }

    /// Offer one sample to the policy.
    pub fn offer(&mut self, s: Sample) {
        self.offer_many(vec![s]);
    }

    /// Offer a batch; the policy decides what is retained.
    pub fn offer_many(&mut self, batch: Vec<Sample>) {
        if batch.is_empty() {
            return;
        }
        // sole owner between rebuilds — no copy (see struct docs)
        let samples = Arc::make_mut(&mut self.samples);
        match self.policy {
            RetentionPolicy::KeepAll => {
                self.seen += batch.len() as u64;
                samples.extend(batch);
            }
            RetentionPolicy::SlidingWindow { cap } => {
                self.seen += batch.len() as u64;
                samples.extend(batch);
                if samples.len() > cap {
                    let excess = samples.len() - cap;
                    samples.drain(..excess);
                    self.evicted += excess as u64;
                }
            }
            RetentionPolicy::Reservoir { cap } => {
                for s in batch {
                    self.seen += 1;
                    if samples.len() < cap {
                        samples.push(s);
                    } else {
                        // Algorithm R: keep the incoming sample with
                        // probability cap/seen, in a uniformly random
                        // slot; either way exactly one sample is evicted
                        let j = self.rng.below(self.seen as usize);
                        if j < cap {
                            samples[j] = s;
                        }
                        self.evicted += 1;
                    }
                }
            }
        }
        self.peak = self.peak.max(samples.len());
    }

    /// Shared handle for a zero-copy rebuild (dropped when the build
    /// returns, restoring sole ownership).
    pub fn shared(&self) -> Arc<Vec<Sample>> {
        Arc::clone(&self.samples)
    }

    /// Samples currently retained.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples ever offered (base included).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Samples the policy forgot.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Whether anything was ever forgotten (classification warm starts
    /// key off this: coordinates are sample positions there, and
    /// eviction invalidates them).
    pub fn has_evicted(&self) -> bool {
        self.evicted > 0
    }

    /// High-water mark of the retained count.
    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn policy(&self) -> RetentionPolicy {
        self.policy
    }
}

/// Thread-safe accumulator for streamed raw examples, with a hard
/// capacity and a drop-oldest backpressure rule.
///
/// With a cap, a push past capacity evicts the *oldest* buffered
/// example (the freshest data is the most valuable to a refit) and
/// counts it in [`dropped`](IngestBuffer::dropped) — the buffer can
/// never grow past `cap` no matter how far ingest outruns the refit
/// cadence.  [`new`](IngestBuffer::new) keeps the unbounded PR-7
/// behavior for existing callers.
#[derive(Default)]
pub struct IngestBuffer {
    inner: Mutex<VecDeque<Sample>>,
    /// 0 = unbounded.
    cap: usize,
    /// Examples ever pushed (drains and drops do not reset this).
    /// Relaxed: statistics counter; the queue itself is mutex-guarded.
    total: AtomicU64,
    /// Examples evicted by backpressure (never drained).  Relaxed:
    /// statistics counter, written under the queue lock.
    dropped: AtomicU64,
}

impl IngestBuffer {
    /// Unbounded buffer (existing behavior; prefer
    /// [`bounded`](IngestBuffer::bounded) for long-lived servers).
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer that never holds more than `cap` examples (`cap == 0`
    /// means unbounded, mirroring the CLI's `--ingest-cap 0`).
    pub fn bounded(cap: usize) -> Self {
        IngestBuffer { cap, ..Self::default() }
    }

    /// The configured capacity, if bounded.
    pub fn capacity(&self) -> Option<usize> {
        (self.cap > 0).then_some(self.cap)
    }

    fn enforce_cap(&self, q: &mut VecDeque<Sample>) {
        if self.cap > 0 {
            let mut evicted = 0u64;
            while q.len() > self.cap {
                q.pop_front();
                evicted += 1;
            }
            if evicted > 0 {
                self.dropped.fetch_add(evicted, Relaxed);
            }
        }
    }

    pub fn push(&self, s: Sample) {
        let mut q = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        q.push_back(s);
        self.enforce_cap(&mut q);
        drop(q);
        self.total.fetch_add(1, Relaxed);
    }

    pub fn push_many(&self, batch: Vec<Sample>) {
        let n = batch.len() as u64;
        let mut q = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        q.extend(batch);
        self.enforce_cap(&mut q);
        drop(q);
        self.total.fetch_add(n, Relaxed);
    }

    /// Examples currently buffered (waiting for the next refit).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Examples ever pushed (dropped ones included).
    pub fn total(&self) -> u64 {
        self.total.load(Relaxed)
    }

    /// Examples evicted by backpressure (pushed but never drained).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Relaxed)
    }

    /// Take everything buffered.
    pub fn drain(&self) -> Vec<Sample> {
        std::mem::take(&mut *self.inner.lock().unwrap_or_else(|e| e.into_inner())).into()
    }
}

/// The refit loop's knobs.
#[derive(Clone, Debug)]
pub struct RefitConfig {
    /// Refit once this many examples are buffered (0 disables the
    /// count trigger).
    pub refit_every: usize,
    /// Refit when this much time passed since the last attempt and at
    /// least one example is buffered (0 disables the time trigger).
    pub refit_secs: f64,
    /// Training budget per refit (`timeout_secs` is the serving-path
    /// latency bound on background training).
    pub budget: StopWhen,
    /// Publish tolerance: a refit whose certificate exceeds
    /// `old_gap * (1 + regress_tol)` (and is not converged outright) is
    /// rejected.
    pub regress_tol: f64,
    /// What the retained corpus forgets at its cap (default
    /// [`RetentionPolicy::KeepAll`]: nothing, the PR-7 behavior).
    pub retention: RetentionPolicy,
    /// Thread topology `(T_A, T_B, V_B)` for refits.
    pub threads: (usize, usize, usize),
    /// Engine name for refits (see [`by_name`]).
    pub solver: String,
    pub seed: u64,
}

impl Default for RefitConfig {
    fn default() -> Self {
        RefitConfig {
            refit_every: 64,
            refit_secs: 0.0,
            budget: StopWhen::gap_below(1e-5).max_epochs(100).timeout_secs(10.0),
            regress_tol: 0.10,
            retention: RetentionPolicy::KeepAll,
            threads: (1, 2, 1),
            solver: "hthc".into(),
            seed: 42,
        }
    }
}

/// The publish rule, separated out so the rejection path is testable
/// without running a diverged fit:
///
/// * a non-finite certificate never publishes (diverged refit);
/// * a certificate within the convergence tolerance always publishes
///   (the refit solved its problem — the old gap, measured on *fewer*
///   examples, is not comparable beyond that);
/// * otherwise publish only if the gap did not regress past
///   `old_gap * (1 + regress_tol)`.
pub fn publish_decision(old_gap: f64, new_gap: f64, gap_tol: f64, regress_tol: f64) -> bool {
    if !new_gap.is_finite() {
        return false;
    }
    new_gap <= gap_tol || new_gap <= old_gap * (1.0 + regress_tol)
}

/// What one refit attempt did.
#[derive(Clone, Debug, PartialEq)]
pub enum RefitOutcome {
    /// New version live.
    Published { version: u64, gap: f64 },
    /// Certificate regressed (or went non-finite); old version keeps
    /// serving.
    Rejected { gap: f64, serving: u64 },
    /// Dataset rebuild or model construction failed; old version keeps
    /// serving, absorbed examples are retained for the next attempt.
    Failed { error: String },
    /// Nothing buffered — no attempt made.
    NoData,
}

/// Owns the bounded retained corpus and runs warm-started refits
/// against a [`ModelStore`] (see module docs).
pub struct Refitter {
    corpus: RetainedCorpus,
    family: Family,
    normalize: bool,
    center: bool,
    model_name: String,
    lam: f32,
    cfg: RefitConfig,
    last_refit: Instant,
    absorbed_total: u64,
}

impl Refitter {
    /// `base` is the initial training set in raw space (e.g.
    /// [`Dataset::to_samples`] of what the live snapshot was trained
    /// on); `normalize`/`center` must match the pipeline flags the base
    /// model was built with, so refits preprocess consistently.  The
    /// retention policy in `cfg` applies from the start: a base corpus
    /// above the cap is thinned before the first refit.
    pub fn new(
        base: Vec<Sample>,
        model_name: &str,
        lam: f32,
        normalize: bool,
        center: bool,
        cfg: RefitConfig,
    ) -> Self {
        Refitter {
            corpus: RetainedCorpus::new(base, cfg.retention, cfg.seed),
            family: crate::glm::family_for(model_name),
            normalize,
            center,
            model_name: model_name.to_string(),
            lam,
            cfg,
            last_refit: Instant::now(),
            absorbed_total: 0,
        }
    }

    pub fn config(&self) -> &RefitConfig {
        &self.cfg
    }

    /// Examples absorbed into the corpus across all refits (counted at
    /// the drain — a sample later forgotten by the policy still counts).
    pub fn absorbed(&self) -> u64 {
        self.absorbed_total
    }

    /// Current retained training-set size.
    pub fn sample_count(&self) -> usize {
        self.corpus.len()
    }

    /// Samples the retention policy forgot so far.
    pub fn corpus_evicted(&self) -> u64 {
        self.corpus.evicted()
    }

    /// High-water mark of the retained corpus.
    pub fn corpus_peak(&self) -> usize {
        self.corpus.peak()
    }

    /// Whether the cadence says a refit is due given `buffered` waiting
    /// examples.
    pub fn should_refit(&self, buffered: usize) -> bool {
        if buffered == 0 {
            return false;
        }
        (self.cfg.refit_every > 0 && buffered >= self.cfg.refit_every)
            || (self.cfg.refit_secs > 0.0
                && self.last_refit.elapsed().as_secs_f64() >= self.cfg.refit_secs)
    }

    fn rebuild(&self) -> crate::Result<Dataset> {
        // shared source: the pipeline borrows the corpus, so this costs
        // O(matrix) regardless of how much history is retained
        DatasetBuilder::libsvm_shared(self.corpus.shared())
            .family(self.family)
            .normalize(self.normalize)
            .center_targets(self.center)
            .build()
    }

    /// The warm-start iterate for a fit on `ds`, or `None` when a warm
    /// start would be unsound: classification coordinates are *sample
    /// positions*, so once the retention policy has evicted anything
    /// the live iterate's coordinates no longer name the same samples
    /// and the refit must cold-start.  Regression coordinates are
    /// features — stable under any retention policy — so the live
    /// alpha is remapped into the rebuild's column space
    /// ([`ModelSnapshot::remapped_alpha`]: old→new `col_scales` ratio,
    /// zero-extended).
    fn warm_alpha(&self, live: &ModelSnapshot, ds: &Dataset) -> Option<Vec<f32>> {
        if self.family == Family::Classification && self.corpus.has_evicted() {
            return None;
        }
        Some(live.remapped_alpha(ds.meta().col_scales.as_deref(), ds.n_cols()))
    }

    /// Drain the buffer, absorb under the retention policy, rebuild,
    /// warm-start a fit from the live snapshot, and publish or reject
    /// by certificate.  Counters land in `stats`; the old version keeps
    /// serving on every non-publish path.
    pub fn refit_once(
        &mut self,
        store: &ModelStore,
        buf: &IngestBuffer,
        stats: &ServeStats,
    ) -> RefitOutcome {
        let fresh = buf.drain();
        stats.ingest_dropped.store(buf.dropped(), Relaxed);
        if fresh.is_empty() {
            return RefitOutcome::NoData;
        }
        stats.refit_attempts.fetch_add(1, Relaxed);
        self.absorbed_total += fresh.len() as u64;
        self.corpus.offer_many(fresh);
        stats.corpus_evicted.store(self.corpus.evicted(), Relaxed);
        stats.corpus_peak.fetch_max(self.corpus.peak() as u64, Relaxed);
        self.last_refit = Instant::now();

        let outcome = self.train_and_decide(store);
        match &outcome {
            RefitOutcome::Published { .. } => stats.refit_published.fetch_add(1, Relaxed),
            RefitOutcome::Rejected { .. } => stats.refit_rejected.fetch_add(1, Relaxed),
            RefitOutcome::Failed { .. } => stats.refit_failed.fetch_add(1, Relaxed),
            RefitOutcome::NoData => 0,
        };
        outcome
    }

    fn train_and_decide(&mut self, store: &ModelStore) -> RefitOutcome {
        let ds = match self.rebuild() {
            Ok(ds) => ds,
            Err(e) => return RefitOutcome::Failed { error: format!("rebuild: {e}") },
        };
        let Some(mut model) = crate::glm::model_by_name(&self.model_name, self.lam, ds.n_cols())
        else {
            return RefitOutcome::Failed {
                error: format!("unknown model {:?}", self.model_name),
            };
        };
        let Some(engine) = by_name(&self.cfg.solver) else {
            return RefitOutcome::Failed {
                error: format!("unknown solver {:?}", self.cfg.solver),
            };
        };
        let live = store.load();
        let (t_a, t_b, v_b) = self.cfg.threads;
        let mut trainer = Trainer::new()
            .solver_boxed(engine)
            .threads(t_a, t_b, v_b)
            .stop_when(self.cfg.budget)
            .seed(self.cfg.seed);
        if let Some(alpha) = self.warm_alpha(&live, &ds) {
            trainer = trainer.warm_start(alpha);
        }
        let report = trainer.fit_with(model.as_mut(), &ds, &TierSim::default());
        // engine-independent certificate: some engines' own traces carry
        // NaN gaps (SGD), and publish decisions must be comparable
        let cert = crate::glm::total_gap(
            model.as_ref(),
            ds.as_block_ops(),
            &report.v,
            ds.targets(),
            &report.alpha,
        );
        if publish_decision(live.gap, cert, self.cfg.budget.gap_tol, self.cfg.regress_tol) {
            let snap =
                ModelSnapshot::from_fit(model.as_ref(), &ds, &report, cert, self.absorbed_total);
            let version = store.publish(snap);
            RefitOutcome::Published { version, gap: cert }
        } else {
            RefitOutcome::Rejected { gap: cert, serving: live.version }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::glm::Lasso;
    use crate::solver::SeqThreshold;
    use crate::util::Rng;

    #[test]
    fn buffer_push_drain_and_totals() {
        let buf = IngestBuffer::new();
        assert!(buf.is_empty());
        assert_eq!(buf.capacity(), None);
        buf.push(Sample { label: 1.0, features: vec![(0, 1.0)] });
        buf.push_many(vec![
            Sample { label: 2.0, features: vec![] },
            Sample { label: 3.0, features: vec![] },
        ]);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf.total(), 3);
        assert_eq!(buf.dropped(), 0);
        let drained = buf.drain();
        assert_eq!(drained.len(), 3);
        assert!(buf.is_empty());
        assert_eq!(buf.total(), 3, "total survives the drain");
    }

    #[test]
    fn bounded_buffer_drops_oldest_and_counts() {
        let buf = IngestBuffer::bounded(4);
        assert_eq!(buf.capacity(), Some(4));
        for k in 0..6 {
            buf.push(Sample { label: k as f32, features: vec![] });
            assert!(buf.len() <= 4, "cap violated at push {k}");
        }
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.total(), 6, "total counts dropped pushes too");
        assert_eq!(buf.dropped(), 2);
        // drop-oldest: the survivors are the last four pushed
        let labels: Vec<f32> = buf.drain().iter().map(|s| s.label).collect();
        assert_eq!(labels, vec![2.0, 3.0, 4.0, 5.0]);
        // a batch larger than the cap keeps its newest tail
        buf.push_many((0..10).map(|k| Sample { label: k as f32, features: vec![] }).collect());
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.dropped(), 8);
        let labels: Vec<f32> = buf.drain().iter().map(|s| s.label).collect();
        assert_eq!(labels, vec![6.0, 7.0, 8.0, 9.0]);
    }

    fn tagged(label: f32) -> Sample {
        Sample { label, features: vec![(0, label)] }
    }

    #[test]
    fn retention_parse_spellings() {
        assert_eq!(RetentionPolicy::parse("keep-all", 0), Some(RetentionPolicy::KeepAll));
        assert_eq!(RetentionPolicy::parse("keep", 7), Some(RetentionPolicy::KeepAll));
        assert_eq!(
            RetentionPolicy::parse("reservoir", 9),
            Some(RetentionPolicy::Reservoir { cap: 9 })
        );
        assert_eq!(
            RetentionPolicy::parse("window", 9),
            Some(RetentionPolicy::SlidingWindow { cap: 9 })
        );
        assert_eq!(
            RetentionPolicy::parse("sliding-window", 1),
            Some(RetentionPolicy::SlidingWindow { cap: 1 })
        );
        assert_eq!(RetentionPolicy::parse("reservoir", 0), None, "capped policy needs a cap");
        assert_eq!(RetentionPolicy::parse("window", 0), None);
        assert_eq!(RetentionPolicy::parse("bogus", 5), None);
        assert_eq!(RetentionPolicy::KeepAll.cap(), None);
        assert_eq!(RetentionPolicy::Reservoir { cap: 3 }.cap(), Some(3));
    }

    #[test]
    fn sliding_window_keeps_newest_in_order() {
        let mut c = RetainedCorpus::new(
            (0..3).map(|k| tagged(k as f32)).collect(),
            RetentionPolicy::SlidingWindow { cap: 4 },
            1,
        );
        assert_eq!(c.len(), 3);
        assert_eq!(c.evicted(), 0);
        c.offer_many((3..8).map(|k| tagged(k as f32)).collect());
        assert_eq!(c.len(), 4);
        assert_eq!(c.evicted(), 4);
        assert_eq!(c.seen(), 8);
        assert_eq!(c.peak(), 4, "peak never exceeds the cap on the window path");
        let labels: Vec<f32> = c.shared().iter().map(|s| s.label).collect();
        assert_eq!(labels, vec![4.0, 5.0, 6.0, 7.0], "most recent cap offers, in order");
        assert!(c.has_evicted());
    }

    #[test]
    fn reservoir_holds_exactly_cap_and_samples_uniformly() {
        let cap = 50;
        let mut c = RetainedCorpus::new(vec![], RetentionPolicy::Reservoir { cap }, 99);
        for k in 0..cap {
            c.offer(tagged(k as f32));
            assert_eq!(c.len(), k + 1, "below cap nothing is forgotten");
        }
        assert_eq!(c.evicted(), 0);
        let total = 2000usize;
        c.offer_many((cap..total).map(|k| tagged(k as f32)).collect());
        assert_eq!(c.len(), cap, "exactly cap once saturated");
        assert_eq!(c.peak(), cap);
        assert_eq!(c.seen(), total as u64);
        assert_eq!(c.evicted(), (total - cap) as u64, "one eviction per offer past cap");
        // unbiasedness smoke: the retained labels should span history,
        // not cluster at either end (mean of uniform 0..2000 ≈ 1000;
        // a sliding window would sit at ~1975, keep-first at ~25)
        let mean: f32 =
            c.shared().iter().map(|s| s.label).sum::<f32>() / cap as f32;
        assert!(
            (400.0..1600.0).contains(&mean),
            "reservoir mean {mean} suggests a biased sample"
        );
    }

    #[test]
    fn keep_all_never_evicts() {
        let mut c = RetainedCorpus::new(
            (0..10).map(|k| tagged(k as f32)).collect(),
            RetentionPolicy::KeepAll,
            3,
        );
        c.offer_many((10..200).map(|k| tagged(k as f32)).collect());
        assert_eq!(c.len(), 200);
        assert_eq!(c.evicted(), 0);
        assert_eq!(c.peak(), 200);
        assert!(!c.has_evicted());
    }

    #[test]
    fn corpus_rebuild_does_not_copy_history() {
        let mut c = RetainedCorpus::new(
            (0..8).map(|k| tagged(1.0 + k as f32)).collect(),
            RetentionPolicy::KeepAll,
            5,
        );
        {
            let shared = c.shared();
            let ds = DatasetBuilder::libsvm_shared(Arc::clone(&shared))
                .family(Family::Regression)
                .build()
                .unwrap();
            assert_eq!(ds.n_rows(), 8);
            // builder dropped its handle after build; only the corpus
            // and this test's clone remain
            assert_eq!(Arc::strong_count(&shared), 2);
        }
        // sole owner again: the next absorb mutates in place via
        // make_mut without cloning — sole ownership proves it
        c.offer(tagged(99.0));
        assert_eq!(c.len(), 9);
        assert_eq!(Arc::strong_count(&c.shared()), 2); // corpus + this call's clone
    }

    #[test]
    fn publish_decision_rules() {
        // converged outright: publish regardless of the old gap
        assert!(publish_decision(1e-9, 5e-6, 1e-5, 0.1));
        // mild regression within tolerance: publish
        assert!(publish_decision(1.0, 1.05, 1e-5, 0.1));
        // regression past tolerance: reject
        assert!(!publish_decision(1.0, 1.2, 1e-5, 0.1));
        // improvement always publishes
        assert!(publish_decision(1.0, 0.5, 1e-5, 0.0));
        // diverged certificates never publish
        assert!(!publish_decision(1.0, f64::NAN, 1e-5, 10.0));
        assert!(!publish_decision(1.0, f64::INFINITY, 1e-5, 10.0));
    }

    #[test]
    fn should_refit_count_cadence() {
        let r = Refitter::new(
            vec![],
            "lasso",
            0.01,
            true,
            true,
            RefitConfig { refit_every: 4, refit_secs: 0.0, ..Default::default() },
        );
        assert!(!r.should_refit(0));
        assert!(!r.should_refit(3));
        assert!(r.should_refit(4));
        // both triggers disabled: never refit
        let never = Refitter::new(
            vec![],
            "lasso",
            0.01,
            true,
            true,
            RefitConfig { refit_every: 0, refit_secs: 0.0, ..Default::default() },
        );
        assert!(!never.should_refit(1000));
    }

    fn fit_store(seed: u64) -> (Dataset, ModelStore, Vec<Sample>) {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(seed)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        let mut model = Lasso::new(0.01);
        let mut trainer = Trainer::new()
            .solver(SeqThreshold)
            .stop_when(StopWhen::gap_below(1e-7).max_epochs(200));
        let report = trainer.fit_with(&mut model, &ds, &Default::default());
        let gap = crate::glm::total_gap(
            &model,
            ds.as_block_ops(),
            &report.v,
            ds.targets(),
            &report.alpha,
        );
        let store = ModelStore::new(ModelSnapshot::from_fit(&model, &ds, &report, gap, 0));
        let base = ds.to_samples().unwrap();
        (ds, store, base)
    }

    /// Full flow: initial fit -> serve -> ingest perturbed examples ->
    /// warm-started refit publishes version 2 with the absorbed count.
    #[test]
    fn refit_publishes_and_counts_absorbed() {
        let (_ds, store, base) = fit_store(71);
        let stats = ServeStats::new();

        let mut refitter = Refitter::new(
            base.clone(),
            "lasso",
            0.01,
            true,
            true,
            RefitConfig {
                refit_every: 2,
                solver: "st".into(),
                budget: StopWhen::gap_below(1e-7).max_epochs(200),
                ..Default::default()
            },
        );
        let buf = IngestBuffer::new();
        assert_eq!(refitter.refit_once(&store, &buf, &stats), RefitOutcome::NoData);

        // stream slightly perturbed copies of real rows
        let mut rng = Rng::new(72);
        buf.push_many(
            base.iter()
                .take(3)
                .map(|s| Sample {
                    label: s.label + 0.01 * rng.normal(),
                    features: s.features.clone(),
                })
                .collect(),
        );
        assert!(refitter.should_refit(buf.len()));
        match refitter.refit_once(&store, &buf, &stats) {
            RefitOutcome::Published { version, gap } => {
                assert_eq!(version, 2);
                assert!(gap.is_finite());
            }
            other => panic!("expected publish, got {other:?}"),
        }
        assert_eq!(store.version(), 2);
        assert_eq!(stats.published(), 1);
        let live = store.load();
        assert_eq!(live.absorbed, 3);
        assert_eq!(refitter.sample_count(), base.len() + 3);
        assert_eq!(refitter.corpus_evicted(), 0, "KeepAll forgets nothing");
        assert_eq!(stats.attempts(), 1);
        assert!(buf.is_empty());
    }

    /// Satellite regression test: across a refit the live iterate lives
    /// in the *old* normalization's column space; feeding it through
    /// `remapped_alpha` (old→new col_scales ratio, zero-extended) must
    /// converge no slower than a cold start on the rebuilt problem —
    /// the stale un-remapped iterate has no such guarantee.
    #[test]
    fn remapped_warm_start_no_slower_than_cold() {
        let (_ds, store, base) = fit_store(77);
        // fresh examples with rescaled features: column norms change, so
        // the rebuild's col_scales differ materially from the old ones
        let fresh: Vec<Sample> = base
            .iter()
            .take(6)
            .map(|s| Sample {
                label: s.label * 1.5,
                features: s.features.iter().map(|&(j, x)| (j, x * 4.0)).collect(),
            })
            .collect();
        let mut corpus = base.clone();
        corpus.extend(fresh);
        let rebuilt = DatasetBuilder::libsvm_samples(corpus)
            .family(Family::Regression)
            .normalize(true)
            .center_targets(true)
            .build()
            .unwrap();
        let live = store.load();
        let old_scales = live.col_scales.clone().unwrap();
        let new_scales = rebuilt.meta().col_scales.clone().unwrap();
        assert!(
            old_scales
                .iter()
                .zip(&new_scales)
                .any(|(o, n)| (o / n - 1.0).abs() > 0.05),
            "test premise: the rebuild must re-normalize differently"
        );
        let warm = live.remapped_alpha(rebuilt.meta().col_scales.as_deref(), rebuilt.n_cols());

        let budget = StopWhen::gap_below(1e-7).max_epochs(500).eval_every(1);
        let fit = |warm_alpha: Option<Vec<f32>>| {
            let mut model = Lasso::new(0.01);
            let mut trainer = Trainer::new().solver(SeqThreshold).stop_when(budget);
            if let Some(a) = warm_alpha {
                trainer = trainer.warm_start(a);
            }
            trainer.fit_with(&mut model, &rebuilt, &Default::default())
        };
        let warm_report = fit(Some(warm));
        let cold_report = fit(None);
        assert!(warm_report.converged, "warm start must reach the tolerance");
        assert!(
            warm_report.epochs <= cold_report.epochs,
            "corrected warm start took {} epochs, cold start {}",
            warm_report.epochs,
            cold_report.epochs
        );
    }

    /// Eviction-aware refit: under a sliding window the corpus stays at
    /// its cap across refits and the certificate gate still governs the
    /// publish.
    #[test]
    fn capped_refit_bounds_corpus_and_still_publishes() {
        let (_ds, store, base) = fit_store(83);
        let cap = base.len(); // forget exactly as much as arrives
        let stats = ServeStats::new();
        let mut refitter = Refitter::new(
            base.clone(),
            "lasso",
            0.01,
            true,
            true,
            RefitConfig {
                refit_every: 2,
                solver: "st".into(),
                budget: StopWhen::gap_below(1e-7).max_epochs(300),
                retention: RetentionPolicy::SlidingWindow { cap },
                ..Default::default()
            },
        );
        let buf = IngestBuffer::bounded(cap);
        let mut rng = Rng::new(84);
        for round in 0..3u64 {
            buf.push_many(
                base.iter()
                    .take(4)
                    .map(|s| Sample {
                        label: s.label + 0.01 * rng.normal(),
                        features: s.features.clone(),
                    })
                    .collect(),
            );
            let outcome = refitter.refit_once(&store, &buf, &stats);
            assert!(
                matches!(
                    outcome,
                    RefitOutcome::Published { .. } | RefitOutcome::Rejected { .. }
                ),
                "round {round}: {outcome:?}"
            );
            assert!(
                refitter.sample_count() <= cap,
                "corpus {} exceeded cap {cap}",
                refitter.sample_count()
            );
        }
        assert_eq!(refitter.corpus_evicted(), 12, "3 rounds x 4 absorbed = 12 forgotten");
        assert_eq!(refitter.corpus_peak(), cap);
        assert_eq!(stats.corpus_evicted.load(Relaxed), 12);
        assert!(stats.attempts() >= 3);
    }

    #[test]
    fn failed_rebuild_keeps_old_version() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Regression)
            .seed(73)
            .build()
            .unwrap();
        let mut model = Lasso::new(0.01);
        let mut trainer =
            Trainer::new().solver(SeqThreshold).stop_when(StopWhen::gap_below(1e-6));
        let report = trainer.fit_with(&mut model, &ds, &Default::default());
        let store = ModelStore::new(ModelSnapshot::from_fit(&model, &ds, &report, 0.1, 0));
        let stats = ServeStats::new();
        // unknown model name forces the failure path after absorption
        let mut refitter = Refitter::new(
            ds.to_samples().unwrap(),
            "definitely-not-a-model",
            0.01,
            false,
            false,
            RefitConfig::default(),
        );
        let buf = IngestBuffer::new();
        buf.push(Sample { label: 0.5, features: vec![(0, 1.0)] });
        match refitter.refit_once(&store, &buf, &stats) {
            RefitOutcome::Failed { error } => assert!(error.contains("unknown model")),
            other => panic!("expected failure, got {other:?}"),
        }
        assert_eq!(store.version(), 1, "old version keeps serving");
        assert_eq!(stats.failed(), 1);
        assert_eq!(stats.attempts(), 1);
    }
}
