//! Serving statistics: lock-free request/refit counters and a
//! fixed-bucket predict-latency histogram.
//!
//! Everything here is plain atomics so the predict hot path never takes
//! a lock to record a sample.  The histogram uses power-of-two
//! nanosecond buckets (`[2^k, 2^(k+1))`), which makes recording one
//! `leading_zeros` plus one relaxed `fetch_add`, and quantile lookup a
//! walk over cumulative counts — the textbook fixed-bucket design (see
//! `rust/DESIGN.md` §11 for the bucket layout rationale).

// Relaxed throughout this module: every atomic here is a monotone
// statistics counter read for reporting — no counter publishes other
// memory, so no acquire/release edges are needed.
use crate::sync::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// Number of histogram buckets.  Bucket 0 holds everything below
/// [`BASE_NS`]; bucket `i >= 1` holds `[BASE_NS << (i-1), BASE_NS << i)`;
/// the last bucket additionally absorbs everything slower.  With a
/// 256 ns base and 32 buckets the range tops out above 500 s — far past
/// any sane predict latency.
pub const BUCKETS: usize = 32;

/// Lower edge of bucket 1 in nanoseconds (power of two).
pub const BASE_NS: u64 = 256;

/// Fixed-bucket latency histogram (power-of-two nanosecond buckets).
#[derive(Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

fn bucket_index(ns: u64) -> usize {
    if ns < BASE_NS {
        return 0;
    }
    // ns >= BASE_NS = 2^8, so ilog2 >= 8 and the subtraction is safe
    let idx = (ns.ilog2() - BASE_NS.ilog2() + 1) as usize;
    idx.min(BUCKETS - 1)
}

/// Upper edge of a bucket in nanoseconds (what a quantile reports — a
/// conservative bound, never an underestimate except in the unbounded
/// last bucket).
fn bucket_upper_ns(idx: usize) -> u64 {
    BASE_NS << idx
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[bucket_index(ns)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return f64::NAN;
        }
        self.sum_ns.load(Relaxed) as f64 * 1e-9 / c as f64
    }

    /// Upper bound of the bucket containing the `q`-quantile sample
    /// (seconds); NaN while empty.  `q` is clamped to `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Relaxed);
            if cum >= target {
                return bucket_upper_ns(i) as f64 * 1e-9;
            }
        }
        bucket_upper_ns(BUCKETS - 1) as f64 * 1e-9
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// The serving layer's counter surface.  One instance is shared by the
/// predict engine (latency, request counts), the ingest/refit loop
/// (absorption and publish/reject/fail counts) and the CLI reporter.
#[derive(Default)]
pub struct ServeStats {
    /// Predict requests answered (one batch = one request).
    pub requests: AtomicU64,
    /// Rows scored across all requests.
    pub rows: AtomicU64,
    /// Streaming labeled examples accepted into the ingest buffer.
    pub ingested: AtomicU64,
    /// Refit attempts that drained at least one example.
    pub refit_attempts: AtomicU64,
    /// Refits whose certificate passed the publish rule.
    pub refit_published: AtomicU64,
    /// Refits rejected by the gap-regression rule (old version kept).
    pub refit_rejected: AtomicU64,
    /// Refits that errored before producing a certificate.
    pub refit_failed: AtomicU64,
    /// Examples the bounded ingest buffer dropped under backpressure
    /// (pushed but never drained into a refit).
    pub ingest_dropped: AtomicU64,
    /// Samples the retention policy forgot from the training corpus.
    pub corpus_evicted: AtomicU64,
    /// High-water mark of the retained corpus size — with a cap
    /// configured this must never exceed it.
    pub corpus_peak: AtomicU64,
    /// Per-request predict latency.
    pub latency: LatencyHistogram,
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one answered predict request of `rows` rows.
    pub fn record_predict(&self, rows: usize, took: Duration) {
        self.requests.fetch_add(1, Relaxed);
        self.rows.fetch_add(rows as u64, Relaxed);
        self.latency.record(took);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Relaxed)
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Relaxed)
    }

    pub fn ingested(&self) -> u64 {
        self.ingested.load(Relaxed)
    }

    pub fn published(&self) -> u64 {
        self.refit_published.load(Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.refit_rejected.load(Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.refit_failed.load(Relaxed)
    }

    pub fn attempts(&self) -> u64 {
        self.refit_attempts.load(Relaxed)
    }

    pub fn ingest_dropped(&self) -> u64 {
        self.ingest_dropped.load(Relaxed)
    }

    pub fn corpus_evicted(&self) -> u64 {
        self.corpus_evicted.load(Relaxed)
    }

    pub fn corpus_peak(&self) -> u64 {
        self.corpus_peak.load(Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(255), 0);
        assert_eq!(bucket_index(256), 1);
        assert_eq!(bucket_index(511), 1);
        assert_eq!(bucket_index(512), 2);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_walk_cumulative_counts() {
        let h = LatencyHistogram::new();
        assert!(h.quantile(0.5).is_nan(), "empty histogram has no quantiles");
        // 90 fast samples at ~1us, 10 slow at ~1ms
        for _ in 0..90 {
            h.record(Duration::from_micros(1));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(1));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 < 5e-6, "p50 {p50} should sit in the ~1us bucket");
        assert!(p99 > 5e-4, "p99 {p99} should sit in the ~1ms bucket");
        assert!(h.p95() <= p99 + 1e-12, "quantiles are monotone");
        assert!(h.mean_secs() > 0.0);
    }

    #[test]
    fn quantile_is_conservative_upper_bound() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(300)); // bucket [256, 512)
        assert_eq!(h.quantile(1.0), 512e-9);
        assert_eq!(h.quantile(0.0), 512e-9, "q clamps and still needs 1 sample");
    }

    #[test]
    fn predict_counters_accumulate() {
        let s = ServeStats::new();
        s.record_predict(8, Duration::from_micros(3));
        s.record_predict(16, Duration::from_micros(5));
        assert_eq!(s.requests(), 2);
        assert_eq!(s.rows(), 24);
        assert_eq!(s.latency.count(), 2);
    }
}
