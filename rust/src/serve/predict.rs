//! [`PredictEngine`] — the crate's single prediction seam.
//!
//! Every prediction in the crate reduces to *dot a dense weight vector
//! against columns of a matrix*, which is exactly the blocked-sweep
//! kernel surface ([`BlockOps::dots_block`]).  This module owns that
//! reduction once:
//!
//! * the free functions ([`decision_scores`], [`accuracy`],
//!   [`mean_squared_error`]) are the consolidated replacements for the
//!   ad-hoc predict loops that used to live in `glm::svm` (training
//!   accuracy), `baselines::sgd` (row-cache MSE) and `main.rs`
//!   (`evaluate`);
//! * [`PredictEngine`] wraps the same tile sweep around a live
//!   [`ModelStore`] snapshot for the serving layer — raw feature
//!   vectors in (the snapshot's weights already fold the training
//!   normalization, see [`super::ModelSnapshot`]), scores out, with
//!   optional [`WorkerPool`] parallelism and latency recording.
//!
//! # Bitwise determinism
//!
//! The batch path tiles columns into fixed [`BLOCK_COLS`]-aligned
//! blocks and evaluates each block with one `dots_block` call — the
//! same call a direct kernel evaluation of that block makes.  Tile
//! boundaries depend only on the column count, and each output element
//! is written by exactly one tile, so the result is **bitwise
//! identical** whether the tiles run serially or race across any
//! number of pool workers (`rust/tests/serve_diff.rs` proves this per
//! representation × backend).

use super::{ModelSnapshot, ModelStore, ServeStats};
use crate::data::{BlockOps, Matrix};
use crate::kernels::BLOCK_COLS;
use crate::sync::{AtomicUsize, Ordering::Relaxed};
use crate::threadpool::WorkerPool;
use std::sync::Arc;
use std::time::Instant;

/// `out[j] = <w, d_j>` for every column, through fixed
/// [`BLOCK_COLS`]-aligned `dots_block` tiles (see module docs).
pub fn scores_into(data: &dyn BlockOps, w: &[f32], out: &mut [f32]) {
    assert_eq!(out.len(), data.n_cols());
    let mut idx = [0usize; BLOCK_COLS];
    for (tile, chunk) in out.chunks_mut(BLOCK_COLS).enumerate() {
        let base = tile * BLOCK_COLS;
        for (t, j) in idx.iter_mut().zip(base..base + chunk.len()) {
            *t = j;
        }
        data.dots_block(&idx[..chunk.len()], w, chunk);
    }
}

/// Column decision scores `<w, d_j>` (serial tile sweep).
pub fn decision_scores(data: &dyn BlockOps, w: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; data.n_cols()];
    scores_into(data, w, &mut out);
    out
}

/// Fraction of columns with a positive decision score.  With the
/// classification orientation's label-scaled columns (`d_j = y_j x_j`)
/// this *is* training/held-out accuracy: sample `j` is correct iff
/// `<v, d_j> > 0`.
pub fn accuracy_from_scores(scores: &[f32]) -> f64 {
    if scores.is_empty() {
        return f64::NAN;
    }
    scores.iter().filter(|&&s| s > 0.0).count() as f64 / scores.len() as f64
}

/// Classification accuracy of the shared vector `v` over label-scaled
/// columns — the consolidated replacement for `SvmDual::accuracy`.
pub fn accuracy(data: &dyn BlockOps, v: &[f32]) -> f64 {
    accuracy_from_scores(&decision_scores(data, v))
}

/// Mean squared error between predictions and targets (f64-accumulated
/// through the kernel layer) — the consolidated replacement for
/// `RowCache::mean_squared_error` and `evaluate`'s inline loop.
pub fn mean_squared_error(preds: &[f32], targets: &[f32]) -> f64 {
    assert_eq!(preds.len(), targets.len());
    crate::kernels::sq_err_f64(preds, targets) / preds.len().max(1) as f64
}

/// Disjoint-tile output pointer for the pooled sweep (each tile writes
/// its own `out` range, claimed exactly once through an atomic cursor).
struct TileOut(*mut f32);
// SAFETY: the pointer names a buffer that outlives the pool sweep, and
// every worker writes only the disjoint range of the tile it claimed
// through the atomic cursor — no two threads touch the same elements.
unsafe impl Send for TileOut {}
// SAFETY: shared access is write-only into disjoint claimed ranges (see
// above); the buffer is only read after `pool.run` returns.
unsafe impl Sync for TileOut {}

/// Batched prediction over a live [`ModelStore`] snapshot.
pub struct PredictEngine {
    store: Arc<ModelStore>,
    pool: Option<WorkerPool>,
    stats: Option<Arc<ServeStats>>,
}

impl PredictEngine {
    pub fn new(store: Arc<ModelStore>) -> Self {
        PredictEngine { store, pool: None, stats: None }
    }

    /// Answer batches with `t` pool workers (`t <= 1` stays serial).
    /// The tile decomposition — and therefore the result, bitwise — is
    /// the same either way.
    pub fn with_threads(mut self, t: usize) -> Self {
        self.pool = (t > 1).then(|| WorkerPool::with_name(t, "serve-predict"));
        self
    }

    /// Record request counts and latency into `stats`.
    pub fn with_stats(mut self, stats: Arc<ServeStats>) -> Self {
        self.stats = Some(stats);
        self
    }

    /// The snapshot requests are currently answered from.
    pub fn snapshot(&self) -> Arc<ModelSnapshot> {
        self.store.load()
    }

    /// Score a batch given as matrix *columns* (each column one raw
    /// input vector).  One snapshot load serves the whole batch, so a
    /// concurrent publish never tears a batch across versions.
    ///
    /// Panics if the batch row count does not match the snapshot's
    /// input dimension.
    pub fn predict_batch(&self, batch: &dyn BlockOps) -> Vec<f32> {
        let t0 = Instant::now();
        let snap = self.store.load();
        assert_eq!(
            batch.n_rows(),
            snap.input_dim(),
            "batch rows must match the snapshot input dimension"
        );
        let n = batch.n_cols();
        let mut out = vec![0.0f32; n];
        match &self.pool {
            None => scores_into(batch, &snap.weights, &mut out),
            Some(pool) => {
                // Relaxed: tile uniqueness comes from fetch_add's RMW
                // atomicity alone; the pool's job handoff publishes the
                // written tiles back to this thread.
                let cursor = AtomicUsize::new(0);
                let base_ptr = TileOut(out.as_mut_ptr());
                let ptr = &base_ptr;
                let w = &snap.weights;
                pool.run(move |_worker| loop {
                    let tile = cursor.fetch_add(1, Relaxed);
                    let lo = tile * BLOCK_COLS;
                    if lo >= n {
                        break;
                    }
                    let m = BLOCK_COLS.min(n - lo);
                    let mut idx = [0usize; BLOCK_COLS];
                    for (t, j) in idx.iter_mut().zip(lo..lo + m) {
                        *t = j;
                    }
                    // SAFETY: tile indices are claimed exactly once, so
                    // no two workers write the same elements; `lo + m`
                    // never exceeds `out.len()`, and `out` outlives the
                    // sweep.
                    let chunk =
                        unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), m) };
                    batch.dots_block(&idx[..m], w, chunk);
                });
            }
        }
        if snap.bias != 0.0 {
            for o in out.iter_mut() {
                *o += snap.bias;
            }
        }
        if let Some(stats) = &self.stats {
            stats.record_predict(n, t0.elapsed());
        }
        out
    }

    /// [`predict_batch`](Self::predict_batch) over a runtime-polymorphic
    /// [`Matrix`].
    pub fn predict_matrix(&self, batch: &Matrix) -> Vec<f32> {
        self.predict_batch(batch.as_block_ops())
    }

    /// Score one dense raw input vector.
    pub fn predict_one(&self, x: &[f32]) -> f32 {
        let t0 = Instant::now();
        let snap = self.store.load();
        assert_eq!(x.len(), snap.input_dim(), "input length mismatch");
        let s = crate::kernels::dot(x, &snap.weights) + snap.bias;
        if let Some(stats) = &self.stats {
            stats.record_predict(1, t0.elapsed());
        }
        s
    }

    /// Score one sparse raw input given as sorted `(feature, value)`
    /// pairs.  Features beyond the snapshot's input dimension are
    /// ignored (a streamed example may mention features the model was
    /// never trained on).
    pub fn predict_sparse_one(&self, features: &[(u32, f32)]) -> f32 {
        let t0 = Instant::now();
        let snap = self.store.load();
        let dim = snap.input_dim() as u32;
        let in_range = features.last().is_none_or(|&(i, _)| i < dim);
        let s = if in_range {
            crate::kernels::pair_dot(features, &snap.weights)
        } else {
            features
                .iter()
                .filter(|&&(i, _)| i < dim)
                .map(|&(i, v)| snap.weights[i as usize] * v)
                .sum()
        } + snap.bias;
        if let Some(stats) = &self.stats {
            stats.record_predict(1, t0.elapsed());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetBuilder, DatasetKind, DenseMatrix, Family, SparseMatrix};
    use crate::glm::ModelKind;
    use crate::util::Rng;
    use std::time::Instant as StdInstant;

    fn store_with(weights: Vec<f32>, bias: f32) -> Arc<ModelStore> {
        let n = weights.len();
        Arc::new(ModelStore::new(ModelSnapshot {
            version: 0,
            kind: ModelKind::Lasso { lam: 0.1, lip_b: 1.0 },
            family: Family::Regression,
            weights,
            bias,
            alpha: vec![0.0; n],
            col_scales: None,
            gap: 0.0,
            trained_cols: n,
            absorbed: 0,
            published_at: StdInstant::now(),
        }))
    }

    fn batch(d: usize, n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        DenseMatrix::from_col_major(d, n, (0..d * n).map(|_| rng.normal()).collect())
    }

    #[test]
    fn pooled_batch_is_bitwise_equal_to_serial() {
        let d = 24;
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        // n chosen to exercise a ragged final tile
        for n in [1usize, 7, 8, 19, 64, 65] {
            let m = batch(d, n, 100 + n as u64);
            let serial = PredictEngine::new(store_with(w.clone(), 0.25));
            let pooled =
                PredictEngine::new(store_with(w.clone(), 0.25)).with_threads(3);
            let a = serial.predict_batch(&m);
            let b = pooled.predict_batch(&m);
            assert_eq!(a.len(), n);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn single_and_sparse_paths_agree_with_batch() {
        let d = 16;
        let mut rng = Rng::new(6);
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let engine = PredictEngine::new(store_with(w.clone(), 1.5));
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let dense = engine.predict_one(&x);
        let pairs: Vec<(u32, f32)> =
            x.iter().enumerate().map(|(i, &v)| (i as u32, v)).collect();
        let sparse = engine.predict_sparse_one(&pairs);
        assert!((dense - sparse).abs() < 1e-4, "{dense} vs {sparse}");
        // out-of-range features are dropped, not a panic
        let oob = engine.predict_sparse_one(&[(0, 1.0), (999, 5.0)]);
        assert!((oob - (w[0] + 1.5)).abs() < 1e-6);
    }

    #[test]
    fn stats_record_requests_and_rows() {
        let stats = Arc::new(ServeStats::new());
        let engine = PredictEngine::new(store_with(vec![1.0; 8], 0.0))
            .with_stats(Arc::clone(&stats));
        engine.predict_batch(&batch(8, 5, 9));
        engine.predict_one(&[0.0; 8]);
        assert_eq!(stats.requests(), 2);
        assert_eq!(stats.rows(), 6);
        assert_eq!(stats.latency.count(), 2);
    }

    #[test]
    fn consolidated_accuracy_matches_per_column_rule() {
        let ds = DatasetBuilder::generated(DatasetKind::Tiny, Family::Classification)
            .seed(11)
            .build()
            .unwrap();
        let mut rng = Rng::new(12);
        let v: Vec<f32> = (0..ds.d()).map(|_| rng.normal()).collect();
        let ops = ds.as_ops();
        let want = (0..ds.n()).filter(|&j| ops.dot(j, &v) > 0.0).count() as f64
            / ds.n() as f64;
        assert_eq!(accuracy(ds.as_block_ops(), &v), want);
    }

    #[test]
    fn mse_matches_inline_loop() {
        let preds = vec![1.0f32, 2.0, 3.0];
        let targets = vec![1.5f32, 2.0, 1.0];
        let want = (0.25 + 0.0 + 4.0) / 3.0;
        assert!((mean_squared_error(&preds, &targets) - want).abs() < 1e-12);
    }

    #[test]
    fn sparse_batch_matches_dense_batch() {
        let d = 12;
        let mut rng = Rng::new(13);
        let w: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let dm = batch(d, 10, 14);
        let cols: Vec<Vec<(u32, f32)>> = (0..10)
            .map(|j| {
                dm.col(j)
                    .iter()
                    .enumerate()
                    .map(|(r, &x)| (r as u32, x))
                    .collect()
            })
            .collect();
        let sm = SparseMatrix::from_columns(d, cols);
        let engine = PredictEngine::new(store_with(w, 0.0));
        let a = engine.predict_batch(&dm);
        let b = engine.predict_batch(&sm);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
