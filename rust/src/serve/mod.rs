//! The always-on serving layer (`rust/DESIGN.md` §11).
//!
//! Training makes a model; this module keeps it *answering* while it
//! keeps learning:
//!
//! * [`ModelStore`] — immutable versioned [`ModelSnapshot`]s behind an
//!   atomic slot swap: readers never lock, writers never tear;
//! * [`PredictEngine`] — batched raw-input prediction through the same
//!   blocked kernels training uses, bitwise-identical to a direct
//!   per-column evaluation, optionally parallel over a
//!   [`WorkerPool`](crate::threadpool::WorkerPool);
//! * [`IngestBuffer`] + [`Refitter`] — streaming examples absorbed on a
//!   cadence by [`Trainer`](crate::solver::Trainer) warm starts, with a
//!   duality-gap certificate gating every publish ([`publish_decision`]);
//!   both ends are memory-bounded: the buffer by a hard capacity with
//!   drop-oldest backpressure, the retained corpus by a
//!   [`RetentionPolicy`] (keep-all / reservoir / sliding window);
//! * [`ServeStats`] — lock-free counters and fixed-bucket latency
//!   quantiles for the `hthc serve` surface, driven by the bounded
//!   in-process simulator in [`sim`].
//!
//! The staleness model follows HOGWILD! (Niu et al.): predictions may
//! lag writes by a bounded amount — here, by at most one refit cadence
//! plus one training budget — and each snapshot reports exactly how
//! stale it is ([`ModelSnapshot::staleness_secs`] + absorbed counts).
//! Warm-starting refits from the live iterate is licensed by Ioannou et
//! al.'s warm-started local subproblems (PAPERS.md): convergence does
//! not suffer, and the certificate gate catches the cases where it
//! would.

pub mod ingest;
pub mod predict;
pub mod sim;
pub mod snapshot;
pub mod stats;
pub mod store;

pub use ingest::{
    publish_decision, IngestBuffer, RefitConfig, RefitOutcome, Refitter, RetainedCorpus,
    RetentionPolicy,
};
pub use predict::{
    accuracy, accuracy_from_scores, decision_scores, mean_squared_error, PredictEngine,
};
pub use sim::{ServeConfig, ServeReport};
pub use snapshot::ModelSnapshot;
pub use stats::{LatencyHistogram, ServeStats};
pub use store::ModelStore;
