//! [`ModelStore`]: immutable versioned model snapshots behind an atomic
//! slot swap — readers never lock, never spin on a healthy store, and
//! never observe a torn snapshot.
//!
//! # Protocol
//!
//! The store keeps a small ring of slots.  Each slot holds an
//! `Arc<ModelSnapshot>` guarded by two atomics: a `stamp` (the version
//! the slot currently holds, or `EMPTY` while a writer owns it) and a
//! `readers` pin count.  A packed `current` word (`version * SLOTS +
//! slot`) names the live slot *and* the version expected in it, so a
//! reader can detect that a slot was recycled under it:
//!
//! * **Reader**: load `current` → pin the named slot (`readers += 1`) →
//!   re-check `stamp == version` → clone the `Arc` → unpin.  If the
//!   stamp check fails the slot was recycled; retry with a fresh
//!   `current`.  Versions are monotone (they never repeat), so the
//!   check cannot pass spuriously — no ABA.
//! * **Writer** (serialized by a mutex; readers are unaffected):
//!   pick a victim slot other than the live one → `stamp = EMPTY` →
//!   wait for `readers == 0` → overwrite the slot → `stamp = version`
//!   → publish `current`.  The stamp invalidation happens *before* the
//!   drain-wait, so any reader that pins the victim after the writer's
//!   check backs off at the stamp re-check without dereferencing the
//!   slot.
//!
//! All protocol atomics use `SeqCst`: publishes are rare (one per
//! refit) and reads add two uncontended RMWs per request — noise next
//! to the predict matvec they guard.

use super::ModelSnapshot;
use crate::sync::spin::SpinWait;
use crate::sync::{AtomicU64, AtomicUsize, Mutex, Ordering::SeqCst};
use std::cell::UnsafeCell;
use std::sync::Arc;

/// Ring size.  Two would suffice for one writer + fast readers; four
/// gives stalled readers (e.g. a thread preempted mid-pin) more slack
/// before a writer has to spin on the drain-wait.
const SLOTS: usize = 4;

/// Stamp value while a writer owns the slot (never a real version —
/// versions start at 1 and increment).
const EMPTY: u64 = u64::MAX;

struct Slot {
    /// Version resident in the slot, or [`EMPTY`] while a writer owns
    /// it.  SeqCst: ordered against `readers` and `current` — the
    /// stamp re-check while pinned is the reader's torn-read guard.
    stamp: AtomicU64,
    /// Pin count.  SeqCst: a writer observing zero *after* stamping
    /// EMPTY must also observe no reader between its own two steps.
    readers: AtomicUsize,
    snap: UnsafeCell<Option<Arc<ModelSnapshot>>>,
}

impl Slot {
    fn vacant() -> Self {
        Slot {
            stamp: AtomicU64::new(EMPTY),
            readers: AtomicUsize::new(0),
            snap: UnsafeCell::new(None),
        }
    }
}

/// Versioned snapshot store with lock-free readers (see module docs).
pub struct ModelStore {
    slots: [Slot; SLOTS],
    /// Packed `version * SLOTS + slot_index`.  SeqCst: publishing this
    /// word is the linearization point of a publish; it must order
    /// after the victim slot's snapshot write and stamp restore.
    current: AtomicU64,
    /// Serializes writers; holds the next version to assign.
    publish_lock: Mutex<u64>,
}

// SAFETY: the UnsafeCell is only written while the slot's stamp is
// EMPTY and its reader count has drained to zero, and only read while
// the reader holds a pin that the writer waits out — see the module
// docs.  All other fields are Sync atomics/locks.
unsafe impl Sync for ModelStore {}
// SAFETY: same argument as Sync; the cell's contents (Arc) are Send.
unsafe impl Send for ModelStore {}

fn pack(version: u64, slot: usize) -> u64 {
    version * SLOTS as u64 + slot as u64
}

fn unpack(cur: u64) -> (u64, usize) {
    (cur / SLOTS as u64, (cur % SLOTS as u64) as usize)
}

impl ModelStore {
    /// A store serving `initial` as version 1 (the snapshot's own
    /// `version` field is overwritten — the store owns version
    /// numbering).
    pub fn new(mut initial: ModelSnapshot) -> Self {
        initial.version = 1;
        let store = ModelStore {
            slots: [Slot::vacant(), Slot::vacant(), Slot::vacant(), Slot::vacant()],
            current: AtomicU64::new(pack(1, 0)),
            publish_lock: Mutex::new(2),
        };
        // SAFETY: no concurrent access yet — plain initialization of
        // slot 0 before the store is shared.
        unsafe { *store.slots[0].snap.get() = Some(Arc::new(initial)) };
        store.slots[0].stamp.store(1, SeqCst);
        store
    }

    /// The live snapshot.  Lock-free; retries only while racing a
    /// publish that recycled the slot under the reader.
    pub fn load(&self) -> Arc<ModelSnapshot> {
        loop {
            let (version, slot_idx) = unpack(self.current.load(SeqCst));
            let slot = &self.slots[slot_idx];
            slot.readers.fetch_add(1, SeqCst);
            if slot.stamp.load(SeqCst) == version {
                // SAFETY: the stamp matched *while pinned*: the writer
                // cannot recycle the slot until the pin drops, so the
                // Arc clone reads a fully-published snapshot.
                // PANIC-OK: a real (non-EMPTY) stamp is only ever
                // stored after the cell was filled.
                let arc = unsafe { (*slot.snap.get()).as_ref().unwrap().clone() };
                slot.readers.fetch_sub(1, SeqCst);
                debug_assert_eq!(arc.version, version, "slot held a torn snapshot");
                return arc;
            }
            slot.readers.fetch_sub(1, SeqCst);
            crate::sync::spin::spin_loop();
        }
    }

    /// Version of the live snapshot.
    pub fn version(&self) -> u64 {
        unpack(self.current.load(SeqCst)).0
    }

    /// Publish `snap` as the next version and return that version.
    /// Readers keep serving the old version until the final `current`
    /// swap; in-flight `Arc`s of older versions stay valid for as long
    /// as their holders keep them.
    pub fn publish(&self, mut snap: ModelSnapshot) -> u64 {
        let mut next = self.publish_lock.lock().unwrap_or_else(|e| e.into_inner());
        let version = *next;
        *next += 1;
        snap.version = version;

        let live = unpack(self.current.load(SeqCst)).1;
        // victim: prefer non-live slots with no pinned readers (a
        // thread parked mid-pin — preempted, paused in a debugger —
        // must not stall every future publish), oldest stamp first
        // (EMPTY slots are oldest of all).  A pinned slot is chosen
        // only when every non-live slot is pinned, which with SLOTS=4
        // takes three simultaneously parked readers.
        let victim = (0..SLOTS)
            .filter(|&i| i != live)
            .min_by_key(|&i| {
                let pinned = self.slots[i].readers.load(SeqCst) != 0;
                let s = self.slots[i].stamp.load(SeqCst);
                (pinned, if s == EMPTY { 0 } else { s + 1 })
            })
            // PANIC-OK: SLOTS > 1, so excluding the live slot leaves
            // at least one candidate.
            .expect("SLOTS > 1");
        let slot = &self.slots[victim];
        slot.stamp.store(EMPTY, SeqCst);
        // wait out readers that pinned the victim before the
        // invalidation; anyone pinning after it backs off at the stamp
        // re-check without touching the cell.  The window is a few
        // instructions wide, so the SpinWait's spin budget covers the
        // healthy case — past it, yield so a preempted pinner can run
        // and drop its pin (a pure spin deadlocks on one core).
        let mut sw = SpinWait::new();
        while slot.readers.load(SeqCst) != 0 {
            sw.spin();
        }
        // SAFETY: the stamp is EMPTY (no new reader passes its
        // re-check) and the pin count drained to zero, so this writer
        // is the only thread touching the cell.
        unsafe { *slot.snap.get() = Some(Arc::new(snap)) };
        slot.stamp.store(version, SeqCst);
        self.current.store(pack(version, victim), SeqCst);
        version
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Family;
    use crate::glm::ModelKind;
    use std::time::Instant;

    fn snap(tag: f32) -> ModelSnapshot {
        ModelSnapshot {
            version: 0,
            kind: ModelKind::Lasso { lam: 0.1, lip_b: 1.0 },
            family: Family::Regression,
            weights: vec![tag; 8],
            bias: tag,
            alpha: vec![tag; 8],
            col_scales: None,
            gap: tag as f64,
            trained_cols: 8,
            absorbed: 0,
            published_at: Instant::now(),
        }
    }

    #[test]
    fn new_store_serves_version_one() {
        let store = ModelStore::new(snap(7.0));
        assert_eq!(store.version(), 1);
        let s = store.load();
        assert_eq!(s.version, 1);
        assert_eq!(s.bias, 7.0);
    }

    #[test]
    fn publish_bumps_version_and_swaps() {
        let store = ModelStore::new(snap(1.0));
        for k in 2..=10u64 {
            let v = store.publish(snap(k as f32));
            assert_eq!(v, k);
            assert_eq!(store.version(), k);
            assert_eq!(store.load().bias, k as f32);
        }
    }

    #[test]
    fn old_arcs_survive_many_publishes() {
        let store = ModelStore::new(snap(1.0));
        let pinned = store.load();
        for k in 2..=20u64 {
            store.publish(snap(k as f32));
        }
        // the pinned Arc still reads version 1 coherently
        assert_eq!(pinned.version, 1);
        assert!(pinned.weights.iter().all(|&w| w == 1.0));
    }

    /// A reader parked mid-pin (preempted between `readers += 1` and
    /// the stamp check) must not stall publishes: victim selection
    /// routes around the pinned slot.  Before the fix this test hung —
    /// once the pinned slot aged into the oldest non-live slot, publish
    /// spun on its reader count forever.
    #[test]
    fn held_reader_pin_does_not_stall_publish() {
        let store = ModelStore::new(snap(1.0));
        store.publish(snap(2.0)); // some non-live slot now holds v1
        // simulate the parked reader: pin the slot holding version 1
        // and never release (exactly what load() does before its stamp
        // check)
        let pinned_idx = (0..SLOTS)
            .find(|&i| store.slots[i].stamp.load(SeqCst) == 1)
            .expect("version 1 still resident");
        store.slots[pinned_idx].readers.fetch_add(1, SeqCst);
        // far more publishes than slots: every remaining slot recycles
        // many times, so the pinned one would be picked without the
        // routing fix
        for k in 3..=40u64 {
            assert_eq!(store.publish(snap(k as f32)), k);
            assert_eq!(store.load().bias, k as f32);
        }
        // the pinned slot was never recycled out from under its reader
        assert_eq!(store.slots[pinned_idx].stamp.load(SeqCst), 1);
        // once the reader resumes and unpins, the slot rejoins rotation
        store.slots[pinned_idx].readers.fetch_sub(1, SeqCst);
        for k in 41..=50u64 {
            assert_eq!(store.publish(snap(k as f32)), k);
        }
        assert!(
            store.slots[pinned_idx].stamp.load(SeqCst) > 1,
            "released slot should be recycled again"
        );
    }

    #[test]
    fn concurrent_readers_never_see_torn_snapshots() {
        // a compact version of the serve_diff stress test: every loaded
        // snapshot must be internally consistent (all fields carry the
        // version tag) and versions must be monotone per reader
        let store = Arc::new(ModelStore::new(snap(1.0)));
        let stop = Arc::new(crate::sync::AtomicBool::new(false));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let store = Arc::clone(&store);
                let stop = Arc::clone(&stop);
                s.spawn(move || {
                    let mut last = 0u64;
                    while !stop.load(SeqCst) {
                        let snap = store.load();
                        assert!(snap.version >= last, "versions went backwards");
                        last = snap.version;
                        let tag = snap.bias;
                        assert_eq!(snap.gap, tag as f64, "torn gap");
                        assert!(snap.weights.iter().all(|&w| w == tag), "torn weights");
                    }
                });
            }
            for k in 2..=300u64 {
                store.publish(snap(k as f32));
            }
            stop.store(true, SeqCst);
        });
        assert_eq!(store.version(), 300);
    }
}
