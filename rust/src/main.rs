//! `hthc` — the leader binary.
//!
//! ```text
//! hthc train      --dataset epsilon --model lasso --solver hthc ...
//! hthc perfmodel  --n 100000 --d 100000 --r-tilde 0.15
//! hthc datasets   [--scale 0.25]
//! hthc artifacts  [--dir artifacts]
//! ```
//!
//! See `hthc help` for all flags.  The bench harnesses under
//! `rust/benches/` drive the same library APIs; this binary is the
//! interactive entry point.

use hthc::coordinator::HthcConfig;
use hthc::data::{Dataset, DatasetBuilder, DatasetKind, Family, Represent};
use hthc::glm::{family_for, GlmModel};
use hthc::memory::TierSim;
use hthc::metrics::Table;
use hthc::runtime::{GapService, XlaRuntime};
use hthc::serve::{RefitConfig, RetentionPolicy, ServeConfig};
use hthc::solver::{self, keys, EpochEvent, Hthc, StopWhen, Trainer};
use hthc::util::Args;

const HELP: &str = "\
hthc — Heterogeneous Tasks on Homogeneous Cores (HiPC'19 reproduction)

USAGE: hthc <command> [flags]

COMMANDS
  train       train a GLM with HTHC or a baseline solver
  search      grid-search (%B, T_A, T_B, V_B) — the paper's §V-B protocol
  perfmodel   calibrate the §IV-F table and recommend (m, T_A, T_B, V_B)
              (--platform knl|thunderx2|centriq|host retargets the model)
  evaluate    load an exported model (--model-file) and score a dataset
  serve       bounded always-on serving run: batched predict from a
              versioned snapshot store, streaming ingest, warm-start
              refits gated by the duality-gap certificate
  cluster     simulated multi-node sharded training: K nodes solve
              CoCoA-style local subproblems on column shards under a
              failure-tolerant coordinator (deterministic virtual
              network with scriptable faults)
  datasets    print the Table-I-style inventory of synthetic datasets
  artifacts   check the PJRT artifacts load and execute
  help        this text

DATASET FLAGS (train / search / evaluate / serve — one DatasetBuilder
pipeline)
  --dataset   epsilon|dvsc|news20|criteo|tiny   (default tiny, generated)
  --data      PATH — load a real file instead; format is sniffed
              (HTHC1 binary magic, else LIBSVM text)
  --scale     generated dataset scale factor    (default 1.0)
  --normalize scale every column to unit L2 norm
  --center    subtract the target mean (regression only)
  --repr      keep|dense|sparse|quantized|auto  (default keep; auto picks
              dense vs sparse by stored-entry density)
  --quantize  shorthand for --repr quantized (paper §IV-E, dense 4-bit)

TRAIN FLAGS
  --model     lasso|svm|svm-l2|ridge|logistic|elastic|huber (default lasso)
  --adaptive-r target refresh fraction for the online %B controller
  --autotune  refine (t_a, t_b, v_b, m, tile) after a few epochs from
              measured tier traffic (§IV-F over live counters); the
              chosen split lands in the autotune_* extras
  --lam       regularization                    (default 1e-3)
  --solver    hthc|st|omp|omp-wild|passcode|passcode-wild|sgd
  --t-a / --t-b / --v-b                         thread topology
  --batch     %B as a fraction                  (default 0.08)
  --selection gap|random|importance             (default gap)
  --epochs    max epochs                        (default 200)
  --tol       duality-gap tolerance             (default 1e-5)
  --timeout   seconds                           (default 120)
  --mse-target SGD stop-at-MSE                  (default 0 = run out)
  --split     train on this column fraction, report the held-out
              duality-gap certificate (and accuracy for SVM) in extras
  --split-seed PRNG seed for the split          (default: --seed)
  --heldout-every N  with --split: recompute the held-out certificate
              every N evaluation epochs via the epoch observer
  --pjrt      route task A's gaps through the AOT artifacts
  --csv       dump the convergence trace as CSV
  --seed      PRNG seed                         (default 42)

SERVE FLAGS (plus the dataset + --model/--lam/--solver/--t-a/--t-b/--v-b
flags above; the dataset seeds the base training set, raw samples
recovered via Dataset::to_samples)
  --duration     wall-clock budget in seconds   (default 5)
  --batch        rows per predict request       (default 64)
  --threads      predict-pool workers           (default 2)
  --ingest       examples streamed per request round (default 4)
  --ingest-cap   max buffered examples; past it the oldest buffered
                 example is dropped and counted  (default 0 = unbounded)
  --retention    keep-all|reservoir|window — what the retained training
                 corpus forgets at --corpus-cap (default keep-all:
                 nothing; reservoir = uniform sample of all history,
                 window = most recent --corpus-cap examples)
  --corpus-cap   retained-corpus cap for reservoir/window (required > 0
                 for those policies)
  --refit-every  refit once this many examples are buffered (default 64)
  --refit-secs   ... or after this many seconds  (default 0 = off)
  --refit-epochs max training epochs per refit  (default 100)
  --refit-timeout  wall-clock budget per refit  (default 10)
  --regress-tol  reject a refit whose certificate regresses beyond
                 old_gap * (1 + tol)            (default 0.10)
  --assert-healthy  exit 1 unless >=1 refit published and rows served

CLUSTER FLAGS (plus the dataset + --model/--lam flags above; --tol,
--epochs (= rounds), --eval-every and --seed mean what they do for
train)
  --nodes        node (and shard) count K        (default 4)
  --local-passes CD sweeps per node per round    (default 1)
  --leader       bootstrap coordinator node id   (default 0)
  --max-ticks    virtual-time budget             (default 100000)
  --drop         P(unicast silently dropped)     (default 0)
  --dup          P(unicast delivered twice)      (default 0)
  --delay        max extra delivery delay, ticks (default 0)
  --kill         NODE@TICK[,NODE@TICK..] scripted node deaths
  --partition    FROM:TO:ID[+ID..][,..] cut the id island off
                 during the tick window [FROM, TO)
  --csv          dump the leader's certified trace as CSV
  --assert-converged  exit 1 unless the run converged to --tol

GLOBAL FLAGS
  --kernels   scalar|simd|portable|avx2 — inner-loop backend for every
              hot dot/axpy kernel (default: best SIMD the host supports;
              also via the RUST_PALLAS_KERNELS environment variable)

All solvers run through the same solver::Trainer facade over a
data::Dataset built by data::DatasetBuilder, and report a unified
FitReport (see rust/DESIGN.md §9 for the dataset pipeline).
";

fn main() {
    let args = Args::from_env();
    // kernel backend override — must run before anything touches a hot
    // loop (the dispatch is process-wide; also settable via the
    // RUST_PALLAS_KERNELS environment variable)
    if let Some(spec) = args.get("kernels") {
        match hthc::kernels::Backend::parse(&spec) {
            Some(b) => hthc::kernels::set_backend(b),
            None => {
                eprintln!("unknown --kernels {spec:?} (want scalar|simd|portable|avx2)");
                std::process::exit(2);
            }
        }
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "search" => cmd_search(&args),
        "perfmodel" => cmd_perfmodel(&args),
        "evaluate" => cmd_evaluate(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "datasets" => cmd_datasets(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => print!("{HELP}"),
    }
    let unknown = args.unknown();
    if !unknown.is_empty() {
        eprintln!("warning: unrecognized flags: {unknown:?}");
    }
}

/// Name-based construction lives in [`hthc::glm::model_by_name`] (one
/// dispatch shared with the serving layer); the binary only owns the
/// exit policy.
fn build_model(name: &str, lam: f32, n: usize) -> Box<dyn GlmModel> {
    hthc::glm::model_by_name(name, lam, n).unwrap_or_else(|| {
        eprintln!("unknown model {name:?}");
        std::process::exit(2);
    })
}

/// The one dataset construction path for every command: flags onto the
/// `DatasetBuilder` pipeline (source -> preprocess -> represent).
fn build_dataset(args: &Args, family: Family) -> Dataset {
    let mut b = if let Some(path) = args.get("data") {
        DatasetBuilder::path(path).family(family)
    } else {
        let kind = DatasetKind::parse(&args.str_or("dataset", "tiny")).unwrap_or_else(|| {
            eprintln!("unknown dataset (want epsilon|dvsc|news20|criteo|tiny or --data PATH)");
            std::process::exit(2);
        });
        DatasetBuilder::generated(kind, family)
            .scale(args.f64_or("scale", 1.0))
            .seed(args.u64_or("seed", 42))
    };
    b = b
        .normalize(args.bool_or("normalize", false))
        .center_targets(args.bool_or("center", false));
    let quantize = args.bool_or("quantize", false);
    let repr = args.get("repr");
    if quantize && repr.as_deref().is_some_and(|r| r != "quantized" && r != "q4") {
        eprintln!(
            "--quantize conflicts with --repr {:?} (drop one)",
            repr.unwrap()
        );
        std::process::exit(2);
    }
    if quantize {
        b = b.represent(Represent::Quantized);
    } else if let Some(spec) = repr {
        match Represent::parse(&spec) {
            Some(r) => b = b.represent(r),
            None => {
                eprintln!("unknown --repr {spec:?} (want keep|dense|sparse|quantized|auto)");
                std::process::exit(2);
            }
        }
    }
    b.build().unwrap_or_else(|e| {
        eprintln!("dataset: {e}");
        std::process::exit(2);
    })
}

fn cmd_train(args: &Args) {
    let model_name = args.str_or("model", "lasso");
    let family = family_for(&model_name);
    let dataset = build_dataset(args, family);
    println!("dataset: {}", dataset.describe());

    // optional train/validation split over columns (zero-copy views;
    // the train side is materialized because the engines' working-set
    // machinery needs owned column storage)
    let split = args.f64_or("split", 0.0);
    if split != 0.0 && !(split > 0.0 && split < 1.0) {
        // reject negative / >= 1 explicitly rather than silently
        // training without a split (0 is the documented "no split")
        eprintln!("--split must be a fraction in (0, 1), got {split}");
        std::process::exit(2);
    }
    let split_seed = args.u64_or("split-seed", args.u64_or("seed", 42));
    let mut train_cols: Option<Vec<usize>> = None;
    let mut val_cols: Option<Vec<usize>> = None;
    let train_owned: Option<Dataset> = if split > 0.0 {
        let (train_view, val_view) = dataset.split(split, split_seed);
        println!(
            "split: {} train / {} held-out columns (seed {split_seed})",
            train_view.len(),
            val_view.len()
        );
        train_cols = Some(train_view.parent_cols());
        val_cols = Some(val_view.parent_cols());
        Some(train_view.materialize())
    } else {
        None
    };
    let train: &Dataset = train_owned.as_ref().unwrap_or(&dataset);

    let lam = args.f32_or("lam", solver::DEFAULT_LAM);
    let mut model = build_model(&model_name, lam, train.n_cols());
    let sim = TierSim::default();
    let solver_name = args.str_or("solver", "hthc");

    // one facade for every solver: flags -> Trainer (solver::cli is the
    // single source of truth — asserted by the CLI-parity test)
    let mut trainer = solver::cli::trainer_from_args(args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    // --heldout-every N: re-score the held-out certificate from inside
    // the run on the observer cadence.  The observer owns a
    // materialized copy of the held-out columns and a fresh scoring
    // model (the trained model is mutably borrowed by the fit).
    let heldout_every = args.usize_or("heldout-every", 0);
    if heldout_every > 0 && val_cols.is_none() {
        eprintln!("--heldout-every needs --split; ignoring");
    }
    let heldout_evals = std::sync::Arc::new(hthc::sync::AtomicU64::new(0));
    let heldout_cb: Option<Box<dyn FnMut(&EpochEvent<'_>) -> bool>> = match &val_cols {
        Some(cols) if heldout_every > 0 => {
            let val = dataset.col_subset(cols.clone()).materialize();
            let scorer = build_model(&model_name, lam, train.n_cols());
            let classify = model_name.starts_with("svm");
            let evals = std::sync::Arc::clone(&heldout_evals);
            Some(Box::new(move |ev: &EpochEvent<'_>| {
                // engines whose events carry a different-length v (e.g.
                // SGD's row predictions on a transposed problem) are
                // skipped rather than mis-scored
                if ev.epoch % heldout_every != 0 || ev.v.len() != val.d() {
                    return false;
                }
                let zeros = vec![0.0f32; val.n()];
                let gap = hthc::glm::total_gap(
                    scorer.as_ref(),
                    val.as_block_ops(),
                    ev.v,
                    val.targets(),
                    &zeros,
                );
                evals.fetch_add(1, hthc::sync::Ordering::Relaxed);
                let mut line = format!("held-out[epoch {}]: gap {gap:.6e}", ev.epoch);
                if classify {
                    let acc = hthc::serve::predict::accuracy(val.as_block_ops(), ev.v);
                    line.push_str(&format!(", accuracy {:.2}%", acc * 100.0));
                }
                println!("{line}");
                false
            }))
        }
        _ => None,
    };

    // gate on the resolved engine, not the flag spelling, so the
    // `A+B` alias also reaches the PJRT path
    let use_pjrt = trainer.solver_ref().name() == "hthc" && trainer.cfg().use_pjrt_gaps;
    let mut result = if use_pjrt {
        let rt = XlaRuntime::start(&hthc::runtime::default_artifacts_dir())
            .unwrap_or_else(|e| {
                eprintln!("PJRT runtime unavailable: {e:#}");
                std::process::exit(1);
            });
        let service = GapService::new(&rt);
        let mut pjrt_trainer = Trainer::new()
            .solver(Hthc::with_backend(&service))
            .config(trainer.cfg().clone());
        if let Some(cb) = heldout_cb {
            pjrt_trainer = pjrt_trainer.on_epoch(cb);
        }
        pjrt_trainer.fit_with(model.as_mut(), train, &sim)
    } else {
        if let Some(cb) = heldout_cb {
            trainer = trainer.on_epoch(cb);
        }
        trainer.fit_with(model.as_mut(), train, &sim)
    };
    let heldout_eval_count = heldout_evals.load(hthc::sync::Ordering::Relaxed);
    if heldout_eval_count > 0 {
        result.extras.set_u64(keys::HELDOUT_EVALS, heldout_eval_count);
    }

    // held-out certificate: the duality gap decomposes per coordinate
    // (Eq. 3), so summing gap_i over the held-out columns at alpha_i = 0
    // scores the trained w on unseen columns — hinge loss of held-out
    // samples for the SVM orientation, screening violation for L1.
    if let Some(cols) = val_cols {
        let val = dataset.col_subset(cols);
        let zeros = vec![0.0f32; val.len()];
        let heldout =
            hthc::glm::total_gap(model.as_ref(), &val, &result.v, dataset.targets(), &zeros);
        result.extras.set_f64(keys::HELDOUT_GAP, heldout);
        result.extras.set_u64(keys::HELDOUT_COLS, val.len() as u64);
        let mut line = format!(
            "held-out: gap {heldout:.6e} over {} columns",
            val.len()
        );
        if model_name.starts_with("svm") {
            let acc = hthc::serve::predict::accuracy(&val, &result.v);
            result.extras.set_f64(keys::HELDOUT_ACCURACY, acc);
            line.push_str(&format!(", accuracy {:.2}%", acc * 100.0));
        }
        println!("{line}");
    }

    println!("solver: {solver_name}");
    if let Some(mse) = result.extras.f64(keys::FINAL_MSE) {
        println!("sgd: final MSE {mse:.6}");
    }
    if let (Some(t_a), Some(t_b), Some(v_b)) = (
        result.extras.u64(keys::AUTOTUNE_T_A),
        result.extras.u64(keys::AUTOTUNE_T_B),
        result.extras.u64(keys::AUTOTUNE_V_B),
    ) {
        println!(
            "autotune: split t_a={t_a} t_b={t_b} v_b={v_b} m={} tile={}",
            result.extras.u64(keys::AUTOTUNE_M).unwrap_or(0),
            result.extras.u64(keys::AUTOTUNE_TILE_COLS).unwrap_or(0),
        );
    }
    println!("result: {}", result.summary());
    if model_name.starts_with("svm") {
        let acc = hthc::serve::predict::accuracy(train.as_block_ops(), &result.v);
        println!("training accuracy: {:.2}%", acc * 100.0);
    }
    if args.bool_or("csv", false) {
        print!("{}", result.trace.to_csv());
    }
    if let Some(path) = args.get("export") {
        // after --split the iterate is indexed by view-local train
        // columns; scatter it back to parent coordinates (held-out
        // coordinates were never trained and stay 0) so the export is
        // always full-length and evaluate-compatible
        let alpha = match &train_cols {
            Some(cols) => {
                let mut full = vec![0.0f32; dataset.n_cols()];
                for (k, &j) in cols.iter().enumerate() {
                    full[j] = result.alpha[k];
                }
                full
            }
            None => result.alpha.clone(),
        };
        let saved = hthc::data::io::SavedModel { name: model_name.clone(), lam, alpha };
        let f = std::fs::File::create(&path).expect("create export file");
        hthc::data::io::save_model(std::io::BufWriter::new(f), &saved).expect("export");
        println!("model exported to {path}");
    }
    println!("{}", result.phase_times.render());
    println!("{}", result.staleness.render());
    print_tier_report(&sim);
}

fn cmd_search(args: &Args) {
    let model_name = args.str_or("model", "lasso");
    let family = family_for(&model_name);
    let g = build_dataset(args, family);
    println!("dataset: {}", g.describe());
    let lam = args.f32_or("lam", solver::DEFAULT_LAM);
    let n = g.n();
    let probe = build_model(&model_name, lam, n);
    let obj0 = probe
        .objective(&vec![0.0; g.d()], g.targets(), &vec![0.0; n])
        .abs()
        .max(1.0);
    let target = args.f64_or("target-rel", 1e-3) * obj0;
    let grid = hthc::coordinator::SearchGrid::small();
    println!(
        "searching {} configurations, target gap {:.3e}, {:.0}s each ...",
        grid.len(),
        target,
        args.f64_or("per-candidate", 10.0)
    );
    let base = HthcConfig {
        max_epochs: args.usize_or("epochs", 100_000),
        eval_every: 5,
        seed: args.u64_or("seed", 42),
        ..Default::default()
    };
    let model_name2 = model_name.clone();
    let results = hthc::coordinator::grid_search(
        &move || build_model(&model_name2, lam, n),
        &g,
        &grid,
        target,
        args.f64_or("per-candidate", 10.0),
        &base,
        true,
    );
    let mut t = Table::new(
        format!("Search results ({} {})", model_name, g.meta().source.describe()),
        &["rank", "%B", "T_A", "T_B", "V_B", "T_total", "t(target)", "epochs", "refresh"],
    );
    for (i, r) in results.iter().take(args.usize_or("top", 10)).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            format!("{:.0}%", r.batch_frac * 100.0),
            r.t_a.to_string(),
            r.t_b.to_string(),
            r.v_b.to_string(),
            r.total_threads().to_string(),
            hthc::metrics::report::fmt_opt_secs(r.time_to_target),
            r.epochs.to_string(),
            format!("{:.0}%", r.refresh_frac * 100.0),
        ]);
    }
    t.print();
    let nb = hthc::coordinator::near_best(&results, 1.1);
    println!("{} configurations within 110% of best (Fig. 6 view)", nb.len());
}

fn cmd_evaluate(args: &Args) {
    let path = args.get("model-file").unwrap_or_else(|| {
        eprintln!("--model-file required");
        std::process::exit(2);
    });
    let f = std::fs::File::open(&path).expect("open model file");
    let saved = hthc::data::io::load_model(std::io::BufReader::new(f)).expect("parse model");
    println!("model: {} (lam {}, {} coordinates)", saved.name, saved.lam, saved.alpha.len());
    let family = if saved.name.starts_with("svm") || saved.name == "logistic" {
        Family::Classification
    } else {
        Family::Regression
    };
    let g = build_dataset(args, family);
    assert_eq!(g.n(), saved.alpha.len(), "model/dataset coordinate mismatch");
    let v = g.matvec_alpha(&saved.alpha);
    // scoring goes through the consolidated serve::predict seam
    match family {
        Family::Regression => {
            let mse = hthc::serve::predict::mean_squared_error(&v, g.targets());
            let support = saved.alpha.iter().filter(|&&a| a != 0.0).count();
            println!("MSE {mse:.6}; support {support}/{}", g.n());
        }
        Family::Classification => {
            let acc = hthc::serve::predict::accuracy(g.as_block_ops(), &v);
            println!("training accuracy {:.2}%", acc * 100.0);
        }
    }
}

/// `hthc serve` — a bounded always-on serving run (`serve::sim::run`):
/// initial fit on the dataset flags, then batched predicts against the
/// live snapshot while streamed examples trigger certificate-gated
/// warm-start refits.
fn cmd_serve(args: &Args) {
    let model_name = args.str_or("model", "lasso");
    let family = family_for(&model_name);
    let dataset = build_dataset(args, family);
    println!("dataset: {}", dataset.describe());
    let base = dataset.to_samples().unwrap_or_else(|e| {
        eprintln!("serve: {e}");
        std::process::exit(2);
    });
    drop(dataset); // the serving run rebuilds through its own pipeline
    let budget = StopWhen::gap_below(args.f64_or("tol", 1e-5))
        .max_epochs(args.usize_or("refit-epochs", 100))
        .timeout_secs(args.f64_or("refit-timeout", 10.0));
    let retention_name = args.str_or("retention", "keep-all");
    let corpus_cap = args.usize_or("corpus-cap", 0);
    let Some(retention) = RetentionPolicy::parse(&retention_name, corpus_cap) else {
        eprintln!(
            "serve: bad --retention {retention_name:?} with --corpus-cap {corpus_cap} \
             (want keep-all, or reservoir/window with a positive cap)"
        );
        std::process::exit(2);
    };
    let cfg = ServeConfig {
        duration_secs: args.f64_or("duration", 5.0),
        batch: args.usize_or("batch", 64),
        threads: args.usize_or("threads", 2),
        ingest_per_round: args.usize_or("ingest", 4),
        ingest_cap: args.usize_or("ingest-cap", 0),
        refit: RefitConfig {
            refit_every: args.usize_or("refit-every", 64),
            refit_secs: args.f64_or("refit-secs", 0.0),
            budget,
            regress_tol: args.f64_or("regress-tol", 0.10),
            retention,
            threads: (
                args.usize_or("t-a", 1),
                args.usize_or("t-b", 2),
                args.usize_or("v-b", 1),
            ),
            solver: args.str_or("solver", "hthc"),
            seed: args.u64_or("seed", 42),
        },
        normalize: args.bool_or("normalize", true),
        center: args.bool_or("center", true),
        model: model_name,
        lam: args.f32_or("lam", solver::DEFAULT_LAM),
        seed: args.u64_or("seed", 42),
    };
    match hthc::serve::sim::run(base, &cfg) {
        Ok(report) => {
            println!("{}", report.render());
            if args.bool_or("assert-healthy", false) && !report.healthy() {
                eprintln!(
                    "serve: UNHEALTHY — need >=1 refit publish and served rows \
                     (published {}, rows {})",
                    report.published, report.rows
                );
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("serve: {e}");
            std::process::exit(1);
        }
    }
}

/// `hthc cluster` — run the simulated multi-node trainer
/// (`cluster::run_cluster`) on the dataset flags and report the final
/// leader's certified fit.
fn cmd_cluster(args: &Args) {
    let model_name = args.str_or("model", "lasso");
    let family = family_for(&model_name);
    let dataset = build_dataset(args, family);
    println!("dataset: {}", dataset.describe());
    let cfg = solver::cli::cluster_config_from_args(args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let lam = args.f32_or("lam", solver::DEFAULT_LAM);
    let n = dataset.n_cols();
    let name = model_name.clone();
    let make = move || build_model(&name, lam, n);
    match hthc::cluster::run_cluster(&dataset, &make, &cfg) {
        Ok(report) => {
            println!("cluster: {}", report.summary());
            if args.bool_or("csv", false) {
                print!("{}", report.fit.trace.to_csv());
            }
            if args.bool_or("assert-converged", false) && !report.fit.converged {
                eprintln!(
                    "cluster: NOT CONVERGED — gap {:.3e} after {} rounds / {} ticks \
                     (tol {:.3e})",
                    report.fit.final_gap().unwrap_or(f64::NAN),
                    report.fit.epochs,
                    report.ticks,
                    cfg.gap_tol,
                );
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("cluster: {e}");
            std::process::exit(1);
        }
    }
}

fn print_tier_report(sim: &TierSim) {
    let slow = sim.stats(hthc::memory::Tier::Slow);
    let fast = sim.stats(hthc::memory::Tier::Fast);
    println!(
        "tier traffic: DRAM {} read / {} written, MCDRAM {} read / {} written",
        hthc::util::fmt_bytes(slow.read_bytes),
        hthc::util::fmt_bytes(slow.write_bytes),
        hthc::util::fmt_bytes(fast.read_bytes),
        hthc::util::fmt_bytes(fast.write_bytes),
    );
}

fn cmd_perfmodel(args: &Args) {
    let n = args.usize_or("n", 100_000);
    let d = args.usize_or("d", 100_000);
    let r = args.f64_or("r-tilde", 0.15);
    let platform = hthc::memory::Platform::parse(&args.str_or("platform", "knl"))
        .unwrap_or_else(|| {
            eprintln!("unknown --platform (knl|thunderx2|centriq|host)");
            std::process::exit(2);
        });
    let budget = args.usize_or("threads", platform.cores);
    println!("platform: {}", platform.describe());
    if !platform.has_fast_tier() {
        println!(
            "note: uniform memory — HTHC loses the placement lever here; \
             the model still balances compute (paper conclusion: ports to \
             other manycores via adaptivity)."
        );
    }
    println!("calibrating t_I,d table (paper §IV-F) ...");
    let pm = hthc::coordinator::PerfModel::calibrate(
        &[10_000, 50_000, 100_000, 500_000, 1_000_000, 5_000_000],
        &[1, 2, 4, 8, 12, 16, 20, 24, 32, 48, 72],
        &[1, 2, 4, 8, 14, 16, 32, 56, 64, 68, 72],
        &[1, 2, 4, 6, 8, 10],
    );
    println!(
        "host constants: {:.2} ns/elem dot, {:.1} ns/barrier",
        pm.per_elem_secs * 1e9,
        pm.sync_secs * 1e9
    );
    match pm.recommend(n, d, r, &[0.001, 0.01, 0.02, 0.04, 0.08, 0.25, 0.5], budget) {
        Some(rec) => {
            let mut t = Table::new(
                format!("Recommended configuration (n={n}, d={d}, r~={r})"),
                &["m", "T_A", "T_B", "V_B", "T_total", "tile", "epoch (model)", "z refresh"],
            );
            t.row(vec![
                rec.m.to_string(),
                rec.t_a.to_string(),
                rec.t_b.to_string(),
                rec.v_b.to_string(),
                (rec.t_a + rec.t_b * rec.v_b).to_string(),
                rec.tile_cols.to_string(),
                hthc::util::fmt_secs(rec.epoch_secs),
                format!("{:.0}%", rec.refresh_frac * 100.0),
            ]);
            t.print();
        }
        None => println!("no feasible configuration under budget {budget}"),
    }
}

fn cmd_datasets(args: &Args) {
    let scale = args.f64_or("scale", 1.0);
    let mut t = Table::new(
        format!("Synthetic datasets (Table I analogues, scale {scale})"),
        &["dataset", "rows (d)", "coords (n)", "repr", "size", "paper original"],
    );
    for (kind, orig) in [
        (DatasetKind::EpsilonLike, "400,000 samples x 2,000 features dense, 3.2 GB"),
        (DatasetKind::DvscLike, "40,002 x 200,704 dense, 32.1 GB"),
        (DatasetKind::News20Like, "19,996 x 1,355,191 sparse, 0.07 GB"),
        (DatasetKind::CriteoLike, "45,840,617 x 1,000,000 sparse, 14.4 GB"),
    ] {
        let g = DatasetBuilder::generated(kind, Family::Regression)
            .scale(scale)
            .seed(42)
            .build()
            .expect("generated dataset");
        t.row(vec![
            kind.name().into(),
            g.d().to_string(),
            g.n().to_string(),
            g.repr_name().into(),
            hthc::util::fmt_bytes(g.meta().bytes),
            orig.into(),
        ]);
    }
    t.print();
}

fn cmd_artifacts(args: &Args) {
    let dir: std::path::PathBuf = args
        .get("dir")
        .map(Into::into)
        .unwrap_or_else(hthc::runtime::default_artifacts_dir);
    match XlaRuntime::start(&dir) {
        Err(e) => {
            eprintln!("FAILED to start runtime over {}: {e:#}", dir.display());
            std::process::exit(1);
        }
        Ok(rt) => {
            println!("{} artifacts in {}", rt.manifest().artifacts.len(), dir.display());
            // smoke: run the small lasso gap artifact with known numbers
            let (d, n) = (1024, 256);
            let out = rt.run(
                "gaps_lasso_1024x256",
                vec![
                    hthc::runtime::ArgData::F32 { data: vec![1.0; d * n], dims: vec![d, n] },
                    hthc::runtime::ArgData::F32 { data: vec![1.0 / d as f32; d], dims: vec![d] },
                    hthc::runtime::ArgData::F32 { data: vec![0.0; n], dims: vec![n] },
                    hthc::runtime::ArgData::ScalarF32(0.5),
                    hthc::runtime::ArgData::ScalarF32(n as f32),
                    hthc::runtime::ArgData::ScalarF32(1.0),
                ],
            );
            match out {
                Ok(res) => {
                    // u = 1 per column; gap = 0*1 + 0 + 1*max(0, 1-0.5) = 0.5
                    let z = &res[0];
                    let ok = z.iter().all(|&g| (g - 0.5).abs() < 1e-4);
                    println!(
                        "gaps_lasso_1024x256 smoke: z[0]={:.4} ({} values) -> {}",
                        z[0],
                        z.len(),
                        if ok { "OK" } else { "MISMATCH" }
                    );
                    if !ok {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("execution failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
    }
}
