//! Convergence traces: (time, epoch, objective, duality gap) series.
//!
//! Fig. 5's precision-vs-time curves and every time-to-threshold table
//! (IV, V, VI) are derived from these.

/// One measurement.
#[derive(Clone, Copy, Debug)]
pub struct ConvergencePoint {
    pub secs: f64,
    pub epoch: usize,
    pub objective: f64,
    pub duality_gap: f64,
}

/// A labelled series of measurements.
#[derive(Clone, Debug, Default)]
pub struct ConvergenceTrace {
    pub label: String,
    pub points: Vec<ConvergencePoint>,
}

impl ConvergenceTrace {
    pub fn new(label: impl Into<String>) -> Self {
        ConvergenceTrace { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, secs: f64, epoch: usize, objective: f64, duality_gap: f64) {
        self.points.push(ConvergencePoint { secs, epoch, objective, duality_gap });
    }

    pub fn final_objective(&self) -> Option<f64> {
        self.points.last().map(|p| p.objective)
    }

    pub fn final_gap(&self) -> Option<f64> {
        self.points.last().map(|p| p.duality_gap)
    }

    /// First time the duality gap drops below `thresh` (time-to-gap
    /// tables: Table VI, Fig. 5 thresholds).
    pub fn time_to_gap(&self, thresh: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.duality_gap <= thresh)
            .map(|p| p.secs)
    }

    /// First epoch at which the gap drops below `thresh` — the currency
    /// for work-normalized comparisons (epochs x updates-per-epoch).
    pub fn epoch_to_gap(&self, thresh: f64) -> Option<usize> {
        self.points
            .iter()
            .find(|p| p.duality_gap <= thresh)
            .map(|p| p.epoch)
    }

    /// First time suboptimality (objective - `opt`) drops below `thresh`.
    pub fn time_to_subopt(&self, opt: f64, thresh: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.objective - opt <= thresh)
            .map(|p| p.secs)
    }

    /// Best objective seen (monotone lower envelope end).
    pub fn best_objective(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.objective)
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Render as CSV (plots are produced offline from these).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("secs,epoch,objective,duality_gap\n");
        for p in &self.points {
            s.push_str(&format!(
                "{:.6},{},{:.9e},{:.9e}\n",
                p.secs, p.epoch, p.objective, p.duality_gap
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ConvergenceTrace {
        let mut t = ConvergenceTrace::new("test");
        t.push(0.1, 1, 10.0, 1.0);
        t.push(0.2, 2, 5.0, 0.1);
        t.push(0.3, 3, 4.0, 0.01);
        t
    }

    #[test]
    fn time_to_gap_finds_first_crossing() {
        let t = sample();
        assert_eq!(t.time_to_gap(0.5), Some(0.2));
        assert_eq!(t.time_to_gap(0.01), Some(0.3));
        assert_eq!(t.time_to_gap(1e-9), None);
        assert_eq!(t.epoch_to_gap(0.5), Some(2));
        assert_eq!(t.epoch_to_gap(1e-9), None);
    }

    #[test]
    fn time_to_subopt() {
        let t = sample();
        assert_eq!(t.time_to_subopt(3.9, 1.2), Some(0.2)); // 5.0-3.9=1.1
        assert_eq!(t.time_to_subopt(3.9, 0.05), None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("secs,epoch,"));
    }

    #[test]
    fn final_and_best() {
        let t = sample();
        assert_eq!(t.final_objective(), Some(4.0));
        assert_eq!(t.best_objective(), Some(4.0));
        assert_eq!(t.final_gap(), Some(0.01));
    }
}
