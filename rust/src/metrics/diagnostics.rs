//! Run diagnostics: staleness histograms and epoch-phase timing.
//!
//! The paper's §IV-F analysis rests on *how stale* the gap memory is
//! and *where epoch time goes* (swap vs A vs B vs eval).  These
//! collectors turn both into printable summaries used by the benches
//! and the EXPERIMENTS.md §Perf narrative.

/// Histogram over staleness ages (epochs since last refresh).
#[derive(Debug, Default, Clone)]
pub struct StalenessHistogram {
    /// buckets: 0, 1, 2-3, 4-7, 8-15, 16+
    pub buckets: [u64; 6],
    pub total: u64,
}

impl StalenessHistogram {
    pub fn from_ages(ages: &[u32]) -> Self {
        let mut h = StalenessHistogram::default();
        for &a in ages {
            let b = match a {
                0 => 0,
                1 => 1,
                2..=3 => 2,
                4..=7 => 3,
                8..=15 => 4,
                _ => 5,
            };
            h.buckets[b] += 1;
            h.total += 1;
        }
        h
    }

    /// Fraction of entries no older than `epochs`.
    pub fn fresh_within(&self, epochs: u32) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        let upto = match epochs {
            0 => 1,
            1 => 2,
            2..=3 => 3,
            4..=7 => 4,
            8..=15 => 5,
            _ => 6,
        };
        let fresh: u64 = self.buckets[..upto].iter().sum();
        fresh as f64 / self.total as f64
    }

    pub fn render(&self) -> String {
        let labels = ["0", "1", "2-3", "4-7", "8-15", "16+"];
        let mut s = String::from("staleness (epochs): ");
        for (l, &c) in labels.iter().zip(&self.buckets) {
            let pct = if self.total > 0 {
                100.0 * c as f64 / self.total as f64
            } else {
                0.0
            };
            s.push_str(&format!("{l}:{pct:.0}% "));
        }
        s.trim_end().to_string()
    }
}

/// Accumulated per-phase epoch timing.
#[derive(Debug, Default, Clone, Copy)]
pub struct PhaseTimes {
    pub snapshot_secs: f64,
    pub select_secs: f64,
    pub swap_secs: f64,
    pub run_secs: f64,
    pub eval_secs: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.snapshot_secs + self.select_secs + self.swap_secs + self.run_secs + self.eval_secs
    }

    pub fn render(&self) -> String {
        let t = self.total().max(1e-12);
        format!(
            "epoch time: snapshot {:.0}% select {:.0}% swap {:.0}% run {:.0}% eval {:.0}% (total {})",
            100.0 * self.snapshot_secs / t,
            100.0 * self.select_secs / t,
            100.0 * self.swap_secs / t,
            100.0 * self.run_secs / t,
            100.0 * self.eval_secs / t,
            crate::util::fmt_secs(self.total()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets() {
        let h = StalenessHistogram::from_ages(&[0, 0, 1, 2, 3, 5, 9, 40]);
        assert_eq!(h.buckets, [2, 1, 2, 1, 1, 1]);
        assert_eq!(h.total, 8);
        assert!((h.fresh_within(0) - 0.25).abs() < 1e-12);
        assert!((h.fresh_within(3) - 5.0 / 8.0).abs() < 1e-12);
        assert_eq!(h.fresh_within(1000), 1.0);
    }

    #[test]
    fn empty_histogram_is_fully_fresh() {
        let h = StalenessHistogram::from_ages(&[]);
        assert_eq!(h.fresh_within(0), 1.0);
        assert!(h.render().contains("0:0%"));
    }

    #[test]
    fn phase_times_render() {
        let p = PhaseTimes {
            snapshot_secs: 0.1,
            select_secs: 0.1,
            swap_secs: 0.2,
            run_secs: 0.5,
            eval_secs: 0.1,
        };
        assert!((p.total() - 1.0).abs() < 1e-12);
        let s = p.render();
        assert!(s.contains("run 50%"), "{s}");
    }
}
