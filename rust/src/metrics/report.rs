//! Aligned-column table rendering for the bench harnesses — every
//! table/figure bench prints rows in the paper's own format.

/// A simple text table with a title, headers and string rows.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                widths[c] = widths[c].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for c in 0..ncol {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[c], width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format an optional time-to-threshold ("--" when never reached).
pub fn fmt_opt_secs(t: Option<f64>) -> String {
    match t {
        Some(s) => crate::util::fmt_secs(s),
        None => "--".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // 'value' column aligned after widest name
        assert!(lines[1].starts_with("name       value"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_opt() {
        assert_eq!(fmt_opt_secs(None), "--");
        assert!(fmt_opt_secs(Some(1.5)).contains('s'));
    }
}
