//! Convergence traces and table rendering for the experiment harnesses.

pub mod diagnostics;
pub mod report;
pub mod trace;

pub use diagnostics::{PhaseTimes, StalenessHistogram};
pub use report::Table;
pub use trace::{ConvergencePoint, ConvergenceTrace};
