//! # HTHC — Heterogeneous Tasks on Homogeneous Cores
//!
//! Reproduction of *"On Linear Learning with Manycore Processors"*
//! (Wszola, Jaggi, Mendler-Dünner, Püschel — HiPC 2019).
//!
//! HTHC trains generalized linear models with duality-gap guided
//! asynchronous block coordinate descent split into two heterogeneous
//! tasks running concurrently on disjoint core sets:
//!
//! * **Task A** recomputes coordinate-wise duality gaps into a shared
//!   *gap memory* (read-only w.r.t. the model),
//! * **Task B** performs asynchronous parallel SCD on the `m`
//!   highest-gap coordinates (the only writer of the model).
//!
//! The crate layers (see `rust/DESIGN.md`):
//!
//! * [`data`] — dense / chunked-sparse / 4-bit-quantized matrices
//!   behind one `Dataset` value (builder pipeline for
//!   load/normalize/represent/place, zero-copy column views),
//!   synthetic workload generators, LIBSVM parsing;
//! * [`memory`] — the two-tier (DRAM vs MCDRAM) placement & bandwidth
//!   simulator standing in for KNL flat mode;
//! * [`kernels`] — every hot inner loop (dense/sparse/quantized
//!   dot/axpy/norms and the shared-vector variants) behind one
//!   runtime-dispatched scalar/SIMD seam (`RUST_PALLAS_KERNELS`);
//! * [`glm`] — the model zoo (Lasso, SVM, ridge, logistic, elastic-net)
//!   with closed-form coordinate updates and duality gaps;
//! * [`threadpool`] — pinned worker pools with counter-based barriers
//!   (the paper's pthreads-over-OpenMP discipline);
//! * [`sched`] — the shard-pinned tile scheduler behind every bulk
//!   column sweep (per-worker shard queues + work stealing);
//! * [`coordinator`] — the HTHC scheme itself plus the §IV-F
//!   performance model;
//! * [`baselines`] — ST, OMP, OMP-WILD, PASSCoDe, SGD comparators;
//! * [`cluster`] — simulate-first multi-node sharded training: column
//!   shards as dataset views, CoCoA-style local subproblems, an
//!   epoch-barrier coordinator with duality-gap certificates, bully
//!   leader election, and a deterministic lossy-network simulator with
//!   reliable-link delivery (`hthc cluster`);
//! * [`solver`] — the engine-agnostic training API: [`solver::Trainer`]
//!   builds a [`solver::Problem`] and runs any [`solver::Solver`]
//!   (HTHC or baseline) to a unified [`solver::FitReport`];
//! * [`serve`] — the always-on serving layer: versioned snapshot store
//!   with lock-free readers, batched raw-input prediction through the
//!   blocked kernels, streaming ingest with certificate-gated
//!   warm-start refits, and latency/QPS statistics (`hthc serve`);
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`), Python never on the hot path;
//! * [`metrics`] — convergence traces and table rendering;
//! * [`sync`] — the concurrency shim every protocol atomic, mutex and
//!   spin loop goes through: pure `std` re-exports normally, a
//!   deterministic model-checking scheduler under
//!   `--cfg pallas_model_check` (DESIGN.md §12);
//! * [`util`] — PRNG, CLI parsing, timing, errors (no external deps).

pub mod baselines;
pub mod bench_support;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod glm;
pub mod kernels;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod solver;
pub mod sync;
pub mod threadpool;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = util::error::Result<T>;
