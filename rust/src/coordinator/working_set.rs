//! Task B's working set: the selected columns copied into the fast
//! memory tier (paper §IV-A1: "B can be configured to work only with a
//! subset of data small enough to be allocated there [MCDRAM]").
//!
//! * Dense columns are copied into one contiguous fast-tier slab.
//! * Sparse columns go through the chunk/stack structure of §IV-D
//!   ([`crate::data::sparse::ChunkPool`]), so epoch-to-epoch swaps reuse
//!   preallocated space despite wildly varying column lengths.
//! * Quantized data is referenced in place (the packed matrix is ~8x
//!   smaller, and the paper's quantized experiments keep D resident);
//!   traffic is still charged to the fast tier.
//!
//! Every swap charges the [`TierSim`]: read from slow, write to fast.

use crate::data::{sparse::ChunkPool, ColumnOps, Matrix};
use crate::memory::{Tier, TierSim};

pub enum WorkingSet<'m> {
    Dense {
        d: usize,
        /// Contiguous column-major copies of the batch columns.
        buf: Vec<f32>,
        sq_norms: Vec<f32>,
        slots: usize,
    },
    Sparse {
        d: usize,
        pool: ChunkPool,
        matrix: &'m crate::data::SparseMatrix,
    },
    QuantRef {
        matrix: &'m crate::data::QuantizedMatrix,
        batch: Vec<usize>,
    },
}

impl<'m> WorkingSet<'m> {
    /// Preallocate for batches of up to `m_max` columns of `matrix`.
    pub fn new(matrix: &'m Matrix, m_max: usize) -> Self {
        match matrix {
            Matrix::Dense(dm) => WorkingSet::Dense {
                d: dm.n_rows(),
                buf: vec![0.0; dm.n_rows() * m_max],
                sq_norms: vec![0.0; m_max],
                slots: m_max,
            },
            Matrix::Sparse(sm) => {
                // Pool sized by the m_max densest columns (paper §IV-D).
                let mut lens: Vec<usize> = (0..sm.n_cols()).map(|j| sm.nnz(j)).collect();
                lens.sort_unstable_by(|a, b| b.cmp(a));
                let max_nnz = lens.first().copied().unwrap_or(1).max(1);
                let chunk_len = 128;
                // Total chunks for the m_max densest columns:
                let total: usize = lens
                    .iter()
                    .take(m_max)
                    .map(|&l| l.div_ceil(chunk_len).max(1))
                    .sum();
                let mut pool = ChunkPool::new(m_max, max_nnz.max(chunk_len), chunk_len);
                // ChunkPool::new sizes uniformly; shrink is not needed —
                // report the uniform bound. `total` documents the tight
                // §IV-D sizing; assert it fits.
                debug_assert!(pool.free_chunks() >= total);
                let _ = &mut pool;
                WorkingSet::Sparse { d: sm.n_rows(), pool, matrix: sm }
            }
            Matrix::Quantized(qm) => WorkingSet::QuantRef { matrix: qm, batch: Vec::new() },
        }
    }

    pub fn n_rows(&self) -> usize {
        match self {
            WorkingSet::Dense { d, .. } => *d,
            WorkingSet::Sparse { d, .. } => *d,
            WorkingSet::QuantRef { matrix, .. } => matrix.n_rows(),
        }
    }

    /// Copy the batch columns in (evicting the previous epoch's), and
    /// charge the tier traffic: read from `home` (the dataset's
    /// recorded placement), write into the fast tier the working set
    /// occupies.  `batch[slot]` gives the original column index of each
    /// slot.
    pub fn swap_in(&mut self, matrix: &Matrix, batch: &[usize], sim: &TierSim, home: Tier) {
        match (self, matrix) {
            (WorkingSet::Dense { d, buf, sq_norms, slots }, Matrix::Dense(dm)) => {
                assert!(batch.len() <= *slots, "batch exceeds working-set slots");
                for (slot, &j) in batch.iter().enumerate() {
                    let col = dm.col(j);
                    buf[slot * *d..(slot + 1) * *d].copy_from_slice(col);
                    sq_norms[slot] = dm.sq_norm(j);
                    let bytes = (*d * 4) as u64;
                    sim.read(home, bytes);
                    sim.write(Tier::Fast, bytes);
                }
            }
            (WorkingSet::Sparse { pool, matrix: sm, .. }, Matrix::Sparse(_)) => {
                assert!(batch.len() <= pool.slots());
                // evict everything first so the stack has all chunks back
                for slot in 0..pool.slots() {
                    pool.swap_out(slot);
                }
                for (slot, &j) in batch.iter().enumerate() {
                    let (rows, vals) = sm.col(j);
                    let ok = pool.swap_in(slot, rows, vals);
                    assert!(ok, "chunk pool exhausted (col {j}, nnz {})", rows.len());
                    let bytes = (rows.len() * 8) as u64;
                    sim.read(home, bytes);
                    sim.write(Tier::Fast, bytes);
                }
            }
            (WorkingSet::QuantRef { batch: b, matrix: qm }, Matrix::Quantized(_)) => {
                b.clear();
                b.extend_from_slice(batch);
                for &j in batch {
                    let bytes = qm.col_bytes(j);
                    sim.read(home, bytes);
                    sim.write(Tier::Fast, bytes);
                }
            }
            _ => panic!("working set / matrix representation mismatch"),
        }
    }

    /// `||column-at-slot||^2`.
    #[inline]
    pub fn sq_norm(&self, slot: usize) -> f32 {
        match self {
            WorkingSet::Dense { sq_norms, .. } => sq_norms[slot],
            WorkingSet::Sparse { pool, .. } => pool.sq_norm(slot),
            WorkingSet::QuantRef { matrix, batch } => matrix.sq_norm(batch[slot]),
        }
    }

    /// Dense column slice for slot (dense working sets only).
    #[inline]
    pub fn dense_col(&self, slot: usize) -> &[f32] {
        match self {
            WorkingSet::Dense { d, buf, .. } => &buf[slot * d..(slot + 1) * d],
            _ => panic!("dense_col on non-dense working set"),
        }
    }

    /// Fused stale dot against the live shared vector over rows
    /// `[lo, hi)`: `sum_r col[r] * w_of(v[r], y[r])`.
    pub fn dot_mapped(
        &self,
        slot: usize,
        v: &super::SharedVector,
        y: &[f32],
        kind: crate::glm::ModelKind,
        lo: usize,
        hi: usize,
    ) -> f32 {
        match self {
            WorkingSet::Dense { .. } => {
                let col = self.dense_col(slot);
                // y-free fast path for the SVM family (§Perf)
                if let Some(scale) = kind.linear_in_v() {
                    v.dot_scaled_range(col, scale, lo, hi)
                } else {
                    v.dot_mapped_range(col, y, |vj, yj| kind.w_of(vj, yj), lo, hi)
                }
            }
            WorkingSet::Sparse { pool, .. } => {
                // V_B is 1 for sparse data in practice (paper §IV-D); a
                // row-window is still honoured for correctness.  Chunk
                // entries are row-sorted, so the window is a contiguous
                // sub-slice of each chunk.
                let mut s = 0.0f32;
                pool.for_each_chunk(slot, |rows, vals| {
                    if lo == 0 && hi >= self.n_rows() {
                        s += v.dot_mapped_sparse(rows, vals, y, |vj, yj| kind.w_of(vj, yj));
                    } else {
                        let a = rows.partition_point(|&r| (r as usize) < lo);
                        let b = rows.partition_point(|&r| (r as usize) < hi);
                        s += v.dot_mapped_sparse(&rows[a..b], &vals[a..b], y, |vj, yj| {
                            kind.w_of(vj, yj)
                        });
                    }
                });
                s
            }
            WorkingSet::QuantRef { matrix, batch } => {
                // Quantized dot over a live v: dequantize on the fly.
                let j = batch[slot];
                let col = matrix.col_dense(j); // small epochs: acceptable
                v.dot_mapped_range(&col, y, |vj, yj| kind.w_of(vj, yj), lo, hi)
            }
        }
    }

    /// `v[lo..hi) += delta * col` under the shared vector's chunk locks.
    pub fn axpy_locked(
        &self,
        slot: usize,
        v: &super::SharedVector,
        delta: f32,
        lo: usize,
        hi: usize,
    ) {
        match self {
            WorkingSet::Dense { .. } => {
                v.axpy_dense_locked(self.dense_col(slot), delta, lo, hi);
            }
            WorkingSet::Sparse { pool, .. } => {
                pool.for_each_chunk(slot, |rows, vals| {
                    if lo == 0 && hi >= self.n_rows() {
                        v.axpy_sparse_locked(rows, vals, delta);
                    } else {
                        let a = rows.partition_point(|&r| (r as usize) < lo);
                        let b = rows.partition_point(|&r| (r as usize) < hi);
                        v.axpy_sparse_locked(&rows[a..b], &vals[a..b], delta);
                    }
                });
            }
            WorkingSet::QuantRef { matrix, batch } => {
                let col = matrix.col_dense(batch[slot]);
                v.axpy_dense_locked(&col, delta, lo, hi);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SharedVector;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::data::{DenseMatrix, QuantizedMatrix};
    use crate::glm::ModelKind;

    fn dense_matrix() -> Matrix {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 81);
        g.matrix
    }

    #[test]
    fn dense_swap_in_copies_columns() {
        let m = dense_matrix();
        let sim = TierSim::default();
        let mut ws = WorkingSet::new(&m, 4);
        ws.swap_in(&m, &[0, 5, 9], &sim, Tier::Slow);
        if let Matrix::Dense(dm) = &m {
            assert_eq!(ws.dense_col(1), dm.col(5));
            assert_eq!(ws.sq_norm(2), dm.sq_norm(9));
        }
        let d = m.n_rows() as u64;
        assert_eq!(sim.stats(Tier::Fast).write_bytes, 3 * d * 4);
        assert_eq!(sim.stats(Tier::Slow).read_bytes, 3 * d * 4);
    }

    #[test]
    fn dense_dot_and_axpy_match_direct() {
        let m = dense_matrix();
        let d = m.n_rows();
        let sim = TierSim::default();
        let mut ws = WorkingSet::new(&m, 2);
        ws.swap_in(&m, &[3, 7], &sim, Tier::Slow);
        let vv: Vec<f32> = (0..d).map(|i| (i % 5) as f32 * 0.25).collect();
        let y: Vec<f32> = (0..d).map(|i| (i % 3) as f32 * 0.5).collect();
        let v = SharedVector::from_slice(&vv, 64);
        let kind = ModelKind::Lasso { lam: 0.1, lip_b: 1.0 };
        let got = ws.dot_mapped(0, &v, &y, kind, 0, d);
        let want: f32 = ws
            .dense_col(0)
            .iter()
            .enumerate()
            .map(|(r, &x)| x * (vv[r] - y[r]))
            .sum();
        assert!((got - want).abs() < 1e-3);
        // split ranges compose
        let parts = ws.dot_mapped(0, &v, &y, kind, 0, d / 2)
            + ws.dot_mapped(0, &v, &y, kind, d / 2, d);
        assert!((parts - want).abs() < 1e-3);
        // axpy
        ws.axpy_locked(1, &v, 0.5, 0, d);
        for r in 0..d {
            let exp = vv[r] + 0.5 * ws.dense_col(1)[r];
            assert!((v.read(r) - exp).abs() < 1e-5);
        }
    }

    #[test]
    fn sparse_working_set_roundtrip() {
        let g = generate(DatasetKind::News20Like, Family::Regression, 0.05, 82);
        let sim = TierSim::default();
        let mut ws = WorkingSet::new(&g.matrix, 8);
        let batch: Vec<usize> = (0..8).map(|i| i * 3).collect();
        ws.swap_in(&g.matrix, &batch, &sim, Tier::Slow);
        if let Matrix::Sparse(sm) = &g.matrix {
            let d = sm.n_rows();
            let v = SharedVector::from_slice(&vec![1.0; d], 1024);
            let y = vec![0.0f32; d];
            let kind = ModelKind::Ridge { lam: 1.0 };
            for (slot, &j) in batch.iter().enumerate() {
                let got = ws.dot_mapped(slot, &v, &y, kind, 0, d);
                let want = sm.dot(j, &vec![1.0; d]);
                assert!((got - want).abs() < 1e-4, "slot {slot}");
                assert!((ws.sq_norm(slot) - sm.sq_norm(j)).abs() < 1e-5);
            }
        } else {
            panic!("expected sparse");
        }
        // second swap must not exhaust the pool
        ws.swap_in(&g.matrix, &batch, &sim, Tier::Slow);
    }

    #[test]
    fn quantized_working_set_by_reference() {
        let m = dense_matrix();
        let q = match m {
            Matrix::Dense(dm) => Matrix::Quantized(QuantizedMatrix::from_dense(&dm)),
            _ => unreachable!(),
        };
        let sim = TierSim::default();
        let mut ws = WorkingSet::new(&q, 4);
        ws.swap_in(&q, &[1, 2], &sim, Tier::Slow);
        // charged at the quantized byte count (much smaller than dense)
        let charged = sim.stats(Tier::Fast).write_bytes;
        assert!(charged < 2 * (q.n_rows() as u64) * 4 / 3);
        let d = q.n_rows();
        let v = SharedVector::from_slice(&vec![0.5; d], 1024);
        let y = vec![0.0f32; d];
        let got = ws.dot_mapped(0, &v, &y, ModelKind::Ridge { lam: 1.0 }, 0, d);
        if let Matrix::Quantized(qm) = &q {
            let want: f32 = qm.col_dense(1).iter().map(|x| x * 0.5).sum();
            assert!((got - want).abs() < 1e-3);
        }
    }

    #[test]
    #[should_panic]
    fn batch_larger_than_slots_panics() {
        let m = dense_matrix();
        let sim = TierSim::default();
        let mut ws = WorkingSet::new(&m, 2);
        ws.swap_in(&m, &[0, 1, 2], &sim, Tier::Slow);
    }

    #[test]
    fn dense_matrix_helper_is_dense() {
        // guard: the helper used above really produces a DenseMatrix
        assert!(matches!(dense_matrix(), Matrix::Dense(_)));
        let _ = DenseMatrix::from_col_major(1, 1, vec![1.0]);
    }
}
