//! Task B: asynchronous parallel SCD over the selected batch
//! (paper §III, §IV-A2, §IV-B).
//!
//! `T_B` updater *groups* work concurrently; each group claims a
//! *tile* of work items at a time from the shard-pinned
//! [`TileScheduler`] (one `fetch_add` per tile instead of per
//! coordinate, with work stealing from the heaviest remaining shard
//! once a group drains its own) so that "each coordinate is processed
//! exactly once" per epoch.  Within a group,
//! `V_B` lanes split the vector work (dot + axpy) by row ranges and
//! synchronize with the counter-barrier pattern of §IV-B:
//!
//! 1. one barrier per *block* after the leader publishes the claim,
//! 2. barrier after the partial dots (each lane overwrites its own
//!    partials slot, so no reset step is needed between coordinates;
//!    the leader then forms delta via the scalar `h-hat`),
//! 3. barrier after delta publication; lanes apply the locked
//!    `v += delta * d_i` on their own row ranges, which no other lane
//!    reads, so the next coordinate's dot can start without a third
//!    per-update barrier.
//!
//! The shared vector `v` is updated under medium-grained chunk locks
//! (§IV-C) to preserve the primal-dual relation `w = grad f(D alpha)`
//! that the PASSCoDe-atomic analysis requires.

use super::shared_vec::SharedVector;
use super::working_set::WorkingSet;
use crate::glm::ModelKind;
use crate::memory::{Tier, TierSim};
use crate::sched::TileScheduler;
use crate::sync::{AtomicU32, AtomicU64, Ordering};
use crate::threadpool::{SpinBarrier, WorkerPool};

/// Lane-0's published claim: `(lo << 32) | hi` over the item list, or
/// [`SPAN_DONE`] when the scheduler is drained.  One word, so the
/// non-leader lanes read the whole tile with a single acquire load.
const SPAN_DONE: u64 = u64::MAX;

fn pack_span(lo: usize, hi: usize) -> u64 {
    debug_assert!(hi < u32::MAX as usize, "item list fits u32 indices");
    ((lo as u64) << 32) | hi as u64
}

fn unpack_span(s: u64) -> (usize, usize) {
    ((s >> 32) as usize, (s & u32::MAX as u64) as usize)
}

/// Per-group shared state for the V_B-lane update protocol.
///
/// Ordering contract: every field below is written Release and read
/// Acquire, and each store→load pair additionally straddles a
/// `barrier.wait()` — the barrier alone would suffice for visibility,
/// but the explicit edges keep each word independently well-published
/// (and keep TSan quiet about the f32-bits handoffs).
struct Group {
    barrier: SpinBarrier,
    /// f32 bits, one per lane; lane i Release-stores its partial before
    /// the "partials complete" barrier, lane 0 Acquire-loads after it.
    partials: Vec<AtomicU32>,
    /// Packed claimed item range (pack_span); lane 0 Release-publishes,
    /// others Acquire-read after the "tile published" barrier.
    span: AtomicU64,
    /// f32 bits of the computed delta; same lane-0-publish shape.
    delta: AtomicU32,
}

/// Statistics from one epoch of task B.
#[derive(Debug, Default, Clone, Copy)]
pub struct BStats {
    pub updates: u64,
    pub zero_deltas: u64,
}

/// One unit of task-B work: which working-set slot holds the column,
/// and which model coordinate it belongs to.  (HTHC swaps batch entry i
/// into slot i, so slot == queue position; ST keeps the whole matrix
/// resident with slot == coordinate and shuffles only the processing
/// order — the two must not be conflated.)
#[derive(Clone, Copy, Debug)]
pub struct WorkItem {
    pub slot: u32,
    pub coord: u32,
}

impl WorkItem {
    /// HTHC layout: batch entry i was swapped into working-set slot i.
    pub fn from_batch(batch: &[usize]) -> Vec<WorkItem> {
        batch
            .iter()
            .enumerate()
            .map(|(i, &j)| WorkItem { slot: i as u32, coord: j as u32 })
            .collect()
    }

    /// Resident layout (ST): slot == coordinate, `order` gives the
    /// processing sequence.
    pub fn from_resident_order(order: &[usize]) -> Vec<WorkItem> {
        order
            .iter()
            .map(|&j| WorkItem { slot: j as u32, coord: j as u32 })
            .collect()
    }
}

/// Run one epoch of task B over the given work items (each exactly
/// once).  `alpha` is indexed by original coordinate id.  The pool must
/// have exactly `t_b * v_b` workers.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    pool: &WorkerPool,
    ws: &WorkingSet<'_>,
    items: &[WorkItem],
    v: &SharedVector,
    y: &[f32],
    alpha: &SharedVector,
    kind: ModelKind,
    t_b: usize,
    v_b: usize,
    sim: &TierSim,
) -> BStats {
    assert_eq!(pool.len(), t_b * v_b, "pool size != T_B * V_B");
    let d = ws.n_rows();
    let groups: Vec<Group> = (0..t_b)
        .map(|_| Group {
            barrier: SpinBarrier::new(v_b),
            partials: (0..v_b).map(|_| AtomicU32::new(0)).collect(),
            span: AtomicU64::new(SPAN_DONE),
            delta: AtomicU32::new(0),
        })
        .collect();
    let updates = AtomicU64::new(0);
    let zero_deltas = AtomicU64::new(0);
    // Groups claim item *tiles*, not single items: one claim fetch_add
    // amortizes over `claim` coordinates (the §IV-D bulk-sweep claim
    // granularity), sized so small batches still spread across groups.
    // The scheduler shards the item list one shard per group; a group
    // that drains its shard steals from the heaviest remainder.
    let claim = (items.len() / (t_b * 8)).clamp(1, crate::kernels::BLOCK_COLS);
    let sched = TileScheduler::new(items.len(), t_b, claim);

    pool.run(|wid| {
        let g = wid / v_b;
        let lane = wid % v_b;
        let group = &groups[g];
        // Row range for this lane (dense split; sparse uses row windows).
        let lo = lane * d / v_b;
        let hi = (lane + 1) * d / v_b;
        let mut local_bytes = 0u64;
        loop {
            // Lane 0 claims the next item tile and publishes its span.
            if lane == 0 {
                let span = match sched.claim(g) {
                    Some(t) => pack_span(t.lo, t.hi),
                    None => SPAN_DONE,
                };
                group.span.store(span, Ordering::Release);
            }
            group.barrier.wait(); // tile published
            let span = group.span.load(Ordering::Acquire);
            if span == SPAN_DONE {
                break;
            }
            let (base, end) = unpack_span(span);
            for item in &items[base..end] {
                let (slot, coord) = (item.slot as usize, item.coord as usize);

                // Partial dot over this lane's rows against live v.
                let part = ws.dot_mapped(slot, v, y, kind, lo, hi);
                group.partials[lane].store(part.to_bits(), Ordering::Release);
                group.barrier.wait(); // barrier: partials complete

                if lane == 0 {
                    // every lane overwrites its own partials slot before
                    // the barrier above, so no reset between items is
                    // needed — the sum only ever reads fresh stores
                    let u: f32 = group
                        .partials
                        .iter()
                        .map(|p| f32::from_bits(p.load(Ordering::Acquire)))
                        .sum();
                    let a = alpha.read(coord);
                    let delta = kind.delta(u, a, ws.sq_norm(slot));
                    group.delta.store(delta.to_bits(), Ordering::Release);
                    if delta != 0.0 {
                        alpha.write(coord, a + delta);
                        updates.fetch_add(1, Ordering::Relaxed);
                    } else {
                        zero_deltas.fetch_add(1, Ordering::Relaxed);
                    }
                }
                group.barrier.wait(); // barrier: delta published
                let delta = f32::from_bits(group.delta.load(Ordering::Acquire));
                if delta != 0.0 {
                    ws.axpy_locked(slot, v, delta, lo, hi);
                }
                // fast-tier traffic: col read (dot) + col read + v rw (axpy)
                local_bytes += ((hi - lo) * 4 * 3) as u64;
            }
        }
        sim.read(Tier::Fast, local_bytes);
    });

    BStats {
        updates: updates.load(Ordering::Relaxed),
        zero_deltas: zero_deltas.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::data::Matrix;
    use crate::glm::{GlmModel, Lasso, Ridge};

    fn setup() -> (Matrix, Vec<f32>) {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 101);
        (g.matrix, g.targets)
    }

    /// After an epoch, v must equal D * alpha exactly (no lost updates):
    /// the §IV-C atomicity invariant.
    fn check_v_consistency(m: &Matrix, v: &SharedVector, alpha: &SharedVector) {
        let n = m.n_cols();
        let a: Vec<f32> = (0..n).map(|j| alpha.read(j)).collect();
        let want = match m {
            Matrix::Dense(dm) => dm.matvec_alpha(&a),
            Matrix::Sparse(sm) => sm.matvec_alpha(&a),
            _ => unreachable!(),
        };
        for (r, &wv) in want.iter().enumerate() {
            assert!(
                (v.read(r) - wv).abs() < 1e-2 * wv.abs().max(1.0),
                "v[{r}] = {} want {wv}",
                v.read(r)
            );
        }
    }

    fn run_b(t_b: usize, v_b: usize, model: &dyn GlmModel, seed: u64) {
        let (m, y) = setup();
        let (d, n) = (m.n_rows(), m.n_cols());
        let sim = TierSim::default();
        let batch: Vec<usize> = (0..n / 2).map(|i| i * 2).collect();
        let mut ws = WorkingSet::new(&m, batch.len());
        ws.swap_in(&m, &batch, &sim, Tier::Slow);
        let v = SharedVector::new(d, 64);
        let alpha = SharedVector::new(n, usize::MAX >> 1);
        let _ = seed;
        let pool = WorkerPool::with_name(t_b * v_b, "test-b");
        let items = WorkItem::from_batch(&batch);
        let stats = run_epoch(
            &pool, &ws, &items, &v, &y, &alpha, model.kind(), t_b, v_b, &sim,
        );
        assert_eq!(stats.updates + stats.zero_deltas, batch.len() as u64);
        assert!(stats.updates > 0, "some coordinates must move");
        check_v_consistency(&m, &v, &alpha);
        // objective must drop vs alpha = 0
        let a: Vec<f32> = (0..n).map(|j| alpha.read(j)).collect();
        let vv: Vec<f32> = (0..d).map(|r| v.read(r)).collect();
        let obj0 = model.objective(&vec![0.0; d], &y, &vec![0.0; n]);
        let obj1 = model.objective(&vv, &y, &a);
        assert!(obj1 < obj0, "{obj1} < {obj0}");
    }

    #[test]
    fn sequential_group_single_lane() {
        run_b(1, 1, &Lasso::new(0.05), 1);
    }

    #[test]
    fn parallel_groups() {
        run_b(4, 1, &Lasso::new(0.05), 2);
    }

    #[test]
    fn split_vectors() {
        run_b(1, 4, &Ridge::new(0.5), 3);
    }

    #[test]
    fn groups_and_lanes_combined() {
        run_b(3, 2, &Ridge::new(0.5), 4);
    }

    #[test]
    fn t_b_1_matches_reference_sequential_cd() {
        // With one group and one lane, B is exactly sequential CD over
        // the batch — cross-check against glm::solve-style updates.
        let (m, y) = setup();
        let (d, n) = (m.n_rows(), m.n_cols());
        let sim = TierSim::default();
        let model = Lasso::new(0.05);
        let kind = model.kind();
        let batch: Vec<usize> = (0..8).collect();
        let mut ws = WorkingSet::new(&m, 8);
        ws.swap_in(&m, &batch, &sim, Tier::Slow);
        let v = SharedVector::new(d, 1024);
        let alpha = SharedVector::new(n, usize::MAX >> 1);
        let pool = WorkerPool::with_name(1, "test-b");
        run_epoch(&pool, &ws, &WorkItem::from_batch(&batch), &v, &y, &alpha, kind, 1, 1, &sim);

        // manual sequential replay
        let mut v_ref = vec![0.0f32; d];
        let mut a_ref = vec![0.0f32; n];
        let ops = m.as_ops();
        for &j in &batch {
            let w: Vec<f32> = v_ref.iter().zip(&y).map(|(&vj, &yj)| kind.w_of(vj, yj)).collect();
            let u = ops.dot(j, &w);
            let delta = kind.delta(u, a_ref[j], ops.sq_norm(j));
            if delta != 0.0 {
                a_ref[j] += delta;
                ops.axpy(j, delta, &mut v_ref);
            }
        }
        for j in 0..n {
            assert!((alpha.read(j) - a_ref[j]).abs() < 1e-5, "alpha[{j}]");
        }
        for r in 0..d {
            assert!((v.read(r) - v_ref[r]).abs() < 1e-4, "v[{r}]");
        }
    }

    #[test]
    fn span_packing_roundtrips_and_reserves_done() {
        for (lo, hi) in [(0usize, 1usize), (0, 0), (7, 900), (1 << 20, (1 << 20) + 8)] {
            assert_eq!(unpack_span(pack_span(lo, hi)), (lo, hi));
            assert_ne!(pack_span(lo, hi), SPAN_DONE);
        }
    }

    #[test]
    #[should_panic]
    fn pool_size_mismatch_panics() {
        let (m, y) = setup();
        let sim = TierSim::default();
        let batch = vec![0usize];
        let mut ws = WorkingSet::new(&m, 1);
        ws.swap_in(&m, &batch, &sim, Tier::Slow);
        let v = SharedVector::new(m.n_rows(), 64);
        let alpha = SharedVector::new(m.n_cols(), usize::MAX >> 1);
        let pool = WorkerPool::with_name(3, "test-b"); // != 2*2
        run_epoch(&pool, &ws, &WorkItem::from_batch(&batch), &v, &y, &alpha,
            Lasso::new(0.1).kind(), 2, 2, &sim);
    }
}
