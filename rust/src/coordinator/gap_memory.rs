//! The gap memory `z in R^n` (paper §III).
//!
//! Task A's threads write `z_i` concurrently (one writer per coordinate
//! at a time in practice, but nothing enforces it — writes are atomic
//! stores and last-writer-wins is fine for an importance *heuristic*).
//! Staleness is tracked per-coordinate by the epoch stamp of the last
//! refresh: the paper's convergence argument needs a sufficient fraction
//! (~15%, §IV-F) of z refreshed every epoch, which [`GapMemory::refresh_stats`]
//! reports and the benches assert.
//!
//! Each entry packs `(f32 gap bits, u32 epoch stamp)` into **one**
//! `AtomicU64`, so the pair is always read and written atomically.
//! With two independent relaxed atomics (the previous layout) a reader
//! could observe a *fresh stamp paired with a stale gap value* — e.g.
//! `refresh_stats` counting an entry as refreshed whose value was still
//! the old epoch's, or selection ranking a coordinate on a gap that the
//! fresh stamp claims is current.  Last-writer-wins on the whole pair
//! is the intended semantics and is now guaranteed; `Relaxed` ordering
//! is still sufficient because no reader infers anything about *other*
//! memory from a gap entry.

use crate::sync::{AtomicU64, Ordering};

/// `(gap bits << 32) | epoch` — one atomic word per coordinate.
#[inline(always)]
fn pack(gap: f32, epoch: u32) -> u64 {
    ((gap.to_bits() as u64) << 32) | epoch as u64
}

#[inline(always)]
fn unpack(word: u64) -> (f32, u32) {
    (f32::from_bits((word >> 32) as u32), word as u32)
}

pub struct GapMemory {
    /// Packed `(z_i, stamp_i)` pairs (see module docs).  Relaxed:
    /// single-word last-writer-wins pairs; no reader infers anything
    /// about other memory from an entry, so no publication edge is
    /// needed (the packing is what rules out torn pairs).
    z: Vec<AtomicU64>,
    /// Updates performed during the current epoch.  Relaxed: a plain
    /// statistics counter read at the epoch boundary.
    epoch_updates: AtomicU64,
}

impl GapMemory {
    /// All-gaps-infinite start: every coordinate looks maximally
    /// important until A has touched it once, so early selection
    /// approximates uniform random (paper: first epoch is random).
    pub fn new(n: usize) -> Self {
        GapMemory {
            z: (0..n).map(|_| AtomicU64::new(pack(f32::INFINITY, 0))).collect(),
            epoch_updates: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.z.len()
    }

    pub fn is_empty(&self) -> bool {
        self.z.is_empty()
    }

    /// Task A's write: refresh `z_i` in epoch `epoch`.  Value and stamp
    /// are published in one atomic store — a reader can never pair this
    /// epoch's stamp with a previous epoch's value.
    #[inline]
    pub fn update(&self, i: usize, gap: f32, epoch: u32) {
        self.z[i].store(pack(gap, epoch), Ordering::Relaxed);
        self.epoch_updates.fetch_add(1, Ordering::Relaxed);
    }

    /// Task B's write-back after processing coordinate `i`: an exact
    /// coordinate step drives that coordinate's own gap to ~0, so its
    /// stale (large) z value must not keep winning selection — without
    /// this, greedy selection re-picks already-handled coordinates and
    /// starves the rest whenever A's refresh fraction is low.  Stamps
    /// the entry fresh but does not count as an A update.
    #[inline]
    pub fn mark_processed(&self, i: usize, residual_gap: f32, epoch: u32) {
        self.z[i].store(pack(residual_gap, epoch), Ordering::Relaxed);
    }

    #[inline]
    pub fn read(&self, i: usize) -> f32 {
        unpack(self.z[i].load(Ordering::Relaxed)).0
    }

    /// The atomically-consistent `(gap, stamp)` pair of coordinate `i`.
    #[inline]
    pub fn read_entry(&self, i: usize) -> (f32, u32) {
        unpack(self.z[i].load(Ordering::Relaxed))
    }

    pub fn values(&self) -> Vec<f32> {
        (0..self.len()).map(|i| self.read(i)).collect()
    }

    /// (updates this epoch, fraction of distinct coords stamped this
    /// epoch).  Called by the leader at the epoch boundary, then reset.
    pub fn refresh_stats(&self, epoch: u32) -> (u64, f64) {
        let updates = self.epoch_updates.load(Ordering::Relaxed);
        let fresh = self
            .z
            .iter()
            .filter(|s| unpack(s.load(Ordering::Relaxed)).1 == epoch)
            .count();
        (updates, fresh as f64 / self.len().max(1) as f64)
    }

    pub fn reset_epoch_counter(&self) {
        self.epoch_updates.store(0, Ordering::Relaxed);
    }

    /// Age (in epochs) of each entry at `epoch` — staleness histogram
    /// input for the diagnostics in EXPERIMENTS.md.
    pub fn staleness(&self, epoch: u32) -> Vec<u32> {
        self.z
            .iter()
            .map(|s| epoch.saturating_sub(unpack(s.load(Ordering::Relaxed)).1))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_infinite_everywhere() {
        let g = GapMemory::new(5);
        assert!(g.values().iter().all(|z| z.is_infinite()));
        assert!(g.staleness(3).iter().all(|&a| a == 3), "initial stamp is epoch 0");
    }

    #[test]
    fn update_and_stats() {
        let g = GapMemory::new(10);
        g.update(3, 0.5, 1);
        g.update(7, 0.25, 1);
        g.update(3, 0.6, 1); // refresh same coord
        let (updates, frac) = g.refresh_stats(1);
        assert_eq!(updates, 3);
        assert!((frac - 0.2).abs() < 1e-12, "2 distinct / 10");
        assert_eq!(g.read(3), 0.6);
        assert_eq!(g.read_entry(3), (0.6, 1));
        g.reset_epoch_counter();
        assert_eq!(g.refresh_stats(1).0, 0);
    }

    #[test]
    fn staleness_ages() {
        let g = GapMemory::new(3);
        g.update(0, 1.0, 1);
        g.update(1, 1.0, 4);
        let s = g.staleness(5);
        assert_eq!(s, vec![4, 1, 5]);
    }

    #[test]
    fn concurrent_updates_all_counted() {
        let g = GapMemory::new(100);
        std::thread::scope(|s| {
            for t in 0..4 {
                let g = &g;
                s.spawn(move || {
                    for i in 0..100 {
                        g.update((t * 25 + i) % 100, i as f32, 2);
                    }
                });
            }
        });
        let (updates, frac) = g.refresh_stats(2);
        assert_eq!(updates, 400);
        assert_eq!(frac, 1.0);
    }

    /// Regression (issue 4): with `z` and `stamp` as two independent
    /// relaxed atomics, a reader could pair a fresh stamp with a stale
    /// value.  Writers maintain the invariant `gap == f(epoch)`; racing
    /// readers must never observe a pair that violates it.
    #[test]
    fn value_and_stamp_are_never_torn() {
        let g = GapMemory::new(8);
        let f = |epoch: u32| epoch as f32 * 3.5 + 1.0;
        let stop = crate::sync::AtomicBool::new(false);
        std::thread::scope(|s| {
            for t in 0..2usize {
                let (g, stop) = (&g, &stop);
                s.spawn(move || {
                    for round in 0..20_000u32 {
                        let epoch = round % 997 + 1;
                        g.update((t * 3 + round as usize) % 8, f(epoch), epoch);
                    }
                    stop.store(true, Ordering::Relaxed);
                });
            }
            for _ in 0..2 {
                let (g, stop) = (&g, &stop);
                s.spawn(move || {
                    let mut i = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let (gap, stamp) = g.read_entry(i % 8);
                        if stamp == 0 {
                            assert!(gap.is_infinite(), "untouched entry must still be +inf");
                        } else {
                            assert_eq!(gap, f(stamp), "torn pair: stamp {stamp} value {gap}");
                        }
                        i += 1;
                    }
                });
            }
        });
    }
}
