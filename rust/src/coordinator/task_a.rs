//! Task A: the gap-memory updater (paper §III, §IV-A2).
//!
//! `T_A` threads refresh `z_i = gap(<w, d_i>, alpha_i)` using the
//! **epoch-start snapshot** of `(v, alpha)` ("A ... computes gap_i with
//! the most recent (i.e., obtained in the previous epoch) parameters",
//! §III).  Because the snapshot is immutable for the whole epoch, A
//! needs no synchronization at all (§IV-B: "Task A does not write to
//! shared variables") — each thread only issues atomic stores into the
//! gap memory.
//!
//! A runs until task B finishes its batch and raises `stop`; one thread
//! per `z_i` update (§IV-A2: multiple threads per update risk deadlock
//! on the stop signal).
//!
//! Both entry points sweep coordinates through the shard-pinned
//! [`TileScheduler`]: each worker owns one contiguous column shard
//! (exactly the [`DatasetView::shards`] split) and claims
//! tile-granular column blocks from it, so every cache line of the
//! epoch-frozen `w` is reused across a whole tile via
//! [`crate::data::BlockOps`] (the §IV-A/IV-D blocked-sweep backend)
//! *and* each worker's streams stay inside its own shard.  The
//! run-until-stopped loop uses cyclic claims (the shard is revisited
//! with period `shard/tile` and the rotation persists across epochs);
//! `run_fixed` drains its coordinate list exactly once, with work
//! stealing from the heaviest remaining shard.
//!
//! [`DatasetView::shards`]: crate::data::DatasetView::shards

use super::gap_memory::GapMemory;
use crate::data::Matrix;
use crate::glm::ModelKind;
use crate::kernels;
use crate::memory::{ReadBatcher, Tier, TierSim};
use crate::sched::TileScheduler;
use crate::sync::{AtomicBool, AtomicU64, Ordering};
use crate::threadpool::WorkerPool;

/// Epoch-frozen inputs for task A.
pub struct ASnapshot<'a> {
    /// Materialized `w = grad f(v_snapshot)` (length d).
    pub w: &'a [f32],
    /// alpha at epoch start (length n).
    pub alpha: &'a [f32],
    pub kind: ModelKind,
    pub epoch: u32,
}

/// Run task A on `pool` until `stop` is raised, claiming column tiles
/// from `sched` (built over all `n` columns with one shard per pool
/// worker).  Returns the number of gap refreshes performed (also
/// counted inside `gaps`).
///
/// `home` is the tier the full matrix lives in (the dataset's recorded
/// placement) — every bulk column read is charged there, batched
/// through [`ReadBatcher`].  Each thread tests `stop` between tiles (a
/// relaxed load — cheap on the hot path).
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    pool: &WorkerPool,
    data: &Matrix,
    snap: &ASnapshot<'_>,
    gaps: &GapMemory,
    stop: &AtomicBool,
    sim: &TierSim,
    home: Tier,
    sched: &TileScheduler,
) -> u64 {
    let ops = data.as_block_ops();
    // Relaxed: per-thread totals folded in after `pool.run` returns;
    // the pool's job handoff is the publication edge.
    let counter = AtomicU64::new(0);
    pool.run(|tid| {
        let mut charges = ReadBatcher::new(sim, home);
        let mut local = 0u64;
        let tile_cols = sched.tile_cols();
        let mut idx = vec![0usize; tile_cols];
        let mut u = vec![0.0f32; tile_cols];
        while !stop.load(Ordering::Relaxed) {
            // one tile per stop-flag check: the whole tile shares a
            // single blocked pass over w, and cyclic claims keep this
            // worker inside its own shard (uniform aging of z)
            let Some(t) = sched.claim_cyclic(tid) else { break };
            let len = t.len();
            for (slot, j) in idx[..len].iter_mut().zip(t.lo..t.hi) {
                *slot = j;
            }
            ops.dots_block(&idx[..len], snap.w, &mut u[..len]);
            for (&j, &uj) in idx[..len].iter().zip(&u[..len]) {
                gaps.update(j, snap.kind.gap(uj, snap.alpha[j]), snap.epoch);
                charges.add(ops.col_bytes(j));
            }
            local += len as u64;
        }
        counter.fetch_add(local, Ordering::Relaxed);
    });
    counter.load(Ordering::Relaxed)
}

/// Sweep task A over an explicit list of coordinates exactly once (used
/// by Fig. 7's fixed-update-budget sensitivity runs and by the PJRT
/// offload path, which processes tile-sized coordinate blocks).  The
/// list is drained through a per-call [`TileScheduler`] (indices into
/// `coords`), so workers claim whole tiles of their own shard first and
/// steal from the heaviest remainder; charges batch through
/// [`ReadBatcher`] exactly like [`run_epoch`].
pub fn run_fixed(
    pool: &WorkerPool,
    data: &Matrix,
    snap: &ASnapshot<'_>,
    gaps: &GapMemory,
    coords: &[usize],
    sim: &TierSim,
    home: Tier,
) {
    let ops = data.as_block_ops();
    let sched = TileScheduler::new(coords.len(), pool.len().max(1), kernels::BLOCK_COLS);
    pool.run(|tid| {
        let mut charges = ReadBatcher::new(sim, home);
        let mut u = [0.0f32; kernels::BLOCK_COLS];
        while let Some(t) = sched.claim(tid) {
            let blk = &coords[t.lo..t.hi];
            ops.dots_block(blk, snap.w, &mut u[..blk.len()]);
            for (&j, &uj) in blk.iter().zip(&u) {
                gaps.update(j, snap.kind.gap(uj, snap.alpha[j]), snap.epoch);
                charges.add(ops.col_bytes(j));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::glm::{GlmModel, Lasso};

    fn setup() -> (Matrix, Vec<f32>, Vec<f32>, ModelKind) {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 91);
        let d = g.d();
        let n = g.n();
        let alpha = vec![0.1f32; n];
        let v = match &g.matrix {
            Matrix::Dense(m) => m.matvec_alpha(&alpha),
            _ => unreachable!(),
        };
        let model = Lasso::new(0.1);
        let kind = model.kind();
        let w: Vec<f32> = v.iter().zip(&g.targets).map(|(&vj, &yj)| kind.w_of(vj, yj)).collect();
        let _ = d;
        (g.matrix, w, alpha, kind)
    }

    #[test]
    fn refreshes_until_stopped_with_correct_values() {
        let (m, w, alpha, kind) = setup();
        let n = m.n_cols();
        let gaps = GapMemory::new(n);
        let stop = AtomicBool::new(false);
        let sim = TierSim::default();
        let pool = WorkerPool::with_name(2, "test-a");
        let sched = TileScheduler::new(n, 2, kernels::BLOCK_COLS);
        let snap = ASnapshot { w: &w, alpha: &alpha, kind, epoch: 1 };

        // stop after a short delay from another thread
        let updates = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                stop.store(true, Ordering::Relaxed);
            });
            run_epoch(&pool, &m, &snap, &gaps, &stop, &sim, Tier::Slow, &sched)
        });
        assert!(updates > 0);
        // values in z match the direct computation wherever refreshed
        // (blocked and per-column dots differ only in summation order,
        // so the tolerance is a little above fp noise)
        let ops = m.as_ops();
        let mut checked = 0;
        for j in 0..n {
            let z = gaps.read(j);
            if z.is_finite() {
                let want = kind.gap(ops.dot(j, &w), alpha[j]);
                assert!((z - want).abs() < 1e-4 * want.abs().max(1.0), "z[{j}]: {z} vs {want}");
                checked += 1;
            }
        }
        assert!(checked > 0);
        assert!(sim.stats(Tier::Slow).read_bytes > 0, "A charges slow tier");
    }

    #[test]
    fn cyclic_sweep_covers_the_whole_gap_memory() {
        // enough tile claims to rotate through both shards: every
        // coordinate must end up refreshed (the uniform-aging property
        // random sampling only gave in expectation)
        let (m, w, alpha, kind) = setup();
        let n = m.n_cols();
        let gaps = GapMemory::new(n);
        let stop = AtomicBool::new(false);
        let sim = TierSim::default();
        let pool = WorkerPool::with_name(2, "test-a");
        let sched = TileScheduler::new(n, 2, kernels::BLOCK_COLS);
        let snap = ASnapshot { w: &w, alpha: &alpha, kind, epoch: 1 };
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(80));
                stop.store(true, Ordering::Relaxed);
            });
            run_epoch(&pool, &m, &snap, &gaps, &stop, &sim, Tier::Slow, &sched)
        });
        let (updates, frac) = gaps.refresh_stats(1);
        if updates >= n as u64 {
            assert!((frac - 1.0).abs() < 1e-9, "full rotation refreshes everything");
        }
    }

    #[test]
    fn run_fixed_touches_exactly_the_given_coords() {
        let (m, w, alpha, kind) = setup();
        let gaps = GapMemory::new(m.n_cols());
        let sim = TierSim::default();
        let pool = WorkerPool::with_name(3, "test-a");
        let snap = ASnapshot { w: &w, alpha: &alpha, kind, epoch: 2 };
        let coords = vec![1, 5, 9, 13];
        run_fixed(&pool, &m, &snap, &gaps, &coords, &sim, Tier::Slow);
        let (updates, frac) = gaps.refresh_stats(2);
        assert_eq!(updates, 4);
        assert!((frac - 4.0 / m.n_cols() as f64).abs() < 1e-9);
        for j in 0..m.n_cols() {
            assert_eq!(gaps.read(j).is_finite(), coords.contains(&j));
        }
        assert!(sim.stats(Tier::Slow).read_bytes > 0, "run_fixed charges are batched but flushed");
    }
}
