//! Task A: the gap-memory updater (paper §III, §IV-A2).
//!
//! `T_A` threads sample coordinates uniformly at random and refresh
//! `z_i = gap(<w, d_i>, alpha_i)` using the **epoch-start snapshot** of
//! `(v, alpha)` ("A ... computes gap_i with the most recent (i.e.,
//! obtained in the previous epoch) parameters", §III).  Because the
//! snapshot is immutable for the whole epoch, A needs no synchronization
//! at all (§IV-B: "Task A does not write to shared variables") — each
//! thread only issues atomic stores into the gap memory.
//!
//! A runs until task B finishes its batch and raises `stop`; one thread
//! per `z_i` update (§IV-A2: multiple threads per update risk deadlock
//! on the stop signal).
//!
//! Both entry points sweep coordinates in *blocks* of
//! [`kernels::BLOCK_COLS`] through [`crate::data::BlockOps`], so each
//! cache line of the epoch-frozen `w` is reused across the whole block
//! instead of re-streamed per column (the §IV-A/IV-D blocked-sweep
//! backend) — task A spends its entire budget in these bulk dots.

use super::gap_memory::GapMemory;
use crate::data::Matrix;
use crate::glm::ModelKind;
use crate::kernels;
use crate::memory::{Tier, TierSim};
use crate::threadpool::WorkerPool;
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};

/// Epoch-frozen inputs for task A.
pub struct ASnapshot<'a> {
    /// Materialized `w = grad f(v_snapshot)` (length d).
    pub w: &'a [f32],
    /// alpha at epoch start (length n).
    pub alpha: &'a [f32],
    pub kind: ModelKind,
    pub epoch: u32,
}

/// Run task A on `pool` until `stop` is raised.  Returns the number of
/// gap refreshes performed (also counted inside `gaps`).
///
/// `home` is the tier the full matrix lives in (the dataset's recorded
/// placement) — every bulk column read is charged there.  Each thread
/// tests `stop` between blocks (a relaxed load — cheap on the hot
/// path).
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    pool: &WorkerPool,
    data: &Matrix,
    snap: &ASnapshot<'_>,
    gaps: &GapMemory,
    stop: &AtomicBool,
    sim: &TierSim,
    home: Tier,
    seed: u64,
) -> u64 {
    let n = data.n_cols();
    let ops = data.as_block_ops();
    let counter = std::sync::atomic::AtomicU64::new(0);
    pool.run(|tid| {
        let mut rng = Rng::new(seed ^ (0x9E37 + tid as u64 * 0x1234_5678_9ABC));
        let mut local = 0u64;
        let mut local_bytes = 0u64;
        let mut block = [0usize; kernels::BLOCK_COLS];
        let mut u = [0.0f32; kernels::BLOCK_COLS];
        while !stop.load(Ordering::Relaxed) {
            // one blocked sweep per stop-flag check: BLOCK_COLS random
            // coordinates share a single pass over w (duplicates within
            // a block are harmless — last write wins, as always)
            for j in block.iter_mut() {
                *j = rng.below(n);
            }
            ops.dots_block(&block, snap.w, &mut u);
            for (&j, &uj) in block.iter().zip(&u) {
                gaps.update(j, snap.kind.gap(uj, snap.alpha[j]), snap.epoch);
                local_bytes += ops.col_bytes(j);
            }
            local += kernels::BLOCK_COLS as u64;
            if local_bytes > (1 << 20) {
                // batch the tier charges to keep atomics off the hot path
                sim.read(home, local_bytes);
                local_bytes = 0;
            }
        }
        sim.read(home, local_bytes);
        counter.fetch_add(local, Ordering::Relaxed);
    });
    counter.load(Ordering::Relaxed)
}

/// Sweep task A over an explicit list of coordinates exactly once (used
/// by Fig. 7's fixed-update-budget sensitivity runs and by the PJRT
/// offload path, which processes tile-sized coordinate blocks).
pub fn run_fixed(
    pool: &WorkerPool,
    data: &Matrix,
    snap: &ASnapshot<'_>,
    gaps: &GapMemory,
    coords: &[usize],
    sim: &TierSim,
    home: Tier,
) {
    let ops = data.as_block_ops();
    let next = std::sync::atomic::AtomicUsize::new(0);
    pool.run(|_tid| {
        let mut local_bytes = 0u64;
        let mut u = [0.0f32; kernels::BLOCK_COLS];
        loop {
            // claim a whole column block, not a single coordinate
            let k = next.fetch_add(kernels::BLOCK_COLS, Ordering::Relaxed);
            if k >= coords.len() {
                break;
            }
            let blk = &coords[k..(k + kernels::BLOCK_COLS).min(coords.len())];
            ops.dots_block(blk, snap.w, &mut u[..blk.len()]);
            for (&j, &uj) in blk.iter().zip(&u) {
                gaps.update(j, snap.kind.gap(uj, snap.alpha[j]), snap.epoch);
                local_bytes += ops.col_bytes(j);
            }
        }
        sim.read(home, local_bytes);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, DatasetKind, Family};
    use crate::glm::{GlmModel, Lasso};

    fn setup() -> (Matrix, Vec<f32>, Vec<f32>, ModelKind) {
        let g = generate(DatasetKind::Tiny, Family::Regression, 1.0, 91);
        let d = g.d();
        let n = g.n();
        let alpha = vec![0.1f32; n];
        let v = match &g.matrix {
            Matrix::Dense(m) => m.matvec_alpha(&alpha),
            _ => unreachable!(),
        };
        let model = Lasso::new(0.1);
        let kind = model.kind();
        let w: Vec<f32> = v.iter().zip(&g.targets).map(|(&vj, &yj)| kind.w_of(vj, yj)).collect();
        let _ = d;
        (g.matrix, w, alpha, kind)
    }

    #[test]
    fn refreshes_until_stopped_with_correct_values() {
        let (m, w, alpha, kind) = setup();
        let n = m.n_cols();
        let gaps = GapMemory::new(n);
        let stop = AtomicBool::new(false);
        let sim = TierSim::default();
        let pool = WorkerPool::with_name(2, "test-a");
        let snap = ASnapshot { w: &w, alpha: &alpha, kind, epoch: 1 };

        // stop after a short delay from another thread
        let updates = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                stop.store(true, Ordering::Relaxed);
            });
            run_epoch(&pool, &m, &snap, &gaps, &stop, &sim, Tier::Slow, 7)
        });
        assert!(updates > 0);
        // values in z match the direct computation wherever refreshed
        // (blocked and per-column dots differ only in summation order,
        // so the tolerance is a little above fp noise)
        let ops = m.as_ops();
        let mut checked = 0;
        for j in 0..n {
            let z = gaps.read(j);
            if z.is_finite() {
                let want = kind.gap(ops.dot(j, &w), alpha[j]);
                assert!((z - want).abs() < 1e-4 * want.abs().max(1.0), "z[{j}]: {z} vs {want}");
                checked += 1;
            }
        }
        assert!(checked > 0);
        assert!(sim.stats(Tier::Slow).read_bytes > 0, "A charges slow tier");
    }

    #[test]
    fn run_fixed_touches_exactly_the_given_coords() {
        let (m, w, alpha, kind) = setup();
        let gaps = GapMemory::new(m.n_cols());
        let sim = TierSim::default();
        let pool = WorkerPool::with_name(3, "test-a");
        let snap = ASnapshot { w: &w, alpha: &alpha, kind, epoch: 2 };
        let coords = vec![1, 5, 9, 13];
        run_fixed(&pool, &m, &snap, &gaps, &coords, &sim, Tier::Slow);
        let (updates, frac) = gaps.refresh_stats(2);
        assert_eq!(updates, 4);
        assert!((frac - 4.0 / m.n_cols() as f64).abs() < 1e-9);
        for j in 0..m.n_cols() {
            assert_eq!(gaps.read(j).is_finite(), coords.contains(&j));
        }
    }
}
